#!/usr/bin/env python3
"""Check one mpampd Prometheus scrape for live job state.

Used by the serve-smoke CI job, which polls `/metrics` while a served
job runs: exit 0 iff the scrape shows at least one running job
(`mpamp_jobs_running >= 1`), process-wide round progress
(`mpamp_rounds_total >= 1`), and a per-job row in the running state
with nonzero rounds.
"""

import sys


def main(path: str) -> int:
    scalars = {}
    running_rows = 0
    for line in open(path):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        name, _, value = line.rpartition(" ")
        if not name:
            continue
        try:
            v = float(value)
        except ValueError:
            continue
        if name.startswith("mpamp_job_rounds{") and 'state="running"' in name:
            if v > 0:
                running_rows += 1
        scalars[name] = v
    ok = (
        scalars.get("mpamp_jobs_running", 0) >= 1
        and scalars.get("mpamp_rounds_total", 0) >= 1
        and running_rows >= 1
    )
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1]))
