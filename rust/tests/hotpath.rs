//! End-to-end pins for the zero-allocation hot path.
//!
//! The pooled, encode-once, scratch-reuse runtime must be *numerically
//! invisible*: a session whose kernels run fully serial (`threads = 1` —
//! the pre-refactor arithmetic, chunk-free) and one whose kernels
//! dispatch chunks to the persistent pool (`threads = 4`) must produce
//! **bit-for-bit identical** estimates, on both partitionings, with raw
//! and entropy-coded uplinks, over both transports. Together with the
//! linalg property tests (pooled kernels ≡ serial kernels bitwise) and
//! the engine `*_into` pins, this is the contract that lets the runtime
//! change freely underneath the paper's numerics.

use mpamp::config::{Partitioning, TransportKind};
use mpamp::{RunReport, SessionBuilder};

fn run(
    partitioning: Partitioning,
    transport: TransportKind,
    compressor: &str,
    raw: bool,
    threads: usize,
    batch: usize,
) -> RunReport {
    let builder = SessionBuilder::test_small(0.05)
        .partitioning(partitioning)
        .transport(transport)
        .compressor(compressor)
        .threads(threads)
        .batch(batch);
    let builder = if raw { builder.uncompressed() } else { builder.fixed_rate(4.0) };
    builder.build().unwrap().run().unwrap()
}

fn assert_reports_bit_identical(a: &RunReport, b: &RunReport, label: &str) {
    assert_eq!(a.iters.len(), b.iters.len(), "{label}: iteration count");
    for (ra, rb) in a.iters.iter().zip(&b.iters) {
        assert_eq!(
            ra.sdr_db.to_bits(),
            rb.sdr_db.to_bits(),
            "{label}: SDR trajectory diverged at t={}",
            ra.t
        );
        assert_eq!(
            ra.sigma_d2_hat.to_bits(),
            rb.sigma_d2_hat.to_bits(),
            "{label}: σ̂² diverged at t={}",
            ra.t
        );
        assert_eq!(
            ra.rate_wire.to_bits(),
            rb.rate_wire.to_bits(),
            "{label}: wire rate diverged at t={}",
            ra.t
        );
    }
    assert_eq!(a.final_xs.len(), b.final_xs.len(), "{label}");
    for (j, (xa, xb)) in a.final_xs.iter().zip(&b.final_xs).enumerate() {
        assert_eq!(xa.len(), xb.len(), "{label}: signal {j}");
        for (i, (va, vb)) in xa.iter().zip(xb).enumerate() {
            assert_eq!(
                va.to_bits(),
                vb.to_bits(),
                "{label}: final_x[{j}][{i}] differs ({va} vs {vb})"
            );
        }
    }
}

/// The full grid: {row, column} × {raw, ecsq.range} × {inproc, tcp},
/// serial (threads = 1) vs pooled (threads = 4), B = 2 so the batched
/// staging/scratch paths are exercised too.
#[test]
fn pooled_session_bitwise_reproduces_serial_session_across_grid() {
    for partitioning in [Partitioning::Row, Partitioning::Column] {
        for raw in [true, false] {
            for transport in [TransportKind::InProc, TransportKind::Tcp] {
                let label = format!(
                    "{}/{}/{}",
                    partitioning.as_str(),
                    if raw { "raw" } else { "ecsq.range" },
                    match transport {
                        TransportKind::InProc => "inproc",
                        TransportKind::Tcp => "tcp",
                    }
                );
                let serial =
                    run(partitioning, transport, "ecsq.range", raw, 1, 2);
                let pooled =
                    run(partitioning, transport, "ecsq.range", raw, 4, 2);
                assert_reports_bit_identical(&serial, &pooled, &label);
            }
        }
    }
}

/// The grid above runs below the parallel gates (test_small shards are
/// tiny), pinning the encode-once/scratch-reuse plumbing. This test makes
/// the pool actually engage end-to-end: N = 32 768 puts every worker
/// shard at/above `PAR_MIN_ENTRIES` (row: 32 × 32 768 × B=1 = 1M
/// multiply-adds; column: 64 × 16 384 = 1M), so the threads = 4 session
/// dispatches real pool chunks — the row scenario through the fused
/// LC-step kernel's parallel branch, the column scenario through the
/// pooled matmul/matmul_t — while threads = 1 runs the serial fused
/// panel pass. The estimates must still match bit-for-bit: the blocked
/// microkernels use absolute column tiles and ascending-row transposed
/// accumulation, so each output element sums in one fixed order
/// regardless of chunking or fusion.
///
/// The GC denoiser deliberately stays below its own 64k crossover here:
/// its η′ mean folds per-chunk f64 partials, so *chunk count* (i.e. the
/// thread setting) legitimately perturbs that reduction's f64 bits —
/// exactly as the pre-pool spawn kernel did. Thread-count invariance is
/// a property of the matrix kernels, not of the chunked reduction.
#[test]
fn pool_engaged_session_bitwise_matches_serial_session() {
    for partitioning in [Partitioning::Row, Partitioning::Column] {
        let build = |threads: usize| {
            SessionBuilder::test_small(0.05)
                .dims(32_768, 64)
                .workers(2)
                .iters(2)
                .partitioning(partitioning)
                .uncompressed()
                .threads(threads)
                .build()
                .unwrap()
                .run()
                .unwrap()
        };
        let serial = build(1);
        let pooled = build(4);
        assert_reports_bit_identical(
            &serial,
            &pooled,
            &format!("{}/pool-engaged", partitioning.as_str()),
        );
    }
}

/// Transports must also agree with each other (the frame-buffer reuse and
/// pooled inproc buffers change the plumbing, never the bytes).
#[test]
fn tcp_session_bitwise_matches_inproc_session() {
    for partitioning in [Partitioning::Row, Partitioning::Column] {
        let inproc =
            run(partitioning, TransportKind::InProc, "ecsq.range", false, 2, 3);
        let tcp = run(partitioning, TransportKind::Tcp, "ecsq.range", false, 2, 3);
        assert_reports_bit_identical(
            &inproc,
            &tcp,
            &format!("{}/inproc-vs-tcp", partitioning.as_str()),
        );
    }
}

/// Running the identical session twice must be deterministic — the
/// reused scratch and recycled frame buffers cannot leak state between
/// rounds or sessions.
#[test]
fn repeated_sessions_are_deterministic() {
    let a = run(Partitioning::Row, TransportKind::InProc, "ecsq.huffman", false, 4, 2);
    let b = run(Partitioning::Row, TransportKind::InProc, "ecsq.huffman", false, 4, 2);
    assert_reports_bit_identical(&a, &b, "repeat row/huffman");
    let a =
        run(Partitioning::Column, TransportKind::InProc, "ecsq-dithered.range", false, 4, 2);
    let b =
        run(Partitioning::Column, TransportKind::InProc, "ecsq-dithered.range", false, 4, 2);
    assert_reports_bit_identical(&a, &b, "repeat column/dithered");
}
