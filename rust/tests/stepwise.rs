//! Regression tests for the stepwise session driver: `Session::run()` is
//! a thin loop over `step()`, and both must reproduce the monolithic
//! fusion loop's numerics exactly (the seed behaviour) for a fixed
//! seed/config.

use mpamp::config::{RunConfig, ScheduleKind, TransportKind};
use mpamp::observe::{RecordLog, StopRule, StopSet};
use mpamp::Session;
use mpamp::SessionBuilder;

fn cfg_for(schedule: ScheduleKind) -> RunConfig {
    let mut cfg = RunConfig::test_small(0.05);
    if matches!(schedule, ScheduleKind::Dp { .. }) {
        // Shrink the Blahut–Arimoto substrate so the DP cache builds in
        // test time (mirrors reproduction.rs's mid-scale settings).
        cfg.rd = mpamp::config::RdConfig {
            alphabet: 161,
            curve_points: 12,
            tol: 1e-5,
            gamma_grid: 9,
        };
    }
    cfg.schedule = schedule;
    cfg
}

/// The equivalence criterion: for a fixed seed/config, the `iters`
/// trajectory (SDR, wire rate, everything else) of `run()` — which is
/// built on `step()` — matches a manual `step()` loop to well below
/// 1e-12. Exercised across every schedule family.
#[test]
fn run_equals_manual_step_loop_across_schedules() {
    for schedule in [
        ScheduleKind::Uncompressed,
        ScheduleKind::Fixed { bits: 4.0 },
        ScheduleKind::BackTrack { ratio_max: 1.02, r_max: 6.0 },
        ScheduleKind::Dp { total_rate: Some(8.0), delta_r: 0.5 },
    ] {
        let label = format!("{schedule:?}");
        let whole = Session::new(cfg_for(schedule.clone()))
            .unwrap()
            .run()
            .unwrap();

        let mut session = Session::new(cfg_for(schedule)).unwrap();
        while session.step().unwrap().is_some() {}
        let stepped = session.finish().unwrap();

        assert_eq!(whole.iters.len(), stepped.iters.len(), "{label}");
        assert!(!whole.iters.is_empty(), "{label}");
        for (a, b) in whole.iters.iter().zip(&stepped.iters) {
            assert!((a.sdr_db - b.sdr_db).abs() < 1e-12, "{label} t={}", a.t);
            assert!((a.sdr_pred_db - b.sdr_pred_db).abs() < 1e-12, "{label}");
            assert!((a.rate_wire - b.rate_wire).abs() < 1e-12, "{label}");
            assert!((a.rate_alloc - b.rate_alloc).abs() < 1e-12, "{label}");
            assert!((a.sigma_d2_hat - b.sigma_d2_hat).abs() < 1e-12, "{label}");
            assert!((a.sigma_q2 - b.sigma_q2).abs() < 1e-12, "{label}");
        }
        for (a, b) in whole.final_x().iter().zip(stepped.final_x()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{label}: final_x differs");
        }
    }
}

/// `run()` must also agree with a hand-driven [`ProtocolCore`] — the
/// generic round implementation the session wraps — on the identical
/// instance: the scenario-generic refactor moved the loop, not the
/// numerics.
#[test]
fn session_matches_hand_driven_protocol_core() {
    use mpamp::alloc::schedule::allocator_from_config;
    use mpamp::coordinator::scenario::{ProtocolCore, Row, Scenario};
    use mpamp::coordinator::transport::inproc_pair;
    use mpamp::coordinator::worker::{run_scenario_worker, WorkerParams};
    use mpamp::engine::RustEngine;
    use mpamp::metrics::ByteMeter;
    use mpamp::se::StateEvolution;
    use mpamp::signal::{Batch, ProblemDims};
    use mpamp::util::rng::Rng;
    use std::sync::Arc;

    let cfg = cfg_for(ScheduleKind::Fixed { bits: 4.0 });
    let mut rng = Rng::new(cfg.seed);
    let batch = Batch::generate(
        cfg.prior,
        ProblemDims { n: cfg.n, m: cfg.m, sigma_e2: cfg.sigma_e2() },
        &mut rng,
        1,
    )
    .unwrap();

    // Hand-driven path: raw transports + the generic core, no Session.
    let se = StateEvolution::new(cfg.prior, cfg.kappa(), cfg.sigma_e2());
    let controller = allocator_from_config(&cfg, &se, None).unwrap();
    let engine = RustEngine::new(cfg.prior, cfg.threads);
    let meter = Arc::new(ByteMeter::new());
    let shards = <Row as Scenario>::split(&batch, cfg.p).unwrap();
    let (mut fusion_eps, worker_eps): (Vec<_>, Vec<_>) =
        (0..cfg.p).map(|_| inproc_pair(meter.clone())).unzip();
    let (records, final_xs) = std::thread::scope(|s| {
        for (id, (shard, mut ep)) in
            shards.into_iter().zip(worker_eps.into_iter()).enumerate()
        {
            let params = WorkerParams {
                id: id as u32,
                p_workers: cfg.p,
                batch: 1,
                prior: cfg.prior,
            };
            let engine = &engine;
            s.spawn(move || {
                run_scenario_worker::<Row>(&params, &shard, engine, &mut ep)
            });
        }
        let mut core: ProtocolCore<Row> = ProtocolCore::new(&batch, &cfg);
        let mut records = Vec::new();
        for _ in 0..cfg.iters {
            records.push(
                core.step(
                    &cfg,
                    &se,
                    controller.as_ref(),
                    None,
                    &engine,
                    &mut fusion_eps,
                    Some(&batch),
                )
                .unwrap(),
            );
        }
        ProtocolCore::<Row>::finish(&mut fusion_eps).unwrap();
        drop(fusion_eps);
        (records, core.into_xs())
    });

    // Stepwise path on the same instance.
    let report = SessionBuilder::from_config(cfg)
        .instance(batch.instance(0))
        .build()
        .unwrap()
        .run()
        .unwrap();

    assert_eq!(records.len(), report.iters.len());
    for (a, b) in records.iter().zip(&report.iters) {
        assert!((a.sdr_db - b.sdr_db).abs() < 1e-12, "t={}", a.t);
        assert!((a.rate_wire - b.rate_wire).abs() < 1e-12, "t={}", a.t);
    }
    for (a, b) in final_xs[0].iter().zip(report.final_x()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

/// Early stopping honours every built-in rule and reports the reason.
#[test]
fn stop_rules_end_to_end() {
    // Uplink budget: 32 bits/el/iter uncompressed ⇒ 2 iterations spend 64.
    let report = SessionBuilder::test_small(0.05)
        .uncompressed()
        .build()
        .unwrap()
        .run_observed(
            &mut RecordLog::new(),
            &StopSet::none().with(StopRule::UplinkBudget { bits_per_element: 64.0 }),
        )
        .unwrap();
    assert_eq!(report.iters.len(), 2);
    assert!(report.stopped_early.unwrap().contains("uplink budget"));

    // Target SDR: small-scale MP-AMP passes 2 dB well before T=6.
    let report = SessionBuilder::test_small(0.05)
        .fixed_rate(4.0)
        .build()
        .unwrap()
        .run_observed(
            &mut RecordLog::new(),
            &StopSet::none().with(StopRule::TargetSdrDb(2.0)),
        )
        .unwrap();
    assert!(report.iters.len() < 6);
    assert!(report.final_sdr_db() >= 2.0);

    // A rule that never fires leaves the run untouched.
    let report = SessionBuilder::test_small(0.05)
        .fixed_rate(4.0)
        .build()
        .unwrap()
        .run_observed(
            &mut RecordLog::new(),
            &StopSet::none().with(StopRule::TargetSdrDb(1e9)),
        )
        .unwrap();
    assert_eq!(report.iters.len(), 6);
    assert!(report.stopped_early.is_none());
}

/// The stepwise driver works over TCP transports too (workers persist
/// across step() calls on real sockets).
#[test]
fn stepwise_over_tcp() {
    let mut session = SessionBuilder::test_small(0.05)
        .fixed_rate(4.0)
        .transport(TransportKind::Tcp)
        .build()
        .unwrap();
    let mut seen = 0usize;
    while let Some(snap) = session.step().unwrap() {
        assert_eq!(snap.t(), seen);
        seen += 1;
        if seen == 3 {
            break;
        }
    }
    let report = session.finish().unwrap();
    assert_eq!(report.iters.len(), 3);
}
