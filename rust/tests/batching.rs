//! Batching-equivalence coverage: a `B`-signal batched session must be
//! **bit-for-bit** `B` independent `B = 1` sessions run on the extracted
//! per-signal instances — row and column, raw and entropy-coded uplinks.
//! Together with `tests/partitioning.rs` (P = 1 batched sessions equal
//! centralized AMP bit-for-bit, the PR 2 numeric anchor, now executed by
//! the scenario-generic `ProtocolCore`), this pins the refactored core to
//! the pre-refactor numerics exactly.

use std::sync::Arc;

use mpamp::config::{Partitioning, RunConfig, ScheduleKind};
use mpamp::signal::{Batch, ProblemDims};
use mpamp::util::rng::Rng;
use mpamp::Session;

fn test_cfg(
    partitioning: Partitioning,
    schedule: ScheduleKind,
    compressor: &str,
    batch: usize,
) -> RunConfig {
    let mut cfg = RunConfig::test_small(0.05);
    cfg.partitioning = partitioning;
    cfg.schedule = schedule;
    cfg.compressor = compressor.to_string();
    cfg.batch = batch;
    cfg
}

/// Run a `B`-signal batched session and `B` independent `B = 1` sessions
/// on the same per-signal instances; assert the final estimates agree
/// bit-for-bit and the batch-mean records agree to f64 round-off.
fn check_batched_matches_independent(
    partitioning: Partitioning,
    schedule: ScheduleKind,
    compressor: &str,
    b: usize,
) {
    let label = format!("{partitioning:?}/{schedule:?}/{compressor}");
    let cfg = test_cfg(partitioning, schedule.clone(), compressor, b);
    let mut rng = Rng::new(cfg.seed);
    let batch = Arc::new(
        Batch::generate(
            cfg.prior,
            ProblemDims { n: cfg.n, m: cfg.m, sigma_e2: cfg.sigma_e2() },
            &mut rng,
            b,
        )
        .unwrap(),
    );
    let batched = Session::with_batch(cfg, batch.clone()).unwrap().run().unwrap();
    assert_eq!(batched.batch, b, "{label}");
    assert_eq!(batched.final_xs.len(), b, "{label}");

    let mut indep = Vec::with_capacity(b);
    for j in 0..b {
        let cfg1 = test_cfg(partitioning, schedule.clone(), compressor, 1);
        let report = Session::with_instance(cfg1, batch.instance(j))
            .unwrap()
            .run()
            .unwrap();
        indep.push(report);
    }

    // Per-signal final estimates: exact.
    for (j, solo) in indep.iter().enumerate() {
        for (i, (a, bb)) in
            solo.final_x().iter().zip(&batched.final_xs[j]).enumerate()
        {
            assert_eq!(
                a.to_bits(),
                bb.to_bits(),
                "{label}: signal {j} final_x[{i}] {bb} != independent {a}"
            );
        }
        assert_eq!(
            solo.sdr_db_per_signal[0].to_bits(),
            batched.sdr_db_per_signal[j].to_bits(),
            "{label}: signal {j} final SDR"
        );
    }
    // Batch-mean records equal the mean of the independent records.
    assert_eq!(batched.iters.len(), indep[0].iters.len(), "{label}");
    for (t, rec) in batched.iters.iter().enumerate() {
        let bf = b as f64;
        let mean_sdr = indep.iter().map(|r| r.iters[t].sdr_db).sum::<f64>() / bf;
        let mean_sd2 =
            indep.iter().map(|r| r.iters[t].sigma_d2_hat).sum::<f64>() / bf;
        let mean_q2 = indep.iter().map(|r| r.iters[t].sigma_q2).sum::<f64>() / bf;
        let mean_wire = indep.iter().map(|r| r.iters[t].rate_wire).sum::<f64>() / bf;
        let mean_alloc =
            indep.iter().map(|r| r.iters[t].rate_alloc).sum::<f64>() / bf;
        assert!(
            (rec.sdr_db - mean_sdr).abs() < 1e-12,
            "{label} t={t}: batched SDR {} vs mean {mean_sdr}",
            rec.sdr_db
        );
        assert!(
            (rec.sigma_d2_hat - mean_sd2).abs() < 1e-12,
            "{label} t={t}: σ̂² {} vs mean {mean_sd2}",
            rec.sigma_d2_hat
        );
        assert!(
            (rec.sigma_q2 - mean_q2).abs() < 1e-12,
            "{label} t={t}: σ_Q² {} vs mean {mean_q2}",
            rec.sigma_q2
        );
        assert!(
            (rec.rate_wire - mean_wire).abs() < 1e-12,
            "{label} t={t}: batched wire rate {} vs mean {mean_wire}",
            rec.rate_wire
        );
        assert!(
            (rec.rate_alloc - mean_alloc).abs() < 1e-12,
            "{label} t={t}: alloc rate {} vs mean {mean_alloc}",
            rec.rate_alloc
        );
    }
}

#[test]
fn row_batched_raw_matches_independent_runs() {
    check_batched_matches_independent(
        Partitioning::Row,
        ScheduleKind::Uncompressed,
        "ecsq.range",
        8,
    );
}

#[test]
fn row_batched_ecsq_matches_independent_runs() {
    // Real entropy-coded uplinks: per-signal quantizer specs and range
    // coding must be identical to the independent runs, byte for byte.
    check_batched_matches_independent(
        Partitioning::Row,
        ScheduleKind::Fixed { bits: 4.0 },
        "ecsq.range",
        8,
    );
}

#[test]
fn column_batched_raw_matches_independent_runs() {
    check_batched_matches_independent(
        Partitioning::Column,
        ScheduleKind::Uncompressed,
        "ecsq.range",
        4,
    );
}

#[test]
fn column_batched_ecsq_matches_independent_runs() {
    check_batched_matches_independent(
        Partitioning::Column,
        ScheduleKind::Fixed { bits: 4.0 },
        "ecsq.range",
        4,
    );
}

#[test]
fn row_batched_bt_schedule_matches_independent_runs() {
    // The BT controller's online decisions depend on each signal's σ̂²
    // trajectory — per-signal directives must reproduce the independent
    // runs exactly.
    check_batched_matches_independent(
        Partitioning::Row,
        ScheduleKind::BackTrack { ratio_max: 1.05, r_max: 6.0 },
        "ecsq.range",
        4,
    );
}

#[test]
fn batched_tcp_matches_inproc() {
    // Batched frames over real sockets: numerics identical to in-process.
    let mut cfg = test_cfg(
        Partitioning::Row,
        ScheduleKind::Fixed { bits: 4.0 },
        "ecsq.range",
        3,
    );
    let inproc = Session::new(cfg.clone()).unwrap().run().unwrap();
    cfg.transport = mpamp::config::TransportKind::Tcp;
    let tcp = Session::new(cfg).unwrap().run().unwrap();
    for (a, b) in inproc.iters.iter().zip(&tcp.iters) {
        assert!((a.sdr_db - b.sdr_db).abs() < 1e-9, "transport changed numerics");
        assert!((a.rate_wire - b.rate_wire).abs() < 1e-12);
    }
    for (xa, xb) in inproc.final_xs.iter().zip(&tcp.final_xs) {
        for (a, b) in xa.iter().zip(xb) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}

#[test]
fn batched_run_recovers_every_signal() {
    // Sanity beyond equivalence: all B signals actually get recovered.
    let cfg = test_cfg(
        Partitioning::Row,
        ScheduleKind::Fixed { bits: 4.0 },
        "ecsq.range",
        6,
    );
    let report = Session::new(cfg).unwrap().run().unwrap();
    assert_eq!(report.sdr_db_per_signal.len(), 6);
    for (j, &sdr) in report.sdr_db_per_signal.iter().enumerate() {
        assert!(sdr > 5.0, "signal {j}: SDR {sdr} dB");
    }
    assert!(report.signals_per_s() > 0.0);
}
