//! Partitioning-scenario coverage: with `P = 1` and raw (uncompressed)
//! uplinks, both the row- and the column-partitioned sessions execute the
//! *identical arithmetic* as centralized AMP — asserted bit-for-bit over
//! random instances — and at `P > 1` the column scenario (C-MP-AMP)
//! recovers the signal with compressed uplinks. Also the round-trip
//! property of the unit-stride transposed matvec against the dense
//! materialized-transpose reference.

use mpamp::amp::run_centralized;
use mpamp::config::{Partitioning, RunConfig, ScheduleKind};
use mpamp::engine::RustEngine;
use mpamp::linalg::Matrix;
use mpamp::se::StateEvolution;
use mpamp::signal::{BernoulliGauss, Instance, ProblemDims};
use mpamp::util::proptest::{prop_assert, prop_close, Prop};
use mpamp::util::rng::Rng;
use mpamp::Session;

/// A P = 1, uncompressed config on the fast-test dimensions.
fn p1_cfg(partitioning: Partitioning, seed: u64, iters: usize) -> RunConfig {
    let mut cfg = RunConfig::test_small(0.05);
    cfg.p = 1;
    cfg.threads = 2;
    cfg.seed = seed;
    cfg.iters = iters;
    cfg.partitioning = partitioning;
    cfg.schedule = ScheduleKind::Uncompressed;
    cfg
}

/// Run centralized AMP and a P = 1 session on the same instance; compare
/// the trajectories bit-for-bit. Returns an error description on the
/// first mismatch (property-test friendly).
fn check_p1_matches_centralized(
    partitioning: Partitioning,
    seed: u64,
    iters: usize,
) -> Result<(), String> {
    let cfg = p1_cfg(partitioning, seed, iters);
    let mut rng = Rng::new(cfg.seed);
    let inst = Instance::generate(
        cfg.prior,
        ProblemDims { n: cfg.n, m: cfg.m, sigma_e2: cfg.sigma_e2() },
        &mut rng,
    )
    .map_err(|e| e.to_string())?;
    let se = StateEvolution::new(cfg.prior, cfg.kappa(), cfg.sigma_e2());
    let engine = RustEngine::new(cfg.prior, cfg.threads);
    let cent =
        run_centralized(&inst, &se, &engine, cfg.iters).map_err(|e| e.to_string())?;
    let report = Session::with_instance(cfg, inst)
        .map_err(|e| e.to_string())?
        .run()
        .map_err(|e| e.to_string())?;
    if cent.iters.len() != report.iters.len() {
        return Err(format!(
            "{partitioning:?}: iteration counts differ ({} vs {})",
            cent.iters.len(),
            report.iters.len()
        ));
    }
    for (c, r) in cent.iters.iter().zip(&report.iters) {
        if c.sigma_d2_hat.to_bits() != r.sigma_d2_hat.to_bits() {
            return Err(format!(
                "{partitioning:?} t={}: σ̂² {} != centralized {}",
                c.t, r.sigma_d2_hat, c.sigma_d2_hat
            ));
        }
        if c.sdr_db.to_bits() != r.sdr_db.to_bits() {
            return Err(format!(
                "{partitioning:?} t={}: SDR {} != centralized {}",
                c.t, r.sdr_db, c.sdr_db
            ));
        }
    }
    for (i, (a, b)) in cent.final_x.iter().zip(report.final_x()).enumerate() {
        // Plain float equality (tolerates only the ±0.0 ambiguity).
        if a != b {
            return Err(format!(
                "{partitioning:?}: final_x[{i}] {b} != centralized {a}"
            ));
        }
    }
    Ok(())
}

#[test]
fn row_p1_raw_matches_centralized_bit_for_bit() {
    check_p1_matches_centralized(Partitioning::Row, 0x5EED, 6).unwrap();
}

#[test]
fn column_p1_raw_matches_centralized_bit_for_bit() {
    check_p1_matches_centralized(Partitioning::Column, 0x5EED, 6).unwrap();
}

#[test]
fn p1_equivalence_holds_over_random_seeds() {
    // Property form: random seeds, both partitionings, shorter runs.
    Prop::new("P=1 raw == centralized (row & column)", 3).check(|g| {
        let seed = g.u64();
        for partitioning in [Partitioning::Row, Partitioning::Column] {
            check_p1_matches_centralized(partitioning, seed, 3)?;
        }
        Ok(())
    });
}

#[test]
fn column_multiworker_recovers_with_compressed_uplinks() {
    // P = 6 column blocks, 5-bit ECSQ range-coded uplinks: C-MP-AMP must
    // still recover the signal and beat the 32-bit baseline on the wire.
    let mut cfg = RunConfig::test_small(0.05);
    cfg.partitioning = Partitioning::Column;
    cfg.schedule = ScheduleKind::Fixed { bits: 5.0 };
    let report = Session::new(cfg).unwrap().run().unwrap();
    assert_eq!(report.partitioning, "column");
    assert!(
        report.final_sdr_db() > 8.0,
        "C-MP-AMP SDR={}",
        report.final_sdr_db()
    );
    assert!(report.savings_vs_float_pct() > 75.0);
    // The quantization-aware prediction tracks reality loosely.
    for it in report.iters.iter().skip(1) {
        assert!(
            (it.sdr_db - it.sdr_pred_db).abs() < 5.0,
            "t={}: empirical {} vs column SE prediction {}",
            it.t,
            it.sdr_db,
            it.sdr_pred_db
        );
    }
}

#[test]
fn row_and_column_agree_without_quantization_at_same_p() {
    // With raw uplinks the two scenarios compute the same fixed point —
    // different message types, same algorithm. P=6 divides both M=180 and
    // N=600 on the test preset. (Finite-N trajectories differ slightly:
    // the schemes apply the Onsager term through different channels.)
    let mut row_cfg = RunConfig::test_small(0.05);
    row_cfg.schedule = ScheduleKind::Uncompressed;
    let mut col_cfg = row_cfg.clone();
    col_cfg.partitioning = Partitioning::Column;
    let mut rng = Rng::new(row_cfg.seed);
    let inst = std::sync::Arc::new(
        Instance::generate(
            row_cfg.prior,
            ProblemDims {
                n: row_cfg.n,
                m: row_cfg.m,
                sigma_e2: row_cfg.sigma_e2(),
            },
            &mut rng,
        )
        .unwrap(),
    );
    let row = Session::with_instance(row_cfg, inst.clone()).unwrap().run().unwrap();
    let col = Session::with_instance(col_cfg, inst).unwrap().run().unwrap();
    assert!(
        (row.final_sdr_db() - col.final_sdr_db()).abs() < 1.5,
        "row {} vs column {} final SDR",
        row.final_sdr_db(),
        col.final_sdr_db()
    );
}

#[test]
fn transposed_matvec_round_trips_against_dense_reference() {
    Prop::new("matvec_t == dense transposed reference", 40).check(|g| {
        let mut rng = Rng::new(g.u64());
        let r = g.usize_in(1, 60);
        let c = g.usize_in(1, 80);
        let mut data = vec![0f32; r * c];
        rng.fill_gaussian(&mut data, 1.0);
        let a = Matrix::from_vec(r, c, data).map_err(|e| e.to_string())?;
        let at = a.transposed();
        prop_assert(
            at.rows() == c && at.cols() == r,
            format!("transpose shape ({}, {})", at.rows(), at.cols()),
        )?;
        // Aᵀᵀ == A exactly.
        prop_assert(
            at.transposed().data() == a.data(),
            "transpose not involutive",
        )?;
        // Unit-stride transposed matvec vs the dense reference, both ways.
        let z = g.gaussian_vec(r, 1.0);
        let (mut fast, mut dense) = (vec![0f32; c], vec![0f32; c]);
        a.matvec_t(&z, &mut fast);
        at.matvec(&z, &mut dense);
        for i in 0..c {
            prop_close(fast[i] as f64, dense[i] as f64, 1e-4, "Aᵀz")?;
        }
        let x = g.gaussian_vec(c, 1.0);
        let (mut fwd, mut via_t) = (vec![0f32; r], vec![0f32; r]);
        a.matvec(&x, &mut fwd);
        at.matvec_t(&x, &mut via_t);
        for i in 0..r {
            prop_close(fwd[i] as f64, via_t[i] as f64, 1e-4, "(Aᵀ)ᵀx")?;
        }
        Ok(())
    });
}

/// Extraction consistency: column blocks tile the matrix, and the P = 1
/// block is byte-identical to the source (the bit-for-bit guarantee above
/// rests on this).
#[test]
fn column_blocks_tile_and_p1_block_is_identity() {
    let prior = BernoulliGauss::standard(0.1);
    let mut rng = Rng::new(77);
    let inst = Instance::generate(
        prior,
        ProblemDims { n: 120, m: 40, sigma_e2: 1e-3 },
        &mut rng,
    )
    .unwrap();
    let whole = inst.a.col_block(0, 120);
    assert_eq!(whole.data(), inst.a.data());
    let blocks: Vec<Matrix> =
        (0..4).map(|i| inst.a.col_block(i * 30, (i + 1) * 30)).collect();
    for r in 0..40 {
        let mut row = Vec::new();
        for b in &blocks {
            row.extend_from_slice(b.row(r));
        }
        assert_eq!(row.as_slice(), inst.a.row(r), "row {r}");
    }
}
