//! Integration tests for the `mpampd` serving daemon: concurrent served
//! jobs must be **bit-identical** to standalone sessions, over-capacity
//! jobs must queue (not drop), and cancellation must free the slot for
//! the next queued job.

use std::time::{Duration, Instant};

use mpamp::config::{Partitioning, RunConfig, ScheduleKind};
use mpamp::serve::{Client, Daemon, JobEvent, Priority, ServeConfig};
use mpamp::{RunReport, Session};

/// The four smoke scenarios: {row, column} × {entropy-coded (default
/// ecsq.range under BT), uncompressed} — all on one P=6 fleet.
fn job_configs() -> Vec<RunConfig> {
    let mut cfgs = Vec::new();
    for (partitioning, raw, seed) in [
        (Partitioning::Row, false, 101),
        (Partitioning::Row, true, 202),
        (Partitioning::Column, false, 303),
        (Partitioning::Column, true, 404),
    ] {
        let mut cfg = RunConfig::test_small(0.05);
        cfg.partitioning = partitioning;
        cfg.seed = seed;
        if raw {
            cfg.schedule = ScheduleKind::Uncompressed;
        }
        cfgs.push(cfg);
    }
    cfgs
}

/// Everything deterministic must match to the bit; `wall_s` is the one
/// nondeterministic field and is excluded.
fn assert_reports_bit_identical(label: &str, want: &RunReport, got: &RunReport) {
    assert_eq!(want.iters.len(), got.iters.len(), "{label}: iteration count");
    for (t, (w, g)) in want.iters.iter().zip(&got.iters).enumerate() {
        assert_eq!(
            w.sdr_db.to_bits(),
            g.sdr_db.to_bits(),
            "{label}: sdr_db differs at t={t}"
        );
        assert_eq!(
            w.sigma_d2_hat.to_bits(),
            g.sigma_d2_hat.to_bits(),
            "{label}: sigma_d2_hat differs at t={t}"
        );
        assert_eq!(
            w.rate_wire.to_bits(),
            g.rate_wire.to_bits(),
            "{label}: rate_wire differs at t={t}"
        );
    }
    assert_eq!(want.final_xs.len(), got.final_xs.len(), "{label}: batch size");
    for (sig, (wx, gx)) in want.final_xs.iter().zip(&got.final_xs).enumerate() {
        assert_eq!(wx.len(), gx.len(), "{label}: x length, signal {sig}");
        for (i, (w, g)) in wx.iter().zip(gx).enumerate() {
            assert_eq!(
                w.to_bits(),
                g.to_bits(),
                "{label}: final_x[{sig}][{i}] differs"
            );
        }
    }
    assert_eq!(
        want.transport_uplink_bits, got.transport_uplink_bits,
        "{label}: uplink byte accounting"
    );
    assert_eq!(
        want.transport_downlink_bits, got.transport_downlink_bits,
        "{label}: downlink byte accounting"
    );
    assert_eq!(want.schedule, got.schedule, "{label}: schedule name");
    assert_eq!(want.partitioning, got.partitioning, "{label}: partitioning");
}

#[test]
fn four_concurrent_jobs_bit_identical_to_standalone() {
    let cfgs = job_configs();
    // Standalone baselines first (sequential, local fleets).
    let standalone: Vec<RunReport> = cfgs
        .iter()
        .map(|c| Session::new(c.clone()).unwrap().run().unwrap())
        .collect();

    let daemon = Daemon::start(ServeConfig::new("127.0.0.1:0", 6)).unwrap();
    let addr = daemon.addr().to_string();
    // All four jobs in flight at once over the one resident fleet.
    let handles: Vec<_> = cfgs
        .iter()
        .cloned()
        .map(|cfg| {
            let addr = addr.clone();
            std::thread::spawn(move || -> (usize, RunReport) {
                let mut job = Client::submit(&addr, &cfg).unwrap();
                assert_eq!(
                    job.queue_pos(),
                    0,
                    "four jobs fit the default max_sessions=4, none should queue"
                );
                let mut iter_events = 0usize;
                loop {
                    match job.next_event().unwrap() {
                        JobEvent::Started => {}
                        JobEvent::Iter(_) => iter_events += 1,
                        JobEvent::Report(report) => return (iter_events, report),
                        JobEvent::Cancelled => panic!("job unexpectedly cancelled"),
                        JobEvent::Failed(msg) => panic!("daemon error: {msg}"),
                    }
                }
            })
        })
        .collect();
    for ((handle, cfg), want) in handles.into_iter().zip(&cfgs).zip(&standalone) {
        let (iter_events, got) = handle.join().unwrap();
        let label = format!(
            "{} / {:?}",
            cfg.partitioning.as_str(),
            cfg.schedule
        );
        assert_eq!(
            iter_events,
            got.iters.len(),
            "{label}: one progress event per completed round"
        );
        assert_reports_bit_identical(&label, want, &got);
    }
    daemon.shutdown().unwrap();
}

#[test]
fn over_capacity_job_queues_and_cancel_frees_the_slot() {
    let mut serve_cfg = ServeConfig::new("127.0.0.1:0", 6);
    serve_cfg.max_sessions = 1;
    serve_cfg.max_queue = 2;
    let daemon = Daemon::start(serve_cfg).unwrap();
    let addr = daemon.addr().to_string();

    // Job A: long enough to still be running while B submits and queues.
    let mut a_cfg = RunConfig::test_small(0.05);
    a_cfg.iters = 300;
    a_cfg.seed = 1;
    let mut a = Client::submit(&addr, &a_cfg).unwrap();
    assert_eq!(a.queue_pos(), 0);
    assert!(matches!(a.next_event().unwrap(), JobEvent::Started));
    assert!(matches!(a.next_event().unwrap(), JobEvent::Iter(_)));

    // Job B: over capacity — must be queued with a positive position,
    // not dropped.
    let mut b_cfg = RunConfig::test_small(0.05);
    b_cfg.iters = 3;
    b_cfg.seed = 2;
    let b_standalone = Session::new(b_cfg.clone()).unwrap().run().unwrap();
    let b = Client::submit(&addr, &b_cfg).unwrap();
    assert!(
        b.queue_pos() > 0,
        "over-capacity job should be queued, got position {}",
        b.queue_pos()
    );

    // Cancelling A frees the slot; B then runs to completion.
    a.cancel().unwrap();
    loop {
        match a.next_event().unwrap() {
            JobEvent::Iter(_) => {}
            JobEvent::Cancelled => break,
            other => panic!("expected cancellation for job A, got {other:?}"),
        }
    }
    let b_report = b.await_report().unwrap();
    assert_eq!(b_report.iters.len(), 3);
    assert!(b_report.stopped_early.is_none());
    // Waiting in the queue must not perturb the result.
    assert_reports_bit_identical("queued job B", &b_standalone, &b_report);
    daemon.shutdown().unwrap();
}

/// With one running slot, the promotion order IS the start order: a
/// high-priority job submitted *after* a normal one must start (and
/// finish) first once the slot frees up — and the queue-jumping must not
/// perturb either job's result.
#[test]
fn high_priority_job_overtakes_queued_normal_job() {
    let mut serve_cfg = ServeConfig::new("127.0.0.1:0", 6);
    serve_cfg.max_sessions = 1;
    serve_cfg.max_queue = 4;
    let daemon = Daemon::start(serve_cfg).unwrap();
    let addr = daemon.addr().to_string();

    // Job A holds the only slot.
    let mut a_cfg = RunConfig::test_small(0.05);
    a_cfg.iters = 300;
    a_cfg.seed = 41;
    let mut a = Client::submit(&addr, &a_cfg).unwrap();
    assert!(matches!(a.next_event().unwrap(), JobEvent::Started));
    assert!(matches!(a.next_event().unwrap(), JobEvent::Iter(_)));

    // Normal-priority B queues first...
    let mut b_cfg = RunConfig::test_small(0.05);
    b_cfg.iters = 3;
    b_cfg.seed = 42;
    let b_standalone = Session::new(b_cfg.clone()).unwrap().run().unwrap();
    let mut b = Client::submit(&addr, &b_cfg).unwrap();
    assert_eq!(b.queue_pos(), 1);

    // ...then high-priority C is admitted ahead of it.
    let mut c_cfg = RunConfig::test_small(0.05);
    c_cfg.iters = 3;
    c_cfg.seed = 43;
    let c_standalone = Session::new(c_cfg.clone()).unwrap().run().unwrap();
    let mut c = Client::submit_with(&addr, &c_cfg, Priority::High, None).unwrap();
    assert_eq!(
        c.queue_pos(),
        1,
        "a high-priority job reports position 1 ahead of the normal waiter"
    );

    // Watch B from its own thread so its Started instant is observed the
    // moment the daemon sends it.
    let b_watcher = std::thread::spawn(move || {
        let mut started_at = None;
        loop {
            match b.next_event().unwrap() {
                JobEvent::Started => started_at = Some(Instant::now()),
                JobEvent::Iter(_) => {}
                JobEvent::Report(report) => {
                    return (started_at.expect("B reported before starting"), report)
                }
                other => panic!("job B: unexpected event {other:?}"),
            }
        }
    });

    // Free the slot: C (high) must start before B (normal) despite B's
    // earlier submission.
    a.cancel().unwrap();
    loop {
        match a.next_event().unwrap() {
            JobEvent::Iter(_) => {}
            JobEvent::Cancelled => break,
            other => panic!("expected cancellation for job A, got {other:?}"),
        }
    }
    let c_started = loop {
        match c.next_event().unwrap() {
            JobEvent::Started => break Instant::now(),
            other => panic!("job C: unexpected event before start: {other:?}"),
        }
    };
    let c_report = loop {
        match c.next_event().unwrap() {
            JobEvent::Iter(_) => {}
            JobEvent::Report(report) => break report,
            other => panic!("job C: unexpected event {other:?}"),
        }
    };
    let (b_started, b_report) = b_watcher.join().unwrap();
    // B's start is gated on C's entire run releasing the one slot, so
    // the ordering check has a full job run of slack in it.
    assert!(
        c_started < b_started,
        "high-priority C must take the freed slot before normal B"
    );
    assert_reports_bit_identical("overtaken job B", &b_standalone, &b_report);
    assert_reports_bit_identical("overtaking job C", &c_standalone, &c_report);
    daemon.shutdown().unwrap();
}

/// Satellite regression: a daemon that accepts a job and then goes
/// permanently silent must not hang the client forever — the handle's
/// read deadline expires into a session-tagged [`mpamp::Error::Transport`].
#[test]
fn client_read_deadline_surfaces_a_mute_daemon_as_transport_error() {
    use std::io::{Read, Write};

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    // A protocol-faithful but mute daemon: read the hello and the submit
    // frame, send J_ACCEPTED {session=42, pos=0}, then never speak again
    // while holding the socket open.
    let mute_daemon = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let mut hello = [0u8; 5];
        s.read_exact(&mut hello).unwrap();
        let mut len = [0u8; 4];
        s.read_exact(&mut len).unwrap();
        let mut frame = vec![0u8; u32::from_le_bytes(len) as usize];
        s.read_exact(&mut frame).unwrap();
        let mut accepted = Vec::new();
        accepted.extend_from_slice(&9u32.to_le_bytes()); // kind + 2×u32
        accepted.push(3); // J_ACCEPTED
        accepted.extend_from_slice(&42u32.to_le_bytes());
        accepted.extend_from_slice(&0u32.to_le_bytes());
        s.write_all(&accepted).unwrap();
        // Outlive the client's deadline without closing the socket.
        std::thread::sleep(Duration::from_millis(1500));
    });

    let cfg = RunConfig::test_small(0.05);
    let mut job = Client::submit_with(
        &addr,
        &cfg,
        Priority::Normal,
        Some(Duration::from_millis(200)),
    )
    .unwrap();
    assert_eq!(job.session_id(), 42);
    let started = Instant::now();
    let err = match job.next_event() {
        Err(e) => e.to_string(),
        Ok(ev) => panic!("expected a read timeout, got event {ev:?}"),
    };
    assert!(
        started.elapsed() < Duration::from_secs(30),
        "timeout did not bound the read"
    );
    assert!(err.contains("timed out"), "unexpected error: {err}");
    assert!(err.contains("session 42"), "missing session context: {err}");
    assert!(err.contains("client"), "missing role context: {err}");
    mute_daemon.join().unwrap();
}

#[test]
fn full_queue_rejects_and_fleet_mismatch_rejects() {
    let mut serve_cfg = ServeConfig::new("127.0.0.1:0", 6);
    serve_cfg.max_sessions = 1;
    serve_cfg.max_queue = 0;
    let daemon = Daemon::start(serve_cfg).unwrap();
    let addr = daemon.addr().to_string();

    let mut a_cfg = RunConfig::test_small(0.05);
    a_cfg.iters = 300;
    a_cfg.seed = 3;
    let mut a = Client::submit(&addr, &a_cfg).unwrap();
    assert!(matches!(a.next_event().unwrap(), JobEvent::Started));
    assert!(matches!(a.next_event().unwrap(), JobEvent::Iter(_)));

    // Queue capacity 0: the second job bounces with a capacity error.
    let b_cfg = RunConfig::test_small(0.05);
    let err = Client::submit(&addr, &b_cfg).unwrap_err().to_string();
    assert!(err.contains("capacity"), "unexpected rejection message: {err}");

    // A config whose P does not match the fleet is rejected at submit.
    let mut wrong_p = RunConfig::test_small(0.05);
    wrong_p.p = 3; // valid standalone (3 | 180), wrong for this fleet
    let err = Client::submit(&addr, &wrong_p).unwrap_err().to_string();
    assert!(err.contains("fleet"), "unexpected rejection message: {err}");

    a.cancel().unwrap();
    loop {
        match a.next_event().unwrap() {
            JobEvent::Iter(_) => {}
            JobEvent::Cancelled => break,
            other => panic!("expected cancellation for job A, got {other:?}"),
        }
    }
    daemon.shutdown().unwrap();
}

/// Priority aging (`--priority-age-s`): a normal job that has waited
/// past the threshold is promoted into the high band, so a later
/// high-priority submission can no longer overtake it.
#[test]
fn aged_normal_job_keeps_its_turn_against_later_high_job() {
    let mut serve_cfg = ServeConfig::new("127.0.0.1:0", 6);
    serve_cfg.max_sessions = 1;
    serve_cfg.max_queue = 4;
    serve_cfg.priority_age = Some(Duration::from_millis(1));
    let daemon = Daemon::start(serve_cfg).unwrap();
    let addr = daemon.addr().to_string();

    // Job A holds the only slot.
    let mut a_cfg = RunConfig::test_small(0.05);
    a_cfg.iters = 300;
    a_cfg.seed = 51;
    let mut a = Client::submit(&addr, &a_cfg).unwrap();
    assert!(matches!(a.next_event().unwrap(), JobEvent::Started));
    assert!(matches!(a.next_event().unwrap(), JobEvent::Iter(_)));

    // Normal-priority B queues and ages past the 1ms threshold (its own
    // wait loop runs the promotion within one 25ms poll beat).
    let mut b_cfg = RunConfig::test_small(0.05);
    b_cfg.iters = 3;
    b_cfg.seed = 52;
    let mut b = Client::submit(&addr, &b_cfg).unwrap();
    assert_eq!(b.queue_pos(), 1);
    std::thread::sleep(Duration::from_millis(500));

    // High-priority C lands *behind* the aged B: both now sit in the
    // high band, which is FIFO.
    let mut c_cfg = RunConfig::test_small(0.05);
    c_cfg.iters = 3;
    c_cfg.seed = 53;
    let mut c = Client::submit_with(&addr, &c_cfg, Priority::High, None).unwrap();
    assert_eq!(
        c.queue_pos(),
        2,
        "B should already sit in the high band when C is admitted"
    );

    let c_watcher = std::thread::spawn(move || {
        let mut started_at = None;
        loop {
            match c.next_event().unwrap() {
                JobEvent::Started => started_at = Some(Instant::now()),
                JobEvent::Iter(_) => {}
                JobEvent::Report(_) => {
                    return started_at.expect("C reported before starting")
                }
                other => panic!("job C: unexpected event {other:?}"),
            }
        }
    });
    a.cancel().unwrap();
    loop {
        match a.next_event().unwrap() {
            JobEvent::Iter(_) => {}
            JobEvent::Cancelled => break,
            other => panic!("expected cancellation for job A, got {other:?}"),
        }
    }
    let b_started = loop {
        match b.next_event().unwrap() {
            JobEvent::Started => break Instant::now(),
            other => panic!("job B: unexpected event before start: {other:?}"),
        }
    };
    loop {
        match b.next_event().unwrap() {
            JobEvent::Iter(_) => {}
            JobEvent::Report(_) => break,
            other => panic!("job B: unexpected event {other:?}"),
        }
    }
    let c_started = c_watcher.join().unwrap();
    assert!(
        b_started < c_started,
        "aged normal job B must take the freed slot before the later high C"
    );
    daemon.shutdown().unwrap();
}

/// Satellite regression: a daemon that dies while the job is still
/// queued must surface a queue-aware transport error — the client knows
/// it never started and where it stood — not a bare timeout.
#[test]
fn daemon_death_while_queued_reports_queue_position() {
    use std::io::{Read, Write};

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    // Accept, read hello + submit, queue the job at position 3, then die.
    let dying_daemon = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let mut hello = [0u8; 5];
        s.read_exact(&mut hello).unwrap();
        let mut len = [0u8; 4];
        s.read_exact(&mut len).unwrap();
        let mut frame = vec![0u8; u32::from_le_bytes(len) as usize];
        s.read_exact(&mut frame).unwrap();
        let mut accepted = Vec::new();
        accepted.extend_from_slice(&9u32.to_le_bytes()); // kind + 2×u32
        accepted.push(3); // J_ACCEPTED
        accepted.extend_from_slice(&42u32.to_le_bytes());
        accepted.extend_from_slice(&3u32.to_le_bytes()); // queued at 3
        s.write_all(&accepted).unwrap();
        // Dropping the socket here is the daemon dying mid-queue.
    });

    let cfg = RunConfig::test_small(0.05);
    let mut job = Client::submit_with(
        &addr,
        &cfg,
        Priority::Normal,
        Some(Duration::from_secs(5)),
    )
    .unwrap();
    assert_eq!((job.session_id(), job.queue_pos()), (42, 3));
    dying_daemon.join().unwrap();
    let err = match job.next_event() {
        Err(e) => e.to_string(),
        Ok(ev) => panic!("expected a transport error, got event {ev:?}"),
    };
    assert!(err.contains("still queued"), "not queue-aware: {err}");
    assert!(err.contains("position 3"), "missing queue position: {err}");
    assert!(err.contains("session 42"), "missing session context: {err}");
}

/// Chaos: a scripted fault plan kills one fleet worker's connection and
/// delays another mid-run. An elastic K-of-P job keeps fusing on the
/// live majority, the killed worker reconnects with backoff (visible on
/// the `workers_reconnected_total` counter), and the daemon still
/// drains and shuts down cleanly afterwards.
#[test]
fn killed_fleet_worker_reconnects_and_elastic_job_completes() {
    use mpamp::coordinator::fault::FaultPlan;

    let mut serve_cfg = ServeConfig::new("127.0.0.1:0", 6);
    serve_cfg.fault_plan = Some(std::sync::Arc::new(
        FaultPlan::parse("kill:w=2,t=1;delay:w=4,t=2,ms=40").unwrap(),
    ));
    let daemon = Daemon::start(serve_cfg).unwrap();
    let addr = daemon.addr().to_string();

    let before = mpamp::telemetry::metrics().workers_reconnected.get();
    let mut cfg = RunConfig::test_small(0.05);
    cfg.iters = 8;
    cfg.seed = 61;
    cfg.min_workers = 4;
    cfg.round_deadline_ms = 250;
    let report = Client::submit(&addr, &cfg).unwrap().await_report().unwrap();
    assert!(
        report.final_sdr_db().is_finite(),
        "elastic job under faults must still produce a finite report"
    );
    // The killed worker must come back (the counter is process-global
    // and monotonic, so compare against the pre-test reading).
    let deadline = Instant::now() + Duration::from_secs(10);
    while mpamp::telemetry::metrics().workers_reconnected.get() <= before {
        assert!(Instant::now() < deadline, "killed worker never reconnected");
        std::thread::sleep(Duration::from_millis(10));
    }
    // Drain and shut down with the fleet possibly mid-reconnect.
    daemon.begin_drain();
    let drain_deadline = Instant::now() + Duration::from_secs(30);
    while !daemon.is_idle() {
        assert!(Instant::now() < drain_deadline, "drain never went idle");
        std::thread::sleep(Duration::from_millis(10));
    }
    daemon.shutdown().unwrap();
}

/// Draining must not depend on a healthy fleet: a drain that begins
/// *before* a scripted worker kill fires still finishes the admitted
/// elastic job (the quorum covers the dead-worker window), bounces new
/// submissions, and reaches idle for a clean shutdown.
#[test]
fn drain_finishes_elastic_job_while_a_worker_is_dead() {
    use mpamp::coordinator::fault::FaultPlan;

    let mut serve_cfg = ServeConfig::new("127.0.0.1:0", 6);
    serve_cfg.fault_plan =
        Some(std::sync::Arc::new(FaultPlan::parse("kill:w=1,t=2").unwrap()));
    let daemon = Daemon::start(serve_cfg).unwrap();
    let addr = daemon.addr().to_string();

    let mut cfg = RunConfig::test_small(0.05);
    cfg.iters = 40;
    cfg.seed = 62;
    cfg.min_workers = 4;
    cfg.round_deadline_ms = 250;
    let mut job = Client::submit(&addr, &cfg).unwrap();
    assert!(matches!(job.next_event().unwrap(), JobEvent::Started));

    // Drain before the round-2 kill fires: the dead-worker window opens
    // while the daemon is already draining.
    daemon.begin_drain();
    let err = Client::submit(&addr, &cfg).unwrap_err().to_string();
    assert!(err.contains("draining"), "unexpected rejection message: {err}");

    let report = job.await_report().unwrap();
    assert_eq!(report.iters.len(), 40, "admitted job must finish its rounds");
    assert!(
        report.final_sdr_db().is_finite(),
        "drained elastic job under a worker kill must still report"
    );

    let deadline = Instant::now() + Duration::from_secs(30);
    while !daemon.is_idle() {
        assert!(Instant::now() < deadline, "drain never went idle");
        std::thread::sleep(Duration::from_millis(10));
    }
    daemon.shutdown().unwrap();
}

/// The graceful-shutdown contract behind `mpamp serve`'s SIGTERM path:
/// after [`Daemon::begin_drain`] new submissions bounce with a "draining"
/// message, while already-admitted jobs run to completion — bit-identical
/// to a standalone session — after which the daemon reports idle.
#[test]
fn draining_daemon_bounces_new_jobs_but_finishes_admitted_ones() {
    let daemon = Daemon::start(ServeConfig::new("127.0.0.1:0", 6)).unwrap();
    let addr = daemon.addr().to_string();

    // Job A is admitted before the drain begins.
    let mut a_cfg = RunConfig::test_small(0.05);
    a_cfg.iters = 5;
    a_cfg.seed = 7;
    let a_standalone = Session::new(a_cfg.clone()).unwrap().run().unwrap();
    let mut a = Client::submit(&addr, &a_cfg).unwrap();
    assert!(matches!(a.next_event().unwrap(), JobEvent::Started));

    assert!(!daemon.is_draining());
    daemon.begin_drain();
    assert!(daemon.is_draining());

    // New submissions bounce with the draining message...
    let err = Client::submit(&addr, &a_cfg).unwrap_err().to_string();
    assert!(err.contains("draining"), "unexpected rejection message: {err}");

    // ...while the admitted job finishes normally and unperturbed.
    let a_report = a.await_report().unwrap();
    assert!(a_report.stopped_early.is_none());
    assert_reports_bit_identical("drained job A", &a_standalone, &a_report);

    // The queue empties out, after which shutdown is clean — the same
    // poll `mpamp serve` does before exiting 0.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    while !daemon.is_idle() {
        assert!(std::time::Instant::now() < deadline, "drain never went idle");
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    daemon.shutdown().unwrap();
}
