//! Fast integration checks of the paper's *qualitative* claims at reduced
//! scale — the full-scale quantitative reproduction lives in
//! `benches/{fig1,table1}.rs` and `examples/full_reproduction.rs`.

use mpamp::alloc::backtrack::{BtController, RateModel};
use mpamp::alloc::dp::DpAllocator;
use mpamp::amp::run_centralized;
use mpamp::config::{RdConfig, RunConfig, ScheduleKind};
use mpamp::coordinator::session::MpAmpSession;
use mpamp::engine::RustEngine;
use mpamp::rd::RdCache;
use mpamp::se::StateEvolution;
use mpamp::signal::{Instance, ProblemDims};
use mpamp::util::rng::Rng;

/// Moderate scale: big enough for SE concentration, small enough for CI.
fn mid_cfg(eps: f64) -> RunConfig {
    let mut cfg = RunConfig::paper_default(eps);
    cfg.n = 3_000;
    cfg.m = 900;
    cfg.p = 10;
    cfg.iters = 8;
    cfg.rd = RdConfig { alphabet: 201, curve_points: 16, tol: 1e-5, gamma_grid: 11 };
    cfg
}

#[test]
fn bt_matches_centralized_quality_with_big_savings() {
    let cfg = mid_cfg(0.05);
    let mut rng = Rng::new(cfg.seed);
    let inst = Instance::generate(
        cfg.prior,
        ProblemDims { n: cfg.n, m: cfg.m, sigma_e2: cfg.sigma_e2() },
        &mut rng,
    )
    .unwrap();
    let se = StateEvolution::new(cfg.prior, cfg.kappa(), cfg.sigma_e2());
    let engine = RustEngine::new(cfg.prior, 4);
    let cent = run_centralized(&inst, &se, &engine, cfg.iters).unwrap();

    let mut bt_cfg = cfg.clone();
    bt_cfg.schedule = ScheduleKind::BackTrack { ratio_max: 1.02, r_max: 6.0 };
    let bt = MpAmpSession::with_instance(bt_cfg, inst).unwrap().run().unwrap();

    // Paper headline 1: almost the same SDR as centralized AMP...
    let gap = cent.final_sdr_db() - bt.final_sdr_db();
    assert!(gap < 1.0, "BT SDR gap {gap:.2} dB too large");
    // ...with >80% communication savings and <6 bits/element/iteration.
    assert!(
        bt.savings_vs_float_pct() > 80.0,
        "savings {:.1}%",
        bt.savings_vs_float_pct()
    );
    for it in &bt.iters {
        assert!(it.rate_wire < 6.5, "t={}: rate {}", it.t, it.rate_wire);
    }
}

#[test]
fn dp_beats_bt_on_total_rate_and_catches_up_in_sdr() {
    let cfg = mid_cfg(0.05);
    let mut rng = Rng::new(cfg.seed + 1);
    let inst = Instance::generate(
        cfg.prior,
        ProblemDims { n: cfg.n, m: cfg.m, sigma_e2: cfg.sigma_e2() },
        &mut rng,
    )
    .unwrap();

    let mut bt_cfg = cfg.clone();
    bt_cfg.schedule = ScheduleKind::BackTrack { ratio_max: 1.02, r_max: 6.0 };
    let bt = MpAmpSession::with_instance(bt_cfg, inst.clone()).unwrap().run().unwrap();

    let mut dp_cfg = cfg.clone();
    dp_cfg.schedule = ScheduleKind::Dp { total_rate: None, delta_r: 0.25 };
    let dp = MpAmpSession::with_instance(dp_cfg, inst).unwrap().run().unwrap();

    // Paper headline 2: DP provides communication reduction beyond BT, at
    // a transient SDR cost that vanishes by t = T.
    assert!(
        dp.total_uplink_bits_per_element() < bt.total_uplink_bits_per_element(),
        "DP {} ≥ BT {}",
        dp.total_uplink_bits_per_element(),
        bt.total_uplink_bits_per_element()
    );
    let final_gap = bt.final_sdr_db() - dp.final_sdr_db();
    assert!(final_gap < 1.0, "DP final gap {final_gap:.2} dB did not close");
}

#[test]
fn dp_ecsq_overhead_near_quarter_bit() {
    // Paper §4: the ECSQ realization costs ≈ 0.255 bits/element/iteration
    // over the RD-based DP budget (2T bits) in the high-rate limit.
    let cfg = mid_cfg(0.05);
    let mut rng = Rng::new(cfg.seed + 2);
    let inst = Instance::generate(
        cfg.prior,
        ProblemDims { n: cfg.n, m: cfg.m, sigma_e2: cfg.sigma_e2() },
        &mut rng,
    )
    .unwrap();
    let mut dp_cfg = cfg.clone();
    dp_cfg.schedule = ScheduleKind::Dp { total_rate: None, delta_r: 0.25 };
    let dp = MpAmpSession::with_instance(dp_cfg, inst).unwrap().run().unwrap();
    let budget = 2.0 * cfg.iters as f64;
    let overhead = (dp.total_uplink_bits_per_element() - budget) / cfg.iters as f64;
    // Low-rate iterations inflate the average a little; accept 0.1–0.45.
    assert!(
        (0.1..0.45).contains(&overhead),
        "ECSQ overhead {overhead:.3} bits/iter not near 0.255"
    );
}

#[test]
fn quantization_noise_visible_in_se_terms() {
    // MP-AMP with a *coarse* fixed quantizer must do measurably worse than
    // uncompressed MP-AMP, and the quantization-aware SE (eq. 8) must
    // keep predicting the SDR.
    let cfg = mid_cfg(0.05);
    let mut rng = Rng::new(cfg.seed + 3);
    let inst = Instance::generate(
        cfg.prior,
        ProblemDims { n: cfg.n, m: cfg.m, sigma_e2: cfg.sigma_e2() },
        &mut rng,
    )
    .unwrap();
    let mut raw_cfg = cfg.clone();
    raw_cfg.schedule = ScheduleKind::Uncompressed;
    let raw = MpAmpSession::with_instance(raw_cfg, inst.clone()).unwrap().run().unwrap();
    let mut coarse_cfg = cfg.clone();
    coarse_cfg.schedule = ScheduleKind::Fixed { bits: 1.0 };
    let coarse = MpAmpSession::with_instance(coarse_cfg, inst).unwrap().run().unwrap();
    assert!(
        raw.final_sdr_db() - coarse.final_sdr_db() > 1.0,
        "1-bit quantization should hurt: raw {} vs coarse {}",
        raw.final_sdr_db(),
        coarse.final_sdr_db()
    );
    // The quantization-aware SE prediction stays within 2.5 dB of reality.
    for it in coarse.iters.iter().skip(1) {
        assert!(
            (it.sdr_db - it.sdr_pred_db).abs() < 2.5,
            "t={}: empirical {} vs eq.8 prediction {}",
            it.t,
            it.sdr_db,
            it.sdr_pred_db
        );
    }
}

#[test]
fn bt_rd_prediction_close_to_paper_totals_at_full_dims() {
    // Offline (SE-only, no data) — cheap even at the paper's dimensions.
    // Paper Table 1, BT RD prediction row: {33.82, 46.43, 96.16} ±20%.
    let paper = [(0.03, 33.82), (0.05, 46.43), (0.10, 96.16)];
    for (eps, want) in paper {
        let cfg = RunConfig::paper_default(eps);
        let se = StateEvolution::new(cfg.prior, cfg.kappa(), cfg.sigma_e2());
        let fp = se.fixed_point(1e-10, 300);
        let rd = RdConfig { alphabet: 257, curve_points: 16, tol: 1e-5, gamma_grid: 13 };
        let cache =
            RdCache::build(&cfg.prior, cfg.p, fp * 0.5, se.sigma0_sq() * 2.0, &rd).unwrap();
        let ctl = BtController::new(&se, cfg.p, 1.02, 6.0, cfg.iters);
        let (dec, _) = ctl.se_schedule(cfg.iters, RateModel::Rd, Some(&cache));
        let total: f64 = dec.iter().map(|d| d.rate).sum();
        assert!(
            (total / want - 1.0).abs() < 0.20,
            "eps={eps}: BT RD total {total:.2} vs paper {want}"
        );
    }
}

#[test]
fn dp_allocation_increases_toward_final_iterations_at_paper_dims() {
    // The visual signature of the paper's Fig. 1 bottom panels.
    let cfg = RunConfig::paper_default(0.05);
    let se = StateEvolution::new(cfg.prior, cfg.kappa(), cfg.sigma_e2());
    let fp = se.fixed_point(1e-10, 300);
    let rd = RdConfig { alphabet: 201, curve_points: 14, tol: 1e-5, gamma_grid: 11 };
    let cache =
        RdCache::build(&cfg.prior, cfg.p, fp * 0.5, se.sigma0_sq() * 2.0, &rd).unwrap();
    let dp = DpAllocator::new(&se, cfg.p, &cache)
        .unwrap()
        .solve(cfg.iters, 2.0 * cfg.iters as f64, 0.1)
        .unwrap();
    let first_half: f64 = dp.rates[..cfg.iters / 2].iter().sum();
    let second_half: f64 = dp.rates[cfg.iters / 2..].iter().sum();
    assert!(
        second_half > first_half,
        "DP rates should grow toward T: {:?}",
        dp.rates
    );
}
