//! Fault-tolerance integration tests: elastic K-of-P sessions must be
//! **bit-identical** to the inelastic protocol when K = P and no faults
//! fire; scripted faults (kills, drops, corruptions, delays) must be
//! absorbed by the quorum or fail with a *typed* error — never hang,
//! never panic, and always reproduce bit-for-bit under the same plan.

use std::sync::Arc;

use mpamp::config::{Partitioning, RunConfig, ScheduleKind};
use mpamp::coordinator::fault::FaultPlan;
use mpamp::util::proptest::{prop_assert, Prop};
use mpamp::{Error, RunReport, Session, SessionBuilder};

/// The four smoke scenarios: {row, column} × {entropy-coded (default
/// ecsq.range under BT), uncompressed} — same shapes the serving tests
/// pin, so elastic coverage matches the daemon's.
fn scenario_configs() -> Vec<RunConfig> {
    let mut cfgs = Vec::new();
    for (partitioning, raw, seed) in [
        (Partitioning::Row, false, 151),
        (Partitioning::Row, true, 252),
        (Partitioning::Column, false, 353),
        (Partitioning::Column, true, 454),
    ] {
        let mut cfg = RunConfig::test_small(0.05);
        cfg.partitioning = partitioning;
        cfg.seed = seed;
        if raw {
            cfg.schedule = ScheduleKind::Uncompressed;
        }
        cfgs.push(cfg);
    }
    cfgs
}

/// Everything deterministic must match to the bit; `wall_s` is the one
/// nondeterministic field and is excluded.
fn assert_reports_bit_identical(label: &str, want: &RunReport, got: &RunReport) {
    assert_eq!(want.iters.len(), got.iters.len(), "{label}: iteration count");
    for (t, (w, g)) in want.iters.iter().zip(&got.iters).enumerate() {
        assert_eq!(
            w.sdr_db.to_bits(),
            g.sdr_db.to_bits(),
            "{label}: sdr_db differs at t={t}"
        );
        assert_eq!(
            w.sigma_d2_hat.to_bits(),
            g.sigma_d2_hat.to_bits(),
            "{label}: sigma_d2_hat differs at t={t}"
        );
        assert_eq!(
            w.rate_wire.to_bits(),
            g.rate_wire.to_bits(),
            "{label}: rate_wire differs at t={t}"
        );
    }
    assert_eq!(want.final_xs.len(), got.final_xs.len(), "{label}: batch size");
    for (sig, (wx, gx)) in want.final_xs.iter().zip(&got.final_xs).enumerate() {
        assert_eq!(wx.len(), gx.len(), "{label}: x length, signal {sig}");
        for (i, (w, g)) in wx.iter().zip(gx).enumerate() {
            assert_eq!(
                w.to_bits(),
                g.to_bits(),
                "{label}: final_x[{sig}][{i}] differs"
            );
        }
    }
    assert_eq!(
        want.transport_uplink_bits, got.transport_uplink_bits,
        "{label}: uplink byte accounting"
    );
    assert_eq!(
        want.transport_downlink_bits, got.transport_downlink_bits,
        "{label}: downlink byte accounting"
    );
    assert_eq!(want.schedule, got.schedule, "{label}: schedule name");
    assert_eq!(want.partitioning, got.partitioning, "{label}: partitioning");
}

fn run_with_plan(cfg: &RunConfig, plan: &Arc<FaultPlan>) -> mpamp::Result<RunReport> {
    SessionBuilder::from_config(cfg.clone())
        .fault_plan(plan.clone())
        .build()?
        .run()
}

/// The elastic acceptance pin: with K = P and no faults, the deadline
/// machinery must be invisible — every scenario's report bit-identical
/// to the inelastic protocol's.
#[test]
fn elastic_k_equals_p_without_faults_is_bit_identical() {
    for cfg in scenario_configs() {
        let label = format!(
            "elastic K=P / {} / {:?}",
            cfg.partitioning.as_str(),
            cfg.schedule
        );
        let want = Session::new(cfg.clone()).unwrap().run().unwrap();
        let got = SessionBuilder::from_config(cfg)
            .min_workers(6)
            .round_deadline_ms(30_000)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_reports_bit_identical(&label, &want, &got);
    }
}

/// Installing an *empty* fault plan must not perturb a session at all —
/// the wrapper channels pass every frame through untouched.
#[test]
fn empty_fault_plan_is_a_strict_no_op() {
    let mut cfg = RunConfig::test_small(0.05);
    cfg.seed = 515;
    let want = Session::new(cfg.clone()).unwrap().run().unwrap();
    let got = run_with_plan(&cfg, &Arc::new(FaultPlan::none())).unwrap();
    assert_reports_bit_identical("empty fault plan", &want, &got);
}

/// One scripted fault of every kind against an elastic 4-of-6 session:
/// the quorum absorbs all of them and the run still reports a finite
/// recovery — the ISSUE's canned kill-one-worker acceptance scenario.
#[test]
fn scripted_faults_are_absorbed_by_the_elastic_quorum() {
    let mut cfg = RunConfig::test_small(0.05);
    cfg.seed = 616;
    let plan = FaultPlan::parse(
        "kill:w=2,t=1;corrupt:w=4,t=2;drop:w=0,t=3;delay:w=1,t=4,ms=30",
    )
    .unwrap();
    let report = SessionBuilder::from_config(cfg.clone())
        .min_workers(4)
        .round_deadline_ms(800)
        .fault_plan(Arc::new(plan))
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(
        report.iters.len(),
        cfg.iters,
        "every round must complete despite the injected faults"
    );
    assert!(
        report.final_sdr_db().is_finite(),
        "partial fusions must still produce a finite SDR, got {}",
        report.final_sdr_db()
    );
}

/// Killing the quorum itself must fail *fast* and *typed*: a Degraded
/// error naming the K floor and the round it fell at — not a hang, not
/// a panic, not an opaque I/O error.
#[test]
fn losing_the_quorum_fails_typed_with_round_context() {
    let mut cfg = RunConfig::test_small(0.05);
    cfg.seed = 717;
    let plan = FaultPlan::parse("kill:w=0,t=2;kill:w=1,t=2;kill:w=2,t=2").unwrap();
    let err = SessionBuilder::from_config(cfg)
        .min_workers(4)
        .round_deadline_ms(1_000)
        .fault_plan(Arc::new(plan))
        .build()
        .unwrap()
        .run()
        .unwrap_err();
    assert!(
        matches!(err, Error::Degraded(_)),
        "expected Error::Degraded, got {err:?}"
    );
    let msg = err.to_string();
    assert!(msg.contains("min_workers 4"), "no K-floor context: {msg}");
    assert!(msg.contains("round 2"), "no round context: {msg}");
}

/// Property: any seeded fault plan on an elastic session either (a)
/// completes with a finite report, or (b) fails with a typed
/// `Transport`/`Degraded` error — and whichever it is, a second run of
/// the same plan reproduces it bit-for-bit (reports) or verbatim
/// (error messages). Nothing hangs: every wait in the elastic round
/// loop is deadline-bounded.
#[test]
fn seeded_fault_plans_are_deterministic_and_typed() {
    Prop::new("elastic fault-plan outcomes", 5).check(|g| {
        let mut cfg = RunConfig::test_small(0.05);
        cfg.seed = 900 + g.case as u64;
        // K = 5 leaves only one worker of slack, so two-fault plans can
        // trip the Degraded floor; K = 3 absorbs everything generated.
        cfg.min_workers = *g.choice(&[3usize, 5]);
        cfg.round_deadline_ms = 400;
        let n_faults = g.usize_in(1, 2);
        let plan = Arc::new(FaultPlan::generate(
            g.u64(),
            cfg.iters as u32,
            cfg.p as u32,
            n_faults,
        ));
        let label = format!("K={} plan [{}]", cfg.min_workers, plan.render());
        let first = run_with_plan(&cfg, &plan);
        let second = run_with_plan(&cfg, &plan);
        match (&first, &second) {
            (Ok(a), Ok(b)) => {
                assert_reports_bit_identical(&label, a, b);
                prop_assert(
                    a.final_sdr_db().is_finite(),
                    format!("{label}: non-finite SDR"),
                )
            }
            (Err(a), Err(b)) => {
                prop_assert(
                    matches!(a, Error::Transport(_) | Error::Degraded(_)),
                    format!("{label}: untyped failure {a:?}"),
                )?;
                prop_assert(
                    a.to_string() == b.to_string(),
                    format!("{label}: nondeterministic failure: '{a}' vs '{b}'"),
                )
            }
            _ => Err(format!(
                "{label}: outcome flipped between two identical runs \
                 (first ok={}, second ok={})",
                first.is_ok(),
                second.is_ok()
            )),
        }
    });
}
