//! Integration tests for `mpamp::telemetry`: attaching a recording
//! handle must never change the math (bit-identical reports across
//! partitionings and compression stacks), the span stream must pin the
//! protocol's round structure, the JSONL trace schema must round-trip,
//! and a served fleet must surface live state through the registry and
//! the HTTP metrics endpoint.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use mpamp::config::{Partitioning, RunConfig, ScheduleKind};
use mpamp::metrics::Json;
use mpamp::serve::{Client, Daemon, JobEvent, Priority, ServeConfig};
use mpamp::telemetry::{self, JobState, MetricsServer, Stage, Telemetry};
use mpamp::{RunReport, Session};

/// The four invariance scenarios: {row, column} × {entropy-coded
/// (default ecsq.range under BT), uncompressed}.
fn scenario_configs() -> Vec<RunConfig> {
    let mut cfgs = Vec::new();
    for (partitioning, raw, seed) in [
        (Partitioning::Row, false, 515),
        (Partitioning::Row, true, 626),
        (Partitioning::Column, false, 737),
        (Partitioning::Column, true, 848),
    ] {
        let mut cfg = RunConfig::test_small(0.05);
        cfg.partitioning = partitioning;
        cfg.seed = seed;
        if raw {
            cfg.schedule = ScheduleKind::Uncompressed;
        }
        cfgs.push(cfg);
    }
    cfgs
}

/// Everything deterministic must match to the bit; `wall_s` is the one
/// nondeterministic field and is excluded.
fn assert_reports_bit_identical(label: &str, want: &RunReport, got: &RunReport) {
    assert_eq!(want.iters.len(), got.iters.len(), "{label}: iteration count");
    for (t, (w, g)) in want.iters.iter().zip(&got.iters).enumerate() {
        assert_eq!(
            w.sdr_db.to_bits(),
            g.sdr_db.to_bits(),
            "{label}: sdr_db differs at t={t}"
        );
        assert_eq!(
            w.sigma_d2_hat.to_bits(),
            g.sigma_d2_hat.to_bits(),
            "{label}: sigma_d2_hat differs at t={t}"
        );
        assert_eq!(
            w.rate_wire.to_bits(),
            g.rate_wire.to_bits(),
            "{label}: rate_wire differs at t={t}"
        );
    }
    for (sig, (wx, gx)) in want.final_xs.iter().zip(&got.final_xs).enumerate() {
        for (i, (w, g)) in wx.iter().zip(gx).enumerate() {
            assert_eq!(
                w.to_bits(),
                g.to_bits(),
                "{label}: final_x[{sig}][{i}] differs"
            );
        }
    }
    assert_eq!(
        want.transport_uplink_bits, got.transport_uplink_bits,
        "{label}: uplink byte accounting"
    );
    assert_eq!(
        want.transport_downlink_bits, got.transport_downlink_bits,
        "{label}: downlink byte accounting"
    );
}

#[test]
fn telemetry_on_is_bit_identical_to_telemetry_off() {
    for cfg in scenario_configs() {
        let label = format!("{} / {:?}", cfg.partitioning.as_str(), cfg.schedule);
        let plain = Session::new(cfg.clone()).unwrap().run().unwrap();
        let tel = Telemetry::enabled();
        let mut traced_session = Session::new(cfg).unwrap();
        traced_session.set_telemetry(tel.clone());
        let traced = traced_session.run().unwrap();
        assert_reports_bit_identical(&label, &plain, &traced);
        assert!(!tel.events().is_empty(), "{label}: no spans recorded");
    }
}

#[test]
fn span_stream_pins_the_round_structure() {
    let cfg = RunConfig::test_small(0.05);
    let p = cfg.p;
    let tel = Telemetry::enabled();
    let mut session = Session::new(cfg).unwrap();
    session.set_telemetry(tel.clone());
    let report = session.run().unwrap();
    let rounds = report.iters.len();
    assert_eq!(rounds, 6, "test_small runs its configured 6 iterations");

    let spans = tel.events();
    assert_eq!(tel.dropped(), 0, "default ring must not wrap at this scale");
    let count = |stage: Stage, fusion: bool| {
        spans
            .iter()
            .filter(|e| e.stage == stage && (e.worker < 0) == fusion)
            .count()
    };
    // Fusion side: one span per stage per round.
    for stage in [
        Stage::Round,
        Stage::Encode,
        Stage::Fusion,
        Stage::Allocator,
        Stage::Uplink,
        Stage::Denoise,
    ] {
        assert_eq!(
            count(stage, true),
            rounds,
            "fusion-side {} span count",
            stage.as_str()
        );
    }
    // Worker side: every worker serves one broadcast (denoise) and one
    // QuantCmd (encode) per round.
    assert_eq!(count(Stage::Encode, false), p * rounds, "worker encode spans");
    assert_eq!(count(Stage::Denoise, false), p * rounds, "worker denoise spans");
    assert_eq!(spans.len(), 6 * rounds + 2 * p * rounds, "total span count");

    // Round envelopes come out in order, one per protocol round, and the
    // fusion-side subsequence is monotonic in start time (single thread).
    let round_ts: Vec<u32> = spans
        .iter()
        .filter(|e| e.stage == Stage::Round)
        .map(|e| e.t)
        .collect();
    assert_eq!(round_ts, (0..rounds as u32).collect::<Vec<_>>());
    let fusion_starts: Vec<u64> =
        spans.iter().filter(|e| e.worker < 0).map(|e| e.start_us).collect();
    assert!(
        fusion_starts.windows(2).all(|w| w[0] <= w[1]),
        "fusion-side spans must be recorded in monotonic start order"
    );

    // Per round, the envelope's bits equal the uplink stage's bits; the
    // sum across rounds is the session's uplink payload byte metric.
    for t in 0..rounds as u32 {
        let round_bits = spans
            .iter()
            .find(|e| e.stage == Stage::Round && e.t == t)
            .unwrap()
            .bits;
        let uplink_bits = spans
            .iter()
            .find(|e| e.stage == Stage::Uplink && e.t == t)
            .unwrap()
            .bits;
        assert_eq!(round_bits.to_bits(), uplink_bits.to_bits(), "bits at t={t}");
        assert!(round_bits > 0.0, "round {t} moved no uplink bits");
    }
    let bits_sum: f64 =
        spans.iter().filter(|e| e.stage == Stage::Round).map(|e| e.bits).sum();
    let payload_bytes = report.uplink_payload_bytes() as f64;
    assert!(
        (bits_sum / 8.0 - payload_bytes).abs() <= 1.0,
        "trace bits ({bits_sum}) disagree with report payload bytes ({payload_bytes})"
    );
    // Round spans carry the σ_Q² / MSE payload; empirical MSE mirrors
    // the per-iteration record's σ̂_D².
    for (t, rec) in report.iters.iter().enumerate() {
        let env = spans
            .iter()
            .find(|e| e.stage == Stage::Round && e.t == t as u32)
            .unwrap();
        assert_eq!(env.mse_emp.to_bits(), rec.sigma_d2_hat.to_bits());
        assert_eq!(env.sigma_q2.to_bits(), rec.sigma_q2.to_bits());
        assert!(env.mse_pred > 0.0, "round {t} missing SE-predicted MSE");
    }
}

#[test]
fn trace_jsonl_schema_round_trips() {
    let tel = Telemetry::enabled();
    let mut session = Session::new(RunConfig::test_small(0.05)).unwrap();
    session.set_telemetry(tel.clone());
    session.run().unwrap();
    let spans = tel.events();

    let mut out = Vec::new();
    telemetry::write_trace(&mut out, &spans).unwrap();
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), spans.len(), "one JSONL line per span");
    let stage_names: Vec<&str> = Stage::ALL.iter().map(|s| s.as_str()).collect();
    for (i, line) in lines.iter().enumerate() {
        let obj = Json::parse(line).unwrap_or_else(|e| panic!("line {i}: {e}"));
        for key in [
            "stage", "t", "worker", "start_us", "dur_us", "bits", "sigma_q2",
            "mse_pred", "mse_emp",
        ] {
            assert!(obj.get(key).is_some(), "line {i} missing key {key}");
        }
        let stage = obj.get("stage").and_then(|j| j.as_str()).unwrap();
        assert!(stage_names.contains(&stage), "line {i}: unknown stage {stage}");
        assert_eq!(
            obj.get("stage").and_then(|j| j.as_str()),
            Some(spans[i].stage.as_str()),
            "line {i}: stage order preserved"
        );
    }
}

fn http_get(addr: &str, path: &str) -> (String, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    write!(s, "GET {path} HTTP/1.0\r\nHost: test\r\n\r\n").unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    let (head, body) = buf.split_once("\r\n\r\n").expect("response head");
    (head.to_string(), body.to_string())
}

/// The only test in this binary that starts a daemon, so the process
/// registry's job table and jobs_* gauges belong to it exclusively
/// (standalone sessions in the other tests touch only round/session
/// counters and stage histograms).
#[test]
fn served_jobs_surface_in_registry_and_metrics_endpoint() {
    let reg = mpamp::telemetry::metrics();
    let completed0 = reg.jobs_completed.get();
    let cancelled0 = reg.jobs_cancelled.get();

    let daemon = Daemon::start(ServeConfig::new("127.0.0.1:0", 6)).unwrap();
    let addr = daemon.addr().to_string();
    let server = MetricsServer::start("127.0.0.1:0").unwrap();
    let maddr = server.addr().to_string();

    // A long-running job holds a slot while we scrape mid-run.
    let mut long_cfg = RunConfig::test_small(0.05);
    long_cfg.iters = 300;
    long_cfg.seed = 31;
    let mut long_job = Client::submit(&addr, &long_cfg).unwrap();
    let long_sid = long_job.session_id();
    assert!(matches!(long_job.next_event().unwrap(), JobEvent::Started));
    assert!(matches!(long_job.next_event().unwrap(), JobEvent::Iter(_)));

    let (head, body) = http_get(&maddr, "/metrics");
    assert!(head.starts_with("HTTP/1.0 200"), "{head}");
    assert!(body.contains("mpamp_jobs_running 1"), "running gauge:\n{body}");
    let running_row =
        format!("mpamp_job_rounds{{session=\"{long_sid}\",state=\"running\",priority=\"normal\"}}");
    assert!(body.contains(&running_row), "missing {running_row} in:\n{body}");
    assert!(body.contains("mpamp_rounds_total"), "{body}");
    assert!(body.contains("mpamp_stage_latency_us_bucket{stage=\"round\""), "{body}");

    // A fast high-priority job shares the fleet and completes.
    let mut fast_cfg = RunConfig::test_small(0.05);
    fast_cfg.iters = 3;
    fast_cfg.seed = 32;
    let fast_job =
        Client::submit_with(&addr, &fast_cfg, Priority::High, None).unwrap();
    let fast_sid = fast_job.session_id();
    let report = fast_job.await_report().unwrap();
    assert_eq!(report.iters.len(), 3);

    assert!(reg.jobs_completed.get() >= completed0 + 1, "completed counter");
    let (_, row) = reg
        .jobs()
        .into_iter()
        .find(|(sid, _)| *sid == fast_sid)
        .expect("fast job missing from the job table");
    assert_eq!(row.state, JobState::Done);
    assert!(row.high_priority, "priority class recorded");
    assert_eq!(row.rounds, 3, "per-job round progress");
    assert!(row.uplink_bits > 0, "per-job uplink accounting");
    assert!(
        row.uplink_bits <= report.transport_uplink_bits,
        "job row bits ({}) cannot exceed the metered transport total ({})",
        row.uplink_bits,
        report.transport_uplink_bits,
    );
    let (_, body) = http_get(&maddr, "/metrics");
    assert!(
        body.contains(&format!(
            "mpamp_job_uplink_bits{{session=\"{fast_sid}\",state=\"done\",priority=\"high\"}}"
        )),
        "{body}"
    );

    // JSON snapshot parses and carries the job table.
    let (head, body) = http_get(&maddr, "/metrics.json");
    assert!(head.starts_with("HTTP/1.0 200"), "{head}");
    let snap = Json::parse(&body).unwrap();
    assert!(snap.get("rounds_total").and_then(|j| j.as_f64()).unwrap_or(0.0) >= 3.0);
    assert!(snap.get("jobs").is_some() && snap.get("stages").is_some());

    // Cancelling the long job drains the fleet and zeroes the gauge.
    long_job.cancel().unwrap();
    loop {
        match long_job.next_event().unwrap() {
            JobEvent::Iter(_) => {}
            JobEvent::Cancelled => break,
            other => panic!("expected cancellation for the long job, got {other:?}"),
        }
    }
    assert!(reg.jobs_cancelled.get() >= cancelled0 + 1, "cancel counter");
    let (_, body) = http_get(&maddr, "/metrics");
    assert!(body.contains("mpamp_jobs_running 0"), "drained gauge:\n{body}");

    server.stop();
    daemon.shutdown().unwrap();
}
