//! Satellite coverage: `config::toml` error paths (malformed values,
//! unknown keys, out-of-range bounds) and `RunReport`'s derived metrics on
//! a hand-built report — no session run needed for either.

use mpamp::config::{toml, RunConfig};
use mpamp::metrics::IterRecord;
use mpamp::RunReport;

// ---------- toml / config error paths ----------

#[test]
fn toml_malformed_values_error_with_line_numbers() {
    for (text, needle) in [
        ("n = ", "empty value"),
        ("n = \"unterminated", "unterminated string"),
        ("n = [1, 2]", "arrays are not supported"),
        ("n = 10e", "cannot parse value"),
    ] {
        let err = toml::parse(text).unwrap_err().to_string();
        assert!(err.contains(needle), "{text:?}: {err}");
        assert!(err.contains("line 1"), "{text:?}: {err}");
    }
}

#[test]
fn config_rejects_wrongly_typed_values() {
    // Number where a string is required, and vice versa.
    let t = toml::parse("codec = 7").unwrap();
    let err = RunConfig::from_table(&t).unwrap_err().to_string();
    assert!(err.contains("codec"), "{err}");

    let t = toml::parse("n = \"ten\"").unwrap();
    let err = RunConfig::from_table(&t).unwrap_err().to_string();
    assert!(err.contains("'n'"), "{err}");

    // Negative integers cannot become usize fields.
    let t = toml::parse("p = -3").unwrap();
    assert!(RunConfig::from_table(&t).is_err());
}

#[test]
fn config_rejects_unknown_keys() {
    let t = toml::parse("[schedule]\nkind = \"bt\"\nratiomax = 1.05").unwrap();
    let err = RunConfig::from_table(&t).unwrap_err().to_string();
    assert!(err.contains("schedule.ratiomax"), "{err}");
}

#[test]
fn config_rejects_out_of_range_bounds() {
    // ε must lie in (0, 1].
    let t = toml::parse("[prior]\neps = 1.5").unwrap();
    assert!(RunConfig::from_table(&t).is_err());
    // P must divide M.
    let t = toml::parse("p = 7").unwrap();
    let err = RunConfig::from_table(&t).unwrap_err().to_string();
    assert!(err.contains("divide"), "{err}");
    // Schedule parameters outside their domains.
    let t = toml::parse("[schedule]\nkind = \"bt\"\nratio_max = 0.5").unwrap();
    assert!(RunConfig::from_table(&t).is_err());
    let t = toml::parse("[schedule]\nkind = \"fixed\"\nbits = -1.0").unwrap();
    assert!(RunConfig::from_table(&t).is_err());
    let t = toml::parse("[schedule]\nkind = \"dp\"\ndelta_r = 0.0").unwrap();
    assert!(RunConfig::from_table(&t).is_err());
}

#[test]
fn from_file_reports_missing_path() {
    let err = RunConfig::from_file("/nonexistent/run.toml").unwrap_err().to_string();
    assert!(err.contains("/nonexistent/run.toml"), "{err}");
}

// ---------- RunReport derived metrics ----------

fn record(t: usize, sdr_db: f64, rate_alloc: f64, rate_wire: f64) -> IterRecord {
    IterRecord {
        t,
        sdr_db,
        sdr_pred_db: sdr_db + 0.1,
        rate_alloc,
        rate_wire,
        sigma_q2: 1e-3,
        sigma_d2_hat: 1e-2,
        wall_s: 0.01,
    }
}

fn hand_built_report() -> RunReport {
    RunReport {
        iters: vec![
            record(0, 3.0, 6.0, 6.2),
            record(1, 9.0, 4.0, 4.1),
            record(2, 14.0, 2.0, 2.2),
            record(3, 17.5, 1.0, 1.5),
        ],
        final_xs: vec![vec![0.0; 16]],
        sdr_db_per_signal: vec![17.5],
        batch: 1,
        dims: (16, 8, 2),
        schedule: "bt".into(),
        engine: "rust".into(),
        partitioning: "row".into(),
        transport_uplink_bits: 1_000,
        transport_downlink_bits: 2_000,
        wall_s: 0.5,
        stopped_early: None,
    }
}

#[test]
fn report_totals_sum_per_iteration_rates() {
    let r = hand_built_report();
    assert!((r.total_uplink_bits_per_element() - 14.0).abs() < 1e-12);
    assert!((r.total_alloc_bits_per_element() - 13.0).abs() < 1e-12);
    assert!((r.final_sdr_db() - 17.5).abs() < 1e-12);
    // Row payload: 14 bits/element × P=2 workers × N=16 elements / 8.
    assert_eq!(r.uplink_payload_bytes(), 56);
    // Column messages have M elements: 14 × 2 × 8 / 8.
    let mut col = hand_built_report();
    col.partitioning = "column".into();
    assert_eq!(col.uplink_payload_bytes(), 28);
    // Batched runs ship B vectors per worker per iteration.
    let mut batched = hand_built_report();
    batched.batch = 4;
    assert_eq!(batched.uplink_payload_bytes(), 4 * 56);
    // Throughput: batch / wall seconds.
    assert!((batched.signals_per_s() - 4.0 / 0.5).abs() < 1e-12);
}

#[test]
fn savings_vs_float_uses_executed_iterations() {
    let r = hand_built_report();
    // Raw baseline = 32 bits × 4 executed iterations = 128.
    let want = 100.0 * (1.0 - 14.0 / 128.0);
    assert!((r.savings_vs_float_pct() - want).abs() < 1e-12);

    // An early-stopped run is compared against floats over the *same*
    // number of iterations, not the configured T.
    let mut short = hand_built_report();
    short.iters.truncate(2);
    short.stopped_early = Some("target SDR reached".into());
    let want = 100.0 * (1.0 - 10.3 / 64.0);
    assert!((short.savings_vs_float_pct() - want).abs() < 1e-12);
}

#[test]
fn empty_report_is_well_defined() {
    let mut r = hand_built_report();
    r.iters.clear();
    assert!(r.final_sdr_db().is_nan());
    assert_eq!(r.total_uplink_bits_per_element(), 0.0);
}

#[test]
fn report_serializes_to_csv_and_json() {
    let r = hand_built_report();
    let csv = r.to_csv().render();
    assert!(csv.starts_with("t,sdr_db,"));
    assert_eq!(csv.lines().count(), 1 + 4);

    let json = r.to_json().render();
    assert!(json.contains("\"schedule\":\"bt\""), "{json}");
    assert!(json.contains("\"partitioning\":\"row\""), "{json}");
    assert!(json.contains("\"iters\":4"), "{json}");
    assert!(json.contains("\"stopped_early\":null"), "{json}");
    assert!(json.contains("\"batch\":1"), "{json}");
    assert!(json.contains("\"sdr_db_per_signal\":[17.5]"), "{json}");
    assert!(json.contains("\"signals_per_s\":2"), "{json}");
    let mut stopped = r;
    stopped.stopped_early = Some("uplink budget spent".into());
    assert!(
        stopped.to_json().render().contains("\"stopped_early\":\"uplink budget spent\"")
    );
}
