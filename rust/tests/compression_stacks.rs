//! Compression-stack coverage for the pluggable-registry redesign.
//!
//! 1. **Bit-equality pin**: the registry's `ecsq.*` stacks must reproduce
//!    the pre-refactor [`EcsqCoder`] pipeline *bit for bit* — same
//!    symbols, same wire bytes, same charged bits, same reconstructions —
//!    on both scenario model channels (row worker channel and column
//!    message channel), across every design target. `EcsqCoder` is kept
//!    in `quant` precisely as this reference implementation.
//! 2. **Session pin**: full `"ecsq.huffman"` sessions are bit-stable
//!    across transports (inproc ≡ TCP) on row and column partitionings.
//! 3. **Property tests**: encode/decode round-trips and
//!    `wire_bits`-vs-actual-bytes consistency for every registered stack
//!    (so a stack registered later is covered automatically).
//! 4. The two new stacks (`ecsq-dithered.range`, `topk.raw`) run end to
//!    end on both partitionings under both rate- and MSE-style schedules.

use mpamp::compress::registry;
use mpamp::compress::{BlockCtx, DesignCtx, CLIP_SDS};
use mpamp::config::{CodecKind, Partitioning, TransportKind};
use mpamp::coordinator::scenario::{design_ctx, Column, Row};
use mpamp::quant::EcsqCoder;
use mpamp::se::prior::BgChannel;
use mpamp::signal::BernoulliGauss;
use mpamp::util::proptest::{prop_assert, Prop};
use mpamp::util::rng::Rng;
use mpamp::SessionBuilder;

fn sample_block(channel: &BgChannel, s2: f64, n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (channel.prior.sample(&mut rng) + rng.gaussian() * s2.sqrt()) as f32)
        .collect()
}

/// The two scenario model channels the runtime designs against.
fn pin_contexts(len: usize) -> Vec<(&'static str, DesignCtx)> {
    let prior = BernoulliGauss::standard(0.05);
    vec![
        ("row", design_ctx::<Row>(&prior, 6, 0.05, len, 3)),
        ("column", design_ctx::<Column>(&prior, 6, 0.03, len, 3)),
    ]
}

/// The bit-equality pin: `ecsq.<codec>` ≡ `EcsqCoder` with that codec.
#[test]
fn ecsq_stacks_bit_identical_to_reference_coder() {
    let len = 2_000usize;
    for (scenario, ctx) in pin_contexts(len) {
        let xs = sample_block(&ctx.channel, ctx.noise_var, len, 0x5EED);
        for (codec_name, codec_kind) in [
            ("analytic", CodecKind::Analytic),
            ("range", CodecKind::Range),
            ("huffman", CodecKind::Huffman),
        ] {
            for (target_label, reference, stack_state) in [
                (
                    "rate3",
                    EcsqCoder::for_rate(&ctx.channel, ctx.noise_var, 3.0, CLIP_SDS, codec_kind)
                        .unwrap(),
                    registry::get(&format!("ecsq.{codec_name}"))
                        .unwrap()
                        .design_rate(&ctx, 3.0)
                        .unwrap(),
                ),
                (
                    "mse",
                    EcsqCoder::for_mse(
                        &ctx.channel,
                        ctx.noise_var,
                        ctx.noise_var * 0.05,
                        CLIP_SDS,
                        codec_kind,
                    )
                    .unwrap(),
                    registry::get(&format!("ecsq.{codec_name}"))
                        .unwrap()
                        .design_mse(&ctx, ctx.noise_var * 0.05)
                        .unwrap(),
                ),
            ] {
                let label = format!("{scenario}/ecsq.{codec_name}/{target_label}");
                // The runtime path: design → wire params → assemble.
                let stack = registry::get(&format!("ecsq.{codec_name}")).unwrap();
                let comp = stack.assemble(&ctx, &stack_state.params()).unwrap();
                let bctx = BlockCtx { worker: 1 };

                // Same quantizer design (Δ rides in params[0]).
                let params = stack_state.params();
                assert_eq!(
                    params[0].to_bits(),
                    reference.quantizer.delta.to_bits(),
                    "{label}: Δ differs"
                );
                assert_eq!(params[1] as i32, reference.quantizer.k_max, "{label}: k_max");

                // Same symbols.
                let ref_syms = reference.quantizer.quantize_block(&xs);
                let new_syms = comp.quantize(&bctx, &xs);
                assert_eq!(ref_syms, new_syms, "{label}: symbols differ");

                // Same model σ_Q² and analytic bits.
                assert_eq!(
                    comp.distortion_model().to_bits(),
                    reference.quantizer.sigma_q2().to_bits(),
                    "{label}: σ_Q²"
                );
                assert_eq!(
                    comp.model_bits_per_element().to_bits(),
                    reference.entropy_bits.to_bits(),
                    "{label}: H_Q"
                );

                // Same wire bytes + charged bits.
                let ref_block = reference.encode_symbols(&ref_syms).unwrap();
                let new_block = comp.encode(&bctx, &xs).unwrap();
                assert_eq!(ref_block.bytes, new_block.bytes, "{label}: wire bytes");
                assert_eq!(
                    ref_block.wire_bits.to_bits(),
                    new_block.wire_bits.to_bits(),
                    "{label}: wire bits"
                );

                // Same reconstruction, element for element.
                let mut ref_out = vec![0f32; len];
                reference.decode(&ref_block, Some(&ref_syms), &mut ref_out).unwrap();
                let mut new_out = vec![0f32; len];
                if comp.carries_payload() {
                    comp.decode(&bctx, &new_block.bytes, &mut new_out).unwrap();
                } else {
                    comp.dequantize(&bctx, &new_syms, &mut new_out).unwrap();
                }
                for (i, (a, b)) in ref_out.iter().zip(&new_out).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "{label}: element {i}");
                }
            }
        }
    }
}

/// Session-level pin: the default-family `"ecsq.huffman"` stack yields
/// bit-identical runs across transports on both partitionings, and the
/// deprecated `codec` alias resolves to the very same stack.
#[test]
fn ecsq_huffman_sessions_bit_stable_row_column_inproc_tcp() {
    for partitioning in [Partitioning::Row, Partitioning::Column] {
        let base = SessionBuilder::test_small(0.05)
            .partitioning(partitioning)
            .fixed_rate(4.0)
            .compressor("ecsq.huffman");
        let inproc = base.clone().build().unwrap().run().unwrap();
        let tcp = base
            .clone()
            .transport(TransportKind::Tcp)
            .build()
            .unwrap()
            .run()
            .unwrap();
        let label = format!("{partitioning:?}");
        assert!(inproc.final_sdr_db() > 8.0, "{label}: SDR {}", inproc.final_sdr_db());
        assert_eq!(inproc.iters.len(), tcp.iters.len(), "{label}");
        for (a, b) in inproc.iters.iter().zip(&tcp.iters) {
            assert_eq!(a.sdr_db.to_bits(), b.sdr_db.to_bits(), "{label} t={}", a.t);
            assert_eq!(a.rate_wire.to_bits(), b.rate_wire.to_bits(), "{label} t={}", a.t);
            assert_eq!(a.sigma_q2.to_bits(), b.sigma_q2.to_bits(), "{label} t={}", a.t);
        }
        for (xa, xb) in inproc.final_xs.iter().zip(&tcp.final_xs) {
            for (a, b) in xa.iter().zip(xb) {
                assert_eq!(a.to_bits(), b.to_bits(), "{label}: final_x");
            }
        }
    }
    // Alias: the pre-refactor `codec = "huffman"` surface selects the
    // identical stack string the sessions above ran with.
    let cfg = mpamp::config::RunConfig::test_small(0.05)
        .apply_overrides(&[("codec".into(), "huffman".into())])
        .unwrap();
    assert_eq!(cfg.compressor, "ecsq.huffman");
}

/// The two new stacks run end to end on both partitionings, under both a
/// rate-style (fixed) and an MSE-style (BT) schedule.
#[test]
fn dithered_and_topk_run_end_to_end_row_and_column() {
    for compressor in ["ecsq-dithered.range", "topk.raw"] {
        for partitioning in [Partitioning::Row, Partitioning::Column] {
            let report = SessionBuilder::test_small(0.05)
                .partitioning(partitioning)
                .fixed_rate(4.0)
                .compressor(compressor)
                .build()
                .unwrap()
                .run()
                .unwrap();
            let label = format!("{compressor}/{partitioning:?}");
            assert_eq!(report.iters.len(), 6, "{label}");
            assert!(report.final_sdr_db().is_finite(), "{label}");
            assert!(report.total_uplink_bits_per_element() > 0.0, "{label}");
            // Subtractive dither keeps the ECSQ operating point: the run
            // must still recover the signal at 4 bits/element.
            if compressor.starts_with("ecsq-dithered") {
                assert!(
                    report.final_sdr_db() > 5.0,
                    "{label}: SDR {}",
                    report.final_sdr_db()
                );
            }
        }
        // MSE-targeted directives (BT) exercise design_mse end to end.
        let report = SessionBuilder::test_small(0.05)
            .backtrack(1.05, 6.0)
            .compressor(compressor)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(report.iters.len(), 6, "{compressor}/bt");
        assert!(report.final_sdr_db().is_finite(), "{compressor}/bt");
    }
}

/// Property: for every registered stack, a wire round trip
/// (quantize → encode → decode → dequantize) reconstructs exactly what
/// direct dequantization of the encoder's symbols gives, and the charged
/// `wire_bits` agree with the bytes that actually travel.
#[test]
fn prop_roundtrip_and_wire_bits_for_every_registered_stack() {
    let names = registry::names();
    Prop::new("stack wire round trips", 40).check(|g| {
        let len = g.usize_in(16, 700);
        let rate = g.f64_in(0.8, 6.0);
        let prior = BernoulliGauss::standard(g.f64_in(0.02, 0.3));
        let var = g.f64_log_in(1e-3, 0.5);
        let ctx = if g.bool_with(0.5) {
            design_ctx::<Row>(&prior, g.usize_in(2, 30), var, len, g.u64())
        } else {
            design_ctx::<Column>(&prior, g.usize_in(2, 30), var, len, g.u64())
        };
        let xs = sample_block(&ctx.channel, ctx.noise_var, len, g.u64());
        let bctx = BlockCtx { worker: *g.choice(&[0u32, 1, 2, 7, 29]) };
        for name in &names {
            let stack = registry::get(name).map_err(|e| e.to_string())?;
            let state = stack.design_rate(&ctx, rate).map_err(|e| e.to_string())?;
            let comp = stack.assemble(&ctx, &state.params()).map_err(|e| e.to_string())?;
            let syms = comp.quantize(&bctx, &xs);
            let mut direct = vec![0f32; len];
            comp.dequantize(&bctx, &syms, &mut direct).map_err(|e| e.to_string())?;
            let block = comp.encode(&bctx, &xs).map_err(|e| e.to_string())?;
            prop_assert(
                block.wire_bits.is_finite() && block.wire_bits >= 0.0,
                format!("{name}: wire_bits {}", block.wire_bits),
            )?;
            if comp.carries_payload() {
                // Bytes on the wire must account for every charged bit,
                // with less than one byte of padding slack.
                let byte_bits = block.bytes.len() as f64 * 8.0;
                prop_assert(
                    byte_bits >= block.wire_bits && byte_bits - block.wire_bits < 8.0,
                    format!("{name}: {byte_bits} byte-bits vs {} charged", block.wire_bits),
                )?;
                let mut via_wire = vec![0f32; len];
                comp.decode(&bctx, &block.bytes, &mut via_wire)
                    .map_err(|e| format!("{name}: {e}"))?;
                for (i, (a, b)) in direct.iter().zip(&via_wire).enumerate() {
                    prop_assert(
                        a.to_bits() == b.to_bits(),
                        format!("{name}: element {i}: {a} != {b}"),
                    )?;
                }
            } else {
                // Payload-free codecs still charge their analytic bits.
                prop_assert(
                    block.bytes.is_empty(),
                    format!("{name}: payload-free codec produced bytes"),
                )?;
            }
            prop_assert(
                comp.distortion_model().is_finite() && comp.distortion_model() >= 0.0,
                format!("{name}: distortion model {}", comp.distortion_model()),
            )?;
        }
        Ok(())
    });
}

/// Property: hostile symbol streams and byte streams are rejected, never
/// trusted (top-K indices out of range, truncated raw streams).
#[test]
fn prop_malformed_wire_input_rejected() {
    Prop::new("malformed stack input rejected", 30).check(|g| {
        let len = g.usize_in(8, 200);
        let prior = BernoulliGauss::standard(0.05);
        let ctx = design_ctx::<Row>(&prior, 6, 0.05, len, g.u64());
        let stack = registry::get("topk.raw").map_err(|e| e.to_string())?;
        let comp = stack.assemble(&ctx, &[4.0]).map_err(|e| e.to_string())?;
        let bctx = BlockCtx { worker: 0 };
        // An index past the end of the block must error, not panic.
        let bad_syms = vec![len + g.usize_in(0, 10), 0x3F80_0000, 0, 0, 1, 0, 2, 0];
        let mut out = vec![0f32; len];
        prop_assert(
            comp.dequantize(&bctx, &bad_syms, &mut out).is_err(),
            "out-of-range index accepted",
        )?;
        // Truncated byte streams must error.
        prop_assert(
            comp.decode(&bctx, &[1, 2, 3], &mut out).is_err(),
            "truncated raw stream accepted",
        )?;
        // Duplicate indices violate the encoder's strictly-increasing
        // invariant and must be rejected, not silently overwritten.
        let dup_syms = vec![0, 0x3F80_0000, 0, 0x3F80_0000, 1, 0, 2, 0];
        prop_assert(
            comp.dequantize(&bctx, &dup_syms, &mut out).is_err(),
            "duplicate topk indices accepted",
        )?;
        Ok(())
    });
}

/// Top-K semantics: the kept coefficients survive exactly, everything
/// else reconstructs to zero, and the reported rate matches 64 bits per
/// kept entry.
#[test]
fn topk_keeps_largest_magnitudes_exactly() {
    let len = 64usize;
    let prior = BernoulliGauss::standard(0.05);
    let ctx = design_ctx::<Row>(&prior, 6, 0.05, len, 9);
    let stack = registry::get("topk.raw").unwrap();
    let k = 5usize;
    let comp = stack.assemble(&ctx, &[k as f64]).unwrap();
    let mut xs = vec![0f32; len];
    // Plant k large entries among small noise.
    let mut rng = Rng::new(4);
    for x in xs.iter_mut() {
        *x = (rng.gaussian() * 0.01) as f32;
    }
    let planted = [(3usize, 5.0f32), (17, -4.0), (31, 3.5), (40, -3.25), (63, 3.0)];
    for &(i, v) in &planted {
        xs[i] = v;
    }
    let bctx = BlockCtx { worker: 0 };
    let block = comp.encode(&bctx, &xs).unwrap();
    assert_eq!(block.bytes.len(), 4 * 2 * k, "4 bytes per index/value symbol");
    assert!((comp.model_bits_per_element() - 64.0 * k as f64 / len as f64).abs() < 1e-12);
    let mut out = vec![0f32; len];
    comp.decode(&bctx, &block.bytes, &mut out).unwrap();
    for (i, &o) in out.iter().enumerate() {
        match planted.iter().find(|(j, _)| *j == i) {
            Some(&(_, v)) => assert_eq!(o.to_bits(), v.to_bits(), "kept {i}"),
            None => assert_eq!(o.to_bits(), 0f32.to_bits(), "dropped {i} must be zero"),
        }
    }
    // Dropped-energy model: strictly positive (something is dropped) and
    // bounded by the channel's total second moment.
    let total = ctx.channel.expect_f(ctx.noise_var, |f| f * f);
    assert!(comp.distortion_model() > 0.0);
    assert!(comp.distortion_model() <= total * (1.0 + 1e-9));
}

/// Subtractive dither: reconstruction error never exceeds Δ/2 away from
/// saturation, and the dither makes per-worker quantization errors
/// differ while both protocol sides stay in lockstep.
#[test]
fn dithered_ecsq_error_bounded_and_worker_independent() {
    let len = 1_000usize;
    let prior = BernoulliGauss::standard(0.05);
    let ctx = design_ctx::<Row>(&prior, 6, 0.05, len, 0xD17);
    let stack = registry::get("ecsq-dithered.range").unwrap();
    let state = stack.design_rate(&ctx, 4.0).unwrap();
    let comp = stack.assemble(&ctx, &state.params()).unwrap();
    let delta = state.params()[0];
    let xs = sample_block(&ctx.channel, ctx.noise_var, len, 12);
    let mut recon = vec![vec![0f32; len]; 2];
    for (w, out) in recon.iter_mut().enumerate() {
        let bctx = BlockCtx { worker: w as u32 };
        let block = comp.encode(&bctx, &xs).unwrap();
        comp.decode(&bctx, &block.bytes, out).unwrap();
        for (i, (x, o)) in xs.iter().zip(out.iter()).enumerate() {
            assert!(
                ((x - o).abs() as f64) <= delta / 2.0 + delta + 1e-9,
                "worker {w} element {i}: |{x} - {o}| vs Δ={delta}"
            );
        }
    }
    // Different workers see different dither streams.
    assert!(
        recon[0].iter().zip(&recon[1]).any(|(a, b)| a.to_bits() != b.to_bits()),
        "worker dither streams identical"
    );
}
