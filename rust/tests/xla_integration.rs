//! Integration tests for the XLA/PJRT engine: the AOT JAX/Pallas artifacts
//! must agree with the pure-Rust engine to f32 tolerance, and a full
//! MP-AMP session on the XLA engine must reproduce the Rust engine's run.
//!
//! These tests need `artifacts/test/` (built by `make artifacts`); they
//! skip with a notice when it is missing so `cargo test` works on a fresh
//! checkout.

use mpamp::config::{EngineKind, RunConfig, ScheduleKind};
use mpamp::coordinator::session::MpAmpSession;
use mpamp::engine::{ComputeEngine, RustEngine, WorkerData};
use mpamp::runtime::XlaEngine;
use mpamp::signal::{BernoulliGauss, Instance, ProblemDims};
use mpamp::util::rng::Rng;

const TEST_ARTIFACTS: &str = "artifacts/test";
const N: usize = 600;
const MP: usize = 30;
const P: usize = 6;

fn artifacts_available() -> bool {
    if !cfg!(feature = "xla") {
        eprintln!("SKIP: built without the `xla` feature — PJRT engine is a stub");
        return false;
    }
    let ok = std::path::Path::new(TEST_ARTIFACTS).join("manifest.toml").exists();
    if !ok {
        eprintln!("SKIP: {TEST_ARTIFACTS}/ missing — run `make artifacts` first");
    }
    ok
}

fn test_instance(seed: u64) -> Instance {
    let prior = BernoulliGauss::standard(0.05);
    let sigma_e2 = mpamp::signal::sigma_e2_for_snr(&prior, 0.3, 20.0);
    let mut rng = Rng::new(seed);
    Instance::generate(prior, ProblemDims { n: N, m: MP * P, sigma_e2 }, &mut rng).unwrap()
}

#[test]
fn xla_lc_step_matches_rust_engine() {
    if !artifacts_available() {
        return;
    }
    let inst = test_instance(21);
    let rust = RustEngine::new(inst.prior, 2);
    let xla = XlaEngine::load(TEST_ARTIFACTS, inst.prior, N, MP, P).unwrap();
    let shard = WorkerData::try_split(&inst.a, &inst.y, P).unwrap().remove(2);
    let mut rng = Rng::new(5);
    let x: Vec<f32> = (0..N).map(|_| rng.gaussian() as f32 * 0.2).collect();
    let z_prev: Vec<f32> = (0..MP).map(|_| rng.gaussian() as f32 * 0.1).collect();
    let r = rust.lc_step(&shard.a, &shard.y, &x, &z_prev, 0.7, P).unwrap();
    let g = xla.lc_step(&shard.a, &shard.y, &x, &z_prev, 0.7, P).unwrap();
    for i in 0..MP {
        assert!(
            (r.z[i] - g.z[i]).abs() < 1e-4,
            "z[{i}]: rust {} vs xla {}",
            r.z[i],
            g.z[i]
        );
    }
    for i in 0..N {
        assert!(
            (r.f_partial[i] - g.f_partial[i]).abs() < 1e-3,
            "f[{i}]: rust {} vs xla {}",
            r.f_partial[i],
            g.f_partial[i]
        );
    }
    assert!(
        (r.z_norm2 - g.z_norm2).abs() < 1e-2 * (1.0 + r.z_norm2),
        "znorm: rust {} vs xla {}",
        r.z_norm2,
        g.z_norm2
    );
}

#[test]
fn xla_gc_step_matches_rust_engine() {
    if !artifacts_available() {
        return;
    }
    let prior = BernoulliGauss::standard(0.05);
    let rust = RustEngine::new(prior, 2);
    let xla = XlaEngine::load(TEST_ARTIFACTS, prior, N, MP, P).unwrap();
    let mut rng = Rng::new(9);
    let f: Vec<f32> = (0..N)
        .map(|_| {
            let s0 = if rng.bernoulli(0.05) { rng.gaussian() } else { 0.0 };
            (s0 + rng.gaussian() * 0.15) as f32
        })
        .collect();
    let s2 = 0.02;
    let r = rust.gc_step(&f, s2).unwrap();
    let g = xla.gc_step(&f, s2).unwrap();
    for i in 0..N {
        assert!(
            (r.x_next[i] - g.x_next[i]).abs() < 5e-4,
            "x[{i}]: rust {} vs xla {} (f={})",
            r.x_next[i],
            g.x_next[i],
            f[i]
        );
    }
    assert!(
        (r.eta_prime_mean - g.eta_prime_mean).abs() < 1e-3,
        "η′ mean: rust {} vs xla {}",
        r.eta_prime_mean,
        g.eta_prime_mean
    );
}

#[test]
fn xla_session_matches_rust_session() {
    if !artifacts_available() {
        return;
    }
    let mut cfg = RunConfig::test_small(0.05);
    cfg.schedule = ScheduleKind::Fixed { bits: 4.0 };
    assert_eq!((cfg.n, cfg.m / cfg.p, cfg.p), (N, MP, P), "test shapes drifted");
    let rust_report = MpAmpSession::new(cfg.clone()).unwrap().run().unwrap();
    cfg.engine = EngineKind::Xla;
    cfg.artifact_dir = TEST_ARTIFACTS.into();
    let xla_report = MpAmpSession::new(cfg).unwrap().run().unwrap();
    assert_eq!(xla_report.engine, "xla");
    for (a, b) in rust_report.iters.iter().zip(&xla_report.iters) {
        assert!(
            (a.sdr_db - b.sdr_db).abs() < 0.5,
            "t={}: rust SDR {} vs xla SDR {}",
            a.t,
            a.sdr_db,
            b.sdr_db
        );
        // Quantizer decisions derive from σ̂², which matches to f32 noise,
        // so wire rates agree closely too.
        assert!(
            (a.rate_wire - b.rate_wire).abs() < 0.1,
            "t={}: wire {} vs {}",
            a.t,
            a.rate_wire,
            b.rate_wire
        );
    }
    assert!(xla_report.final_sdr_db() > 8.0);
}

#[test]
fn xla_engine_used_from_many_threads() {
    // The Mutex-serialized Send/Sync wrapper must survive concurrent use.
    if !artifacts_available() {
        return;
    }
    let prior = BernoulliGauss::standard(0.05);
    let xla =
        std::sync::Arc::new(XlaEngine::load(TEST_ARTIFACTS, prior, N, MP, P).unwrap());
    let inst = test_instance(33);
    let shards = WorkerData::try_split(&inst.a, &inst.y, P).unwrap();
    std::thread::scope(|s| {
        for shard in &shards {
            let xla = xla.clone();
            s.spawn(move || {
                let x = vec![0.1f32; N];
                let z = vec![0.0f32; MP];
                for _ in 0..3 {
                    let out = xla.lc_step(&shard.a, &shard.y, &x, &z, 0.0, P).unwrap();
                    assert_eq!(out.f_partial.len(), N);
                }
            });
        }
    });
}
