//! Experiment-lab integration tests: the knob manifest must cover (and
//! round-trip) every `RunConfig` knob, studies must validate against it
//! with the offending knob named, a one-point `lab run` must be
//! bit-identical to a direct `Session::new(cfg).run()`, and the `lab
//! gate` CLI must classify pass/regress/improve/new/missing with the
//! documented exit codes and bless semantics.

use std::process::Command;

use mpamp::bench_util::{read_bench_json, write_bench_json, BenchRecord};
use mpamp::config::toml;
use mpamp::config::{Partitioning, RunConfig, ScheduleKind, TransportKind, KNOWN_KEYS};
use mpamp::lab::{Manifest, Study};
use mpamp::util::proptest::{prop_assert, Prop};
use mpamp::Session;

/// The compiled CLI under test (cargo builds bin targets for test runs).
const BIN: &str = env!("CARGO_BIN_EXE_mpamp");

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("mpamp_lab_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn run_cli(args: &[&str]) -> (bool, String, String) {
    let out = Command::new(BIN).args(args).output().expect("spawn mpamp");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

// ---------------------------------------------------------------------
// Manifest coverage + round-trip
// ---------------------------------------------------------------------

#[test]
fn manifest_covers_every_config_knob_and_own_defaults_validate() {
    let m = Manifest::generate();
    let ids: Vec<&str> = m.knobs.iter().map(|k| k.id).collect();
    assert_eq!(ids, KNOWN_KEYS.to_vec(), "manifest must mirror KNOWN_KEYS");
    for knob in &m.knobs {
        if let Some(default) = &knob.default {
            knob.validate_value(default).unwrap_or_else(|e| {
                panic!("default of knob '{}' rejects itself: {e}", knob.id)
            });
        }
    }
}

/// Drift guard, property-tested through the TOML layer: any `RunConfig`
/// the config module can encode must validate cleanly knob-by-knob
/// against the generated manifest — so a new config field without a knob
/// spec (or a spec with wrong type/bounds) fails here, not in a study.
#[test]
fn manifest_roundtrips_randomized_configs_via_toml_layer() {
    let manifest = Manifest::generate();
    let stacks = mpamp::compress::registry::names();
    Prop::new("lab.manifest.roundtrip", 64).check(|g| {
        let mut cfg = RunConfig::paper_default(0.05);
        cfg.n = g.usize_in(100, 5_000);
        cfg.m = g.usize_in(50, 2_000);
        cfg.p = g.usize_in(1, 16);
        cfg.batch = g.usize_in(1, 4);
        cfg.partitioning = if g.bool_with(0.5) {
            Partitioning::Column
        } else {
            Partitioning::Row
        };
        cfg.prior.eps = g.f64_in(0.005, 0.95);
        cfg.prior.mu_s = g.gaussian();
        cfg.prior.sigma_s2 = g.f64_log_in(0.1, 10.0);
        cfg.snr_db = g.f64_in(0.0, 40.0);
        cfg.iters = g.usize_in(0, 40);
        // The TOML layer carries seeds as i64 — stay in its range.
        cfg.seed = g.u64() >> 1;
        cfg.threads = g.usize_in(1, 8);
        cfg.compressor = g.choice(&stacks).clone();
        cfg.transport = if g.bool_with(0.5) {
            TransportKind::Tcp
        } else {
            TransportKind::InProc
        };
        cfg.schedule = match g.usize_in(0, 3) {
            0 => ScheduleKind::Uncompressed,
            1 => ScheduleKind::Fixed { bits: g.f64_in(0.5, 8.0) },
            2 => ScheduleKind::BackTrack {
                ratio_max: g.f64_in(1.001, 2.0),
                r_max: g.f64_in(1.0, 8.0),
            },
            _ => {
                let budget = g.bool_with(0.5);
                ScheduleKind::Dp {
                    total_rate: budget.then(|| g.f64_in(4.0, 40.0)),
                    delta_r: g.f64_in(0.05, 0.5),
                }
            }
        };
        cfg.rd.alphabet = g.usize_in(3, 1_025);
        cfg.rd.curve_points = g.usize_in(2, 64);
        cfg.rd.tol = g.f64_log_in(1e-6, 1e-2);
        cfg.rd.gamma_grid = g.usize_in(2, 64);

        let mut table = toml::Table::new();
        cfg.encode_into(&mut table);
        for (id, v) in &table {
            manifest
                .validate_override(id, v)
                .map_err(|e| format!("encoded knob rejected: {e}"))?;
        }
        prop_assert(
            table.keys().all(|k| KNOWN_KEYS.contains(&k.as_str())),
            "encode_into emitted a key outside KNOWN_KEYS",
        )
    });
}

// ---------------------------------------------------------------------
// Determinism pin: declarative one-point study ≡ direct session
// ---------------------------------------------------------------------

/// `mpamp lab run` with a one-point overrides file must reproduce
/// `Session::new(cfg).run()` bit for bit — per-iteration SDR and wire
/// rate, final estimates, and both transport byte counters — on row and
/// column partitionings with a real compressed stack in the loop.
#[test]
fn one_point_study_reproduces_direct_session_bit_for_bit() {
    let manifest = Manifest::generate();
    for partitioning in ["row", "column"] {
        let text = format!(
            "[lab]\nname = \"pin\"\n[base]\nn = 400\nm = 120\np = 4\niters = 3\n\
             partitioning = \"{partitioning}\"\nschedule.kind = \"fixed\"\n\
             schedule.bits = 4.0\ncompressor = \"ecsq.range\"\nseed = 77\n"
        );
        let study =
            Study::from_table(&toml::parse(&text).unwrap(), "pin", &manifest).unwrap();
        assert_eq!(study.len(), 1, "{partitioning}: one-point study");
        let trials = study.trials().unwrap();
        assert_eq!(trials[0].label, "pin");

        let direct = Session::new(trials[0].config.clone()).unwrap().run().unwrap();
        let reports = study.run().unwrap();
        assert_eq!(reports.len(), 1);
        let got = &reports[0].report;

        assert_eq!(
            direct.iters.len(),
            got.iters.len(),
            "{partitioning}: iteration count"
        );
        for (t, (w, g)) in direct.iters.iter().zip(&got.iters).enumerate() {
            assert_eq!(
                w.sdr_db.to_bits(),
                g.sdr_db.to_bits(),
                "{partitioning}: sdr_db differs at t={t}"
            );
            assert_eq!(
                w.rate_wire.to_bits(),
                g.rate_wire.to_bits(),
                "{partitioning}: rate_wire differs at t={t}"
            );
        }
        assert_eq!(direct.final_xs.len(), got.final_xs.len());
        for (wx, gx) in direct.final_xs.iter().zip(&got.final_xs) {
            assert_eq!(wx.len(), gx.len());
            for (i, (w, g)) in wx.iter().zip(gx).enumerate() {
                assert_eq!(
                    w.to_bits(),
                    g.to_bits(),
                    "{partitioning}: final_x[{i}] differs"
                );
            }
        }
        assert_eq!(
            direct.transport_uplink_bits, got.transport_uplink_bits,
            "{partitioning}: uplink byte accounting"
        );
        assert_eq!(
            direct.transport_downlink_bits, got.transport_downlink_bits,
            "{partitioning}: downlink byte accounting"
        );
    }
}

// ---------------------------------------------------------------------
// CLI: lab check / lab run
// ---------------------------------------------------------------------

#[test]
fn lab_check_cli_accepts_valid_and_names_offending_knobs() {
    let dir = tmp_dir("check");
    let good = dir.join("good.toml");
    std::fs::write(
        &good,
        "[base]\nn = 400\nm = 120\np = 4\niters = 2\n[grid]\n\
         partitioning = \"row,column\"\n",
    )
    .unwrap();
    let (ok, stdout, _) = run_cli(&["lab", "check", good.to_str().unwrap()]);
    assert!(ok, "valid study must pass: {stdout}");
    assert!(stdout.contains("OK") && stdout.contains("2 trial(s)"), "{stdout}");

    // Unknown key, out-of-bounds value, type mismatch: each must fail
    // with the offending knob named.
    for (name, body, needle) in [
        ("unknown.toml", "snr_dbb = 20.0\n", "snr_dbb"),
        ("bounds.toml", "prior.eps = 1.5\n", "maximum"),
        ("type.toml", "n = \"many\"\n", "integer"),
    ] {
        let bad = dir.join(name);
        std::fs::write(&bad, body).unwrap();
        let (ok, stdout, stderr) = run_cli(&["lab", "check", bad.to_str().unwrap()]);
        assert!(!ok, "{name} must fail");
        assert!(stdout.contains("FAIL"), "{name}: {stdout}");
        assert!(stderr.contains(needle), "{name}: {stderr}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn lab_run_cli_writes_bench_records_for_every_trial() {
    let dir = tmp_dir("run");
    let study = dir.join("smoke.toml");
    std::fs::write(
        &study,
        "[lab]\nname = \"smoke\"\nthreads = 2\n[base]\nn = 400\nm = 120\np = 4\n\
         iters = 2\nschedule.kind = \"fixed\"\n[grid]\npartitioning = \"row,column\"\n",
    )
    .unwrap();
    let records_path = dir.join("BENCH_lab.json");
    let (ok, _, stderr) = run_cli(&[
        "lab",
        "run",
        study.to_str().unwrap(),
        "--records",
        records_path.to_str().unwrap(),
        "--quiet",
    ]);
    assert!(ok, "lab run failed: {stderr}");
    let records = read_bench_json(records_path.to_str().unwrap()).unwrap();
    assert_eq!(records.len(), 2);
    assert_eq!(records[0].name, "smoke/partitioning=row");
    assert_eq!(records[1].name, "smoke/partitioning=column");
    for r in &records {
        assert!(r.wall_s > 0.0 && r.bytes_uplinked > 0 && r.signals_per_s > 0.0);
        assert!(r.sdr_per_bit.is_some() && r.rounds_per_s.is_some());
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// CLI: lab manifest snapshot check
// ---------------------------------------------------------------------

#[test]
fn lab_manifest_cli_snapshot_matches_library_and_detects_drift() {
    let dir = tmp_dir("manifest");
    let snap = dir.join("knob_manifest.json");
    let (ok, _, stderr) =
        run_cli(&["lab", "manifest", "--out", snap.to_str().unwrap()]);
    assert!(ok, "manifest --out failed: {stderr}");
    // CLI output is exactly the library render (what CI snapshots).
    let written = std::fs::read_to_string(&snap).unwrap();
    assert_eq!(written, Manifest::generate().render());

    let (ok, _, stderr) =
        run_cli(&["lab", "manifest", "--check", snap.to_str().unwrap()]);
    assert!(ok, "pristine snapshot must pass --check: {stderr}");

    // Any byte of drift fails the check with a regeneration hint.
    std::fs::write(&snap, written + " ").unwrap();
    let (ok, _, stderr) =
        run_cli(&["lab", "manifest", "--check", snap.to_str().unwrap()]);
    assert!(!ok, "tampered snapshot must fail --check");
    assert!(stderr.contains("drifted"), "{stderr}");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// CLI: lab gate classification + bless
// ---------------------------------------------------------------------

fn gate_rec(name: &str, wall_s: f64, bytes: u64, rps: Option<f64>) -> BenchRecord {
    BenchRecord {
        name: name.into(),
        wall_s,
        bytes_uplinked: bytes,
        signals_per_s: 2.0,
        sdr_per_bit: Some(0.8),
        rounds_per_s: rps,
        gflops: None,
        jobs_per_s: None,
    }
}

#[test]
fn lab_gate_cli_classifies_and_blesses() {
    let dir = tmp_dir("gate");
    let baseline = dir.join("baselines.json");
    let current = dir.join("BENCH_pr.json");
    let bp = baseline.to_str().unwrap();
    let cp = current.to_str().unwrap();
    let write_current = |records: &[BenchRecord]| {
        write_bench_json(cp, records).unwrap();
    };

    // Bless into a fresh store, then the same records pass the gate.
    write_current(&[gate_rec("a", 1.0, 100, Some(5.0)), gate_rec("b", 2.0, 0, None)]);
    let (ok, _, stderr) =
        run_cli(&["lab", "gate", "--baseline", bp, "--current", cp, "--bless"]);
    assert!(ok, "bless failed: {stderr}");
    let (ok, stdout, _) = run_cli(&["lab", "gate", "--baseline", bp, "--current", cp]);
    assert!(ok, "unchanged records must pass: {stdout}");
    assert!(stdout.contains("**PASS**"), "{stdout}");

    // Out-of-band wall_s (±50% band): exit nonzero, markdown names the
    // record, the metric, the delta, and the verdict; --md writes it.
    let md_path = dir.join("gate.md");
    write_current(&[gate_rec("a", 3.0, 100, Some(5.0)), gate_rec("b", 2.0, 0, None)]);
    let (ok, stdout, _) = run_cli(&[
        "lab", "gate", "--baseline", bp, "--current", cp, "--md",
        md_path.to_str().unwrap(),
    ]);
    assert!(!ok, "regression must exit nonzero: {stdout}");
    assert!(stdout.contains("**FAIL**"), "{stdout}");
    assert!(stdout.contains("| `a` | wall_s |"), "{stdout}");
    assert!(stdout.contains("+200.0%"), "{stdout}");
    assert!(stdout.contains("**regress**"), "{stdout}");
    let md = std::fs::read_to_string(&md_path).unwrap();
    assert!(md.starts_with("### Perf gate"), "{md}");

    // Improvements stay green (flagged, not failed).
    write_current(&[gate_rec("a", 0.3, 100, Some(9.0)), gate_rec("b", 2.0, 0, None)]);
    let (ok, stdout, _) = run_cli(&["lab", "gate", "--baseline", bp, "--current", cp]);
    assert!(ok, "improvement must pass: {stdout}");
    assert!(stdout.contains("**improve**"), "{stdout}");

    // A record only in the current run is new (passes); a baseline
    // record missing from the current run fails the gate.
    write_current(&[
        gate_rec("a", 1.0, 100, Some(5.0)),
        gate_rec("b", 2.0, 0, None),
        gate_rec("c", 1.0, 0, None),
    ]);
    let (ok, stdout, _) = run_cli(&["lab", "gate", "--baseline", bp, "--current", cp]);
    assert!(ok, "new record must pass: {stdout}");
    assert!(stdout.contains("| `c` |") && stdout.contains("**new**"), "{stdout}");
    write_current(&[gate_rec("a", 1.0, 100, Some(5.0))]);
    let (ok, stdout, _) = run_cli(&["lab", "gate", "--baseline", bp, "--current", cp]);
    assert!(!ok, "missing record must fail: {stdout}");
    assert!(stdout.contains("| `b` |") && stdout.contains("**missing**"), "{stdout}");

    // --bless re-baselines: the previously failing set now passes, and
    // the store keeps one record per line for reviewable diffs.
    write_current(&[gate_rec("a", 3.0, 100, Some(5.0))]);
    let (ok, _, stderr) =
        run_cli(&["lab", "gate", "--baseline", bp, "--current", cp, "--bless"]);
    assert!(ok, "re-bless failed: {stderr}");
    let (ok, stdout, _) = run_cli(&["lab", "gate", "--baseline", bp, "--current", cp]);
    assert!(ok, "blessed records must pass: {stdout}");
    let store_text = std::fs::read_to_string(&baseline).unwrap();
    assert!(store_text.contains("\"tolerances\""), "{store_text}");
    assert_eq!(
        store_text.lines().filter(|l| l.contains("\"name\":")).count(),
        1,
        "{store_text}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// `--subset` waives coverage (baseline records the current set does not
/// measure) but keeps full-strength bands on the records it does cover —
/// the mode the scheduled reproduction job uses to gate its study records
/// against the same committed store as the per-PR suite.
#[test]
fn lab_gate_cli_subset_waives_coverage_not_bands() {
    let dir = tmp_dir("subset");
    let baseline = dir.join("baselines.json");
    let current = dir.join("BENCH_repro.json");
    let bp = baseline.to_str().unwrap();
    let cp = current.to_str().unwrap();

    write_bench_json(
        cp,
        &[gate_rec("a", 1.0, 100, Some(5.0)), gate_rec("b", 2.0, 0, None)],
    )
    .unwrap();
    let (ok, _, stderr) =
        run_cli(&["lab", "gate", "--baseline", bp, "--current", cp, "--bless"]);
    assert!(ok, "bless failed: {stderr}");

    // Current measures only `a`, in band: strict fails on the uncovered
    // `b`, --subset passes without even listing it.
    write_bench_json(cp, &[gate_rec("a", 1.0, 100, Some(5.0))]).unwrap();
    let (ok, stdout, _) = run_cli(&["lab", "gate", "--baseline", bp, "--current", cp]);
    assert!(!ok, "strict mode must fail on missing record: {stdout}");
    let (ok, stdout, _) =
        run_cli(&["lab", "gate", "--baseline", bp, "--current", cp, "--subset"]);
    assert!(ok, "--subset must waive the uncovered record: {stdout}");
    assert!(!stdout.contains("| `b` |"), "{stdout}");

    // A covered record out of band still fails under --subset.
    write_bench_json(cp, &[gate_rec("a", 9.0, 100, Some(5.0))]).unwrap();
    let (ok, stdout, _) =
        run_cli(&["lab", "gate", "--baseline", bp, "--current", cp, "--subset"]);
    assert!(!ok, "--subset must keep gating covered records: {stdout}");
    assert!(stdout.contains("**regress**"), "{stdout}");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// The committed store actually enforces
// ---------------------------------------------------------------------

/// `ci/baselines.json` must be a live gate, not a bootstrap stub: it has
/// blessed records (including the three kernel GFLOP/s rows the perf-smoke
/// job requires), it passes against itself, and a collapsed kernel
/// throughput trips it.
#[test]
fn committed_baseline_store_is_nonempty_and_enforces() {
    use mpamp::bench_util::compare::{compare, Baselines};
    let store =
        Baselines::load(concat!(env!("CARGO_MANIFEST_DIR"), "/ci/baselines.json")).unwrap();
    assert!(!store.records.is_empty(), "ci/baselines.json must have blessed records");
    for want in
        ["gflops matmul shard", "gflops matmul_t shard", "gflops fused lc_step"]
    {
        assert!(
            store.records.iter().any(|r| r.name.starts_with(want)),
            "store must bless a '{want}' record"
        );
    }
    // Blessed records must only use structurally-zero byte counters: the
    // ±2% bytes_uplinked band is too tight for entropy-coded sessions, so
    // those records enter the store via an intentional future bless, not
    // the hand-seeded floor set.
    assert!(store.records.iter().all(|r| r.bytes_uplinked == 0));
    assert!(store.tolerance("bytes_uplinked") <= 0.05);

    let cmp = compare(&store, &store.records);
    assert!(cmp.gate_passes(), "store must pass against itself:\n{}", cmp.markdown());

    // A kernel delivering 1% of its blessed GFLOP/s is out of band.
    let mut collapsed = store.records.clone();
    let slot = collapsed
        .iter_mut()
        .find(|r| r.gflops.is_some())
        .expect("store has a gflops record");
    slot.gflops = slot.gflops.map(|g| g * 0.01);
    let cmp = compare(&store, &collapsed);
    assert!(!cmp.gate_passes(), "collapsed kernel must fail:\n{}", cmp.markdown());
}
