//! Fused LC step: the `matvec → residual → matvec_t` chain of one AMP
//! round as a single pass per row panel.
//!
//! The row-partitioned LC step (paper §2) is
//!
//! ```text
//! z = y − A·x + coef·z_prev        (residual)
//! f = x/P + Aᵀ·z                   (pseudo-data partial)
//! ```
//!
//! Composed from separate kernels that is three full passes over the
//! shard. [`Matrix::lc_fused`] instead computes, per [`PANEL_ROWS`]
//! panel: the panel's `z` rows (forward microkernel), the residual
//! epilogue on those rows, and the panel's contribution to `Aᵀz` —
//! while the panel of `A` and the fresh `z` values are still cache-hot.
//!
//! # Bitwise contract
//!
//! The fused pass is bit-for-bit identical to the composed reference
//! (`matmul → residual epilogue → matmul_t → estimate epilogue`) by
//! construction: forward results are panel-invariant (absolute column
//! tiles), the residual epilogue is elementwise, and `f` accumulates
//! row panels in strictly ascending row order exactly like
//! [`Matrix::matmul_t`]. Both outputs are fully overwritten — callers
//! may pass dirty buffers. Property-pinned across {serial, pooled
//! chunks 1/2/odd/>dims} × {wide, tall shards} × B∈{1,4} below.

use super::kernel::{self, COL_TILE, PANEL_ROWS};
use super::{Matrix, PAR_MIN_ENTRIES};
use crate::runtime::pool::SendPtr;

impl Matrix {
    /// Fused LC step over `b` column-major signals:
    /// `z_j = y_j − A·x_j + coef_j·z_prev_j`, `f_j = x_j·inv_p + Aᵀ·z_j`.
    ///
    /// `z_out` (`b·rows`) and `f_out` (`b·cols`) are fully overwritten.
    /// Below the parallel crossover (same gate as
    /// [`matmul_par`](Self::matmul_par), batch folded in) this runs the
    /// truly fused serial panel pass; above it, the two passes dispatch
    /// through the gated pooled kernels. Both paths produce identical
    /// bits — see the module docs.
    #[allow(clippy::too_many_arguments)]
    pub fn lc_fused(
        &self,
        ys: &[f32],
        xs: &[f32],
        z_prevs: &[f32],
        coefs: &[f32],
        b: usize,
        inv_p: f32,
        z_out: &mut [f32],
        f_out: &mut [f32],
        threads: usize,
    ) {
        if !self.par_gate(self.rows, b, threads) {
            return self.lc_fused_serial(ys, xs, z_prevs, coefs, b, inv_p, z_out, f_out);
        }
        self.matmul_par(xs, b, z_out, threads);
        residual_epilogue(ys, z_prevs, coefs, self.rows, 0, self.rows, z_out);
        self.matmul_t_par(z_out, b, f_out, threads);
        estimate_epilogue(xs, inv_p, f_out);
    }

    /// The pooled body of [`lc_fused`](Self::lc_fused) without the size
    /// gate — `chunks` pool chunks for both passes regardless of shape
    /// (exposed so tests can pin pooled ≡ serial-fused at any size).
    #[allow(clippy::too_many_arguments)]
    pub fn lc_fused_pooled(
        &self,
        ys: &[f32],
        xs: &[f32],
        z_prevs: &[f32],
        coefs: &[f32],
        b: usize,
        inv_p: f32,
        z_out: &mut [f32],
        f_out: &mut [f32],
        chunks: usize,
    ) {
        self.matmul_pooled(xs, b, z_out, chunks);
        residual_epilogue(ys, z_prevs, coefs, self.rows, 0, self.rows, z_out);
        self.matmul_t_pooled(z_out, b, f_out, chunks);
        estimate_epilogue(xs, inv_p, f_out);
    }

    /// Serial fused pass: one trip over the shard per panel — forward,
    /// residual, and transposed accumulation share the hot panel.
    #[allow(clippy::too_many_arguments)]
    fn lc_fused_serial(
        &self,
        ys: &[f32],
        xs: &[f32],
        z_prevs: &[f32],
        coefs: &[f32],
        b: usize,
        inv_p: f32,
        z_out: &mut [f32],
        f_out: &mut [f32],
    ) {
        let rows = self.rows;
        let cols = self.cols;
        debug_assert_eq!(ys.len(), b * rows);
        debug_assert_eq!(xs.len(), b * cols);
        debug_assert_eq!(z_prevs.len(), b * rows);
        debug_assert_eq!(coefs.len(), b);
        debug_assert_eq!(z_out.len(), b * rows);
        debug_assert_eq!(f_out.len(), b * cols);
        f_out.iter_mut().for_each(|o| *o = 0.0);
        let mut p0 = 0;
        while p0 < rows {
            let p1 = (p0 + PANEL_ROWS).min(rows);
            let z_ptr = SendPtr::new(z_out.as_mut_ptr());
            // SAFETY: exclusive `&mut z_out`; this is the only live view.
            unsafe { kernel::forward_rows(&self.data, rows, cols, xs, b, z_ptr, p0, p1) };
            residual_epilogue(ys, z_prevs, coefs, rows, p0, p1, z_out);
            // Accumulate this panel's Aᵀz contribution while the panel of
            // A and the fresh z rows are cache-hot. Per output column the
            // row visit order is still strictly ascending across panels,
            // so f matches matmul_t bitwise.
            let mut t0 = 0;
            while t0 < cols {
                let t1 = (t0 + COL_TILE).min(cols);
                for r in p0..p1 {
                    let row = &self.data[r * cols + t0..r * cols + t1];
                    for j in 0..b {
                        let zr = z_out[j * rows + r];
                        kernel::axpy(zr, row, &mut f_out[j * cols + t0..j * cols + t1]);
                    }
                }
                t0 = t1;
            }
            p0 = p1;
        }
        estimate_epilogue(xs, inv_p, f_out);
    }
}

/// `z[k] = y[k] − z[k] + coef_j·z_prev[k]` over rows `[r0, r1)` of every
/// signal — elementwise, so application order never affects bits.
fn residual_epilogue(
    ys: &[f32],
    z_prevs: &[f32],
    coefs: &[f32],
    rows: usize,
    r0: usize,
    r1: usize,
    z: &mut [f32],
) {
    for (j, &cj) in coefs.iter().enumerate() {
        for r in r0..r1 {
            let k = j * rows + r;
            z[k] = ys[k] - z[k] + cj * z_prevs[k];
        }
    }
}

/// `f[i] += x[i]·inv_p` — the worker's own share of the estimate.
fn estimate_epilogue(xs: &[f32], inv_p: f32, f: &mut [f32]) {
    for (fi, &xi) in f.iter_mut().zip(xs) {
        *fi += xi * inv_p;
    }
}

#[cfg(test)]
mod tests {
    use super::super::Matrix;
    use crate::util::proptest::{prop_assert, Prop};
    use crate::util::rng::Rng;

    fn rand_matrix(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        let mut data = vec![0f32; r * c];
        rng.fill_gaussian(&mut data, 1.0);
        Matrix::from_vec(r, c, data).unwrap()
    }

    /// The composed reference the fused kernel must reproduce exactly:
    /// `matmul → residual epilogue → matmul_t → estimate epilogue`.
    fn composed(
        a: &Matrix,
        ys: &[f32],
        xs: &[f32],
        zp: &[f32],
        coefs: &[f32],
        b: usize,
        inv_p: f32,
    ) -> (Vec<f32>, Vec<f32>) {
        let (r, c) = (a.rows(), a.cols());
        let mut z = vec![0f32; b * r];
        a.matmul(xs, b, &mut z);
        for (j, &cj) in coefs.iter().enumerate() {
            for i in 0..r {
                let k = j * r + i;
                z[k] = ys[k] - z[k] + cj * zp[k];
            }
        }
        let mut f = vec![0f32; b * c];
        a.matmul_t(&z, b, &mut f);
        for (fi, &xi) in f.iter_mut().zip(xs) {
            *fi += xi * inv_p;
        }
        (z, f)
    }

    #[test]
    fn fused_bitwise_matches_composed_reference() {
        // {serial fused, pooled chunks 1/2/odd/>dims} × {wide row-shard,
        // tall column-shard} × B ∈ {1, 4}, with dirty outputs (the
        // fully-overwritten contract).
        Prop::new("lc_fused == composed (bitwise)", 10).check(|g| {
            let mut rng = Rng::new(g.u64());
            let wide = (g.usize_in(1, 30), g.usize_in(40, 90));
            let tall = (g.usize_in(40, 90), g.usize_in(1, 30));
            for &(r, c) in &[wide, tall] {
                for &b in &[1usize, 4] {
                    let a = rand_matrix(&mut rng, r, c);
                    let ys = g.gaussian_vec(b * r, 1.0);
                    let xs = g.gaussian_vec(b * c, 1.0);
                    let zp = g.gaussian_vec(b * r, 0.5);
                    let coefs: Vec<f32> =
                        (0..b).map(|_| g.f64_in(-0.9, 0.9) as f32).collect();
                    let inv_p = 0.25f32;
                    let (z_ref, f_ref) = composed(&a, &ys, &xs, &zp, &coefs, b, inv_p);
                    // chunks == 0 marks the serial truly-fused panel pass
                    // (threads=1 forces the gate to the serial branch).
                    for chunks in [0usize, 1, 2, 3, r + c + 1] {
                        let mut z = vec![7.5f32; b * r];
                        let mut f = vec![-2.5f32; b * c];
                        if chunks == 0 {
                            a.lc_fused(&ys, &xs, &zp, &coefs, b, inv_p, &mut z, &mut f, 1);
                        } else {
                            a.lc_fused_pooled(
                                &ys, &xs, &zp, &coefs, b, inv_p, &mut z, &mut f, chunks,
                            );
                        }
                        prop_assert(
                            z.iter().zip(&z_ref).all(|(x, y)| x.to_bits() == y.to_bits()),
                            format!("z {r}x{c} B={b} chunks={chunks}"),
                        )?;
                        prop_assert(
                            f.iter().zip(&f_ref).all(|(x, y)| x.to_bits() == y.to_bits()),
                            format!("f {r}x{c} B={b} chunks={chunks}"),
                        )?;
                    }
                }
            }
            Ok(())
        });
    }
}
