//! Fixed-lane, cache-blocked microkernels — the single arithmetic
//! reference for every dense kernel in the AMP hot path.
//!
//! # Blocking scheme
//!
//! All dense operations reduce to two panel kernels over a row-major
//! `rows × cols` shard:
//!
//! - [`forward_rows`] — `out_j[r] = ⟨A[r,·], x_j⟩` for a row range,
//!   computed panel-by-panel ([`PANEL_ROWS`] rows at a time) and
//!   tile-by-tile ([`COL_TILE`] columns at a time) so each `A` panel is
//!   reused across all `b` signals while L1/L2-resident.
//! - [`transposed_cols`] — `out_j[c] += z_j[r]·A[r,c]` for a column
//!   range, walking **all** rows in ascending order (panel over rows,
//!   tile over the owned columns) so each row of `A` is read once for
//!   all `b` signals.
//!
//! The innermost loops of both are the [`LANES`]-wide kernels
//! [`dot_lanes`] and [`axpy`]: fixed-width `[f32; 8]` accumulator
//! arrays over `chunks_exact(LANES)` slices, the shape LLVM reliably
//! autovectorizes to packed single-precision FMA/mul+add sequences.
//!
//! # Bitwise contract
//!
//! Summation order is a *function of the element index only*, never of
//! how work is split:
//!
//! - Tile boundaries are **absolute** (multiples of [`COL_TILE`] from
//!   column 0 of the slice passed in), and [`dot`] folds its lane
//!   accumulator in one fixed tree per tile — so a row dot product is
//!   the same float no matter which panel or chunk computed it.
//! - Transposed accumulation always visits rows `0..rows` in ascending
//!   order per output column — so column chunking and tiling never
//!   reorder the sum.
//! - [`axpy`] is elementwise; lane blocking changes instruction
//!   scheduling only.
//!
//! Consequently serial ≡ pooled (any chunk count) ≡ batched (any `B`)
//! bit-for-bit *by construction*, which is what lets the repo pin
//! TCP ≡ in-process and served ≡ standalone sessions bitwise.

use crate::runtime::pool::SendPtr;

/// SIMD lane width of the inner kernels: accumulators are `[f32; LANES]`
/// arrays processed over `chunks_exact(LANES)` slices.
///
/// 8 × f32 = one AVX2 register (two SSE registers / one NEON pair) —
/// wide enough to saturate the FP ports, narrow enough that the fixed
/// fold tree stays cheap on the tile tail.
pub const LANES: usize = 8;

/// Column-tile width (elements) of the blocked kernels. Tiles are
/// **absolute** — boundaries at multiples of `COL_TILE` from the start
/// of the row slice — which is what makes per-element sums independent
/// of panel/chunk splits. 512 × f32 = 2 KiB per row tile, so a
/// [`PANEL_ROWS`]-row panel tile (64 KiB) plus `b` signal tiles stay
/// L1/L2-resident.
pub const COL_TILE: usize = 512;

/// Rows per panel in the blocked kernels. A panel's output/residual
/// slice (`PANEL_ROWS × b` floats) stays register/L1-hot while the
/// panel's column tiles stream through.
pub const PANEL_ROWS: usize = 32;

/// `⟨a, b⟩` over one column tile with a fixed-width lane accumulator.
///
/// The `[f32; LANES]` accumulator over `chunks_exact(LANES)` is the
/// autovectorization-friendly core; the fold tree
/// `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))` and the scalar tail are
/// fixed, so the result depends only on the slice contents.
#[inline(always)]
pub(super) fn dot_lanes(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0f32; LANES];
    let aw = a.chunks_exact(LANES);
    let bw = b.chunks_exact(LANES);
    let (at, bt) = (aw.remainder(), bw.remainder());
    for (ca, cb) in aw.zip(bw) {
        for ((s, &x), &y) in acc.iter_mut().zip(ca).zip(cb) {
            *s += x * y;
        }
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for (&x, &y) in at.iter().zip(bt) {
        s += x * y;
    }
    s
}

/// Dot product: absolute [`COL_TILE`] segments, each reduced by the
/// fixed-width lane kernel (`dot_lanes`), accumulated left to right.
///
/// This exact order — tile partials added in ascending tile index onto
/// a zero-initialized scalar — is what the blocked matmul kernels
/// reproduce per output element, so `matmul`/`matvec` results are
/// bit-for-bit `dot(row, x)` regardless of blocking.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut s = 0f32;
    let mut c0 = 0;
    while c0 < n {
        let c1 = (c0 + COL_TILE).min(n);
        s += dot_lanes(&a[c0..c1], &b[c0..c1]);
        c0 = c1;
    }
    s
}

/// `y += alpha * x`, lane-blocked ([`LANES`]-wide inner loop).
///
/// The operation is elementwise (`y[i] += alpha·x[i]` independently per
/// lane), so blocking changes instruction scheduling only — results are
/// bit-identical to the rolled loop by construction.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let split = n - n % LANES;
    let (xw, xt) = x.split_at(split);
    let (yw, yt) = y.split_at_mut(split);
    for (cy, cx) in yw.chunks_exact_mut(LANES).zip(xw.chunks_exact(LANES)) {
        for (yi, &xi) in cy.iter_mut().zip(cx) {
            *yi += alpha * xi;
        }
    }
    for (yi, &xi) in yt.iter_mut().zip(xt) {
        *yi += alpha * xi;
    }
}

/// Forward panel kernel: `out[j·rows + r] = ⟨A[r,·], x_j⟩` for rows
/// `[r0, r1)` of a row-major `rows × cols` shard and `b` column-major
/// signals (`xs[j·cols..(j+1)·cols]`).
///
/// Output elements in the range are zero-initialized, then accumulated
/// one absolute [`COL_TILE`] at a time via [`dot_lanes`] — per element
/// the identical float sequence as [`dot`], so the result is invariant
/// to the row range this call covers (pooled chunks compose bitwise).
/// Rows are processed in [`PANEL_ROWS`] panels so a hot `A` panel tile
/// is reused across all `b` signals.
///
/// # Safety
///
/// `out` must point at a `b·rows` allocation, and indices
/// `j·rows + r` for `r ∈ [r0, r1)`, `j ∈ [0, b)` must be owned
/// exclusively by this call (disjoint row ranges across pool chunks).
#[allow(clippy::too_many_arguments)]
pub(super) unsafe fn forward_rows(
    data: &[f32],
    rows: usize,
    cols: usize,
    xs: &[f32],
    b: usize,
    out: SendPtr<f32>,
    r0: usize,
    r1: usize,
) {
    let mut p0 = r0;
    while p0 < r1 {
        let p1 = (p0 + PANEL_ROWS).min(r1);
        for j in 0..b {
            for r in p0..p1 {
                *out.add(j * rows + r) = 0.0;
            }
        }
        let mut c0 = 0;
        while c0 < cols {
            let c1 = (c0 + COL_TILE).min(cols);
            for r in p0..p1 {
                let row = &data[r * cols + c0..r * cols + c1];
                for j in 0..b {
                    let xj = &xs[j * cols + c0..j * cols + c1];
                    *out.add(j * rows + r) += dot_lanes(row, xj);
                }
            }
            c0 = c1;
        }
        p0 = p1;
    }
}

/// Transposed panel kernel: `out[j·cols + c] = Σ_r z_j[r]·A[r,c]` for
/// columns `[c0, c1)` of a row-major `rows × cols` shard and `b`
/// column-major inputs (`zs[j·rows..(j+1)·rows]`).
///
/// The owned column range is zero-initialized, then every row `0..rows`
/// is accumulated in strictly ascending order (panel over rows, tile
/// over the owned columns, [`axpy`] inner loop) — per output column the
/// identical float sequence regardless of column chunking or tiling, so
/// pooled chunks compose bitwise. Zero inputs are **not** skipped:
/// `o += 0.0·a` is applied like any other row, keeping `-0.0` edge
/// cases identical across every dispatch path.
///
/// # Safety
///
/// `out` must point at a `b·cols` allocation, and indices
/// `j·cols + c` for `c ∈ [c0, c1)`, `j ∈ [0, b)` must be owned
/// exclusively by this call (disjoint column ranges across pool
/// chunks). Per-signal views are created one at a time, never aliased.
#[allow(clippy::too_many_arguments)]
pub(super) unsafe fn transposed_cols(
    data: &[f32],
    rows: usize,
    cols: usize,
    zs: &[f32],
    b: usize,
    out: SendPtr<f32>,
    c0: usize,
    c1: usize,
) {
    for j in 0..b {
        let oj = std::slice::from_raw_parts_mut(out.add(j * cols + c0), c1 - c0);
        oj.iter_mut().for_each(|o| *o = 0.0);
    }
    let mut p0 = 0;
    while p0 < rows {
        let p1 = (p0 + PANEL_ROWS).min(rows);
        let mut t0 = c0;
        while t0 < c1 {
            let t1 = (t0 + COL_TILE).min(c1);
            for r in p0..p1 {
                let row = &data[r * cols + t0..r * cols + t1];
                for j in 0..b {
                    let zr = zs[j * rows + r];
                    let oj = std::slice::from_raw_parts_mut(out.add(j * cols + t0), t1 - t0);
                    axpy(zr, row, oj);
                }
            }
            t0 = t1;
        }
        p0 = p1;
    }
}
