//! Dense linear algebra for the AMP hot path.
//!
//! The sensing-matrix block a worker owns is `(M/P) × N` row-major
//! `f32`. Every dense operation — `A·x`, `Aᵀ·z`, their B-signal batched
//! forms, and the fused LC step ([`Matrix::lc_fused`]) — is built from
//! two cache-blocked microkernels (see `kernel.rs`): fixed [`LANES`]-wide
//! `[f32; 8]` accumulators in the inner loops ([`dot`], [`axpy`]),
//! absolute [`COL_TILE`] column tiles, and [`PANEL_ROWS`] row panels so
//! each hot panel of `A` is reused across all `b` signals.
//!
//! One arithmetic reference means serial, pooled, batched, row- and
//! column-scenario paths all produce identical bits **by construction**:
//! tile boundaries are absolute (a row dot product is the same float no
//! matter which chunk computed it) and transposed accumulation always
//! walks rows in ascending order per output column. The `*_pooled`
//! entry points skip the size gate so tests can pin pooled ≡ serial at
//! any size and chunk count.
//!
//! Parallel variants (`*_par`) dispatch panel-aligned chunks (see
//! [`chunk_span`](crate::runtime::pool::chunk_span)) to the shared
//! persistent [`Pool`] — no threads are spawned per call, and chunks
//! write disjoint regions of the caller's output directly, so the
//! parallel kernels allocate nothing.

mod fused;
mod kernel;

pub use kernel::{axpy, dot, COL_TILE, LANES, PANEL_ROWS};

use crate::error::{Error, Result};
use crate::runtime::pool::{chunk_span, Pool, SendPtr};

/// FLOP-proportional entry count (`rows·cols·b`) below which the
/// `*_par` kernels stay serial.
///
/// Carried dispatch-model value: with per-call thread spawns (the
/// pre-pool implementation) the measured break-even sat near 4M
/// entries; the persistent pool's dispatch is a mutex wake instead of
/// `P` spawns+joins, which moves the break-even down to roughly this
/// size on typical hardware — below it, memory-bandwidth saturation
/// makes extra threads a wash. The gate compares `rows·cols·b`, so a
/// B=8 batched matmul (8× the FLOPs of the same-shape matvec) crosses
/// over at one eighth the matrix size. Re-measure on target hardware
/// with `cargo bench --bench throughput -- --crossover`; the scheduled
/// reproduction CI job uploads that sweep as an artifact so future
/// re-measurements have a hardware-matched trace.
pub const PAR_MIN_ENTRIES: usize = 1_000_000;

/// Row-major dense `f32` matrix.
#[derive(Debug, Clone)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// Create from row-major data.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Numerical(format!(
                "matrix data length {} != {rows}x{cols}",
                data.len()
            )));
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row-major backing slice.
    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable backing slice.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Borrow row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Take a contiguous block of rows `[r0, r1)` as a new matrix (copy).
    pub fn row_block(&self, r0: usize, r1: usize) -> Matrix {
        debug_assert!(r0 <= r1 && r1 <= self.rows);
        Matrix {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    /// Take a contiguous block of columns `[c0, c1)` as a new matrix
    /// (copy). The gather is strided over the source (one slice per row) —
    /// it runs once at session start; the hot-path kernels then stay
    /// unit-stride over the extracted block.
    pub fn col_block(&self, c0: usize, c1: usize) -> Matrix {
        debug_assert!(c0 <= c1 && c1 <= self.cols);
        let w = c1 - c0;
        let mut data = Vec::with_capacity(self.rows * w);
        for r in 0..self.rows {
            data.extend_from_slice(&self.data[r * self.cols + c0..r * self.cols + c1]);
        }
        Matrix { rows: self.rows, cols: w, data }
    }

    /// Explicit transpose (copy) — the dense reference the transposed
    /// matvec ([`matvec_t`](Self::matvec_t), which never materializes `Aᵀ`
    /// and keeps its inner loop unit-stride) is property-tested against.
    pub fn transposed(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for (c, &v) in self.row(r).iter().enumerate() {
                out.data[c * self.rows + r] = v;
            }
        }
        out
    }

    /// `out = A x` (`out` has length `rows`) — [`matmul`](Self::matmul)
    /// with `b = 1`.
    pub fn matvec(&self, x: &[f32], out: &mut [f32]) {
        self.matmul(x, 1, out);
    }

    /// `out = Aᵀ z` (`out` has length `cols`) —
    /// [`matmul_t`](Self::matmul_t) with `b = 1`. Never materializes
    /// `Aᵀ`; accumulates row-by-row so the inner loop stays unit-stride
    /// over the matrix storage.
    pub fn matvec_t(&self, z: &[f32], out: &mut [f32]) {
        self.matmul_t(z, 1, out);
    }

    /// Blocked batched `out_j = A x_j` for `b` column-major inputs
    /// (`xs[j·cols .. (j+1)·cols]` is signal `j`; same layout for `out`).
    ///
    /// One pass over `A` in ([`PANEL_ROWS`] × [`COL_TILE`]) blocks: each
    /// panel tile is loaded once and dotted against all `b` inputs while
    /// hot in cache. Every output element is bit-for-bit the same float
    /// as [`dot`]`(row, x_j)` — tile boundaries are absolute — so the
    /// batched result is identical to `b` sequential matvecs and
    /// invariant to how rows are chunked (property-tested).
    pub fn matmul(&self, xs: &[f32], b: usize, out: &mut [f32]) {
        debug_assert_eq!(xs.len(), b * self.cols);
        debug_assert_eq!(out.len(), b * self.rows);
        let ptr = SendPtr::new(out.as_mut_ptr());
        // SAFETY: exclusive `&mut out`; one call covers rows [0, rows).
        unsafe { kernel::forward_rows(&self.data, self.rows, self.cols, xs, b, ptr, 0, self.rows) }
    }

    /// Blocked batched `out_j = Aᵀ z_j` (column-major batch layout as in
    /// [`matmul`](Self::matmul)). Walks row panels in ascending order,
    /// reading each matrix row once for all `b` inputs; per output
    /// column the accumulation order is fixed (rows ascending), so the
    /// result is bit-for-bit identical across batch sizes, column
    /// chunkings, and tilings.
    pub fn matmul_t(&self, zs: &[f32], b: usize, out: &mut [f32]) {
        debug_assert_eq!(zs.len(), b * self.rows);
        debug_assert_eq!(out.len(), b * self.cols);
        let ptr = SendPtr::new(out.as_mut_ptr());
        // SAFETY: exclusive `&mut out`; one call covers cols [0, cols).
        unsafe {
            kernel::transposed_cols(&self.data, self.rows, self.cols, zs, b, ptr, 0, self.cols)
        }
    }

    /// Batch-aware crossover: go parallel only when there are enough
    /// split-axis units to keep `threads` busy and at least
    /// [`PAR_MIN_ENTRIES`] multiply-adds (`rows·cols·b`) to amortize
    /// pool dispatch.
    #[inline]
    fn par_gate(&self, split: usize, b: usize, threads: usize) -> bool {
        threads > 1 && split >= 4 * threads && self.rows * self.cols * b >= PAR_MIN_ENTRIES
    }

    /// Parallel [`matmul`](Self::matmul): panel-aligned row chunks
    /// dispatched to the shared [`Pool`], each writing its (interleaved,
    /// disjoint) slice of the column-major output directly — no per-call
    /// threads, no scratch, no copy-back. Serial below the batch-aware
    /// crossover (see [`PAR_MIN_ENTRIES`]). Bit-for-bit identical to the
    /// serial kernel for any chunk count.
    pub fn matmul_par(&self, xs: &[f32], b: usize, out: &mut [f32], threads: usize) {
        if !self.par_gate(self.rows, b, threads) {
            return self.matmul(xs, b, out);
        }
        self.matmul_pooled(xs, b, out, threads);
    }

    /// The pooled body of [`matmul_par`](Self::matmul_par) without the
    /// size gate — `chunks` row chunks on the shared pool regardless of
    /// shape (exposed so tests can pin pooled == serial at any size).
    pub fn matmul_pooled(&self, xs: &[f32], b: usize, out: &mut [f32], chunks: usize) {
        debug_assert_eq!(xs.len(), b * self.cols);
        debug_assert_eq!(out.len(), b * self.rows);
        let rows = self.rows;
        let cols = self.cols;
        let chunk = chunk_span(rows, chunks, PANEL_ROWS);
        let out_ptr = SendPtr::new(out.as_mut_ptr());
        Pool::global().run(rows.div_ceil(chunk), |ci| {
            let r0 = ci * chunk;
            let r1 = (r0 + chunk).min(rows);
            // SAFETY: rows [r0, r1) of every signal's output block belong
            // to chunk `ci` alone, so writes are disjoint across chunks.
            unsafe { kernel::forward_rows(&self.data, rows, cols, xs, b, out_ptr, r0, r1) }
        });
    }

    /// Parallel [`matmul_t`](Self::matmul_t): each pool chunk owns a
    /// lane-aligned column range and walks all rows once for every
    /// signal, accumulating directly into its disjoint output columns.
    /// Serial below the batch-aware crossover. Bit-for-bit identical to
    /// the serial kernel for any chunk count.
    pub fn matmul_t_par(&self, zs: &[f32], b: usize, out: &mut [f32], threads: usize) {
        if !self.par_gate(self.cols, b, threads) {
            return self.matmul_t(zs, b, out);
        }
        self.matmul_t_pooled(zs, b, out, threads);
    }

    /// The pooled body of [`matmul_t_par`](Self::matmul_t_par) without
    /// the size gate.
    pub fn matmul_t_pooled(&self, zs: &[f32], b: usize, out: &mut [f32], chunks: usize) {
        debug_assert_eq!(zs.len(), b * self.rows);
        debug_assert_eq!(out.len(), b * self.cols);
        let rows = self.rows;
        let cols = self.cols;
        let chunk = chunk_span(cols, chunks, LANES);
        let out_ptr = SendPtr::new(out.as_mut_ptr());
        Pool::global().run(cols.div_ceil(chunk), |ci| {
            let c0 = ci * chunk;
            let c1 = (c0 + chunk).min(cols);
            // SAFETY: columns [c0, c1) of every signal's block belong to
            // chunk `ci` alone; per-signal views are created one at a
            // time, never aliased.
            unsafe { kernel::transposed_cols(&self.data, rows, cols, zs, b, out_ptr, c0, c1) }
        });
    }

    /// Parallel `A x` over row chunks on the shared [`Pool`] —
    /// [`matmul_par`](Self::matmul_par) with `b = 1`. Falls back to
    /// serial below the crossover ([`PAR_MIN_ENTRIES`]; re-measure with
    /// `cargo bench --bench throughput -- --crossover`).
    pub fn matvec_par(&self, x: &[f32], out: &mut [f32], threads: usize) {
        self.matmul_par(x, 1, out, threads);
    }

    /// The pooled body of [`matvec_par`](Self::matvec_par) without the
    /// size gate.
    pub fn matvec_pooled(&self, x: &[f32], out: &mut [f32], chunks: usize) {
        self.matmul_pooled(x, 1, out, chunks);
    }

    /// Parallel `Aᵀ z` — [`matmul_t_par`](Self::matmul_t_par) with
    /// `b = 1`. Serial below the crossover (see
    /// [`matvec_par`](Self::matvec_par)).
    pub fn matvec_t_par(&self, z: &[f32], out: &mut [f32], threads: usize) {
        self.matmul_t_par(z, 1, out, threads);
    }

    /// The pooled body of [`matvec_t_par`](Self::matvec_t_par) without
    /// the size gate.
    pub fn matvec_t_pooled(&self, z: &[f32], out: &mut [f32], chunks: usize) {
        self.matmul_t_pooled(z, 1, out, chunks);
    }
}

/// Squared L2 norm in f64 accumulation (AMP uses ‖z‖²/M as a variance
/// estimator, so accumulation error matters).
#[inline]
pub fn norm2_sq(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum()
}

/// Elementwise `a - b` into `out`.
#[inline]
pub fn sub(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for ((o, &ai), &bi) in out.iter_mut().zip(a).zip(b) {
        *o = ai - bi;
    }
}

/// Mean of a slice (f64 accumulation).
#[inline]
pub fn mean(x: &[f32]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().map(|&v| v as f64).sum::<f64>() / x.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{prop_assert, prop_close, Prop};
    use crate::util::rng::Rng;

    fn rand_matrix(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        let mut data = vec![0f32; r * c];
        rng.fill_gaussian(&mut data, 1.0);
        Matrix::from_vec(r, c, data).unwrap()
    }

    #[test]
    fn matvec_small_known() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let mut out = vec![0f32; 2];
        a.matvec(&[1., 1., 1.], &mut out);
        assert_eq!(out, vec![6., 15.]);
    }

    #[test]
    fn matvec_t_small_known() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let mut out = vec![0f32; 3];
        a.matvec_t(&[1., 2.], &mut out);
        assert_eq!(out, vec![9., 12., 15.]);
    }

    #[test]
    fn from_vec_rejects_bad_len() {
        assert!(Matrix::from_vec(2, 3, vec![0.0; 5]).is_err());
    }

    #[test]
    fn parallel_matches_serial() {
        Prop::new("matvec par == serial", 30).check(|g| {
            let mut rng = Rng::new(g.u64());
            let r = g.usize_in(1, 80);
            let c = g.usize_in(1, 120);
            let a = rand_matrix(&mut rng, r, c);
            let x = g.gaussian_vec(c, 1.0);
            let z = g.gaussian_vec(r, 1.0);
            let (mut o1, mut o2) = (vec![0f32; r], vec![0f32; r]);
            a.matvec(&x, &mut o1);
            a.matvec_par(&x, &mut o2, 4);
            for i in 0..r {
                prop_close(o1[i] as f64, o2[i] as f64, 1e-4, "matvec")?;
            }
            let (mut t1, mut t2) = (vec![0f32; c], vec![0f32; c]);
            a.matvec_t(&z, &mut t1);
            a.matvec_t_par(&z, &mut t2, 4);
            for i in 0..c {
                prop_close(t1[i] as f64, t2[i] as f64, 1e-4, "matvec_t")?;
            }
            Ok(())
        });
    }

    #[test]
    fn transpose_adjoint_identity() {
        // <A x, z> == <x, Aᵀ z> — the adjoint identity AMP relies on.
        Prop::new("adjoint identity", 40).check(|g| {
            let mut rng = Rng::new(g.u64());
            let r = g.usize_in(1, 50);
            let c = g.usize_in(1, 70);
            let a = rand_matrix(&mut rng, r, c);
            let x = g.gaussian_vec(c, 1.0);
            let z = g.gaussian_vec(r, 1.0);
            let mut ax = vec![0f32; r];
            a.matvec(&x, &mut ax);
            let mut atz = vec![0f32; c];
            a.matvec_t(&z, &mut atz);
            let lhs: f64 = ax.iter().zip(&z).map(|(&u, &v)| u as f64 * v as f64).sum();
            let rhs: f64 = x.iter().zip(&atz).map(|(&u, &v)| u as f64 * v as f64).sum();
            prop_close(lhs, rhs, 1e-2 * (1.0 + lhs.abs()), "adjoint")
        });
    }

    #[test]
    fn norm2_sq_known() {
        assert!((norm2_sq(&[3.0, 4.0]) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn dot_matches_naive() {
        Prop::new("dot lanes == naive", 50).check(|g| {
            let n = g.usize_in(0, 257);
            let a = g.gaussian_vec(n, 1.0);
            let b = g.gaussian_vec(n, 1.0);
            let naive: f64 = a.iter().zip(&b).map(|(&x, &y)| (x * y) as f64).sum();
            prop_close(dot(&a, &b) as f64, naive, 1e-3 * (1.0 + naive.abs()), "dot")
        });
    }

    #[test]
    fn dot_follows_documented_tile_lane_order() {
        // Pin the summation order contract: absolute COL_TILE segments,
        // LANES-wide accumulator, fixed fold tree, scalar tail — the
        // order every blocked kernel reproduces per output element.
        fn reference(a: &[f32], b: &[f32]) -> f32 {
            let mut s = 0f32;
            for (ta, tb) in a.chunks(COL_TILE).zip(b.chunks(COL_TILE)) {
                let mut acc = [0f32; LANES];
                let mut i = 0;
                while i + LANES <= ta.len() {
                    for l in 0..LANES {
                        acc[l] += ta[i + l] * tb[i + l];
                    }
                    i += LANES;
                }
                let mut t = ((acc[0] + acc[1]) + (acc[2] + acc[3]))
                    + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
                for k in i..ta.len() {
                    t += ta[k] * tb[k];
                }
                s += t;
            }
            s
        }
        let mut rng = Rng::new(17);
        for n in [0usize, 5, 8, 63, 511, 512, 513, 1024, 1300] {
            let mut a = vec![0f32; n];
            rng.fill_gaussian(&mut a, 1.0);
            let mut b = vec![0f32; n];
            rng.fill_gaussian(&mut b, 1.0);
            assert_eq!(dot(&a, &b).to_bits(), reference(&a, &b).to_bits(), "n={n}");
        }
    }

    #[test]
    fn batched_matmul_bitwise_matches_sequential_matvecs() {
        // The batching contract: a blocked B-signal matmul is bit-for-bit
        // the same floats as B sequential matvecs, serial and threaded,
        // forward and transposed.
        Prop::new("matmul == B × matvec (bitwise)", 25).check(|g| {
            let mut rng = Rng::new(g.u64());
            let r = g.usize_in(1, 50);
            let c = g.usize_in(1, 70);
            let b = g.usize_in(1, 6);
            let a = rand_matrix(&mut rng, r, c);
            let xs = g.gaussian_vec(b * c, 1.0);
            let zs = g.gaussian_vec(b * r, 1.0);
            let mut fwd = vec![0f32; b * r];
            a.matmul(&xs, b, &mut fwd);
            let mut fwd_par = vec![0f32; b * r];
            a.matmul_par(&xs, b, &mut fwd_par, 4);
            let mut t = vec![0f32; b * c];
            a.matmul_t(&zs, b, &mut t);
            let mut t_par = vec![0f32; b * c];
            a.matmul_t_par(&zs, b, &mut t_par, 4);
            for j in 0..b {
                let mut want = vec![0f32; r];
                a.matvec(&xs[j * c..(j + 1) * c], &mut want);
                for i in 0..r {
                    let (got, gp) = (fwd[j * r + i], fwd_par[j * r + i]);
                    prop_assert(
                        got.to_bits() == want[i].to_bits()
                            && gp.to_bits() == want[i].to_bits(),
                        format!("matmul sig {j} row {i}: {got} vs {}", want[i]),
                    )?;
                }
                let mut want_t = vec![0f32; c];
                a.matvec_t(&zs[j * r..(j + 1) * r], &mut want_t);
                for i in 0..c {
                    let (got, gp) = (t[j * c + i], t_par[j * c + i]);
                    prop_assert(
                        got.to_bits() == want_t[i].to_bits()
                            && gp.to_bits() == want_t[i].to_bits(),
                        format!("matmul_t sig {j} col {i}: {got} vs {}", want_t[i]),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pooled_kernels_bitwise_match_serial_across_chunk_counts() {
        // The pool contract: every pooled kernel is bit-for-bit the
        // serial kernel, for chunk counts of 1, 2, odd, and more chunks
        // than rows/cols (empty tail chunks).
        Prop::new("pooled == serial (bitwise)", 20).check(|g| {
            let mut rng = Rng::new(g.u64());
            let r = g.usize_in(1, 60);
            let c = g.usize_in(1, 80);
            let b = g.usize_in(1, 4);
            let a = rand_matrix(&mut rng, r, c);
            let x = g.gaussian_vec(c, 1.0);
            let z = g.gaussian_vec(r, 1.0);
            let xs = g.gaussian_vec(b * c, 1.0);
            let zs = g.gaussian_vec(b * r, 1.0);
            let mut mv = vec![0f32; r];
            a.matvec(&x, &mut mv);
            let mut mvt = vec![0f32; c];
            a.matvec_t(&z, &mut mvt);
            let mut mm = vec![0f32; b * r];
            a.matmul(&xs, b, &mut mm);
            let mut mmt = vec![0f32; b * c];
            a.matmul_t(&zs, b, &mut mmt);
            for chunks in [1usize, 2, 3, r + c + 1] {
                // Dirty outputs: pooled kernels must fully overwrite.
                let mut o = vec![7.5f32; r];
                a.matvec_pooled(&x, &mut o, chunks);
                prop_assert(
                    o.iter().zip(&mv).all(|(p, s)| p.to_bits() == s.to_bits()),
                    format!("matvec_pooled chunks={chunks}"),
                )?;
                let mut o = vec![7.5f32; c];
                a.matvec_t_pooled(&z, &mut o, chunks);
                prop_assert(
                    o.iter().zip(&mvt).all(|(p, s)| p.to_bits() == s.to_bits()),
                    format!("matvec_t_pooled chunks={chunks}"),
                )?;
                let mut o = vec![7.5f32; b * r];
                a.matmul_pooled(&xs, b, &mut o, chunks);
                prop_assert(
                    o.iter().zip(&mm).all(|(p, s)| p.to_bits() == s.to_bits()),
                    format!("matmul_pooled chunks={chunks}"),
                )?;
                let mut o = vec![7.5f32; b * c];
                a.matmul_t_pooled(&zs, b, &mut o, chunks);
                prop_assert(
                    o.iter().zip(&mmt).all(|(p, s)| p.to_bits() == s.to_bits()),
                    format!("matmul_t_pooled chunks={chunks}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn axpy_unrolled_matches_rolled() {
        Prop::new("axpy lanes == rolled (bitwise)", 50).check(|g| {
            let n = g.usize_in(0, 133);
            let alpha = g.f64_in(-2.0, 2.0) as f32;
            let x = g.gaussian_vec(n, 1.0);
            let mut y = g.gaussian_vec(n, 1.0);
            let mut want = y.clone();
            for (w, &xi) in want.iter_mut().zip(&x) {
                *w += alpha * xi;
            }
            axpy(alpha, &x, &mut y);
            for i in 0..n {
                prop_assert(
                    y[i].to_bits() == want[i].to_bits(),
                    format!("axpy element {i}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn batch_folded_par_gate_matches_serial_bitwise() {
        // Satellite pin: rows·cols < PAR_MIN_ENTRIES but rows·cols·b ≥ —
        // the batch-aware gate sends this batched call through the pool
        // (the same-shape B=1 call stays serial), and the pooled result
        // must still be bitwise the serial kernel.
        let (r, c, b) = (600usize, 600usize, 3usize);
        assert!(r * c < PAR_MIN_ENTRIES && r * c * b >= PAR_MIN_ENTRIES);
        let mut rng = Rng::new(41);
        let a = rand_matrix(&mut rng, r, c);
        let mut xs = vec![0f32; b * c];
        rng.fill_gaussian(&mut xs, 1.0);
        let mut zs = vec![0f32; b * r];
        rng.fill_gaussian(&mut zs, 1.0);
        let (mut s, mut p) = (vec![0f32; b * r], vec![0f32; b * r]);
        a.matmul(&xs, b, &mut s);
        a.matmul_par(&xs, b, &mut p, 4);
        assert!(s.iter().zip(&p).all(|(x, y)| x.to_bits() == y.to_bits()));
        let (mut st, mut pt) = (vec![0f32; b * c], vec![0f32; b * c]);
        a.matmul_t(&zs, b, &mut st);
        a.matmul_t_par(&zs, b, &mut pt, 4);
        assert!(st.iter().zip(&pt).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn matmul_threaded_crossover_path_matches() {
        // Force the gated parallel branch (≥ PAR_MIN_ENTRIES) once to
        // cover the pool dispatch path on a non-trivial batch.
        let mut rng = Rng::new(99);
        let a = rand_matrix(&mut rng, 1000, 4096);
        let b = 3usize;
        let mut g = Rng::new(7);
        let mut xs = vec![0f32; b * 4096];
        g.fill_gaussian(&mut xs, 1.0);
        let mut zs = vec![0f32; b * 1000];
        g.fill_gaussian(&mut zs, 1.0);
        let (mut s1, mut s2) = (vec![0f32; b * 1000], vec![0f32; b * 1000]);
        a.matmul(&xs, b, &mut s1);
        a.matmul_par(&xs, b, &mut s2, 4);
        assert!(s1.iter().zip(&s2).all(|(x, y)| x.to_bits() == y.to_bits()));
        let (mut t1, mut t2) = (vec![0f32; b * 4096], vec![0f32; b * 4096]);
        a.matmul_t(&zs, b, &mut t1);
        a.matmul_t_par(&zs, b, &mut t2, 4);
        assert!(t1.iter().zip(&t2).all(|(x, y)| x.to_bits() == y.to_bits()));
        // The fused LC step through the gated parallel branch matches
        // the serial fused panel pass bitwise (dirty outputs).
        let mut ys = vec![0f32; b * 1000];
        g.fill_gaussian(&mut ys, 1.0);
        let coefs = [0.3f32, -0.2, 0.7];
        let (mut z1, mut f1) = (vec![7.5f32; b * 1000], vec![7.5f32; b * 4096]);
        let (mut z2, mut f2) = (vec![-1.0f32; b * 1000], vec![-1.0f32; b * 4096]);
        a.lc_fused(&ys, &xs, &zs, &coefs, b, 0.25, &mut z1, &mut f1, 1);
        a.lc_fused(&ys, &xs, &zs, &coefs, b, 0.25, &mut z2, &mut f2, 4);
        assert!(z1.iter().zip(&z2).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert!(f1.iter().zip(&f2).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn col_block_copies_right_columns() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let b = a.col_block(1, 3);
        assert_eq!((b.rows(), b.cols()), (2, 2));
        assert_eq!(b.data(), &[2., 3., 5., 6.]);
        // Blocks tile the matrix: every column lands in exactly one block.
        let left = a.col_block(0, 1);
        assert_eq!(left.data(), &[1., 4.]);
    }

    #[test]
    fn transposed_is_involutive_and_matches_matvec_t() {
        Prop::new("transpose roundtrip + adjoint kernels", 30).check(|g| {
            let mut rng = Rng::new(g.u64());
            let r = g.usize_in(1, 40);
            let c = g.usize_in(1, 60);
            let a = rand_matrix(&mut rng, r, c);
            // Aᵀᵀ == A exactly (pure copies).
            let back = a.transposed().transposed();
            prop_assert(back.data() == a.data(), "transpose not involutive")?;
            // The unit-stride transposed matvec equals the dense reference
            // `Aᵀ z` computed on the materialized transpose.
            let z = g.gaussian_vec(r, 1.0);
            let (mut fast, mut dense) = (vec![0f32; c], vec![0f32; c]);
            a.matvec_t(&z, &mut fast);
            a.transposed().matvec(&z, &mut dense);
            for i in 0..c {
                prop_close(fast[i] as f64, dense[i] as f64, 1e-4, "matvec_t")?;
            }
            Ok(())
        });
    }

    #[test]
    fn row_block_copies_right_rows() {
        let a = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let b = a.row_block(1, 3);
        assert_eq!(b.rows(), 2);
        assert_eq!(b.data(), &[3., 4., 5., 6.]);
    }

    #[test]
    fn mean_and_sub() {
        let mut out = vec![0f32; 2];
        sub(&[3.0, 5.0], &[1.0, 1.0], &mut out);
        assert_eq!(out, vec![2.0, 4.0]);
        assert!((mean(&[1.0, 2.0, 3.0]) - 2.0).abs() < 1e-12);
    }
}
