//! Dense linear algebra for the AMP hot path.
//!
//! The sensing matrix block a worker owns is `(M/P) × N` row-major `f32`.
//! Two operations dominate: `A x` (per-row dot products) and `Aᵀ z`
//! (accumulation across rows). Both are written cache-friendly (unit-stride
//! inner loops over matrix rows) with optional row-parallelism via scoped
//! threads; the compiler auto-vectorizes the unrolled inner loops.

use crate::error::{Error, Result};

/// Row-major dense `f32` matrix.
#[derive(Debug, Clone)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// Create from row-major data.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Numerical(format!(
                "matrix data length {} != {rows}x{cols}",
                data.len()
            )));
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row-major backing slice.
    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable backing slice.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Borrow row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Take a contiguous block of rows `[r0, r1)` as a new matrix (copy).
    pub fn row_block(&self, r0: usize, r1: usize) -> Matrix {
        debug_assert!(r0 <= r1 && r1 <= self.rows);
        Matrix {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    /// Take a contiguous block of columns `[c0, c1)` as a new matrix
    /// (copy). The gather is strided over the source (one slice per row) —
    /// it runs once at session start; the hot-path kernels then stay
    /// unit-stride over the extracted block.
    pub fn col_block(&self, c0: usize, c1: usize) -> Matrix {
        debug_assert!(c0 <= c1 && c1 <= self.cols);
        let w = c1 - c0;
        let mut data = Vec::with_capacity(self.rows * w);
        for r in 0..self.rows {
            data.extend_from_slice(&self.data[r * self.cols + c0..r * self.cols + c1]);
        }
        Matrix { rows: self.rows, cols: w, data }
    }

    /// Explicit transpose (copy) — the dense reference the transposed
    /// matvec ([`matvec_t`](Self::matvec_t), which never materializes `Aᵀ`
    /// and keeps its inner loop unit-stride) is property-tested against.
    pub fn transposed(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for (c, &v) in self.row(r).iter().enumerate() {
                out.data[c * self.rows + r] = v;
            }
        }
        out
    }

    /// `out = A x` (`out` has length `rows`).
    pub fn matvec(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.cols);
        debug_assert_eq!(out.len(), self.rows);
        for (r, o) in out.iter_mut().enumerate() {
            *o = dot(self.row(r), x);
        }
    }

    /// `out = Aᵀ z` (`out` has length `cols`).
    ///
    /// Accumulates row-by-row (`out += z_r * row_r`) so the inner loop stays
    /// unit-stride over the matrix storage.
    pub fn matvec_t(&self, z: &[f32], out: &mut [f32]) {
        debug_assert_eq!(z.len(), self.rows);
        debug_assert_eq!(out.len(), self.cols);
        out.iter_mut().for_each(|o| *o = 0.0);
        for (r, &zr) in z.iter().enumerate() {
            if zr != 0.0 {
                axpy(zr, self.row(r), out);
            }
        }
    }

    /// Blocked batched `out_j = A x_j` for `b` column-major inputs
    /// (`xs[j·cols .. (j+1)·cols]` is signal `j`; same layout for `out`).
    ///
    /// One pass over `A`: each matrix row is loaded once and dotted
    /// against all `b` inputs while it is hot in cache, instead of `b`
    /// full passes over the matrix. Every output element is the same
    /// [`dot`] call [`matvec`](Self::matvec) would make, so the batched
    /// result is bit-for-bit identical to `b` sequential matvecs
    /// (property-tested).
    pub fn matmul(&self, xs: &[f32], b: usize, out: &mut [f32]) {
        debug_assert_eq!(xs.len(), b * self.cols);
        debug_assert_eq!(out.len(), b * self.rows);
        for r in 0..self.rows {
            let row = self.row(r);
            for j in 0..b {
                out[j * self.rows + r] = dot(row, &xs[j * self.cols..(j + 1) * self.cols]);
            }
        }
    }

    /// Blocked batched `out_j = Aᵀ z_j` (column-major batch layout as in
    /// [`matmul`](Self::matmul)). Accumulates row-by-row so each matrix
    /// row is read once for all `b` inputs; per-signal accumulation order
    /// matches [`matvec_t`](Self::matvec_t) exactly (bit-for-bit).
    pub fn matmul_t(&self, zs: &[f32], b: usize, out: &mut [f32]) {
        debug_assert_eq!(zs.len(), b * self.rows);
        debug_assert_eq!(out.len(), b * self.cols);
        out.iter_mut().for_each(|o| *o = 0.0);
        for r in 0..self.rows {
            let row = self.row(r);
            for j in 0..b {
                let zr = zs[j * self.rows + r];
                if zr != 0.0 {
                    axpy(zr, row, &mut out[j * self.cols..(j + 1) * self.cols]);
                }
            }
        }
    }

    /// Threaded [`matmul`](Self::matmul): row chunks are computed into
    /// per-thread scratch (the column-major output interleaves signals, so
    /// chunks are not contiguous) and copied back. Serial below the same
    /// crossover as [`matvec_par`](Self::matvec_par). Per-element
    /// arithmetic is unchanged, so results stay bit-for-bit identical to
    /// the serial kernel.
    pub fn matmul_par(&self, xs: &[f32], b: usize, out: &mut [f32], threads: usize) {
        if threads <= 1 || self.rows < 4 * threads || self.rows * self.cols < 4_000_000 {
            return self.matmul(xs, b, out);
        }
        let rows = self.rows;
        let cols = self.cols;
        let chunk = rows.div_ceil(threads);
        let results: Vec<(usize, usize, Vec<f32>)> = std::thread::scope(|s| {
            let mut handles = Vec::new();
            let mut r0 = 0usize;
            while r0 < rows {
                let r1 = (r0 + chunk).min(rows);
                let mat = &*self;
                handles.push(s.spawn(move || {
                    let h = r1 - r0;
                    let mut tmp = vec![0f32; h * b];
                    for r in r0..r1 {
                        let row = mat.row(r);
                        for j in 0..b {
                            tmp[j * h + (r - r0)] =
                                dot(row, &xs[j * cols..(j + 1) * cols]);
                        }
                    }
                    (r0, r1, tmp)
                }));
                r0 = r1;
            }
            handles.into_iter().map(|h| h.join().expect("matmul thread")).collect()
        });
        for (r0, r1, tmp) in results {
            let h = r1 - r0;
            for j in 0..b {
                out[j * rows + r0..j * rows + r1].copy_from_slice(&tmp[j * h..(j + 1) * h]);
            }
        }
    }

    /// Threaded [`matmul_t`](Self::matmul_t): each thread owns a column
    /// range and walks all rows once for every signal (same partitioning
    /// as [`matvec_t_par`](Self::matvec_t_par)), accumulating into scratch
    /// that is copied back. Bit-for-bit identical to the serial kernel.
    pub fn matmul_t_par(&self, zs: &[f32], b: usize, out: &mut [f32], threads: usize) {
        if threads <= 1 || self.cols < 4 * threads || self.rows * self.cols < 4_000_000 {
            return self.matmul_t(zs, b, out);
        }
        let rows = self.rows;
        let cols = self.cols;
        let chunk = cols.div_ceil(threads);
        let results: Vec<(usize, usize, Vec<f32>)> = std::thread::scope(|s| {
            let mut handles = Vec::new();
            let mut c0 = 0usize;
            while c0 < cols {
                let c1 = (c0 + chunk).min(cols);
                let mat = &*self;
                handles.push(s.spawn(move || {
                    let w = c1 - c0;
                    let mut tmp = vec![0f32; w * b];
                    for r in 0..rows {
                        let row = &mat.row(r)[c0..c1];
                        for j in 0..b {
                            let zr = zs[j * rows + r];
                            if zr != 0.0 {
                                axpy(zr, row, &mut tmp[j * w..(j + 1) * w]);
                            }
                        }
                    }
                    (c0, c1, tmp)
                }));
                c0 = c1;
            }
            handles.into_iter().map(|h| h.join().expect("matmul_t thread")).collect()
        });
        for (c0, c1, tmp) in results {
            let w = c1 - c0;
            for j in 0..b {
                out[j * cols + c0..j * cols + c1].copy_from_slice(&tmp[j * w..(j + 1) * w]);
            }
        }
    }

    /// Threaded `A x` over row chunks. Falls back to serial when the
    /// matrix is small enough that spawn overhead + memory-bandwidth
    /// saturation make threads a loss (measured crossover ≈ 4M entries;
    /// see EXPERIMENTS.md §Perf).
    pub fn matvec_par(&self, x: &[f32], out: &mut [f32], threads: usize) {
        if threads <= 1 || self.rows < 4 * threads || self.rows * self.cols < 4_000_000 {
            return self.matvec(x, out);
        }
        let chunk = self.rows.div_ceil(threads);
        std::thread::scope(|s| {
            for (ci, out_chunk) in out.chunks_mut(chunk).enumerate() {
                let r0 = ci * chunk;
                let mat = &*self;
                s.spawn(move || {
                    for (i, o) in out_chunk.iter_mut().enumerate() {
                        *o = dot(mat.row(r0 + i), x);
                    }
                });
            }
        });
    }

    /// Threaded `Aᵀ z`: each thread owns a column range and walks all rows.
    /// Serial below the measured crossover (see `matvec_par`).
    pub fn matvec_t_par(&self, z: &[f32], out: &mut [f32], threads: usize) {
        if threads <= 1 || self.cols < 4 * threads || self.rows * self.cols < 4_000_000 {
            return self.matvec_t(z, out);
        }
        let chunk = self.cols.div_ceil(threads);
        let cols = self.cols;
        std::thread::scope(|s| {
            for (ci, out_chunk) in out.chunks_mut(chunk).enumerate() {
                let c0 = ci * chunk;
                let mat = &*self;
                s.spawn(move || {
                    out_chunk.iter_mut().for_each(|o| *o = 0.0);
                    for (r, &zr) in z.iter().enumerate() {
                        if zr != 0.0 {
                            let row = &mat.row(r)[c0..c0 + out_chunk.len()];
                            axpy(zr, row, out_chunk);
                        }
                    }
                    let _ = cols;
                });
            }
        });
    }
}

/// Dot product with 4-way unrolling (auto-vectorizes well).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * *xi;
    }
}

/// Squared L2 norm in f64 accumulation (AMP uses ‖z‖²/M as a variance
/// estimator, so accumulation error matters).
#[inline]
pub fn norm2_sq(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum()
}

/// Elementwise `a - b` into `out`.
#[inline]
pub fn sub(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for ((o, &ai), &bi) in out.iter_mut().zip(a).zip(b) {
        *o = ai - bi;
    }
}

/// Mean of a slice (f64 accumulation).
#[inline]
pub fn mean(x: &[f32]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().map(|&v| v as f64).sum::<f64>() / x.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{prop_assert, prop_close, Prop};
    use crate::util::rng::Rng;

    fn rand_matrix(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        let mut data = vec![0f32; r * c];
        rng.fill_gaussian(&mut data, 1.0);
        Matrix::from_vec(r, c, data).unwrap()
    }

    #[test]
    fn matvec_small_known() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let mut out = vec![0f32; 2];
        a.matvec(&[1., 1., 1.], &mut out);
        assert_eq!(out, vec![6., 15.]);
    }

    #[test]
    fn matvec_t_small_known() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let mut out = vec![0f32; 3];
        a.matvec_t(&[1., 2.], &mut out);
        assert_eq!(out, vec![9., 12., 15.]);
    }

    #[test]
    fn from_vec_rejects_bad_len() {
        assert!(Matrix::from_vec(2, 3, vec![0.0; 5]).is_err());
    }

    #[test]
    fn parallel_matches_serial() {
        Prop::new("matvec par == serial", 30).check(|g| {
            let mut rng = Rng::new(g.u64());
            let r = g.usize_in(1, 80);
            let c = g.usize_in(1, 120);
            let a = rand_matrix(&mut rng, r, c);
            let x = g.gaussian_vec(c, 1.0);
            let z = g.gaussian_vec(r, 1.0);
            let (mut o1, mut o2) = (vec![0f32; r], vec![0f32; r]);
            a.matvec(&x, &mut o1);
            a.matvec_par(&x, &mut o2, 4);
            for i in 0..r {
                prop_close(o1[i] as f64, o2[i] as f64, 1e-4, "matvec")?;
            }
            let (mut t1, mut t2) = (vec![0f32; c], vec![0f32; c]);
            a.matvec_t(&z, &mut t1);
            a.matvec_t_par(&z, &mut t2, 4);
            for i in 0..c {
                prop_close(t1[i] as f64, t2[i] as f64, 1e-4, "matvec_t")?;
            }
            Ok(())
        });
    }

    #[test]
    fn transpose_adjoint_identity() {
        // <A x, z> == <x, Aᵀ z> — the adjoint identity AMP relies on.
        Prop::new("adjoint identity", 40).check(|g| {
            let mut rng = Rng::new(g.u64());
            let r = g.usize_in(1, 50);
            let c = g.usize_in(1, 70);
            let a = rand_matrix(&mut rng, r, c);
            let x = g.gaussian_vec(c, 1.0);
            let z = g.gaussian_vec(r, 1.0);
            let mut ax = vec![0f32; r];
            a.matvec(&x, &mut ax);
            let mut atz = vec![0f32; c];
            a.matvec_t(&z, &mut atz);
            let lhs: f64 = ax.iter().zip(&z).map(|(&u, &v)| u as f64 * v as f64).sum();
            let rhs: f64 = x.iter().zip(&atz).map(|(&u, &v)| u as f64 * v as f64).sum();
            prop_close(lhs, rhs, 1e-2 * (1.0 + lhs.abs()), "adjoint")
        });
    }

    #[test]
    fn norm2_sq_known() {
        assert!((norm2_sq(&[3.0, 4.0]) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn dot_matches_naive() {
        Prop::new("dot unrolled == naive", 50).check(|g| {
            let n = g.usize_in(0, 257);
            let a = g.gaussian_vec(n, 1.0);
            let b = g.gaussian_vec(n, 1.0);
            let naive: f64 = a.iter().zip(&b).map(|(&x, &y)| (x * y) as f64).sum();
            prop_close(dot(&a, &b) as f64, naive, 1e-3 * (1.0 + naive.abs()), "dot")
        });
    }

    #[test]
    fn batched_matmul_bitwise_matches_sequential_matvecs() {
        // The batching contract: a blocked B-signal matmul is bit-for-bit
        // the same floats as B sequential matvecs, serial and threaded,
        // forward and transposed.
        Prop::new("matmul == B × matvec (bitwise)", 25).check(|g| {
            let mut rng = Rng::new(g.u64());
            let r = g.usize_in(1, 50);
            let c = g.usize_in(1, 70);
            let b = g.usize_in(1, 6);
            let a = rand_matrix(&mut rng, r, c);
            let xs = g.gaussian_vec(b * c, 1.0);
            let zs = g.gaussian_vec(b * r, 1.0);
            let mut fwd = vec![0f32; b * r];
            a.matmul(&xs, b, &mut fwd);
            let mut fwd_par = vec![0f32; b * r];
            a.matmul_par(&xs, b, &mut fwd_par, 4);
            let mut t = vec![0f32; b * c];
            a.matmul_t(&zs, b, &mut t);
            let mut t_par = vec![0f32; b * c];
            a.matmul_t_par(&zs, b, &mut t_par, 4);
            for j in 0..b {
                let mut want = vec![0f32; r];
                a.matvec(&xs[j * c..(j + 1) * c], &mut want);
                for i in 0..r {
                    let (got, gp) = (fwd[j * r + i], fwd_par[j * r + i]);
                    prop_assert(
                        got.to_bits() == want[i].to_bits()
                            && gp.to_bits() == want[i].to_bits(),
                        format!("matmul sig {j} row {i}: {got} vs {}", want[i]),
                    )?;
                }
                let mut want_t = vec![0f32; c];
                a.matvec_t(&zs[j * r..(j + 1) * r], &mut want_t);
                for i in 0..c {
                    let (got, gp) = (t[j * c + i], t_par[j * c + i]);
                    prop_assert(
                        got.to_bits() == want_t[i].to_bits()
                            && gp.to_bits() == want_t[i].to_bits(),
                        format!("matmul_t sig {j} col {i}: {got} vs {}", want_t[i]),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn matmul_threaded_crossover_path_matches() {
        // Force the threaded branch (≥ 4M entries) once to cover the
        // scratch-and-copy path on a non-trivial batch.
        let mut rng = Rng::new(99);
        let a = rand_matrix(&mut rng, 1000, 4096);
        let b = 3usize;
        let mut g = Rng::new(7);
        let mut xs = vec![0f32; b * 4096];
        g.fill_gaussian(&mut xs, 1.0);
        let mut zs = vec![0f32; b * 1000];
        g.fill_gaussian(&mut zs, 1.0);
        let (mut s1, mut s2) = (vec![0f32; b * 1000], vec![0f32; b * 1000]);
        a.matmul(&xs, b, &mut s1);
        a.matmul_par(&xs, b, &mut s2, 4);
        assert!(s1.iter().zip(&s2).all(|(x, y)| x.to_bits() == y.to_bits()));
        let (mut t1, mut t2) = (vec![0f32; b * 4096], vec![0f32; b * 4096]);
        a.matmul_t(&zs, b, &mut t1);
        a.matmul_t_par(&zs, b, &mut t2, 4);
        assert!(t1.iter().zip(&t2).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn col_block_copies_right_columns() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let b = a.col_block(1, 3);
        assert_eq!((b.rows(), b.cols()), (2, 2));
        assert_eq!(b.data(), &[2., 3., 5., 6.]);
        // Blocks tile the matrix: every column lands in exactly one block.
        let left = a.col_block(0, 1);
        assert_eq!(left.data(), &[1., 4.]);
    }

    #[test]
    fn transposed_is_involutive_and_matches_matvec_t() {
        Prop::new("transpose roundtrip + adjoint kernels", 30).check(|g| {
            let mut rng = Rng::new(g.u64());
            let r = g.usize_in(1, 40);
            let c = g.usize_in(1, 60);
            let a = rand_matrix(&mut rng, r, c);
            // Aᵀᵀ == A exactly (pure copies).
            let back = a.transposed().transposed();
            prop_assert(back.data() == a.data(), "transpose not involutive")?;
            // The unit-stride transposed matvec equals the dense reference
            // `Aᵀ z` computed on the materialized transpose.
            let z = g.gaussian_vec(r, 1.0);
            let (mut fast, mut dense) = (vec![0f32; c], vec![0f32; c]);
            a.matvec_t(&z, &mut fast);
            a.transposed().matvec(&z, &mut dense);
            for i in 0..c {
                prop_close(fast[i] as f64, dense[i] as f64, 1e-4, "matvec_t")?;
            }
            Ok(())
        });
    }

    #[test]
    fn row_block_copies_right_rows() {
        let a = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let b = a.row_block(1, 3);
        assert_eq!(b.rows(), 2);
        assert_eq!(b.data(), &[3., 4., 5., 6.]);
    }

    #[test]
    fn mean_and_sub() {
        let mut out = vec![0f32; 2];
        sub(&[3.0, 5.0], &[1.0, 1.0], &mut out);
        assert_eq!(out, vec![2.0, 4.0]);
        assert!((mean(&[1.0, 2.0, 3.0]) - 2.0).abs() < 1e-12);
    }
}
