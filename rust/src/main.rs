//! `mpamp` CLI — leader entrypoint for the MP-AMP coordinator.
//!
//! See `cli::usage()` (or `mpamp help`) for the command reference. Every
//! config key can be overridden on the command line, e.g.
//! `mpamp run --prior.eps 0.03 --schedule.kind dp --p 30`.

use mpamp::alloc::backtrack::{BtController, RateModel};
use mpamp::alloc::dp::DpAllocator;
use mpamp::amp::run_centralized;
use mpamp::cli::{usage, Args};
use mpamp::config::{RunConfig, ScheduleKind};
use mpamp::engine::RustEngine;
use mpamp::error::{Error, Result};
use mpamp::observe::{NullObserver, RunObserver, StopRule, StopSet, TablePrinter};
use mpamp::rd::{rd_curve_for_channel, RdCache};
use mpamp::runtime::Manifest;
use mpamp::se::prior::BgChannel;
use mpamp::se::StateEvolution;
use mpamp::SessionBuilder;

/// Option keys consumed by the CLI itself (everything else is a config
/// override).
const RESERVED: &[&str] = &[
    "config",
    "preset",
    "out",
    "sigma2",
    "max-iters",
    "target-sdr",
    "stall-window",
    "stall-delta",
    "max-bits",
    "listen",
    "connect",
    "max-sessions",
    "max-queue",
    "deadline-s",
    "priority-age-s",
    "fault-plan",
    "metrics-listen",
    "trace",
    "priority",
];

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if args.command.is_empty() || args.command == "help" || args.has_flag("help") {
        print!("{}", usage());
        return;
    }
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> Result<RunConfig> {
    let base = match (args.get("config"), args.get("preset")) {
        (Some(_), Some(_)) => {
            return Err(Error::Config(
                "--config and --preset are mutually exclusive".into(),
            ))
        }
        (Some(path), None) => RunConfig::from_file(path)?,
        (None, Some("paper")) => RunConfig::paper_default(0.05),
        (None, Some("test_small")) => RunConfig::test_small(0.05),
        (None, Some(other)) => {
            return Err(Error::Config(format!(
                "unknown preset '{other}' (try 'paper' or 'test_small')"
            )))
        }
        (None, None) => RunConfig::paper_default(0.05),
    };
    base.apply_overrides(&args.config_overrides(RESERVED))
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "run" => cmd_run(args),
        "serve" => cmd_serve(args),
        "trace" => cmd_trace(args),
        "centralized" => cmd_centralized(args),
        "se" => cmd_se(args),
        "dp" => cmd_dp(args),
        "bt" => cmd_bt(args),
        "rd" => cmd_rd(args),
        "compressors" => cmd_compressors(args),
        "artifacts" => cmd_artifacts(args),
        "lab" => cmd_lab(args),
        other => Err(Error::Config(format!(
            "unknown command '{other}' (try `mpamp help`)"
        ))),
    }
}

/// Assemble the early-stopping rules requested on the command line.
fn stop_rules(args: &Args) -> Result<StopSet> {
    let mut stop = StopSet::none();
    if let Some(k) = args.get_parsed::<usize>("max-iters")? {
        stop.push(StopRule::MaxIters(k));
    }
    if let Some(db) = args.get_parsed::<f64>("target-sdr")? {
        stop.push(StopRule::TargetSdrDb(db));
    }
    let window = args.get_parsed::<usize>("stall-window")?;
    let delta = args.get_parsed::<f64>("stall-delta")?;
    match (window, delta) {
        (None, None) => {}
        (Some(window), Some(min_delta_db)) => {
            stop.push(StopRule::SdrStall { window, min_delta_db });
        }
        _ => {
            return Err(Error::Config(
                "--stall-window and --stall-delta must be given together".into(),
            ))
        }
    }
    if let Some(bits) = args.get_parsed::<f64>("max-bits")? {
        stop.push(StopRule::UplinkBudget { bits_per_element: bits });
    }
    Ok(stop)
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    if let Some(addr) = args.get("connect") {
        return cmd_run_remote(args, addr, cfg);
    }
    if args.get("priority").is_some() {
        return Err(Error::Config(
            "--priority applies to --connect (daemon-submitted) runs only"
                .into(),
        ));
    }
    let quiet = args.has_flag("quiet");
    eprintln!(
        "mpamp run: N={} M={} P={} B={} ({}-partitioned) ε={} SNR={} dB T={} \
         schedule={:?} engine={:?}",
        cfg.n,
        cfg.m,
        cfg.p,
        cfg.batch,
        cfg.partitioning.as_str(),
        cfg.prior.eps,
        cfg.snr_db,
        cfg.iters,
        cfg.schedule,
        cfg.engine
    );
    let stop = stop_rules(args)?;
    let tel = match args.get("trace") {
        Some(_) => mpamp::telemetry::Telemetry::enabled(),
        None => mpamp::telemetry::Telemetry::off(),
    };
    let mut session = SessionBuilder::from_config(cfg).build()?;
    if tel.is_on() {
        session.set_telemetry(tel.clone());
    }
    let mut table = TablePrinter::new();
    let mut null = NullObserver;
    let observer: &mut dyn RunObserver =
        if quiet { &mut null } else { &mut table };
    let report = session.run_observed(observer, &stop)?;
    if let Some(path) = args.get("trace") {
        let spans = tel.events();
        mpamp::telemetry::write_trace_file(path, &spans)?;
        eprintln!(
            "wrote {} telemetry span(s) to {path}{}",
            spans.len(),
            match tel.dropped() {
                0 => String::new(),
                n => format!(" ({n} oldest dropped by the ring)"),
            }
        );
    }
    if let Some(why) = &report.stopped_early {
        println!("stopped early after {} iterations: {why}", report.iters.len());
    }
    println!(
        "final SDR {:.2} dB | uplink {:.2} bits/element total ({:.1}% savings vs 32-bit) | {:.2}s",
        report.final_sdr_db(),
        report.total_uplink_bits_per_element(),
        report.savings_vs_float_pct(),
        report.wall_s
    );
    if report.batch > 1 {
        println!(
            "batch of {}: {:.2} signals/s | per-signal SDR (dB): {}",
            report.batch,
            report.signals_per_s(),
            report
                .sdr_db_per_signal
                .iter()
                .map(|v| format!("{v:.2}"))
                .collect::<Vec<_>>()
                .join(", ")
        );
    }
    if let Some(out) = args.get("out") {
        report.to_csv().write(out)?;
        eprintln!("wrote {out}");
    }
    if args.has_flag("json") {
        println!("{}", report.to_json().render());
    }
    Ok(())
}

/// `mpamp run --connect <addr>`: submit the config to a running mpampd
/// and stream its per-round progress instead of spawning a local fleet.
fn cmd_run_remote(args: &Args, addr: &str, cfg: RunConfig) -> Result<()> {
    use mpamp::serve::client::DEFAULT_READ_TIMEOUT;
    use mpamp::serve::{Client, JobEvent, Priority};
    if !stop_rules(args)?.is_empty() {
        return Err(Error::Config(
            "early-stopping options apply to local runs only (the daemon \
             owns a served job's stopping; use --deadline-s on the serve \
             side)"
                .into(),
        ));
    }
    if args.get("trace").is_some() {
        return Err(Error::Config(
            "--trace applies to local runs only (spans are recorded in the \
             process running the fusion loop)"
                .into(),
        ));
    }
    let priority = match args.get("priority") {
        Some(v) => Priority::parse(v).ok_or_else(|| {
            Error::Config(format!(
                "unknown --priority '{v}' (expected 'high' or 'normal')"
            ))
        })?,
        None => Priority::Normal,
    };
    let quiet = args.has_flag("quiet");
    let mut job =
        Client::submit_with(addr, &cfg, priority, Some(DEFAULT_READ_TIMEOUT))?;
    eprintln!(
        "mpamp run: submitted to {addr} as session {} (priority {}, queue \
         position {})",
        job.session_id(),
        priority.as_str(),
        job.queue_pos()
    );
    let mut table = TablePrinter::new();
    let report = loop {
        match job.next_event()? {
            JobEvent::Started => {}
            JobEvent::Iter(snap) => {
                if !quiet {
                    table.on_iter(&snap);
                }
            }
            JobEvent::Report(report) => break report,
            JobEvent::Cancelled => {
                return Err(Error::Transport("job was cancelled".into()))
            }
            JobEvent::Failed(msg) => {
                return Err(Error::Transport(format!("daemon error: {msg}")))
            }
        }
    };
    if let Some(why) = &report.stopped_early {
        println!("stopped early after {} iterations: {why}", report.iters.len());
    }
    println!(
        "final SDR {:.2} dB | uplink {:.2} bits/element total ({:.1}% savings vs 32-bit) | {:.2}s",
        report.final_sdr_db(),
        report.total_uplink_bits_per_element(),
        report.savings_vs_float_pct(),
        report.wall_s
    );
    if let Some(out) = args.get("out") {
        report.to_csv().write(out)?;
        eprintln!("wrote {out}");
    }
    if args.has_flag("json") {
        println!("{}", report.to_json().render());
    }
    Ok(())
}

/// `mpamp serve`: boot the daemon and block until killed.
fn cmd_serve(args: &Args) -> Result<()> {
    use mpamp::serve::{Daemon, ServeConfig};
    let cfg = load_config(args)?;
    let listen = args.get("listen").unwrap_or("127.0.0.1:7700");
    let mut sc = ServeConfig::new(listen, cfg.p);
    if let Some(v) = args.get_parsed::<usize>("max-sessions")? {
        sc.max_sessions = v;
    }
    if let Some(v) = args.get_parsed::<usize>("max-queue")? {
        sc.max_queue = v;
    }
    if let Some(s) = args.get_parsed::<f64>("deadline-s")? {
        if !(s > 0.0) {
            return Err(Error::Config("--deadline-s must be > 0".into()));
        }
        sc.deadline = Some(std::time::Duration::from_secs_f64(s));
    }
    if let Some(s) = args.get_parsed::<f64>("priority-age-s")? {
        if !(s > 0.0) {
            return Err(Error::Config("--priority-age-s must be > 0".into()));
        }
        sc.priority_age = Some(std::time::Duration::from_secs_f64(s));
    }
    // Hidden chaos-testing hook (deliberately absent from `usage()`):
    // install a deterministic fault plan on the fleet links. Spec
    // grammar is documented on `coordinator::fault::FaultPlan::parse`.
    if let Some(spec) = args.get("fault-plan") {
        let plan = mpamp::coordinator::fault::FaultPlan::parse(spec)?;
        if !plan.is_empty() {
            eprintln!("mpampd: FAULT INJECTION ACTIVE: {}", plan.render());
            sc.fault_plan = Some(std::sync::Arc::new(plan));
        }
    }
    term_signal::install();
    // The metrics endpoint outlives the daemon into the drain, so the
    // final scrape still sees the terminal job states.
    let metrics = match args.get("metrics-listen") {
        Some(maddr) => {
            let srv = mpamp::telemetry::MetricsServer::start(maddr)?;
            eprintln!(
                "mpampd: metrics on http://{}/metrics (JSON at /metrics.json)",
                srv.addr()
            );
            Some(srv)
        }
        None => None,
    };
    let daemon = Daemon::start(sc)?;
    eprintln!(
        "mpampd: serving on {} (fleet P={}, max {} running + {} queued{})",
        daemon.addr(),
        cfg.p,
        args.get_parsed::<usize>("max-sessions")?.unwrap_or(4),
        args.get_parsed::<usize>("max-queue")?.unwrap_or(16),
        match args.get_parsed::<f64>("deadline-s")? {
            Some(s) => format!(", {s}s deadline"),
            None => String::new(),
        }
    );
    // Serve until SIGTERM/SIGINT, then drain gracefully: stop admitting,
    // let admitted jobs (running and queued) finish, and exit 0.
    while !term_signal::received() {
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    let (running, queued) = daemon.load();
    eprintln!(
        "mpampd: shutdown signal received; draining ({running} running, \
         {queued} queued)"
    );
    daemon.begin_drain();
    while !daemon.is_idle() {
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    daemon.shutdown()?;
    if let Some(srv) = metrics {
        srv.stop();
    }
    eprintln!("mpampd: drained; exiting");
    Ok(())
}

/// `mpamp trace <out.jsonl>`: run one session with telemetry enabled and
/// dump its span stream as JSONL (schema in the `mpamp::telemetry`
/// rustdoc). Accepts the same config/preset/override and early-stopping
/// options as a local `mpamp run`.
fn cmd_trace(args: &Args) -> Result<()> {
    use mpamp::telemetry::{self, Stage, Telemetry};
    let out = args.positional.first().ok_or_else(|| {
        Error::Config(
            "usage: mpamp trace <out.jsonl> [--preset test_small] [overrides]"
                .into(),
        )
    })?;
    let cfg = load_config(args)?;
    let stop = stop_rules(args)?;
    let tel = Telemetry::enabled();
    let mut session = SessionBuilder::from_config(cfg).build()?;
    session.set_telemetry(tel.clone());
    let mut null = NullObserver;
    let report = session.run_observed(&mut null, &stop)?;
    let spans = tel.events();
    telemetry::write_trace_file(out, &spans)?;
    let rounds = spans.iter().filter(|e| e.stage == Stage::Round).count();
    let wire_bits: f64 = spans
        .iter()
        .filter(|e| e.stage == Stage::Round)
        .map(|e| e.bits)
        .sum();
    println!(
        "wrote {} span(s) to {out}: {rounds} rounds, {:.0} uplink payload \
         bits{}",
        spans.len(),
        wire_bits,
        match tel.dropped() {
            0 => String::new(),
            n => format!(" ({n} oldest spans dropped by the ring)"),
        }
    );
    println!(
        "final SDR {:.2} dB in {} iterations | {:.2} bits/element",
        report.final_sdr_db(),
        report.iters.len(),
        report.total_uplink_bits_per_element()
    );
    Ok(())
}

/// Process-wide SIGTERM/SIGINT latch for the serve loop — direct libc
/// `signal(2)` FFI, since the vendored crate set has no `libc`/`signal-hook`.
#[cfg(unix)]
mod term_signal {
    use std::sync::atomic::{AtomicBool, Ordering};

    static TERM: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_term(_sig: i32) {
        // Only async-signal-safe work here: flip the latch.
        TERM.store(true, Ordering::SeqCst);
    }

    /// Install the handler for SIGINT (2) and SIGTERM (15).
    pub fn install() {
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        }
        unsafe {
            signal(2, on_term);
            signal(15, on_term);
        }
    }

    /// Whether a termination signal has arrived.
    pub fn received() -> bool {
        TERM.load(Ordering::SeqCst)
    }
}

/// Non-unix fallback: no signal hook; `mpamp serve` runs until killed.
#[cfg(not(unix))]
mod term_signal {
    pub fn install() {}

    pub fn received() -> bool {
        false
    }
}

fn cmd_centralized(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let se = StateEvolution::new(cfg.prior, cfg.kappa(), cfg.sigma_e2());
    let mut rng = mpamp::util::rng::Rng::new(cfg.seed);
    let inst = mpamp::signal::Instance::generate(
        cfg.prior,
        mpamp::signal::ProblemDims { n: cfg.n, m: cfg.m, sigma_e2: cfg.sigma_e2() },
        &mut rng,
    )?;
    let engine = RustEngine::new(cfg.prior, cfg.threads);
    let rep = run_centralized(&inst, &se, &engine, cfg.iters)?;
    println!("{:>3} {:>9} {:>9}", "t", "SDR(dB)", "SE(dB)");
    for r in &rep.iters {
        println!("{:>3} {:>9.3} {:>9.3}", r.t, r.sdr_db, r.sdr_pred_db);
    }
    println!("final SDR {:.2} dB (centralized baseline)", rep.final_sdr_db());
    Ok(())
}

fn cmd_se(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let se = StateEvolution::new(cfg.prior, cfg.kappa(), cfg.sigma_e2());
    let traj = se.trajectory(cfg.iters);
    println!("{:>3} {:>14} {:>9}", "t", "sigma_t^2", "SDR(dB)");
    for (t, s2) in traj.iter().enumerate() {
        println!("{:>3} {:>14.6e} {:>9.3}", t, s2, se.sdr_db(*s2));
    }
    let steady = se.iters_to_steady(0.05, 64);
    println!("steady state (0.05 dB/iter) at T = {steady}");
    Ok(())
}

fn cmd_dp(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let (total, delta_r) = match cfg.schedule {
        ScheduleKind::Dp { total_rate, delta_r } => {
            (total_rate.unwrap_or(2.0 * cfg.iters as f64), delta_r)
        }
        _ => (2.0 * cfg.iters as f64, 0.1),
    };
    let se = StateEvolution::new(cfg.prior, cfg.kappa(), cfg.sigma_e2());
    let fp = se.fixed_point(1e-10, 300);
    eprintln!(
        "building RD cache (γ grid {}, alphabet {})...",
        cfg.rd.gamma_grid, cfg.rd.alphabet
    );
    let cache = RdCache::build(&cfg.prior, cfg.p, fp * 0.5, se.sigma0_sq() * 2.0, &cfg.rd)?;
    let alloc = DpAllocator::new(&se, cfg.p, &cache)?;
    let t0 = std::time::Instant::now();
    let dp = alloc.solve(cfg.iters, total, delta_r)?;
    eprintln!(
        "DP table {}×{} solved in {:.2}s",
        dp.dims.0,
        dp.dims.1,
        t0.elapsed().as_secs_f64()
    );
    println!("{:>3} {:>8} {:>14} {:>9}", "t", "R_t", "sigma_D^2", "SDR(dB)");
    for t in 0..cfg.iters {
        println!(
            "{:>3} {:>8.2} {:>14.6e} {:>9.3}",
            t,
            dp.rates[t],
            dp.sigma_d2[t + 1],
            se.sdr_db(dp.sigma_d2[t + 1])
        );
    }
    println!(
        "total {:.1} bits/element (budget {total}), final SDR {:.2} dB",
        dp.rates.iter().sum::<f64>(),
        se.sdr_db(*dp.sigma_d2.last().unwrap())
    );
    Ok(())
}

fn cmd_bt(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let (ratio_max, r_max) = match cfg.schedule {
        ScheduleKind::BackTrack { ratio_max, r_max } => (ratio_max, r_max),
        _ => (1.02, 6.0),
    };
    let se = StateEvolution::new(cfg.prior, cfg.kappa(), cfg.sigma_e2());
    let ctl = BtController::new(&se, cfg.p, ratio_max, r_max, cfg.iters);
    let (decisions, traj) = ctl.se_schedule(cfg.iters, RateModel::Ecsq, None);
    println!("{:>3} {:>8} {:>14} {:>9}", "t", "R_t", "sigma_D^2", "SDR(dB)");
    for (t, d) in decisions.iter().enumerate() {
        println!(
            "{:>3} {:>8.2} {:>14.6e} {:>9.3}",
            t,
            d.rate,
            traj[t + 1],
            se.sdr_db(traj[t + 1])
        );
    }
    println!(
        "total {:.2} bits/element (ECSQ model), final SDR {:.2} dB",
        decisions.iter().map(|d| d.rate).sum::<f64>(),
        se.sdr_db(*traj.last().unwrap())
    );
    Ok(())
}

fn cmd_rd(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let sigma_t2: f64 = args.get_parsed("sigma2")?.unwrap_or(0.05);
    let ch = BgChannel::new(cfg.prior);
    let (wch, ws2) = ch.worker_channel(sigma_t2, cfg.p);
    let curve =
        rd_curve_for_channel(&wch, ws2, cfg.rd.alphabet, cfg.rd.curve_points, cfg.rd.tol)?;
    println!(
        "R(D) of the worker uplink source at sigma_t^2={sigma_t2}, P={}",
        cfg.p
    );
    println!("{:>12} {:>8}", "D", "R(bits)");
    let var = wch.var_f(ws2);
    for k in 0..=24 {
        let d = var * 2f64.powi(-k);
        println!("{:>12.4e} {:>8.3}", d, curve.rate_for_mse(d));
    }
    Ok(())
}

fn cmd_compressors(args: &Args) -> Result<()> {
    // `--names`: bare names only, one per line (for scripts / CI loops).
    if args.has_flag("names") {
        for name in mpamp::compress::registry::names() {
            println!("{name}");
        }
        return Ok(());
    }
    eprintln!(
        "registered compression stacks (select with --compressor or \
         compressor = \"<name>\" in TOML):"
    );
    println!(
        "{:<22} {:<14} {:<9} {:<8} {:<10} {}",
        "NAME", "QUANTIZER", "CODEC", "PAYLOAD", "MODEL-PMF", "DESCRIPTION"
    );
    for stack in mpamp::compress::registry::all() {
        let caps = stack.caps();
        println!(
            "{:<22} {:<14} {:<9} {:<8} {:<10} {}",
            stack.name(),
            stack.quantizer().family(),
            stack.codec().name(),
            if caps.payload_free { "free" } else { "coded" },
            if caps.needs_model_pmf { "needs" } else { "-" },
            stack.description(),
        );
    }
    Ok(())
}

/// `mpamp lab <manifest|run|check|gate>` — the experiment lab.
fn cmd_lab(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("manifest") => cmd_lab_manifest(args),
        Some("run") => cmd_lab_run(args),
        Some("check") => cmd_lab_check(args),
        Some("gate") => cmd_lab_gate(args),
        Some(other) => Err(Error::Config(format!(
            "unknown lab subcommand '{other}' (manifest, run, check, gate)"
        ))),
        None => Err(Error::Config(
            "usage: mpamp lab <manifest|run|check|gate> (see `mpamp help`)".into(),
        )),
    }
}

fn cmd_lab_manifest(args: &Args) -> Result<()> {
    let manifest = mpamp::lab::Manifest::generate();
    let text = manifest.render();
    if args.has_flag("check") {
        let path = args.positional.get(1).ok_or_else(|| {
            Error::Config("usage: mpamp lab manifest --check <snapshot.json>".into())
        })?;
        let snapshot = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read '{path}': {e}")))?;
        if snapshot != text {
            return Err(Error::Config(format!(
                "knob manifest drifted from '{path}': a RunConfig knob was \
                 added or changed; regenerate with `mpamp lab manifest --out \
                 {path}` and review the diff"
            )));
        }
        eprintln!("manifest matches {path} ({} knobs)", manifest.knobs.len());
        return Ok(());
    }
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &text).map_err(Error::Io)?;
            eprintln!("wrote {path} ({} knobs)", manifest.knobs.len());
        }
        None => print!("{text}"),
    }
    Ok(())
}

fn cmd_lab_run(args: &Args) -> Result<()> {
    let path = args.positional.get(1).ok_or_else(|| {
        Error::Config("usage: mpamp lab run <study.toml> [--records <out.json>]".into())
    })?;
    let manifest = mpamp::lab::Manifest::generate();
    let study = mpamp::lab::Study::from_file(path, &manifest)?;
    eprintln!("lab run: study '{}' — {} trial(s)", study.name, study.len());
    let reports = study.run()?;
    if !args.has_flag("quiet") {
        println!(
            "{:<56} {:>9} {:>11} {:>9} {:>9}",
            "TRIAL", "SDR(dB)", "bits/elem", "dB/bit", "wall(s)"
        );
        for tr in &reports {
            let bits = tr.report.total_uplink_bits_per_element();
            let per_bit =
                if bits > 0.0 { tr.report.final_sdr_db() / bits } else { f64::NAN };
            println!(
                "{:<56} {:>9.2} {:>11.2} {:>9.3} {:>9.2}",
                tr.label,
                tr.report.final_sdr_db(),
                bits,
                per_bit,
                tr.report.wall_s
            );
        }
    }
    let records = mpamp::lab::records_from_reports(&reports);
    if let Some(out) = args.get("records") {
        mpamp::bench_util::write_bench_json(out, &records).map_err(Error::Io)?;
        eprintln!("wrote {} record(s) to {out}", records.len());
    }
    Ok(())
}

fn cmd_lab_check(args: &Args) -> Result<()> {
    let files = &args.positional[1..];
    if files.is_empty() {
        return Err(Error::Config(
            "usage: mpamp lab check <file.toml> [more files...]".into(),
        ));
    }
    let manifest = mpamp::lab::Manifest::generate();
    let mut failures = 0usize;
    for path in files {
        match mpamp::lab::Study::from_file(path, &manifest) {
            Ok(study) => {
                println!("OK   {path} ({} trial(s))", study.len());
            }
            Err(e) => {
                failures += 1;
                println!("FAIL {path}");
                eprintln!("  {e}");
            }
        }
    }
    if failures > 0 {
        return Err(Error::Config(format!(
            "{failures} of {} file(s) failed manifest validation",
            files.len()
        )));
    }
    Ok(())
}

fn cmd_lab_gate(args: &Args) -> Result<()> {
    use mpamp::bench_util::compare::{compare, compare_subset, Baselines};
    let baseline_path = args.get("baseline").ok_or_else(|| {
        Error::Config(
            "usage: mpamp lab gate --baseline <baselines.json> --current \
             <BENCH.json> [--md <out.md>] [--bless] [--subset]"
                .into(),
        )
    })?;
    let current_path = args
        .get("current")
        .ok_or_else(|| Error::Config("lab gate: missing --current <BENCH.json>".into()))?;
    let current = mpamp::bench_util::read_bench_json(current_path)?;
    if args.has_flag("bless") {
        // Re-baseline: keep the store's note/tolerances when it already
        // exists, otherwise start one with the default bands.
        let note = format!("blessed from {current_path}");
        let store = if std::path::Path::new(baseline_path).exists() {
            let mut s = Baselines::load(baseline_path)?;
            s.records = current;
            s.note = note;
            s
        } else {
            Baselines::from_records(&note, current)
        };
        store.save(baseline_path)?;
        eprintln!(
            "blessed {} record(s) into {baseline_path}",
            store.records.len()
        );
        return Ok(());
    }
    let store = Baselines::load(baseline_path)?;
    let comparison = if args.has_flag("subset") {
        compare_subset(&store, &current)
    } else {
        compare(&store, &current)
    };
    let md = comparison.markdown();
    if let Some(out) = args.get("md") {
        std::fs::write(out, &md).map_err(Error::Io)?;
        eprintln!("wrote {out}");
    }
    print!("{md}");
    if !comparison.gate_passes() {
        return Err(Error::Config(format!(
            "perf gate failed: {} record(s) out of band (re-baseline \
             intentionally with --bless)",
            comparison.failures().len()
        )));
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let m = Manifest::load(&cfg.artifact_dir)?;
    println!(
        "artifacts OK in {}: n={} mp={} ({} / {})",
        cfg.artifact_dir, m.n, m.mp, m.lc_file, m.gc_file
    );
    let want_mp = cfg.m / cfg.p;
    if m.n != cfg.n || m.mp != want_mp {
        println!(
            "WARNING: config wants n={} mp={want_mp}; re-run \
             `make artifacts N={} MP={want_mp}`",
            cfg.n, cfg.n
        );
    }
    Ok(())
}
