//! Client handle for submitting recovery jobs to a running `mpampd`.
//!
//! [`Client::submit`] ships a [`RunConfig`] to the daemon and returns a
//! [`JobHandle`]; the handle is an event stream ([`JobHandle::next_event`])
//! ending in exactly one terminal event — the full [`RunReport`], a
//! cancellation, or a daemon-side error. [`JobHandle::await_report`]
//! collapses the stream for callers that only want the result.

use std::time::Duration;

use crate::config::toml::Table;
use crate::config::RunConfig;
use crate::coordinator::session::{IterSnapshot, RunReport};
use crate::error::{Error, Result};
use crate::serve::queue::Priority;
use crate::serve::wire::{self, JobConn, Reader};

/// Default bound on any single blocking read from the daemon. Generous
/// against slow rounds on loaded fleets, but finite: a daemon killed
/// mid-run surfaces as a timed-out [`Error::Transport`] instead of
/// hanging the client forever.
pub const DEFAULT_READ_TIMEOUT: Duration = Duration::from_secs(120);

/// One streamed job event.
#[derive(Debug)]
pub enum JobEvent {
    /// The job left the daemon's queue and started running.
    Started,
    /// One protocol round completed (same snapshot a local
    /// `Session::run_observed` observer would see).
    Iter(IterSnapshot),
    /// Terminal: the finished run's full report.
    Report(RunReport),
    /// Terminal: the job was cancelled (usually by this client).
    Cancelled,
    /// Terminal: the daemon failed the job with this message.
    Failed(String),
}

/// Job submission entry point.
pub struct Client;

impl Client {
    /// Submit `cfg` to the daemon at `addr` (e.g. `"127.0.0.1:7700"`)
    /// at [`Priority::Normal`] with the
    /// [default read deadline](DEFAULT_READ_TIMEOUT). Validates the
    /// config locally first, so obvious mistakes fail before any bytes
    /// move. Returns once the daemon accepts or rejects the job.
    pub fn submit(addr: &str, cfg: &RunConfig) -> Result<JobHandle> {
        Self::submit_with(addr, cfg, Priority::Normal, Some(DEFAULT_READ_TIMEOUT))
    }

    /// [`submit`](Self::submit) with explicit scheduling class and read
    /// deadline. `read_timeout` bounds every blocking read on the
    /// returned handle (`None` waits forever); an expired deadline
    /// surfaces as [`Error::Transport`] tagged with the session id.
    pub fn submit_with(
        addr: &str,
        cfg: &RunConfig,
        priority: Priority,
        read_timeout: Option<Duration>,
    ) -> Result<JobHandle> {
        cfg.validate()?;
        let mut conn = JobConn::client(addr, read_timeout)?;
        let mut table = Table::new();
        cfg.encode_into(&mut table);
        conn.send(wire::J_SUBMIT, |buf| {
            wire::encode_table(buf, &table);
            buf.push(priority.to_wire());
        })?;
        let (kind, payload) = conn.recv()?;
        match kind {
            wire::J_ACCEPTED => {
                let mut r = Reader::new(payload);
                let session = r.u32()?;
                let queue_pos = r.u32()?;
                r.finish()?;
                Ok(JobHandle {
                    conn,
                    session,
                    queue_pos,
                    started: false,
                    done: false,
                })
            }
            wire::J_ERROR => {
                let mut r = Reader::new(payload);
                let msg = r.str()?;
                Err(Error::Transport(format!("mpampd rejected the job: {msg}")))
            }
            other => Err(Error::Protocol(format!(
                "expected accept/reject after submit, got frame kind {other}"
            ))),
        }
    }
}

/// A submitted job: session identity plus the progress event stream.
pub struct JobHandle {
    conn: JobConn,
    session: u32,
    queue_pos: u32,
    /// Whether [`JobEvent::Started`] has arrived — before it, the job is
    /// still queued daemon-side, and read failures are reported as such.
    started: bool,
    done: bool,
}

impl JobHandle {
    /// The daemon-assigned session id (appears in daemon-side transport
    /// error context).
    pub fn session_id(&self) -> u32 {
        self.session
    }

    /// Queue position at admission time: `0` means the job ran
    /// immediately; `k > 0` means it waited behind `k - 1` other jobs.
    pub fn queue_pos(&self) -> u32 {
        self.queue_pos
    }

    /// Ask the daemon to cancel this job. The stream still ends with a
    /// terminal event — normally [`JobEvent::Cancelled`], or
    /// [`JobEvent::Report`] if the run finished before the cancel
    /// arrived.
    pub fn cancel(&mut self) -> Result<()> {
        self.conn.send_empty(wire::J_CANCEL)
    }

    /// Block for the next event. After a terminal event
    /// ([`JobEvent::Report`] / [`JobEvent::Cancelled`] /
    /// [`JobEvent::Failed`]), further calls error. A read past the
    /// handle's deadline (daemon died, network gone) returns
    /// [`Error::Transport`] tagged with this session's id; if the job
    /// was still queued (no [`JobEvent::Started`] yet), the error says
    /// so and reports the admission-time queue position, so a client
    /// parked behind a dead daemon sees *why* nothing ever arrived.
    pub fn next_event(&mut self) -> Result<JobEvent> {
        if self.done {
            return Err(Error::Protocol(
                "job already reached its terminal event".into(),
            ));
        }
        let session = self.session;
        let (kind, payload) = match self.conn.recv() {
            Ok(frame) => frame,
            Err(e) => {
                let e = e.transport_context(session, "client");
                if !self.started && self.queue_pos > 0 {
                    return Err(Error::Transport(format!(
                        "session {session}: daemon went away while the job \
                         was still queued (position {} at admission): {e}",
                        self.queue_pos
                    )));
                }
                return Err(e);
            }
        };
        let mut r = Reader::new(payload);
        match kind {
            wire::J_STARTED => {
                r.finish()?;
                self.started = true;
                Ok(JobEvent::Started)
            }
            wire::J_ITER => {
                let snap = wire::decode_snapshot(&mut r)?;
                r.finish()?;
                Ok(JobEvent::Iter(snap))
            }
            wire::J_REPORT => {
                let report = wire::decode_report(&mut r)?;
                self.done = true;
                Ok(JobEvent::Report(report))
            }
            wire::J_CANCELLED => {
                r.finish()?;
                self.done = true;
                Ok(JobEvent::Cancelled)
            }
            wire::J_ERROR => {
                let msg = r.str()?;
                self.done = true;
                Ok(JobEvent::Failed(msg))
            }
            other => Err(Error::Protocol(format!(
                "unexpected job frame kind {other}"
            ))),
        }
    }

    /// Drain the stream to its terminal event and return the report;
    /// cancellation and daemon errors surface as [`Error::Transport`].
    pub fn await_report(mut self) -> Result<RunReport> {
        loop {
            match self.next_event()? {
                JobEvent::Report(report) => return Ok(report),
                JobEvent::Cancelled => {
                    return Err(Error::Transport(format!(
                        "session {}: job was cancelled",
                        self.session
                    )))
                }
                JobEvent::Failed(msg) => {
                    return Err(Error::Transport(format!(
                        "session {}: daemon error: {msg}",
                        self.session
                    )))
                }
                JobEvent::Started | JobEvent::Iter(_) => {}
            }
        }
    }
}
