//! Job-connection wire protocol for the serving daemon.
//!
//! Each recovery job talks to `mpampd` over its **own** TCP connection
//! (separate from the multiplexed worker-fleet links): a 5-byte hello
//! `[PROTOCOL_VERSION: u8][JOB_MAGIC: u32 LE]`, then length-prefixed
//! frames `[len: u32 LE][kind: u8][payload]` where `len` counts the kind
//! byte plus the payload. All scalars are little-endian; floats travel as
//! raw IEEE-754 bits so decoded values are bit-identical to what the
//! daemon computed.
//!
//! Client → daemon: [`J_SUBMIT`] (a [`RunConfig`] as its flat config
//! table, followed by one priority byte — see
//! [`Priority`](crate::serve::Priority)), then optionally
//! [`J_CANCEL`]. Daemon → client:
//! [`J_ACCEPTED`] `{session_id, queue_pos}` (pos 0 = running now),
//! [`J_STARTED`], one [`J_ITER`] per protocol round (an
//! [`IterSnapshot`]), and exactly one terminal frame — [`J_REPORT`]
//! (full [`RunReport`]), [`J_CANCELLED`], or [`J_ERROR`].

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::config::toml::{Table, Value};
use crate::coordinator::message::PROTOCOL_VERSION;
use crate::coordinator::session::{IterSnapshot, RunReport};
use crate::error::{Error, Result};
use crate::metrics::IterRecord;

/// Magic identifying a job connection's hello (vs a fleet worker hello,
/// which carries a worker id in these four bytes).
pub(crate) const JOB_MAGIC: u32 = u32::from_le_bytes(*b"mpjb");

/// Client → daemon: submit a job (payload: encoded config table).
pub(crate) const J_SUBMIT: u8 = 1;
/// Client → daemon: cancel the submitted job (no payload).
pub(crate) const J_CANCEL: u8 = 2;
/// Daemon → client: job admitted (`{session_id: u32, queue_pos: u32}`).
pub(crate) const J_ACCEPTED: u8 = 3;
/// Daemon → client: job left the queue and is running (no payload).
pub(crate) const J_STARTED: u8 = 4;
/// Daemon → client: one per-round progress snapshot.
pub(crate) const J_ITER: u8 = 5;
/// Daemon → client, terminal: the full run report.
pub(crate) const J_REPORT: u8 = 6;
/// Daemon → client, terminal: the job failed (payload: message string).
pub(crate) const J_ERROR: u8 = 7;
/// Daemon → client, terminal: the job was cancelled (no payload).
pub(crate) const J_CANCELLED: u8 = 8;

/// Frame size cap (kind byte + payload); reports carry `B × N` floats.
const MAX_JOB_FRAME: usize = (1 << 30) + 1;

// ---------- scalar codec helpers ----------

pub(crate) fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn push_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_bits().to_le_bytes());
}

pub(crate) fn push_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_bits().to_le_bytes());
}

pub(crate) fn push_str(buf: &mut Vec<u8>, s: &str) {
    push_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

/// Cursor over a received payload; every read is bounds-checked so a
/// malformed frame fails with a protocol error instead of a panic.
pub(crate) struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len());
        let Some(end) = end else {
            return Err(Error::Protocol(format!(
                "job frame truncated: need {n} bytes at offset {} of {}",
                self.pos,
                self.buf.len()
            )));
        };
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub(crate) fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub(crate) fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| Error::Protocol("job frame string is not UTF-8".into()))
    }

    pub(crate) fn finish(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(Error::Protocol(format!(
                "job frame has {} trailing bytes",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

// ---------- config table codec ----------

/// Encode a flat config table (`BTreeMap` iteration order makes the
/// encoding deterministic).
pub(crate) fn encode_table(buf: &mut Vec<u8>, t: &Table) {
    push_u32(buf, t.len() as u32);
    for (key, value) in t {
        push_str(buf, key);
        match value {
            Value::Int(v) => {
                buf.push(0);
                buf.extend_from_slice(&v.to_le_bytes());
            }
            Value::Float(v) => {
                buf.push(1);
                push_f64(buf, *v);
            }
            Value::Str(v) => {
                buf.push(2);
                push_str(buf, v);
            }
            Value::Bool(v) => {
                buf.push(3);
                buf.push(*v as u8);
            }
        }
    }
}

/// Decode a flat config table.
pub(crate) fn decode_table(r: &mut Reader) -> Result<Table> {
    let count = r.u32()? as usize;
    let mut t = Table::new();
    for _ in 0..count {
        let key = r.str()?;
        let value = match r.u8()? {
            0 => Value::Int(i64::from_le_bytes(r.take(8)?.try_into().unwrap())),
            1 => Value::Float(r.f64()?),
            2 => Value::Str(r.str()?),
            3 => Value::Bool(r.u8()? != 0),
            tag => {
                return Err(Error::Protocol(format!(
                    "unknown config value tag {tag} for key '{key}'"
                )))
            }
        };
        t.insert(key, value);
    }
    Ok(t)
}

// ---------- progress / report codec ----------

/// Encode one per-iteration snapshot.
pub(crate) fn encode_snapshot(buf: &mut Vec<u8>, s: &IterSnapshot) {
    push_u64(buf, s.record.t as u64);
    push_f64(buf, s.record.sdr_db);
    push_f64(buf, s.record.sdr_pred_db);
    push_f64(buf, s.record.rate_alloc);
    push_f64(buf, s.record.rate_wire);
    push_f64(buf, s.record.sigma_q2);
    push_f64(buf, s.record.sigma_d2_hat);
    push_f64(buf, s.record.wall_s);
    push_f64(buf, s.cum_wire_bits_per_element);
    push_f64(buf, s.cum_alloc_bits_per_element);
}

fn decode_record(r: &mut Reader) -> Result<IterRecord> {
    Ok(IterRecord {
        t: r.u64()? as usize,
        sdr_db: r.f64()?,
        sdr_pred_db: r.f64()?,
        rate_alloc: r.f64()?,
        rate_wire: r.f64()?,
        sigma_q2: r.f64()?,
        sigma_d2_hat: r.f64()?,
        wall_s: r.f64()?,
    })
}

/// Decode one per-iteration snapshot.
pub(crate) fn decode_snapshot(r: &mut Reader) -> Result<IterSnapshot> {
    Ok(IterSnapshot {
        record: decode_record(r)?,
        cum_wire_bits_per_element: r.f64()?,
        cum_alloc_bits_per_element: r.f64()?,
    })
}

/// Encode a full run report.
pub(crate) fn encode_report(buf: &mut Vec<u8>, rep: &RunReport) {
    push_u32(buf, rep.iters.len() as u32);
    for rec in &rep.iters {
        push_u64(buf, rec.t as u64);
        push_f64(buf, rec.sdr_db);
        push_f64(buf, rec.sdr_pred_db);
        push_f64(buf, rec.rate_alloc);
        push_f64(buf, rec.rate_wire);
        push_f64(buf, rec.sigma_q2);
        push_f64(buf, rec.sigma_d2_hat);
        push_f64(buf, rec.wall_s);
    }
    push_u32(buf, rep.final_xs.len() as u32);
    for x in &rep.final_xs {
        push_u32(buf, x.len() as u32);
        for v in x {
            push_f32(buf, *v);
        }
    }
    push_u32(buf, rep.sdr_db_per_signal.len() as u32);
    for v in &rep.sdr_db_per_signal {
        push_f64(buf, *v);
    }
    push_u32(buf, rep.batch as u32);
    push_u32(buf, rep.dims.0 as u32);
    push_u32(buf, rep.dims.1 as u32);
    push_u32(buf, rep.dims.2 as u32);
    push_str(buf, &rep.schedule);
    push_str(buf, &rep.engine);
    push_str(buf, &rep.partitioning);
    push_u64(buf, rep.transport_uplink_bits);
    push_u64(buf, rep.transport_downlink_bits);
    push_f64(buf, rep.wall_s);
    match &rep.stopped_early {
        None => buf.push(0),
        Some(why) => {
            buf.push(1);
            push_str(buf, why);
        }
    }
}

/// Decode a full run report.
pub(crate) fn decode_report(r: &mut Reader) -> Result<RunReport> {
    let n_iters = r.u32()? as usize;
    let mut iters = Vec::with_capacity(n_iters);
    for _ in 0..n_iters {
        iters.push(decode_record(r)?);
    }
    let n_sig = r.u32()? as usize;
    let mut final_xs = Vec::with_capacity(n_sig);
    for _ in 0..n_sig {
        let len = r.u32()? as usize;
        let mut x = Vec::with_capacity(len);
        for _ in 0..len {
            x.push(r.f32()?);
        }
        final_xs.push(x);
    }
    let n_sdr = r.u32()? as usize;
    let mut sdr_db_per_signal = Vec::with_capacity(n_sdr);
    for _ in 0..n_sdr {
        sdr_db_per_signal.push(r.f64()?);
    }
    let batch = r.u32()? as usize;
    let dims = (r.u32()? as usize, r.u32()? as usize, r.u32()? as usize);
    let schedule = r.str()?;
    let engine = r.str()?;
    let partitioning = r.str()?;
    let transport_uplink_bits = r.u64()?;
    let transport_downlink_bits = r.u64()?;
    let wall_s = r.f64()?;
    let stopped_early = match r.u8()? {
        0 => None,
        _ => Some(r.str()?),
    };
    r.finish()?;
    Ok(RunReport {
        iters,
        final_xs,
        sdr_db_per_signal,
        batch,
        dims,
        schedule,
        engine,
        partitioning,
        transport_uplink_bits,
        transport_downlink_bits,
        wall_s,
        stopped_early,
    })
}

// ---------- framed job connection ----------

/// Map a blocking-read failure to [`Error::Transport`], naming an
/// expired read deadline for what it is (the raw `ErrorKind` differs by
/// platform: `WouldBlock` on Unix, `TimedOut` on Windows).
fn recv_error(what: &str, e: &std::io::Error) -> Error {
    use std::io::ErrorKind;
    if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) {
        Error::Transport(
            "job read timed out: no frame from peer within the read deadline"
                .into(),
        )
    } else {
        Error::Transport(format!("{what}: {e}"))
    }
}

/// What a server-side poll of the client socket observed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum ClientSignal {
    /// The client sent a well-formed cancel frame.
    Cancel,
    /// The client disconnected (or sent something other than a cancel).
    Gone,
}

/// One framed job connection (either side). Owns a reused frame buffer,
/// so streaming a progress event per round allocates nothing in steady
/// state.
pub(crate) struct JobConn {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl JobConn {
    /// Client side: connect and send the job hello. `read_timeout`
    /// bounds every blocking read on the handle (accept frame, progress
    /// events, the terminal report): a daemon that dies mid-run surfaces
    /// as a timed-out [`Error::Transport`] instead of hanging the client
    /// forever. `None` waits indefinitely (the pre-timeout behaviour).
    pub(crate) fn client(addr: &str, read_timeout: Option<Duration>) -> Result<Self> {
        let stream = TcpStream::connect(addr).map_err(|e| {
            Error::Transport(format!("cannot reach mpampd at {addr}: {e}"))
        })?;
        stream.set_nodelay(true).map_err(Error::Io)?;
        stream.set_read_timeout(read_timeout).map_err(Error::Io)?;
        let mut hello = [0u8; 5];
        hello[0] = PROTOCOL_VERSION;
        hello[1..5].copy_from_slice(&JOB_MAGIC.to_le_bytes());
        let mut conn = JobConn { stream, buf: Vec::new() };
        conn.stream.write_all(&hello).map_err(Error::Io)?;
        Ok(conn)
    }

    /// Server side: validate the job hello on an accepted stream. The
    /// handshake (and the submit frame that follows) runs under
    /// `handshake_timeout` so a silent client cannot pin a daemon thread;
    /// call [`JobConn::set_blocking`] once the job is admitted.
    pub(crate) fn server(stream: TcpStream, handshake_timeout: Duration) -> Result<Self> {
        stream.set_nodelay(true).map_err(Error::Io)?;
        stream
            .set_read_timeout(Some(handshake_timeout))
            .map_err(Error::Io)?;
        let mut conn = JobConn { stream, buf: Vec::new() };
        let mut hello = [0u8; 5];
        conn.stream.read_exact(&mut hello).map_err(|e| {
            Error::Transport(format!("job hello not received: {e}"))
        })?;
        if hello[0] != PROTOCOL_VERSION {
            return Err(Error::Protocol(format!(
                "job client speaks protocol v{}, daemon speaks v{PROTOCOL_VERSION}",
                hello[0]
            )));
        }
        let magic = u32::from_le_bytes(hello[1..5].try_into().unwrap());
        if magic != JOB_MAGIC {
            return Err(Error::Protocol(format!(
                "not a job connection (hello magic {magic:#x})"
            )));
        }
        Ok(conn)
    }

    /// Drop the read deadline (used once a job is admitted: the client
    /// legitimately stays silent while results stream toward it).
    pub(crate) fn set_blocking(&mut self) -> Result<()> {
        self.stream.set_read_timeout(None).map_err(Error::Io)
    }

    /// Send one frame whose payload is written by `fill`.
    pub(crate) fn send(
        &mut self,
        kind: u8,
        fill: impl FnOnce(&mut Vec<u8>),
    ) -> Result<()> {
        self.buf.clear();
        self.buf.extend_from_slice(&[0, 0, 0, 0, kind]);
        fill(&mut self.buf);
        let len = (self.buf.len() - 4) as u32;
        self.buf[..4].copy_from_slice(&len.to_le_bytes());
        self.stream.write_all(&self.buf).map_err(Error::Io)
    }

    /// Send a payload-free frame.
    pub(crate) fn send_empty(&mut self, kind: u8) -> Result<()> {
        self.send(kind, |_| {})
    }

    /// Send a terminal error frame (best-effort on an already-failing
    /// connection, hence the ignored result at most call sites).
    pub(crate) fn send_error(&mut self, message: &str) -> Result<()> {
        self.send(J_ERROR, |buf| push_str(buf, message))
    }

    /// Receive one frame; returns the kind byte and borrows the payload
    /// from the connection's reused buffer.
    pub(crate) fn recv(&mut self) -> Result<(u8, &[u8])> {
        let mut hdr = [0u8; 4];
        self.stream
            .read_exact(&mut hdr)
            .map_err(|e| recv_error("job connection closed", &e))?;
        let len = u32::from_le_bytes(hdr) as usize;
        if !(1..=MAX_JOB_FRAME).contains(&len) {
            return Err(Error::Protocol(format!("bad job frame length {len}")));
        }
        self.buf.resize(len, 0);
        self.stream
            .read_exact(&mut self.buf)
            .map_err(|e| recv_error("job frame truncated by peer", &e))?;
        Ok((self.buf[0], &self.buf[1..]))
    }

    /// Server side, non-blocking-ish: peek for a client frame between
    /// protocol rounds. A cancel frame is consumed; EOF or any other
    /// traffic reads as [`ClientSignal::Gone`] (the only legal client
    /// frame after submit is a cancel). Returns `None` when the client is
    /// silently connected — the common case — within ~5 ms.
    pub(crate) fn poll_client(&mut self) -> Option<ClientSignal> {
        let mut hdr = [0u8; 5];
        if self.stream.set_read_timeout(Some(Duration::from_millis(5))).is_err() {
            return Some(ClientSignal::Gone);
        }
        let peeked = self.stream.peek(&mut hdr);
        let _ = self.stream.set_read_timeout(None);
        match peeked {
            Ok(0) => Some(ClientSignal::Gone),
            Ok(n) if n >= 5 => {
                // A full header is buffered: consume exactly those bytes.
                let mut sink = [0u8; 5];
                if self.stream.read_exact(&mut sink).is_err() {
                    return Some(ClientSignal::Gone);
                }
                let len = u32::from_le_bytes(hdr[..4].try_into().unwrap());
                if len == 1 && hdr[4] == J_CANCEL {
                    Some(ClientSignal::Cancel)
                } else {
                    Some(ClientSignal::Gone)
                }
            }
            // Partial header or timeout: nothing actionable yet.
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    #[test]
    fn table_codec_roundtrips_a_config() {
        let cfg = RunConfig::test_small(0.05);
        let mut table = Table::new();
        cfg.encode_into(&mut table);
        let mut buf = Vec::new();
        encode_table(&mut buf, &table);
        let mut r = Reader::new(&buf);
        let back = decode_table(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(table, back);
        let decoded = RunConfig::from_table(&back).unwrap();
        assert_eq!(decoded.n, cfg.n);
        assert_eq!(decoded.p, cfg.p);
        assert_eq!(decoded.iters, cfg.iters);
        assert_eq!(decoded.compressor, cfg.compressor);
    }

    #[test]
    fn snapshot_codec_roundtrips_bits() {
        let snap = IterSnapshot {
            record: IterRecord {
                t: 3,
                sdr_db: 12.5,
                sdr_pred_db: 12.25,
                rate_alloc: 4.0,
                rate_wire: 3.875,
                sigma_q2: 1.5e-3,
                sigma_d2_hat: 2.5e-3,
                wall_s: 0.125,
            },
            cum_wire_bits_per_element: 11.625,
            cum_alloc_bits_per_element: 12.0,
        };
        let mut buf = Vec::new();
        encode_snapshot(&mut buf, &snap);
        let mut r = Reader::new(&buf);
        let back = decode_snapshot(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(back.record.t, 3);
        assert_eq!(back.record.sdr_db.to_bits(), snap.record.sdr_db.to_bits());
        assert_eq!(
            back.record.sigma_d2_hat.to_bits(),
            snap.record.sigma_d2_hat.to_bits()
        );
        assert_eq!(
            back.cum_wire_bits_per_element.to_bits(),
            snap.cum_wire_bits_per_element.to_bits()
        );
    }

    #[test]
    fn report_codec_roundtrips_bits() {
        let rep = RunReport {
            iters: vec![IterRecord {
                t: 0,
                sdr_db: 1.0,
                sdr_pred_db: 1.5,
                rate_alloc: 4.0,
                rate_wire: 3.75,
                sigma_q2: 0.01,
                sigma_d2_hat: 0.02,
                wall_s: 0.5,
            }],
            final_xs: vec![vec![0.5, -1.25, 0.0], vec![3.5, 2.0, -0.125]],
            sdr_db_per_signal: vec![10.0, 11.5],
            batch: 2,
            dims: (600, 180, 6),
            schedule: "bt".into(),
            engine: "rust".into(),
            partitioning: "row".into(),
            transport_uplink_bits: 12_345,
            transport_downlink_bits: 67_890,
            wall_s: 1.25,
            stopped_early: Some("target SDR reached (10 dB)".into()),
        };
        let mut buf = Vec::new();
        encode_report(&mut buf, &rep);
        let mut r = Reader::new(&buf);
        let back = decode_report(&mut r).unwrap();
        assert_eq!(back.iters.len(), 1);
        assert_eq!(back.iters[0].sdr_db.to_bits(), rep.iters[0].sdr_db.to_bits());
        assert_eq!(back.final_xs.len(), 2);
        for (a, b) in back.final_xs.iter().flatten().zip(rep.final_xs.iter().flatten())
        {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(back.dims, (600, 180, 6));
        assert_eq!(back.transport_uplink_bits, 12_345);
        assert_eq!(back.stopped_early.as_deref(), Some("target SDR reached (10 dB)"));
    }

    #[test]
    fn reader_rejects_truncated_and_trailing() {
        let mut buf = Vec::new();
        push_u32(&mut buf, 7);
        let mut r = Reader::new(&buf);
        assert!(r.u64().is_err(), "truncated read must fail");
        let mut r = Reader::new(&buf);
        r.u8().unwrap();
        assert!(r.finish().is_err(), "trailing bytes must fail");
    }
}
