//! `mpampd` — the long-running serving daemon.
//!
//! One daemon process hosts a single worker fleet (`fleet_p` threads,
//! connected back to the fusion side over loopback TCP with the
//! protocol-v5 **multiplexed** links) and a public job listener. Each
//! accepted job connection submits one [`RunConfig`]; admission control
//! ([`JobQueue`]) decides whether the job runs now, waits, or bounces.
//! A running job drives an ordinary [`Session`] over per-session mux
//! endpoints, so its [`RunReport`] — per-iteration records, final
//! estimates, and exact byte accounting — is **bit-identical to a
//! standalone run of the same config**, even while other sessions'
//! rounds interleave on the same fleet sockets.
//!
//! Compute is shared through [`Pool::global`]: every served session uses
//! a pool-aware engine whose chunk-count-invariant kernels size their
//! fan-out to the pool's free capacity, so concurrent sessions divide
//! the machine instead of oversubscribing it (and the chunk-ordered
//! reduction keeps its fixed fan-out, preserving bit-determinism).
//!
//! [`Pool::global`]: crate::runtime::pool::Pool::global

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::{EngineKind, Partitioning, RunConfig};
use crate::coordinator::scenario::{Column, Row, Scenario};
use crate::coordinator::session::{IterSnapshot, RunReport, Session};
use crate::coordinator::transport::{
    tcp_connect_mux, Endpoint, MuxFusionLink, MuxWorkerLink, TcpFusionListener,
    TcpTimeouts,
};
use crate::coordinator::worker::{Served, WorkerParams, WorkerSession};
use crate::engine::{ColumnWorkerData, ComputeEngine, RowBatchData, RustEngine};
use crate::error::{Error, Result};
use crate::metrics::ByteMeter;
use crate::observe::{RunObserver, StopSet};
use crate::serve::queue::{Admission, JobQueue, Priority};
use crate::serve::wire::{self, ClientSignal, JobConn, Reader};
use crate::signal::{Batch, ProblemDims};
use crate::telemetry::{metrics as tel_metrics, JobState, Telemetry};
use crate::util::rng::Rng;

/// Ring capacity of the per-job [`Telemetry`] handle attached to served
/// sessions: enough for every span of a long run's recent rounds while
/// keeping the per-job footprint small. Attaching it keeps the
/// process-wide per-stage latency histograms warm under serving load;
/// telemetry is measurement-only, so reports stay bit-identical.
const JOB_TELEMETRY_CAPACITY: usize = 4096;

/// Mirror the admission queue into the registry's gauges (called under
/// the queue lock, so a scrape never sees a half-applied transition).
fn sync_queue_gauges(q: &JobQueue) {
    let reg = tel_metrics();
    reg.jobs_running.set(q.running() as u64);
    reg.jobs_queued.set(q.queued() as u64);
}

/// Daemon capacity and placement policy.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Job listener address (`"127.0.0.1:0"` picks a free port; read the
    /// bound address back with [`Daemon::addr`]).
    pub listen: String,
    /// Fleet size: every job's `cfg.p` must equal this (shards are
    /// pinned to fleet workers by id for the whole run).
    pub fleet_p: usize,
    /// Max concurrently *running* sessions.
    pub max_sessions: usize,
    /// Max sessions *waiting* beyond that (0 = reject on overload).
    pub max_queue: usize,
    /// Per-job wall-clock deadline, checked after every round; an
    /// over-deadline job stops early and still reports.
    pub deadline: Option<Duration>,
    /// Timeout policy for the fleet links and the job handshake.
    pub timeouts: TcpTimeouts,
}

impl ServeConfig {
    /// Defaults: 4 concurrent sessions, 16 queued, no deadline.
    pub fn new(listen: &str, fleet_p: usize) -> Self {
        ServeConfig {
            listen: listen.to_string(),
            fleet_p,
            max_sessions: 4,
            max_queue: 16,
            deadline: None,
            timeouts: TcpTimeouts::default(),
        }
    }
}

/// Everything a fleet worker needs to serve one session: the scenario's
/// shard + per-round state behind one dispatch point, and the session's
/// pool-aware engine.
enum WorkerEntry {
    Row {
        params: WorkerParams,
        shard: RowBatchData,
        ws: WorkerSession<Row>,
        engine: RustEngine,
    },
    Column {
        params: WorkerParams,
        shard: ColumnWorkerData,
        ws: WorkerSession<Column>,
        engine: RustEngine,
    },
}

impl WorkerEntry {
    fn handle(&mut self, frame: &[u8], ep: &mut Endpoint) -> Result<Served> {
        match self {
            WorkerEntry::Row { params, shard, ws, engine } => {
                ws.handle_frame(params, shard, &*engine, frame, ep)
            }
            WorkerEntry::Column { params, shard, ws, engine } => {
                ws.handle_frame(params, shard, &*engine, frame, ep)
            }
        }
    }
}

/// Hand a session's shard to one fleet worker, ahead of its first frame.
struct FleetRegister {
    session: u32,
    /// The job's meter (shared with the fusion endpoints): metering is
    /// sender-side, so worker sends land here as uplink bits exactly as
    /// they do in a standalone run.
    meter: Arc<ByteMeter>,
    entry: WorkerEntry,
}

/// State shared between the acceptor, the job threads, and shutdown.
struct DaemonShared {
    cfg: ServeConfig,
    /// Fusion sides of the fleet links, in worker-id order. Taken (and
    /// dropped) on shutdown, which EOFs the fleet; job threads arriving
    /// after that see `None` and bounce.
    links: Mutex<Option<Vec<MuxFusionLink>>>,
    /// Per-worker registration channels (`Mutex` keeps the `Sender`
    /// shareable across job threads on any toolchain).
    ctrls: Vec<Mutex<Sender<FleetRegister>>>,
    queue: Mutex<JobQueue>,
    queue_cv: Condvar,
    next_session: AtomicU32,
    shutdown: AtomicBool,
    /// Graceful-drain mode: new submissions bounce, admitted jobs run to
    /// completion. Set by [`Daemon::begin_drain`] (the CLI's SIGTERM /
    /// SIGINT path).
    draining: AtomicBool,
}

/// A running serving daemon. Dropping it shuts the fleet down and joins
/// every fleet thread (jobs mid-flight fail over to error frames).
pub struct Daemon {
    addr: SocketAddr,
    shared: Arc<DaemonShared>,
    acceptor: Option<JoinHandle<()>>,
    fleet: Vec<JoinHandle<Result<()>>>,
}

impl Daemon {
    /// Boot the fleet, bind the job listener, and start accepting.
    pub fn start(cfg: ServeConfig) -> Result<Daemon> {
        if cfg.fleet_p == 0 {
            return Err(Error::Config("fleet_p must be ≥ 1".into()));
        }
        // Fleet: P worker threads connect back over loopback, then the
        // fusion side wraps each connection in a multiplexed link.
        let fleet_listener =
            TcpFusionListener::bind_with("127.0.0.1:0", cfg.fleet_p, cfg.timeouts)?;
        let fleet_addr = fleet_listener.addr()?.to_string();
        let mut ctrls = Vec::with_capacity(cfg.fleet_p);
        let mut fleet = Vec::with_capacity(cfg.fleet_p);
        for id in 0..cfg.fleet_p {
            let (tx, rx) = mpsc::channel::<FleetRegister>();
            ctrls.push(Mutex::new(tx));
            let addr = fleet_addr.clone();
            let timeouts = cfg.timeouts;
            fleet.push(
                std::thread::Builder::new()
                    .name(format!("mpampd-worker-{id}"))
                    .spawn(move || {
                        let link = tcp_connect_mux(&addr, id as u32, timeouts)?;
                        fleet_worker(link, rx, id as u32)
                    })
                    .map_err(Error::Io)?,
            );
        }
        let links = fleet_listener.accept_all_mux()?;

        let job_listener = TcpListener::bind(&cfg.listen).map_err(Error::Io)?;
        let addr = job_listener.local_addr().map_err(Error::Io)?;
        let queue = JobQueue::new(cfg.max_sessions, cfg.max_queue);
        let shared = Arc::new(DaemonShared {
            cfg,
            links: Mutex::new(Some(links)),
            ctrls,
            queue: Mutex::new(queue),
            queue_cv: Condvar::new(),
            next_session: AtomicU32::new(1),
            shutdown: AtomicBool::new(false),
            draining: AtomicBool::new(false),
        });
        let acc = shared.clone();
        let acceptor = std::thread::Builder::new()
            .name("mpampd-accept".into())
            .spawn(move || {
                for conn in job_listener.incoming() {
                    if acc.shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let job_shared = acc.clone();
                    // Job threads are detached: each one ends by writing a
                    // terminal frame to its own client.
                    let _ = std::thread::Builder::new()
                        .name("mpampd-job".into())
                        .spawn(move || {
                            let _ = serve_job(job_shared, stream);
                        });
                }
            })
            .map_err(Error::Io)?;
        Ok(Daemon { addr, shared, acceptor: Some(acceptor), fleet })
    }

    /// The bound job-listener address (what clients connect to).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Sessions currently running / waiting (for logs and smoke checks).
    pub fn load(&self) -> (usize, usize) {
        let q = self.shared.queue.lock().expect("queue poisoned");
        (q.running(), q.queued())
    }

    /// Enter graceful-drain mode: stop admitting new jobs (submissions
    /// are rejected with a "draining" message) while already-admitted
    /// jobs — running *and* queued — finish normally. Poll
    /// [`is_idle`](Self::is_idle) and then [`shutdown`](Self::shutdown)
    /// to exit cleanly; this is the `mpamp serve` SIGTERM/SIGINT path.
    pub fn begin_drain(&self) {
        self.shared.draining.store(true, Ordering::SeqCst);
    }

    /// Whether the drain has been requested via [`begin_drain`](Self::begin_drain).
    pub fn is_draining(&self) -> bool {
        self.shared.draining.load(Ordering::SeqCst)
    }

    /// Whether no job is running or queued (drain complete).
    pub fn is_idle(&self) -> bool {
        let q = self.shared.queue.lock().expect("queue poisoned");
        q.running() == 0 && q.queued() == 0
    }

    /// Stop accepting, EOF the fleet, and join it. Called by `Drop`;
    /// explicit for callers that want shutdown errors surfaced.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop();
        let mut first_err = None;
        for h in self.fleet.drain(..) {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => {
                    first_err = first_err
                        .or_else(|| Some(Error::Transport("fleet worker panicked".into())))
                }
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    fn stop(&mut self) {
        if self.shared.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        // Unblock the acceptor's `incoming()` with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        // Dropping the fusion links EOFs every fleet worker's demux read.
        let links = self.shared.links.lock().expect("links poisoned").take();
        drop(links);
        // Wake queued jobs so they notice shutdown and bail out.
        self.shared.queue_cv.notify_all();
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        self.stop();
        for h in self.fleet.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------- fleet side ----------

/// One fleet worker: demultiplex session frames off the shared link,
/// look up (or register) the session's state, and serve the frame with
/// the exact same [`WorkerSession`] state machine a standalone worker
/// thread runs.
fn fleet_worker(
    mut link: MuxWorkerLink,
    ctrl: Receiver<FleetRegister>,
    worker_id: u32,
) -> Result<()> {
    struct Live {
        entry: WorkerEntry,
        ep: Endpoint,
    }
    let mut live: HashMap<u32, Live> = HashMap::new();
    let mut frame: Vec<u8> = Vec::new();
    let role = format!("worker {worker_id}");
    loop {
        let sid = match link.recv_session_frame(&mut frame)? {
            Some(sid) => sid,
            // Fusion links dropped: clean fleet shutdown.
            None => return Ok(()),
        };
        if !live.contains_key(&sid) {
            // Registrations are enqueued before the job's first frame is
            // sent, so draining here always finds a new session's entry.
            while let Ok(reg) = ctrl.try_recv() {
                let ep = link.session_endpoint(reg.session, reg.meter);
                live.insert(reg.session, Live { entry: reg.entry, ep });
            }
        }
        let Some(l) = live.get_mut(&sid) else {
            return Err(Error::Protocol(format!(
                "fleet {role}: frame for unregistered session {sid}"
            )));
        };
        match l
            .entry
            .handle(&frame, &mut l.ep)
            .map_err(|e| e.transport_context(sid, &role))?
        {
            Served::Continue => {}
            Served::Done => {
                live.remove(&sid);
            }
        }
    }
}

// ---------- job side ----------

enum JobOutcome {
    Report(RunReport),
    Cancelled(String),
}

/// Streams per-round progress to the job's client and turns client
/// cancels / disconnects / the daemon deadline into an early stop.
/// Also refreshes the job's registry row each round, so a metrics
/// scrape mid-run sees live per-job round counts and uplink bits.
struct ProgressForwarder<'a> {
    conn: &'a mut JobConn,
    sid: u32,
    meter: Arc<ByteMeter>,
    started: Instant,
    deadline: Option<Duration>,
    cancelled: Option<String>,
}

impl RunObserver for ProgressForwarder<'_> {
    fn on_iter(&mut self, snap: &IterSnapshot) {
        let uplink_bits = self.meter.uplink_bits();
        tel_metrics().job_update(self.sid, |j| {
            j.rounds = snap.record.t as u64 + 1;
            j.uplink_bits = uplink_bits;
        });
        if self.cancelled.is_some() {
            return;
        }
        if self
            .conn
            .send(wire::J_ITER, |buf| wire::encode_snapshot(buf, snap))
            .is_err()
        {
            self.cancelled = Some("client disconnected".into());
        }
    }

    fn should_stop(&mut self) -> Option<String> {
        if let Some(why) = &self.cancelled {
            return Some(why.clone());
        }
        if let Some(d) = self.deadline {
            if self.started.elapsed() > d {
                return Some(format!(
                    "job deadline exceeded ({:.1}s)",
                    d.as_secs_f64()
                ));
            }
        }
        match self.conn.poll_client() {
            Some(ClientSignal::Cancel) => {
                self.cancelled = Some("cancelled by client".into());
                self.cancelled.clone()
            }
            Some(ClientSignal::Gone) => {
                self.cancelled = Some("client disconnected".into());
                self.cancelled.clone()
            }
            None => None,
        }
    }
}

/// A job's config must fit the fleet it will run on.
fn validate_job(cfg: &RunConfig, serve: &ServeConfig) -> Result<()> {
    cfg.validate()?;
    if cfg.p != serve.fleet_p {
        return Err(Error::Config(format!(
            "job wants P={} workers but this daemon's fleet has {}",
            cfg.p, serve.fleet_p
        )));
    }
    if cfg.engine != EngineKind::Rust {
        return Err(Error::Config(
            "served jobs require engine = \"rust\" (the fleet shares the \
             process-wide compute pool)"
                .into(),
        ));
    }
    Ok(())
}

/// Drive one job connection end to end. Every failure path ends with a
/// terminal frame to the client (best-effort) before returning.
fn serve_job(shared: Arc<DaemonShared>, stream: TcpStream) -> Result<()> {
    let mut conn = JobConn::server(stream, shared.cfg.timeouts.accept)?;
    // Submit.
    let (cfg, priority) = match recv_submit(&mut conn) {
        Ok(sub) => sub,
        Err(e) => {
            let _ = conn.send_error(&e.to_string());
            return Err(e);
        }
    };
    conn.set_blocking()?;
    if let Err(e) = validate_job(&cfg, &shared.cfg) {
        let _ = conn.send_error(&e.to_string());
        return Err(e);
    }
    // A draining daemon finishes what it admitted but takes nothing new.
    if shared.draining.load(Ordering::SeqCst) {
        let msg = "daemon is draining; not accepting new jobs";
        let _ = conn.send_error(msg);
        return Ok(());
    }
    let sid = shared.next_session.fetch_add(1, Ordering::Relaxed);
    let reg = tel_metrics();
    // Admission.
    let admission = {
        let mut q = shared.queue.lock().expect("queue poisoned");
        let admission = q.admit(sid, priority);
        sync_queue_gauges(&q);
        admission
    };
    match admission {
        Admission::Reject => {
            reg.jobs_rejected.add(1);
            let q = shared.queue.lock().expect("queue poisoned");
            let msg = format!(
                "daemon at capacity: {} running, {} queued (max {} + {})",
                q.running(),
                q.queued(),
                shared.cfg.max_sessions,
                shared.cfg.max_queue
            );
            drop(q);
            let _ = conn.send_error(&msg);
            return Ok(());
        }
        Admission::Run => {
            reg.job_insert(sid, priority == Priority::High, JobState::Running);
            // An unreachable client must not leak its admitted slot.
            if let Err(e) = send_accepted(&mut conn, sid, 0) {
                let mut q = shared.queue.lock().expect("queue poisoned");
                q.release();
                sync_queue_gauges(&q);
                drop(q);
                shared.queue_cv.notify_all();
                reg.job_update(sid, |j| j.state = JobState::Cancelled);
                reg.jobs_cancelled.add(1);
                return Err(e);
            }
        }
        Admission::Queued(pos) => {
            reg.job_insert(sid, priority == Priority::High, JobState::Queued);
            if let Err(e) = send_accepted(&mut conn, sid, pos as u32) {
                abandon_queued(&shared, sid);
                reg.jobs_cancelled.add(1);
                return Err(e);
            }
            if !wait_for_slot(&shared, &mut conn, sid)? {
                return Ok(()); // cancelled / disconnected while queued
            }
            reg.job_update(sid, |j| j.state = JobState::Running);
        }
    }
    // From here this thread owns a running slot: release it on all paths.
    let outcome = run_job(&shared, &mut conn, sid, &cfg);
    {
        let mut q = shared.queue.lock().expect("queue poisoned");
        q.release();
        sync_queue_gauges(&q);
    }
    shared.queue_cv.notify_all();
    match outcome {
        Ok(JobOutcome::Report(report)) => {
            reg.job_update(sid, |j| j.state = JobState::Done);
            reg.jobs_completed.add(1);
            conn.send(wire::J_REPORT, |buf| wire::encode_report(buf, &report))
        }
        Ok(JobOutcome::Cancelled(_)) => {
            reg.job_update(sid, |j| j.state = JobState::Cancelled);
            reg.jobs_cancelled.add(1);
            conn.send_empty(wire::J_CANCELLED)
        }
        Err(e) => {
            reg.job_update(sid, |j| j.state = JobState::Failed);
            reg.jobs_failed.add(1);
            let tagged = e.transport_context(sid, "fusion");
            let _ = conn.send_error(&tagged.to_string());
            Err(tagged)
        }
    }
}

/// Drop a still-queued (or just-promoted) session from the queue, mirror
/// the gauges, mark its registry row cancelled, and wake the waiters.
fn abandon_queued(shared: &DaemonShared, sid: u32) {
    {
        let mut q = shared.queue.lock().expect("queue poisoned");
        q.abandon(sid);
        sync_queue_gauges(&q);
    }
    shared.queue_cv.notify_all();
    tel_metrics().job_update(sid, |j| j.state = JobState::Cancelled);
}

fn recv_submit(conn: &mut JobConn) -> Result<(RunConfig, Priority)> {
    let (kind, payload) = conn.recv()?;
    if kind != wire::J_SUBMIT {
        return Err(Error::Protocol(format!(
            "expected a submit frame, got kind {kind}"
        )));
    }
    let mut r = Reader::new(payload);
    let table = wire::decode_table(&mut r)?;
    let priority = Priority::from_wire(r.u8()?).ok_or_else(|| {
        Error::Protocol("unknown job priority byte in submit frame".into())
    })?;
    r.finish()?;
    Ok((RunConfig::from_table(&table)?, priority))
}

fn send_accepted(conn: &mut JobConn, sid: u32, pos: u32) -> Result<()> {
    conn.send(wire::J_ACCEPTED, |buf| {
        wire::push_u32(buf, sid);
        wire::push_u32(buf, pos);
    })
}

/// Park a queued job until its slot frees. Returns `false` when the job
/// left the queue without running (client cancel/disconnect, shutdown).
fn wait_for_slot(
    shared: &DaemonShared,
    conn: &mut JobConn,
    sid: u32,
) -> Result<bool> {
    loop {
        {
            let mut q = shared.queue.lock().expect("queue poisoned");
            if q.claim(sid) {
                return Ok(true);
            }
            let (mut q, _timeout) = shared
                .queue_cv
                .wait_timeout(q, Duration::from_millis(25))
                .expect("queue poisoned");
            if q.claim(sid) {
                return Ok(true);
            }
        }
        // Lock released: poll the client socket between waits.
        match conn.poll_client() {
            Some(signal) => {
                abandon_queued(shared, sid);
                tel_metrics().jobs_cancelled.add(1);
                if signal == ClientSignal::Cancel {
                    let _ = conn.send_empty(wire::J_CANCELLED);
                }
                return Ok(false);
            }
            None => {}
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            {
                let mut q = shared.queue.lock().expect("queue poisoned");
                q.abandon(sid);
                sync_queue_gauges(&q);
            }
            let reg = tel_metrics();
            reg.job_update(sid, |j| j.state = JobState::Failed);
            reg.jobs_failed.add(1);
            let _ = conn.send_error("daemon is shutting down");
            return Ok(false);
        }
    }
}

/// Run an admitted job: regenerate the problem from the config's seed
/// (bit-identical to `Session::new`), register per-worker shards with
/// the fleet, open the session's fusion-side mux endpoints, and drive a
/// plain [`Session`] with progress forwarding.
fn run_job(
    shared: &DaemonShared,
    conn: &mut JobConn,
    sid: u32,
    cfg: &RunConfig,
) -> Result<JobOutcome> {
    conn.send_empty(wire::J_STARTED)?;
    let mut rng = Rng::new(cfg.seed);
    let batch = Arc::new(Batch::generate(
        cfg.prior,
        ProblemDims { n: cfg.n, m: cfg.m, sigma_e2: cfg.sigma_e2() },
        &mut rng,
        cfg.batch,
    )?);
    let job_meter = Arc::new(ByteMeter::new());
    register_fleet(shared, sid, cfg, &batch, &job_meter)?;
    let endpoints: Vec<Endpoint> = {
        let guard = shared.links.lock().expect("links poisoned");
        let Some(links) = guard.as_ref() else {
            return Err(Error::Transport("daemon is shutting down".into()));
        };
        links.iter().map(|l| l.open_session(sid, job_meter.clone())).collect()
    };
    let engine: Arc<dyn ComputeEngine> =
        Arc::new(RustEngine::new_pool_aware(cfg.prior, cfg.threads));
    let mut session = Session::with_external_transport(
        cfg.clone(),
        batch,
        engine,
        job_meter.clone(),
        endpoints,
    )?;
    // Measurement-only: keeps the per-stage latency histograms warm
    // while leaving the report bit-identical to a standalone run.
    session.set_telemetry(Telemetry::with_capacity(JOB_TELEMETRY_CAPACITY));
    let mut forwarder = ProgressForwarder {
        conn,
        sid,
        meter: job_meter,
        started: Instant::now(),
        deadline: shared.cfg.deadline,
        cancelled: None,
    };
    let report = session.run_observed(&mut forwarder, &StopSet::none())?;
    match forwarder.cancelled.take() {
        Some(why) => Ok(JobOutcome::Cancelled(why)),
        None => Ok(JobOutcome::Report(report)),
    }
}

/// Build and ship one session's per-worker state to every fleet worker.
/// Registration precedes the session's first broadcast, so a fleet
/// worker that sees an unknown session id only has to drain its control
/// channel.
fn register_fleet(
    shared: &DaemonShared,
    sid: u32,
    cfg: &RunConfig,
    batch: &Arc<Batch>,
    meter: &Arc<ByteMeter>,
) -> Result<()> {
    let entries: Vec<WorkerEntry> = match cfg.partitioning {
        Partitioning::Row => Row::split(batch, cfg.p)?
            .into_iter()
            .enumerate()
            .map(|(i, shard)| {
                let params = worker_params(i, cfg);
                let ws = WorkerSession::<Row>::new(&shard, cfg.batch);
                let engine = RustEngine::new_pool_aware(cfg.prior, cfg.threads);
                WorkerEntry::Row { params, shard, ws, engine }
            })
            .collect(),
        Partitioning::Column => Column::split(batch, cfg.p)?
            .into_iter()
            .enumerate()
            .map(|(i, shard)| {
                let params = worker_params(i, cfg);
                let ws = WorkerSession::<Column>::new(&shard, cfg.batch);
                let engine = RustEngine::new_pool_aware(cfg.prior, cfg.threads);
                WorkerEntry::Column { params, shard, ws, engine }
            })
            .collect(),
    };
    for (i, entry) in entries.into_iter().enumerate() {
        let reg = FleetRegister { session: sid, meter: meter.clone(), entry };
        shared.ctrls[i]
            .lock()
            .expect("fleet control poisoned")
            .send(reg)
            .map_err(|_| {
                Error::Transport(format!("fleet worker {i} is gone"))
                    .transport_context(sid, "fusion")
            })?;
    }
    Ok(())
}

fn worker_params(id: usize, cfg: &RunConfig) -> WorkerParams {
    WorkerParams {
        id: id as u32,
        p_workers: cfg.p,
        batch: cfg.batch,
        prior: cfg.prior,
    }
}
