//! `mpampd` — the long-running serving daemon.
//!
//! One daemon process hosts a single worker fleet (`fleet_p` threads,
//! connected back to the fusion side over loopback TCP with the
//! protocol-v5 **multiplexed** links) and a public job listener. Each
//! accepted job connection submits one [`RunConfig`]; admission control
//! ([`JobQueue`]) decides whether the job runs now, waits, or bounces.
//! A running job drives an ordinary [`Session`] over per-session mux
//! endpoints, so its [`RunReport`] — per-iteration records, final
//! estimates, and exact byte accounting — is **bit-identical to a
//! standalone run of the same config**, even while other sessions'
//! rounds interleave on the same fleet sockets.
//!
//! Compute is shared through [`Pool::global`]: every served session uses
//! a pool-aware engine whose chunk-count-invariant kernels size their
//! fan-out to the pool's free capacity, so concurrent sessions divide
//! the machine instead of oversubscribing it (and the chunk-ordered
//! reduction keeps its fixed fan-out, preserving bit-determinism).
//!
//! # Fault tolerance
//!
//! The fleet is **elastic**: each worker connection lives in a
//! [`FleetSlot`] rather than being fixed for the daemon's lifetime. When
//! a worker's link dies (process exit, scripted [`FaultPlan`] kill, or a
//! plain TCP reset), its thread reconnects with capped exponential
//! backoff and deterministic jitter; the fleet acceptor replays every
//! in-flight session's registration to the rejoined worker and bumps the
//! slot generation, which makes the sessions' [`SlotChannel`]s re-open
//! their routes on the replacement link. A job configured with elastic
//! K-of-P rounds (`min_workers` + `round_deadline_ms`) keeps fusing on
//! the live majority in the meantime and only fails once fewer than K
//! workers remain; the rejoined worker resumes at the next round
//! boundary.
//!
//! [`Pool::global`]: crate::runtime::pool::Pool::global

use std::collections::{HashMap, HashSet};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::{EngineKind, Partitioning, RunConfig};
use crate::coordinator::fault::{frame_round, Fault, FaultChannel, FaultPlan};
use crate::coordinator::message::{TAG_COLSTEP, TAG_QUANT, TAG_STEP};
use crate::coordinator::scenario::{Column, Row, Scenario};
use crate::coordinator::session::{IterSnapshot, RunReport, Session};
use crate::coordinator::transport::{
    tcp_connect_mux, Channel, Endpoint, MuxFusionLink, MuxWorkerLink,
    RecvStatus, Side, TcpFusionListener, TcpTimeouts,
};
use crate::coordinator::worker::{Served, WorkerParams, WorkerSession};
use crate::engine::{ColumnWorkerData, ComputeEngine, RowBatchData, RustEngine};
use crate::error::{Error, Result};
use crate::metrics::ByteMeter;
use crate::observe::{RunObserver, StopSet};
use crate::serve::queue::{Admission, JobQueue, Priority};
use crate::serve::wire::{self, ClientSignal, JobConn, Reader};
use crate::signal::{Batch, ProblemDims};
use crate::telemetry::{metrics as tel_metrics, JobState, Telemetry};
use crate::util::rng::Rng;

/// Ring capacity of the per-job [`Telemetry`] handle attached to served
/// sessions: enough for every span of a long run's recent rounds while
/// keeping the per-job footprint small. Attaching it keeps the
/// process-wide per-stage latency histograms warm under serving load;
/// telemetry is measurement-only, so reports stay bit-identical.
const JOB_TELEMETRY_CAPACITY: usize = 4096;

/// Mirror the admission queue into the registry's gauges (called under
/// the queue lock, so a scrape never sees a half-applied transition).
fn sync_queue_gauges(q: &JobQueue) {
    let reg = tel_metrics();
    reg.jobs_running.set(q.running() as u64);
    reg.jobs_queued.set(q.queued() as u64);
}

/// Feed a queue promotion (the return of [`JobQueue::release`] /
/// [`JobQueue::abandon`]) into the per-priority queue-wait histograms.
fn record_promotion(promoted: Option<(u32, Priority, Duration)>) {
    if let Some((_, priority, waited)) = promoted {
        tel_metrics()
            .queue_wait(priority == Priority::High)
            .observe_us(waited.as_micros() as u64);
    }
}

/// Daemon capacity and placement policy.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Job listener address (`"127.0.0.1:0"` picks a free port; read the
    /// bound address back with [`Daemon::addr`]).
    pub listen: String,
    /// Fleet size: every job's `cfg.p` must equal this (shards are
    /// pinned to fleet workers by id for the whole run).
    pub fleet_p: usize,
    /// Max concurrently *running* sessions.
    pub max_sessions: usize,
    /// Max sessions *waiting* beyond that (0 = reject on overload).
    pub max_queue: usize,
    /// Per-job wall-clock deadline, checked after every round; an
    /// over-deadline job stops early and still reports.
    pub deadline: Option<Duration>,
    /// Timeout policy for the fleet links and the job handshake.
    pub timeouts: TcpTimeouts,
    /// Priority aging: a normal-priority job queued at least this long
    /// is promoted to the back of the high band (`None` = strict
    /// two-level priority, the pre-aging behaviour).
    pub priority_age: Option<Duration>,
    /// Deterministic fault plan installed on every fleet worker's link
    /// (kill/delay at the link level, drop/corrupt on the per-session
    /// uplinks). `None` serves faithfully; this is the chaos-testing
    /// hook behind `mpamp serve --fault-plan`.
    pub fault_plan: Option<Arc<FaultPlan>>,
}

impl ServeConfig {
    /// Defaults: 4 concurrent sessions, 16 queued, no deadline, strict
    /// priority, no injected faults.
    pub fn new(listen: &str, fleet_p: usize) -> Self {
        ServeConfig {
            listen: listen.to_string(),
            fleet_p,
            max_sessions: 4,
            max_queue: 16,
            deadline: None,
            timeouts: TcpTimeouts::default(),
            priority_age: None,
            fault_plan: None,
        }
    }
}

/// Everything a fleet worker needs to serve one session: the scenario's
/// shard + per-round state behind one dispatch point, and the session's
/// pool-aware engine.
enum WorkerEntry {
    Row {
        params: WorkerParams,
        shard: RowBatchData,
        ws: WorkerSession<Row>,
        engine: RustEngine,
    },
    Column {
        params: WorkerParams,
        shard: ColumnWorkerData,
        ws: WorkerSession<Column>,
        engine: RustEngine,
    },
}

impl WorkerEntry {
    fn handle(&mut self, frame: &[u8], ep: &mut Endpoint) -> Result<Served> {
        match self {
            WorkerEntry::Row { params, shard, ws, engine } => {
                ws.handle_frame(params, shard, &*engine, frame, ep)
            }
            WorkerEntry::Column { params, shard, ws, engine } => {
                ws.handle_frame(params, shard, &*engine, frame, ep)
            }
        }
    }
}

/// Hand a session's shard to one fleet worker, ahead of its first frame.
struct FleetRegister {
    session: u32,
    /// The job's meter (shared with the fusion endpoints): metering is
    /// sender-side, so worker sends land here as uplink bits exactly as
    /// they do in a standalone run.
    meter: Arc<ByteMeter>,
    entry: WorkerEntry,
}

/// The fusion side of one fleet worker's connection. `link` is `None`
/// while the worker is down; the fleet acceptor installs the
/// replacement link and bumps `generation`, which tells every session's
/// [`SlotChannel`] on this slot to re-open its route there.
struct FleetSlot {
    link: Mutex<Option<MuxFusionLink>>,
    generation: AtomicU64,
}

/// Everything needed to replay a session's registration to a worker
/// that reconnects mid-run (kept from admission until the job's slot is
/// released).
struct RejoinEntry {
    cfg: RunConfig,
    batch: Arc<Batch>,
    meter: Arc<ByteMeter>,
}

/// Stub channel for a slot whose worker is down at session-open time:
/// every operation reports the dead link — classified as peer loss,
/// which elastic sessions tolerate — until a refresh swaps in a live
/// route.
struct ClosedChannel;

impl Channel for ClosedChannel {
    fn send_bytes(&mut self, _buf: &[u8]) -> Result<()> {
        Err(Error::Transport("mux link closed (worker down)".into()))
    }
    fn recv_bytes_into(&mut self, _buf: &mut Vec<u8>) -> Result<()> {
        Err(Error::Transport("mux link closed (worker down)".into()))
    }
}

/// A per-session fusion channel that follows its [`FleetSlot`] across
/// worker reconnects: a send or deadline-bounded receive that fails
/// with peer loss re-opens the session's route on the slot's current
/// link (if a replacement arrived) and retries once.
struct SlotChannel {
    session: u32,
    slot: Arc<FleetSlot>,
    gen: u64,
    inner: Box<dyn Channel>,
}

impl SlotChannel {
    /// Swap `inner` onto the slot's current link if one arrived since
    /// this channel last looked.
    fn refresh(&mut self) -> bool {
        let cur = self.slot.generation.load(Ordering::SeqCst);
        if cur == self.gen {
            return false;
        }
        let guard = self.slot.link.lock().expect("fleet slot poisoned");
        let Some(link) = guard.as_ref() else { return false };
        self.inner = link.open_session_channel(self.session);
        self.gen = cur;
        true
    }
}

impl Channel for SlotChannel {
    fn send_bytes(&mut self, buf: &[u8]) -> Result<()> {
        match self.inner.send_bytes(buf) {
            Err(e) if e.is_peer_loss() && self.refresh() => {
                self.inner.send_bytes(buf)
            }
            other => other,
        }
    }
    fn recv_bytes_into(&mut self, buf: &mut Vec<u8>) -> Result<()> {
        // Blocking receives never retry: the retried wait could block
        // forever on a worker that missed the round's broadcast. The
        // deadline path below is the elastic one.
        self.inner.recv_bytes_into(buf)
    }
    fn recv_bytes_into_by(
        &mut self,
        buf: &mut Vec<u8>,
        timeout: Duration,
    ) -> Result<RecvStatus> {
        match self.inner.recv_bytes_into_by(buf, timeout) {
            Err(e) if e.is_peer_loss() && self.refresh() => {
                self.inner.recv_bytes_into_by(buf, timeout)
            }
            other => other,
        }
    }
}

/// Open session `sid`'s fusion endpoint on one fleet slot. Never fails:
/// a slot whose worker is down gets a [`ClosedChannel`] (peer-loss
/// errors an elastic session degrades over instead of aborting), and a
/// later round picks the worker back up through the slot generation.
fn open_slot_endpoint(
    slot: &Arc<FleetSlot>,
    sid: u32,
    meter: Arc<ByteMeter>,
) -> Endpoint {
    let gen = slot.generation.load(Ordering::SeqCst);
    let inner: Box<dyn Channel> = {
        let guard = slot.link.lock().expect("fleet slot poisoned");
        match guard.as_ref() {
            Some(link) => link.open_session_channel(sid),
            None => Box::new(ClosedChannel),
        }
    };
    Endpoint::new(
        Box::new(SlotChannel { session: sid, slot: slot.clone(), gen, inner }),
        meter,
        Side::Fusion,
    )
}

/// State shared between the acceptors, the job threads, and shutdown.
struct DaemonShared {
    cfg: ServeConfig,
    /// Per-worker fleet slots, in worker-id order. Links are taken (and
    /// dropped) on shutdown, which EOFs the fleet.
    slots: Vec<Arc<FleetSlot>>,
    /// Per-worker registration channels (`Mutex` keeps the `Sender`
    /// shareable across job threads on any toolchain).
    ctrls: Vec<Mutex<Sender<FleetRegister>>>,
    /// In-flight sessions, for registration replay to rejoined workers.
    rejoin: Mutex<HashMap<u32, RejoinEntry>>,
    queue: Mutex<JobQueue>,
    queue_cv: Condvar,
    next_session: AtomicU32,
    /// Shared with the fleet threads directly (they outlive individual
    /// links, so they check it between reconnect attempts).
    shutdown: Arc<AtomicBool>,
    /// Graceful-drain mode: new submissions bounce, admitted jobs run to
    /// completion. Set by [`Daemon::begin_drain`] (the CLI's SIGTERM /
    /// SIGINT path).
    draining: AtomicBool,
}

/// A running serving daemon. Dropping it shuts the fleet down and joins
/// every fleet thread (jobs mid-flight fail over to error frames).
pub struct Daemon {
    addr: SocketAddr,
    shared: Arc<DaemonShared>,
    acceptor: Option<JoinHandle<()>>,
    fleet_acceptor: Option<JoinHandle<()>>,
    fleet: Vec<JoinHandle<Result<()>>>,
}

impl Daemon {
    /// Boot the fleet, bind the job listener, and start accepting.
    pub fn start(cfg: ServeConfig) -> Result<Daemon> {
        if cfg.fleet_p == 0 {
            return Err(Error::Config("fleet_p must be ≥ 1".into()));
        }
        // Fleet: P worker threads connect back over loopback, then the
        // fusion side wraps each connection in a multiplexed link. The
        // threads own their reconnect loops, so they get the fleet
        // address, the fault plan, and the shutdown flag directly.
        let fleet_listener =
            TcpFusionListener::bind_with("127.0.0.1:0", cfg.fleet_p, cfg.timeouts)?;
        let fleet_addr = fleet_listener.addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut ctrls = Vec::with_capacity(cfg.fleet_p);
        let mut fleet = Vec::with_capacity(cfg.fleet_p);
        for id in 0..cfg.fleet_p {
            let (tx, rx) = mpsc::channel::<FleetRegister>();
            ctrls.push(Mutex::new(tx));
            let timeouts = cfg.timeouts;
            let plan = cfg.fault_plan.clone();
            let stop = shutdown.clone();
            fleet.push(
                std::thread::Builder::new()
                    .name(format!("mpampd-worker-{id}"))
                    .spawn(move || {
                        fleet_worker_loop(fleet_addr, rx, id as u32, timeouts, plan, stop)
                    })
                    .map_err(Error::Io)?,
            );
        }
        // Initial fleet accept, one link at a time: unlike the one-shot
        // `accept_all_mux`, this keeps the listener alive afterwards so
        // dead workers can reconnect into their slots.
        let mut pending: Vec<Option<MuxFusionLink>> =
            (0..cfg.fleet_p).map(|_| None).collect();
        let deadline = Instant::now() + cfg.timeouts.accept;
        let mut connected = 0usize;
        while connected < cfg.fleet_p {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Err(Error::Transport(format!(
                    "fleet accept timed out with {connected}/{} workers connected",
                    cfg.fleet_p
                )));
            }
            let wait = left.min(Duration::from_millis(250));
            if let Some((id, link)) = fleet_listener.accept_one_mux(wait)? {
                let slot = &mut pending[id as usize];
                if slot.is_some() {
                    return Err(Error::Protocol(format!(
                        "fleet worker id {id} connected twice during boot"
                    )));
                }
                *slot = Some(link);
                connected += 1;
            }
        }
        let slots: Vec<Arc<FleetSlot>> = pending
            .into_iter()
            .map(|link| {
                Arc::new(FleetSlot {
                    link: Mutex::new(link),
                    generation: AtomicU64::new(0),
                })
            })
            .collect();

        let job_listener = TcpListener::bind(&cfg.listen).map_err(Error::Io)?;
        let addr = job_listener.local_addr().map_err(Error::Io)?;
        let queue = JobQueue::new(cfg.max_sessions, cfg.max_queue);
        let shared = Arc::new(DaemonShared {
            cfg,
            slots,
            ctrls,
            rejoin: Mutex::new(HashMap::new()),
            queue: Mutex::new(queue),
            queue_cv: Condvar::new(),
            next_session: AtomicU32::new(1),
            shutdown,
            draining: AtomicBool::new(false),
        });
        let reacc = shared.clone();
        let fleet_acceptor = std::thread::Builder::new()
            .name("mpampd-fleet-accept".into())
            .spawn(move || fleet_accept_loop(fleet_listener, reacc))
            .map_err(Error::Io)?;
        let acc = shared.clone();
        let acceptor = std::thread::Builder::new()
            .name("mpampd-accept".into())
            .spawn(move || {
                for conn in job_listener.incoming() {
                    if acc.shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let job_shared = acc.clone();
                    // Job threads are detached: each one ends by writing a
                    // terminal frame to its own client.
                    let _ = std::thread::Builder::new()
                        .name("mpampd-job".into())
                        .spawn(move || {
                            let _ = serve_job(job_shared, stream);
                        });
                }
            })
            .map_err(Error::Io)?;
        Ok(Daemon {
            addr,
            shared,
            acceptor: Some(acceptor),
            fleet_acceptor: Some(fleet_acceptor),
            fleet,
        })
    }

    /// The bound job-listener address (what clients connect to).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Sessions currently running / waiting (for logs and smoke checks).
    pub fn load(&self) -> (usize, usize) {
        let q = self.shared.queue.lock().expect("queue poisoned");
        (q.running(), q.queued())
    }

    /// Enter graceful-drain mode: stop admitting new jobs (submissions
    /// are rejected with a "draining" message) while already-admitted
    /// jobs — running *and* queued — finish normally. Poll
    /// [`is_idle`](Self::is_idle) and then [`shutdown`](Self::shutdown)
    /// to exit cleanly; this is the `mpamp serve` SIGTERM/SIGINT path.
    pub fn begin_drain(&self) {
        self.shared.draining.store(true, Ordering::SeqCst);
    }

    /// Whether the drain has been requested via [`begin_drain`](Self::begin_drain).
    pub fn is_draining(&self) -> bool {
        self.shared.draining.load(Ordering::SeqCst)
    }

    /// Whether no job is running or queued (drain complete).
    pub fn is_idle(&self) -> bool {
        let q = self.shared.queue.lock().expect("queue poisoned");
        q.running() == 0 && q.queued() == 0
    }

    /// Stop accepting, EOF the fleet, and join it. Called by `Drop`;
    /// explicit for callers that want shutdown errors surfaced.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop();
        let mut first_err = None;
        for h in self.fleet.drain(..) {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => {
                    first_err = first_err
                        .or_else(|| Some(Error::Transport("fleet worker panicked".into())))
                }
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    fn stop(&mut self) {
        if self.shared.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        // Unblock the acceptor's `incoming()` with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        // Dropping the fusion links EOFs every fleet worker's demux read;
        // the workers then see the shutdown flag and exit instead of
        // reconnecting.
        for slot in &self.shared.slots {
            let link = slot.link.lock().expect("fleet slot poisoned").take();
            drop(link);
        }
        // The fleet acceptor polls with a short timeout, so it notices
        // the flag within one beat; joining it also drops the fleet
        // listener, failing any reconnect attempt still in flight.
        if let Some(h) = self.fleet_acceptor.take() {
            let _ = h.join();
        }
        // Wake queued jobs so they notice shutdown and bail out.
        self.shared.queue_cv.notify_all();
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        self.stop();
        for h in self.fleet.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------- fleet side ----------

/// How one serve pass over a fleet link ended.
enum LinkEnd {
    /// The daemon is shutting down: exit the worker thread.
    Shutdown,
    /// The link died (peer loss, scripted kill): reconnect with backoff.
    Reconnect,
    /// An unrecoverable protocol error: surface it from the thread.
    Fatal(Error),
}

/// Capped exponential backoff with deterministic per-worker jitter,
/// sliced into short sleeps so shutdown interrupts a long wait promptly.
fn backoff_sleep(worker_id: u32, attempt: u32, shutdown: &AtomicBool) {
    let exp = attempt.clamp(1, 8) - 1;
    let base = (10u64 << exp).min(2_000);
    let mut rng = Rng::new(((worker_id as u64) << 32) ^ u64::from(attempt));
    let mut left = base + rng.below(base / 2 + 1);
    while left > 0 && !shutdown.load(Ordering::SeqCst) {
        let slice = left.min(25);
        std::thread::sleep(Duration::from_millis(slice));
        left -= slice;
    }
}

/// The first not-yet-fired `KillConn` fault due for `worker` at `round`.
/// `should_kill`'s `round <= t` match is sticky by design (a severed
/// standalone connection stays severed), but a daemon worker *recovers*
/// — so each scripted kill must fire exactly once or the worker would
/// re-kill itself forever after reconnecting.
fn due_kill(
    plan: &FaultPlan,
    worker: u32,
    round: u32,
    fired: &HashSet<usize>,
) -> Option<usize> {
    plan.faults.iter().enumerate().find_map(|(i, f)| match f {
        Fault::KillConn { worker: w, round: r }
            if *w == worker && *r <= round && !fired.contains(&i) =>
        {
            Some(i)
        }
        _ => None,
    })
}

/// One fleet worker thread: connect (and reconnect, with backoff) to the
/// fusion listener, then serve frames until the link dies or the daemon
/// shuts down.
fn fleet_worker_loop(
    addr: SocketAddr,
    ctrl: Receiver<FleetRegister>,
    worker_id: u32,
    timeouts: TcpTimeouts,
    plan: Option<Arc<FaultPlan>>,
    shutdown: Arc<AtomicBool>,
) -> Result<()> {
    // Kill and delay faults act on the link itself (below); the
    // per-session endpoints get the plan stripped to its frame-level
    // faults (drop/corrupt) so nothing fires twice.
    let frame_plan = plan.as_ref().map(|p| {
        Arc::new(FaultPlan {
            faults: p
                .faults
                .iter()
                .filter(|f| {
                    matches!(f, Fault::DropUplink { .. } | Fault::Corrupt { .. })
                })
                .copied()
                .collect(),
        })
    });
    let mut fired: HashSet<usize> = HashSet::new();
    let mut attempt: u32 = 0;
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        let link = match tcp_connect_mux(addr, worker_id, timeouts) {
            Ok(link) => {
                attempt = 0;
                link
            }
            Err(_) => {
                attempt = attempt.saturating_add(1);
                backoff_sleep(worker_id, attempt, &shutdown);
                continue;
            }
        };
        match serve_link(
            link,
            &ctrl,
            worker_id,
            plan.as_deref(),
            frame_plan.as_ref(),
            &mut fired,
            &shutdown,
        ) {
            LinkEnd::Shutdown => return Ok(()),
            LinkEnd::Reconnect => {
                attempt = attempt.saturating_add(1);
                backoff_sleep(worker_id, attempt, &shutdown);
            }
            LinkEnd::Fatal(e) => return Err(e),
        }
    }
}

/// Serve one fleet link until it ends: demultiplex session frames,
/// look up (or register) each session's state, and serve the frame with
/// the exact same [`WorkerSession`] state machine a standalone worker
/// thread runs.
fn serve_link(
    mut link: MuxWorkerLink,
    ctrl: &Receiver<FleetRegister>,
    worker_id: u32,
    plan: Option<&FaultPlan>,
    frame_plan: Option<&Arc<FaultPlan>>,
    fired: &mut HashSet<usize>,
    shutdown: &AtomicBool,
) -> LinkEnd {
    struct Live {
        entry: WorkerEntry,
        ep: Endpoint,
        synced: bool,
    }
    let mut live: HashMap<u32, Live> = HashMap::new();
    let mut frame: Vec<u8> = Vec::new();
    let role = format!("worker {worker_id}");
    let ended = |shutdown: &AtomicBool| {
        if shutdown.load(Ordering::SeqCst) {
            LinkEnd::Shutdown
        } else {
            LinkEnd::Reconnect
        }
    };
    loop {
        let sid = match link.recv_session_frame(&mut frame) {
            Ok(Some(sid)) => sid,
            // Fusion side dropped the link: shutdown or reconnect.
            Ok(None) => return ended(shutdown),
            Err(e) if e.is_peer_loss() || e.is_timeout() || matches!(e, Error::Io(_)) => {
                return ended(shutdown)
            }
            Err(e) => return LinkEnd::Fatal(e),
        };
        // Scripted link-level faults: stall this round's broadcast, or
        // sever the connection (once per scripted kill).
        if let Some(p) = plan {
            if let Some((tag, t)) = frame_round(&frame) {
                if tag == TAG_STEP || tag == TAG_COLSTEP {
                    let ms = p.delay_ms(worker_id, t);
                    if ms > 0 {
                        std::thread::sleep(Duration::from_millis(ms));
                    }
                }
                if let Some(idx) = due_kill(p, worker_id, t, fired) {
                    fired.insert(idx);
                    let _ = link.kill();
                    return ended(shutdown);
                }
            }
        }
        if !live.contains_key(&sid) {
            // Registrations are enqueued before the job's first frame is
            // sent, so draining here always finds a new session's entry.
            // A replayed registration racing the original is dropped:
            // re-inserting would reset a live session mid-run.
            while let Ok(reg) = ctrl.try_recv() {
                if live.contains_key(&reg.session) {
                    continue;
                }
                let mut ep = link.session_endpoint(reg.session, reg.meter);
                if let Some(fp) = frame_plan.filter(|fp| !fp.is_empty()) {
                    let fp = fp.clone();
                    ep.wrap_channel(move |inner| {
                        Box::new(FaultChannel::new(inner, fp, worker_id))
                    });
                }
                live.insert(
                    reg.session,
                    Live { entry: reg.entry, ep, synced: false },
                );
            }
        }
        let Some(l) = live.get_mut(&sid) else {
            return LinkEnd::Fatal(Error::Protocol(format!(
                "fleet {role}: frame for unregistered session {sid}"
            )));
        };
        // A freshly (re)registered session must open on a broadcast: a
        // stale QuantCmd for a round this replacement never stepped is
        // discarded instead of being fed to the state machine.
        if !l.synced {
            if frame.first() == Some(&TAG_QUANT) {
                continue;
            }
            l.synced = true;
        }
        match l.entry.handle(&frame, &mut l.ep) {
            Ok(Served::Continue) => {}
            Ok(Served::Done) => {
                live.remove(&sid);
            }
            Err(e) => return LinkEnd::Fatal(e.transport_context(sid, &role)),
        }
    }
}

/// Accept fleet reconnects for the daemon's lifetime: replay every
/// in-flight session's registration to the rejoined worker, then
/// install the replacement link and bump the slot generation so the
/// sessions' [`SlotChannel`]s migrate onto it.
fn fleet_accept_loop(listener: TcpFusionListener, shared: Arc<DaemonShared>) {
    while !shared.shutdown.load(Ordering::SeqCst) {
        // `accept_one_mux` validates the hello's worker id < fleet_p.
        let (id, link) =
            match listener.accept_one_mux(Duration::from_millis(250)) {
                Ok(Some(pair)) => pair,
                Ok(None) | Err(_) => continue,
            };
        let idx = id as usize;
        if replay_sessions(&shared, idx).is_err() {
            continue;
        }
        let slot = &shared.slots[idx];
        *slot.link.lock().expect("fleet slot poisoned") = Some(link);
        slot.generation.fetch_add(1, Ordering::SeqCst);
        tel_metrics().workers_reconnected.add(1);
    }
}

/// Queue a fresh registration for every in-flight session onto a
/// rejoined worker's control channel (consumed when the worker first
/// sees an unknown session id on the new link).
fn replay_sessions(shared: &Arc<DaemonShared>, worker: usize) -> Result<()> {
    let rejoin = shared.rejoin.lock().expect("rejoin registry poisoned");
    for (&sid, entry) in rejoin.iter() {
        let we = build_entry(worker, &entry.cfg, &entry.batch)?;
        let reg =
            FleetRegister { session: sid, meter: entry.meter.clone(), entry: we };
        shared.ctrls[worker]
            .lock()
            .expect("fleet control poisoned")
            .send(reg)
            .map_err(|_| {
                Error::Transport(format!("fleet worker {worker} is gone"))
            })?;
    }
    Ok(())
}

/// Rebuild one worker's shard state for a session, for rejoin replay.
/// The shard split is deterministic in the config, so the replacement
/// serves the exact bytes the original would have (workers hold no
/// cross-round state: every round opens with a full broadcast).
fn build_entry(id: usize, cfg: &RunConfig, batch: &Arc<Batch>) -> Result<WorkerEntry> {
    let params = worker_params(id, cfg);
    Ok(match cfg.partitioning {
        Partitioning::Row => {
            let shard = Row::split(batch, cfg.p)?.swap_remove(id);
            let ws = WorkerSession::<Row>::new(&shard, cfg.batch);
            let engine = RustEngine::new_pool_aware(cfg.prior, cfg.threads);
            WorkerEntry::Row { params, shard, ws, engine }
        }
        Partitioning::Column => {
            let shard = Column::split(batch, cfg.p)?.swap_remove(id);
            let ws = WorkerSession::<Column>::new(&shard, cfg.batch);
            let engine = RustEngine::new_pool_aware(cfg.prior, cfg.threads);
            WorkerEntry::Column { params, shard, ws, engine }
        }
    })
}

// ---------- job side ----------

enum JobOutcome {
    Report(RunReport),
    Cancelled(String),
}

/// Streams per-round progress to the job's client and turns client
/// cancels / disconnects / the daemon deadline into an early stop.
/// Also refreshes the job's registry row each round, so a metrics
/// scrape mid-run sees live per-job round counts and uplink bits.
struct ProgressForwarder<'a> {
    conn: &'a mut JobConn,
    sid: u32,
    meter: Arc<ByteMeter>,
    started: Instant,
    deadline: Option<Duration>,
    cancelled: Option<String>,
}

impl RunObserver for ProgressForwarder<'_> {
    fn on_iter(&mut self, snap: &IterSnapshot) {
        let uplink_bits = self.meter.uplink_bits();
        tel_metrics().job_update(self.sid, |j| {
            j.rounds = snap.record.t as u64 + 1;
            j.uplink_bits = uplink_bits;
        });
        if self.cancelled.is_some() {
            return;
        }
        if self
            .conn
            .send(wire::J_ITER, |buf| wire::encode_snapshot(buf, snap))
            .is_err()
        {
            self.cancelled = Some("client disconnected".into());
        }
    }

    fn should_stop(&mut self) -> Option<String> {
        if let Some(why) = &self.cancelled {
            return Some(why.clone());
        }
        if let Some(d) = self.deadline {
            if self.started.elapsed() > d {
                return Some(format!(
                    "job deadline exceeded ({:.1}s)",
                    d.as_secs_f64()
                ));
            }
        }
        match self.conn.poll_client() {
            Some(ClientSignal::Cancel) => {
                self.cancelled = Some("cancelled by client".into());
                self.cancelled.clone()
            }
            Some(ClientSignal::Gone) => {
                self.cancelled = Some("client disconnected".into());
                self.cancelled.clone()
            }
            None => None,
        }
    }
}

/// A job's config must fit the fleet it will run on.
fn validate_job(cfg: &RunConfig, serve: &ServeConfig) -> Result<()> {
    cfg.validate()?;
    if cfg.p != serve.fleet_p {
        return Err(Error::Config(format!(
            "job wants P={} workers but this daemon's fleet has {}",
            cfg.p, serve.fleet_p
        )));
    }
    if cfg.engine != EngineKind::Rust {
        return Err(Error::Config(
            "served jobs require engine = \"rust\" (the fleet shares the \
             process-wide compute pool)"
                .into(),
        ));
    }
    Ok(())
}

/// Drive one job connection end to end. Every failure path ends with a
/// terminal frame to the client (best-effort) before returning.
fn serve_job(shared: Arc<DaemonShared>, stream: TcpStream) -> Result<()> {
    let mut conn = JobConn::server(stream, shared.cfg.timeouts.accept)?;
    // Submit.
    let (cfg, priority) = match recv_submit(&mut conn) {
        Ok(sub) => sub,
        Err(e) => {
            let _ = conn.send_error(&e.to_string());
            return Err(e);
        }
    };
    conn.set_blocking()?;
    if let Err(e) = validate_job(&cfg, &shared.cfg) {
        let _ = conn.send_error(&e.to_string());
        return Err(e);
    }
    // A draining daemon finishes what it admitted but takes nothing new.
    if shared.draining.load(Ordering::SeqCst) {
        let msg = "daemon is draining; not accepting new jobs";
        let _ = conn.send_error(msg);
        return Ok(());
    }
    let sid = shared.next_session.fetch_add(1, Ordering::Relaxed);
    let reg = tel_metrics();
    // Admission.
    let admission = {
        let mut q = shared.queue.lock().expect("queue poisoned");
        let admission = q.admit(sid, priority);
        sync_queue_gauges(&q);
        admission
    };
    match admission {
        Admission::Reject => {
            reg.jobs_rejected.add(1);
            let q = shared.queue.lock().expect("queue poisoned");
            let msg = format!(
                "daemon at capacity: {} running, {} queued (max {} + {})",
                q.running(),
                q.queued(),
                shared.cfg.max_sessions,
                shared.cfg.max_queue
            );
            drop(q);
            let _ = conn.send_error(&msg);
            return Ok(());
        }
        Admission::Run => {
            reg.job_insert(sid, priority == Priority::High, JobState::Running);
            // An unreachable client must not leak its admitted slot.
            if let Err(e) = send_accepted(&mut conn, sid, 0) {
                let mut q = shared.queue.lock().expect("queue poisoned");
                record_promotion(q.release());
                sync_queue_gauges(&q);
                drop(q);
                shared.queue_cv.notify_all();
                reg.job_update(sid, |j| j.state = JobState::Cancelled);
                reg.jobs_cancelled.add(1);
                return Err(e);
            }
        }
        Admission::Queued(pos) => {
            reg.job_insert(sid, priority == Priority::High, JobState::Queued);
            if let Err(e) = send_accepted(&mut conn, sid, pos as u32) {
                abandon_queued(&shared, sid);
                reg.jobs_cancelled.add(1);
                return Err(e);
            }
            if !wait_for_slot(&shared, &mut conn, sid)? {
                return Ok(()); // cancelled / disconnected while queued
            }
            reg.job_update(sid, |j| j.state = JobState::Running);
        }
    }
    // From here this thread owns a running slot: release it on all paths.
    let outcome = run_job(&shared, &mut conn, sid, &cfg);
    shared.rejoin.lock().expect("rejoin registry poisoned").remove(&sid);
    {
        let mut q = shared.queue.lock().expect("queue poisoned");
        record_promotion(q.release());
        sync_queue_gauges(&q);
    }
    shared.queue_cv.notify_all();
    match outcome {
        Ok(JobOutcome::Report(report)) => {
            reg.job_update(sid, |j| j.state = JobState::Done);
            reg.jobs_completed.add(1);
            conn.send(wire::J_REPORT, |buf| wire::encode_report(buf, &report))
        }
        Ok(JobOutcome::Cancelled(_)) => {
            reg.job_update(sid, |j| j.state = JobState::Cancelled);
            reg.jobs_cancelled.add(1);
            conn.send_empty(wire::J_CANCELLED)
        }
        Err(e) => {
            reg.job_update(sid, |j| j.state = JobState::Failed);
            reg.jobs_failed.add(1);
            let tagged = e.transport_context(sid, "fusion");
            let _ = conn.send_error(&tagged.to_string());
            Err(tagged)
        }
    }
}

/// Drop a still-queued (or just-promoted) session from the queue, mirror
/// the gauges, mark its registry row cancelled, and wake the waiters.
fn abandon_queued(shared: &DaemonShared, sid: u32) {
    {
        let mut q = shared.queue.lock().expect("queue poisoned");
        record_promotion(q.abandon(sid));
        sync_queue_gauges(&q);
    }
    shared.queue_cv.notify_all();
    tel_metrics().job_update(sid, |j| j.state = JobState::Cancelled);
}

fn recv_submit(conn: &mut JobConn) -> Result<(RunConfig, Priority)> {
    let (kind, payload) = conn.recv()?;
    if kind != wire::J_SUBMIT {
        return Err(Error::Protocol(format!(
            "expected a submit frame, got kind {kind}"
        )));
    }
    let mut r = Reader::new(payload);
    let table = wire::decode_table(&mut r)?;
    let priority = Priority::from_wire(r.u8()?).ok_or_else(|| {
        Error::Protocol("unknown job priority byte in submit frame".into())
    })?;
    r.finish()?;
    Ok((RunConfig::from_table(&table)?, priority))
}

fn send_accepted(conn: &mut JobConn, sid: u32, pos: u32) -> Result<()> {
    conn.send(wire::J_ACCEPTED, |buf| {
        wire::push_u32(buf, sid);
        wire::push_u32(buf, pos);
    })
}

/// Park a queued job until its slot frees. Returns `false` when the job
/// left the queue without running (client cancel/disconnect, shutdown).
fn wait_for_slot(
    shared: &DaemonShared,
    conn: &mut JobConn,
    sid: u32,
) -> Result<bool> {
    loop {
        {
            let mut q = shared.queue.lock().expect("queue poisoned");
            // Priority aging: starved normal jobs move to the high band.
            // Every queued job's wait loop runs this, so aging advances
            // even when no job finishes; `promote_aged` only counts
            // actual moves, so concurrent pollers cannot double-count.
            if let Some(age) = shared.cfg.priority_age {
                let moved = q.promote_aged(age);
                if moved > 0 {
                    tel_metrics().jobs_requeued.add(moved as u64);
                }
            }
            if q.claim(sid) {
                return Ok(true);
            }
            let (mut q, _timeout) = shared
                .queue_cv
                .wait_timeout(q, Duration::from_millis(25))
                .expect("queue poisoned");
            if q.claim(sid) {
                return Ok(true);
            }
        }
        // Lock released: poll the client socket between waits.
        match conn.poll_client() {
            Some(signal) => {
                abandon_queued(shared, sid);
                tel_metrics().jobs_cancelled.add(1);
                if signal == ClientSignal::Cancel {
                    let _ = conn.send_empty(wire::J_CANCELLED);
                }
                return Ok(false);
            }
            None => {}
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            {
                let mut q = shared.queue.lock().expect("queue poisoned");
                record_promotion(q.abandon(sid));
                sync_queue_gauges(&q);
            }
            let reg = tel_metrics();
            reg.job_update(sid, |j| j.state = JobState::Failed);
            reg.jobs_failed.add(1);
            let _ = conn.send_error("daemon is shutting down");
            return Ok(false);
        }
    }
}

/// Run an admitted job: regenerate the problem from the config's seed
/// (bit-identical to `Session::new`), register per-worker shards with
/// the fleet, open the session's fusion-side mux endpoints, and drive a
/// plain [`Session`] with progress forwarding.
fn run_job(
    shared: &DaemonShared,
    conn: &mut JobConn,
    sid: u32,
    cfg: &RunConfig,
) -> Result<JobOutcome> {
    conn.send_empty(wire::J_STARTED)?;
    let mut rng = Rng::new(cfg.seed);
    let batch = Arc::new(Batch::generate(
        cfg.prior,
        ProblemDims { n: cfg.n, m: cfg.m, sigma_e2: cfg.sigma_e2() },
        &mut rng,
        cfg.batch,
    )?);
    let job_meter = Arc::new(ByteMeter::new());
    register_fleet(shared, sid, cfg, &batch, &job_meter)?;
    // Record the session for rejoin replay: a worker reconnecting
    // mid-run gets this registration replayed and resumes at its next
    // round boundary. Removed by `serve_job` when the slot is released.
    shared.rejoin.lock().expect("rejoin registry poisoned").insert(
        sid,
        RejoinEntry {
            cfg: cfg.clone(),
            batch: batch.clone(),
            meter: job_meter.clone(),
        },
    );
    if shared.shutdown.load(Ordering::SeqCst) {
        return Err(Error::Transport("daemon is shutting down".into()));
    }
    let endpoints: Vec<Endpoint> = shared
        .slots
        .iter()
        .map(|slot| open_slot_endpoint(slot, sid, job_meter.clone()))
        .collect();
    let engine: Arc<dyn ComputeEngine> =
        Arc::new(RustEngine::new_pool_aware(cfg.prior, cfg.threads));
    let mut session = Session::with_external_transport(
        cfg.clone(),
        batch,
        engine,
        job_meter.clone(),
        endpoints,
    )?;
    // Measurement-only: keeps the per-stage latency histograms warm
    // while leaving the report bit-identical to a standalone run.
    session.set_telemetry(Telemetry::with_capacity(JOB_TELEMETRY_CAPACITY));
    let mut forwarder = ProgressForwarder {
        conn,
        sid,
        meter: job_meter,
        started: Instant::now(),
        deadline: shared.cfg.deadline,
        cancelled: None,
    };
    let report = session.run_observed(&mut forwarder, &StopSet::none())?;
    match forwarder.cancelled.take() {
        Some(why) => Ok(JobOutcome::Cancelled(why)),
        None => Ok(JobOutcome::Report(report)),
    }
}

/// Build and ship one session's per-worker state to every fleet worker.
/// Registration precedes the session's first broadcast, so a fleet
/// worker that sees an unknown session id only has to drain its control
/// channel.
fn register_fleet(
    shared: &DaemonShared,
    sid: u32,
    cfg: &RunConfig,
    batch: &Arc<Batch>,
    meter: &Arc<ByteMeter>,
) -> Result<()> {
    let entries: Vec<WorkerEntry> = match cfg.partitioning {
        Partitioning::Row => Row::split(batch, cfg.p)?
            .into_iter()
            .enumerate()
            .map(|(i, shard)| {
                let params = worker_params(i, cfg);
                let ws = WorkerSession::<Row>::new(&shard, cfg.batch);
                let engine = RustEngine::new_pool_aware(cfg.prior, cfg.threads);
                WorkerEntry::Row { params, shard, ws, engine }
            })
            .collect(),
        Partitioning::Column => Column::split(batch, cfg.p)?
            .into_iter()
            .enumerate()
            .map(|(i, shard)| {
                let params = worker_params(i, cfg);
                let ws = WorkerSession::<Column>::new(&shard, cfg.batch);
                let engine = RustEngine::new_pool_aware(cfg.prior, cfg.threads);
                WorkerEntry::Column { params, shard, ws, engine }
            })
            .collect(),
    };
    for (i, entry) in entries.into_iter().enumerate() {
        let reg = FleetRegister { session: sid, meter: meter.clone(), entry };
        shared.ctrls[i]
            .lock()
            .expect("fleet control poisoned")
            .send(reg)
            .map_err(|_| {
                Error::Transport(format!("fleet worker {i} is gone"))
                    .transport_context(sid, "fusion")
            })?;
    }
    Ok(())
}

fn worker_params(id: usize, cfg: &RunConfig) -> WorkerParams {
    WorkerParams {
        id: id as u32,
        p_workers: cfg.p,
        batch: cfg.batch,
        prior: cfg.prior,
    }
}
