//! Admission control for the serving daemon: a bounded running set plus
//! a bounded two-level priority wait queue, as pure data (no locks, no
//! sockets, no globals) so the policy is unit-testable in isolation. The
//! daemon wraps one [`JobQueue`] in a `Mutex`/`Condvar` pair; each job
//! thread admits itself, waits to be promoted if queued, and releases
//! its slot when the run ends.
//!
//! Priority is strict between levels and FIFO within a level: a freed
//! slot always goes to the longest-waiting [`Priority::High`] job, and
//! only when no high job waits to the longest-waiting
//! [`Priority::Normal`] one. Both levels share the one `max_queued`
//! bound — priority buys ordering, not extra capacity.
//!
//! Strict priority can starve the normal band under a steady stream of
//! high submissions, so the queue also supports **aging**
//! ([`promote_aged`](JobQueue::promote_aged)): a normal job that has
//! waited past a configurable threshold is re-queued at the back of the
//! high band (FIFO among the promoted, original enqueue time kept), so
//! every admitted job eventually drains. The daemon calls it from its
//! wait loop with `--priority-age-s`.

use std::collections::{HashSet, VecDeque};
use std::time::{Duration, Instant};

/// Scheduling class of a submitted job. High-priority jobs overtake
/// normal ones in the daemon's wait queue; within a class, first come,
/// first served. Travels on the wire as one byte at the tail of the
/// submit frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Priority {
    /// Drains first: jumps ahead of every waiting normal-priority job
    /// (but never preempts a running one).
    High,
    /// The default class.
    #[default]
    Normal,
}

impl Priority {
    /// Stable lowercase name (CLI value and metric label).
    pub fn as_str(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
        }
    }

    /// Parse a CLI value.
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "high" => Some(Priority::High),
            "normal" => Some(Priority::Normal),
            _ => None,
        }
    }

    /// Wire byte (tail of the submit frame).
    pub(crate) fn to_wire(self) -> u8 {
        match self {
            Priority::Normal => 0,
            Priority::High => 1,
        }
    }

    /// Decode the wire byte.
    pub(crate) fn from_wire(b: u8) -> Option<Priority> {
        match b {
            0 => Some(Priority::Normal),
            1 => Some(Priority::High),
            _ => None,
        }
    }
}

/// Outcome of submitting a job to the queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// A running slot was free: the job runs immediately.
    Run,
    /// All slots busy; the job waits at this 1-based queue position
    /// (its place in the strict high-before-normal drain order at
    /// admission time — later high submissions can push a normal job
    /// back).
    Queued(usize),
    /// Both the running set and the wait queue are full.
    Reject,
}

/// Capacity policy state: who is running, who is waiting at which
/// priority, and who has been promoted out of the queue but not yet
/// noticed.
#[derive(Debug)]
pub struct JobQueue {
    max_running: usize,
    max_queued: usize,
    running: usize,
    /// Waiting high-priority sessions with their enqueue times, oldest
    /// first (aged normal jobs keep their original enqueue time).
    high: VecDeque<(u32, Instant)>,
    /// Waiting normal-priority sessions with their enqueue times,
    /// oldest first.
    normal: VecDeque<(u32, Instant)>,
    /// Sessions moved queue → running by [`release`](JobQueue::release)
    /// whose owning thread has not yet [`claim`](JobQueue::claim)ed the
    /// slot (promotion happens under the releasing thread's lock hold).
    promoted: HashSet<u32>,
}

impl JobQueue {
    /// New queue admitting up to `max_running` concurrent sessions and
    /// holding up to `max_queued` waiting ones (both priority levels
    /// share that bound).
    pub fn new(max_running: usize, max_queued: usize) -> Self {
        JobQueue {
            max_running: max_running.max(1),
            max_queued,
            running: 0,
            high: VecDeque::new(),
            normal: VecDeque::new(),
            promoted: HashSet::new(),
        }
    }

    /// Submit session `id` at `priority`: take a running slot, join the
    /// wait queue, or bounce.
    pub fn admit(&mut self, id: u32, priority: Priority) -> Admission {
        if self.running < self.max_running {
            self.running += 1;
            Admission::Run
        } else if self.queued() < self.max_queued {
            match priority {
                Priority::High => {
                    self.high.push_back((id, Instant::now()));
                    Admission::Queued(self.high.len())
                }
                Priority::Normal => {
                    self.normal.push_back((id, Instant::now()));
                    Admission::Queued(self.high.len() + self.normal.len())
                }
            }
        } else {
            Admission::Reject
        }
    }

    /// Whether session `id` has been promoted into a running slot; the
    /// queued job thread polls this after each condvar wake. Consumes the
    /// promotion — the caller owns the slot from then on.
    pub fn claim(&mut self, id: u32) -> bool {
        self.promoted.remove(&id)
    }

    /// A running session ended: free its slot and promote the
    /// longest-waiting high-priority session, else the longest-waiting
    /// normal one (the promoted session keeps the slot counted as
    /// running until it releases in turn). Returns the promoted
    /// session, the band it drained from, and how long it waited — the
    /// daemon feeds the wait into the per-priority queue-wait
    /// histograms.
    pub fn release(&mut self) -> Option<(u32, Priority, Duration)> {
        debug_assert!(self.running > 0, "release without a running session");
        self.running = self.running.saturating_sub(1);
        let (next, priority, since) = match self.high.pop_front() {
            Some((id, t)) => (id, Priority::High, t),
            None => {
                let (id, t) = self.normal.pop_front()?;
                (id, Priority::Normal, t)
            }
        };
        self.running += 1;
        self.promoted.insert(next);
        Some((next, priority, since.elapsed()))
    }

    /// A *waiting* session gave up (client cancel or disconnect). If it
    /// was promoted between its last poll and now, the slot it silently
    /// held is released onward (the onward promotion, if any, is
    /// returned exactly as from [`release`](JobQueue::release)).
    pub fn abandon(&mut self, id: u32) -> Option<(u32, Priority, Duration)> {
        if let Some(idx) = self.high.iter().position(|&(q, _)| q == id) {
            self.high.remove(idx);
            None
        } else if let Some(idx) = self.normal.iter().position(|&(q, _)| q == id) {
            self.normal.remove(idx);
            None
        } else if self.promoted.remove(&id) {
            self.release()
        } else {
            None
        }
    }

    /// Aging: re-queue every normal-priority waiter that has waited at
    /// least `max_age` to the back of the high band. Aged jobs keep
    /// their original enqueue time and relative order (they form a
    /// prefix of the normal deque, which is FIFO by construction).
    /// Returns how many jobs moved, for the `jobs_requeued_total`
    /// counter.
    pub fn promote_aged(&mut self, max_age: Duration) -> usize {
        let mut moved = 0usize;
        while let Some(&(_, since)) = self.normal.front() {
            if since.elapsed() < max_age {
                break;
            }
            let entry = self.normal.pop_front().expect("front just peeked");
            self.high.push_back(entry);
            moved += 1;
        }
        moved
    }

    /// Sessions currently holding running slots.
    pub fn running(&self) -> usize {
        self.running
    }

    /// Sessions currently waiting (both priority levels).
    pub fn queued(&self) -> usize {
        self.high.len() + self.normal.len()
    }

    /// 1-based wait position of session `id` in the current drain order
    /// (every waiting high job precedes every waiting normal one), if it
    /// is queued.
    pub fn position(&self, id: u32) -> Option<usize> {
        if let Some(i) = self.high.iter().position(|&(q, _)| q == id) {
            return Some(i + 1);
        }
        self.normal
            .iter()
            .position(|&(q, _)| q == id)
            .map(|i| self.high.len() + i + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Normal-priority shorthand keeps the capacity tests readable.
    fn admit_n(q: &mut JobQueue, id: u32) -> Admission {
        q.admit(id, Priority::Normal)
    }

    #[test]
    fn admits_up_to_capacity_then_queues_then_rejects() {
        let mut q = JobQueue::new(2, 1);
        assert_eq!(admit_n(&mut q, 1), Admission::Run);
        assert_eq!(admit_n(&mut q, 2), Admission::Run);
        assert_eq!(admit_n(&mut q, 3), Admission::Queued(1));
        assert_eq!(admit_n(&mut q, 4), Admission::Reject);
        assert_eq!(q.running(), 2);
        assert_eq!(q.queued(), 1);
        assert_eq!(q.position(3), Some(1));
        assert_eq!(q.position(4), None);
    }

    #[test]
    fn release_promotes_fifo() {
        let mut q = JobQueue::new(1, 4);
        assert_eq!(admit_n(&mut q, 10), Admission::Run);
        assert_eq!(admit_n(&mut q, 11), Admission::Queued(1));
        assert_eq!(admit_n(&mut q, 12), Admission::Queued(2));
        let _ = q.release();
        // 11 was promoted and holds the slot even before claiming it.
        assert_eq!(q.running(), 1);
        assert_eq!(q.queued(), 1);
        assert!(!q.claim(12), "12 is still waiting");
        assert!(q.claim(11), "11 owns the freed slot");
        assert!(!q.claim(11), "claim consumes the promotion");
        let _ = q.release();
        assert!(q.claim(12));
        let _ = q.release();
        assert_eq!(q.running(), 0);
    }

    #[test]
    fn high_priority_overtakes_waiting_normal_jobs() {
        let mut q = JobQueue::new(1, 8);
        assert_eq!(admit_n(&mut q, 1), Admission::Run);
        assert_eq!(admit_n(&mut q, 2), Admission::Queued(1));
        assert_eq!(admit_n(&mut q, 3), Admission::Queued(2));
        // A high job arrives last but reports position 1 and pushes the
        // normal waiters back in the drain order.
        assert_eq!(q.admit(4, Priority::High), Admission::Queued(1));
        assert_eq!(q.position(4), Some(1));
        assert_eq!(q.position(2), Some(2));
        assert_eq!(q.position(3), Some(3));
        // FIFO within the high level.
        assert_eq!(q.admit(5, Priority::High), Admission::Queued(2));
        // Drain order: 4, 5 (high, FIFO), then 2, 3 (normal, FIFO).
        let _ = q.release();
        assert!(q.claim(4));
        let _ = q.release();
        assert!(q.claim(5));
        let _ = q.release();
        assert!(q.claim(2));
        let _ = q.release();
        assert!(q.claim(3));
        let _ = q.release();
        assert_eq!(q.running(), 0);
        assert_eq!(q.queued(), 0);
    }

    #[test]
    fn priority_levels_share_one_queue_bound() {
        let mut q = JobQueue::new(1, 2);
        assert_eq!(admit_n(&mut q, 1), Admission::Run);
        assert_eq!(admit_n(&mut q, 2), Admission::Queued(1));
        assert_eq!(q.admit(3, Priority::High), Admission::Queued(1));
        // The queue is full: even a high submission bounces.
        assert_eq!(q.admit(4, Priority::High), Admission::Reject);
        assert_eq!(q.queued(), 2);
    }

    #[test]
    fn abandon_from_queue_and_after_promotion() {
        let mut q = JobQueue::new(1, 4);
        assert_eq!(admit_n(&mut q, 1), Admission::Run);
        assert_eq!(admit_n(&mut q, 2), Admission::Queued(1));
        assert_eq!(admit_n(&mut q, 3), Admission::Queued(2));
        // 2 gives up while still queued: 3 moves forward.
        let _ = q.abandon(2);
        assert_eq!(q.position(3), Some(1));
        // 1 finishes, promoting 3; 3 then gives up *after* promotion —
        // the slot must not leak.
        let _ = q.release();
        let _ = q.abandon(3);
        assert_eq!(q.running(), 0);
        assert_eq!(q.queued(), 0);
        assert_eq!(admit_n(&mut q, 4), Admission::Run);
    }

    #[test]
    fn abandon_removes_a_waiting_high_job() {
        let mut q = JobQueue::new(1, 4);
        assert_eq!(admit_n(&mut q, 1), Admission::Run);
        assert_eq!(q.admit(2, Priority::High), Admission::Queued(1));
        assert_eq!(admit_n(&mut q, 3), Admission::Queued(2));
        let _ = q.abandon(2);
        assert_eq!(q.position(3), Some(1));
        let _ = q.release();
        assert!(q.claim(3));
    }

    #[test]
    fn zero_queue_capacity_rejects_immediately() {
        let mut q = JobQueue::new(1, 0);
        assert_eq!(admit_n(&mut q, 1), Admission::Run);
        assert_eq!(admit_n(&mut q, 2), Admission::Reject);
    }

    #[test]
    fn max_running_floor_is_one() {
        let mut q = JobQueue::new(0, 0);
        assert_eq!(admit_n(&mut q, 1), Admission::Run);
    }

    #[test]
    fn promote_aged_moves_starved_normal_jobs_fifo() {
        let mut q = JobQueue::new(1, 8);
        assert_eq!(admit_n(&mut q, 1), Admission::Run);
        assert_eq!(admit_n(&mut q, 2), Admission::Queued(1));
        assert_eq!(admit_n(&mut q, 3), Admission::Queued(2));
        assert_eq!(q.admit(4, Priority::High), Admission::Queued(1));
        // With a zero threshold every normal waiter ages out at once,
        // landing *behind* the already-waiting high job and keeping
        // their own 2-before-3 FIFO order.
        assert_eq!(q.promote_aged(Duration::ZERO), 2);
        assert_eq!(q.position(4), Some(1));
        assert_eq!(q.position(2), Some(2));
        assert_eq!(q.position(3), Some(3));
        // Nothing left to age; a huge threshold promotes nothing.
        assert_eq!(q.promote_aged(Duration::ZERO), 0);
        assert_eq!(admit_n(&mut q, 5), Admission::Queued(4));
        assert_eq!(q.promote_aged(Duration::from_secs(3600)), 0);
        assert_eq!(q.position(5), Some(4));
        // Promoted jobs drain from (and report) the high band.
        let _ = q.release();
        assert!(q.claim(4));
        let _ = q.release();
        assert!(q.claim(2));
        let (id, pri, _wait) = q.release().expect("3 was next");
        assert_eq!((id, pri), (3, Priority::High));
    }

    #[test]
    fn release_reports_band_and_wait() {
        let mut q = JobQueue::new(1, 4);
        assert_eq!(admit_n(&mut q, 1), Admission::Run);
        assert_eq!(q.admit(2, Priority::High), Admission::Queued(1));
        assert_eq!(admit_n(&mut q, 3), Admission::Queued(2));
        let (id, pri, _wait) = q.release().expect("2 promoted");
        assert_eq!((id, pri), (2, Priority::High));
        assert!(q.claim(2));
        let (id, pri, _wait) = q.release().expect("3 promoted");
        assert_eq!((id, pri), (3, Priority::Normal));
        let _ = q.release();
        assert_eq!((q.running(), q.queued()), (0, 0));
    }

    #[test]
    fn priority_wire_byte_roundtrips() {
        for p in [Priority::High, Priority::Normal] {
            assert_eq!(Priority::from_wire(p.to_wire()), Some(p));
            assert_eq!(Priority::parse(p.as_str()), Some(p));
        }
        assert_eq!(Priority::from_wire(7), None);
        assert_eq!(Priority::parse("urgent"), None);
    }
}
