//! Admission control for the serving daemon: a bounded running set plus
//! a bounded FIFO wait queue, as pure data (no locks, no sockets) so the
//! policy is unit-testable in isolation. The daemon wraps one [`JobQueue`]
//! in a `Mutex`/`Condvar` pair; each job thread admits itself, waits to be
//! promoted if queued, and releases its slot when the run ends.

use std::collections::{HashSet, VecDeque};

/// Outcome of submitting a job to the queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// A running slot was free: the job runs immediately.
    Run,
    /// All slots busy; the job waits at this 1-based queue position.
    Queued(usize),
    /// Both the running set and the wait queue are full.
    Reject,
}

/// Capacity policy state: who is running, who is waiting, and who has
/// been promoted out of the queue but not yet noticed.
#[derive(Debug)]
pub struct JobQueue {
    max_running: usize,
    max_queued: usize,
    running: usize,
    queued: VecDeque<u32>,
    /// Sessions moved queue → running by [`release`](JobQueue::release)
    /// whose owning thread has not yet [`claim`](JobQueue::claim)ed the
    /// slot (promotion happens under the releasing thread's lock hold).
    promoted: HashSet<u32>,
}

impl JobQueue {
    /// New queue admitting up to `max_running` concurrent sessions and
    /// holding up to `max_queued` waiting ones.
    pub fn new(max_running: usize, max_queued: usize) -> Self {
        JobQueue {
            max_running: max_running.max(1),
            max_queued,
            running: 0,
            queued: VecDeque::new(),
            promoted: HashSet::new(),
        }
    }

    /// Submit session `id`: take a running slot, join the wait queue, or
    /// bounce.
    pub fn admit(&mut self, id: u32) -> Admission {
        if self.running < self.max_running {
            self.running += 1;
            Admission::Run
        } else if self.queued.len() < self.max_queued {
            self.queued.push_back(id);
            Admission::Queued(self.queued.len())
        } else {
            Admission::Reject
        }
    }

    /// Whether session `id` has been promoted into a running slot; the
    /// queued job thread polls this after each condvar wake. Consumes the
    /// promotion — the caller owns the slot from then on.
    pub fn claim(&mut self, id: u32) -> bool {
        self.promoted.remove(&id)
    }

    /// A running session ended: free its slot and promote the longest
    /// waiter, if any (the promoted session keeps the slot counted as
    /// running until it releases in turn).
    pub fn release(&mut self) {
        debug_assert!(self.running > 0, "release without a running session");
        self.running = self.running.saturating_sub(1);
        if let Some(next) = self.queued.pop_front() {
            self.running += 1;
            self.promoted.insert(next);
        }
    }

    /// A *waiting* session gave up (client cancel or disconnect). If it
    /// was promoted between its last poll and now, the slot it silently
    /// held is released onward.
    pub fn abandon(&mut self, id: u32) {
        if let Some(idx) = self.queued.iter().position(|&q| q == id) {
            self.queued.remove(idx);
        } else if self.promoted.remove(&id) {
            self.release();
        }
    }

    /// Sessions currently holding running slots.
    pub fn running(&self) -> usize {
        self.running
    }

    /// Sessions currently waiting.
    pub fn queued(&self) -> usize {
        self.queued.len()
    }

    /// 1-based wait position of session `id`, if it is queued.
    pub fn position(&self, id: u32) -> Option<usize> {
        self.queued.iter().position(|&q| q == id).map(|i| i + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_capacity_then_queues_then_rejects() {
        let mut q = JobQueue::new(2, 1);
        assert_eq!(q.admit(1), Admission::Run);
        assert_eq!(q.admit(2), Admission::Run);
        assert_eq!(q.admit(3), Admission::Queued(1));
        assert_eq!(q.admit(4), Admission::Reject);
        assert_eq!(q.running(), 2);
        assert_eq!(q.queued(), 1);
        assert_eq!(q.position(3), Some(1));
        assert_eq!(q.position(4), None);
    }

    #[test]
    fn release_promotes_fifo() {
        let mut q = JobQueue::new(1, 4);
        assert_eq!(q.admit(10), Admission::Run);
        assert_eq!(q.admit(11), Admission::Queued(1));
        assert_eq!(q.admit(12), Admission::Queued(2));
        q.release();
        // 11 was promoted and holds the slot even before claiming it.
        assert_eq!(q.running(), 1);
        assert_eq!(q.queued(), 1);
        assert!(!q.claim(12), "12 is still waiting");
        assert!(q.claim(11), "11 owns the freed slot");
        assert!(!q.claim(11), "claim consumes the promotion");
        q.release();
        assert!(q.claim(12));
        q.release();
        assert_eq!(q.running(), 0);
    }

    #[test]
    fn abandon_from_queue_and_after_promotion() {
        let mut q = JobQueue::new(1, 4);
        assert_eq!(q.admit(1), Admission::Run);
        assert_eq!(q.admit(2), Admission::Queued(1));
        assert_eq!(q.admit(3), Admission::Queued(2));
        // 2 gives up while still queued: 3 moves forward.
        q.abandon(2);
        assert_eq!(q.position(3), Some(1));
        // 1 finishes, promoting 3; 3 then gives up *after* promotion —
        // the slot must not leak.
        q.release();
        q.abandon(3);
        assert_eq!(q.running(), 0);
        assert_eq!(q.queued(), 0);
        assert_eq!(q.admit(4), Admission::Run);
    }

    #[test]
    fn zero_queue_capacity_rejects_immediately() {
        let mut q = JobQueue::new(1, 0);
        assert_eq!(q.admit(1), Admission::Run);
        assert_eq!(q.admit(2), Admission::Reject);
    }

    #[test]
    fn max_running_floor_is_one() {
        let mut q = JobQueue::new(0, 0);
        assert_eq!(q.admit(1), Admission::Run);
    }
}
