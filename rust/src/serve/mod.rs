//! `mpampd`: a multi-session MP-AMP serving daemon.
//!
//! A standalone [`Session`](crate::Session) spins up a worker fleet, runs
//! one recovery, and tears everything down. This module keeps the fleet
//! **resident**: one daemon process owns `P` fleet workers (connected over
//! the protocol-v5 multiplexed TCP links, where every frame carries a
//! session id) and serves many concurrent recovery jobs over them —
//! interleaving different sessions' rounds on the same sockets, sharing
//! the process-wide compute pool via pool-aware chunk sizing, and
//! admission-controlling overload with a bounded run set + FIFO queue.
//!
//! The serving path reuses the standalone protocol code end to end (same
//! [`WorkerSession`](crate::coordinator::worker) state machine, same
//! fusion driver, same seeded problem generation, sender-side byte
//! metering below the mux framing), so a served job's
//! [`RunReport`](crate::RunReport) — per-iteration records, final
//! estimates, and exact bit accounting — is **bit-identical** to running
//! the same config standalone.
//!
//! # Worked example
//!
//! ```no_run
//! use mpamp::config::RunConfig;
//! use mpamp::serve::{Client, Daemon, JobEvent, ServeConfig};
//!
//! // Daemon side (usually `mpamp serve --listen 127.0.0.1:7700`):
//! // a resident fleet of 6 workers, at most 2 jobs running at once.
//! let mut serve_cfg = ServeConfig::new("127.0.0.1:0", 6);
//! serve_cfg.max_sessions = 2;
//! let daemon = Daemon::start(serve_cfg).unwrap();
//! let addr = daemon.addr().to_string();
//!
//! // Client side: submit a job whose P matches the fleet, then stream
//! // per-round progress until the terminal report.
//! let cfg = RunConfig::test_small(0.05); // P = 6
//! let mut job = Client::submit(&addr, &cfg).unwrap();
//! println!("session {} (queue position {})", job.session_id(), job.queue_pos());
//! loop {
//!     match job.next_event().unwrap() {
//!         JobEvent::Started => println!("running"),
//!         JobEvent::Iter(snap) => {
//!             println!("t={} SDR={:.2} dB", snap.t(), snap.sdr_db());
//!         }
//!         JobEvent::Report(report) => {
//!             println!(
//!                 "done: {:.2} dB in {:.2} bits/element",
//!                 report.final_sdr_db(),
//!                 report.total_uplink_bits_per_element()
//!             );
//!             break;
//!         }
//!         JobEvent::Cancelled => break,
//!         JobEvent::Failed(msg) => panic!("daemon error: {msg}"),
//!     }
//! }
//! daemon.shutdown().unwrap();
//! ```
//!
//! # Capacity policy
//!
//! [`ServeConfig::max_sessions`] bounds concurrently *running* jobs;
//! [`ServeConfig::max_queue`] bounds jobs *waiting* beyond that (a full
//! queue rejects, an admitted-but-queued job learns its 1-based position
//! from [`JobHandle::queue_pos`]); [`ServeConfig::deadline`] stops
//! over-long jobs after the current round while still returning their
//! partial report. Cancelling ([`JobHandle::cancel`]) — or just
//! disconnecting — frees the job's slot for the next queued session.
//!
//! The wait queue has two scheduling classes ([`Priority`], the last
//! byte of the submit frame — `mpamp run --connect … --priority high`):
//! a freed slot goes to the longest-waiting high-priority job first,
//! FIFO within each class, one shared `max_queue` bound across both.
//! [`ServeConfig::priority_age`] (`--priority-age-s`) turns on priority
//! aging: normal jobs that have waited past the threshold promote into
//! the high band in arrival order, so the normal class can be delayed
//! but never starved.
//!
//! # Fault tolerance
//!
//! Fleet workers that lose their mux connection are detected, backed
//! off, and re-accepted with their session registrations replayed —
//! elastic jobs (`elastic.min_workers` / `elastic.round_deadline_ms`
//! in the submitted config) ride through the outage on partial
//! fusions. See the [`daemon`] module docs for the reconnect design
//! and [`coordinator::fault`](crate::coordinator::fault) for the
//! deterministic chaos-testing hooks
//! ([`ServeConfig::fault_plan`], `mpamp serve --fault-plan`).
//!
//! # Observability
//!
//! The daemon feeds the process-wide
//! [`telemetry`](crate::telemetry) registry: admission gauges
//! (`jobs_running` / `jobs_queued`), lifecycle counters
//! (rejected/completed/cancelled/failed), and a per-job table whose
//! round counts and uplink bits refresh every round. `mpamp serve
//! --metrics-listen <addr>` exposes all of it over HTTP as Prometheus
//! text (`/metrics`) and a JSON snapshot (`/metrics.json`) via
//! [`telemetry::export::MetricsServer`](crate::telemetry::export::MetricsServer),
//! so a scrape mid-run shows live per-job progress alongside fleet
//! counters. Served jobs also run with a small per-session
//! [`Telemetry`](crate::telemetry::Telemetry) ring attached, keeping
//! the per-stage latency histograms warm — telemetry is
//! measurement-only, so reports stay bit-identical to standalone runs.
//!
//! Client reads carry a default 120 s deadline
//! ([`Client::submit_with`] tunes or disables it), so a daemon that
//! dies mid-run surfaces as a timed-out
//! [`Error::Transport`](crate::Error::Transport) instead of hanging
//! the client forever.

pub mod client;
pub mod daemon;
pub mod queue;
pub(crate) mod wire;

pub use client::{Client, JobEvent, JobHandle};
pub use daemon::{Daemon, ServeConfig};
pub use queue::{Admission, JobQueue, Priority};
