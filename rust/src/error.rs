//! Crate-wide error type (hand-rolled `Display`/`Error` impls — the
//! offline crate set has no `thiserror`).

use std::fmt;

/// Unified error type for the mpamp crate.
#[derive(Debug)]
pub enum Error {
    /// Configuration file / CLI errors.
    Config(String),

    /// Malformed wire messages or framing problems.
    Protocol(String),

    /// Transport-level failures (channel closed, socket error, ...).
    Transport(String),

    /// Entropy-coder failures (corrupt stream, model mismatch, ...).
    Codec(String),

    /// Numerical failures (non-convergence, domain errors, ...).
    Numerical(String),

    /// Missing or malformed AOT artifacts.
    Artifact(String),

    /// Errors surfaced by the XLA/PJRT runtime.
    Xla(String),

    /// I/O errors.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Protocol(m) => write!(f, "protocol error: {m}"),
            Error::Transport(m) => write!(f, "transport error: {m}"),
            Error::Codec(m) => write!(f, "codec error: {m}"),
            Error::Numerical(m) => write!(f, "numerical error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
