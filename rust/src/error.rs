//! Crate-wide error type (hand-rolled `Display`/`Error` impls — the
//! offline crate set has no `thiserror`).

use std::fmt;

/// Unified error type for the mpamp crate.
#[derive(Debug)]
pub enum Error {
    /// Configuration file / CLI errors.
    Config(String),

    /// Malformed wire messages or framing problems.
    Protocol(String),

    /// Transport-level failures (channel closed, socket error, ...).
    Transport(String),

    /// A session degraded below its elastic floor: fewer than
    /// `min_workers` live uplinks remained for a round, so the K-of-P
    /// protocol could not proceed. Carries session/role/round context.
    Degraded(String),

    /// Entropy-coder failures (corrupt stream, model mismatch, ...).
    Codec(String),

    /// Numerical failures (non-convergence, domain errors, ...).
    Numerical(String),

    /// Missing or malformed AOT artifacts.
    Artifact(String),

    /// Errors surfaced by the XLA/PJRT runtime.
    Xla(String),

    /// I/O errors.
    Io(std::io::Error),
}

impl Error {
    /// Tag a [`Error::Transport`] with the serve-session it belongs to and
    /// the peer role that raised it (`"fusion"`, `"worker 3"`, `"client"`),
    /// so a failure on a multiplexed daemon link is attributable from the
    /// log line alone. Non-transport errors pass through unchanged — they
    /// already name their own context.
    pub fn transport_context(self, session: u32, role: &str) -> Error {
        match self {
            Error::Transport(m) => {
                Error::Transport(format!("session {session} ({role}): {m}"))
            }
            Error::Degraded(m) => {
                Error::Degraded(format!("session {session} ({role}): {m}"))
            }
            other => other,
        }
    }

    /// Does this error describe a bounded wait that expired (deadline /
    /// read timeout), as opposed to a peer that actively went away? The
    /// distinction drives the elastic protocol's straggler handling: a
    /// timed-out worker may still answer next round, a lost peer won't.
    pub fn is_timeout(&self) -> bool {
        match self {
            Error::Transport(m) => m.contains("timed out"),
            Error::Io(e) => matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            ),
            _ => false,
        }
    }

    /// Does this error describe a peer that actively disconnected
    /// (hangup, EOF, closed mux link, reset socket)? Peer loss marks a
    /// worker dead until it reconnects; a timeout does not.
    pub fn is_peer_loss(&self) -> bool {
        match self {
            Error::Transport(m) => {
                m.contains("peer hung up")
                    || m.contains("link closed")
                    || m.contains("connection killed")
            }
            Error::Io(e) => matches!(
                e.kind(),
                std::io::ErrorKind::UnexpectedEof
                    | std::io::ErrorKind::ConnectionReset
                    | std::io::ErrorKind::ConnectionAborted
                    | std::io::ErrorKind::BrokenPipe
            ),
            _ => false,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Protocol(m) => write!(f, "protocol error: {m}"),
            Error::Transport(m) => write!(f, "transport error: {m}"),
            Error::Degraded(m) => write!(f, "degraded: {m}"),
            Error::Codec(m) => write!(f, "codec error: {m}"),
            Error::Numerical(m) => write!(f, "numerical error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_context_tags_only_transport_errors() {
        let e = Error::Transport("peer hung up (recv)".into());
        let tagged = e.transport_context(17, "worker 3");
        assert_eq!(
            tagged.to_string(),
            "transport error: session 17 (worker 3): peer hung up (recv)"
        );
        let cfg = Error::Config("bad p".into()).transport_context(17, "fusion");
        assert_eq!(cfg.to_string(), "config error: bad p");
        let deg = Error::Degraded("1 live < min_workers 2 at round 4".into())
            .transport_context(17, "fusion");
        assert_eq!(
            deg.to_string(),
            "degraded: session 17 (fusion): 1 live < min_workers 2 at round 4"
        );
    }

    #[test]
    fn timeout_and_peer_loss_classification() {
        assert!(Error::Transport("tcp read timed out after 50ms (peer silent)".into())
            .is_timeout());
        assert!(!Error::Transport("peer hung up (recv)".into()).is_timeout());
        assert!(Error::Transport("peer hung up (recv)".into()).is_peer_loss());
        assert!(Error::Transport(
            "mux link closed while session 3 awaited a frame".into()
        )
        .is_peer_loss());
        assert!(!Error::Transport("tcp read timed out after 50ms".into()).is_peer_loss());
        assert!(!Error::Config("bad p".into()).is_timeout());
    }
}
