//! Crate-wide error type.

/// Unified error type for the mpamp crate.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Configuration file / CLI errors.
    #[error("config error: {0}")]
    Config(String),

    /// Malformed wire messages or framing problems.
    #[error("protocol error: {0}")]
    Protocol(String),

    /// Transport-level failures (channel closed, socket error, ...).
    #[error("transport error: {0}")]
    Transport(String),

    /// Entropy-coder failures (corrupt stream, model mismatch, ...).
    #[error("codec error: {0}")]
    Codec(String),

    /// Numerical failures (non-convergence, domain errors, ...).
    #[error("numerical error: {0}")]
    Numerical(String),

    /// Missing or malformed AOT artifacts.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Errors surfaced by the XLA/PJRT runtime.
    #[error("xla error: {0}")]
    Xla(String),

    /// I/O errors.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
