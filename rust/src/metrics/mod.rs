//! Metrics and report output: byte counters, per-iteration records,
//! CSV/JSON writers (hand-rolled — no serde in the offline crate set).

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};

/// Thread-safe bit counter used by the transports (uplink/downlink split).
#[derive(Debug, Default)]
pub struct ByteMeter {
    uplink_bits: AtomicU64,
    downlink_bits: AtomicU64,
    uplink_msgs: AtomicU64,
    downlink_msgs: AtomicU64,
}

impl ByteMeter {
    /// New zeroed meter.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record an uplink payload of `bits` bits.
    pub fn add_uplink_bits(&self, bits: u64) {
        self.uplink_bits.fetch_add(bits, Ordering::Relaxed);
        self.uplink_msgs.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a downlink payload of `bits` bits.
    pub fn add_downlink_bits(&self, bits: u64) {
        self.downlink_bits.fetch_add(bits, Ordering::Relaxed);
        self.downlink_msgs.fetch_add(1, Ordering::Relaxed);
    }

    /// Total uplink bits so far.
    pub fn uplink_bits(&self) -> u64 {
        self.uplink_bits.load(Ordering::Relaxed)
    }

    /// Total downlink bits so far.
    pub fn downlink_bits(&self) -> u64 {
        self.downlink_bits.load(Ordering::Relaxed)
    }

    /// Uplink message count.
    pub fn uplink_msgs(&self) -> u64 {
        self.uplink_msgs.load(Ordering::Relaxed)
    }

    /// Downlink message count.
    pub fn downlink_msgs(&self) -> u64 {
        self.downlink_msgs.load(Ordering::Relaxed)
    }
}

/// Record of a single MP-AMP iteration (one row of the run report).
#[derive(Debug, Clone, PartialEq)]
pub struct IterRecord {
    /// Iteration index t (0-based).
    pub t: usize,
    /// Empirical SDR of `x_{t+1}` vs the ground truth, in dB.
    pub sdr_db: f64,
    /// SE-predicted SDR at this iteration (quantization-aware SE).
    pub sdr_pred_db: f64,
    /// Coding rate allocated this iteration (bits/element, analytic).
    pub rate_alloc: f64,
    /// Measured wire rate this iteration (bits/element, actual codec).
    pub rate_wire: f64,
    /// Quantization MSE target σ_Q² used this iteration (0 = uncompressed).
    pub sigma_q2: f64,
    /// Estimated σ²_{t,D} from the residual (‖z‖²/M).
    pub sigma_d2_hat: f64,
    /// Wall-clock seconds spent in this iteration.
    pub wall_s: f64,
}

/// CSV writer for a uniform table of f64/str columns.
#[derive(Debug, Default)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    /// New CSV with the given column names.
    pub fn new(columns: &[&str]) -> Self {
        Csv {
            header: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
        .validate()
    }

    fn validate(self) -> Self {
        debug_assert!(!self.header.is_empty());
        self
    }

    /// Append a row of already-formatted cells.
    pub fn push_raw(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    /// Append a row of f64 cells (formatted with 6 significant digits).
    pub fn push_f64(&mut self, cells: &[f64]) {
        self.push_raw(cells.iter().map(|v| format!("{v:.6}")).collect());
    }

    /// Render to a CSV string.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join(","));
        }
        out
    }

    /// Write to a file, creating parent directories.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.render())
    }
}

/// Minimal JSON value builder (objects/arrays/scalars) for run reports.
#[derive(Debug, Clone)]
pub enum Json {
    /// Null.
    Null,
    /// Boolean.
    Bool(bool),
    /// Number.
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (insertion-ordered).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// New empty object.
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Parse JSON text (the inverse of [`render`](Json::render)). Covers
    /// the full scalar/array/object grammar the crate's own writers emit
    /// — which is what the perf-trajectory gate reads back
    /// (`BENCH_pr.json`, `ci/baselines.json`) — plus standard escapes.
    pub fn parse(text: &str) -> crate::error::Result<Json> {
        let bytes = text.as_bytes();
        let mut p = JsonParser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    /// Object field lookup (`None` for non-objects / absent keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(entries) => {
                entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// As a number (`None` for non-numbers).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// As a string slice (`None` for non-strings).
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As an array slice (`None` for non-arrays).
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// As the entries of an object (`None` for non-objects).
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(entries) => Some(entries),
            _ => None,
        }
    }

    /// Insert into an object (panics on non-objects — builder misuse).
    pub fn set(mut self, key: &str, v: Json) -> Json {
        match &mut self {
            Json::Obj(entries) => entries.push((key.to_string(), v)),
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Serialize.
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.render_into(&mut s);
        s
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(v) => {
                if v.is_finite() {
                    let _ = write!(out, "{v}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(entries) => {
                out.push('{');
                for (i, (k, v)) in entries.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).render_into(out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

/// Recursive-descent JSON reader behind [`Json::parse`].
struct JsonParser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl JsonParser<'_> {
    fn err(&self, msg: &str) -> crate::error::Error {
        crate::error::Error::Config(format!("json (byte {}): {msg}", self.pos))
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.bytes.get(self.pos) {
            match b {
                b' ' | b'\t' | b'\n' | b'\r' => self.pos += 1,
                _ => break,
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> crate::error::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn eat_literal(&mut self, lit: &str) -> bool {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            true
        } else {
            false
        }
    }

    fn value(&mut self) -> crate::error::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') if self.eat_literal("null") => Ok(Json::Null),
            Some(b't') if self.eat_literal("true") => Ok(Json::Bool(true)),
            Some(b'f') if self.eat_literal("false") => Ok(Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => {
                self.pos += 1;
                let mut items = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    items.push(self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Json::Arr(items));
                        }
                        _ => return Err(self.err("expected ',' or ']' in array")),
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                let mut entries = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(entries));
                }
                loop {
                    self.skip_ws();
                    let key = self.string()?;
                    self.skip_ws();
                    self.eat(b':')?;
                    entries.push((key, self.value()?));
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(Json::Obj(entries));
                        }
                        _ => return Err(self.err("expected ',' or '}' in object")),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn number(&mut self) -> crate::error::Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            match c {
                b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9' => self.pos += 1,
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("non-UTF-8 number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("malformed number '{text}'")))
    }

    fn string(&mut self) -> crate::error::Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("dangling escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or_else(|| self.err("truncated \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("malformed \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs are not emitted by our writer;
                            // map lone surrogates to the replacement char.
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Copy one UTF-8 scalar (multi-byte sequences intact).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("non-UTF-8 string"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_accumulates() {
        let m = ByteMeter::new();
        m.add_uplink_bits(100);
        m.add_uplink_bits(50);
        m.add_downlink_bits(7);
        assert_eq!(m.uplink_bits(), 150);
        assert_eq!(m.downlink_bits(), 7);
        assert_eq!(m.uplink_msgs(), 2);
        assert_eq!(m.downlink_msgs(), 1);
    }

    #[test]
    fn meter_thread_safe() {
        let m = std::sync::Arc::new(ByteMeter::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.add_uplink_bits(3);
                    }
                });
            }
        });
        assert_eq!(m.uplink_bits(), 8 * 1000 * 3);
    }

    #[test]
    fn csv_renders() {
        let mut c = Csv::new(&["t", "sdr"]);
        c.push_f64(&[0.0, 12.5]);
        c.push_raw(vec!["1".into(), "hello".into()]);
        let s = c.render();
        assert!(s.starts_with("t,sdr\n"));
        assert!(s.contains("0.000000,12.500000"));
        assert!(s.contains("1,hello"));
    }

    #[test]
    fn json_parse_roundtrips_writer_output() {
        let j = Json::obj()
            .set("name", Json::Str("a\"b\\c\nd µ".into()))
            .set("xs", Json::Arr(vec![Json::Num(1.5), Json::Null, Json::Bool(true)]))
            .set("n", Json::Num(-3.25e-2))
            .set("inner", Json::obj().set("k", Json::Str("v".into())));
        let text = j.render();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.render(), text);
        assert_eq!(back.get("name").unwrap().as_str(), Some("a\"b\\c\nd µ"));
        assert_eq!(back.get("xs").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(back.get("n").unwrap().as_f64(), Some(-3.25e-2));
        assert_eq!(
            back.get("inner").unwrap().get("k").unwrap().as_str(),
            Some("v")
        );
    }

    #[test]
    fn json_parse_accepts_whitespace_and_escapes() {
        let j = Json::parse(
            " {\n  \"a\" : [ 1 , 2.0e1 ] ,\n \"s\" : \"x\\u0041\\t\" }\n",
        )
        .unwrap();
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64(), Some(20.0));
        assert_eq!(j.get("s").unwrap().as_str(), Some("xA\t"));
    }

    #[test]
    fn json_parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] tail").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn json_escapes_and_nests() {
        let j = Json::obj()
            .set("name", Json::Str("a\"b\\c\nd".into()))
            .set("xs", Json::Arr(vec![Json::Num(1.0), Json::Null, Json::Bool(true)]))
            .set("nan", Json::Num(f64::NAN));
        let s = j.render();
        assert_eq!(
            s,
            "{\"name\":\"a\\\"b\\\\c\\nd\",\"xs\":[1,null,true],\"nan\":null}"
        );
    }
}
