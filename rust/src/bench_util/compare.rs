//! Perf-trajectory comparison: classify a current `BENCH_pr.json` against
//! stored baselines (`ci/baselines.json`) with per-metric-family noise
//! bands, so CI fails on real regressions instead of only schema-checking.
//!
//! Each metric family carries a direction (is higher better?) and a
//! relative tolerance — the noise band. A current value outside the band
//! on the bad side is a [`Verdict::Regress`]; outside on the good side an
//! [`Verdict::Improve`]; inside, [`Verdict::Pass`]. Records present only
//! in the current run are [`Verdict::New`] (pass — they enter the
//! baseline at the next `--bless`); baseline records that vanished are
//! [`Verdict::Missing`] (fail — a silently dropped bench is how
//! trajectories rot). `mpamp lab gate` turns a [`Comparison`] into a
//! markdown delta table and an exit code; `--bless` rewrites the store.

use std::collections::BTreeMap;

use crate::bench_util::{record_to_json, BenchRecord};
use crate::error::{Error, Result};
use crate::metrics::Json;

/// Whether larger values of a metric are better.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricDir {
    /// Throughput-like: regress when the value falls.
    Higher,
    /// Cost-like: regress when the value rises.
    Lower,
}

/// The metric families the gate tracks, with direction and default
/// relative tolerance. Wall-clock families get wide bands (shared CI
/// runners are noisy); deterministic families (bytes on the wire, SDR per
/// bit) get tight ones.
pub const FAMILIES: &[(&str, MetricDir, f64)] = &[
    ("wall_s", MetricDir::Lower, 0.50),
    ("bytes_uplinked", MetricDir::Lower, 0.02),
    ("signals_per_s", MetricDir::Higher, 0.35),
    ("sdr_per_bit", MetricDir::Higher, 0.05),
    ("rounds_per_s", MetricDir::Higher, 0.35),
    ("gflops", MetricDir::Higher, 0.35),
    ("jobs_per_s", MetricDir::Higher, 0.50),
];

fn family(metric: &str) -> Option<(MetricDir, f64)> {
    FAMILIES
        .iter()
        .find(|(name, _, _)| *name == metric)
        .map(|(_, dir, tol)| (*dir, *tol))
}

/// Classification of one metric or one record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Within the noise band.
    Pass,
    /// Outside the band on the good side.
    Improve,
    /// Outside the band on the bad side (fails the gate).
    Regress,
    /// Present only in the current run (passes; blessed in next baseline).
    New,
    /// Present only in the baseline (fails the gate).
    Missing,
}

impl Verdict {
    /// Stable label for tables and JSON.
    pub fn as_str(&self) -> &'static str {
        match self {
            Verdict::Pass => "pass",
            Verdict::Improve => "improve",
            Verdict::Regress => "regress",
            Verdict::New => "new",
            Verdict::Missing => "missing",
        }
    }

    /// Whether this verdict fails the gate.
    pub fn fails(&self) -> bool {
        matches!(self, Verdict::Regress | Verdict::Missing)
    }
}

/// One metric of one record, classified.
#[derive(Debug, Clone)]
pub struct MetricDelta {
    /// Metric family name.
    pub metric: &'static str,
    /// Baseline value (`None` if the baseline record lacks it).
    pub base: Option<f64>,
    /// Current value (`None` if it vanished from the current record).
    pub current: Option<f64>,
    /// Signed relative change `(current - base) / |base|` when both sides
    /// exist and the base is nonzero.
    pub rel: Option<f64>,
    /// The noise band applied (relative).
    pub tol: f64,
    /// Classification.
    pub verdict: Verdict,
}

/// One record, classified across its metrics.
#[derive(Debug, Clone)]
pub struct RecordDelta {
    /// Record name.
    pub name: String,
    /// Worst metric verdict ([`Verdict::New`]/[`Verdict::Missing`] for
    /// unmatched records).
    pub verdict: Verdict,
    /// Per-metric classification (empty for unmatched records).
    pub metrics: Vec<MetricDelta>,
}

/// Result of comparing a current record set against a baseline store.
#[derive(Debug, Clone)]
pub struct Comparison {
    /// Every record, baseline order first, then new records.
    pub records: Vec<RecordDelta>,
}

impl Comparison {
    /// Whether the gate passes (no regressions, no missing records).
    pub fn gate_passes(&self) -> bool {
        self.records.iter().all(|r| !r.verdict.fails())
    }

    /// The failing records.
    pub fn failures(&self) -> Vec<&RecordDelta> {
        self.records.iter().filter(|r| r.verdict.fails()).collect()
    }

    /// Render the per-record markdown delta table CI uploads as a step
    /// summary.
    pub fn markdown(&self) -> String {
        let mut out = String::new();
        out.push_str("### Perf gate\n\n");
        let (fails, improves) = self.records.iter().fold((0, 0), |(f, i), r| {
            (
                f + usize::from(r.verdict.fails()),
                i + usize::from(r.verdict == Verdict::Improve),
            )
        });
        if fails == 0 {
            out.push_str(&format!(
                "**PASS** — {} record(s) within their noise bands ({} improved).\n\n",
                self.records.len(),
                improves
            ));
        } else {
            out.push_str(&format!(
                "**FAIL** — {fails} of {} record(s) out of band.\n\n",
                self.records.len()
            ));
        }
        out.push_str("| record | metric | baseline | current | Δ | band | verdict |\n");
        out.push_str("|---|---|---:|---:|---:|---:|---|\n");
        for r in &self.records {
            if r.metrics.is_empty() {
                out.push_str(&format!(
                    "| `{}` | — | — | — | — | — | **{}** |\n",
                    r.name,
                    r.verdict.as_str()
                ));
                continue;
            }
            for m in &r.metrics {
                let fmt = |v: Option<f64>| match v {
                    Some(v) => format!("{v:.4}"),
                    None => "—".into(),
                };
                let rel = match m.rel {
                    Some(rel) => format!("{:+.1}%", rel * 100.0),
                    None => "—".into(),
                };
                let verdict = if m.verdict.fails() || m.verdict == Verdict::Improve {
                    format!("**{}**", m.verdict.as_str())
                } else {
                    m.verdict.as_str().to_string()
                };
                out.push_str(&format!(
                    "| `{}` | {} | {} | {} | {} | ±{:.0}% | {} |\n",
                    r.name,
                    m.metric,
                    fmt(m.base),
                    fmt(m.current),
                    rel,
                    m.tol * 100.0,
                    verdict
                ));
            }
        }
        out
    }
}

/// The `ci/baselines.json` store: named records plus the per-family noise
/// bands in force when they were blessed, so tolerance changes are
/// reviewed like any other diff.
#[derive(Debug, Clone)]
pub struct Baselines {
    /// Free-form provenance note.
    pub note: String,
    /// Effective relative tolerance per metric family.
    pub tolerances: BTreeMap<String, f64>,
    /// The blessed records.
    pub records: Vec<BenchRecord>,
}

impl Baselines {
    /// New store around `records` with the default per-family bands.
    pub fn from_records(note: &str, records: Vec<BenchRecord>) -> Baselines {
        Baselines {
            note: note.to_string(),
            tolerances: FAMILIES
                .iter()
                .map(|(name, _, tol)| (name.to_string(), *tol))
                .collect(),
            records,
        }
    }

    /// The band for a metric: the stored override, else the family
    /// default, else 0 (unknown metrics never gate).
    pub fn tolerance(&self, metric: &str) -> f64 {
        self.tolerances
            .get(metric)
            .copied()
            .or_else(|| family(metric).map(|(_, tol)| tol))
            .unwrap_or(0.0)
    }

    /// Parse from JSON text. A bare record array (the `BENCH_pr.json`
    /// schema) is accepted too — it becomes a store with default bands,
    /// so any bench output can seed a baseline.
    pub fn from_json_text(text: &str) -> Result<Baselines> {
        let json = Json::parse(text)?;
        if json.as_arr().is_some() {
            return Ok(Baselines::from_records(
                "seeded from a bare record array",
                records_from_json(&json)?,
            ));
        }
        let note = json
            .get("note")
            .and_then(|n| n.as_str())
            .unwrap_or_default()
            .to_string();
        let mut tolerances: BTreeMap<String, f64> = FAMILIES
            .iter()
            .map(|(name, _, tol)| (name.to_string(), *tol))
            .collect();
        if let Some(tols) = json.get("tolerances").and_then(|t| t.as_obj()) {
            for (k, v) in tols {
                let tol = v.as_f64().filter(|t| *t >= 0.0).ok_or_else(|| {
                    Error::Config(format!(
                        "baselines: tolerance '{k}' must be a non-negative number"
                    ))
                })?;
                tolerances.insert(k.clone(), tol);
            }
        }
        let records = json
            .get("records")
            .ok_or_else(|| Error::Config("baselines: missing 'records' array".into()))?;
        Ok(Baselines { note, tolerances, records: records_from_json(records)? })
    }

    /// Load from a file.
    pub fn load(path: &str) -> Result<Baselines> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read '{path}': {e}")))?;
        Self::from_json_text(&text).map_err(|e| Error::Config(format!("{path}: {e}")))
    }

    /// Render as the store JSON (one record per line for reviewable
    /// diffs).
    pub fn render(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("\"note\":{},\n", Json::Str(self.note.clone()).render()));
        let tols = Json::Obj(
            self.tolerances
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v)))
                .collect(),
        );
        out.push_str(&format!("\"tolerances\":{},\n", tols.render()));
        out.push_str("\"records\":[\n");
        for (i, r) in self.records.iter().enumerate() {
            out.push_str(&record_to_json(r).render());
            out.push_str(if i + 1 < self.records.len() { ",\n" } else { "\n" });
        }
        out.push_str("]\n}\n");
        out
    }

    /// Write to a file (the `--bless` path).
    pub fn save(&self, path: &str) -> Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).map_err(Error::Io)?;
            }
        }
        std::fs::write(path, self.render()).map_err(Error::Io)
    }
}

/// Parse a JSON array of bench records (the `BENCH_pr.json` schema).
pub fn records_from_json(json: &Json) -> Result<Vec<BenchRecord>> {
    let items = json
        .as_arr()
        .ok_or_else(|| Error::Config("bench records: expected a JSON array".into()))?;
    items.iter().map(record_from_json).collect()
}

fn record_from_json(item: &Json) -> Result<BenchRecord> {
    let name = item
        .get("name")
        .and_then(|n| n.as_str())
        .ok_or_else(|| Error::Config("bench record: missing 'name'".into()))?
        .to_string();
    let req = |key: &str| {
        item.get(key).and_then(|v| v.as_f64()).ok_or_else(|| {
            Error::Config(format!("bench record '{name}': missing number '{key}'"))
        })
    };
    let opt = |key: &str| item.get(key).and_then(|v| v.as_f64());
    Ok(BenchRecord {
        wall_s: req("wall_s")?,
        bytes_uplinked: req("bytes_uplinked")? as u64,
        signals_per_s: req("signals_per_s")?,
        sdr_per_bit: opt("sdr_per_bit"),
        rounds_per_s: opt("rounds_per_s"),
        gflops: opt("gflops"),
        jobs_per_s: opt("jobs_per_s"),
        name,
    })
}

/// All seven metric slots of a record, present or not. `signals_per_s`
/// and `bytes_uplinked` use 0 as their "not applicable" sentinel, which
/// the zero-base rules below treat as absent-on-both-sides.
fn metric_slots(r: &BenchRecord) -> [(&'static str, Option<f64>); 7] {
    [
        ("wall_s", Some(r.wall_s)),
        ("bytes_uplinked", Some(r.bytes_uplinked as f64)),
        ("signals_per_s", Some(r.signals_per_s)),
        ("sdr_per_bit", r.sdr_per_bit),
        ("rounds_per_s", r.rounds_per_s),
        ("gflops", r.gflops),
        ("jobs_per_s", r.jobs_per_s),
    ]
}

fn classify(
    metric: &'static str,
    base: Option<f64>,
    current: Option<f64>,
    tol: f64,
) -> Option<MetricDelta> {
    let (dir, _) = family(metric)?;
    let (b, c) = match (base, current) {
        // Not tracked in the baseline: nothing to gate (it enters at the
        // next bless).
        (None, _) => return None,
        // Tracked in the baseline but vanished from the current run: a
        // lost metric is a regression, not a skip.
        (Some(b), None) => {
            return Some(MetricDelta {
                metric,
                base: Some(b),
                current: None,
                rel: None,
                tol,
                verdict: Verdict::Regress,
            })
        }
        (Some(b), Some(c)) => (b, c),
    };
    let (rel, verdict) = if b == 0.0 {
        if c == 0.0 {
            (None, Verdict::Pass)
        } else {
            // 0 → nonzero: infinitely out of band; good or bad per
            // direction (a microbench growing wire traffic regresses, a
            // zero-throughput slot coming alive improves).
            let v = match dir {
                MetricDir::Higher => Verdict::Improve,
                MetricDir::Lower => Verdict::Regress,
            };
            (None, v)
        }
    } else {
        let rel = (c - b) / b.abs();
        let bad = match dir {
            MetricDir::Higher => rel < -tol,
            MetricDir::Lower => rel > tol,
        };
        let good = match dir {
            MetricDir::Higher => rel > tol,
            MetricDir::Lower => rel < -tol,
        };
        let v = if bad {
            Verdict::Regress
        } else if good {
            Verdict::Improve
        } else {
            Verdict::Pass
        };
        (Some(rel), v)
    };
    Some(MetricDelta { metric, base: Some(b), current: Some(c), rel, tol, verdict })
}

/// Compare current records against the baseline store: baseline records
/// first (matched by name; absent ones [`Verdict::Missing`]), then
/// current-only records as [`Verdict::New`].
pub fn compare(baselines: &Baselines, current: &[BenchRecord]) -> Comparison {
    let mut records = Vec::with_capacity(baselines.records.len());
    for base in &baselines.records {
        let Some(cur) = current.iter().find(|r| r.name == base.name) else {
            records.push(RecordDelta {
                name: base.name.clone(),
                verdict: Verdict::Missing,
                metrics: Vec::new(),
            });
            continue;
        };
        let base_slots = metric_slots(base);
        let cur_slots = metric_slots(cur);
        let mut metrics = Vec::new();
        for ((metric, b), (_, c)) in base_slots.into_iter().zip(cur_slots) {
            if let Some(delta) = classify(metric, b, c, baselines.tolerance(metric)) {
                metrics.push(delta);
            }
        }
        let verdict = if metrics.iter().any(|m| m.verdict.fails()) {
            Verdict::Regress
        } else if metrics.iter().any(|m| m.verdict == Verdict::Improve) {
            Verdict::Improve
        } else {
            Verdict::Pass
        };
        records.push(RecordDelta { name: base.name.clone(), verdict, metrics });
    }
    for cur in current {
        if !baselines.records.iter().any(|b| b.name == cur.name) {
            records.push(RecordDelta {
                name: cur.name.clone(),
                verdict: Verdict::New,
                metrics: Vec::new(),
            });
        }
    }
    Comparison { records }
}

/// Like [`compare`], but for current record sets that intentionally
/// measure a different slice of the trajectory than the blessed set (the
/// scheduled reproduction study vs the per-PR bench suite): baseline
/// records with no counterpart in `current` are *skipped* instead of
/// classified [`Verdict::Missing`]. Records present on both sides still
/// gate normally, and current-only records still classify
/// [`Verdict::New`] — subset mode never loosens a band, it only waives
/// the coverage requirement.
pub fn compare_subset(baselines: &Baselines, current: &[BenchRecord]) -> Comparison {
    let mut cmp = compare(baselines, current);
    cmp.records.retain(|r| r.verdict != Verdict::Missing);
    cmp
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(name: &str, wall_s: f64, bytes: u64, rps: Option<f64>) -> BenchRecord {
        BenchRecord {
            name: name.into(),
            wall_s,
            bytes_uplinked: bytes,
            signals_per_s: 0.0,
            sdr_per_bit: None,
            rounds_per_s: rps,
            gflops: None,
            jobs_per_s: None,
        }
    }

    #[test]
    fn identical_records_pass() {
        let base = Baselines::from_records("t", vec![rec("a", 1.0, 100, Some(5.0))]);
        let cmp = compare(&base, &base.records);
        assert!(cmp.gate_passes());
        assert_eq!(cmp.records[0].verdict, Verdict::Pass);
    }

    #[test]
    fn out_of_band_regressions_fail_per_direction() {
        let base = Baselines::from_records("t", vec![rec("a", 1.0, 100, Some(5.0))]);
        // wall_s up 2x (band ±50%) — cost metric regresses upward.
        let cmp = compare(&base, &[rec("a", 2.0, 100, Some(5.0))]);
        assert!(!cmp.gate_passes());
        let m = &cmp.records[0].metrics[0];
        assert_eq!((m.metric, m.verdict), ("wall_s", Verdict::Regress));
        assert!((m.rel.unwrap() - 1.0).abs() < 1e-12);
        // rounds_per_s down 2x (band ±35%) — throughput regresses downward.
        let cmp = compare(&base, &[rec("a", 1.0, 100, Some(2.5))]);
        assert!(!cmp.gate_passes());
        // bytes up 1% stays inside its ±2% band.
        let cmp = compare(&base, &[rec("a", 1.0, 101, Some(5.0))]);
        assert!(cmp.gate_passes());
        // bytes up 5% does not.
        let cmp = compare(&base, &[rec("a", 1.0, 105, Some(5.0))]);
        assert!(!cmp.gate_passes());
    }

    #[test]
    fn improvements_pass_and_are_flagged() {
        let base = Baselines::from_records("t", vec![rec("a", 1.0, 100, Some(5.0))]);
        let cmp = compare(&base, &[rec("a", 0.3, 100, Some(9.0))]);
        assert!(cmp.gate_passes());
        assert_eq!(cmp.records[0].verdict, Verdict::Improve);
    }

    #[test]
    fn new_passes_missing_fails() {
        let base = Baselines::from_records("t", vec![rec("a", 1.0, 0, None)]);
        let cmp = compare(&base, &[rec("a", 1.0, 0, None), rec("b", 1.0, 0, None)]);
        assert!(cmp.gate_passes());
        assert_eq!(cmp.records[1].verdict, Verdict::New);
        let cmp = compare(&base, &[rec("b", 1.0, 0, None)]);
        assert!(!cmp.gate_passes());
        assert_eq!(cmp.records[0].verdict, Verdict::Missing);
        assert_eq!(cmp.failures().len(), 1);
    }

    #[test]
    fn vanished_metric_regresses_new_metric_waits_for_bless() {
        let base = Baselines::from_records("t", vec![rec("a", 1.0, 0, Some(5.0))]);
        // rounds_per_s vanished from the current record.
        let cmp = compare(&base, &[rec("a", 1.0, 0, None)]);
        assert!(!cmp.gate_passes());
        // The reverse — metric only in current — does not gate.
        let base = Baselines::from_records("t", vec![rec("a", 1.0, 0, None)]);
        let cmp = compare(&base, &[rec("a", 1.0, 0, Some(5.0))]);
        assert!(cmp.gate_passes());
    }

    #[test]
    fn zero_base_rules() {
        // bytes 0 → 4096: cost appearing from nowhere regresses.
        let base = Baselines::from_records("t", vec![rec("a", 1.0, 0, None)]);
        let cmp = compare(&base, &[rec("a", 1.0, 4096, None)]);
        assert!(!cmp.gate_passes());
        // signals_per_s 0 → 5: throughput coming alive improves.
        let mut b = rec("a", 1.0, 0, None);
        let mut c = b.clone();
        c.signals_per_s = 5.0;
        let base = Baselines::from_records("t", vec![b.clone()]);
        let cmp = compare(&base, &[c]);
        assert!(cmp.gate_passes());
        // 0 → 0 passes.
        b.signals_per_s = 0.0;
        let cmp = compare(&base, &[b]);
        assert_eq!(cmp.records[0].verdict, Verdict::Pass);
    }

    #[test]
    fn stored_tolerances_override_defaults() {
        let mut base = Baselines::from_records("t", vec![rec("a", 1.0, 100, None)]);
        base.tolerances.insert("bytes_uplinked".into(), 0.5);
        // +20% bytes would fail the default ±2% band but passes ±50%.
        let cmp = compare(&base, &[rec("a", 1.0, 120, None)]);
        assert!(cmp.gate_passes());
    }

    #[test]
    fn store_roundtrips_and_accepts_bare_arrays() {
        let store = Baselines::from_records(
            "seeded for tests",
            vec![rec("a", 1.0, 100, Some(5.0)), rec("b µ", 0.5, 0, None)],
        );
        let text = store.render();
        let back = Baselines::from_json_text(&text).unwrap();
        assert_eq!(back.note, "seeded for tests");
        assert_eq!(back.records, store.records);
        assert_eq!(back.tolerance("wall_s"), 0.5);
        // A bare BENCH_pr.json array seeds a store with default bands.
        let bare = crate::bench_util::write_bench_records_text(&store.records);
        let seeded = Baselines::from_json_text(&bare).unwrap();
        assert_eq!(seeded.records, store.records);
        assert!(compare(&seeded, &store.records).gate_passes());
        // Garbage fails loudly.
        assert!(Baselines::from_json_text("{}").is_err());
        assert!(Baselines::from_json_text("[{\"name\":\"x\"}]").is_err());
    }

    #[test]
    fn markdown_table_names_every_out_of_band_record() {
        let base = Baselines::from_records(
            "t",
            vec![rec("fast", 1.0, 100, Some(5.0)), rec("gone", 1.0, 0, None)],
        );
        let cmp = compare(&base, &[rec("fast", 3.0, 100, Some(5.0))]);
        let md = cmp.markdown();
        assert!(md.contains("**FAIL**"), "{md}");
        assert!(md.contains("| `fast` | wall_s |"), "{md}");
        assert!(md.contains("+200.0%"), "{md}");
        assert!(md.contains("| `gone` |") && md.contains("**missing**"), "{md}");
        let ok = compare(&base, &compare_pass_set());
        assert!(ok.markdown().contains("**PASS**"), "{}", ok.markdown());
    }

    fn compare_pass_set() -> Vec<BenchRecord> {
        vec![rec("fast", 1.0, 100, Some(5.0)), rec("gone", 1.0, 0, None)]
    }

    #[test]
    fn subset_waives_missing_records_only() {
        let base = Baselines::from_records(
            "t",
            vec![rec("a", 1.0, 100, Some(5.0)), rec("b", 1.0, 0, None)],
        );
        // Current measures only `a`, in band: strict mode fails on the
        // uncovered `b`, subset mode waives it.
        let cur = vec![rec("a", 1.0, 100, Some(5.0))];
        assert!(!compare(&base, &cur).gate_passes());
        let cmp = compare_subset(&base, &cur);
        assert!(cmp.gate_passes());
        assert_eq!(cmp.records.len(), 1);
        // A covered record that regresses still fails in subset mode —
        // the bands themselves never loosen.
        let cmp = compare_subset(&base, &[rec("a", 9.0, 100, Some(5.0))]);
        assert!(!cmp.gate_passes());
        // Current-only records still show up as New.
        let cmp = compare_subset(&base, &[rec("a", 1.0, 100, Some(5.0)), rec("c", 1.0, 0, None)]);
        assert!(cmp.gate_passes());
        assert!(cmp.records.iter().any(|r| r.verdict == Verdict::New));
    }
}
