//! Micro-benchmark harness (the offline crate set has no `criterion`).
//!
//! Provides warmup + timed iterations with robust statistics (median, mean,
//! stddev, min), throughput reporting, and a `black_box` to defeat
//! dead-code elimination. Benches under `benches/` are plain
//! `harness = false` binaries built on this module, so `cargo bench` works
//! end-to-end.

pub mod compare;

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

/// Re-export of `std::hint::black_box` under the criterion-style name.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Result statistics of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Benchmark label.
    pub name: String,
    /// Number of timed samples.
    pub samples: usize,
    /// Median per-iteration time.
    pub median: Duration,
    /// Mean per-iteration time.
    pub mean: Duration,
    /// Standard deviation.
    pub stddev: Duration,
    /// Fastest sample.
    pub min: Duration,
    /// Optional element count for throughput lines.
    pub elements: Option<u64>,
}

impl BenchStats {
    /// Elements/second based on the median, if `elements` was set.
    pub fn throughput(&self) -> Option<f64> {
        self.elements.map(|e| e as f64 / self.median.as_secs_f64())
    }

    /// One human-readable summary row.
    pub fn row(&self) -> String {
        let tput = match self.throughput() {
            Some(t) if t >= 1e9 => format!("  {:>8.2} Gelem/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("  {:>8.2} Melem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:>8.2} Kelem/s", t / 1e3),
            Some(t) => format!("  {t:>8.2} elem/s"),
            None => String::new(),
        };
        format!(
            "{:<44} median {:>12}  mean {:>12}  sd {:>10}  min {:>12}{}",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.stddev),
            fmt_dur(self.min),
            tput
        )
    }
}

/// Format a duration with an appropriate unit.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with criterion-like ergonomics.
pub struct Bencher {
    /// Minimum number of timed samples.
    pub min_samples: usize,
    /// Maximum number of timed samples.
    pub max_samples: usize,
    /// Warmup budget.
    pub warmup: Duration,
    /// Measurement budget.
    pub budget: Duration,
    collected: Vec<BenchStats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            min_samples: 10,
            max_samples: 200,
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            collected: Vec::new(),
        }
    }
}

impl Bencher {
    /// New default bencher.
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick profile for expensive end-to-end benches.
    pub fn quick() -> Self {
        Bencher {
            min_samples: 3,
            max_samples: 10,
            warmup: Duration::from_millis(10),
            budget: Duration::from_millis(1500),
            collected: Vec::new(),
        }
    }

    /// Time `f`, which performs exactly one logical iteration per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchStats {
        self.bench_elements(name, None, &mut f)
    }

    /// Time `f` and report throughput over `elements` per iteration.
    pub fn bench_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        elements: u64,
        mut f: F,
    ) -> BenchStats {
        self.bench_elements(name, Some(elements), &mut f)
    }

    fn bench_elements(
        &mut self,
        name: &str,
        elements: Option<u64>,
        f: &mut dyn FnMut(),
    ) -> BenchStats {
        // Warmup.
        let warm_start = Instant::now();
        while warm_start.elapsed() < self.warmup {
            f();
        }
        // Timed samples.
        let mut samples: Vec<Duration> = Vec::with_capacity(self.min_samples);
        let start = Instant::now();
        while samples.len() < self.min_samples
            || (start.elapsed() < self.budget && samples.len() < self.max_samples)
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        samples.sort_unstable();
        let n = samples.len();
        let median = samples[n / 2];
        let mean_ns = samples.iter().map(|d| d.as_nanos() as f64).sum::<f64>() / n as f64;
        let var_ns = samples
            .iter()
            .map(|d| {
                let x = d.as_nanos() as f64 - mean_ns;
                x * x
            })
            .sum::<f64>()
            / n as f64;
        let stats = BenchStats {
            name: name.to_string(),
            samples: n,
            median,
            mean: Duration::from_nanos(mean_ns as u64),
            stddev: Duration::from_nanos(var_ns.sqrt() as u64),
            min: samples[0],
            elements,
        };
        println!("{}", stats.row());
        self.collected.push(stats.clone());
        stats
    }

    /// All stats collected so far.
    pub fn results(&self) -> &[BenchStats] {
        &self.collected
    }
}

/// Print a section header consistent across bench binaries.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// One machine-readable bench record for the CI perf trajectory
/// (`BENCH_pr.json`): wall seconds, the bytes the benchmarked run
/// uplinked (0 for pure-compute microbenches), and the aggregate
/// signal-instance throughput (0 when not a session run).
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRecord {
    /// Record label.
    pub name: String,
    /// Wall-clock seconds (the median for repeated microbenches).
    pub wall_s: f64,
    /// Uplink bytes moved by the benchmarked run (0 if not applicable).
    pub bytes_uplinked: u64,
    /// Signal instances recovered per second (0 if not applicable).
    pub signals_per_s: f64,
    /// Final SDR (dB) per uplinked bit per signal element — the
    /// compressor-ablation quality metric (`None` for non-session
    /// benches; serialized only when present).
    pub sdr_per_bit: Option<f64>,
    /// Protocol rounds completed per second — the session throughput
    /// metric `benches/throughput.rs` tracks (`None` for non-session
    /// benches; serialized only when present).
    pub rounds_per_s: Option<f64>,
    /// Kernel arithmetic throughput in GFLOP/s (`None` for non-kernel
    /// benches; serialized only when present).
    pub gflops: Option<f64>,
    /// Completed jobs per second for `mpampd` serving benches (`None`
    /// for non-serving benches; serialized only when present).
    pub jobs_per_s: Option<f64>,
}

impl BenchRecord {
    /// Record from microbench stats (no uplink traffic, no signals).
    pub fn from_stats(s: &BenchStats) -> Self {
        BenchRecord {
            name: s.name.clone(),
            wall_s: s.median.as_secs_f64(),
            bytes_uplinked: 0,
            signals_per_s: 0.0,
            sdr_per_bit: None,
            rounds_per_s: None,
            gflops: None,
            jobs_per_s: None,
        }
    }

    /// Record from kernel stats whose `elements` field counted FLOPs:
    /// the throughput lands in [`gflops`](BenchRecord::gflops).
    pub fn from_flops_stats(s: &BenchStats) -> Self {
        let mut r = Self::from_stats(s);
        r.gflops = s.throughput().map(|t| t / 1e9);
        r
    }
}

/// One record as its `BENCH_pr.json` object (optional metrics serialized
/// only when present).
pub fn record_to_json(r: &BenchRecord) -> crate::metrics::Json {
    use crate::metrics::Json;
    let mut obj = Json::obj()
        .set("name", Json::Str(r.name.clone()))
        .set("wall_s", Json::Num(r.wall_s))
        .set("bytes_uplinked", Json::Num(r.bytes_uplinked as f64))
        .set("signals_per_s", Json::Num(r.signals_per_s));
    if let Some(spb) = r.sdr_per_bit {
        obj = obj.set("sdr_per_bit", Json::Num(spb));
    }
    if let Some(rps) = r.rounds_per_s {
        obj = obj.set("rounds_per_s", Json::Num(rps));
    }
    if let Some(gf) = r.gflops {
        obj = obj.set("gflops", Json::Num(gf));
    }
    if let Some(jps) = r.jobs_per_s {
        obj = obj.set("jobs_per_s", Json::Num(jps));
    }
    obj
}

/// Records as the `BENCH_pr.json` array text.
pub fn write_bench_records_text(records: &[BenchRecord]) -> String {
    crate::metrics::Json::Arr(records.iter().map(record_to_json).collect()).render()
}

/// Write records as a JSON array of
/// `{name, wall_s, bytes_uplinked, signals_per_s}` objects — the schema
/// CI's `bench-smoke` job uploads per PR.
pub fn write_bench_json(path: &str, records: &[BenchRecord]) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, write_bench_records_text(records))
}

/// Read a `BENCH_pr.json`-schema record array back (the inverse of
/// [`write_bench_json`]) — what `mpamp lab gate --current` consumes.
pub fn read_bench_json(path: &str) -> crate::error::Result<Vec<BenchRecord>> {
    let text = std::fs::read_to_string(path).map_err(|e| {
        crate::error::Error::Config(format!("cannot read '{path}': {e}"))
    })?;
    let json = crate::metrics::Json::parse(&text)
        .map_err(|e| crate::error::Error::Config(format!("{path}: {e}")))?;
    compare::records_from_json(&json)
        .map_err(|e| crate::error::Error::Config(format!("{path}: {e}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut b = Bencher {
            min_samples: 5,
            max_samples: 8,
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(20),
            collected: Vec::new(),
        };
        let s = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(s.samples >= 5);
        assert!(s.min <= s.median);
        assert!(s.median > Duration::ZERO);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn throughput_reported() {
        let mut b = Bencher::quick();
        let s = b.bench_throughput("noop-1k", 1000, || {
            black_box(());
        });
        assert!(s.throughput().unwrap() > 0.0);
    }

    #[test]
    fn bench_json_records_roundtrip_schema() {
        let records = vec![
            BenchRecord {
                name: "lc step".into(),
                wall_s: 0.0125,
                bytes_uplinked: 0,
                signals_per_s: 0.0,
                sdr_per_bit: None,
                rounds_per_s: None,
                gflops: None,
                jobs_per_s: None,
            },
            BenchRecord {
                name: "e2e row".into(),
                wall_s: 1.5,
                bytes_uplinked: 4096,
                signals_per_s: 5.25,
                sdr_per_bit: Some(0.75),
                rounds_per_s: Some(4.0),
                gflops: Some(1.5),
                jobs_per_s: Some(2.5),
            },
        ];
        let dir = std::env::temp_dir().join("mpamp_bench_json_test");
        let path = dir.join("BENCH_pr.json");
        write_bench_json(path.to_str().unwrap(), &records).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with('[') && text.ends_with(']'), "{text}");
        assert!(text.contains("\"name\":\"lc step\""), "{text}");
        assert!(text.contains("\"wall_s\":0.0125"), "{text}");
        assert!(text.contains("\"bytes_uplinked\":4096"), "{text}");
        assert!(text.contains("\"signals_per_s\":5.25"), "{text}");
        // Optional fields serialized only when present.
        assert!(text.contains("\"sdr_per_bit\":0.75"), "{text}");
        assert_eq!(text.matches("sdr_per_bit").count(), 1, "{text}");
        assert!(text.contains("\"rounds_per_s\":4"), "{text}");
        assert_eq!(text.matches("rounds_per_s").count(), 1, "{text}");
        assert!(text.contains("\"gflops\":1.5"), "{text}");
        assert_eq!(text.matches("gflops").count(), 1, "{text}");
        assert!(text.contains("\"jobs_per_s\":2.5"), "{text}");
        assert_eq!(text.matches("jobs_per_s").count(), 1, "{text}");
        // ...and the reader inverts the writer exactly.
        assert_eq!(read_bench_json(path.to_str().unwrap()).unwrap(), records);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).contains(" s"));
    }
}
