//! Run observation and early stopping for the stepwise session driver.
//!
//! * [`RunObserver`] — per-iteration callbacks streaming [`IterRecord`]s
//!   as a [`Session`] advances (progress tables, CSV capture, adaptive
//!   controllers, ...),
//! * [`StopRule`] / [`StopSet`] — composable convergence criteria (max
//!   iterations, target SDR, SDR stall, uplink byte budget) evaluated
//!   after every step, making early stopping first-class instead of
//!   something every caller hand-rolls.
//!
//! [`Session`]: crate::coordinator::session::Session

use crate::config::RunConfig;
use crate::coordinator::session::{IterSnapshot, RunReport};
use crate::metrics::IterRecord;

/// Callbacks invoked by [`Session::run_observed`] (and anything else
/// driving [`Session::step`] that wants to share instrumentation).
///
/// All methods have empty defaults — implement only what you need.
///
/// [`Session::run_observed`]: crate::coordinator::session::Session::run_observed
/// [`Session::step`]: crate::coordinator::session::Session::step
pub trait RunObserver {
    /// Called once before the first iteration.
    fn on_start(&mut self, _cfg: &RunConfig) {}

    /// Called after every completed iteration.
    fn on_iter(&mut self, _snap: &IterSnapshot) {}

    /// Consulted after every `on_iter`: return `Some(reason)` to end the
    /// run early (the reason lands in [`RunReport::stopped_early`], like
    /// a fired [`StopRule`]). This is the push-style complement to
    /// [`StopSet`] for observers reacting to signals outside the
    /// iteration history — a client cancel frame, a job deadline, an
    /// operator interrupt.
    fn should_stop(&mut self) -> Option<String> {
        None
    }

    /// Called once with the final report (after `Done`/join).
    fn on_finish(&mut self, _report: &RunReport) {}
}

/// The do-nothing observer.
#[derive(Debug, Default, Clone, Copy)]
pub struct NullObserver;

impl RunObserver for NullObserver {}

/// Collects every per-iteration record (e.g. for post-hoc analysis when
/// the caller does not keep the report).
#[derive(Debug, Default)]
pub struct RecordLog {
    /// Records in iteration order.
    pub records: Vec<IterRecord>,
}

impl RecordLog {
    /// New empty log.
    pub fn new() -> Self {
        Self::default()
    }
}

impl RunObserver for RecordLog {
    fn on_iter(&mut self, snap: &IterSnapshot) {
        self.records.push(snap.record.clone());
    }
}

/// Streams a human-readable per-iteration table to stdout (the CLI's
/// `mpamp run` view, now emitted live instead of after the run).
#[derive(Debug, Default, Clone, Copy)]
pub struct TablePrinter {
    header_printed: bool,
}

impl TablePrinter {
    /// New printer (prints its header lazily on the first iteration).
    pub fn new() -> Self {
        Self::default()
    }
}

impl RunObserver for TablePrinter {
    fn on_iter(&mut self, snap: &IterSnapshot) {
        if !self.header_printed {
            println!(
                "{:>3} {:>9} {:>9} {:>11} {:>10} {:>12}",
                "t", "SDR(dB)", "SE(dB)", "alloc(b/el)", "wire(b/el)", "sigma_hat^2"
            );
            self.header_printed = true;
        }
        let r = &snap.record;
        println!(
            "{:>3} {:>9.3} {:>9.3} {:>11.3} {:>10.3} {:>12.6e}",
            r.t, r.sdr_db, r.sdr_pred_db, r.rate_alloc, r.rate_wire, r.sigma_d2_hat
        );
    }
}

/// Adapts a closure into an observer: `fn_observer(|snap| ...)`.
pub struct FnObserver<F: FnMut(&IterSnapshot)> {
    f: F,
}

/// Build a per-iteration closure observer.
pub fn fn_observer<F: FnMut(&IterSnapshot)>(f: F) -> FnObserver<F> {
    FnObserver { f }
}

impl<F: FnMut(&IterSnapshot)> RunObserver for FnObserver<F> {
    fn on_iter(&mut self, snap: &IterSnapshot) {
        (self.f)(snap)
    }
}

/// Fan-out to several observers (borrowed, so callers keep ownership and
/// can inspect each one after the run).
#[derive(Default)]
pub struct MultiObserver<'a> {
    parts: Vec<&'a mut dyn RunObserver>,
}

impl<'a> MultiObserver<'a> {
    /// New empty fan-out.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add an observer (builder-style).
    pub fn with(mut self, obs: &'a mut dyn RunObserver) -> Self {
        self.parts.push(obs);
        self
    }
}

impl RunObserver for MultiObserver<'_> {
    fn on_start(&mut self, cfg: &RunConfig) {
        for p in self.parts.iter_mut() {
            p.on_start(cfg);
        }
    }

    fn on_iter(&mut self, snap: &IterSnapshot) {
        for p in self.parts.iter_mut() {
            p.on_iter(snap);
        }
    }

    fn should_stop(&mut self) -> Option<String> {
        // First stop request wins; later parts are still polled next
        // iteration if the run somehow continues.
        self.parts.iter_mut().find_map(|p| p.should_stop())
    }

    fn on_finish(&mut self, report: &RunReport) {
        for p in self.parts.iter_mut() {
            p.on_finish(report);
        }
    }
}

/// One early-stopping criterion, evaluated on the history of completed
/// iterations after every step.
#[derive(Debug, Clone, PartialEq)]
pub enum StopRule {
    /// Stop after this many iterations (caps `cfg.iters` from below).
    MaxIters(usize),
    /// Stop once the empirical SDR reaches this many dB.
    TargetSdrDb(f64),
    /// Stop when SDR improved by less than `min_delta_db` over the last
    /// `window` iterations (requires `window + 1` completed iterations).
    SdrStall {
        /// Look-back length in iterations (≥ 1).
        window: usize,
        /// Minimum improvement over the window to keep going, in dB.
        min_delta_db: f64,
    },
    /// Stop once the cumulative *measured* uplink spend reaches this many
    /// bits per element of the uplinked message (the paper's headline cost
    /// metric): `f_t^p` (length N) under row partitioning, the partial
    /// residual `u_t^p` (length M) under column partitioning.
    UplinkBudget {
        /// Total budget in bits/element.
        bits_per_element: f64,
    },
}

impl StopRule {
    /// Whether this rule fires on the given iteration history.
    pub fn triggered(&self, history: &[IterRecord]) -> bool {
        match self {
            StopRule::MaxIters(k) => history.len() >= *k,
            StopRule::TargetSdrDb(db) => {
                history.last().is_some_and(|r| r.sdr_db >= *db)
            }
            StopRule::SdrStall { window, min_delta_db } => {
                let w = (*window).max(1);
                if history.len() < w + 1 {
                    return false;
                }
                let now = history[history.len() - 1].sdr_db;
                let then = history[history.len() - 1 - w].sdr_db;
                now - then < *min_delta_db
            }
            StopRule::UplinkBudget { bits_per_element } => {
                history.iter().map(|r| r.rate_wire).sum::<f64>() >= *bits_per_element
            }
        }
    }

    /// Short human-readable description (recorded in the run report).
    pub fn describe(&self) -> String {
        match self {
            StopRule::MaxIters(k) => format!("max iterations ({k})"),
            StopRule::TargetSdrDb(db) => format!("target SDR reached ({db} dB)"),
            StopRule::SdrStall { window, min_delta_db } => {
                format!("SDR stalled (<{min_delta_db} dB over {window} iters)")
            }
            StopRule::UplinkBudget { bits_per_element } => {
                format!("uplink budget spent ({bits_per_element} bits/element)")
            }
        }
    }
}

/// A composable set of stop rules; the run stops when *any* rule fires
/// (an empty set never stops early).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StopSet {
    rules: Vec<StopRule>,
}

impl StopSet {
    /// The empty set (never stops early).
    pub fn none() -> Self {
        Self::default()
    }

    /// Builder-style: add a rule.
    pub fn with(mut self, rule: StopRule) -> Self {
        self.rules.push(rule);
        self
    }

    /// Add a rule in place.
    pub fn push(&mut self, rule: StopRule) {
        self.rules.push(rule);
    }

    /// Whether the set holds no rules.
    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    /// The description of the first rule that fires, if any.
    pub fn triggered(&self, history: &[IterRecord]) -> Option<String> {
        self.rules
            .iter()
            .find(|r| r.triggered(history))
            .map(StopRule::describe)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(t: usize, sdr_db: f64, rate_wire: f64) -> IterRecord {
        IterRecord {
            t,
            sdr_db,
            sdr_pred_db: sdr_db,
            rate_alloc: rate_wire,
            rate_wire,
            sigma_q2: 0.0,
            sigma_d2_hat: 0.1,
            wall_s: 0.0,
        }
    }

    #[test]
    fn max_iters_counts_completed_steps() {
        let rule = StopRule::MaxIters(2);
        assert!(!rule.triggered(&[rec(0, 1.0, 4.0)]));
        assert!(rule.triggered(&[rec(0, 1.0, 4.0), rec(1, 2.0, 4.0)]));
    }

    #[test]
    fn target_sdr_fires_on_last_record() {
        let rule = StopRule::TargetSdrDb(10.0);
        assert!(!rule.triggered(&[rec(0, 9.9, 4.0)]));
        assert!(rule.triggered(&[rec(0, 9.9, 4.0), rec(1, 10.2, 4.0)]));
    }

    #[test]
    fn stall_needs_full_window() {
        let rule = StopRule::SdrStall { window: 2, min_delta_db: 0.1 };
        let h = [rec(0, 5.0, 4.0), rec(1, 5.01, 4.0)];
        assert!(!rule.triggered(&h), "window not yet filled");
        let h = [rec(0, 5.0, 4.0), rec(1, 5.01, 4.0), rec(2, 5.02, 4.0)];
        assert!(rule.triggered(&h), "0.02 dB over 2 iters is a stall");
        let h = [rec(0, 5.0, 4.0), rec(1, 6.0, 4.0), rec(2, 7.0, 4.0)];
        assert!(!rule.triggered(&h));
    }

    #[test]
    fn uplink_budget_sums_wire_rate() {
        let rule = StopRule::UplinkBudget { bits_per_element: 10.0 };
        assert!(!rule.triggered(&[rec(0, 1.0, 6.0)]));
        assert!(rule.triggered(&[rec(0, 1.0, 6.0), rec(1, 2.0, 4.0)]));
    }

    #[test]
    fn stop_set_any_semantics() {
        let set = StopSet::none()
            .with(StopRule::MaxIters(5))
            .with(StopRule::TargetSdrDb(10.0));
        assert!(set.triggered(&[rec(0, 3.0, 4.0)]).is_none());
        let why = set.triggered(&[rec(0, 11.0, 4.0)]).unwrap();
        assert!(why.contains("target SDR"), "{why}");
        assert!(StopSet::none().triggered(&[rec(0, 99.0, 99.0)]).is_none());
    }
}
