//! Closed-form / bound-based RD references: the Gaussian RD function and
//! the Shannon lower bound for the mixture source. Used to validate
//! Blahut–Arimoto and as a fast approximation in ablations.

use crate::se::prior::BgChannel;
use crate::se::quad::integrate_multiscale;

/// Gaussian source `N(0, var)`: `R(D) = max(0, ½ log2(var/D))`.
pub fn gaussian_rate_for_mse(var: f64, d: f64) -> f64 {
    if d >= var {
        0.0
    } else {
        0.5 * (var / d).log2()
    }
}

/// Inverse of the Gaussian RD function: `D(R) = var·2^{−2R}`.
pub fn gaussian_mse_for_rate(var: f64, rate: f64) -> f64 {
    var * 2f64.powf(-2.0 * rate.max(0.0))
}

/// Differential entropy `h(F)` of the scalar-channel marginal in bits
/// (numeric; multiscale grid resolves both mixture scales).
pub fn differential_entropy_bits(channel: &BgChannel, sigma2: f64) -> f64 {
    let p = &channel.prior;
    let scales = [(0.0, sigma2.sqrt()), (p.mu_s, (p.sigma_s2 + sigma2).sqrt())];
    let nats = integrate_multiscale(&scales, 10.0, 0.4, |f| {
        let pf = channel.pdf_f(f, sigma2);
        if pf > 0.0 {
            -pf * pf.ln()
        } else {
            0.0
        }
    });
    nats / std::f64::consts::LN_2
}

/// Shannon lower bound on the mixture RD function:
/// `R(D) ≥ h(F) − ½ log2(2πe D)`.
pub fn shannon_lower_bound(channel: &BgChannel, sigma2: f64, d: f64) -> f64 {
    let h = differential_entropy_bits(channel, sigma2);
    (h - 0.5 * (2.0 * std::f64::consts::PI * std::f64::consts::E * d).log2()).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rd::blahut::rd_curve_for_channel;
    use crate::signal::BernoulliGauss;

    #[test]
    fn gaussian_rd_roundtrip() {
        let var = 2.5;
        for r in [0.5, 1.0, 3.0, 8.0] {
            let d = gaussian_mse_for_rate(var, r);
            assert!((gaussian_rate_for_mse(var, d) - r).abs() < 1e-12);
        }
        assert_eq!(gaussian_rate_for_mse(var, 3.0), 0.0);
    }

    #[test]
    fn entropy_of_pure_gaussian() {
        // h(N(0,σ²)) = ½ log2(2πeσ²).
        let c = BgChannel::new(BernoulliGauss { eps: 1.0, mu_s: 0.0, sigma_s2: 1e-12 });
        let s2 = 0.7;
        let h = differential_entropy_bits(&c, s2);
        let want = 0.5 * (2.0 * std::f64::consts::PI * std::f64::consts::E * s2).log2();
        assert!((h - want).abs() < 1e-6, "h={h} want {want}");
    }

    #[test]
    fn mixture_entropy_below_gaussian_of_same_variance() {
        let c = BgChannel::new(BernoulliGauss::standard(0.05));
        let s2 = 0.01;
        let h = differential_entropy_bits(&c, s2);
        let var = c.var_f(s2);
        let h_gauss = 0.5 * (2.0 * std::f64::consts::PI * std::f64::consts::E * var).log2();
        assert!(h < h_gauss, "mixture h={h} ≥ gaussian {h_gauss}");
    }

    #[test]
    fn slb_lower_bounds_blahut() {
        let c = BgChannel::new(BernoulliGauss::standard(0.1));
        let s2 = 0.05;
        let curve = rd_curve_for_channel(&c, s2, 201, 20, 1e-7).unwrap();
        for d in [1e-4, 1e-3, 1e-2] {
            let slb = shannon_lower_bound(&c, s2, d);
            let ba = curve.rate_for_mse(d);
            assert!(
                ba >= slb - 0.06,
                "BA R({d})={ba} violates SLB {slb}"
            );
        }
    }
}
