//! Rate-distortion substrate: Blahut–Arimoto curves ([`blahut`]),
//! closed-form references ([`gaussian`]), and a γ-parameterized curve cache
//! ([`RdCache`]) exploiting the scalar-channel normalization (DESIGN.md §6).
//!
//! The per-worker source is `F_t^p = S0/P + (σ_t/√P) Z`. Rescaling by
//! `√P/σ_t` gives the one-parameter family
//! `X(γ) ~ ε N(μ̃, 1+γ) + (1−ε) N(0, 1)` with `γ = σ_s²/(P σ_t²)`
//! (μ̃ = μ_s √P/(P σ_t); zero for the paper's priors), and
//! `R_{F}(D) = R_{X}(D · P/σ_t²)`. We therefore compute Blahut–Arimoto
//! curves once per γ grid point and serve every (σ_t², rate) query by
//! interpolation — this is what makes the DP allocator tractable.

pub mod blahut;
pub mod gaussian;

pub use blahut::{rd_curve_for_channel, RdCurve};

use crate::config::RdConfig;
use crate::error::{Error, Result};
use crate::se::prior::BgChannel;
use crate::signal::BernoulliGauss;

/// Cache of normalized RD curves over a log-spaced γ grid.
#[derive(Debug, Clone)]
pub struct RdCache {
    /// Sparsity ε of the prior (the cache key).
    pub eps: f64,
    /// σ_s² of the prior.
    pub sigma_s2: f64,
    /// Worker count P.
    pub p_workers: usize,
    gammas: Vec<f64>,
    curves: Vec<RdCurve>,
}

impl RdCache {
    /// Build curves for `γ ∈ [γ_lo, γ_hi]` covering the SE trajectory range
    /// `σ_t² ∈ [sigma2_min, sigma2_max]`.
    pub fn build(
        prior: &BernoulliGauss,
        p_workers: usize,
        sigma2_min: f64,
        sigma2_max: f64,
        cfg: &RdConfig,
    ) -> Result<Self> {
        if prior.mu_s != 0.0 {
            return Err(Error::Numerical(
                "RdCache requires μ_s = 0 (the paper's setting); use \
                 rd_curve_for_channel directly for shifted priors"
                    .into(),
            ));
        }
        if sigma2_min <= 0.0 || sigma2_max < sigma2_min {
            return Err(Error::Numerical(format!(
                "bad sigma2 range [{sigma2_min}, {sigma2_max}]"
            )));
        }
        let pf = p_workers as f64;
        // γ = σ_s²/(P σ²): large σ² → small γ. Pad the range slightly.
        let g_lo = prior.sigma_s2 / (pf * sigma2_max) * 0.5;
        let g_hi = prior.sigma_s2 / (pf * sigma2_min) * 2.0;
        let n = cfg.gamma_grid.max(2);
        let ratio = (g_hi / g_lo).ln() / (n - 1) as f64;
        let mut gammas = Vec::with_capacity(n);
        let mut curves = Vec::with_capacity(n);
        for i in 0..n {
            let gamma = g_lo * (ratio * i as f64).exp();
            gammas.push(gamma);
        }
        // Curves are independent — compute in parallel.
        let eps = prior.eps;
        let results: Vec<Result<RdCurve>> = std::thread::scope(|s| {
            let handles: Vec<_> = gammas
                .iter()
                .map(|&gamma| {
                    s.spawn(move || {
                        let ch = BgChannel::new(BernoulliGauss {
                            eps,
                            mu_s: 0.0,
                            sigma_s2: gamma,
                        });
                        rd_curve_for_channel(&ch, 1.0, cfg.alphabet, cfg.curve_points, cfg.tol)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("BA thread panicked")).collect()
        });
        for r in results {
            curves.push(r?);
        }
        Ok(RdCache {
            eps: prior.eps,
            sigma_s2: prior.sigma_s2,
            p_workers,
            gammas,
            curves,
        })
    }

    /// γ for a given σ_t².
    fn gamma(&self, sigma_t2: f64) -> f64 {
        self.sigma_s2 / (self.p_workers as f64 * sigma_t2)
    }

    /// Normalized↔physical distortion scale: `D_phys = D_norm · σ_t²/P`.
    fn d_scale(&self, sigma_t2: f64) -> f64 {
        sigma_t2 / self.p_workers as f64
    }

    /// Bracketing curve indices + interpolation weight for γ.
    fn locate(&self, gamma: f64) -> (usize, usize, f64) {
        let n = self.gammas.len();
        if gamma <= self.gammas[0] {
            return (0, 0, 0.0);
        }
        if gamma >= self.gammas[n - 1] {
            return (n - 1, n - 1, 0.0);
        }
        let mut lo = 0;
        let mut hi = n - 1;
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if self.gammas[mid] <= gamma {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let t = (gamma.ln() - self.gammas[lo].ln())
            / (self.gammas[hi].ln() - self.gammas[lo].ln());
        (lo, hi, t)
    }

    /// `R(D)` of the iteration-t uplink source (bits/element) for a
    /// per-worker quantization MSE `sigma_q2`.
    pub fn rate_for_mse(&self, sigma_t2: f64, sigma_q2: f64) -> f64 {
        let d_norm = sigma_q2 / self.d_scale(sigma_t2);
        let (lo, hi, t) = self.locate(self.gamma(sigma_t2));
        let r_lo = self.curves[lo].rate_for_mse(d_norm);
        if lo == hi {
            return r_lo;
        }
        let r_hi = self.curves[hi].rate_for_mse(d_norm);
        r_lo + t * (r_hi - r_lo)
    }

    /// Inverse: per-worker quantization MSE achievable at `rate` bits.
    pub fn mse_for_rate(&self, sigma_t2: f64, rate: f64) -> f64 {
        let (lo, hi, t) = self.locate(self.gamma(sigma_t2));
        let d_lo = self.curves[lo].mse_for_rate(rate).ln();
        let d_norm = if lo == hi {
            d_lo.exp()
        } else {
            let d_hi = self.curves[hi].mse_for_rate(rate).ln();
            (d_lo + t * (d_hi - d_lo)).exp()
        };
        d_norm * self.d_scale(sigma_t2)
    }

    /// Number of cached curves.
    pub fn len(&self) -> usize {
        self.curves.len()
    }

    /// Always false post-construction.
    pub fn is_empty(&self) -> bool {
        self.curves.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::se::prior::BgChannel;

    fn small_cfg() -> RdConfig {
        RdConfig { alphabet: 161, curve_points: 16, tol: 1e-6, gamma_grid: 9 }
    }

    #[test]
    fn cache_matches_direct_blahut() {
        let prior = BernoulliGauss::standard(0.05);
        let p = 30;
        let cache = RdCache::build(&prior, p, 1e-3, 0.2, &small_cfg()).unwrap();
        // Pick a σ_t² inside the range and compare vs a directly-computed
        // curve on the *worker* channel.
        let sigma_t2 = 0.02;
        let base = BgChannel::new(prior);
        let (wch, ws2) = base.worker_channel(sigma_t2, p);
        let direct = rd_curve_for_channel(&wch, ws2, 201, 20, 1e-7).unwrap();
        for rate in [1.0, 2.0, 4.0] {
            let d_cache = cache.mse_for_rate(sigma_t2, rate);
            let d_direct = direct.mse_for_rate(rate);
            let ratio = d_cache / d_direct;
            assert!(
                (0.8..1.25).contains(&ratio),
                "rate {rate}: cache D={d_cache}, direct D={d_direct}"
            );
        }
    }

    #[test]
    fn rate_mse_inverse_consistency() {
        let prior = BernoulliGauss::standard(0.1);
        let cache = RdCache::build(&prior, 10, 1e-3, 0.5, &small_cfg()).unwrap();
        for sigma_t2 in [0.002, 0.02, 0.3] {
            for rate in [0.5, 2.0, 5.0] {
                let d = cache.mse_for_rate(sigma_t2, rate);
                let r = cache.rate_for_mse(sigma_t2, d);
                assert!(
                    (r - rate).abs() < 0.08 * (1.0 + rate),
                    "σ²={sigma_t2} rate {rate} → D {d} → rate {r}"
                );
            }
        }
    }

    #[test]
    fn zero_rate_gives_source_variance() {
        let prior = BernoulliGauss::standard(0.05);
        let p = 30;
        let cache = RdCache::build(&prior, p, 1e-3, 0.2, &small_cfg()).unwrap();
        let sigma_t2 = 0.05;
        let d0 = cache.mse_for_rate(sigma_t2, 0.0);
        let base = BgChannel::new(prior);
        let (wch, ws2) = base.worker_channel(sigma_t2, p);
        let var = wch.var_f(ws2);
        assert!((d0 / var - 1.0).abs() < 0.05, "D(0)={d0} vs var={var}");
    }

    #[test]
    fn more_rate_less_distortion() {
        let prior = BernoulliGauss::standard(0.05);
        let cache = RdCache::build(&prior, 30, 1e-3, 0.2, &small_cfg()).unwrap();
        let s2 = 0.01;
        let mut prev = f64::INFINITY;
        for r in [0.0, 0.5, 1.0, 2.0, 4.0, 8.0] {
            let d = cache.mse_for_rate(s2, r);
            assert!(d < prev || r == 0.0, "D not decreasing at rate {r}");
            prev = d;
        }
    }

    #[test]
    fn rejects_shifted_prior() {
        let prior = BernoulliGauss { eps: 0.05, mu_s: 1.0, sigma_s2: 1.0 };
        assert!(RdCache::build(&prior, 30, 1e-3, 0.2, &small_cfg()).is_err());
    }
}
