//! Blahut–Arimoto computation of the rate-distortion function `R(D)` for
//! the (discretized) scalar-channel source under squared-error distortion —
//! the paper's §3.2 "The RD function R(D) can be computed numerically
//! (cf. Blahut and Arimoto)".
//!
//! For a fixed Lagrange slope `s < 0` the BA fixed point yields one point
//! `(D(s), R(s))` on the curve; sweeping `s` traces the whole curve.
//! Per-iteration cost is two matvecs over the precomputed kernel
//! `K_ij = exp(s·d_ij)`.

use crate::error::{Error, Result};
use crate::rd::gaussian::differential_entropy_bits;
use crate::se::prior::BgChannel;

/// A computed rate-distortion curve with monotone interpolation.
///
/// Points are stored sorted by increasing distortion; rates decrease.
#[derive(Debug, Clone)]
pub struct RdCurve {
    /// ln(D) per point (ascending).
    ln_d: Vec<f64>,
    /// Rate in bits per point (descending).
    r: Vec<f64>,
    /// Distortion at which the rate hits zero (source variance).
    pub d_max: f64,
    /// Differential entropy of the source in bits (None → pure BA curve).
    ///
    /// When present, queries return `max(BA, SLB)` where the Shannon lower
    /// bound `R ≥ h − ½log2(2πeD)` is asymptotically tight as D→0 for
    /// squared error — this covers the high-rate regime a discretized BA
    /// cannot reach (the grid caps the achievable rate at its discrete
    /// entropy and floors D at ~step²/12).
    pub h_bits: Option<f64>,
}

impl RdCurve {
    /// Build from raw (distortion, rate) points + the zero-rate distortion.
    pub fn from_points(pts: Vec<(f64, f64)>, d_max: f64) -> Result<Self> {
        Self::from_points_with_entropy(pts, d_max, None)
    }

    /// Build with a known source differential entropy (enables the SLB
    /// high-rate extension).
    pub fn from_points_with_entropy(
        mut pts: Vec<(f64, f64)>,
        d_max: f64,
        h_bits: Option<f64>,
    ) -> Result<Self> {
        pts.retain(|&(d, r)| d.is_finite() && r.is_finite() && d > 0.0 && r >= 0.0);
        if pts.is_empty() {
            return Err(Error::Numerical("empty RD curve".into()));
        }
        pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        // Enforce monotonicity (BA noise can produce tiny violations) and
        // append the zero-rate endpoint.
        let mut ln_d = Vec::with_capacity(pts.len() + 1);
        let mut r = Vec::with_capacity(pts.len() + 1);
        for (d, rate) in pts {
            if d >= d_max {
                continue;
            }
            if let Some(&last) = r.last() {
                if rate >= last {
                    continue; // keep strictly decreasing rates
                }
            }
            ln_d.push(d.ln());
            r.push(rate);
        }
        ln_d.push(d_max.ln());
        r.push(0.0);
        if ln_d.len() < 2 {
            return Err(Error::Numerical("degenerate RD curve".into()));
        }
        Ok(RdCurve { ln_d, r, d_max, h_bits })
    }

    /// Shannon lower bound `h − ½ log2(2πe D)` (−∞ if no entropy known).
    #[inline]
    fn slb(&self, d: f64) -> f64 {
        match self.h_bits {
            Some(h) => {
                h - 0.5 * (2.0 * std::f64::consts::PI * std::f64::consts::E * d).log2()
            }
            None => f64::NEG_INFINITY,
        }
    }

    /// Inverse SLB: distortion at which the SLB equals `rate`.
    #[inline]
    fn slb_inv(&self, rate: f64) -> f64 {
        match self.h_bits {
            Some(h) => 2f64.powf(2.0 * (h - rate)) / (2.0 * std::f64::consts::PI * std::f64::consts::E),
            None => f64::INFINITY,
        }
    }

    /// `R(D)` in bits: `max(BA interpolation, SLB)`; 0 beyond `d_max`.
    pub fn rate_for_mse(&self, d: f64) -> f64 {
        if d >= self.d_max {
            return 0.0;
        }
        self.ba_rate_for_mse(d).max(self.slb(d)).max(0.0)
    }

    /// BA-only interpolation (linear in ln D between knots; clamped to the
    /// first knot's rate below the computed range — SLB covers that side).
    fn ba_rate_for_mse(&self, d: f64) -> f64 {
        let x = d.max(1e-300).ln();
        let n = self.ln_d.len();
        if x <= self.ln_d[0] {
            return self.r[0];
        }
        let mut lo = 0usize;
        let mut hi = n - 1;
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if self.ln_d[mid] <= x {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let t = (x - self.ln_d[lo]) / (self.ln_d[hi] - self.ln_d[lo]);
        (self.r[lo] + t * (self.r[hi] - self.r[lo])).max(0.0)
    }

    /// Inverse: the distortion achievable at `rate` bits — the pointwise
    /// min of the BA inverse and the SLB inverse (inverse of a pointwise
    /// max of decreasing functions).
    pub fn mse_for_rate(&self, rate: f64) -> f64 {
        if rate <= 0.0 {
            return self.d_max;
        }
        self.ba_mse_for_rate(rate).min(self.slb_inv(rate)).min(self.d_max)
    }

    fn ba_mse_for_rate(&self, rate: f64) -> f64 {
        if rate >= self.r[0] {
            // Below the BA grid's reach; the SLB inverse governs there.
            return self.ln_d[0].exp();
        }
        // r is descending in index.
        let n = self.r.len();
        let mut lo = 0usize;
        let mut hi = n - 1;
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if self.r[mid] >= rate {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let denom = self.r[hi] - self.r[lo];
        let t = if denom.abs() < 1e-300 { 0.0 } else { (rate - self.r[lo]) / denom };
        (self.ln_d[lo] + t * (self.ln_d[hi] - self.ln_d[lo])).exp()
    }

    /// Number of knots.
    pub fn len(&self) -> usize {
        self.r.len()
    }

    /// Always false post-construction.
    pub fn is_empty(&self) -> bool {
        self.r.is_empty()
    }
}

/// One Blahut–Arimoto fixed point: returns `(D, R_bits)` for slope `s < 0`.
///
/// `px` is the source pmf on support `x`; the reconstruction alphabet is
/// also `x` (dense enough grids make this immaterial).
pub fn blahut_point(px: &[f64], x: &[f64], s: f64, tol: f64, max_iter: usize) -> (f64, f64) {
    let n = x.len();
    debug_assert_eq!(px.len(), n);
    debug_assert!(s < 0.0);
    // Precompute kernel K_ij = exp(s (x_i - x_j)^2), row-major.
    let mut k = vec![0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            let d = x[i] - x[j];
            k[i * n + j] = (s * d * d).exp();
        }
    }
    let mut q = vec![1.0 / n as f64; n];
    let mut r_i = vec![0f64; n]; // normalizers Σ_j q_j K_ij
    let mut u = vec![0f64; n];
    let mut prev_obj = f64::INFINITY;
    for _ in 0..max_iter {
        // r_i = Σ_j K_ij q_j
        for i in 0..n {
            let row = &k[i * n..(i + 1) * n];
            let mut acc = 0.0;
            for j in 0..n {
                acc += row[j] * q[j];
            }
            r_i[i] = acc.max(f64::MIN_POSITIVE);
        }
        // u_i = p_i / r_i ; q'_j = q_j Σ_i u_i K_ij
        for i in 0..n {
            u[i] = px[i] / r_i[i];
        }
        let mut norm = 0.0;
        for j in 0..n {
            let mut acc = 0.0;
            for i in 0..n {
                acc += u[i] * k[i * n + j];
            }
            q[j] *= acc;
            norm += q[j];
        }
        for qj in q.iter_mut() {
            *qj /= norm;
        }
        // Convergence via the BA objective (monotone): F = Σ p_i ln r_i.
        let obj: f64 = px.iter().zip(&r_i).map(|(&p, &r)| p * r.ln()).sum();
        if (obj - prev_obj).abs() < tol * (1.0 + obj.abs()) {
            break;
        }
        prev_obj = obj;
    }
    // Final D and R from the implied conditional W_ij = q_j K_ij / r_i.
    for i in 0..n {
        let row = &k[i * n..(i + 1) * n];
        let mut acc = 0.0;
        for j in 0..n {
            acc += row[j] * q[j];
        }
        r_i[i] = acc.max(f64::MIN_POSITIVE);
    }
    let mut d_avg = 0.0;
    let mut rate_nats = 0.0;
    for i in 0..n {
        let row = &k[i * n..(i + 1) * n];
        let inv_ri = 1.0 / r_i[i];
        let mut di = 0.0;
        let mut ri_nats = 0.0;
        for j in 0..n {
            let w = q[j] * row[j] * inv_ri;
            if w > 0.0 {
                let dd = (x[i] - x[j]) * (x[i] - x[j]);
                di += w * dd;
                // ln(W/q) = ln(K_ij / r_i) = s*d_ij − ln r_i
                ri_nats += w * (s * dd - r_i[i].ln());
            }
        }
        d_avg += px[i] * di;
        rate_nats += px[i] * ri_nats;
    }
    (d_avg, (rate_nats / std::f64::consts::LN_2).max(0.0))
}

/// Discretize the scalar-channel marginal onto a *multiscale* grid: the
/// union of a spike-scale grid and a slab-scale grid (both `n/2` points),
/// so both mixture components are resolved without quadratic blowup.
/// Returns (support, pmf) with pmf from CDF differences at midpoints.
pub fn discretize_channel(
    channel: &BgChannel,
    sigma2: f64,
    n: usize,
    sds: f64,
) -> (Vec<f64>, Vec<f64>) {
    let p = &channel.prior;
    let spike_sd = sigma2.sqrt();
    let slab_sd = (p.sigma_s2 + sigma2).sqrt();
    let half = n / 2;
    let mut x: Vec<f64> = Vec::with_capacity(2 * half);
    let step_spike = 2.0 * sds * spike_sd / half as f64;
    let step_slab = 2.0 * sds * slab_sd / half as f64;
    for i in 0..half {
        x.push(-sds * spike_sd + (i as f64 + 0.5) * step_spike);
        x.push(p.mu_s - sds * slab_sd + (i as f64 + 0.5) * step_slab);
    }
    x.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // Merge near-duplicates (within 1e-3 of the local spacing).
    x.dedup_by(|a, b| (*a - *b).abs() < 1e-3 * step_spike);
    let m = x.len();
    // pmf via CDF differences at midpoints between neighbors.
    let mut px = Vec::with_capacity(m);
    let mut prev_cdf = 0.0;
    for i in 0..m {
        let hi_edge = if i + 1 < m {
            channel.cdf_f(0.5 * (x[i] + x[i + 1]), sigma2)
        } else {
            1.0
        };
        px.push((hi_edge - prev_cdf).max(0.0));
        prev_cdf = hi_edge;
    }
    let s: f64 = px.iter().sum();
    for pi in px.iter_mut() {
        *pi /= s;
    }
    (x, px)
}

/// Mass-weighted distortion floor of a grid: below ~8× this value the
/// discretized BA curve is dominated by grid granularity and is discarded.
pub fn grid_distortion_floor(x: &[f64], px: &[f64]) -> f64 {
    let m = x.len();
    let mut acc = 0.0;
    for i in 0..m {
        let gap = if i == 0 {
            x[1] - x[0]
        } else if i + 1 == m {
            x[m - 1] - x[m - 2]
        } else {
            0.5 * (x[i + 1] - x[i - 1])
        };
        acc += px[i] * gap * gap / 12.0;
    }
    acc
}

/// Compute the full RD curve of the scalar-channel source by sweeping
/// Lagrange slopes. `points` controls the sweep resolution.
pub fn rd_curve_for_channel(
    channel: &BgChannel,
    sigma2: f64,
    alphabet: usize,
    points: usize,
    tol: f64,
) -> Result<RdCurve> {
    let var = channel.var_f(sigma2);
    let (x, px) = discretize_channel(channel, sigma2, alphabet, 8.0);
    // BA covers the low-rate regime: D from ~var down to var/256 (well
    // above the grid's distortion floor of ~step²/12); the SLB extension
    // in RdCurve covers higher rates. Slopes: D(s) ≈ −1/(2s) at high rate.
    let mut pts = Vec::with_capacity(points);
    let s_lo = -0.5 / var; // gentle slope → D near var, R near 0
    let growth = 2f64.powf(8.0 / points as f64); // total factor 2^8 = 256
    let d_trust = 8.0 * grid_distortion_floor(&x, &px);
    let mut s = s_lo;
    for _ in 0..points {
        let (d, r) = blahut_point(&px, &x, s, tol, 400);
        if d >= d_trust {
            pts.push((d, r));
        }
        s *= growth;
    }
    let h = differential_entropy_bits(channel, sigma2);
    RdCurve::from_points_with_entropy(pts, var, Some(h))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::signal::BernoulliGauss;
    use crate::util::proptest::{prop_assert, Prop};

    /// Pure Gaussian "mixture" (eps=1) — R(D) = ½log2(σ²/D) closed form.
    fn gaussian_channel() -> BgChannel {
        BgChannel::new(BernoulliGauss { eps: 1.0, mu_s: 0.0, sigma_s2: 1e-12 })
    }

    #[test]
    fn blahut_matches_gaussian_closed_form() {
        let c = gaussian_channel();
        let sigma2 = 1.0;
        let curve = rd_curve_for_channel(&c, sigma2, 257, 24, 1e-7).unwrap();
        for d in [0.5, 0.25, 0.1, 0.03, 0.01] {
            let want = 0.5 * (sigma2 / d).log2();
            let got = curve.rate_for_mse(d);
            assert!(
                (got - want).abs() < 0.06,
                "R({d}) = {got}, closed form {want}"
            );
        }
    }

    #[test]
    fn curve_monotone_decreasing() {
        let c = BgChannel::new(BernoulliGauss::standard(0.1));
        let curve = rd_curve_for_channel(&c, 0.05, 201, 20, 1e-7).unwrap();
        let mut prev = f64::INFINITY;
        for k in 1..100 {
            let d = 1e-4 * 1.12f64.powi(k);
            let r = curve.rate_for_mse(d);
            assert!(r <= prev + 1e-9, "R not decreasing at D={d}");
            prev = r;
        }
    }

    #[test]
    fn inverse_consistency() {
        let c = BgChannel::new(BernoulliGauss::standard(0.05));
        let curve = rd_curve_for_channel(&c, 0.02, 201, 20, 1e-7).unwrap();
        Prop::new("mse_for_rate inverts rate_for_mse", 60).check(|g| {
            let rate = g.f64_in(0.1, 9.0);
            let d = curve.mse_for_rate(rate);
            let r_back = curve.rate_for_mse(d);
            // Tolerance reflects knot-interpolation granularity; the DP
            // allocator works at ΔR = 0.1 bits anyway.
            prop_assert(
                (r_back - rate).abs() < 0.06 * (1.0 + rate),
                format!("rate {rate} → D {d} → rate {r_back}"),
            )
        });
    }

    #[test]
    fn sparse_source_cheaper_than_gaussian() {
        // A sparse mixture has smaller R(D) than a Gaussian of equal
        // variance (Gaussian is the max-entropy source under a variance
        // constraint).
        let eps = 0.1;
        let c = BgChannel::new(BernoulliGauss::standard(eps));
        let s2 = 0.01;
        let var = c.var_f(s2);
        let curve = rd_curve_for_channel(&c, s2, 201, 20, 1e-7).unwrap();
        for dfrac in [0.01, 0.001] {
            let d = var * dfrac;
            let gauss = 0.5 * (var / d).log2();
            let got = curve.rate_for_mse(d);
            assert!(
                got < gauss + 0.02,
                "sparse R({d})={got} should be ≤ gaussian {gauss}"
            );
        }
    }

    #[test]
    fn zero_rate_at_variance() {
        let c = BgChannel::new(BernoulliGauss::standard(0.05));
        let s2 = 0.02;
        let curve = rd_curve_for_channel(&c, s2, 201, 16, 1e-7).unwrap();
        assert_eq!(curve.rate_for_mse(c.var_f(s2) * 1.01), 0.0);
        assert!((curve.mse_for_rate(0.0) - c.var_f(s2)).abs() < 1e-12);
    }

    #[test]
    fn discretize_channel_pmf_valid() {
        let c = BgChannel::new(BernoulliGauss::standard(0.05));
        let (x, px) = discretize_channel(&c, 0.02, 301, 8.0);
        // Multiscale union grid: size ≈ requested (dedup may drop a few).
        assert!((x.len() as i64 - 300).abs() <= 4, "got {} points", x.len());
        assert!((px.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(px.iter().all(|&p| p >= 0.0));
        // Grid symmetric-ish around 0 and sorted.
        assert!(x.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn high_rate_extrapolation_sane() {
        let c = gaussian_channel();
        let curve = rd_curve_for_channel(&c, 1.0, 201, 16, 1e-7).unwrap();
        // At 14 bits (beyond computed range) D should be ≈ 2^{-28}.
        let d = curve.mse_for_rate(14.0);
        let want = 2f64.powf(-28.0);
        assert!(
            (d.ln() - want.ln()).abs() < 1.0,
            "extrapolated D {d} vs {want}"
        );
    }
}
