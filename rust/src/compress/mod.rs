//! The pluggable uplink-compression stack — the subsystem the paper
//! actually studies, opened up the same way PR 1–3 opened sessions,
//! transports, and partitioning scenarios.
//!
//! A **stack** is a named `(Quantizer, EntropyCodec)` pair assembled from
//! the [`registry`] (e.g. `"ecsq.huffman"`, `"ecsq-dithered.range"`,
//! `"topk.raw"`). Each protocol round the fusion center *designs* a
//! quantizer from the round's rate directive ([`Quantizer::design_mse`] /
//! [`Quantizer::design_rate`]), broadcasts the resulting wire parameters
//! in the `QuantSpec`, and both sides *assemble* the identical
//! [`Compressor`] from those parameters
//! ([`CompressionStack::assemble`]) — determinism of that rebuild is what
//! keeps the encoder and decoder codecs in sync with no codebook on the
//! wire.
//!
//! The quantization-aware state evolution (paper eq. 8) stays correct for
//! every stack because each designed quantizer reports its own error
//! variance through [`QuantizerState::distortion_model`]; the protocol
//! core folds that σ_Q² into the effective noise exactly where the old
//! hard-wired ECSQ Δ²/12 model went.
//!
//! # Registering a custom quantizer end-to-end
//!
//! A new compressor family only has to implement the two traits and
//! register a stack; the wire protocol, rate allocators, state evolution
//! hooks, metering, CLI (`--compressor sign.raw`), and TOML
//! (`compressor = "sign.raw"`) all inherit it. A complete 1-bit
//! sign-quantizer example:
//!
//! ```no_run
//! use mpamp::compress::registry::{self, CompressionStack};
//! use mpamp::compress::stacks::RawSymbolCodec;
//! use mpamp::compress::{BlockCtx, DesignCtx, Quantizer, QuantizerState, SymbolModel};
//! use mpamp::error::Result;
//! use mpamp::SessionBuilder;
//! use std::sync::Arc;
//!
//! /// 1-bit sign quantizer: each element becomes sign(x)·α, where the
//! /// step α is fitted to the model channel at design time.
//! struct SignQuantizer;
//!
//! struct SignState {
//!     alpha: f64,
//! }
//!
//! impl QuantizerState for SignState {
//!     fn params(&self) -> Vec<f64> {
//!         vec![self.alpha]
//!     }
//!     fn model(&self) -> Option<SymbolModel> {
//!         None // the raw codec needs no symbol model
//!     }
//!     fn symbol_count(&self, len: usize) -> usize {
//!         len
//!     }
//!     fn quantize(&self, _ctx: &BlockCtx, xs: &[f32]) -> Vec<usize> {
//!         xs.iter().map(|&x| usize::from(x >= 0.0)).collect()
//!     }
//!     fn dequantize(&self, _ctx: &BlockCtx, syms: &[usize], out: &mut [f32]) -> Result<()> {
//!         for (o, &s) in out.iter_mut().zip(syms) {
//!             *o = if s == 1 { self.alpha as f32 } else { -self.alpha as f32 };
//!         }
//!         Ok(())
//!     }
//!     fn distortion_model(&self) -> f64 {
//!         self.alpha * self.alpha // crude: E[(F − sign(F)α)²] ≤ E[F²] + α²
//!     }
//!     fn model_bits_per_element(&self) -> f64 {
//!         32.0 // the raw codec spends one u32 symbol per element
//!     }
//! }
//!
//! impl Quantizer for SignQuantizer {
//!     fn family(&self) -> &'static str {
//!         "sign"
//!     }
//!     fn design_mse(&self, ctx: &DesignCtx, _sigma_q2: f64) -> Result<Box<dyn QuantizerState>> {
//!         // α = E[|F|] would be the MMSE step; the channel std is close.
//!         let alpha = ctx.channel.var_f(ctx.noise_var).sqrt();
//!         Ok(Box::new(SignState { alpha }))
//!     }
//!     fn design_rate(&self, ctx: &DesignCtx, _rate_bits: f64) -> Result<Box<dyn QuantizerState>> {
//!         self.design_mse(ctx, 0.0)
//!     }
//!     fn from_params(&self, _ctx: &DesignCtx, params: &[f64]) -> Result<Box<dyn QuantizerState>> {
//!         Ok(Box::new(SignState { alpha: params[0] }))
//!     }
//! }
//!
//! // Register once, then select the stack like any built-in.
//! registry::register(CompressionStack::new(
//!     "sign.raw",
//!     Arc::new(SignQuantizer),
//!     Arc::new(RawSymbolCodec),
//! ))?;
//! let report = SessionBuilder::test_small(0.05)
//!     .compressor("sign.raw")
//!     .build()?
//!     .run()?;
//! println!("sign.raw: {:.2} dB", report.final_sdr_db());
//! # Ok::<(), mpamp::Error>(())
//! ```

pub mod registry;
pub mod stacks;

pub use registry::CompressionStack;

use crate::error::{Error, Result};
use crate::quant::EncodedBlock;
use crate::se::prior::BgChannel;

/// Saturation half-range of designed quantizers, in model standard
/// deviations (the pre-refactor hard-wired value, kept for bit equality).
pub const CLIP_SDS: f64 = 8.0;

/// Everything a stack needs to design — or deterministically rebuild —
/// a compressor for one signal's uplink this round.
#[derive(Debug, Clone)]
pub struct DesignCtx {
    /// Model channel of one element of the uplinked message (row mode:
    /// the per-worker channel at σ̂²; column mode: the Gaussian message
    /// channel at v̂).
    pub channel: BgChannel,
    /// Gaussian noise variance of that channel.
    pub noise_var: f64,
    /// Saturation half-range in model standard deviations.
    pub clip_sds: f64,
    /// Elements per uplink vector.
    pub len: usize,
    /// Deterministic per-round/per-signal seed, carried in the spec so
    /// both protocol sides derive identical shared randomness (dither).
    pub seed: u64,
}

/// Per-block coding context: which worker's block is being coded. Shared
/// randomness (subtractive dither) forks on this so the `P` workers'
/// quantization errors stay independent while both protocol sides agree.
#[derive(Debug, Clone, Copy)]
pub struct BlockCtx {
    /// Worker id of the block's producer.
    pub worker: u32,
}

/// Symbol statistics handed from a quantizer to a model-based entropy
/// codec (range/Huffman/analytic). Model-free codecs take `None`.
#[derive(Debug, Clone)]
pub struct SymbolModel {
    /// Pmf over the symbol alphabet (index = wire symbol).
    pub pmf: Vec<f64>,
}

impl SymbolModel {
    /// Model entropy in bits/symbol.
    pub fn entropy_bits(&self) -> f64 {
        -self.pmf.iter().map(|&p| crate::util::xlog2x(p)).sum::<f64>()
    }
}

/// A quantizer family: maps design targets (MSE or rate) to concrete
/// [`QuantizerState`]s, and rebuilds a state from its wire parameters.
/// Implementations are stateless; everything designed lives in the state.
pub trait Quantizer: Send + Sync {
    /// Family label — the part before the `.` in registered stack names.
    fn family(&self) -> &'static str;

    /// Whether this family's designed states return a symbol-model pmf
    /// from [`QuantizerState::model`]. Capability flag for the registry:
    /// pairing a model-free family (top-K) with a model-based codec
    /// (range/Huffman/analytic) is rejected at registration instead of
    /// failing rounds later at assembly time.
    fn provides_model_pmf(&self) -> bool {
        true
    }

    /// Design for a target per-worker quantization MSE σ_Q².
    fn design_mse(&self, ctx: &DesignCtx, sigma_q2: f64) -> Result<Box<dyn QuantizerState>>;

    /// Design for a target rate in bits per element.
    fn design_rate(&self, ctx: &DesignCtx, rate_bits: f64) -> Result<Box<dyn QuantizerState>>;

    /// Rebuild a designed state from its wire parameters. Must be
    /// deterministic: the fusion center and every worker call this with
    /// the same spec and must end up with bit-identical codecs.
    fn from_params(&self, ctx: &DesignCtx, params: &[f64]) -> Result<Box<dyn QuantizerState>>;
}

/// One designed quantizer, ready to code blocks.
pub trait QuantizerState: Send + Sync {
    /// Wire parameters from which [`Quantizer::from_params`] rebuilds
    /// this exact state (what the `QuantSpec` carries).
    fn params(&self) -> Vec<f64>;

    /// Symbol model for the entropy codec (`None` for quantizers whose
    /// symbol streams carry no exploitable model, e.g. index+value pairs).
    fn model(&self) -> Option<SymbolModel>;

    /// Number of wire symbols produced for a block of `len` elements.
    fn symbol_count(&self, len: usize) -> usize;

    /// Quantize a block to wire symbols.
    fn quantize(&self, ctx: &BlockCtx, xs: &[f32]) -> Vec<usize>;

    /// Reconstruct a block (length fixed by `out`) from wire symbols.
    /// Must reject malformed symbol streams instead of panicking — the
    /// symbols may come off the wire.
    fn dequantize(&self, ctx: &BlockCtx, syms: &[usize], out: &mut [f32]) -> Result<()>;

    /// The per-worker error variance σ_Q² this quantizer contributes to
    /// the quantization-aware state evolution (paper eq. 8). ECSQ's
    /// uniform model gives Δ²/12; a sparsifier reports its dropped-energy
    /// model instead.
    fn distortion_model(&self) -> f64;

    /// Analytic bits/element the design predicts (the rate-allocation
    /// accounting and the analytic codec charge this).
    fn model_bits_per_element(&self) -> f64;
}

/// An entropy-codec family: builds a per-round [`BlockCodec`] from a
/// quantizer's symbol model.
pub trait EntropyCodec: Send + Sync {
    /// Codec label — the part after the `.` in registered stack names.
    fn name(&self) -> &'static str;

    /// Whether encoded bytes actually travel. The analytic codec returns
    /// `false`: it accounts model-entropy bits while the (dequantized)
    /// values ship as raw floats, so numerics match the coded paths
    /// exactly.
    fn carries_payload(&self) -> bool {
        true
    }

    /// Whether [`build`](EntropyCodec::build) requires a symbol-model
    /// pmf. Capability flag for the registry (see
    /// [`Quantizer::provides_model_pmf`]); the model-free
    /// [`RawSymbolCodec`](stacks::RawSymbolCodec) returns `false`.
    fn needs_model_pmf(&self) -> bool {
        true
    }

    /// Build the block codec for this round's symbol model.
    fn build(&self, model: Option<&SymbolModel>) -> Result<Box<dyn BlockCodec>>;
}

/// A ready-to-use block codec (one protocol round, one signal).
pub trait BlockCodec: Send + Sync {
    /// Entropy-code a symbol block; `wire_bits` must be the exact bits
    /// charged on the wire (`8·bytes` for byte-aligned codecs).
    fn encode(&self, syms: &[usize]) -> Result<EncodedBlock>;

    /// Decode exactly `n_syms` symbols from wire bytes.
    fn decode(&self, bytes: &[u8], n_syms: usize) -> Result<Vec<usize>>;
}

/// A fully assembled compression stack for one signal's uplink this
/// round: designed quantizer + built codec. Both protocol sides assemble
/// it from the same `QuantSpec` via [`CompressionStack::assemble`].
pub struct Compressor {
    stack_name: String,
    state: Box<dyn QuantizerState>,
    block: Box<dyn BlockCodec>,
    carries_payload: bool,
}

impl Compressor {
    /// Registry name of the stack this compressor was assembled from.
    pub fn stack_name(&self) -> &str {
        &self.stack_name
    }

    /// Whether encoded bytes travel (false for the analytic codec).
    pub fn carries_payload(&self) -> bool {
        self.carries_payload
    }

    /// The designed quantizer's σ_Q² for the quantization-aware SE.
    pub fn distortion_model(&self) -> f64 {
        self.state.distortion_model()
    }

    /// Analytic bits/element of the design (rate accounting).
    pub fn model_bits_per_element(&self) -> f64 {
        self.state.model_bits_per_element()
    }

    /// Quantize a block to wire symbols.
    pub fn quantize(&self, ctx: &BlockCtx, xs: &[f32]) -> Vec<usize> {
        self.state.quantize(ctx, xs)
    }

    /// Reconstruct a block from wire symbols.
    pub fn dequantize(&self, ctx: &BlockCtx, syms: &[usize], out: &mut [f32]) -> Result<()> {
        self.state.dequantize(ctx, syms, out)
    }

    /// Quantize + entropy-code a block.
    pub fn encode(&self, ctx: &BlockCtx, xs: &[f32]) -> Result<EncodedBlock> {
        let syms = self.state.quantize(ctx, xs);
        self.block.encode(&syms)
    }

    /// Decode wire bytes back into a reconstruction of length `out.len()`.
    pub fn decode(&self, ctx: &BlockCtx, bytes: &[u8], out: &mut [f32]) -> Result<()> {
        let n_syms = self.state.symbol_count(out.len());
        let syms = self.block.decode(bytes, n_syms)?;
        self.state.dequantize(ctx, &syms, out)
    }
}

/// Stable mixer for design seeds: one independent 64-bit stream per
/// (session seed, iteration, signal), SplitMix64-finalized so adjacent
/// rounds decorrelate.
pub fn design_seed(session_seed: u64, t: usize, sig: usize) -> u64 {
    let mut z = session_seed
        ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (sig as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Internal constructor used by [`CompressionStack::assemble`].
pub(crate) fn assemble_parts(
    stack_name: &str,
    state: Box<dyn QuantizerState>,
    codec: &dyn EntropyCodec,
) -> Result<Compressor> {
    let model = state.model();
    let block = codec.build(model.as_ref())?;
    Ok(Compressor {
        stack_name: stack_name.to_string(),
        state,
        block,
        carries_payload: codec.carries_payload(),
    })
}

/// Convenience for errors raised by stack implementations.
pub(crate) fn codec_err(msg: impl Into<String>) -> Error {
    Error::Codec(msg.into())
}
