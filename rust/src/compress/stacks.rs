//! Built-in quantizers and entropy codecs: the paper's ECSQ (plain and
//! subtractively dithered) and a top-K magnitude sparsifier, plus the
//! analytic / range / Huffman / raw codecs they pair with in the
//! [`registry`](crate::compress::registry).

use std::cmp::Ordering;

use crate::compress::{
    codec_err, BlockCodec, BlockCtx, DesignCtx, EntropyCodec, Quantizer, QuantizerState,
    SymbolModel,
};
use crate::error::Result;
use crate::quant::entropy::{FreqTable, Huffman};
use crate::quant::{EncodedBlock, UniformQuantizer};
use crate::util::rng::Rng;

/// Hard cap on `k_max` accepted off the wire (matches the bin cap of
/// [`UniformQuantizer::new`]); a hostile spec must not size allocations.
const MAX_K_MAX: f64 = (1u64 << 20) as f64;

// ---------------------------------------------------------------------
// ECSQ — the paper's entropy-coded scalar quantizer (§3.2)
// ---------------------------------------------------------------------

/// Plain mid-tread uniform quantizer, designed from the model channel —
/// byte-identical to the pre-registry `EcsqCoder` pipeline.
#[derive(Debug, Clone, Copy, Default)]
pub struct EcsqQuantizer;

/// Subtractively dithered variant: both sides share a seeded dither
/// sequence `d_i ~ U(−Δ/2, Δ/2)`, the encoder quantizes `x + d_i`, the
/// decoder subtracts `d_i` after reconstruction. The error `Q(x+d)−(x+d)`
/// is exactly uniform and independent of the signal (Schuchman's
/// condition), so reconstruction is unbiased and the Δ²/12 model holds
/// without the paper's `Δ ≤ 2σ` validity caveat.
#[derive(Debug, Clone, Copy, Default)]
pub struct DitheredEcsqQuantizer;

/// Designed ECSQ state shared by the plain and dithered families
/// (`dither_seed = None` → plain).
struct EcsqState {
    q: UniformQuantizer,
    pmf: Vec<f64>,
    entropy_bits: f64,
    dither_seed: Option<u64>,
}

impl EcsqState {
    fn build(q: UniformQuantizer, ctx: &DesignCtx, dither_seed: Option<u64>) -> Self {
        let pmf = q.bin_pmf(&ctx.channel, ctx.noise_var);
        let entropy_bits = -pmf.iter().map(|&p| crate::util::xlog2x(p)).sum::<f64>();
        EcsqState { q, pmf, entropy_bits, dither_seed }
    }

    /// Per-(seed, worker) dither stream; both protocol sides derive the
    /// identical sequence from the spec's seed and the block's worker id.
    fn dither_rng(seed: u64, worker: u32) -> Rng {
        Rng::new(seed ^ (worker as u64 + 1).wrapping_mul(0xA076_1D64_78BD_642F))
    }
}

impl QuantizerState for EcsqState {
    fn params(&self) -> Vec<f64> {
        vec![self.q.delta, self.q.k_max as f64]
    }

    fn model(&self) -> Option<SymbolModel> {
        Some(SymbolModel { pmf: self.pmf.clone() })
    }

    fn symbol_count(&self, len: usize) -> usize {
        len
    }

    fn quantize(&self, ctx: &BlockCtx, xs: &[f32]) -> Vec<usize> {
        match self.dither_seed {
            None => self.q.quantize_block(xs),
            Some(seed) => {
                let mut rng = Self::dither_rng(seed, ctx.worker);
                xs.iter()
                    .map(|&x| {
                        let d = (rng.uniform() - 0.5) * self.q.delta;
                        self.q.symbol(x as f64 + d)
                    })
                    .collect()
            }
        }
    }

    fn dequantize(&self, ctx: &BlockCtx, syms: &[usize], out: &mut [f32]) -> Result<()> {
        if syms.len() != out.len() {
            return Err(codec_err(format!(
                "ecsq: {} symbols for {} elements",
                syms.len(),
                out.len()
            )));
        }
        match self.dither_seed {
            None => self.q.dequantize_block(syms, out),
            Some(seed) => {
                let mut rng = Self::dither_rng(seed, ctx.worker);
                for (o, &s) in out.iter_mut().zip(syms) {
                    let d = (rng.uniform() - 0.5) * self.q.delta;
                    *o = (self.q.reconstruct_symbol(s) - d) as f32;
                }
            }
        }
        Ok(())
    }

    fn distortion_model(&self) -> f64 {
        self.q.sigma_q2()
    }

    fn model_bits_per_element(&self) -> f64 {
        self.entropy_bits
    }
}

/// Shared design/rebuild logic of the two ECSQ families.
fn ecsq_design_mse(
    ctx: &DesignCtx,
    sigma_q2: f64,
    dithered: bool,
) -> Result<Box<dyn QuantizerState>> {
    let clip = ctx.channel.clip_range(ctx.noise_var, ctx.clip_sds);
    let q = UniformQuantizer::for_mse(sigma_q2, clip, 0.0)?;
    let seed = if dithered { Some(ctx.seed) } else { None };
    Ok(Box::new(EcsqState::build(q, ctx, seed)))
}

fn ecsq_design_rate(
    ctx: &DesignCtx,
    rate_bits: f64,
    dithered: bool,
) -> Result<Box<dyn QuantizerState>> {
    let q = UniformQuantizer::for_rate(
        &ctx.channel,
        ctx.noise_var,
        rate_bits,
        ctx.clip_sds,
        0.0,
    )?;
    let seed = if dithered { Some(ctx.seed) } else { None };
    Ok(Box::new(EcsqState::build(q, ctx, seed)))
}

fn ecsq_from_params(
    ctx: &DesignCtx,
    params: &[f64],
    dithered: bool,
) -> Result<Box<dyn QuantizerState>> {
    if params.len() != 2 {
        return Err(codec_err(format!("ecsq spec wants 2 params, got {}", params.len())));
    }
    let (delta, k_max) = (params[0], params[1]);
    if !(delta.is_finite() && delta > 0.0) {
        return Err(codec_err(format!("ecsq spec: bad delta {delta}")));
    }
    if !(k_max.is_finite() && k_max >= 1.0 && k_max <= MAX_K_MAX && k_max.fract() == 0.0) {
        return Err(codec_err(format!("ecsq spec: bad k_max {k_max}")));
    }
    let q = UniformQuantizer { delta, k_max: k_max as i32, center: 0.0 };
    let seed = if dithered { Some(ctx.seed) } else { None };
    Ok(Box::new(EcsqState::build(q, ctx, seed)))
}

impl Quantizer for EcsqQuantizer {
    fn family(&self) -> &'static str {
        "ecsq"
    }

    fn design_mse(&self, ctx: &DesignCtx, sigma_q2: f64) -> Result<Box<dyn QuantizerState>> {
        ecsq_design_mse(ctx, sigma_q2, false)
    }

    fn design_rate(&self, ctx: &DesignCtx, rate_bits: f64) -> Result<Box<dyn QuantizerState>> {
        ecsq_design_rate(ctx, rate_bits, false)
    }

    fn from_params(&self, ctx: &DesignCtx, params: &[f64]) -> Result<Box<dyn QuantizerState>> {
        ecsq_from_params(ctx, params, false)
    }
}

impl Quantizer for DitheredEcsqQuantizer {
    fn family(&self) -> &'static str {
        "ecsq-dithered"
    }

    fn design_mse(&self, ctx: &DesignCtx, sigma_q2: f64) -> Result<Box<dyn QuantizerState>> {
        ecsq_design_mse(ctx, sigma_q2, true)
    }

    fn design_rate(&self, ctx: &DesignCtx, rate_bits: f64) -> Result<Box<dyn QuantizerState>> {
        ecsq_design_rate(ctx, rate_bits, true)
    }

    fn from_params(&self, ctx: &DesignCtx, params: &[f64]) -> Result<Box<dyn QuantizerState>> {
        ecsq_from_params(ctx, params, true)
    }
}

// ---------------------------------------------------------------------
// Top-K magnitude sparsifier
// ---------------------------------------------------------------------

/// Wire bits per kept entry under the raw codec: a u32 index + an f32
/// value, both as one u32 symbol each.
const TOPK_BITS_PER_ENTRY: f64 = 64.0;

/// Keep the `K` largest-magnitude elements, drop the rest to zero; kept
/// values travel exactly (index + f32 bits). A qualitatively different
/// rate-distortion trade-off from ECSQ: zero error on the kept support,
/// the model channel's truncated energy `E[F²; |F| ≤ τ(K)]` on the rest —
/// which is what [`QuantizerState::distortion_model`] reports into the
/// quantization-aware SE.
#[derive(Debug, Clone, Copy, Default)]
pub struct TopKQuantizer;

struct TopKState {
    k: usize,
    len: usize,
    drop_var: f64,
}

impl TopKState {
    /// Shared constructor: both design paths and `from_params` resolve a
    /// `k` through here so the distortion model is identical on every
    /// protocol side.
    fn for_k(ctx: &DesignCtx, k: usize) -> TopKState {
        let len = ctx.len.max(1);
        let k = k.min(len);
        let drop_var = if k >= len {
            0.0
        } else {
            let tau = tau_for_keep_fraction(ctx, k as f64 / len as f64);
            dropped_energy(ctx, tau)
        };
        TopKState { k, len, drop_var }
    }
}

/// `P(|F| > τ)` under the design channel.
fn keep_fraction(ctx: &DesignCtx, tau: f64) -> f64 {
    let c = &ctx.channel;
    (1.0 - (c.cdf_f(tau, ctx.noise_var) - c.cdf_f(-tau, ctx.noise_var))).max(0.0)
}

/// `E[F²; |F| ≤ τ]` — the energy a magnitude threshold drops.
fn dropped_energy(ctx: &DesignCtx, tau: f64) -> f64 {
    ctx.channel
        .expect_f(ctx.noise_var, |f| if f.abs() <= tau { f * f } else { 0.0 })
}

/// Invert `keep_fraction`: the magnitude threshold with
/// `P(|F| > τ) = frac` (bisection; `keep_fraction` is decreasing in τ).
fn tau_for_keep_fraction(ctx: &DesignCtx, frac: f64) -> f64 {
    let mut lo = 0.0f64;
    let mut hi = ctx.channel.clip_range(ctx.noise_var, 40.0).max(1e-12);
    for _ in 0..80 {
        let mid = 0.5 * (lo + hi);
        if keep_fraction(ctx, mid) > frac {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

impl Quantizer for TopKQuantizer {
    fn family(&self) -> &'static str {
        "topk"
    }

    fn provides_model_pmf(&self) -> bool {
        // Index + f32-bit pairs carry no exploitable symbol model.
        false
    }

    /// Smallest `K` whose modeled dropped energy stays under the target
    /// σ_Q²: bisect the magnitude threshold on `E[F²; |F| ≤ τ]`, then
    /// round the implied keep fraction up (erring toward less distortion).
    fn design_mse(&self, ctx: &DesignCtx, sigma_q2: f64) -> Result<Box<dyn QuantizerState>> {
        let len = ctx.len.max(1);
        let total = ctx.channel.expect_f(ctx.noise_var, |f| f * f);
        if !(sigma_q2.is_finite()) || sigma_q2 >= total {
            return Ok(Box::new(TopKState::for_k(ctx, 0)));
        }
        let target = sigma_q2.max(0.0);
        let mut lo = 0.0f64;
        let mut hi = ctx.channel.clip_range(ctx.noise_var, 40.0).max(1e-12);
        for _ in 0..80 {
            let mid = 0.5 * (lo + hi);
            if dropped_energy(ctx, mid) <= target {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let tau = 0.5 * (lo + hi);
        let k = (len as f64 * keep_fraction(ctx, tau)).ceil() as usize;
        Ok(Box::new(TopKState::for_k(ctx, k.min(len))))
    }

    /// `K = ⌊rate·len / 64⌋` — each kept entry costs an index + value pair.
    fn design_rate(&self, ctx: &DesignCtx, rate_bits: f64) -> Result<Box<dyn QuantizerState>> {
        if !(rate_bits.is_finite() && rate_bits >= 0.0) {
            return Err(codec_err(format!("topk: bad rate {rate_bits}")));
        }
        let len = ctx.len.max(1);
        let k = ((rate_bits * len as f64) / TOPK_BITS_PER_ENTRY).floor() as usize;
        Ok(Box::new(TopKState::for_k(ctx, k.min(len))))
    }

    fn from_params(&self, ctx: &DesignCtx, params: &[f64]) -> Result<Box<dyn QuantizerState>> {
        if params.len() != 1 {
            return Err(codec_err(format!("topk spec wants 1 param, got {}", params.len())));
        }
        let k = params[0];
        if !(k.is_finite() && k >= 0.0 && k.fract() == 0.0 && k <= (1u64 << 32) as f64) {
            return Err(codec_err(format!("topk spec: bad k {k}")));
        }
        Ok(Box::new(TopKState::for_k(ctx, k as usize)))
    }
}

impl QuantizerState for TopKState {
    fn params(&self) -> Vec<f64> {
        vec![self.k as f64]
    }

    fn model(&self) -> Option<SymbolModel> {
        None
    }

    fn symbol_count(&self, len: usize) -> usize {
        2 * self.k.min(len)
    }

    fn quantize(&self, _ctx: &BlockCtx, xs: &[f32]) -> Vec<usize> {
        let k = self.k.min(xs.len());
        let mut order: Vec<usize> = (0..xs.len()).collect();
        // Deterministic selection: magnitude descending, index ascending
        // on ties (both sides only ever see the encoder's choice, but the
        // tie-break keeps runs reproducible across platforms).
        order.sort_unstable_by(|&a, &b| {
            xs[b]
                .abs()
                .partial_cmp(&xs[a].abs())
                .unwrap_or(Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut top = order[..k].to_vec();
        top.sort_unstable();
        let mut syms = Vec::with_capacity(2 * k);
        for i in top {
            syms.push(i);
            syms.push(xs[i].to_bits() as usize);
        }
        syms
    }

    fn dequantize(&self, _ctx: &BlockCtx, syms: &[usize], out: &mut [f32]) -> Result<()> {
        if syms.len() != self.symbol_count(out.len()) {
            return Err(codec_err(format!(
                "topk: {} symbols for K={} over {} elements",
                syms.len(),
                self.k,
                out.len()
            )));
        }
        out.fill(0.0);
        // The encoder emits strictly increasing indices; anything else
        // (duplicates, shuffles) is a malformed wire stream, not data.
        let mut prev: Option<usize> = None;
        for pair in syms.chunks_exact(2) {
            let i = pair[0];
            if i >= out.len() {
                return Err(codec_err(format!(
                    "topk: index {i} out of range {}",
                    out.len()
                )));
            }
            if prev.is_some_and(|p| i <= p) {
                return Err(codec_err(format!(
                    "topk: indices not strictly increasing at {i}"
                )));
            }
            prev = Some(i);
            if pair[1] > u32::MAX as usize {
                return Err(codec_err(format!("topk: bad value symbol {}", pair[1])));
            }
            out[i] = f32::from_bits(pair[1] as u32);
        }
        Ok(())
    }

    fn distortion_model(&self) -> f64 {
        self.drop_var
    }

    fn model_bits_per_element(&self) -> f64 {
        TOPK_BITS_PER_ENTRY * self.k.min(self.len) as f64 / self.len as f64
    }
}

// ---------------------------------------------------------------------
// Entropy codecs
// ---------------------------------------------------------------------

/// No actual coding: charge the model entropy `H_Q` per symbol (the
/// paper's accounting) while the dequantized values travel as raw floats.
#[derive(Debug, Clone, Copy, Default)]
pub struct AnalyticCodec;

/// Static range coder over the quantizer's model pmf (real wire bytes).
#[derive(Debug, Clone, Copy, Default)]
pub struct RangeCodec;

/// Canonical Huffman over the model pmf (real bytes; integer-bit penalty).
#[derive(Debug, Clone, Copy, Default)]
pub struct HuffmanCodec;

/// Model-free 4-byte little-endian symbol stream — for quantizers whose
/// symbols are already incompressible (top-K index + f32-bit pairs).
#[derive(Debug, Clone, Copy, Default)]
pub struct RawSymbolCodec;

fn require_model<'m>(model: Option<&'m SymbolModel>, codec: &str) -> Result<&'m SymbolModel> {
    model.ok_or_else(|| {
        codec_err(format!("{codec} codec needs a symbol model from the quantizer"))
    })
}

struct AnalyticBlock {
    bits_per_sym: f64,
}

impl BlockCodec for AnalyticBlock {
    fn encode(&self, syms: &[usize]) -> Result<EncodedBlock> {
        Ok(EncodedBlock {
            bytes: Vec::new(),
            wire_bits: self.bits_per_sym * syms.len() as f64,
            n: syms.len(),
        })
    }

    fn decode(&self, _bytes: &[u8], _n_syms: usize) -> Result<Vec<usize>> {
        Err(codec_err("analytic codec carries no payload to decode"))
    }
}

impl EntropyCodec for AnalyticCodec {
    fn name(&self) -> &'static str {
        "analytic"
    }

    fn carries_payload(&self) -> bool {
        false
    }

    fn build(&self, model: Option<&SymbolModel>) -> Result<Box<dyn BlockCodec>> {
        let model = require_model(model, "analytic")?;
        Ok(Box::new(AnalyticBlock { bits_per_sym: model.entropy_bits() }))
    }
}

struct RangeBlock {
    freq: FreqTable,
}

impl BlockCodec for RangeBlock {
    fn encode(&self, syms: &[usize]) -> Result<EncodedBlock> {
        let bytes = crate::quant::entropy::range::encode_block(&self.freq, syms);
        let wire_bits = bytes.len() as f64 * 8.0;
        Ok(EncodedBlock { bytes, wire_bits, n: syms.len() })
    }

    fn decode(&self, bytes: &[u8], n_syms: usize) -> Result<Vec<usize>> {
        crate::quant::entropy::range::decode_block(&self.freq, bytes, n_syms)
    }
}

impl EntropyCodec for RangeCodec {
    fn name(&self) -> &'static str {
        "range"
    }

    fn build(&self, model: Option<&SymbolModel>) -> Result<Box<dyn BlockCodec>> {
        let model = require_model(model, "range")?;
        Ok(Box::new(RangeBlock { freq: FreqTable::from_pmf(&model.pmf)? }))
    }
}

struct HuffmanBlock {
    huff: Huffman,
}

impl BlockCodec for HuffmanBlock {
    fn encode(&self, syms: &[usize]) -> Result<EncodedBlock> {
        // Exact bit count (not 8·bytes): the pre-registry EcsqCoder
        // charged Huffman's true bits, and the bit-equality pin holds us
        // to it.
        let wire_bits = self.huff.block_bits(syms) as f64;
        Ok(EncodedBlock { bytes: self.huff.encode_block(syms), wire_bits, n: syms.len() })
    }

    fn decode(&self, bytes: &[u8], n_syms: usize) -> Result<Vec<usize>> {
        self.huff.decode_block(bytes, n_syms)
    }
}

impl EntropyCodec for HuffmanCodec {
    fn name(&self) -> &'static str {
        "huffman"
    }

    fn build(&self, model: Option<&SymbolModel>) -> Result<Box<dyn BlockCodec>> {
        let model = require_model(model, "huffman")?;
        let freq = FreqTable::from_pmf(&model.pmf)?;
        Ok(Box::new(HuffmanBlock { huff: Huffman::from_table(&freq)? }))
    }
}

struct RawSymbolBlock;

impl BlockCodec for RawSymbolBlock {
    fn encode(&self, syms: &[usize]) -> Result<EncodedBlock> {
        let mut bytes = Vec::with_capacity(4 * syms.len());
        for &s in syms {
            if s > u32::MAX as usize {
                return Err(codec_err(format!("raw codec: symbol {s} exceeds u32")));
            }
            bytes.extend_from_slice(&(s as u32).to_le_bytes());
        }
        let wire_bits = bytes.len() as f64 * 8.0;
        Ok(EncodedBlock { bytes, wire_bits, n: syms.len() })
    }

    fn decode(&self, bytes: &[u8], n_syms: usize) -> Result<Vec<usize>> {
        if bytes.len() != 4 * n_syms {
            return Err(codec_err(format!(
                "raw codec: {} bytes for {n_syms} symbols",
                bytes.len()
            )));
        }
        let mut syms = Vec::with_capacity(n_syms);
        for chunk in bytes.chunks_exact(4) {
            syms.push(u32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]) as usize);
        }
        Ok(syms)
    }
}

impl EntropyCodec for RawSymbolCodec {
    fn name(&self) -> &'static str {
        "raw"
    }

    fn needs_model_pmf(&self) -> bool {
        false
    }

    fn build(&self, _model: Option<&SymbolModel>) -> Result<Box<dyn BlockCodec>> {
        Ok(Box::new(RawSymbolBlock))
    }
}
