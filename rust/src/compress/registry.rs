//! The named compression-stack registry.
//!
//! Stack names follow `"<quantizer-family>.<codec>"` — `"ecsq.huffman"`,
//! `"ecsq-dithered.range"`, `"topk.raw"`. The name travels inside every
//! `QuantSpec`, so a worker can assemble the *identical* stack the fusion
//! center designed with, including stacks registered at runtime by the
//! embedding application (see the worked example in
//! [`compress`](crate::compress)).
//!
//! The registry is process-global: sessions run their workers as threads
//! of the same process (in-proc and loopback-TCP alike), so one
//! registration makes a stack available to every protocol side.

use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock, RwLock};

use crate::compress::stacks::{
    AnalyticCodec, DitheredEcsqQuantizer, EcsqQuantizer, HuffmanCodec, RangeCodec,
    RawSymbolCodec, TopKQuantizer,
};
use crate::compress::{
    assemble_parts, Compressor, DesignCtx, EntropyCodec, Quantizer, QuantizerState,
};
use crate::error::{Error, Result};

/// The default stack — plain ECSQ over the range coder, matching the
/// pre-registry `codec = "range"` default bit for bit.
pub const DEFAULT_STACK: &str = "ecsq.range";

/// Longest registered name accepted (the wire decoder enforces the same
/// cap before allocating).
pub const MAX_STACK_NAME: usize = 64;

/// Capability flags a stack advertises (derived from its parts) — what
/// `mpamp compressors` tabulates and registration validates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StackCaps {
    /// The codec requires a symbol-model pmf from the quantizer.
    pub needs_model_pmf: bool,
    /// No encoded bytes travel (entropy-accounted, e.g. the analytic
    /// codec) — the dequantized values ship as raw floats instead.
    pub payload_free: bool,
}

/// A named `(Quantizer, EntropyCodec)` pair.
#[derive(Clone)]
pub struct CompressionStack {
    name: Arc<str>,
    description: String,
    quantizer: Arc<dyn Quantizer>,
    codec: Arc<dyn EntropyCodec>,
}

impl CompressionStack {
    /// Assemble a stack under a registry name.
    pub fn new(
        name: impl Into<String>,
        quantizer: Arc<dyn Quantizer>,
        codec: Arc<dyn EntropyCodec>,
    ) -> Self {
        CompressionStack {
            name: name.into().into(),
            description: String::new(),
            quantizer,
            codec,
        }
    }

    /// Attach a one-line human description (shown by `mpamp compressors`).
    pub fn with_description(mut self, description: impl Into<String>) -> Self {
        self.description = description.into();
        self
    }

    /// The registry name (what configs, CLI, and `QuantSpec`s carry).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The registry name as a shared string — what per-round spec design
    /// clones (a pointer bump, not a string copy).
    pub fn name_arc(&self) -> std::sync::Arc<str> {
        self.name.clone()
    }

    /// The one-line description (empty if none was attached).
    pub fn description(&self) -> &str {
        &self.description
    }

    /// The stack's advertised capability flags.
    pub fn caps(&self) -> StackCaps {
        StackCaps {
            needs_model_pmf: self.codec.needs_model_pmf(),
            payload_free: !self.codec.carries_payload(),
        }
    }

    /// Error if the stack's parts are incompatible (a model-based codec
    /// over a model-free quantizer family) — checked at registration so
    /// a bad pairing fails with the stack named, not rounds later with
    /// an assembly error on a worker.
    pub fn validate_caps(&self) -> Result<()> {
        if self.codec.needs_model_pmf() && !self.quantizer.provides_model_pmf() {
            return Err(Error::Config(format!(
                "compression stack '{}': codec '{}' needs a symbol-model pmf \
                 but quantizer family '{}' provides none",
                self.name,
                self.codec.name(),
                self.quantizer.family()
            )));
        }
        Ok(())
    }

    /// The stack's quantizer family.
    pub fn quantizer(&self) -> &dyn Quantizer {
        self.quantizer.as_ref()
    }

    /// The stack's entropy codec.
    pub fn codec(&self) -> &dyn EntropyCodec {
        self.codec.as_ref()
    }

    /// Design a quantizer state for a target per-worker MSE σ_Q².
    pub fn design_mse(&self, ctx: &DesignCtx, sigma_q2: f64) -> Result<Box<dyn QuantizerState>> {
        self.quantizer.design_mse(ctx, sigma_q2)
    }

    /// Design a quantizer state for a target rate (bits/element).
    pub fn design_rate(&self, ctx: &DesignCtx, rate_bits: f64) -> Result<Box<dyn QuantizerState>> {
        self.quantizer.design_rate(ctx, rate_bits)
    }

    /// Rebuild the ready-to-code [`Compressor`] from wire parameters —
    /// the call both protocol sides make from the same `QuantSpec`.
    pub fn assemble(&self, ctx: &DesignCtx, params: &[f64]) -> Result<Compressor> {
        let state = self.quantizer.from_params(ctx, params)?;
        assemble_parts(&self.name, state, self.codec.as_ref())
    }
}

impl std::fmt::Debug for CompressionStack {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CompressionStack")
            .field("name", &self.name)
            .field("quantizer", &self.quantizer.family())
            .field("codec", &self.codec.name())
            .finish()
    }
}

type StackMap = BTreeMap<String, Arc<CompressionStack>>;

static REGISTRY: OnceLock<RwLock<StackMap>> = OnceLock::new();

fn builtin_stacks() -> StackMap {
    let ecsq: Arc<dyn Quantizer> = Arc::new(EcsqQuantizer);
    let dithered: Arc<dyn Quantizer> = Arc::new(DitheredEcsqQuantizer);
    let topk: Arc<dyn Quantizer> = Arc::new(TopKQuantizer);
    let stacks = [
        CompressionStack::new("ecsq.analytic", ecsq.clone(), Arc::new(AnalyticCodec))
            .with_description("ECSQ, entropy-accounted (H_Q bits, raw floats travel)"),
        CompressionStack::new("ecsq.range", ecsq.clone(), Arc::new(RangeCodec))
            .with_description("ECSQ over a static range coder (default)"),
        CompressionStack::new("ecsq.huffman", ecsq, Arc::new(HuffmanCodec))
            .with_description("ECSQ over canonical Huffman (integer-bit penalty)"),
        CompressionStack::new("ecsq-dithered.range", dithered, Arc::new(RangeCodec))
            .with_description("Subtractively-dithered ECSQ, seeded per worker"),
        CompressionStack::new("topk.raw", topk, Arc::new(RawSymbolCodec))
            .with_description("Top-K magnitude sparsifier, index+f32 coding"),
    ];
    stacks
        .into_iter()
        .map(|s| (s.name().to_string(), Arc::new(s)))
        .collect()
}

fn map() -> &'static RwLock<StackMap> {
    REGISTRY.get_or_init(|| RwLock::new(builtin_stacks()))
}

/// Look up a stack by name. The error lists every registered name, so an
/// unknown `--compressor` fails with the menu in hand.
pub fn get(name: &str) -> Result<Arc<CompressionStack>> {
    let m = map().read().expect("compression registry poisoned");
    m.get(name).cloned().ok_or_else(|| {
        let known: Vec<&str> = m.keys().map(String::as_str).collect();
        Error::Config(format!(
            "unknown compression stack '{name}' (registered: {})",
            known.join(", ")
        ))
    })
}

/// Register a new stack. Names must be non-empty, at most
/// [`MAX_STACK_NAME`] bytes, without whitespace (they travel on the
/// wire), and not collide with an existing registration — the built-ins
/// cannot be silently replaced out from under a running session. The
/// stack's capability flags must also be consistent
/// ([`CompressionStack::validate_caps`]), so an impossible pairing fails
/// here with the stack named instead of rounds later on a worker.
pub fn register(stack: CompressionStack) -> Result<()> {
    let name = stack.name().to_string();
    if name.is_empty() || name.len() > MAX_STACK_NAME || name.chars().any(char::is_whitespace)
    {
        return Err(Error::Config(format!(
            "bad compression stack name '{name}': need 1..={MAX_STACK_NAME} bytes, \
             no whitespace"
        )));
    }
    stack.validate_caps()?;
    let mut m = map().write().expect("compression registry poisoned");
    if m.contains_key(&name) {
        return Err(Error::Config(format!(
            "compression stack '{name}' is already registered"
        )));
    }
    m.insert(name, Arc::new(stack));
    Ok(())
}

/// All registered stack names, sorted.
pub fn names() -> Vec<String> {
    map().read().expect("compression registry poisoned").keys().cloned().collect()
}

/// All registered stacks, sorted by name — what `mpamp compressors`
/// tabulates (name, parts, capability flags, description).
pub fn all() -> Vec<Arc<CompressionStack>> {
    map().read().expect("compression registry poisoned").values().cloned().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{BlockCtx, SymbolModel};
    use crate::se::prior::BgChannel;
    use crate::signal::BernoulliGauss;

    fn ctx(len: usize) -> DesignCtx {
        let base = BgChannel::new(BernoulliGauss::standard(0.05));
        let (channel, noise_var) = base.worker_channel(0.05, 6);
        DesignCtx { channel, noise_var, clip_sds: crate::compress::CLIP_SDS, len, seed: 7 }
    }

    #[test]
    fn builtins_present_and_sorted() {
        let names = names();
        for want in
            ["ecsq.analytic", "ecsq.range", "ecsq.huffman", "ecsq-dithered.range", "topk.raw"]
        {
            assert!(names.iter().any(|n| n == want), "missing {want} in {names:?}");
        }
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
        assert!(names.iter().any(|n| n == DEFAULT_STACK));
    }

    #[test]
    fn get_unknown_lists_known() {
        let err = get("ecsq.lzma").unwrap_err().to_string();
        assert!(err.contains("ecsq.range"), "{err}");
        assert!(err.contains("topk.raw"), "{err}");
    }

    #[test]
    fn register_rejects_duplicates_and_bad_names() {
        struct NopQ;
        impl Quantizer for NopQ {
            fn family(&self) -> &'static str {
                "nop"
            }
            fn design_mse(&self, _: &DesignCtx, _: f64) -> Result<Box<dyn QuantizerState>> {
                unimplemented!()
            }
            fn design_rate(&self, _: &DesignCtx, _: f64) -> Result<Box<dyn QuantizerState>> {
                unimplemented!()
            }
            fn from_params(&self, _: &DesignCtx, _: &[f64]) -> Result<Box<dyn QuantizerState>> {
                unimplemented!()
            }
        }
        let mk = |name: &str| {
            CompressionStack::new(name, Arc::new(NopQ), Arc::new(RawSymbolCodec))
        };
        assert!(register(mk("ecsq.range")).is_err(), "built-in must not be replaced");
        assert!(register(mk("")).is_err());
        assert!(register(mk("has space")).is_err());
        register(mk("nop.test-registry")).unwrap();
        assert!(register(mk("nop.test-registry")).is_err(), "duplicate");
        assert!(get("nop.test-registry").is_ok());
    }

    #[test]
    fn design_then_assemble_roundtrips_every_builtin() {
        // Registry smoke: every built-in designs from a rate, re-assembles
        // from its own params, and round-trips a block through
        // encode/decode to the same reconstruction.
        let len = 400usize;
        let c = ctx(len);
        let mut rng = crate::util::rng::Rng::new(11);
        let xs: Vec<f32> = (0..len)
            .map(|_| {
                (c.channel.prior.sample(&mut rng) + rng.gaussian() * c.noise_var.sqrt()) as f32
            })
            .collect();
        for name in names() {
            let stack = get(&name).unwrap();
            if stack.name().starts_with("nop.") {
                continue; // test-registered stub from another test
            }
            let state = stack.design_rate(&c, 3.0).unwrap();
            let comp = stack.assemble(&c, &state.params()).unwrap();
            let bctx = BlockCtx { worker: 2 };
            let syms = comp.quantize(&bctx, &xs);
            let mut direct = vec![0f32; len];
            comp.dequantize(&bctx, &syms, &mut direct).unwrap();
            if comp.carries_payload() {
                let block = comp.encode(&bctx, &xs).unwrap();
                let mut via_wire = vec![0f32; len];
                comp.decode(&bctx, &block.bytes, &mut via_wire).unwrap();
                for (i, (a, b)) in direct.iter().zip(&via_wire).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "{name}: element {i}");
                }
                // Byte-aligned codecs: wire_bits within one byte of 8·len.
                assert!(
                    block.bytes.len() as f64 * 8.0 >= block.wire_bits
                        && block.bytes.len() as f64 * 8.0 - block.wire_bits < 8.0,
                    "{name}: {} bytes vs {} wire bits",
                    block.bytes.len(),
                    block.wire_bits
                );
            }
            assert!(comp.distortion_model() >= 0.0, "{name}");
            assert!(comp.model_bits_per_element() >= 0.0, "{name}");
        }
    }

    #[test]
    fn caps_advertised_and_incompatible_pairs_rejected() {
        let topk = get("topk.raw").unwrap();
        assert_eq!(
            topk.caps(),
            StackCaps { needs_model_pmf: false, payload_free: false }
        );
        let analytic = get("ecsq.analytic").unwrap();
        assert!(analytic.caps().payload_free);
        assert!(analytic.caps().needs_model_pmf);
        assert!(!get("ecsq.range").unwrap().caps().payload_free);
        // A model-free quantizer under a model-based codec is impossible
        // to assemble — rejected at registration, with the stack named.
        let bad = CompressionStack::new(
            "topk.range-bad",
            Arc::new(TopKQuantizer),
            Arc::new(RangeCodec),
        );
        let err = register(bad).unwrap_err().to_string();
        assert!(err.contains("needs a symbol-model pmf"), "{err}");
        assert!(err.contains("topk.range-bad"), "{err}");
        assert!(get("topk.range-bad").is_err(), "bad stack must not register");
        // Every built-in carries a real description for the CLI table.
        for s in all() {
            if !s.name().starts_with("nop.") {
                assert!(!s.description().is_empty(), "{} lacks description", s.name());
            }
        }
    }

    #[test]
    fn sign_model_entropy_matches_hand_value() {
        let m = SymbolModel { pmf: vec![0.5, 0.5] };
        assert!((m.entropy_bits() - 1.0).abs() < 1e-12);
    }
}
