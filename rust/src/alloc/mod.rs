//! Coding-rate allocation across AMP iterations — the paper's two schemes:
//! the online back-tracking heuristic ([`backtrack`], §3.3) and the
//! dynamic-programming optimum ([`dp`], §3.4) — plus the unified
//! per-iteration [`schedule::Directive`] interface the coordinator consumes.

pub mod backtrack;
pub mod dp;
pub mod schedule;

pub use backtrack::{BtController, BtDecision, RateModel};
pub use dp::{DpAllocator, DpResult};
pub use schedule::{Directive, RateController};
