//! Coding-rate allocation across AMP iterations — the paper's two schemes:
//! the online back-tracking heuristic ([`backtrack`], §3.3) and the
//! dynamic-programming optimum ([`dp`], §3.4) — behind the open
//! [`schedule::RateAllocator`] trait whose per-iteration
//! [`schedule::Directive`]s the coordinator consumes.

pub mod backtrack;
pub mod dp;
pub mod schedule;

pub use backtrack::{BtController, BtDecision, RateModel};
pub use dp::{DpAllocator, DpResult};
pub use schedule::{allocator_from_config, Directive, RateAllocator};
