//! DP-MP-AMP: optimal per-iteration coding-rate allocation by dynamic
//! programming (paper §3.4, eqs. 9–12).
//!
//! Given a total budget `R` (bits/element across all `T` iterations) and a
//! rate resolution `ΔR`, the allocator builds the `S×T` table `Σ` where
//! `Σ[s][t]` is the minimal `σ²_{t,D}` achievable when `R^{(s)} = s·ΔR`
//! bits have been spent in the first `t` iterations (eq. 11, with eq. 12 as
//! the first column), plus a backpointer table to recover the allocation.
//! The per-step map `f₁(σ², R_t)` composes the RD inverse (rate → σ_Q² for
//! the iteration-t uplink source) with the quantization-aware SE step
//! (eq. 8); both are monotone, which is what makes the recursion valid.

use crate::error::{Error, Result};
use crate::rd::RdCache;
use crate::se::table::MmseTable;
use crate::se::StateEvolution;

/// Result of a DP allocation.
#[derive(Debug, Clone)]
pub struct DpResult {
    /// Per-iteration rates `R_t` (bits/element), length T, summing to R.
    pub rates: Vec<f64>,
    /// Predicted `σ²_{t,D}` trajectory (length T+1, exact SE along `rates`).
    pub sigma_d2: Vec<f64>,
    /// Per-iteration per-worker quantization MSE targets σ_Q².
    pub sigma_q2: Vec<f64>,
    /// Table dimensions (S, T).
    pub dims: (usize, usize),
    /// Optimal final `σ²_{T,D}` from the table (table-precision SE).
    pub table_final_sigma_d2: f64,
}

/// DP-MP-AMP allocator.
pub struct DpAllocator<'a> {
    se: &'a StateEvolution,
    p_workers: usize,
    cache: &'a RdCache,
    mmse: MmseTable,
}

impl<'a> DpAllocator<'a> {
    /// Build (precomputes the MMSE interpolation table).
    pub fn new(se: &'a StateEvolution, p_workers: usize, cache: &'a RdCache) -> Result<Self> {
        let sigma0 = se.sigma0_sq();
        // Effective noise range: lower end below the centralized fixed
        // point, upper end σ_0² plus the worst-case quantization noise
        // (zero-rate: P·Var(F^p) = ε σ_s²/P + σ²).
        let fp = se.fixed_point(1e-10, 400);
        let worst_q = se.channel.prior.eps * se.channel.prior.sigma_s2 / p_workers as f64
            + sigma0;
        let lo = (fp.min(se.sigma_e2) * 0.5).max(1e-12);
        let hi = (sigma0 + worst_q) * 1.1;
        let mmse = MmseTable::build(&se.channel, lo, hi, 768)?;
        Ok(DpAllocator { se, p_workers, cache, mmse })
    }

    /// One step `f₁(σ², R)`: RD-optimal σ_Q² at rate R, then eq. 8.
    #[inline]
    fn f1(&self, sigma2: f64, rate: f64) -> f64 {
        let sigma_q2 = self.cache.mse_for_rate(sigma2, rate);
        let eff = sigma2 + self.p_workers as f64 * sigma_q2;
        self.se.sigma_e2 + self.mmse.mmse(eff) / self.se.kappa
    }

    /// Exact (non-table) version of `f₁`, used to report the final
    /// trajectory at full precision.
    fn f1_exact(&self, sigma2: f64, rate: f64) -> (f64, f64) {
        let sigma_q2 = self.cache.mse_for_rate(sigma2, rate);
        let next = self.se.step_quantized(sigma2, self.p_workers as f64 * sigma_q2);
        (next, sigma_q2)
    }

    /// Solve for `t_iters` iterations with budget `total_rate` at
    /// resolution `delta_r`.
    pub fn solve(&self, t_iters: usize, total_rate: f64, delta_r: f64) -> Result<DpResult> {
        if t_iters == 0 {
            return Err(Error::Config("DP needs at least one iteration".into()));
        }
        if total_rate <= 0.0 || delta_r <= 0.0 {
            return Err(Error::Config("DP rates must be positive".into()));
        }
        let s_count = (total_rate / delta_r).round() as usize + 1;
        if s_count < 2 || s_count > 100_000 {
            return Err(Error::Config(format!("bad DP grid size S={s_count}")));
        }
        let sigma0 = self.se.sigma0_sq();
        let threads = crate::config::num_threads_default();

        // Column t=0 (eq. 12): spend s·ΔR in the first iteration.
        let mut prev: Vec<f64> = (0..s_count)
            .map(|s| self.f1(sigma0, s as f64 * delta_r))
            .collect();
        // Backpointers: bp[t][s] = r index of the *previous* column.
        let mut bp: Vec<Vec<u32>> = Vec::with_capacity(t_iters);
        bp.push((0..s_count as u32).collect()); // t=0: all budget in iter 0

        for _t in 1..t_iters {
            let mut cur = vec![f64::INFINITY; s_count];
            let mut bpt = vec![0u32; s_count];
            let prev_ref = &prev;
            std::thread::scope(|scope| {
                let chunk = s_count.div_ceil(threads);
                let mut cur_slices: Vec<&mut [f64]> = cur.chunks_mut(chunk).collect();
                let mut bp_slices: Vec<&mut [u32]> = bpt.chunks_mut(chunk).collect();
                for ti in (0..cur_slices.len()).rev() {
                    let cur_chunk = cur_slices.pop().unwrap();
                    let bp_chunk = bp_slices.pop().unwrap();
                    let s0 = ti * chunk;
                    scope.spawn(move || {
                        for (off, (c, b)) in
                            cur_chunk.iter_mut().zip(bp_chunk.iter_mut()).enumerate()
                        {
                            let s = s0 + off;
                            let mut best = f64::INFINITY;
                            let mut best_r = 0u32;
                            // eq. 11: min over previous spend r ≤ s.
                            for r in 0..=s {
                                let rate_t = (s - r) as f64 * delta_r;
                                let v = self.f1(prev_ref[r], rate_t);
                                if v < best {
                                    best = v;
                                    best_r = r as u32;
                                }
                            }
                            *c = best;
                            *b = best_r;
                        }
                    });
                }
            });
            prev = cur;
            bp.push(bpt);
        }

        // Recover the allocation from the backpointers, starting at full
        // budget (monotonicity ⇒ spending everything is optimal).
        let mut rates_rev = Vec::with_capacity(t_iters);
        let mut s = s_count - 1;
        for t in (1..t_iters).rev() {
            let r = bp[t][s] as usize;
            rates_rev.push((s - r) as f64 * delta_r);
            s = r;
        }
        rates_rev.push(s as f64 * delta_r); // iteration 0 gets the rest
        let rates: Vec<f64> = rates_rev.into_iter().rev().collect();
        debug_assert!((rates.iter().sum::<f64>() - total_rate).abs() < 1e-9);

        // Exact trajectory along the chosen allocation.
        let mut sigma_d2 = Vec::with_capacity(t_iters + 1);
        let mut sigma_q2 = Vec::with_capacity(t_iters);
        let mut cur_s2 = sigma0;
        sigma_d2.push(cur_s2);
        for &r in &rates {
            let (next, q2) = self.f1_exact(cur_s2, r);
            sigma_q2.push(q2);
            sigma_d2.push(next);
            cur_s2 = next;
        }
        Ok(DpResult {
            rates,
            sigma_d2,
            sigma_q2,
            dims: (s_count, t_iters),
            table_final_sigma_d2: prev[s_count - 1],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RdConfig;
    use crate::signal::{sigma_e2_for_snr, BernoulliGauss};

    fn setup(eps: f64, p: usize) -> (StateEvolution, RdCache) {
        let prior = BernoulliGauss::standard(eps);
        let kappa = 0.3;
        let se = StateEvolution::new(prior, kappa, sigma_e2_for_snr(&prior, kappa, 20.0));
        let fp = se.fixed_point(1e-10, 300);
        let cfg = RdConfig { alphabet: 161, curve_points: 12, tol: 1e-5, gamma_grid: 9 };
        let cache = RdCache::build(&prior, p, fp * 0.5, se.sigma0_sq() * 2.0, &cfg).unwrap();
        (se, cache)
    }

    #[test]
    fn dp_beats_uniform_allocation() {
        let (se, cache) = setup(0.05, 30);
        let alloc = DpAllocator::new(&se, 30, &cache).unwrap();
        let t = 6;
        let total = 12.0;
        let dp = alloc.solve(t, total, 0.25).unwrap();
        // Uniform allocation as comparison.
        let mut s2 = se.sigma0_sq();
        for _ in 0..t {
            let q2 = cache.mse_for_rate(s2, total / t as f64);
            s2 = se.step_quantized(s2, 30.0 * q2);
        }
        let dp_final = *dp.sigma_d2.last().unwrap();
        assert!(
            dp_final <= s2 * 1.02,
            "DP {dp_final} should beat uniform {s2}"
        );
        assert_eq!(dp.rates.len(), t);
        assert!((dp.rates.iter().sum::<f64>() - total).abs() < 1e-9);
    }

    #[test]
    fn dp_rates_nonnegative_and_final_reasonable() {
        let (se, cache) = setup(0.05, 30);
        let alloc = DpAllocator::new(&se, 30, &cache).unwrap();
        let dp = alloc.solve(5, 10.0, 0.5).unwrap();
        assert!(dp.rates.iter().all(|&r| r >= 0.0));
        // With 2 bits/iter avg the final σ² should be well below σ_0².
        assert!(*dp.sigma_d2.last().unwrap() < se.sigma0_sq() * 0.3);
        // Table-precision and exact trajectories agree loosely.
        let exact = *dp.sigma_d2.last().unwrap();
        assert!(
            (dp.table_final_sigma_d2 / exact - 1.0).abs() < 0.05,
            "table {} vs exact {exact}",
            dp.table_final_sigma_d2
        );
    }

    #[test]
    fn dp_rates_increase_toward_later_iterations() {
        // The paper's Fig. 1 shows DP allocating more rate as t → T
        // (early iterations tolerate more noise). Check the trend:
        // the mean of the second half exceeds the mean of the first half.
        let (se, cache) = setup(0.05, 30);
        let alloc = DpAllocator::new(&se, 30, &cache).unwrap();
        let t = 8;
        let dp = alloc.solve(t, 16.0, 0.25).unwrap();
        let first: f64 = dp.rates[..t / 2].iter().sum();
        let second: f64 = dp.rates[t / 2..].iter().sum();
        assert!(
            second > first,
            "expected increasing allocation, got {:?}",
            dp.rates
        );
    }

    #[test]
    fn more_budget_never_hurts() {
        let (se, cache) = setup(0.1, 10);
        let alloc = DpAllocator::new(&se, 10, &cache).unwrap();
        let a = alloc.solve(4, 4.0, 0.5).unwrap();
        let b = alloc.solve(4, 8.0, 0.5).unwrap();
        assert!(
            b.sigma_d2.last().unwrap() <= &(a.sigma_d2.last().unwrap() * 1.001),
            "more budget worse: {:?} vs {:?}",
            b.sigma_d2.last(),
            a.sigma_d2.last()
        );
    }

    #[test]
    fn invalid_args_rejected() {
        let (se, cache) = setup(0.05, 30);
        let alloc = DpAllocator::new(&se, 30, &cache).unwrap();
        assert!(alloc.solve(0, 10.0, 0.1).is_err());
        assert!(alloc.solve(5, -1.0, 0.1).is_err());
        assert!(alloc.solve(5, 10.0, 0.0).is_err());
    }
}
