//! Unified rate-schedule interface consumed by the MP-AMP session: every
//! scheme (uncompressed / fixed / BT / DP) reduces to a per-iteration
//! [`Directive`] telling the workers how to code `f_t^p`.

use crate::alloc::backtrack::{BtController, RateModel};
use crate::alloc::dp::{DpAllocator, DpResult};
use crate::config::{RunConfig, ScheduleKind};
use crate::error::Result;
use crate::rd::RdCache;
use crate::se::StateEvolution;

/// What the workers should do with `f_t^p` this iteration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Directive {
    /// Send raw 32-bit floats (32 bits/element on the wire).
    Raw,
    /// ECSQ with the given per-worker quantization MSE target.
    QuantizeMse(f64),
    /// ECSQ designed for the given rate (bits/element).
    QuantizeRate(f64),
    /// Send nothing (zero-rate iteration; fusion reconstructs zeros).
    Skip,
}

/// A resolved rate controller for one run.
pub enum RateController {
    /// 32-bit float baseline.
    Uncompressed,
    /// Fixed rate every iteration.
    Fixed {
        /// Bits/element per iteration.
        bits: f64,
    },
    /// BT-MP-AMP (online; decisions depend on σ̂²_{t,D}).
    BackTrack {
        /// The controller.
        ratio_max: f64,
        /// Per-iteration cap.
        r_max: f64,
    },
    /// DP-MP-AMP (offline; rates precomputed).
    Dp {
        /// The DP solution.
        result: DpResult,
    },
}

impl RateController {
    /// Resolve a config into a controller (runs the DP solver if needed).
    pub fn from_config(
        cfg: &RunConfig,
        se: &StateEvolution,
        cache: Option<&RdCache>,
    ) -> Result<Self> {
        Ok(match &cfg.schedule {
            ScheduleKind::Uncompressed => RateController::Uncompressed,
            ScheduleKind::Fixed { bits } => RateController::Fixed { bits: *bits },
            ScheduleKind::BackTrack { ratio_max, r_max } => {
                RateController::BackTrack { ratio_max: *ratio_max, r_max: *r_max }
            }
            ScheduleKind::Dp { total_rate, delta_r } => {
                let cache = cache.ok_or_else(|| {
                    crate::error::Error::Config("DP schedule requires an RdCache".into())
                })?;
                let total = total_rate.unwrap_or(2.0 * cfg.iters as f64);
                let alloc = DpAllocator::new(se, cfg.p, cache)?;
                let result = alloc.solve(cfg.iters, total, *delta_r)?;
                RateController::Dp { result }
            }
        })
    }

    /// Directive for iteration `t` given the current σ̂²_{t,D} estimate.
    pub fn directive(
        &self,
        t: usize,
        sigma_d2_hat: f64,
        se: &StateEvolution,
        p_workers: usize,
        t_iters: usize,
        cache: Option<&RdCache>,
    ) -> Directive {
        match self {
            RateController::Uncompressed => Directive::Raw,
            RateController::Fixed { bits } => Directive::QuantizeRate(*bits),
            RateController::BackTrack { ratio_max, r_max } => {
                let ctl = BtController::new(se, p_workers, *ratio_max, *r_max, t_iters);
                let d = ctl.decide(t, sigma_d2_hat, RateModel::Ecsq, cache);
                if d.sigma_q2 <= 0.0 {
                    Directive::QuantizeRate(*r_max)
                } else {
                    Directive::QuantizeMse(d.sigma_q2)
                }
            }
            RateController::Dp { result } => {
                let rate = result.rates.get(t).copied().unwrap_or(0.0);
                if rate <= 0.0 {
                    Directive::Skip
                } else {
                    // ECSQ realization of the DP's RD-optimal σ_Q² target:
                    // quantize to the σ_Q² the DP assumed; the entropy coder
                    // then costs ≈ rate + 0.255 bits (paper §4).
                    Directive::QuantizeMse(
                        result.sigma_q2.get(t).copied().unwrap_or(f64::INFINITY),
                    )
                }
            }
        }
    }

    /// Human-readable name (reports).
    pub fn name(&self) -> &'static str {
        match self {
            RateController::Uncompressed => "uncompressed",
            RateController::Fixed { .. } => "fixed",
            RateController::BackTrack { .. } => "bt",
            RateController::Dp { .. } => "dp",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RdConfig;
    use crate::signal::{sigma_e2_for_snr, BernoulliGauss};

    fn se_cache(eps: f64, p: usize) -> (StateEvolution, RdCache) {
        let prior = BernoulliGauss::standard(eps);
        let kappa = 0.3;
        let se = StateEvolution::new(prior, kappa, sigma_e2_for_snr(&prior, kappa, 20.0));
        let fp = se.fixed_point(1e-10, 300);
        let cfg = RdConfig { alphabet: 161, curve_points: 12, tol: 1e-5, gamma_grid: 9 };
        let cache = RdCache::build(&prior, p, fp * 0.5, se.sigma0_sq() * 2.0, &cfg).unwrap();
        (se, cache)
    }

    #[test]
    fn uncompressed_and_fixed_directives() {
        let mut cfg = RunConfig::test_small(0.05);
        let (se, cache) = se_cache(0.05, cfg.p);
        cfg.schedule = ScheduleKind::Uncompressed;
        let rc = RateController::from_config(&cfg, &se, Some(&cache)).unwrap();
        assert_eq!(
            rc.directive(0, se.sigma0_sq(), &se, cfg.p, cfg.iters, Some(&cache)),
            Directive::Raw
        );
        cfg.schedule = ScheduleKind::Fixed { bits: 3.0 };
        let rc = RateController::from_config(&cfg, &se, Some(&cache)).unwrap();
        assert_eq!(
            rc.directive(2, 0.1, &se, cfg.p, cfg.iters, Some(&cache)),
            Directive::QuantizeRate(3.0)
        );
    }

    #[test]
    fn dp_controller_resolves_rates() {
        let mut cfg = RunConfig::test_small(0.05);
        cfg.schedule = ScheduleKind::Dp { total_rate: Some(8.0), delta_r: 0.5 };
        let (se, cache) = se_cache(0.05, cfg.p);
        let rc = RateController::from_config(&cfg, &se, Some(&cache)).unwrap();
        if let RateController::Dp { result } = &rc {
            assert_eq!(result.rates.len(), cfg.iters);
            assert!((result.rates.iter().sum::<f64>() - 8.0).abs() < 1e-9);
        } else {
            panic!("expected DP controller");
        }
        // Directives: Skip for zero-rate, QuantizeMse otherwise.
        for t in 0..cfg.iters {
            let d = rc.directive(t, 0.1, &se, cfg.p, cfg.iters, Some(&cache));
            match d {
                Directive::Skip | Directive::QuantizeMse(_) => {}
                other => panic!("unexpected directive {other:?}"),
            }
        }
    }

    #[test]
    fn bt_controller_gives_quantize_directives() {
        let mut cfg = RunConfig::test_small(0.05);
        cfg.schedule = ScheduleKind::BackTrack { ratio_max: 1.05, r_max: 6.0 };
        let (se, cache) = se_cache(0.05, cfg.p);
        let rc = RateController::from_config(&cfg, &se, Some(&cache)).unwrap();
        let d = rc.directive(0, se.sigma0_sq(), &se, cfg.p, cfg.iters, Some(&cache));
        match d {
            Directive::QuantizeMse(q) => assert!(q > 0.0),
            Directive::QuantizeRate(r) => assert!(r > 0.0),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn dp_without_cache_errors() {
        let mut cfg = RunConfig::test_small(0.05);
        cfg.schedule = ScheduleKind::Dp { total_rate: None, delta_r: 0.5 };
        let prior = cfg.prior;
        let se = StateEvolution::new(prior, cfg.kappa(), cfg.sigma_e2());
        assert!(RateController::from_config(&cfg, &se, None).is_err());
    }
}
