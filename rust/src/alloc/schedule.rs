//! Rate allocation as an open trait: every scheme reduces to a
//! per-iteration [`Directive`] telling the workers how to code `f_t^p`.
//!
//! [`RateAllocator`] replaces the old closed `RateController` enum — the
//! uncompressed / fixed / BT / DP schemes are now ordinary impls
//! ([`RawAllocator`], [`FixedRateAllocator`], [`BtRateAllocator`],
//! [`DpRateAllocator`]), and a session accepts any
//! `Box<dyn RateAllocator>`; [`allocator_from_config`] resolves the
//! config's `ScheduleKind` into one (running the DP solver when needed).

use crate::alloc::backtrack::{BtController, RateModel};
use crate::alloc::dp::{DpAllocator, DpResult};
use crate::config::{RunConfig, ScheduleKind};
use crate::error::Result;
use crate::rd::RdCache;
use crate::se::StateEvolution;

/// What the workers should do with `f_t^p` this iteration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Directive {
    /// Send raw 32-bit floats (32 bits/element on the wire).
    Raw,
    /// Quantize to the given per-worker quantization MSE target.
    QuantizeMse(f64),
    /// Quantize at the given design rate (bits/element).
    QuantizeRate(f64),
    /// Send nothing (zero-rate iteration; fusion reconstructs zeros).
    Skip,
}

/// A per-iteration coding-rate policy. Implementations see the online
/// σ̂²_{t,D} estimate each round and answer with a [`Directive`]; whether
/// the directive is realized by ECSQ, dithered ECSQ, top-K, or a custom
/// stack is the compression registry's business, not the allocator's.
pub trait RateAllocator: Send + Sync {
    /// Directive for iteration `t` given the current σ̂²_{t,D} estimate.
    fn directive(
        &self,
        t: usize,
        sigma_d2_hat: f64,
        se: &StateEvolution,
        p_workers: usize,
        t_iters: usize,
        cache: Option<&RdCache>,
    ) -> Directive;

    /// Human-readable scheme name (reports).
    fn name(&self) -> &'static str;
}

/// 32-bit float baseline (the paper's uncompressed MP-AMP).
#[derive(Debug, Clone, Copy, Default)]
pub struct RawAllocator;

impl RateAllocator for RawAllocator {
    fn directive(
        &self,
        _t: usize,
        _sigma_d2_hat: f64,
        _se: &StateEvolution,
        _p_workers: usize,
        _t_iters: usize,
        _cache: Option<&RdCache>,
    ) -> Directive {
        Directive::Raw
    }

    fn name(&self) -> &'static str {
        "uncompressed"
    }
}

/// Fixed rate (bits/element) every iteration.
#[derive(Debug, Clone, Copy)]
pub struct FixedRateAllocator {
    /// Bits/element per iteration.
    pub bits: f64,
}

impl RateAllocator for FixedRateAllocator {
    fn directive(
        &self,
        _t: usize,
        _sigma_d2_hat: f64,
        _se: &StateEvolution,
        _p_workers: usize,
        _t_iters: usize,
        _cache: Option<&RdCache>,
    ) -> Directive {
        Directive::QuantizeRate(self.bits)
    }

    fn name(&self) -> &'static str {
        "fixed"
    }
}

/// BT-MP-AMP (paper §3.3): online back-tracking, decisions depend on the
/// measured σ̂²_{t,D}.
///
/// The controller prices its σ_Q² targets with the **ECSQ rate model**
/// (`RateModel::Ecsq`), as in the paper. Non-ECSQ stacks still realize
/// the σ_Q² targets correctly (the quantization-aware SE uses each
/// stack's own `distortion_model()`), but their *bit cost* for hitting a
/// target can differ from the model — e.g. `topk.raw` pays `64·K/len`
/// bits, so `r_max` bounds the modeled rate, not top-K's wire rate.
/// Coupling allocators to the registered stack's R(D) is a ROADMAP open
/// item.
#[derive(Debug, Clone, Copy)]
pub struct BtRateAllocator {
    /// Allowed ratio `σ²_{t+1,D} / σ²_{t+1,C}`.
    pub ratio_max: f64,
    /// Per-iteration rate cap in bits/element.
    pub r_max: f64,
}

impl RateAllocator for BtRateAllocator {
    fn directive(
        &self,
        t: usize,
        sigma_d2_hat: f64,
        se: &StateEvolution,
        p_workers: usize,
        t_iters: usize,
        cache: Option<&RdCache>,
    ) -> Directive {
        let ctl = BtController::new(se, p_workers, self.ratio_max, self.r_max, t_iters);
        let d = ctl.decide(t, sigma_d2_hat, RateModel::Ecsq, cache);
        if d.sigma_q2 <= 0.0 {
            Directive::QuantizeRate(self.r_max)
        } else {
            Directive::QuantizeMse(d.sigma_q2)
        }
    }

    fn name(&self) -> &'static str {
        "bt"
    }
}

/// DP-MP-AMP (paper §3.4): offline dynamic-programming allocation; the
/// rates are precomputed at construction.
#[derive(Debug, Clone)]
pub struct DpRateAllocator {
    /// The DP solution.
    pub result: DpResult,
}

impl RateAllocator for DpRateAllocator {
    fn directive(
        &self,
        t: usize,
        _sigma_d2_hat: f64,
        _se: &StateEvolution,
        _p_workers: usize,
        _t_iters: usize,
        _cache: Option<&RdCache>,
    ) -> Directive {
        let rate = self.result.rates.get(t).copied().unwrap_or(0.0);
        if rate <= 0.0 {
            Directive::Skip
        } else {
            // ECSQ realization of the DP's RD-optimal σ_Q² target:
            // quantize to the σ_Q² the DP assumed; the entropy coder
            // then costs ≈ rate + 0.255 bits (paper §4).
            Directive::QuantizeMse(self.result.sigma_q2.get(t).copied().unwrap_or(f64::INFINITY))
        }
    }

    fn name(&self) -> &'static str {
        "dp"
    }
}

/// Resolve a config's `ScheduleKind` into an allocator (runs the DP
/// solver if needed).
pub fn allocator_from_config(
    cfg: &RunConfig,
    se: &StateEvolution,
    cache: Option<&RdCache>,
) -> Result<Box<dyn RateAllocator>> {
    Ok(match &cfg.schedule {
        ScheduleKind::Uncompressed => Box::new(RawAllocator),
        ScheduleKind::Fixed { bits } => Box::new(FixedRateAllocator { bits: *bits }),
        ScheduleKind::BackTrack { ratio_max, r_max } => {
            Box::new(BtRateAllocator { ratio_max: *ratio_max, r_max: *r_max })
        }
        ScheduleKind::Dp { total_rate, delta_r } => {
            let cache = cache.ok_or_else(|| {
                crate::error::Error::Config("DP schedule requires an RdCache".into())
            })?;
            let total = total_rate.unwrap_or(2.0 * cfg.iters as f64);
            let alloc = DpAllocator::new(se, cfg.p, cache)?;
            let result = alloc.solve(cfg.iters, total, *delta_r)?;
            Box::new(DpRateAllocator { result })
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RdConfig;
    use crate::signal::{sigma_e2_for_snr, BernoulliGauss};

    fn se_cache(eps: f64, p: usize) -> (StateEvolution, RdCache) {
        let prior = BernoulliGauss::standard(eps);
        let kappa = 0.3;
        let se = StateEvolution::new(prior, kappa, sigma_e2_for_snr(&prior, kappa, 20.0));
        let fp = se.fixed_point(1e-10, 300);
        let cfg = RdConfig { alphabet: 161, curve_points: 12, tol: 1e-5, gamma_grid: 9 };
        let cache = RdCache::build(&prior, p, fp * 0.5, se.sigma0_sq() * 2.0, &cfg).unwrap();
        (se, cache)
    }

    #[test]
    fn uncompressed_and_fixed_directives() {
        let mut cfg = RunConfig::test_small(0.05);
        let (se, cache) = se_cache(0.05, cfg.p);
        cfg.schedule = ScheduleKind::Uncompressed;
        let rc = allocator_from_config(&cfg, &se, Some(&cache)).unwrap();
        assert_eq!(rc.name(), "uncompressed");
        assert_eq!(
            rc.directive(0, se.sigma0_sq(), &se, cfg.p, cfg.iters, Some(&cache)),
            Directive::Raw
        );
        cfg.schedule = ScheduleKind::Fixed { bits: 3.0 };
        let rc = allocator_from_config(&cfg, &se, Some(&cache)).unwrap();
        assert_eq!(rc.name(), "fixed");
        assert_eq!(
            rc.directive(2, 0.1, &se, cfg.p, cfg.iters, Some(&cache)),
            Directive::QuantizeRate(3.0)
        );
    }

    #[test]
    fn dp_allocator_resolves_rates() {
        let mut cfg = RunConfig::test_small(0.05);
        cfg.schedule = ScheduleKind::Dp { total_rate: Some(8.0), delta_r: 0.5 };
        let (se, cache) = se_cache(0.05, cfg.p);
        let rc = allocator_from_config(&cfg, &se, Some(&cache)).unwrap();
        assert_eq!(rc.name(), "dp");
        // Directives: Skip for zero-rate, QuantizeMse otherwise.
        for t in 0..cfg.iters {
            let d = rc.directive(t, 0.1, &se, cfg.p, cfg.iters, Some(&cache));
            match d {
                Directive::Skip | Directive::QuantizeMse(_) => {}
                other => panic!("unexpected directive {other:?}"),
            }
        }
        // Past the horizon the DP charges nothing.
        assert_eq!(
            rc.directive(cfg.iters + 3, 0.1, &se, cfg.p, cfg.iters, Some(&cache)),
            Directive::Skip
        );
    }

    #[test]
    fn bt_allocator_gives_quantize_directives() {
        let mut cfg = RunConfig::test_small(0.05);
        cfg.schedule = ScheduleKind::BackTrack { ratio_max: 1.05, r_max: 6.0 };
        let (se, cache) = se_cache(0.05, cfg.p);
        let rc = allocator_from_config(&cfg, &se, Some(&cache)).unwrap();
        assert_eq!(rc.name(), "bt");
        let d = rc.directive(0, se.sigma0_sq(), &se, cfg.p, cfg.iters, Some(&cache));
        match d {
            Directive::QuantizeMse(q) => assert!(q > 0.0),
            Directive::QuantizeRate(r) => assert!(r > 0.0),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn dp_without_cache_errors() {
        let mut cfg = RunConfig::test_small(0.05);
        cfg.schedule = ScheduleKind::Dp { total_rate: None, delta_r: 0.5 };
        let prior = cfg.prior;
        let se = StateEvolution::new(prior, cfg.kappa(), cfg.sigma_e2());
        assert!(allocator_from_config(&cfg, &se, None).is_err());
    }

    #[test]
    fn custom_allocator_plugs_in() {
        // The point of the trait: a scheme the repo never shipped — rate
        // halving per iteration — is a three-line impl.
        struct Halving {
            r0: f64,
        }
        impl RateAllocator for Halving {
            fn directive(
                &self,
                t: usize,
                _s: f64,
                _se: &StateEvolution,
                _p: usize,
                _ti: usize,
                _c: Option<&RdCache>,
            ) -> Directive {
                Directive::QuantizeRate(self.r0 / (1u64 << t.min(32)) as f64)
            }
            fn name(&self) -> &'static str {
                "halving"
            }
        }
        let cfg = RunConfig::test_small(0.05);
        let se = StateEvolution::new(cfg.prior, cfg.kappa(), cfg.sigma_e2());
        let b: Box<dyn RateAllocator> = Box::new(Halving { r0: 8.0 });
        assert_eq!(b.directive(1, 0.1, &se, cfg.p, cfg.iters, None), Directive::QuantizeRate(4.0));
        assert_eq!(b.name(), "halving");
    }
}
