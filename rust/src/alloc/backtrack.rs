//! BT-MP-AMP: the online back-tracking rate controller (paper §3.3).
//!
//! Before quantizing `f_t^p`, the fusion center (which knows
//! `σ̂²_{t,D} = Σ_p ‖z_t^p‖²/M` from the scalar uplink) computes the
//! centralized target `σ²_{t+1,C}` and finds the **largest** quantization
//! MSE σ_Q² such that the quantization-aware SE prediction stays within
//! `ratio_max` of the centralized value — subject to the per-iteration rate
//! cap `r_max`. Larger σ_Q² ⇒ coarser bins ⇒ fewer bits.

use crate::quant::UniformQuantizer;
use crate::rd::RdCache;
use crate::se::StateEvolution;

/// How the rate for a given σ_Q² is accounted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RateModel {
    /// RD function (the paper's "RD prediction" rows/curves).
    Rd,
    /// ECSQ entropy `H_Q` (the paper's "ECSQ simulation" rows/curves).
    Ecsq,
}

/// Per-iteration decision of the controller.
#[derive(Debug, Clone, Copy)]
pub struct BtDecision {
    /// Target per-worker quantization MSE.
    pub sigma_q2: f64,
    /// Rate in bits/element under the chosen [`RateModel`].
    pub rate: f64,
    /// SE-predicted σ²_{t+1,D} under this decision.
    pub predicted_next: f64,
}

/// BT-MP-AMP controller.
pub struct BtController<'a> {
    se: &'a StateEvolution,
    p_workers: usize,
    /// Allowed σ²_{t+1,D}/σ²_{t+1,C} ratio (> 1).
    pub ratio_max: f64,
    /// Per-iteration rate cap (bits/element).
    pub r_max: f64,
    /// Centralized SE trajectory σ²_{t,C}, t = 0..=T.
    pub centralized: Vec<f64>,
    /// Saturation range for ECSQ quantizers (std devs of the slab).
    pub clip_sds: f64,
}

impl<'a> BtController<'a> {
    /// Build for `t_iters` iterations.
    pub fn new(
        se: &'a StateEvolution,
        p_workers: usize,
        ratio_max: f64,
        r_max: f64,
        t_iters: usize,
    ) -> Self {
        BtController {
            se,
            p_workers,
            ratio_max,
            r_max,
            centralized: se.trajectory(t_iters),
            clip_sds: 8.0,
        }
    }

    /// Rate (bits/element) implied by a σ_Q² under the given model.
    pub fn rate_for_sigma_q2(
        &self,
        sigma_d2_hat: f64,
        sigma_q2: f64,
        model: RateModel,
        cache: Option<&RdCache>,
    ) -> f64 {
        match model {
            RateModel::Rd => cache
                .expect("RD rate model requires an RdCache")
                .rate_for_mse(sigma_d2_hat, sigma_q2),
            RateModel::Ecsq => {
                let (wch, ws2) = self.se.channel.worker_channel(sigma_d2_hat, self.p_workers);
                let clip = wch.clip_range(ws2, self.clip_sds);
                match UniformQuantizer::for_mse(sigma_q2, clip, 0.0) {
                    Ok(q) => q.entropy(&wch, ws2),
                    Err(_) => f64::INFINITY,
                }
            }
        }
    }

    /// σ_Q² achieving exactly `rate` bits under the model (inverse).
    pub fn sigma_q2_for_rate(
        &self,
        sigma_d2_hat: f64,
        rate: f64,
        model: RateModel,
        cache: Option<&RdCache>,
    ) -> f64 {
        match model {
            RateModel::Rd => cache
                .expect("RD rate model requires an RdCache")
                .mse_for_rate(sigma_d2_hat, rate),
            RateModel::Ecsq => {
                let (wch, ws2) = self.se.channel.worker_channel(sigma_d2_hat, self.p_workers);
                match UniformQuantizer::for_rate(&wch, ws2, rate, self.clip_sds, 0.0) {
                    Ok(q) => q.sigma_q2(),
                    // Rate unreachable → quantize as finely as possible.
                    Err(_) => 0.0,
                }
            }
        }
    }

    /// Decide the quantizer for iteration `t` (0-based) given the current
    /// residual-based estimate `σ̂²_{t,D}`.
    pub fn decide(
        &self,
        t: usize,
        sigma_d2_hat: f64,
        model: RateModel,
        cache: Option<&RdCache>,
    ) -> BtDecision {
        // Constrain the *excess* MSE over the noise floor:
        // `σ²_D − σ_e² ≤ ratio_max · (σ²_C − σ_e²)`, i.e. keep the SDR
        // within `10·log10(ratio_max)` dB of centralized — the quantity the
        // paper's Fig. 1 plots. (A constraint on the raw σ² ratio goes
        // slack near the fixed point, where σ² → σ_e² + excess.)
        let c_next = self.centralized[(t + 1).min(self.centralized.len() - 1)];
        let target = self.se.sigma_e2 + self.ratio_max * (c_next - self.se.sigma_e2);
        let pf = self.p_workers as f64;
        let lossless_next = self.se.step_quantized(sigma_d2_hat, 0.0);
        let (mut sigma_q2, mut rate);
        if lossless_next > target {
            // Even lossless transmission misses the target (the estimate is
            // behind the centralized trajectory) — spend the cap.
            sigma_q2 = self.sigma_q2_for_rate(sigma_d2_hat, self.r_max, model, cache);
            rate = self.r_max;
        } else {
            // Bisect the largest σ_Q² with predicted next ≤ target.
            // Upper bracket: worker-source variance (zero-rate regime).
            let (wch, ws2) = self.se.channel.worker_channel(sigma_d2_hat, self.p_workers);
            let mut hi = wch.var_f(ws2);
            let mut lo = 0.0f64;
            if self.se.step_quantized(sigma_d2_hat, pf * hi) <= target {
                lo = hi; // even zero rate meets the target
            } else {
                for _ in 0..60 {
                    let mid = 0.5 * (lo + hi);
                    if self.se.step_quantized(sigma_d2_hat, pf * mid) <= target {
                        lo = mid;
                    } else {
                        hi = mid;
                    }
                    if hi - lo <= 1e-9 * hi.max(1e-30) {
                        break;
                    }
                }
            }
            sigma_q2 = lo;
            rate = self.rate_for_sigma_q2(sigma_d2_hat, sigma_q2, model, cache);
            if rate > self.r_max {
                rate = self.r_max;
                sigma_q2 = self.sigma_q2_for_rate(sigma_d2_hat, rate, model, cache);
            }
        }
        let predicted_next = self.se.step_quantized(sigma_d2_hat, pf * sigma_q2);
        BtDecision { sigma_q2, rate, predicted_next }
    }

    /// Run the controller purely on SE (no data): returns per-iteration
    /// decisions and the predicted σ²_{t,D} trajectory. This generates the
    /// paper's offline BT curves.
    pub fn se_schedule(
        &self,
        t_iters: usize,
        model: RateModel,
        cache: Option<&RdCache>,
    ) -> (Vec<BtDecision>, Vec<f64>) {
        let mut traj = Vec::with_capacity(t_iters + 1);
        let mut decisions = Vec::with_capacity(t_iters);
        let mut s2 = self.se.sigma0_sq();
        traj.push(s2);
        for t in 0..t_iters {
            let d = self.decide(t, s2, model, cache);
            s2 = d.predicted_next;
            decisions.push(d);
            traj.push(s2);
        }
        (decisions, traj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RdConfig;
    use crate::signal::{sigma_e2_for_snr, BernoulliGauss};

    fn setup(eps: f64) -> (StateEvolution, RdCache) {
        let prior = BernoulliGauss::standard(eps);
        let kappa = 0.3;
        let se = StateEvolution::new(prior, kappa, sigma_e2_for_snr(&prior, kappa, 20.0));
        let fp = se.fixed_point(1e-10, 300);
        let cfg = RdConfig { alphabet: 161, curve_points: 12, tol: 1e-5, gamma_grid: 9 };
        let cache = RdCache::build(&prior, 30, fp * 0.5, se.sigma0_sq() * 2.0, &cfg).unwrap();
        (se, cache)
    }

    #[test]
    fn bt_tracks_centralized_within_ratio() {
        let (se, cache) = setup(0.05);
        let t_iters = 10;
        let ctl = BtController::new(&se, 30, 1.05, 6.0, t_iters);
        let (decisions, traj) = ctl.se_schedule(t_iters, RateModel::Rd, Some(&cache));
        assert_eq!(decisions.len(), t_iters);
        for (t, s2) in traj.iter().enumerate().skip(1) {
            let c = ctl.centralized[t];
            assert!(
                *s2 <= c * 1.30,
                "t={t}: σ_D²={s2} drifted beyond centralized {c}"
            );
        }
    }

    #[test]
    fn bt_rates_under_cap_and_under_6_bits() {
        // Paper: "BT-MP-AMP uses fewer than 6 bits per element in each
        // iteration".
        let (se, cache) = setup(0.05);
        let ctl = BtController::new(&se, 30, 1.05, 6.0, 10);
        for model in [RateModel::Rd, RateModel::Ecsq] {
            let (decisions, _) = ctl.se_schedule(10, model, Some(&cache));
            for (t, d) in decisions.iter().enumerate() {
                assert!(d.rate <= 6.0 + 1e-9, "{model:?} t={t}: rate {}", d.rate);
                assert!(d.rate >= 0.0);
                assert!(d.sigma_q2 >= 0.0);
            }
        }
    }

    #[test]
    fn ecsq_rate_exceeds_rd_rate_for_same_mse() {
        // ECSQ is suboptimal vs vector quantization at the same distortion:
        // H_Q ≥ R(D), approaching R(D)+0.255 at high rate.
        let (se, cache) = setup(0.05);
        let ctl = BtController::new(&se, 30, 1.05, 6.0, 10);
        let s2 = se.sigma0_sq() * 0.3;
        for q_frac in [1e-4, 1e-3] {
            let (wch, ws2) = se.channel.worker_channel(s2, 30);
            let sigma_q2 = q_frac * wch.var_f(ws2);
            let r_rd = ctl.rate_for_sigma_q2(s2, sigma_q2, RateModel::Rd, Some(&cache));
            let r_ecsq = ctl.rate_for_sigma_q2(s2, sigma_q2, RateModel::Ecsq, None);
            assert!(
                r_ecsq >= r_rd - 0.1,
                "ECSQ {r_ecsq} should be ≥ RD {r_rd} (σ_Q²={sigma_q2})"
            );
        }
    }

    #[test]
    fn tighter_ratio_needs_more_bits() {
        let (se, cache) = setup(0.05);
        let tight = BtController::new(&se, 30, 1.01, 12.0, 10);
        let loose = BtController::new(&se, 30, 1.30, 12.0, 10);
        let (dt, _) = tight.se_schedule(10, RateModel::Rd, Some(&cache));
        let (dl, _) = loose.se_schedule(10, RateModel::Rd, Some(&cache));
        let bits_tight: f64 = dt.iter().map(|d| d.rate).sum();
        let bits_loose: f64 = dl.iter().map(|d| d.rate).sum();
        assert!(
            bits_tight > bits_loose,
            "tight {bits_tight} ≤ loose {bits_loose}"
        );
    }

    #[test]
    fn decide_handles_bad_estimate_gracefully() {
        // If σ̂² is way behind the centralized trajectory, the controller
        // spends the cap instead of diverging.
        let (se, cache) = setup(0.05);
        let ctl = BtController::new(&se, 30, 1.05, 6.0, 10);
        let d = ctl.decide(8, se.sigma0_sq(), RateModel::Rd, Some(&cache));
        assert!((d.rate - 6.0).abs() < 1e-9);
    }
}
