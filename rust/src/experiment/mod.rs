//! Experiment orchestration: run grids of configured sessions across a
//! thread pool and collect their [`RunReport`]s.
//!
//! The MP-AMP literature's core experimental object is the sweep — SDR /
//! rate trade-off curves over ε × SNR × P × partitioning × budget grids —
//! and before this module every bench hand-rolled its own loop. [`Sweep`]
//! owns that scaffolding once: label each trial, optionally share one
//! problem instance across trials (so schedules — or the row vs. column
//! partitioning scenarios, see `benches/ablation_partitioning.rs` — are
//! compared on identical data), bound parallelism, and get back ordered
//! [`TrialReport`]s.
//!
//! ```no_run
//! use mpamp::experiment::Sweep;
//! use mpamp::SessionBuilder;
//!
//! let mut sweep = Sweep::new();
//! for eps in [0.03, 0.05, 0.10] {
//!     sweep.add(format!("bt/{eps}"), SessionBuilder::paper_default(eps));
//!     sweep.add(
//!         format!("dp/{eps}"),
//!         SessionBuilder::paper_default(eps).dp(None, 0.1),
//!     );
//! }
//! for trial in sweep.run().unwrap() {
//!     println!("{}: {:.2} dB", trial.label, trial.report.final_sdr_db());
//! }
//! ```

use std::sync::Mutex;

use crate::coordinator::builder::SessionBuilder;
use crate::coordinator::session::RunReport;
use crate::error::{Error, Result};
use crate::observe::StopSet;

/// One configured trial: a label plus a ready-to-build session.
struct Trial {
    label: String,
    builder: SessionBuilder,
}

/// One finished trial of a [`Sweep`].
#[derive(Debug, Clone)]
pub struct TrialReport {
    /// The label given at [`Sweep::add`] time.
    pub label: String,
    /// The run's report.
    pub report: RunReport,
}

/// A grid of sessions executed across a thread pool.
#[derive(Default)]
pub struct Sweep {
    trials: Vec<Trial>,
    threads: Option<usize>,
    stop: StopSet,
}

impl Sweep {
    /// New empty sweep.
    pub fn new() -> Self {
        Self::default()
    }

    /// Bound the number of concurrently running sessions (default: the
    /// machine's available parallelism, capped by the trial count). Each
    /// session spawns its own `P` worker threads, but all of their
    /// compute kernels dispatch to the one process-global
    /// [`Pool`](crate::runtime::pool::Pool) — concurrent trials share
    /// that bounded pool instead of oversubscribing the machine with
    /// per-kernel thread spawns, so this knob only bounds protocol
    /// (mostly-blocked) threads.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads.max(1));
        self
    }

    /// Apply these early-stopping rules to every trial.
    pub fn stop(mut self, stop: StopSet) -> Self {
        self.stop = stop;
        self
    }

    /// Queue a trial. The builder is validated/built inside the pool, so
    /// config errors surface per-trial from [`run`](Self::run) with the
    /// trial's label attached.
    pub fn add(&mut self, label: impl Into<String>, builder: SessionBuilder) {
        self.trials.push(Trial { label: label.into(), builder });
    }

    /// The compressor axis: queue one trial per compression stack — the
    /// base builder crossed with each registry name, labelled
    /// `"<label>/<stack>"`. Combine with per-schedule or per-partitioning
    /// loops to sweep stacks × schedules × partitionings in one call:
    ///
    /// ```no_run
    /// use mpamp::experiment::Sweep;
    /// use mpamp::SessionBuilder;
    ///
    /// let mut sweep = Sweep::new();
    /// for bits in [2.0, 4.0] {
    ///     sweep.add_compressors(
    ///         &format!("fixed{bits}"),
    ///         &SessionBuilder::test_small(0.05).fixed_rate(bits),
    ///         mpamp::compress::registry::names(),
    ///     );
    /// }
    /// for trial in sweep.run().unwrap() {
    ///     println!("{}: {:.2} dB", trial.label, trial.report.final_sdr_db());
    /// }
    /// ```
    pub fn add_compressors<I, S>(&mut self, label: &str, base: &SessionBuilder, stacks: I)
    where
        I: IntoIterator<Item = S>,
        S: AsRef<str>,
    {
        for stack in stacks {
            let stack = stack.as_ref();
            self.add(format!("{label}/{stack}"), base.clone().compressor(stack));
        }
    }

    /// Number of queued trials.
    pub fn len(&self) -> usize {
        self.trials.len()
    }

    /// Whether the sweep holds no trials.
    pub fn is_empty(&self) -> bool {
        self.trials.is_empty()
    }

    /// Execute every trial, at most `threads` at a time, and return their
    /// reports **in the order the trials were added**. The first trial
    /// error aborts the sweep: remaining queued trials are skipped, while
    /// already-running trials complete their runs normally before the
    /// pool drains.
    pub fn run(self) -> Result<Vec<TrialReport>> {
        let n = self.trials.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let pool = self
            .threads
            .unwrap_or_else(crate::config::num_threads_default)
            .min(n)
            .max(1);
        let stop = &self.stop;
        // Work queue: an index into `trials`; results slotted by index so
        // output order matches insertion order regardless of completion
        // order.
        let next = Mutex::new(0usize);
        let results: Vec<Mutex<Option<Result<TrialReport>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        let trials = self.trials;
        std::thread::scope(|s| {
            for _ in 0..pool {
                s.spawn(|| loop {
                    let idx = {
                        let mut guard = next.lock().expect("sweep queue poisoned");
                        if *guard >= n {
                            return;
                        }
                        let i = *guard;
                        *guard += 1;
                        i
                    };
                    let trial = &trials[idx];
                    let outcome = trial
                        .builder
                        .clone()
                        .build()
                        .and_then(|session| {
                            session.run_observed(
                                &mut crate::observe::NullObserver,
                                stop,
                            )
                        })
                        .map(|report| TrialReport {
                            label: trial.label.clone(),
                            report,
                        })
                        .map_err(|e| label_error(&trial.label, e));
                    let abort = outcome.is_err();
                    *results[idx].lock().expect("sweep result poisoned") =
                        Some(outcome);
                    if abort {
                        // Drain the queue so other pool threads stop
                        // picking up new trials.
                        *next.lock().expect("sweep queue poisoned") = n;
                        return;
                    }
                });
            }
        });
        let mut out = Vec::with_capacity(n);
        for slot in results {
            match slot.into_inner().expect("sweep result poisoned") {
                Some(Ok(r)) => out.push(r),
                Some(Err(e)) => return Err(e),
                // Skipped after an abort: find and return the error below.
                None => {}
            }
        }
        Ok(out)
    }
}

/// Attach the trial label to an error's message while keeping its
/// variant, so callers can still match on the error kind.
fn label_error(label: &str, e: Error) -> Error {
    let tag = |m: String| format!("trial '{label}': {m}");
    match e {
        Error::Config(m) => Error::Config(tag(m)),
        Error::Protocol(m) => Error::Protocol(tag(m)),
        Error::Transport(m) => Error::Transport(tag(m)),
        Error::Codec(m) => Error::Codec(tag(m)),
        Error::Numerical(m) => Error::Numerical(tag(m)),
        Error::Artifact(m) => Error::Artifact(tag(m)),
        Error::Xla(m) => Error::Xla(tag(m)),
        // io::Error cannot be rebuilt with a prefixed message losslessly;
        // keep it untouched (the kind matters more than the label here).
        Error::Io(e) => Error::Io(e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::observe::StopRule;
    use crate::signal::{Instance, ProblemDims};
    use crate::util::rng::Rng;
    use crate::SessionBuilder;

    #[test]
    fn sweep_preserves_order_and_labels() {
        let mut sweep = Sweep::new();
        for (i, bits) in [3.0, 4.0, 5.0].iter().enumerate() {
            sweep.add(
                format!("fixed{i}"),
                SessionBuilder::test_small(0.05).fixed_rate(*bits),
            );
        }
        let results = sweep.threads(2).run().unwrap();
        assert_eq!(results.len(), 3);
        for (i, tr) in results.iter().enumerate() {
            assert_eq!(tr.label, format!("fixed{i}"));
            assert_eq!(tr.report.iters.len(), 6);
        }
        // Coarser quantization must not cost more bits.
        assert!(
            results[0].report.total_uplink_bits_per_element()
                < results[2].report.total_uplink_bits_per_element()
        );
    }

    #[test]
    fn sweep_matches_sequential_run() {
        // Parallel execution must not perturb numerics: same builder ⇒
        // identical trajectory as a direct run.
        let builder = SessionBuilder::test_small(0.05).fixed_rate(4.0);
        let direct = builder.clone().build().unwrap().run().unwrap();
        let mut sweep = Sweep::new();
        sweep.add("a", builder.clone());
        sweep.add("b", builder);
        let results = sweep.run().unwrap();
        for tr in &results {
            for (x, y) in direct.iters.iter().zip(&tr.report.iters) {
                assert_eq!(x.sdr_db.to_bits(), y.sdr_db.to_bits());
            }
        }
    }

    #[test]
    fn shared_instance_compares_schedules_on_same_data() {
        let cfg = crate::config::RunConfig::test_small(0.05);
        let mut rng = Rng::new(cfg.seed);
        let inst = Instance::generate(
            cfg.prior,
            ProblemDims { n: cfg.n, m: cfg.m, sigma_e2: cfg.sigma_e2() },
            &mut rng,
        )
        .unwrap();
        let mut sweep = Sweep::new();
        sweep.add(
            "fixed",
            SessionBuilder::test_small(0.05).fixed_rate(4.0).instance(inst.clone()),
        );
        sweep.add(
            "raw",
            SessionBuilder::test_small(0.05).uncompressed().instance(inst),
        );
        let results = sweep.run().unwrap();
        // Same data: uncompressed is at least as good per iteration.
        assert!(
            results[1].report.final_sdr_db() >= results[0].report.final_sdr_db() - 0.5
        );
    }

    #[test]
    fn sweep_stop_rules_apply_to_every_trial() {
        let mut sweep = Sweep::new();
        sweep.add("a", SessionBuilder::test_small(0.05).fixed_rate(4.0));
        sweep.add("b", SessionBuilder::test_small(0.05).uncompressed());
        let results = sweep
            .stop(StopSet::none().with(StopRule::MaxIters(3)))
            .run()
            .unwrap();
        for tr in &results {
            assert_eq!(tr.report.iters.len(), 3, "{}", tr.label);
            assert!(tr.report.stopped_early.is_some());
        }
    }

    #[test]
    fn compressor_axis_crosses_stacks() {
        let mut sweep = Sweep::new();
        sweep.add_compressors(
            "fixed4",
            &SessionBuilder::test_small(0.05).fixed_rate(4.0),
            ["ecsq.range", "ecsq.huffman"],
        );
        assert_eq!(sweep.len(), 2);
        let results = sweep
            .stop(StopSet::none().with(StopRule::MaxIters(2)))
            .run()
            .unwrap();
        assert_eq!(results[0].label, "fixed4/ecsq.range");
        assert_eq!(results[1].label, "fixed4/ecsq.huffman");
        // Same quantizer, different codec: identical numerics, and the
        // Huffman wire spend pays at most the integer-codeword penalty.
        for (a, b) in results[0].report.iters.iter().zip(&results[1].report.iters) {
            assert!((a.sdr_db - b.sdr_db).abs() < 1e-12);
        }
        assert!(
            results[0].report.total_uplink_bits_per_element()
                <= results[1].report.total_uplink_bits_per_element() + 1e-9
        );
    }

    #[test]
    fn trial_error_carries_label() {
        let mut sweep = Sweep::new();
        // P=7 does not divide M=180.
        sweep.add("bad-p", SessionBuilder::test_small(0.05).workers(7));
        let err = sweep.run().unwrap_err().to_string();
        assert!(err.contains("bad-p"), "{err}");
    }
}
