//! Minimal TOML-subset parser (the vendored crate set has no `serde`/`toml`).
//!
//! Supported: `[section]` headers, `key = value` pairs with string
//! (`"..."`), boolean, integer, and float values, `#` comments, blank lines.
//! Keys inside a section are flattened to `section.key`. This intentionally
//! covers exactly what run configs need — nested tables and arrays are not
//! supported and produce clear errors.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
}

impl Value {
    /// As f64 (ints are widened).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }

    /// As i64.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// As usize (rejects negatives).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Flat map of `section.key` → value.
pub type Table = BTreeMap<String, Value>;

/// Parse a single scalar literal.
pub fn parse_value(raw: &str, line_no: usize) -> Result<Value> {
    let raw = raw.trim();
    if raw.is_empty() {
        return Err(Error::Config(format!("line {line_no}: empty value")));
    }
    if let Some(stripped) = raw.strip_prefix('"') {
        let Some(inner) = stripped.strip_suffix('"') else {
            return Err(Error::Config(format!("line {line_no}: unterminated string")));
        };
        return Ok(Value::Str(inner.to_string()));
    }
    match raw {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if raw.starts_with('[') {
        return Err(Error::Config(format!(
            "line {line_no}: arrays are not supported by this config parser"
        )));
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(Error::Config(format!("line {line_no}: cannot parse value '{raw}'")))
}

/// Parse TOML-subset text into a flat table.
pub fn parse(text: &str) -> Result<Table> {
    let mut table = Table::new();
    let mut section = String::new();
    for (idx, raw_line) in text.lines().enumerate() {
        let line_no = idx + 1;
        // Strip a trailing comment: the first '#' preceded by an *even*
        // number of quotes is outside any string value.
        let mut cut = raw_line.len();
        let mut quotes = 0usize;
        for (i, c) in raw_line.char_indices() {
            match c {
                '"' => quotes += 1,
                '#' if quotes % 2 == 0 => {
                    cut = i;
                    break;
                }
                _ => {}
            }
        }
        let line = raw_line[..cut].trim();
        if line.is_empty() {
            continue;
        }
        if let Some(hdr) = line.strip_prefix('[') {
            let Some(name) = hdr.strip_suffix(']') else {
                return Err(Error::Config(format!("line {line_no}: malformed section header")));
            };
            let name = name.trim();
            if name.is_empty() || name.contains('[') {
                return Err(Error::Config(format!("line {line_no}: bad section name '{name}'")));
            }
            section = name.to_string();
            continue;
        }
        let Some(eq) = line.find('=') else {
            return Err(Error::Config(format!("line {line_no}: expected 'key = value'")));
        };
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(Error::Config(format!("line {line_no}: empty key")));
        }
        let value = parse_value(&line[eq + 1..], line_no)?;
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        if table.insert(full_key.clone(), value).is_some() {
            return Err(Error::Config(format!("line {line_no}: duplicate key '{full_key}'")));
        }
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_sections() {
        let t = parse(
            r#"
            # top comment
            n = 10000
            rate = 0.1
            name = "paper"
            verbose = true

            [schedule]
            kind = "dp"
            total_rate = 16.0
            "#,
        )
        .unwrap();
        assert_eq!(t["n"], Value::Int(10000));
        assert_eq!(t["rate"], Value::Float(0.1));
        assert_eq!(t["name"], Value::Str("paper".into()));
        assert_eq!(t["verbose"], Value::Bool(true));
        assert_eq!(t["schedule.kind"], Value::Str("dp".into()));
        assert_eq!(t["schedule.total_rate"], Value::Float(16.0));
    }

    #[test]
    fn rejects_duplicates() {
        assert!(parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("just words").is_err());
        assert!(parse("[unclosed").is_err());
        assert!(parse("k = ").is_err());
        assert!(parse("k = [1, 2]").is_err());
        assert!(parse("k = \"unterminated").is_err());
    }

    #[test]
    fn trailing_comments_after_string_values() {
        let t = parse("engine = \"rust\"  # \"xla\" also works\nk = 3 # three").unwrap();
        assert_eq!(t["engine"], Value::Str("rust".into()));
        assert_eq!(t["k"], Value::Int(3));
    }

    #[test]
    fn hash_inside_string_survives() {
        let t = parse("name = \"a#b\"").unwrap();
        assert_eq!(t["name"], Value::Str("a#b".into()));
    }

    #[test]
    fn negative_numbers() {
        let t = parse("a = -3\nb = -0.5").unwrap();
        assert_eq!(t["a"].as_i64(), Some(-3));
        assert_eq!(t["b"].as_f64(), Some(-0.5));
        assert_eq!(t["a"].as_usize(), None);
    }

    #[test]
    fn value_accessors() {
        assert_eq!(Value::Int(3).as_f64(), Some(3.0));
        assert_eq!(Value::Str("x".into()).as_f64(), None);
        assert_eq!(Value::Bool(true).as_bool(), Some(true));
        assert_eq!(Value::Str("s".into()).as_str(), Some("s"));
    }
}
