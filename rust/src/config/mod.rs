//! Typed run configuration + loading from TOML-subset files and CLI
//! overrides. This is the single source of truth for every experiment knob.

pub mod toml;

use crate::error::{Error, Result};
use crate::signal::BernoulliGauss;
use toml::{parse_value, Table, Value};

/// How the sensing matrix is sharded across the `P` worker processors.
///
/// The two partitionings exchange different message types over the same
/// transport/quantizer machinery (see the overview paper 1702.03049):
/// row-wise workers uplink local estimates `f_t^p` of length `N`,
/// column-wise workers uplink partial residuals `A^p x_t^p` of length `M`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Partitioning {
    /// Row-wise MP-AMP (Han, Zhu, Niu & Baron 2016): each worker owns an
    /// `(M/P) × N` row block of `A` plus the matching slice of `y`.
    #[default]
    Row,
    /// Column-wise C-MP-AMP (Ma, Lu & Baron 2017, 1701.02578): each worker
    /// owns an `M × (N/P)` column block of `A` and the matching slice of
    /// the estimate; the fusion center owns `y` and the combined residual.
    ///
    /// All schedules apply. Note that the BT/DP allocators pick their
    /// per-iteration σ_Q² targets under the row-mode state evolution;
    /// those targets transfer (the fused quantization noise reaches the
    /// denoiser as `P σ_Q²` in both scenarios) but the allocators' rate
    /// accounting keeps the row message model, so their bit totals are
    /// approximate in column mode.
    Column,
}

impl Partitioning {
    /// Stable lowercase label used in configs and reports.
    pub fn as_str(&self) -> &'static str {
        match self {
            Partitioning::Row => "row",
            Partitioning::Column => "column",
        }
    }
}

/// Rate-allocation scheme for the uplink.
#[derive(Debug, Clone, PartialEq)]
pub enum ScheduleKind {
    /// No compression: 32-bit floats on the wire (the paper's baseline).
    Uncompressed,
    /// Fixed ECSQ rate (bits/element) every iteration.
    Fixed {
        /// Bits per element per iteration.
        bits: f64,
    },
    /// BT-MP-AMP: online back-tracking (paper §3.3).
    BackTrack {
        /// Allowed ratio `σ²_{t+1,D} / σ²_{t+1,C}` (paper: "some constant").
        ratio_max: f64,
        /// Per-iteration rate cap in bits/element (paper: "some threshold").
        r_max: f64,
    },
    /// DP-MP-AMP: offline dynamic-programming allocation (paper §3.4).
    Dp {
        /// Total budget R in bits/element; `None` → the paper's `R = 2T`.
        total_rate: Option<f64>,
        /// Bit-rate resolution ΔR (paper: 0.1).
        delta_r: f64,
    },
}

/// Entropy codec of the legacy standalone [`EcsqCoder`] pipeline, and the
/// value space of the deprecated `codec` config key (which aliases to
/// `compressor = "ecsq.<codec>"`). Sessions themselves select their full
/// compression stack by registry name via [`RunConfig::compressor`].
///
/// [`EcsqCoder`]: crate::quant::EcsqCoder
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CodecKind {
    /// No actual coding — account analytic `H_Q` bits (paper's accounting).
    Analytic,
    /// Static range coder over the model pmf (real bits on the wire).
    Range,
    /// Canonical Huffman (real bits; integer-bit overhead vs `H_Q`).
    Huffman,
}

/// Which compute engine evaluates the LC/GC steps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Portable pure-Rust engine.
    Rust,
    /// XLA/PJRT engine running AOT-compiled JAX/Pallas artifacts.
    Xla,
}

/// Transport between workers and the fusion center.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process channels (byte-metered).
    InProc,
    /// TCP loopback sockets (byte-metered at the socket layer).
    Tcp,
}

/// Rate-distortion substrate tuning.
#[derive(Debug, Clone, PartialEq)]
pub struct RdConfig {
    /// Source-alphabet discretization size for Blahut–Arimoto.
    pub alphabet: usize,
    /// Number of distortion points per RD curve.
    pub curve_points: usize,
    /// BA convergence tolerance (bits).
    pub tol: f64,
    /// Number of γ grid points for the curve cache.
    pub gamma_grid: usize,
}

impl Default for RdConfig {
    fn default() -> Self {
        RdConfig { alphabet: 513, curve_points: 48, tol: 1e-4, gamma_grid: 33 }
    }
}

/// Full configuration of one MP-AMP run.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    /// Signal length N.
    pub n: usize,
    /// Measurement count M.
    pub m: usize,
    /// Number of worker processors P.
    pub p: usize,
    /// Signal instances carried through the session together (B ≥ 1).
    /// All B signals share one sensing matrix and every protocol round
    /// processes the whole batch in one blocked pass over `A`.
    pub batch: usize,
    /// How the sensing matrix is sharded across the workers.
    pub partitioning: Partitioning,
    /// Source prior.
    pub prior: BernoulliGauss,
    /// Measurement SNR in dB.
    pub snr_db: f64,
    /// AMP iteration count T (0 → auto from SE steady state).
    pub iters: usize,
    /// RNG seed.
    pub seed: u64,
    /// Worker-side compute threads for the pure-Rust engine.
    pub threads: usize,
    /// Rate-allocation scheme.
    pub schedule: ScheduleKind,
    /// Uplink compression stack, by registry name (e.g. `"ecsq.huffman"`,
    /// `"ecsq-dithered.range"`, `"topk.raw"`; see
    /// [`compress::registry`](crate::compress::registry)). Validated
    /// against the registry by [`RunConfig::validate`].
    pub compressor: String,
    /// Compute engine.
    pub engine: EngineKind,
    /// Directory holding AOT artifacts (XLA engine).
    pub artifact_dir: String,
    /// Transport kind.
    pub transport: TransportKind,
    /// Elastic K-of-P floor: the minimum number of live worker uplinks a
    /// fusion round may proceed on. `0` disables elasticity — every
    /// round blocks for all `P` replies (the paper's hard barrier).
    /// With `K < P` live workers the fused sum is rescaled by `P/K` and
    /// the missing shard mass is folded into the quantization-noise term
    /// of the state evolution (see `coordinator::scenario`).
    pub min_workers: usize,
    /// Per-round reply deadline in milliseconds for elastic sessions
    /// (how long the fusion center waits on each worker before moving
    /// on without it). Required (> 0) whenever `min_workers` is set,
    /// rejected without it.
    pub round_deadline_ms: u64,
    /// RD substrate tuning.
    pub rd: RdConfig,
}

/// The paper's steady-state iteration counts per sparsity (Fig. 1 caption).
pub fn paper_iters(eps: f64) -> usize {
    if eps <= 0.035 {
        8
    } else if eps <= 0.075 {
        10
    } else {
        20
    }
}

impl RunConfig {
    /// The paper's evaluation setup for a given sparsity ε:
    /// N=10 000, M=3 000, P=30, SNR=20 dB, μ_s=0, σ_s=1, BT schedule.
    pub fn paper_default(eps: f64) -> Self {
        RunConfig {
            n: 10_000,
            m: 3_000,
            p: 30,
            batch: 1,
            partitioning: Partitioning::Row,
            prior: BernoulliGauss::standard(eps),
            snr_db: 20.0,
            iters: paper_iters(eps),
            seed: 0x5EED,
            threads: num_threads_default(),
            schedule: ScheduleKind::BackTrack { ratio_max: 1.02, r_max: 6.0 },
            compressor: crate::compress::registry::DEFAULT_STACK.to_string(),
            engine: EngineKind::Rust,
            artifact_dir: "artifacts".into(),
            transport: TransportKind::InProc,
            min_workers: 0,
            round_deadline_ms: 0,
            rd: RdConfig::default(),
        }
    }

    /// A small config for fast tests (N=600, M=180, P=6).
    pub fn test_small(eps: f64) -> Self {
        let mut c = Self::paper_default(eps);
        c.n = 600;
        c.m = 180;
        c.p = 6;
        c.iters = 6;
        c.threads = 2;
        c
    }

    /// κ = M/N.
    pub fn kappa(&self) -> f64 {
        self.m as f64 / self.n as f64
    }

    /// σ_e² implied by the target SNR.
    pub fn sigma_e2(&self) -> f64 {
        crate::signal::sigma_e2_for_snr(&self.prior, self.kappa(), self.snr_db)
    }

    /// Validate invariants the coordinator relies on.
    pub fn validate(&self) -> Result<()> {
        self.prior.validate()?;
        if self.n == 0 || self.m == 0 {
            return Err(Error::Config("N and M must be positive".into()));
        }
        if self.batch == 0 {
            return Err(Error::Config("batch must be ≥ 1".into()));
        }
        if self.batch > 1 && self.engine == EngineKind::Xla {
            return Err(Error::Config(
                "batch > 1 requires engine = \"rust\" (the AOT artifacts are \
                 lowered for single-signal kernels)"
                    .into(),
            ));
        }
        match self.partitioning {
            Partitioning::Row => {
                if self.p == 0 || self.m % self.p != 0 {
                    return Err(Error::Config(format!(
                        "P={} must be positive and divide M={}",
                        self.p, self.m
                    )));
                }
            }
            Partitioning::Column => {
                if self.p == 0 || self.n % self.p != 0 {
                    return Err(Error::Config(format!(
                        "column partitioning: P={} must be positive and divide N={}",
                        self.p, self.n
                    )));
                }
                if self.engine == EngineKind::Xla {
                    return Err(Error::Config(
                        "column partitioning requires engine = \"rust\" (the AOT \
                         artifacts only lower the row-block kernels)"
                            .into(),
                    ));
                }
            }
        }
        if self.min_workers > self.p {
            return Err(Error::Config(format!(
                "elastic.min_workers={} must not exceed P={}",
                self.min_workers, self.p
            )));
        }
        if self.min_workers > 0 && self.round_deadline_ms == 0 {
            return Err(Error::Config(
                "elastic.min_workers requires elastic.round_deadline_ms > 0 (a \
                 K-of-P floor is meaningless without a round deadline)"
                    .into(),
            ));
        }
        if self.min_workers == 0 && self.round_deadline_ms > 0 {
            return Err(Error::Config(
                "elastic.round_deadline_ms requires elastic.min_workers ≥ 1".into(),
            ));
        }
        match &self.schedule {
            ScheduleKind::Fixed { bits } if *bits <= 0.0 => {
                return Err(Error::Config("fixed rate must be > 0".into()))
            }
            ScheduleKind::BackTrack { ratio_max, r_max } => {
                if *ratio_max <= 1.0 {
                    return Err(Error::Config("ratio_max must exceed 1".into()));
                }
                if *r_max <= 0.0 {
                    return Err(Error::Config("r_max must be > 0".into()));
                }
            }
            ScheduleKind::Dp { total_rate, delta_r } => {
                if *delta_r <= 0.0 {
                    return Err(Error::Config("delta_r must be > 0".into()));
                }
                if let Some(r) = total_rate {
                    if *r <= 0.0 {
                        return Err(Error::Config("total_rate must be > 0".into()));
                    }
                }
            }
            _ => {}
        }
        // The compression stack must exist in the registry (the error
        // lists every registered name) and its advertised capabilities
        // must be consistent — registration already enforces this for
        // stacks that went through `register`, so this is a cheap
        // defense-in-depth check that fails at config time, not mid-run.
        crate::compress::registry::get(&self.compressor)?.validate_caps()?;
        Ok(())
    }

    /// Build from a parsed table (missing keys keep `paper_default(0.05)`
    /// values — configs only need to state what they change). Unknown
    /// keys are rejected so typos fail loudly instead of silently keeping
    /// defaults.
    pub fn from_table(t: &Table) -> Result<Self> {
        for key in t.keys() {
            if !KNOWN_KEYS.contains(&key.as_str()) {
                return Err(Error::Config(format!(
                    "unknown config key '{key}' (known keys: {})",
                    KNOWN_KEYS.join(", ")
                )));
            }
        }
        let mut c = RunConfig::paper_default(0.05);
        // Parse prior first: iters default depends on eps.
        if let Some(v) = t.get("prior.eps") {
            c.prior.eps = req_f64(v, "prior.eps")?;
            c.iters = paper_iters(c.prior.eps);
        }
        if let Some(v) = t.get("prior.mu_s") {
            c.prior.mu_s = req_f64(v, "prior.mu_s")?;
        }
        if let Some(v) = t.get("prior.sigma_s2") {
            c.prior.sigma_s2 = req_f64(v, "prior.sigma_s2")?;
        }
        if let Some(v) = t.get("n") {
            c.n = req_usize(v, "n")?;
        }
        if let Some(v) = t.get("m") {
            c.m = req_usize(v, "m")?;
        }
        if let Some(v) = t.get("p") {
            c.p = req_usize(v, "p")?;
        }
        if let Some(v) = t.get("batch") {
            c.batch = req_usize(v, "batch")?;
        }
        if let Some(v) = t.get("partitioning") {
            c.partitioning = match req_str(v, "partitioning")? {
                "row" => Partitioning::Row,
                "column" | "col" => Partitioning::Column,
                other => {
                    return Err(Error::Config(format!("unknown partitioning '{other}'")))
                }
            };
        }
        if let Some(v) = t.get("snr_db") {
            c.snr_db = req_f64(v, "snr_db")?;
        }
        if let Some(v) = t.get("iters") {
            c.iters = req_usize(v, "iters")?;
        }
        if let Some(v) = t.get("seed") {
            c.seed = req_usize(v, "seed")? as u64;
        }
        if let Some(v) = t.get("threads") {
            c.threads = req_usize(v, "threads")?;
        }
        if let Some(v) = t.get("artifact_dir") {
            c.artifact_dir = req_str(v, "artifact_dir")?.to_string();
        }
        if let Some(v) = t.get("codec") {
            // Deprecated alias from the pre-registry config surface:
            // `codec = "huffman"` selects the ECSQ stack with that codec.
            c.compressor = match req_str(v, "codec")? {
                s @ ("analytic" | "range" | "huffman") => format!("ecsq.{s}"),
                other => return Err(Error::Config(format!("unknown codec '{other}'"))),
            };
        }
        if let Some(v) = t.get("compressor") {
            c.compressor = req_str(v, "compressor")?.to_string();
        }
        if let Some(v) = t.get("engine") {
            c.engine = match req_str(v, "engine")? {
                "rust" => EngineKind::Rust,
                "xla" => EngineKind::Xla,
                other => return Err(Error::Config(format!("unknown engine '{other}'"))),
            };
        }
        if let Some(v) = t.get("transport") {
            c.transport = match req_str(v, "transport")? {
                "inproc" => TransportKind::InProc,
                "tcp" => TransportKind::Tcp,
                other => return Err(Error::Config(format!("unknown transport '{other}'"))),
            };
        }
        if let Some(v) = t.get("elastic.min_workers") {
            c.min_workers = req_usize(v, "elastic.min_workers")?;
        }
        if let Some(v) = t.get("elastic.round_deadline_ms") {
            c.round_deadline_ms = req_usize(v, "elastic.round_deadline_ms")? as u64;
        }
        if let Some(v) = t.get("schedule.kind") {
            c.schedule = match req_str(v, "schedule.kind")? {
                "uncompressed" => ScheduleKind::Uncompressed,
                "fixed" => ScheduleKind::Fixed {
                    bits: t
                        .get("schedule.bits")
                        .map(|v| req_f64(v, "schedule.bits"))
                        .transpose()?
                        .unwrap_or(4.0),
                },
                "bt" | "backtrack" => ScheduleKind::BackTrack {
                    ratio_max: t
                        .get("schedule.ratio_max")
                        .map(|v| req_f64(v, "schedule.ratio_max"))
                        .transpose()?
                        .unwrap_or(1.02),
                    r_max: t
                        .get("schedule.r_max")
                        .map(|v| req_f64(v, "schedule.r_max"))
                        .transpose()?
                        .unwrap_or(6.0),
                },
                "dp" => ScheduleKind::Dp {
                    total_rate: t
                        .get("schedule.total_rate")
                        .map(|v| req_f64(v, "schedule.total_rate"))
                        .transpose()?,
                    delta_r: t
                        .get("schedule.delta_r")
                        .map(|v| req_f64(v, "schedule.delta_r"))
                        .transpose()?
                        .unwrap_or(0.1),
                },
                other => return Err(Error::Config(format!("unknown schedule '{other}'"))),
            };
        }
        if let Some(v) = t.get("rd.alphabet") {
            c.rd.alphabet = req_usize(v, "rd.alphabet")?;
        }
        if let Some(v) = t.get("rd.curve_points") {
            c.rd.curve_points = req_usize(v, "rd.curve_points")?;
        }
        if let Some(v) = t.get("rd.tol") {
            c.rd.tol = req_f64(v, "rd.tol")?;
        }
        if let Some(v) = t.get("rd.gamma_grid") {
            c.rd.gamma_grid = req_usize(v, "rd.gamma_grid")?;
        }
        c.validate()?;
        Ok(c)
    }

    /// Load from a config file.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read '{path}': {e}")))?;
        Self::from_table(&toml::parse(&text)?)
    }

    /// Apply `key=value` CLI overrides on top of this config.
    pub fn apply_overrides(self, overrides: &[(String, String)]) -> Result<Self> {
        let mut table = Table::new();
        // Round-trip through the table-based builder: encode current state,
        // overlay overrides, rebuild. Encoding only the overridden keys and
        // re-parsing against `self` would drop schedule sub-keys, so we
        // rebuild from a full table instead.
        self.encode_into(&mut table);
        // Overriding ε re-derives the paper's T for that sparsity unless
        // the caller pins `iters` explicitly — otherwise the encoded base
        // value would always win inside `from_table`.
        let overrides_eps = overrides.iter().any(|(k, _)| k == "prior.eps");
        let overrides_iters = overrides.iter().any(|(k, _)| k == "iters");
        if overrides_eps && !overrides_iters {
            table.remove("iters");
        }
        // A `codec` override must beat the always-encoded `compressor`
        // base value (inside `from_table` the alias is applied first).
        let overrides_codec = overrides.iter().any(|(k, _)| k == "codec");
        let overrides_compressor = overrides.iter().any(|(k, _)| k == "compressor");
        if overrides_codec && !overrides_compressor {
            table.remove("compressor");
        }
        for (k, v) in overrides {
            // CLI values arrive unquoted; fall back to a bare string when
            // the literal is not a number/bool.
            let value = parse_value(v, 0).unwrap_or_else(|_| Value::Str(v.clone()));
            table.insert(k.clone(), value);
        }
        Self::from_table(&table)
    }

    /// Encode this config into a flat table (inverse of `from_table`).
    pub fn encode_into(&self, t: &mut Table) {
        t.insert("n".into(), Value::Int(self.n as i64));
        t.insert("m".into(), Value::Int(self.m as i64));
        t.insert("p".into(), Value::Int(self.p as i64));
        t.insert("batch".into(), Value::Int(self.batch as i64));
        t.insert("partitioning".into(), Value::Str(self.partitioning.as_str().into()));
        t.insert("prior.eps".into(), Value::Float(self.prior.eps));
        t.insert("prior.mu_s".into(), Value::Float(self.prior.mu_s));
        t.insert("prior.sigma_s2".into(), Value::Float(self.prior.sigma_s2));
        t.insert("snr_db".into(), Value::Float(self.snr_db));
        t.insert("iters".into(), Value::Int(self.iters as i64));
        t.insert("seed".into(), Value::Int(self.seed as i64));
        t.insert("threads".into(), Value::Int(self.threads as i64));
        t.insert("artifact_dir".into(), Value::Str(self.artifact_dir.clone()));
        t.insert("compressor".into(), Value::Str(self.compressor.clone()));
        let engine = match self.engine {
            EngineKind::Rust => "rust",
            EngineKind::Xla => "xla",
        };
        t.insert("engine".into(), Value::Str(engine.into()));
        let transport = match self.transport {
            TransportKind::InProc => "inproc",
            TransportKind::Tcp => "tcp",
        };
        t.insert("transport".into(), Value::Str(transport.into()));
        t.insert("elastic.min_workers".into(), Value::Int(self.min_workers as i64));
        t.insert(
            "elastic.round_deadline_ms".into(),
            Value::Int(self.round_deadline_ms as i64),
        );
        match &self.schedule {
            ScheduleKind::Uncompressed => {
                t.insert("schedule.kind".into(), Value::Str("uncompressed".into()));
            }
            ScheduleKind::Fixed { bits } => {
                t.insert("schedule.kind".into(), Value::Str("fixed".into()));
                t.insert("schedule.bits".into(), Value::Float(*bits));
            }
            ScheduleKind::BackTrack { ratio_max, r_max } => {
                t.insert("schedule.kind".into(), Value::Str("bt".into()));
                t.insert("schedule.ratio_max".into(), Value::Float(*ratio_max));
                t.insert("schedule.r_max".into(), Value::Float(*r_max));
            }
            ScheduleKind::Dp { total_rate, delta_r } => {
                t.insert("schedule.kind".into(), Value::Str("dp".into()));
                if let Some(r) = total_rate {
                    t.insert("schedule.total_rate".into(), Value::Float(*r));
                }
                t.insert("schedule.delta_r".into(), Value::Float(*delta_r));
            }
        }
        t.insert("rd.alphabet".into(), Value::Int(self.rd.alphabet as i64));
        t.insert("rd.curve_points".into(), Value::Int(self.rd.curve_points as i64));
        t.insert("rd.tol".into(), Value::Float(self.rd.tol));
        t.insert("rd.gamma_grid".into(), Value::Int(self.rd.gamma_grid as i64));
    }
}

/// Every key `from_table` understands (the schedule sub-keys are valid
/// regardless of `schedule.kind` so partial overrides round-trip).
pub const KNOWN_KEYS: &[&str] = &[
    "n",
    "m",
    "p",
    "batch",
    "partitioning",
    "prior.eps",
    "prior.mu_s",
    "prior.sigma_s2",
    "snr_db",
    "iters",
    "seed",
    "threads",
    "artifact_dir",
    "codec",
    "compressor",
    "engine",
    "transport",
    "elastic.min_workers",
    "elastic.round_deadline_ms",
    "schedule.kind",
    "schedule.bits",
    "schedule.ratio_max",
    "schedule.r_max",
    "schedule.total_rate",
    "schedule.delta_r",
    "rd.alphabet",
    "rd.curve_points",
    "rd.tol",
    "rd.gamma_grid",
];

fn req_f64(v: &Value, key: &str) -> Result<f64> {
    v.as_f64().ok_or_else(|| Error::Config(format!("'{key}' must be a number")))
}

fn req_usize(v: &Value, key: &str) -> Result<usize> {
    v.as_usize()
        .ok_or_else(|| Error::Config(format!("'{key}' must be a non-negative integer")))
}

fn req_str<'v>(v: &'v Value, key: &str) -> Result<&'v str> {
    v.as_str().ok_or_else(|| Error::Config(format!("'{key}' must be a string")))
}

/// Default worker thread count: physical parallelism, capped.
pub fn num_threads_default() -> usize {
    std::thread::available_parallelism().map(|n| n.get().min(16)).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_matches_paper() {
        let c = RunConfig::paper_default(0.05);
        assert_eq!((c.n, c.m, c.p, c.iters), (10_000, 3_000, 30, 10));
        assert!((c.kappa() - 0.3).abs() < 1e-12);
        assert!((c.snr_db - 20.0).abs() < 1e-12);
        c.validate().unwrap();
    }

    #[test]
    fn paper_iters_per_eps() {
        assert_eq!(paper_iters(0.03), 8);
        assert_eq!(paper_iters(0.05), 10);
        assert_eq!(paper_iters(0.10), 20);
    }

    #[test]
    fn from_table_roundtrip() {
        let c = RunConfig::paper_default(0.03);
        let mut t = Table::new();
        c.encode_into(&mut t);
        let c2 = RunConfig::from_table(&t).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn from_table_dp_schedule() {
        let t = toml::parse(
            r#"
            [prior]
            eps = 0.1
            [schedule]
            kind = "dp"
            total_rate = 40.0
            delta_r = 0.1
            "#,
        )
        .unwrap();
        let c = RunConfig::from_table(&t).unwrap();
        assert_eq!(c.iters, 20);
        assert_eq!(
            c.schedule,
            ScheduleKind::Dp { total_rate: Some(40.0), delta_r: 0.1 }
        );
    }

    #[test]
    fn overrides_apply() {
        let c = RunConfig::paper_default(0.05)
            .apply_overrides(&[
                ("p".into(), "10".into()),
                ("schedule.kind".into(), "fixed".into()),
                ("schedule.bits".into(), "3.5".into()),
            ])
            .unwrap();
        assert_eq!(c.p, 10);
        assert_eq!(c.schedule, ScheduleKind::Fixed { bits: 3.5 });
    }

    #[test]
    fn validate_rejects_bad_p() {
        let mut c = RunConfig::paper_default(0.05);
        c.p = 7; // does not divide 3000
        assert!(c.validate().is_err());
    }

    #[test]
    fn partitioning_parses_and_roundtrips() {
        // P=40 divides N=10000 (the paper default P=30 does not).
        let t = toml::parse("partitioning = \"column\"\np = 40").unwrap();
        let c = RunConfig::from_table(&t).unwrap();
        assert_eq!(c.partitioning, Partitioning::Column);
        assert_eq!(c.p, 40);
        let mut enc = Table::new();
        c.encode_into(&mut enc);
        assert_eq!(RunConfig::from_table(&enc).unwrap(), c);
        // Unknown labels fail loudly.
        let t = toml::parse("partitioning = \"diagonal\"").unwrap();
        assert!(RunConfig::from_table(&t).is_err());
    }

    #[test]
    fn column_partitioning_validates_against_n() {
        let mut c = RunConfig::paper_default(0.05);
        c.partitioning = Partitioning::Column;
        // The paper default P=30 does not divide N=10000 -> must fail.
        c.p = 30;
        assert!(c.validate().is_err());
        // P=16 divides N=10000 but not M=3000 — valid only for columns.
        c.p = 16;
        c.validate().unwrap();
        c.partitioning = Partitioning::Row;
        assert!(c.validate().is_err());
    }

    #[test]
    fn batch_knob_parses_validates_and_roundtrips() {
        let t = toml::parse("batch = 8").unwrap();
        let c = RunConfig::from_table(&t).unwrap();
        assert_eq!(c.batch, 8);
        let mut enc = Table::new();
        c.encode_into(&mut enc);
        assert_eq!(RunConfig::from_table(&enc).unwrap().batch, 8);
        // batch = 0 is rejected.
        let t = toml::parse("batch = 0").unwrap();
        assert!(RunConfig::from_table(&t).is_err());
        // Batched runs need the rust engine (no batched AOT kernels).
        let mut c = RunConfig::paper_default(0.05);
        c.batch = 4;
        c.engine = EngineKind::Xla;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("rust"), "{err}");
        c.engine = EngineKind::Rust;
        c.validate().unwrap();
    }

    #[test]
    fn column_partitioning_rejects_xla_engine() {
        let mut c = RunConfig::paper_default(0.05);
        c.partitioning = Partitioning::Column;
        c.p = 40;
        c.engine = EngineKind::Xla;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("rust"), "{err}");
    }

    #[test]
    fn validate_rejects_bad_schedule() {
        let mut c = RunConfig::paper_default(0.05);
        c.schedule = ScheduleKind::BackTrack { ratio_max: 0.9, r_max: 6.0 };
        assert!(c.validate().is_err());
        c.schedule = ScheduleKind::Fixed { bits: -1.0 };
        assert!(c.validate().is_err());
        c.schedule = ScheduleKind::Dp { total_rate: Some(-2.0), delta_r: 0.1 };
        assert!(c.validate().is_err());
    }

    #[test]
    fn unknown_enum_values_rejected() {
        let t = toml::parse("codec = \"lzma\"").unwrap();
        assert!(RunConfig::from_table(&t).is_err());
    }

    #[test]
    fn compressor_key_parses_and_validates() {
        let t = toml::parse("compressor = \"topk.raw\"").unwrap();
        let c = RunConfig::from_table(&t).unwrap();
        assert_eq!(c.compressor, "topk.raw");
        // Round-trips through encode_into.
        let mut enc = Table::new();
        c.encode_into(&mut enc);
        assert_eq!(RunConfig::from_table(&enc).unwrap().compressor, "topk.raw");
        // Unregistered stacks fail at validate with the menu attached.
        let t = toml::parse("compressor = \"vq.range\"").unwrap();
        let err = RunConfig::from_table(&t).unwrap_err().to_string();
        assert!(err.contains("vq.range") && err.contains("ecsq.range"), "{err}");
    }

    #[test]
    fn legacy_codec_key_aliases_to_ecsq_stack() {
        let t = toml::parse("codec = \"huffman\"").unwrap();
        assert_eq!(RunConfig::from_table(&t).unwrap().compressor, "ecsq.huffman");
        // An explicit compressor key wins over the alias.
        let t = toml::parse("codec = \"huffman\"\ncompressor = \"topk.raw\"").unwrap();
        assert_eq!(RunConfig::from_table(&t).unwrap().compressor, "topk.raw");
        // ...and a codec *override* beats the encoded base compressor.
        let c = RunConfig::paper_default(0.05)
            .apply_overrides(&[("codec".into(), "analytic".into())])
            .unwrap();
        assert_eq!(c.compressor, "ecsq.analytic");
    }

    #[test]
    fn unknown_keys_rejected() {
        let t = toml::parse("snr_dbb = 20.0").unwrap();
        let err = RunConfig::from_table(&t).unwrap_err().to_string();
        assert!(err.contains("unknown config key 'snr_dbb'"), "{err}");
        // ...including typos inside sections.
        let t = toml::parse("[schedule]\nkindd = \"dp\"").unwrap();
        assert!(RunConfig::from_table(&t).is_err());
    }

    #[test]
    fn elastic_knobs_parse_validate_and_roundtrip() {
        let t = toml::parse("[elastic]\nmin_workers = 20\nround_deadline_ms = 250").unwrap();
        let c = RunConfig::from_table(&t).unwrap();
        assert_eq!((c.min_workers, c.round_deadline_ms), (20, 250));
        let mut enc = Table::new();
        c.encode_into(&mut enc);
        assert_eq!(RunConfig::from_table(&enc).unwrap(), c);
        // A floor without a deadline (and vice versa) fails loudly.
        let t = toml::parse("elastic.min_workers = 20").unwrap();
        let err = RunConfig::from_table(&t).unwrap_err().to_string();
        assert!(err.contains("round_deadline_ms"), "{err}");
        let t = toml::parse("elastic.round_deadline_ms = 250").unwrap();
        let err = RunConfig::from_table(&t).unwrap_err().to_string();
        assert!(err.contains("min_workers"), "{err}");
        // K must not exceed P.
        let t =
            toml::parse("[elastic]\nmin_workers = 31\nround_deadline_ms = 250").unwrap();
        let err = RunConfig::from_table(&t).unwrap_err().to_string();
        assert!(err.contains("must not exceed P"), "{err}");
    }

    #[test]
    fn sigma_e2_consistency() {
        let c = RunConfig::paper_default(0.05);
        let rho = c.prior.second_moment() / c.kappa();
        let snr = 10.0 * (rho / c.sigma_e2()).log10();
        assert!((snr - 20.0).abs() < 1e-9);
    }
}
