//! The scenario-generic protocol core.
//!
//! Every MP-AMP partitioning in the literature — row-wise MP-AMP (Han,
//! Zhu, Niu & Baron 2016), column-wise C-MP-AMP (Ma, Lu & Baron 2017,
//! arXiv:1701.02578), and the family the overview paper (Zhu, Pilgrim &
//! Baron 2017, arXiv:1702.03049) sketches — shares one round structure:
//!
//! 1. the fusion center **broadcasts** the round state,
//! 2. workers run their **local step** and reply with pre-uplink scalars,
//! 3. the fusion center **designs a quantizer** per signal from a rate
//!    directive and broadcasts it,
//! 4. workers **uplink** lossily-coded vectors, which the fusion center
//!    decodes and **fuses** by summation,
//! 5. a scenario-specific **global computation** folds the fused vectors
//!    into the next round's state.
//!
//! [`ProtocolCore`] implements that skeleton exactly once, batched over
//! `B ≥ 1` signal instances; the [`Scenario`] trait supplies the five
//! scenario-specific holes. [`Row`] and [`Column`] are the two shipped
//! scenarios — `ProtocolCore<Row>` replaces the old `FusionState` and
//! `ProtocolCore<Column>` the old `ColumnFusionState`, with the worker
//! loops collapsed into one generic
//! [`run_scenario_worker`](crate::coordinator::worker::run_scenario_worker).
//!
//! # Zero-allocation rounds
//!
//! The steady-state round loop neither allocates nor spawns: broadcasts
//! are encoded **once** per round into a persistent frame buffer
//! (`begin_round` writes the wire bytes directly; every endpoint gets
//! [`send_encoded`](Endpoint::send_encoded)), worker replies are parsed
//! **in place** out of each endpoint's reused receive buffer with the
//! borrowed decoders in [`message`](crate::coordinator::message)
//! (`absorb` takes the raw frame), fusion sums land in one persistent
//! flat `B × len` buffer, and the scenario's global computation writes
//! the next round's state in place (`global_step` takes the flat sums;
//! the engines' `*_into` kernels denoise straight into fusion state).
//! Compute parallelism runs on the persistent
//! [`Pool`](crate::runtime::pool::Pool) — no thread spawns per kernel
//! call. What still allocates per round is O(B)-small spec design
//! (boxed quantizer states, wire params) and codec output blocks —
//! nothing proportional to the signal length.
//!
//! # Adding a third scenario
//!
//! A new partitioning only has to fill the trait's holes — the round
//! driver, batching, wire protocol, the compression-stack registry, rate
//! allocators, metering, and session machinery are inherited. Sketch for
//! a hypothetical overlapping-block scenario:
//!
//! ```ignore
//! use mpamp::coordinator::scenario::{ProtocolCore, RoundStat, Scenario};
//!
//! struct Overlap;
//!
//! impl Scenario for Overlap {
//!     type Shard = OverlapShard;      // worker's slice of A (+ data)
//!     type Fusion = OverlapFusion;    // fusion state across rounds
//!     type WorkerState = OverlapWorker; // worker state across rounds
//!     const NAME: &'static str = "overlap";
//!     const REPLY_TAG: u8 = 42; // wire tag of the phase-2 reply frame
//!
//!     // How the problem shards across P workers:
//!     fn split(batch: &Batch, p: usize) -> Result<Vec<OverlapShard>> { .. }
//!     // Fresh fusion/worker state at t = 0:
//!     fn init(batch: &Batch, cfg: &RunConfig) -> OverlapFusion { .. }
//!     fn worker_init(shard: &OverlapShard, batch: usize) -> OverlapWorker { .. }
//!     // Phase 1–2: encode the broadcast into the reused frame, serve it
//!     // on the worker (reply sent via `ep`, uplinks staged flat into
//!     // `pending`), parse the reply frame on the fusion side:
//!     fn begin_round(fu: &mut OverlapFusion, cfg: &RunConfig, t: usize, frame: &mut Vec<u8>) { .. }
//!     fn worker_serve(.., frame: &[u8], pending: &mut Vec<f32>, ep: &mut Endpoint) -> Result<()> { .. }
//!     fn absorb(fu: &mut OverlapFusion, .., widx: usize, frame: &[u8]) -> Result<()> { .. }
//!     // Elastic K-of-P: rescale partial phase-2 aggregates to full-P:
//!     fn rescale_partial_replies(fu: &mut OverlapFusion, cfg: &RunConfig, k: usize) { .. }
//!     // Phase 3: which variance the round's stats carry into the spec,
//!     // and the model channel every compression stack designs against:
//!     fn stats(fu: &OverlapFusion, cfg: &RunConfig, out: &mut Vec<RoundStat>) { .. }
//!     fn spec_var(stat: RoundStat) -> f64 { .. }
//!     fn channel_for_var(prior: &BernoulliGauss, p: usize, var: f64) -> (BgChannel, f64) { .. }
//!     // Phase 5: fold the fused uplinks (flat B × len) into the next state:
//!     fn global_step(.., sums: &[f32], ..) -> Result<()> { .. }
//!     fn predicted_sigma(..) -> f64 { .. }
//!     fn uplink_len(cfg: &RunConfig) -> usize { .. }
//!     fn x(fu: &OverlapFusion, sig: usize) -> &[f32] { .. }
//!     fn into_xs(fu: OverlapFusion) -> Vec<Vec<f32>> { .. }
//! }
//!
//! // Then: drive it with the generic machinery.
//! let mut core: ProtocolCore<Overlap> = ProtocolCore::new(&batch, &cfg);
//! let record = core.step(&cfg, &se, controller.as_ref(), None, &engine, &mut endpoints, Some(&batch))?;
//! ```
//!
//! The two in-tree implementations below are the best reference for what
//! each hole has to guarantee (notably: `absorb` must validate iteration
//! and worker ids, and `channel_for_var` must be deterministic from the
//! spec's variance alone, because the worker rebuilds the identical
//! compressor on its side).

use std::time::{Duration, Instant};

use crate::alloc::schedule::{Directive, RateAllocator};
use crate::compress::{design_seed, BlockCtx, Compressor, CompressionStack, DesignCtx, CLIP_SDS};
use crate::config::RunConfig;
use crate::coordinator::message::{self, FPayloadRef, Message, QuantSpec};
use crate::coordinator::transport::Endpoint;
use crate::coordinator::worker::{compressor_for_spec, WorkerParams};
use crate::engine::{ColumnWorkerData, ComputeEngine, RowBatchData};
use crate::error::{Error, Result};
use crate::metrics::IterRecord;
use crate::rd::RdCache;
use crate::se::prior::BgChannel;
use crate::se::StateEvolution;
use crate::signal::{Batch, BernoulliGauss};
use crate::telemetry::{Stage, Telemetry};

/// Per-signal statistics available when the round's quantizer is designed.
#[derive(Debug, Clone, Copy)]
pub struct RoundStat {
    /// Residual-variance estimate σ̂²_{t,D} — the SE state variable the
    /// rate allocators understand.
    pub sigma_d2_hat: f64,
    /// Variance the quantizer's model channel is built from (row mode:
    /// σ̂² again; column mode: the empirical message variance v̂).
    pub msg_var: f64,
}

/// The scenario-specific holes of one protocol round (see the module docs
/// for the worked example). Implementations are zero-sized types; all
/// state lives in the associated `Fusion`/`WorkerState` types.
pub trait Scenario: Send + Sync + 'static {
    /// The worker's shard of the problem (sent to the worker thread once).
    type Shard: Send + 'static;
    /// Fusion-side state carried across rounds.
    type Fusion: Send;
    /// Worker-side state carried across rounds.
    type WorkerState: Send;

    /// Stable lowercase scenario label (matches `Partitioning::as_str`).
    const NAME: &'static str;

    /// Wire tag of the scenario's phase-2 (pre-uplink) reply frame. The
    /// elastic round driver uses it to tell an expected reply from a
    /// stale straggler frame it should drain and discard.
    const REPLY_TAG: u8;

    /// Shard the signal batch across `p` workers.
    fn split(batch: &Batch, p: usize) -> Result<Vec<Self::Shard>>;

    /// Fresh fusion state at `t = 0`.
    fn init(batch: &Batch, cfg: &RunConfig) -> Self::Fusion;

    /// Per-signal length of the uplinked message vector (`N` in row mode,
    /// `M` in column mode) — the denominator of the paper's bits/element
    /// accounting.
    fn uplink_len(cfg: &RunConfig) -> usize;

    /// Phase 1: reset the round accumulators and encode the broadcast
    /// directly into `frame` (cleared by the `encode_*` builder) — the
    /// round state is never cloned into an owned [`Message`], and the
    /// frame is sent to every endpoint as-is (encode-once).
    fn begin_round(fu: &mut Self::Fusion, cfg: &RunConfig, t: usize, frame: &mut Vec<u8>);

    /// Phase 2: absorb worker `widx`'s pre-uplink reply, parsed in place
    /// from the endpoint's receive buffer with the borrowed decoders
    /// (must validate the iteration index, worker id, and batch sizes).
    fn absorb(
        fu: &mut Self::Fusion,
        cfg: &RunConfig,
        t: usize,
        widx: usize,
        frame: &[u8],
    ) -> Result<()>;

    /// Elastic K-of-P correction, called between phases 2 and 3 when
    /// only `k < P` pre-uplink replies arrived before the round
    /// deadline: rescale the phase-2 accumulators in place so the round
    /// statistics keep estimating the full-`P` aggregates (the fused
    /// uplink sum itself is rescaled generically by the round driver).
    /// Never called with `k == P` — the fault-free path is bit-identical
    /// to a non-elastic session.
    fn rescale_partial_replies(fu: &mut Self::Fusion, cfg: &RunConfig, k: usize);

    /// Phase 3a: per-signal round statistics, after all replies, written
    /// into the reused `out` (cleared first).
    fn stats(fu: &Self::Fusion, cfg: &RunConfig, out: &mut Vec<RoundStat>);

    /// Phase 3b, hole 1: the variance a round's spec carries (σ̂²_{t,D}
    /// in row mode, the empirical message variance v̂ in column mode).
    fn spec_var(stat: RoundStat) -> f64;

    /// Phase 3b, hole 2: the model channel of one element of the
    /// uplinked message, rebuilt from a spec variance. Every compression
    /// stack designs (and re-assembles) against this channel, so it must
    /// be deterministic in `(prior, p_workers, var)` — both protocol
    /// sides call it with the spec's `model_var`.
    fn channel_for_var(
        prior: &BernoulliGauss,
        p_workers: usize,
        var: f64,
    ) -> (BgChannel, f64);

    /// Phase 5: fold the fused uplink sums (flat `B × len` column-major,
    /// signal `j`'s sum at `sums[j·len..(j+1)·len]`) into the next
    /// round's state — in place, via the engine's `*_into` kernels.
    fn global_step(
        fu: &mut Self::Fusion,
        cfg: &RunConfig,
        se: &StateEvolution,
        engine: &dyn ComputeEngine,
        sums: &[f32],
        stats: &[RoundStat],
        sigma_q2: &[f64],
    ) -> Result<()>;

    /// SE-predicted next effective noise level for the report (the
    /// quantization noise enters the two scenarios differently).
    fn predicted_sigma(se: &StateEvolution, stat: RoundStat, p_sigma_q2: f64) -> f64;

    /// Current estimate of signal `sig`.
    fn x(fu: &Self::Fusion, sig: usize) -> &[f32];

    /// Consume the fusion state, yielding per-signal final estimates.
    fn into_xs(fu: Self::Fusion) -> Vec<Vec<f32>>;

    /// Fresh worker state at `t = 0` for a `batch`-signal session.
    fn worker_init(shard: &Self::Shard, batch: usize) -> Self::WorkerState;

    /// Serve the round's broadcast on the worker: parse `frame`
    /// **zero-copy** with the borrowed decoders (copying the wire floats
    /// into reused `WorkerState` scratch — never an owned `Message` with
    /// fresh `B × N` vectors), update local state, stage the pending
    /// per-signal uplink vectors **flat** into `pending` (`B × len`
    /// column-major, reused every round; quantized and shipped when the
    /// `QuantCmd` arrives), and send the pre-uplink reply directly on
    /// `ep` via [`send_frame`](Endpoint::send_frame) — no reply staging
    /// clones. A frame of the wrong type must fail with a protocol
    /// error, not hang.
    fn worker_serve(
        params: &WorkerParams,
        shard: &Self::Shard,
        ws: &mut Self::WorkerState,
        engine: &dyn ComputeEngine,
        frame: &[u8],
        pending: &mut Vec<f32>,
        ep: &mut Endpoint,
    ) -> Result<()>;
}

/// Split a flat column-major batch vector into per-signal vectors.
pub(crate) fn split_batch_vec(flat: Vec<f32>, b: usize) -> Vec<Vec<f32>> {
    debug_assert_eq!(flat.len() % b.max(1), 0);
    let len = flat.len() / b.max(1);
    (0..b).map(|j| flat[j * len..(j + 1) * len].to_vec()).collect()
}

/// The [`DesignCtx`] both protocol sides derive for one signal's spec:
/// the scenario's model channel at the spec variance, the shared clip
/// range, and the spec's design seed.
pub fn design_ctx<S: Scenario>(
    prior: &BernoulliGauss,
    p_workers: usize,
    model_var: f64,
    len: usize,
    seed: u64,
) -> DesignCtx {
    let (channel, noise_var) = S::channel_for_var(prior, p_workers, model_var);
    DesignCtx { channel, noise_var, clip_sds: CLIP_SDS, len, seed }
}

/// Design one signal's [`QuantSpec`] from its rate directive with the
/// configured compression stack (fusion side; the workers re-assemble
/// the identical stack from the spec via
/// [`compressor_for_spec`](crate::coordinator::worker::compressor_for_spec)).
pub fn design_spec<S: Scenario>(
    stack: &CompressionStack,
    directive: &Directive,
    cfg: &RunConfig,
    t: usize,
    sig: usize,
    stat: RoundStat,
    len: usize,
) -> Result<QuantSpec> {
    let model_var = S::spec_var(stat);
    let seed = design_seed(cfg.seed, t, sig);
    let ctx = design_ctx::<S>(&cfg.prior, cfg.p, model_var, len, seed);
    let state = match directive {
        Directive::Raw => return Ok(QuantSpec::Raw),
        Directive::Skip => return Ok(QuantSpec::Skip),
        Directive::QuantizeMse(q2) => stack.design_mse(&ctx, *q2)?,
        Directive::QuantizeRate(rate) => stack.design_rate(&ctx, *rate)?,
    };
    let params = state.params();
    // Fail at design time with the stack named, not rounds later with a
    // worker-side decode error: the wire cap is a protocol constant.
    if params.len() > crate::coordinator::message::MAX_WIRE_SPEC_PARAMS as usize {
        return Err(Error::Codec(format!(
            "stack '{}' produced {} wire params; the protocol caps specs at {}",
            stack.name(),
            params.len(),
            crate::coordinator::message::MAX_WIRE_SPEC_PARAMS
        )));
    }
    Ok(QuantSpec::Stack { name: stack.name_arc(), model_var, seed, params })
}

/// Per-worker σ_Q² implied by a spec. `Raw` is lossless; a `Skip` round
/// reconstructs zeros, so the error is the model channel's marginal
/// variance; a stack spec reports its designed quantizer's own
/// distortion model (ECSQ: Δ²/12; top-K: dropped energy; custom stacks:
/// whatever their [`QuantizerState::distortion_model`] says).
///
/// [`QuantizerState::distortion_model`]: crate::compress::QuantizerState::distortion_model
pub fn sigma_q2_for_spec<S: Scenario>(
    spec: &QuantSpec,
    comp: Option<&Compressor>,
    prior: &BernoulliGauss,
    p_workers: usize,
    stat: RoundStat,
) -> f64 {
    match spec {
        QuantSpec::Raw => 0.0,
        QuantSpec::Skip => {
            let (ch, ws2) = S::channel_for_var(prior, p_workers, S::spec_var(stat));
            ch.var_f(ws2)
        }
        QuantSpec::Stack { .. } => comp.map(|c| c.distortion_model()).unwrap_or(0.0),
    }
}

/// Fuse one signal's payload into `sum`, straight from the borrowed wire
/// view (shared by both scenarios — they differ only in the compressor
/// that gets passed in). Raw payloads accumulate directly out of the
/// receive buffer; coded payloads decode into the persistent
/// `decode_scratch` (every dequantizer overwrites the full block, so
/// reuse is safe).
fn fuse_payload(
    payload: FPayloadRef<'_>,
    comp: &Option<Compressor>,
    worker: u32,
    len: usize,
    sum: &mut [f32],
    decode_scratch: &mut Vec<f32>,
    wire_bits: &mut f64,
) -> Result<()> {
    match payload {
        FPayloadRef::Raw(v) => {
            if v.len() != len {
                return Err(Error::Protocol(format!(
                    "fusion: raw payload length {} != {len}",
                    v.len()
                )));
            }
            // Payload-free codecs (analytic): account the model bits
            // instead of the raw float bits that moved in-process.
            if let Some(c) = comp {
                if !c.carries_payload() {
                    *wire_bits += c.model_bits_per_element() * len as f64
                        - 32.0 * len as f64;
                }
            }
            v.add_to(sum);
        }
        FPayloadRef::Coded { n, bytes } => {
            let c = comp.as_ref().ok_or_else(|| {
                Error::Protocol("coded payload without a stack spec".into())
            })?;
            if n as usize != len {
                return Err(Error::Protocol(format!(
                    "fusion: coded payload length {n} != {len}"
                )));
            }
            decode_scratch.resize(len, 0.0);
            c.decode(&BlockCtx { worker }, bytes, decode_scratch)?;
            crate::linalg::axpy(1.0, decode_scratch, sum);
        }
        FPayloadRef::Skipped => {}
    }
    Ok(())
}

/// How one endpoint's deadline-bounded receive resolved for the elastic
/// round driver.
enum RoundRecv {
    /// The expected frame arrived and sits in the endpoint's receive
    /// buffer (re-borrow it with [`Endpoint::last_frame`]).
    Frame,
    /// The deadline expired with the link intact — the worker is a
    /// straggler this round, not dead.
    TimedOut,
    /// A current-round frame arrived but failed the header checks
    /// (wrong tag or worker id — e.g. a corrupted uplink); the worker
    /// sends nothing further this round, so give up on it now instead
    /// of burning the rest of the deadline.
    Rejected,
}

/// Header-only verdict on a received frame, produced inside the drain
/// loop so no borrow of the receive buffer escapes an iteration.
enum Verdict {
    Keep,
    Stale,
    Reject,
}

/// Peek a frame's `(tag, t)` header without decoding the body. `None`
/// for runt frames (the 1-byte `Done` never flows worker → fusion, so
/// anything shorter than a round header is stale garbage here).
fn frame_header(frame: &[u8]) -> Option<(u8, u32)> {
    if frame.len() < 5 {
        return None;
    }
    Some((frame[0], u32::from_le_bytes([frame[1], frame[2], frame[3], frame[4]])))
}

/// Classify a frame against the phase's expectation. Frames from earlier
/// rounds (late straggler replies the elastic barrier moved on without)
/// are `Stale` — drained and discarded. A current-round frame with the
/// wrong tag or, for uplinks, a worker id that does not match the
/// endpoint's slot (the signature of a corrupted frame) is `Reject`:
/// everything behind it is this worker's business, not ours, and the
/// body validation would refuse it anyway.
fn classify_frame(frame: &[u8], want_tag: u8, t: u32, want_worker: Option<u32>) -> Verdict {
    let (tag, ft) = match frame_header(frame) {
        Some(h) => h,
        None => return Verdict::Stale,
    };
    if ft < t {
        return Verdict::Stale;
    }
    if tag != want_tag || ft != t {
        return Verdict::Reject;
    }
    if let Some(w) = want_worker {
        // FVector header: worker id at bytes [5..9]. Checking it here —
        // before any payload is fused — is what keeps a corrupted frame
        // from polluting the round's sums.
        if frame.len() < 13 {
            return Verdict::Reject;
        }
        if u32::from_le_bytes([frame[5], frame[6], frame[7], frame[8]]) != w {
            return Verdict::Reject;
        }
    }
    Verdict::Keep
}

/// Deadline-bounded receive of the round-`t` frame tagged `want_tag`
/// (and, for uplinks, from worker `want_worker`), draining and
/// discarding stale straggler frames along the way. The whole drain —
/// however many stale frames it swallows — shares one `budget`, so a
/// flooding peer cannot stall the round past the deadline. On
/// `Ok(RoundRecv::Frame)` the accepted frame is the endpoint's
/// [`last_frame`](Endpoint::last_frame).
fn recv_round_frame(
    ep: &mut Endpoint,
    budget: Duration,
    want_tag: u8,
    t: u32,
    want_worker: Option<u32>,
) -> Result<RoundRecv> {
    let start = Instant::now();
    loop {
        let remaining = budget.saturating_sub(start.elapsed());
        if remaining.is_zero() {
            return Ok(RoundRecv::TimedOut);
        }
        let verdict = match ep.recv_frame_by(remaining)? {
            None => return Ok(RoundRecv::TimedOut),
            Some(frame) => classify_frame(frame, want_tag, t, want_worker),
        };
        match verdict {
            Verdict::Keep => return Ok(RoundRecv::Frame),
            Verdict::Stale => continue,
            Verdict::Reject => return Ok(RoundRecv::Rejected),
        }
    }
}

/// How long the elastic barrier polls a worker already marked dead: just
/// enough to notice a daemon-side reconnect resurrecting the slot,
/// without spending the full round deadline on a peer that is known
/// gone.
const DEAD_POLL: Duration = Duration::from_millis(2);

/// Per-session round scratch: every buffer the round loop needs, sized
/// on the first round and reused (cleared or overwritten in place) on
/// every later one, so steady-state rounds allocate nothing proportional
/// to the problem size.
#[derive(Default)]
struct RoundScratch {
    /// Broadcast/quant frame — each round command is encoded exactly
    /// once here and sent pre-encoded to every endpoint.
    frame: Vec<u8>,
    /// Per-signal round statistics.
    stats: Vec<RoundStat>,
    /// Per-signal rate directives.
    directives: Vec<Directive>,
    /// Per-signal quantizer specs.
    specs: Vec<QuantSpec>,
    /// Per-signal decoders (rebuilt each round from the specs).
    comps: Vec<Option<Compressor>>,
    /// Per-signal σ_Q².
    sigma_q2s: Vec<f64>,
    /// Fusion sums, flat `B × len` column-major.
    sums: Vec<f32>,
    /// Coded-payload decode scratch (`len`).
    decode: Vec<f32>,
    /// Elastic rounds only: which workers made this round's phase-2
    /// barrier (phase 4 collects uplinks from exactly this set).
    live: Vec<bool>,
    /// Elastic rounds only: one worker's uplink staged `B × len` before
    /// it is committed to `sums`, so a worker whose frame fails body
    /// validation mid-fuse contributes nothing instead of a torn sum.
    wsum: Vec<f32>,
}

/// The generic, resumable fusion-side protocol driver: one [`step`]
/// executes exactly one round of whichever [`Scenario`] it is
/// instantiated with, over all `B` signals of the session's batch.
///
/// [`step`]: ProtocolCore::step
pub struct ProtocolCore<S: Scenario> {
    fu: S::Fusion,
    b: usize,
    t: usize,
    scratch: RoundScratch,
    /// Workers whose link raised peer loss (elastic sessions): polled
    /// with [`DEAD_POLL`] instead of the round deadline until a frame
    /// proves them resurrected (daemon reconnect).
    dead: Vec<bool>,
    tel: Telemetry,
}

impl<S: Scenario> ProtocolCore<S> {
    /// Fresh state at `t = 0` (telemetry disabled; see
    /// [`set_telemetry`](ProtocolCore::set_telemetry)).
    pub fn new(batch: &Batch, cfg: &RunConfig) -> Self {
        ProtocolCore {
            fu: S::init(batch, cfg),
            b: batch.batch(),
            t: 0,
            scratch: RoundScratch::default(),
            dead: vec![false; cfg.p],
            tel: Telemetry::off(),
        }
    }

    /// Attach a [`Telemetry`] handle: every subsequent round records one
    /// span per phase plus a whole-round envelope carrying the round's
    /// wire bits, batch-mean σ_Q², and SE-predicted vs empirical MSE.
    /// Recording is measurement-only — it never feeds back into the
    /// algorithm, so traced sessions stay bit-identical to untraced ones.
    pub fn set_telemetry(&mut self, tel: Telemetry) {
        self.tel = tel;
    }

    /// Iterations completed so far.
    pub fn t(&self) -> usize {
        self.t
    }

    /// Number of signals in the session's batch.
    pub fn batch(&self) -> usize {
        self.b
    }

    /// The current estimate of signal `sig`.
    pub fn x(&self, sig: usize) -> &[f32] {
        S::x(&self.fu, sig)
    }

    /// Consume the state, yielding the per-signal final estimates.
    pub fn into_xs(self) -> Vec<Vec<f32>> {
        S::into_xs(self.fu)
    }

    /// Run one protocol round over the worker endpoints. `eval` (ground
    /// truth) fills the SDR fields of the record — it is measurement-only
    /// and never feeds back into the algorithm. Per-signal quantities are
    /// reported as batch means (for `B = 1` the record is bit-for-bit the
    /// single-signal record).
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &mut self,
        cfg: &RunConfig,
        se: &StateEvolution,
        controller: &dyn RateAllocator,
        cache: Option<&RdCache>,
        engine: &dyn ComputeEngine,
        endpoints: &mut [Endpoint],
        eval: Option<&Batch>,
    ) -> Result<IterRecord> {
        let t = self.t;
        let p = cfg.p;
        let b = self.b;
        debug_assert_eq!(endpoints.len(), p);
        let t0 = Instant::now();
        // Span recording is gated on one flag check; when the handle is
        // off the round loop takes no clock reads and no locks.
        let tel = self.tel.clone();
        let tel_on = tel.is_on();
        let round_start_us = if tel_on { tel.clock_us() } else { 0 };
        let mut mark_us = round_start_us;
        let stack = crate::compress::registry::get(&cfg.compressor)?;
        let len = S::uplink_len(cfg);
        // Elastic K-of-P is armed by both knobs together (validation
        // rejects one without the other); when off, every barrier below
        // is the original blocking all-P path, bit for bit.
        let elastic = cfg.min_workers > 0 && cfg.round_deadline_ms > 0;
        let deadline = Duration::from_millis(cfg.round_deadline_ms.max(1));
        if self.dead.len() != p {
            self.dead.resize(p, false);
        }
        let dead = &mut self.dead;
        // Split-borrow the persistent scratch so fusion state and the
        // round buffers can be used independently below.
        let RoundScratch {
            frame,
            stats,
            directives,
            specs,
            comps,
            sigma_q2s,
            sums,
            decode,
            live,
            wsum,
        } = &mut self.scratch;
        live.clear();
        live.resize(p, true);
        // 1. Encode the round command once, broadcast the same frame to
        //    every endpoint. Elastic sessions tolerate a dead endpoint —
        //    that worker just misses the round.
        S::begin_round(&mut self.fu, cfg, t, frame);
        for (widx, ep) in endpoints.iter_mut().enumerate() {
            match ep.send_encoded(frame) {
                Ok(()) => {}
                Err(e) if elastic && (e.is_peer_loss() || e.is_timeout()) => {
                    live[widx] = false;
                    dead[widx] = true;
                }
                Err(e) => return Err(e),
            }
        }
        if tel_on {
            mark_us = tel.phase(Stage::Encode, t, -1, mark_us, 0.0);
        }
        // 2. Absorb every worker's pre-uplink reply (worker-id order),
        //    parsed in place from each endpoint's receive buffer.
        //    Elastic sessions bound the wait per endpoint, drain stale
        //    straggler frames by round tag, and move on once the
        //    deadline fires — down to `min_workers` live replies.
        if !elastic {
            for (widx, ep) in endpoints.iter_mut().enumerate() {
                let reply = ep.recv_frame()?;
                S::absorb(&mut self.fu, cfg, t, widx, reply)?;
            }
        } else {
            for (widx, ep) in endpoints.iter_mut().enumerate() {
                if !live[widx] {
                    continue;
                }
                let budget = if dead[widx] { DEAD_POLL } else { deadline };
                match recv_round_frame(ep, budget, S::REPLY_TAG, t as u32, None) {
                    Ok(RoundRecv::Frame) => {
                        match S::absorb(&mut self.fu, cfg, t, widx, ep.last_frame()) {
                            Ok(()) => dead[widx] = false,
                            // A reply that fails body validation counts
                            // as missing, not fatal — the rescale and
                            // the K floor below absorb it.
                            Err(_) => live[widx] = false,
                        }
                    }
                    Ok(RoundRecv::TimedOut) | Ok(RoundRecv::Rejected) => live[widx] = false,
                    Err(e) if e.is_peer_loss() => {
                        live[widx] = false;
                        dead[widx] = true;
                    }
                    Err(e) if e.is_timeout() => live[widx] = false,
                    Err(e) => return Err(e),
                }
            }
            let k = live.iter().filter(|&&l| l).count();
            if k < cfg.min_workers {
                return Err(Error::Degraded(format!(
                    "{k} live pre-uplink replies < min_workers {} at round {t}",
                    cfg.min_workers
                )));
            }
            if k < p {
                S::rescale_partial_replies(&mut self.fu, cfg, k);
            }
        }
        if tel_on {
            mark_us = tel.phase(Stage::Fusion, t, -1, mark_us, 0.0);
        }
        // 3. Per-signal stats → directives → stack designs → one batched
        //    quantizer round trip covering the whole batch (the QuantCmd
        //    is likewise encoded once).
        S::stats(&self.fu, cfg, stats);
        debug_assert_eq!(stats.len(), b);
        directives.clear();
        specs.clear();
        for (sig, stat) in stats.iter().enumerate() {
            let d = controller.directive(t, stat.sigma_d2_hat, se, p, cfg.iters, cache);
            specs.push(design_spec::<S>(&stack, &d, cfg, t, sig, *stat, len)?);
            directives.push(d);
        }
        message::encode_quant_cmd(frame, t as u32, specs);
        // Stragglers that missed the phase-2 barrier still get the
        // QuantCmd: their protocol state machine stays in sync, their
        // local state keeps evolving from the (global) broadcasts, and
        // their unfused uplink is drained by round tag later — so a
        // slow worker rejoins seamlessly at the next round it makes.
        for (widx, ep) in endpoints.iter_mut().enumerate() {
            match ep.send_encoded(frame) {
                Ok(()) => {}
                Err(e) if elastic && (e.is_peer_loss() || e.is_timeout()) => {
                    live[widx] = false;
                    dead[widx] = true;
                }
                Err(e) => return Err(e),
            }
        }
        // The decoders matching the workers' encoders, one per signal —
        // assembled from the spec exactly the way the workers do it.
        comps.clear();
        sigma_q2s.clear();
        for (spec, stat) in specs.iter().zip(stats.iter()) {
            let comp = compressor_for_spec::<S>(spec, &cfg.prior, p, len)?;
            sigma_q2s.push(sigma_q2_for_spec::<S>(
                spec,
                comp.as_ref(),
                &cfg.prior,
                p,
                *stat,
            ));
            comps.push(comp);
        }
        if tel_on {
            mark_us = tel.phase(Stage::Allocator, t, -1, mark_us, 0.0);
        }
        // 4. Collect and fuse the batched uplinks, accumulating each
        //    payload straight out of the receive buffer into the
        //    persistent flat sums.
        sums.resize(b * len, 0.0);
        sums.iter_mut().for_each(|s| *s = 0.0);
        let mut wire_bits = 0.0f64;
        if !elastic {
            for (widx, ep) in endpoints.iter_mut().enumerate() {
                let reply = ep.recv_frame()?;
                let (rt, worker, count) = message::decode_fvector(reply, |sig, payload| {
                    if sig >= b {
                        return Err(Error::Protocol(format!(
                            "fusion: more than {b} payloads from worker {widx}"
                        )));
                    }
                    wire_bits += payload.wire_bits();
                    fuse_payload(
                        payload,
                        &comps[sig],
                        widx as u32,
                        len,
                        &mut sums[sig * len..(sig + 1) * len],
                        decode,
                        &mut wire_bits,
                    )
                })?;
                if rt as usize != t || worker as usize != widx {
                    return Err(Error::Protocol(format!(
                        "fusion: bad FVector (t={rt}, worker={worker}) expected \
                         (t={t}, worker={widx})"
                    )));
                }
                if count != b {
                    return Err(Error::Protocol(format!(
                        "fusion: {count} payloads from worker {widx}, batch is {b}"
                    )));
                }
            }
        } else {
            // Collect uplinks from exactly the phase-2 live set. Each
            // worker's payloads are staged into `wsum` and committed to
            // `sums` only after the whole frame validated, so a corrupt
            // or truncated uplink contributes nothing (staging from
            // zeros then adding in worker-id order is bit-identical to
            // fusing in place — `sums` starts at +0.0 and stays
            // non-negative-zero under addition).
            wsum.resize(b * len, 0.0);
            let mut k4 = 0usize;
            for (widx, ep) in endpoints.iter_mut().enumerate() {
                if !live[widx] {
                    continue;
                }
                let budget = if dead[widx] { DEAD_POLL } else { deadline };
                let fused = match recv_round_frame(
                    ep,
                    budget,
                    message::TAG_FVEC,
                    t as u32,
                    Some(widx as u32),
                ) {
                    Ok(RoundRecv::Frame) => {
                        wsum.iter_mut().for_each(|s| *s = 0.0);
                        let mut wbits = 0.0f64;
                        let parsed = message::decode_fvector(ep.last_frame(), |sig, payload| {
                            if sig >= b {
                                return Err(Error::Protocol(format!(
                                    "fusion: more than {b} payloads from worker {widx}"
                                )));
                            }
                            wbits += payload.wire_bits();
                            fuse_payload(
                                payload,
                                &comps[sig],
                                widx as u32,
                                len,
                                &mut wsum[sig * len..(sig + 1) * len],
                                decode,
                                &mut wbits,
                            )
                        });
                        match parsed {
                            // The (t, worker) header ids were pre-checked
                            // by the drain loop; the payload count is the
                            // one body invariant left.
                            Ok((_, _, count)) if count == b => {
                                for (s, w) in sums.iter_mut().zip(wsum.iter()) {
                                    *s += *w;
                                }
                                wire_bits += wbits;
                                true
                            }
                            Ok(_) => false,
                            Err(e) if e.is_peer_loss() => {
                                dead[widx] = true;
                                false
                            }
                            Err(Error::Protocol(_)) | Err(Error::Codec(_)) => false,
                            Err(e) => return Err(e),
                        }
                    }
                    Ok(RoundRecv::TimedOut) | Ok(RoundRecv::Rejected) => false,
                    Err(e) if e.is_peer_loss() => {
                        dead[widx] = true;
                        false
                    }
                    Err(e) if e.is_timeout() => false,
                    Err(e) => return Err(e),
                };
                if fused {
                    k4 += 1;
                } else {
                    live[widx] = false;
                }
            }
            if k4 < cfg.min_workers {
                return Err(Error::Degraded(format!(
                    "{k4} live uplinks < min_workers {} at round {t}",
                    cfg.min_workers
                )));
            }
            if k4 < p {
                // Unbias the partial fusion (scale by P/K) and fold the
                // missing shard mass into the per-worker σ_Q² slot: the
                // scenario's model channel noise ws² is the per-worker
                // message variance, so the rescaled sum carries an extra
                // P·ws²·(P−K)/K of variance. Threading it through
                // `sigma_q2s` puts it in front of the denoiser's
                // effective noise level, `S::predicted_sigma`, and the
                // BT/DP allocators in one move — the same path the
                // paper's quantization error takes (eq. 8).
                let scale = (p as f64 / k4 as f64) as f32;
                sums.iter_mut().for_each(|v| *v *= scale);
                let miss = (p - k4) as f64 / k4 as f64;
                for (j, stat) in stats.iter().enumerate() {
                    let (_, ws2) = S::channel_for_var(&cfg.prior, p, S::spec_var(*stat));
                    sigma_q2s[j] += ws2 * miss;
                }
            }
        }
        if tel_on {
            mark_us = tel.phase(Stage::Uplink, t, -1, mark_us, wire_bits);
        }
        // Allocation accounting (analytic rate, batch mean).
        let rate_alloc = directives
            .iter()
            .zip(comps.iter())
            .map(|(d, c)| match d {
                Directive::Raw => 32.0,
                Directive::Skip => 0.0,
                Directive::QuantizeRate(r) => *r,
                Directive::QuantizeMse(_) => {
                    c.as_ref().map(|c| c.model_bits_per_element()).unwrap_or(0.0)
                }
            })
            .sum::<f64>()
            / b as f64;
        // 5. Scenario-specific global computation over all signals, in
        //    place on the fusion state.
        S::global_step(&mut self.fu, cfg, se, engine, sums, stats, sigma_q2s)?;
        self.t = t + 1;
        if tel_on {
            tel.phase(Stage::Denoise, t, -1, mark_us, 0.0);
        }
        // 6. Record.
        let sdr_db = match eval {
            Some(batch) => {
                (0..b).map(|j| batch.sdr_db(j, S::x(&self.fu, j))).sum::<f64>() / b as f64
            }
            None => f64::NAN,
        };
        let sdr_pred_db = stats
            .iter()
            .zip(sigma_q2s.iter())
            .map(|(stat, q2)| se.sdr_db(S::predicted_sigma(se, *stat, p as f64 * q2)))
            .sum::<f64>()
            / b as f64;
        let rec = IterRecord {
            t,
            sdr_db,
            sdr_pred_db,
            rate_alloc,
            rate_wire: wire_bits / (p as f64 * (b * len) as f64),
            sigma_q2: sigma_q2s.iter().sum::<f64>() / b as f64,
            sigma_d2_hat: stats.iter().map(|s| s.sigma_d2_hat).sum::<f64>() / b as f64,
            wall_s: t0.elapsed().as_secs_f64(),
        };
        if tel_on {
            // The whole-round envelope carries the round's payload: wire
            // bits (their sum over rounds is the session's uplink payload
            // bits), mean σ_Q², and SE-predicted vs empirical MSE.
            let mse_pred = stats
                .iter()
                .zip(sigma_q2s.iter())
                .map(|(stat, q2)| S::predicted_sigma(se, *stat, p as f64 * q2))
                .sum::<f64>()
                / b as f64;
            tel.round(t, round_start_us, wire_bits, rec.sigma_q2, mse_pred, rec.sigma_d2_hat);
        }
        Ok(rec)
    }

    /// Release the workers: broadcast `Done` on every endpoint (encoded
    /// once, like every other broadcast).
    pub fn finish(endpoints: &mut [Endpoint]) -> Result<()> {
        let done = Message::Done.encode();
        for ep in endpoints.iter_mut() {
            ep.send_encoded(&done)?;
        }
        Ok(())
    }

    /// [`finish`](ProtocolCore::finish) for elastic sessions: a `Done`
    /// that cannot be delivered because the peer is gone (or its link
    /// timed out) is swallowed — the session already survived that
    /// worker's absence, releasing it is moot. Any other send failure
    /// still propagates.
    pub fn finish_lossy(endpoints: &mut [Endpoint]) -> Result<()> {
        let done = Message::Done.encode();
        for ep in endpoints.iter_mut() {
            if let Err(e) = ep.send_encoded(&done) {
                if !(e.is_peer_loss() || e.is_timeout()) {
                    return Err(e);
                }
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Row-wise MP-AMP (Han, Zhu, Niu & Baron 2016)
// ---------------------------------------------------------------------

/// Row-partitioned MP-AMP: workers own row blocks of `A` plus measurement
/// slices and uplink local estimates `f_t^p` (length `N`); the fusion
/// center denoises.
#[derive(Debug, Clone, Copy)]
pub struct Row;

/// Fusion state of the row scenario: per-signal estimates, Onsager
/// coefficients, and the round's `‖z‖²` accumulators.
#[derive(Debug, Clone)]
pub struct RowFusion {
    n: usize,
    b: usize,
    /// Estimates, `B × N` column-major.
    x: Vec<f32>,
    /// Per-signal Onsager coefficients.
    coefs: Vec<f32>,
    /// Per-signal Σ_p ‖z_t^p‖² accumulators (reset each round).
    znorm: Vec<f64>,
}

/// Worker state of the row scenario: the local residuals plus the
/// round-scratch buffers the engine's `lc_step_batch_into` writes into
/// and the broadcast-decode scratch the wire floats are copied into
/// (all sized once, reused every round).
#[derive(Debug, Clone)]
pub struct RowWorker {
    /// Local residuals, `B × (M/P)` column-major.
    z_prev: Vec<f32>,
    /// Next-round residual scratch (swapped with `z_prev` each round).
    z_next: Vec<f32>,
    /// Per-signal `‖z‖²` reply scratch.
    z_norm2: Vec<f64>,
    /// Broadcast decode scratch: per-signal Onsager coefficients.
    coefs: Vec<f32>,
    /// Broadcast decode scratch: estimates, `B × N` column-major.
    x: Vec<f32>,
}

impl Scenario for Row {
    type Shard = RowBatchData;
    type Fusion = RowFusion;
    type WorkerState = RowWorker;

    const NAME: &'static str = "row";

    const REPLY_TAG: u8 = message::TAG_ZNORM;

    fn split(batch: &Batch, p: usize) -> Result<Vec<RowBatchData>> {
        RowBatchData::try_split(batch, p)
    }

    fn init(batch: &Batch, cfg: &RunConfig) -> RowFusion {
        let b = batch.batch();
        RowFusion {
            n: cfg.n,
            b,
            x: vec![0f32; b * cfg.n],
            coefs: vec![0f32; b],
            znorm: vec![0f64; b],
        }
    }

    fn uplink_len(cfg: &RunConfig) -> usize {
        cfg.n
    }

    fn begin_round(fu: &mut RowFusion, _cfg: &RunConfig, t: usize, frame: &mut Vec<u8>) {
        fu.znorm.iter_mut().for_each(|v| *v = 0.0);
        // Encode the broadcast straight from the fusion state — the old
        // per-endpoint re-encode cloned `coefs` and the `B × N` estimate
        // every round.
        message::encode_step_cmd(frame, t as u32, &fu.coefs, &fu.x);
    }

    fn absorb(
        fu: &mut RowFusion,
        _cfg: &RunConfig,
        t: usize,
        widx: usize,
        frame: &[u8],
    ) -> Result<()> {
        let reply = message::decode_znorm(frame).map_err(|e| {
            Error::Protocol(format!("fusion: expected ZNorm from worker {widx}: {e}"))
        })?;
        if reply.t as usize != t || reply.worker as usize != widx {
            return Err(Error::Protocol(format!(
                "fusion: bad ZNorm (t={}, worker={}) expected (t={t}, worker={widx})",
                reply.t, reply.worker
            )));
        }
        if reply.z_norm2.len() != fu.b {
            return Err(Error::Protocol(format!(
                "fusion: {} z-norms from worker {widx}, batch is {}",
                reply.z_norm2.len(),
                fu.b
            )));
        }
        for (acc, v) in fu.znorm.iter_mut().zip(reply.z_norm2.iter()) {
            *acc += v;
        }
        Ok(())
    }

    fn rescale_partial_replies(fu: &mut RowFusion, cfg: &RunConfig, k: usize) {
        // Only k of P ‖z^p‖² replies made the barrier: rescale the
        // aggregate so σ̂² = Σ_p‖z^p‖²/M keeps estimating the full-P
        // residual energy (row shards are equal-sized, so the partial
        // sum is an unbiased k/P fraction of it).
        let scale = cfg.p as f64 / k as f64;
        fu.znorm.iter_mut().for_each(|v| *v *= scale);
    }

    fn stats(fu: &RowFusion, cfg: &RunConfig, out: &mut Vec<RoundStat>) {
        let m = cfg.m as f64;
        out.clear();
        out.extend(fu.znorm.iter().map(|&zn| {
            let s = zn / m;
            RoundStat { sigma_d2_hat: s, msg_var: s }
        }));
    }

    fn spec_var(stat: RoundStat) -> f64 {
        stat.sigma_d2_hat
    }

    fn channel_for_var(
        prior: &BernoulliGauss,
        p_workers: usize,
        var: f64,
    ) -> (BgChannel, f64) {
        // The per-worker uplink channel F_t^p at σ̂² (paper §3.2).
        BgChannel::new(*prior).worker_channel(var, p_workers)
    }

    fn global_step(
        fu: &mut RowFusion,
        cfg: &RunConfig,
        se: &StateEvolution,
        engine: &dyn ComputeEngine,
        sums: &[f32],
        stats: &[RoundStat],
        sigma_q2: &[f64],
    ) -> Result<()> {
        let n = fu.n;
        for j in 0..fu.b {
            // Denoise at the quantization-aware effective noise level,
            // straight into the fusion state (no intermediate estimate).
            let sigma_eff2 = stats[j].sigma_d2_hat + cfg.p as f64 * sigma_q2[j];
            let eta = engine.gc_step_into(
                &sums[j * n..(j + 1) * n],
                sigma_eff2,
                &mut fu.x[j * n..(j + 1) * n],
            )?;
            fu.coefs[j] = (eta / se.kappa) as f32;
        }
        Ok(())
    }

    fn predicted_sigma(se: &StateEvolution, stat: RoundStat, p_sigma_q2: f64) -> f64 {
        se.step_quantized(stat.sigma_d2_hat, p_sigma_q2)
    }

    fn x(fu: &RowFusion, sig: usize) -> &[f32] {
        &fu.x[sig * fu.n..(sig + 1) * fu.n]
    }

    fn into_xs(fu: RowFusion) -> Vec<Vec<f32>> {
        split_batch_vec(fu.x, fu.b)
    }

    fn worker_init(shard: &RowBatchData, batch: usize) -> RowWorker {
        RowWorker {
            z_prev: vec![0f32; batch * shard.a.rows()],
            z_next: Vec::new(),
            z_norm2: Vec::new(),
            coefs: Vec::new(),
            x: Vec::new(),
        }
    }

    fn worker_serve(
        params: &WorkerParams,
        shard: &RowBatchData,
        ws: &mut RowWorker,
        engine: &dyn ComputeEngine,
        frame: &[u8],
        pending: &mut Vec<f32>,
        ep: &mut Endpoint,
    ) -> Result<()> {
        let cmd = message::decode_step_cmd(frame)
            .map_err(|e| Error::Protocol(format!("worker {}: {e}", params.id)))?;
        let b = params.batch;
        let n = shard.a.cols();
        if cmd.coefs.len() != b || cmd.x.len() != b * n {
            return Err(Error::Protocol(format!(
                "worker {}: StepCmd batch {} / x length {} do not match \
                 batch {b} × N {n}",
                params.id,
                cmd.coefs.len(),
                cmd.x.len()
            )));
        }
        // Copy the broadcast out of the wire view into reused scratch —
        // the engine kernels need contiguous slices, but the old owned
        // decode (a fresh B × N vector every round) is gone.
        ws.coefs.resize(b, 0.0);
        cmd.coefs.copy_to(&mut ws.coefs);
        ws.x.resize(b * n, 0.0);
        cmd.x.copy_to(&mut ws.x);
        // The pending uplinks (f) land flat in the shared staging
        // buffer; residuals swap through the reused scratch.
        engine.lc_step_batch_into(
            shard,
            &ws.x,
            &ws.z_prev,
            &ws.coefs,
            params.p_workers,
            &mut ws.z_next,
            pending,
            &mut ws.z_norm2,
        )?;
        std::mem::swap(&mut ws.z_prev, &mut ws.z_next);
        let (id, z_norm2) = (params.id, &ws.z_norm2);
        ep.send_frame(|buf| {
            message::encode_znorm(buf, cmd.t, id, z_norm2);
            Ok(())
        })
    }
}

// ---------------------------------------------------------------------
// Column-wise C-MP-AMP (Ma, Lu & Baron 2017)
// ---------------------------------------------------------------------

/// Column-partitioned C-MP-AMP: workers own column blocks and denoise
/// locally; the fusion center owns `y`, broadcasts the combined residual,
/// and workers uplink partial residuals `u_t^p = A^p x_t^p` (length `M`).
#[derive(Debug, Clone, Copy)]
pub struct Column;

/// Fusion state of the column scenario: the measurements, combined
/// residuals, assembled estimates, and the round's scalar accumulators.
#[derive(Debug, Clone)]
pub struct ColumnFusion {
    n: usize,
    m: usize,
    b: usize,
    /// Measurements, `B × M` column-major.
    y: Vec<f32>,
    /// Combined residuals, `B × M` column-major.
    z: Vec<f32>,
    /// Assembled estimates (from the eval shards), `B × N` column-major.
    x: Vec<f32>,
    /// Per-signal σ̂² = ‖z_j‖²/M (computed at broadcast time).
    sigma_d2: Vec<f64>,
    /// Per-signal Σ_p ‖u^p_j‖² accumulators (reset each round).
    unorm: Vec<f64>,
    /// Per-signal Σ_p mean(η′) accumulators (reset each round).
    deriv: Vec<f64>,
}

/// Worker state of the column scenario: the local estimate blocks plus
/// the round-scratch buffers `col_lc_step_batch_into` writes into and
/// the broadcast-decode scratch the wire floats are copied into (all
/// sized once, reused every round).
#[derive(Debug, Clone)]
pub struct ColumnWorker {
    /// Local estimate blocks, `B × (N/P)` column-major.
    x: Vec<f32>,
    /// Next-round estimate scratch (swapped with `x` each round).
    x_next: Vec<f32>,
    /// Per-signal `‖u‖²` reply scratch.
    u_norm2: Vec<f64>,
    /// Per-signal η′-mean reply scratch.
    eta: Vec<f64>,
    /// Pseudo-data scratch for the engine (`B × (N/P)`).
    f_scratch: Vec<f32>,
    /// Broadcast decode scratch: per-signal noise levels.
    sigma_eff2: Vec<f64>,
    /// Broadcast decode scratch: combined residuals, `B × M` column-major.
    z: Vec<f32>,
}

impl Scenario for Column {
    type Shard = ColumnWorkerData;
    type Fusion = ColumnFusion;
    type WorkerState = ColumnWorker;

    const NAME: &'static str = "column";

    const REPLY_TAG: u8 = message::TAG_COLSCALARS;

    fn split(batch: &Batch, p: usize) -> Result<Vec<ColumnWorkerData>> {
        ColumnWorkerData::try_split(&batch.a, p)
    }

    fn init(batch: &Batch, cfg: &RunConfig) -> ColumnFusion {
        let b = batch.batch();
        let m = cfg.m;
        let mut y = Vec::with_capacity(b * m);
        for yj in &batch.y {
            y.extend_from_slice(yj);
        }
        // The residual starts at y (the estimate is all-zero), matching
        // centralized AMP's first iteration exactly.
        ColumnFusion {
            n: cfg.n,
            m,
            b,
            z: y.clone(),
            y,
            x: vec![0f32; b * cfg.n],
            sigma_d2: vec![0f64; b],
            unorm: vec![0f64; b],
            deriv: vec![0f64; b],
        }
    }

    fn uplink_len(cfg: &RunConfig) -> usize {
        cfg.m
    }

    fn begin_round(fu: &mut ColumnFusion, _cfg: &RunConfig, t: usize, frame: &mut Vec<u8>) {
        let m = fu.m;
        for j in 0..fu.b {
            fu.sigma_d2[j] =
                crate::linalg::norm2_sq(&fu.z[j * m..(j + 1) * m]) / m as f64;
        }
        fu.unorm.iter_mut().for_each(|v| *v = 0.0);
        fu.deriv.iter_mut().for_each(|v| *v = 0.0);
        // Broadcast the residuals + the denoisers' effective noise levels
        // (the residual variance already carries the quantization noise of
        // previous iterations — see `StateEvolution::column_residual_step`),
        // encoded straight from the fusion state (no clones).
        message::encode_col_step(frame, t as u32, &fu.sigma_d2, &fu.z);
    }

    fn absorb(
        fu: &mut ColumnFusion,
        cfg: &RunConfig,
        t: usize,
        widx: usize,
        frame: &[u8],
    ) -> Result<()> {
        let np = cfg.n / cfg.p;
        let reply = message::decode_col_scalars(frame).map_err(|e| {
            Error::Protocol(format!(
                "fusion: expected ColScalars from worker {widx}: {e}"
            ))
        })?;
        if reply.t as usize != t || reply.worker as usize != widx {
            return Err(Error::Protocol(format!(
                "fusion: bad ColScalars (t={}, worker={}) expected \
                 (t={t}, worker={widx})",
                reply.t, reply.worker
            )));
        }
        if reply.u_norm2.len() != fu.b
            || reply.eta_prime_mean.len() != fu.b
            || reply.x_shard.len() != fu.b * np
        {
            return Err(Error::Protocol(format!(
                "fusion: ColScalars batch sizes ({}, {}, {}) from worker \
                 {widx} do not match batch {} × N/P {np}",
                reply.u_norm2.len(),
                reply.eta_prime_mean.len(),
                reply.x_shard.len(),
                fu.b
            )));
        }
        for (j, (un, eta)) in
            reply.u_norm2.iter().zip(reply.eta_prime_mean.iter()).enumerate()
        {
            fu.unorm[j] += un;
            fu.deriv[j] += eta;
        }
        // Copy the eval shards straight out of the wire view into the
        // assembled estimates.
        for j in 0..fu.b {
            reply
                .x_shard
                .slice(j * np, np)
                .copy_to(&mut fu.x[j * fu.n + widx * np..j * fu.n + (widx + 1) * np]);
        }
        Ok(())
    }

    fn rescale_partial_replies(fu: &mut ColumnFusion, cfg: &RunConfig, k: usize) {
        // Only k of P ColScalars replies made the barrier: rescale the
        // Σ_p‖u^p‖² and Σ_p η̄′ aggregates so v̂ = Σ‖u^p‖²/(P·M) and the
        // Onsager mean (÷P in `global_step`) keep estimating the full-P
        // quantities. σ̂² is computed fusion-side from the residual and
        // needs no correction; a missing worker's eval shard in `x`
        // simply stays at its last uplinked value (measurement only).
        let scale = cfg.p as f64 / k as f64;
        fu.unorm.iter_mut().for_each(|v| *v *= scale);
        fu.deriv.iter_mut().for_each(|v| *v *= scale);
    }

    fn stats(fu: &ColumnFusion, cfg: &RunConfig, out: &mut Vec<RoundStat>) {
        // Empirical message variance v̂ = Σ‖u^p‖²/(P·M) — the quantizer's
        // model channel (the same CLT-Gaussian for every worker). The
        // directive still resolves on the residual variance, the SE state
        // variable the allocators understand; see the PR 2 notes on this
        // deliberate approximation in `config::Partitioning::Column`.
        let pm = (cfg.p * cfg.m) as f64;
        out.clear();
        out.extend((0..fu.b).map(|j| RoundStat {
            sigma_d2_hat: fu.sigma_d2[j],
            msg_var: fu.unorm[j] / pm,
        }));
    }

    fn spec_var(stat: RoundStat) -> f64 {
        stat.msg_var
    }

    fn channel_for_var(
        _prior: &BernoulliGauss,
        _p_workers: usize,
        var: f64,
    ) -> (BgChannel, f64) {
        // CLT-Gaussian message channel at the empirical v̂ (its marginal
        // variance is v̂, so the generic Skip error Var(U^p) is exact).
        BgChannel::column_message_channel(var)
    }

    fn global_step(
        fu: &mut ColumnFusion,
        cfg: &RunConfig,
        se: &StateEvolution,
        _engine: &dyn ComputeEngine,
        sums: &[f32],
        _stats: &[RoundStat],
        _sigma_q2: &[f64],
    ) -> Result<()> {
        // Onsager-corrected residual update with the aggregated η′ mean
        // (equal-size blocks ⇒ the mean of per-block means is the global
        // mean): z_{t+1} = y − Σ û^p + coef·z_t, per signal, in place.
        let m = fu.m;
        for j in 0..fu.b {
            let coef = ((fu.deriv[j] / cfg.p as f64) / se.kappa) as f32;
            let u_sum = &sums[j * m..(j + 1) * m];
            for i in 0..m {
                let k = j * m + i;
                fu.z[k] = fu.y[k] - u_sum[i] + coef * fu.z[k];
            }
        }
        Ok(())
    }

    fn predicted_sigma(se: &StateEvolution, stat: RoundStat, _p_sigma_q2: f64) -> f64 {
        // The estimate x_{t+1} saw the residual at σ̂², so its predicted
        // quality is one plain SE step from there; the new quantization
        // noise shows up in the *next* residual.
        se.step(stat.sigma_d2_hat)
    }

    fn x(fu: &ColumnFusion, sig: usize) -> &[f32] {
        &fu.x[sig * fu.n..(sig + 1) * fu.n]
    }

    fn into_xs(fu: ColumnFusion) -> Vec<Vec<f32>> {
        split_batch_vec(fu.x, fu.b)
    }

    fn worker_init(shard: &ColumnWorkerData, batch: usize) -> ColumnWorker {
        ColumnWorker {
            x: vec![0f32; batch * shard.a.cols()],
            x_next: Vec::new(),
            u_norm2: Vec::new(),
            eta: Vec::new(),
            f_scratch: Vec::new(),
            sigma_eff2: Vec::new(),
            z: Vec::new(),
        }
    }

    fn worker_serve(
        params: &WorkerParams,
        shard: &ColumnWorkerData,
        ws: &mut ColumnWorker,
        engine: &dyn ComputeEngine,
        frame: &[u8],
        pending: &mut Vec<f32>,
        ep: &mut Endpoint,
    ) -> Result<()> {
        let cmd = message::decode_col_step(frame)
            .map_err(|e| Error::Protocol(format!("worker {}: {e}", params.id)))?;
        let b = params.batch;
        let m = shard.a.rows();
        if cmd.sigma_eff2.len() != b || cmd.z.len() != b * m {
            return Err(Error::Protocol(format!(
                "worker {}: ColStep batch {} / z length {} do not match \
                 batch {b} × M {m}",
                params.id,
                cmd.sigma_eff2.len(),
                cmd.z.len()
            )));
        }
        // Copy the broadcast out of the wire view into reused scratch
        // (the old owned decode allocated a fresh B × M vector per round).
        ws.sigma_eff2.resize(b, 0.0);
        cmd.sigma_eff2.copy_to(&mut ws.sigma_eff2);
        ws.z.resize(b * m, 0.0);
        cmd.z.copy_to(&mut ws.z);
        // The pending uplinks (u) land flat in the shared staging
        // buffer; estimates swap through the reused scratch, and
        // the reply encodes straight from the worker state — the
        // old path cloned the `B × (N/P)` shard every round.
        engine.col_lc_step_batch_into(
            shard,
            b,
            &ws.x,
            &ws.z,
            &ws.sigma_eff2,
            &mut ws.x_next,
            pending,
            &mut ws.u_norm2,
            &mut ws.eta,
            &mut ws.f_scratch,
        )?;
        std::mem::swap(&mut ws.x, &mut ws.x_next);
        let (id, u_norm2, eta, x_shard) = (params.id, &ws.u_norm2, &ws.eta, &ws.x);
        ep.send_frame(|buf| {
            message::encode_col_scalars(buf, cmd.t, id, u_norm2, eta, x_shard);
            Ok(())
        })
    }
}
