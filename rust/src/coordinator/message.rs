//! Wire format between workers and the fusion center.
//!
//! Binary little-endian framing (no serde in the offline crate set): one
//! type byte, fixed header fields, then the payload. Every message
//! round-trips exactly (property-tested) and reports its payload bit cost
//! for the paper's communication accounting.
//!
//! Since protocol version 2 every data-bearing message is **natively
//! batched**: a session carries `B ≥ 1` signal instances, and each round
//! trip moves all `B` per-signal vectors in one frame (column-major, one
//! length-prefixed block per message). `B = 1` is simply a batch of one.
//! Peers exchange [`PROTOCOL_VERSION`] in the transport hello so a
//! mismatched peer fails fast instead of decoding garbage.

use std::sync::Arc;

use crate::error::{Error, Result};

/// Version byte exchanged in the worker hello frame. Bump on every wire
/// format change; peers with a different version refuse to talk.
///
/// * v1 — single-signal messages (PR 1–2).
/// * v2 — batched messages (`B` signals per frame) + versioned hello.
/// * v3 — named compression-stack specs (`QuantSpec::Stack` carries the
///   registry name + opaque quantizer parameters instead of hard-wired
///   ECSQ fields).
/// * v4 — session multiplexing: serve-mode links prefix every frame with
///   a session-ID `u32` so one worker fleet carries interleaved rounds
///   from many concurrent sessions (standalone links are unchanged —
///   the prefix exists only on multiplexed daemon links).
/// * v5 — job priority: the serve-mode submit frame carries a trailing
///   priority byte (`0` normal, `1` high) steering the daemon's
///   two-level admission queue. Fleet/worker framing is unchanged; the
///   bump keeps v4 clients (whose submit frame lacks the byte) from
///   being misparsed.
pub const PROTOCOL_VERSION: u8 = 5;

/// How workers should code one signal's uplink vector this iteration
/// (broadcast by fusion; one spec per batch member rides in a single
/// [`Message::QuantCmd`]).
#[derive(Debug, Clone, PartialEq)]
pub enum QuantSpec {
    /// Send raw 32-bit floats.
    Raw,
    /// Send nothing (zero-rate iteration).
    Skip,
    /// Quantize + entropy-code with a registered compression stack.
    /// Workers and fusion assemble the identical stack from the registry
    /// name plus these parameters (and the static prior/P from config) —
    /// no codebook on the wire.
    Stack {
        /// Registry name of the stack (e.g. `"ecsq.huffman"`). Shared
        /// (`Arc`) so per-round spec design clones a pointer, not a
        /// string.
        name: Arc<str>,
        /// The variance estimate the model channel is rebuilt from
        /// (σ̂²_{t,D} in row mode, the message variance v̂ in column
        /// mode).
        model_var: f64,
        /// Deterministic design seed (shared dither streams fork on it).
        seed: u64,
        /// Quantizer parameters, interpreted by the named stack (ECSQ:
        /// `[Δ, k_max]`; top-K: `[K]`).
        params: Vec<f64>,
    },
}

/// The uplinked vector of one signal.
#[derive(Debug, Clone, PartialEq)]
pub enum FPayload {
    /// Raw floats (32 bits/element), or dequantized values under the
    /// analytic codec (entropy-accounted, not entropy-coded).
    Raw(Vec<f32>),
    /// Entropy-coded symbols.
    Coded {
        /// Number of symbols.
        n: u32,
        /// Codec output bytes.
        bytes: Vec<u8>,
    },
    /// Zero-rate iteration (fusion substitutes zeros).
    Skipped,
}

/// All protocol messages. Vector fields hold `B` per-signal blocks
/// (column-major: signal `j`'s block is contiguous).
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Fusion → workers (row mode): run LC for iteration `t` on all `B`
    /// signals.
    StepCmd {
        /// Iteration index.
        t: u32,
        /// Per-signal Onsager coefficients `(1/κ)·mean(η′_{t−1})`.
        coefs: Vec<f32>,
        /// Current estimates, `B × N` column-major (raw broadcast).
        x: Vec<f32>,
    },
    /// Worker → fusion (row mode): per-signal `‖z_t^p‖²` for the σ̂²
    /// estimates.
    ZNorm {
        /// Iteration index.
        t: u32,
        /// Worker id.
        worker: u32,
        /// Per-signal squared norms of the local residuals.
        z_norm2: Vec<f64>,
    },
    /// Fusion → workers: per-signal coding directives for this round's
    /// uplink (one quantizer-design round trip covers the whole batch).
    QuantCmd {
        /// Iteration index.
        t: u32,
        /// One spec per batch member.
        specs: Vec<QuantSpec>,
    },
    /// Worker → fusion: the (coded) uplink vectors, one per signal.
    FVector {
        /// Iteration index.
        t: u32,
        /// Worker id.
        worker: u32,
        /// One payload per batch member.
        payloads: Vec<FPayload>,
    },
    /// Fusion → workers (column mode, C-MP-AMP): the combined residuals
    /// plus per-signal effective noise levels for the local denoisers.
    ColStep {
        /// Iteration index.
        t: u32,
        /// Per-signal denoiser noise levels `σ̂²_j = ‖z_{t,j}‖²/M`.
        sigma_eff2: Vec<f64>,
        /// Combined residuals, `B × M` column-major (raw broadcast).
        z: Vec<f32>,
    },
    /// Worker → fusion (column mode): the scalars the fusion center needs
    /// before designing the quantizers, plus the worker's updated estimate
    /// blocks. The blocks are carried for evaluation/reporting only and
    /// are excluded from the uplink rate accounting (`f_payload_bits`).
    ColScalars {
        /// Iteration index.
        t: u32,
        /// Worker id.
        worker: u32,
        /// Per-signal `‖u^p_j‖²` of the pending residual contributions.
        u_norm2: Vec<f64>,
        /// Per-signal means of `η′` over this worker's block.
        eta_prime_mean: Vec<f64>,
        /// Updated `x^p` blocks, `B × (N/P)` column-major (eval only).
        x_shard: Vec<f32>,
    },
    /// Fusion → workers: shut down.
    Done,
}

pub(crate) const TAG_STEP: u8 = 1;
pub(crate) const TAG_ZNORM: u8 = 2;
pub(crate) const TAG_QUANT: u8 = 3;
pub(crate) const TAG_FVEC: u8 = 4;
pub(crate) const TAG_DONE: u8 = 5;
pub(crate) const TAG_COLSTEP: u8 = 6;
pub(crate) const TAG_COLSCALARS: u8 = 7;

const SPEC_RAW: u8 = 0;
const SPEC_SKIP: u8 = 1;
const SPEC_STACK: u8 = 2;

/// Cap on the `QuantSpec::Stack` name length accepted by `decode` (a
/// spec is tiny in memory, but unbounded strings/param vectors sized by
/// wire-controlled counts would still be a hostile-peer amplification
/// hole). Matches `registry::MAX_STACK_NAME`, which gates registration.
const MAX_WIRE_STACK_NAME: u32 = 64;

/// Cap on `QuantSpec::Stack` wire parameters. Enforced symmetrically: at
/// design time (a custom quantizer whose `params()` overflows this fails
/// with a clear error before anything is broadcast) and at `decode`.
pub const MAX_WIRE_SPEC_PARAMS: u32 = 16;

const PAY_RAW: u8 = 0;
const PAY_CODED: u8 = 1;
const PAY_SKIPPED: u8 = 2;

/// Upper bound on the per-message batch count accepted by `decode`. The
/// float blocks are naturally bounded by the transport's frame cap (4–8
/// wire bytes per element), but `QuantCmd`/`FVector` entries can be a
/// single tag byte on the wire while costing tens of bytes in memory —
/// an unbounded count would let a malicious peer amplify a ~1 GiB frame
/// into a multi-ten-GiB allocation. No real session approaches this.
const MAX_WIRE_BATCH: u32 = 65_536;

impl Message {
    /// Serialize to fresh bytes (a thin wrapper over
    /// [`encode_into`](Message::encode_into); hot paths reuse a frame
    /// buffer instead).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16);
        self.encode_into(&mut out);
        out
    }

    /// Serialize into a reused frame buffer (cleared first). Produces
    /// byte-identical frames to [`encode`](Message::encode); the
    /// encode-once broadcast path encodes each round's command exactly
    /// once and hands the same frame to every endpoint.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match self {
            Message::StepCmd { t, coefs, x } => encode_step_cmd(out, *t, coefs, x),
            Message::ZNorm { t, worker, z_norm2 } => {
                encode_znorm(out, *t, *worker, z_norm2)
            }
            Message::QuantCmd { t, specs } => encode_quant_cmd(out, *t, specs),
            Message::FVector { t, worker, payloads } => {
                begin_fvector(out, *t, *worker, payloads.len() as u32);
                for payload in payloads {
                    match payload {
                        FPayload::Raw(v) => push_raw_payload(out, v),
                        FPayload::Coded { n, bytes } => {
                            push_coded_payload(out, *n, bytes)
                        }
                        FPayload::Skipped => push_skipped_payload(out),
                    }
                }
            }
            Message::ColStep { t, sigma_eff2, z } => {
                encode_col_step(out, *t, sigma_eff2, z)
            }
            Message::ColScalars { t, worker, u_norm2, eta_prime_mean, x_shard } => {
                encode_col_scalars(out, *t, *worker, u_norm2, eta_prime_mean, x_shard)
            }
            Message::Done => {
                out.clear();
                out.push(TAG_DONE);
            }
        }
    }

    /// Deserialize.
    pub fn decode(buf: &[u8]) -> Result<Message> {
        let mut c = Cursor { buf, pos: 0 };
        let tag = c.u8()?;
        let msg = match tag {
            TAG_STEP => Message::StepCmd {
                t: c.u32()?,
                coefs: c.f32_block()?,
                x: c.f32_block()?,
            },
            TAG_ZNORM => Message::ZNorm {
                t: c.u32()?,
                worker: c.u32()?,
                z_norm2: c.f64_block()?,
            },
            TAG_QUANT => {
                let t = c.u32()?;
                let count = c.batch_count()?;
                let mut specs = Vec::with_capacity(count);
                for _ in 0..count {
                    specs.push(match c.u8()? {
                        SPEC_RAW => QuantSpec::Raw,
                        SPEC_SKIP => QuantSpec::Skip,
                        SPEC_STACK => {
                            let name_len = c.u32()?;
                            if name_len == 0 || name_len > MAX_WIRE_STACK_NAME {
                                return Err(Error::Protocol(format!(
                                    "stack name length {name_len} outside \
                                     1..={MAX_WIRE_STACK_NAME}"
                                )));
                            }
                            let name: Arc<str> = std::str::from_utf8(
                                c.bytes(name_len as usize)?,
                            )
                            .map_err(|_| {
                                Error::Protocol("stack name is not UTF-8".into())
                            })?
                            .into();
                            let model_var = c.f64()?;
                            let seed = c.u64()?;
                            let n_params = c.u32()?;
                            if n_params > MAX_WIRE_SPEC_PARAMS {
                                return Err(Error::Protocol(format!(
                                    "spec param count {n_params} exceeds \
                                     {MAX_WIRE_SPEC_PARAMS}"
                                )));
                            }
                            let mut params = Vec::with_capacity(n_params as usize);
                            for _ in 0..n_params {
                                params.push(c.f64()?);
                            }
                            QuantSpec::Stack { name, model_var, seed, params }
                        }
                        other => {
                            return Err(Error::Protocol(format!(
                                "bad quant spec tag {other}"
                            )))
                        }
                    });
                }
                Message::QuantCmd { t, specs }
            }
            TAG_FVEC => {
                let t = c.u32()?;
                let worker = c.u32()?;
                let count = c.batch_count()?;
                let mut payloads = Vec::with_capacity(count);
                for _ in 0..count {
                    payloads.push(match c.u8()? {
                        PAY_RAW => FPayload::Raw(c.f32_block()?),
                        PAY_CODED => {
                            let n = c.u32()?;
                            let len = c.u32()? as usize;
                            FPayload::Coded { n, bytes: c.bytes(len)?.to_vec() }
                        }
                        PAY_SKIPPED => FPayload::Skipped,
                        other => {
                            return Err(Error::Protocol(format!(
                                "bad payload tag {other}"
                            )))
                        }
                    });
                }
                Message::FVector { t, worker, payloads }
            }
            TAG_COLSTEP => Message::ColStep {
                t: c.u32()?,
                sigma_eff2: c.f64_block()?,
                z: c.f32_block()?,
            },
            TAG_COLSCALARS => Message::ColScalars {
                t: c.u32()?,
                worker: c.u32()?,
                u_norm2: c.f64_block()?,
                eta_prime_mean: c.f64_block()?,
                x_shard: c.f32_block()?,
            },
            TAG_DONE => Message::Done,
            other => return Err(Error::Protocol(format!("unknown message tag {other}"))),
        };
        if c.pos != buf.len() {
            return Err(Error::Protocol(format!(
                "trailing bytes: consumed {} of {}",
                c.pos,
                buf.len()
            )));
        }
        Ok(msg)
    }

    /// Payload bits of the uplinked vector content, summed over the batch
    /// (the paper's uplink metric); 0 for non-FVector messages.
    pub fn f_payload_bits(&self) -> f64 {
        match self {
            Message::FVector { payloads, .. } => payloads
                .iter()
                .map(|payload| match payload {
                    FPayload::Raw(v) => 32.0 * v.len() as f64,
                    FPayload::Coded { bytes, .. } => 8.0 * bytes.len() as f64,
                    FPayload::Skipped => 0.0,
                })
                .sum(),
            _ => 0.0,
        }
    }
}

// ---------------------------------------------------------------------
// Frame builders — the encode-once hot path. Each `encode_*` function
// writes one complete frame into a reused buffer (cleared first) and is
// byte-identical to `Message::encode` of the corresponding variant, so
// senders never materialize an owned `Message` (no cloned broadcast
// state, no staged reply vectors). `begin_fvector` + `push_*_payload`
// build the uplink frame payload by payload (appending).
// ---------------------------------------------------------------------

/// Encode a row-mode `StepCmd` broadcast (clears `out`).
pub fn encode_step_cmd(out: &mut Vec<u8>, t: u32, coefs: &[f32], x: &[f32]) {
    out.clear();
    out.push(TAG_STEP);
    push_u32(out, t);
    push_f32_block(out, coefs);
    push_f32_block(out, x);
}

/// Encode a column-mode `ColStep` broadcast (clears `out`).
pub fn encode_col_step(out: &mut Vec<u8>, t: u32, sigma_eff2: &[f64], z: &[f32]) {
    out.clear();
    out.push(TAG_COLSTEP);
    push_u32(out, t);
    push_f64_block(out, sigma_eff2);
    push_f32_block(out, z);
}

/// Encode a `QuantCmd` broadcast (clears `out`).
pub fn encode_quant_cmd(out: &mut Vec<u8>, t: u32, specs: &[QuantSpec]) {
    out.clear();
    out.push(TAG_QUANT);
    push_u32(out, t);
    push_u32(out, specs.len() as u32);
    for spec in specs {
        match spec {
            QuantSpec::Raw => out.push(SPEC_RAW),
            QuantSpec::Skip => out.push(SPEC_SKIP),
            QuantSpec::Stack { name, model_var, seed, params } => {
                out.push(SPEC_STACK);
                push_u32(out, name.len() as u32);
                out.extend_from_slice(name.as_bytes());
                push_f64(out, *model_var);
                push_u64(out, *seed);
                push_u32(out, params.len() as u32);
                for p in params {
                    push_f64(out, *p);
                }
            }
        }
    }
}

/// Encode a row-mode `ZNorm` reply (clears `out`).
pub fn encode_znorm(out: &mut Vec<u8>, t: u32, worker: u32, z_norm2: &[f64]) {
    out.clear();
    out.push(TAG_ZNORM);
    push_u32(out, t);
    push_u32(out, worker);
    push_f64_block(out, z_norm2);
}

/// Encode a column-mode `ColScalars` reply (clears `out`) — straight from
/// the worker's round state, no per-round `x_shard` clone.
pub fn encode_col_scalars(
    out: &mut Vec<u8>,
    t: u32,
    worker: u32,
    u_norm2: &[f64],
    eta_prime_mean: &[f64],
    x_shard: &[f32],
) {
    out.clear();
    out.push(TAG_COLSCALARS);
    push_u32(out, t);
    push_u32(out, worker);
    push_f64_block(out, u_norm2);
    push_f64_block(out, eta_prime_mean);
    push_f32_block(out, x_shard);
}

/// Start an `FVector` uplink frame (clears `out`); follow with exactly
/// `payload_count` `push_*_payload` calls.
pub fn begin_fvector(out: &mut Vec<u8>, t: u32, worker: u32, payload_count: u32) {
    out.clear();
    out.push(TAG_FVEC);
    push_u32(out, t);
    push_u32(out, worker);
    push_u32(out, payload_count);
}

/// Append one raw-floats payload to an `FVector` frame.
pub fn push_raw_payload(out: &mut Vec<u8>, v: &[f32]) {
    out.push(PAY_RAW);
    push_f32_block(out, v);
}

/// Append one entropy-coded payload to an `FVector` frame.
pub fn push_coded_payload(out: &mut Vec<u8>, n: u32, bytes: &[u8]) {
    out.push(PAY_CODED);
    push_u32(out, n);
    push_u32(out, bytes.len() as u32);
    out.extend_from_slice(bytes);
}

/// Append one zero-rate payload to an `FVector` frame.
pub fn push_skipped_payload(out: &mut Vec<u8>) {
    out.push(PAY_SKIPPED);
}

// ---------------------------------------------------------------------
// Borrowed decoders — the zero-copy fusion path. The fusion center reads
// every worker reply straight out of the endpoint's reused receive
// buffer: scalar blocks come back as little-endian views, payload bytes
// as sub-slices. Validation (caps, lengths, trailing bytes) matches
// `Message::decode` exactly.
// ---------------------------------------------------------------------

/// Borrowed little-endian `f32` block (a length-prefixed block's body).
#[derive(Debug, Clone, Copy)]
pub struct LeF32s<'a> {
    bytes: &'a [u8],
}

impl<'a> LeF32s<'a> {
    /// Number of encoded floats.
    pub fn len(&self) -> usize {
        self.bytes.len() / 4
    }

    /// Whether the block is empty.
    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    /// Iterate the decoded values.
    pub fn iter(&self) -> impl Iterator<Item = f32> + 'a {
        self.bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
    }

    /// Sub-view of `count` floats starting at element `start`.
    pub fn slice(&self, start: usize, count: usize) -> LeF32s<'a> {
        LeF32s { bytes: &self.bytes[4 * start..4 * (start + count)] }
    }

    /// Decode into `out` (must have length [`len`](LeF32s::len)).
    pub fn copy_to(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.len());
        for (o, v) in out.iter_mut().zip(self.iter()) {
            *o = v;
        }
    }

    /// Accumulate into `out` (`out[i] += v[i]`) — the fusion sum, fused
    /// with the decode so no intermediate vector exists. Bit-identical to
    /// decoding then `axpy(1.0, v, out)`.
    pub fn add_to(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.len());
        for (o, v) in out.iter_mut().zip(self.iter()) {
            *o += v;
        }
    }
}

/// Borrowed little-endian `f64` block.
#[derive(Debug, Clone, Copy)]
pub struct LeF64s<'a> {
    bytes: &'a [u8],
}

impl<'a> LeF64s<'a> {
    /// Number of encoded doubles.
    pub fn len(&self) -> usize {
        self.bytes.len() / 8
    }

    /// Whether the block is empty.
    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    /// Iterate the decoded values.
    pub fn iter(&self) -> impl Iterator<Item = f64> + 'a {
        self.bytes.chunks_exact(8).map(|c| {
            let mut a = [0u8; 8];
            a.copy_from_slice(c);
            f64::from_le_bytes(a)
        })
    }

    /// Decode into `out` (must have length [`len`](LeF64s::len)).
    pub fn copy_to(&self, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.len());
        for (o, v) in out.iter_mut().zip(self.iter()) {
            *o = v;
        }
    }
}

impl<'a> Cursor<'a> {
    /// Borrow a length-prefixed `f32` block without decoding.
    fn f32_view(&mut self) -> Result<LeF32s<'a>> {
        let n = self.u32()? as usize;
        Ok(LeF32s { bytes: self.bytes(4 * n)? })
    }

    /// Borrow a length-prefixed `f64` block without decoding.
    fn f64_view(&mut self) -> Result<LeF64s<'a>> {
        let n = self.u32()? as usize;
        Ok(LeF64s { bytes: self.bytes(8 * n)? })
    }

    /// Error unless the whole buffer was consumed (mirrors `decode`).
    fn finish(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(Error::Protocol(format!(
                "trailing bytes: consumed {} of {}",
                self.pos,
                self.buf.len()
            )));
        }
        Ok(())
    }
}

/// Borrowed view of a `ZNorm` reply.
#[derive(Debug, Clone, Copy)]
pub struct ZNormRef<'a> {
    /// Iteration index.
    pub t: u32,
    /// Worker id.
    pub worker: u32,
    /// Per-signal squared norms.
    pub z_norm2: LeF64s<'a>,
}

/// Parse a `ZNorm` frame without allocating.
pub fn decode_znorm(buf: &[u8]) -> Result<ZNormRef<'_>> {
    let mut c = Cursor { buf, pos: 0 };
    let tag = c.u8()?;
    if tag != TAG_ZNORM {
        return Err(Error::Protocol(format!("expected ZNorm frame, got tag {tag}")));
    }
    let r = ZNormRef { t: c.u32()?, worker: c.u32()?, z_norm2: c.f64_view()? };
    c.finish()?;
    Ok(r)
}

/// Borrowed view of a `ColScalars` reply.
#[derive(Debug, Clone, Copy)]
pub struct ColScalarsRef<'a> {
    /// Iteration index.
    pub t: u32,
    /// Worker id.
    pub worker: u32,
    /// Per-signal `‖u^p‖²`.
    pub u_norm2: LeF64s<'a>,
    /// Per-signal means of `η′`.
    pub eta_prime_mean: LeF64s<'a>,
    /// Updated estimate blocks, `B × (N/P)` column-major.
    pub x_shard: LeF32s<'a>,
}

/// Parse a `ColScalars` frame without allocating.
pub fn decode_col_scalars(buf: &[u8]) -> Result<ColScalarsRef<'_>> {
    let mut c = Cursor { buf, pos: 0 };
    let tag = c.u8()?;
    if tag != TAG_COLSCALARS {
        return Err(Error::Protocol(format!(
            "expected ColScalars frame, got tag {tag}"
        )));
    }
    let r = ColScalarsRef {
        t: c.u32()?,
        worker: c.u32()?,
        u_norm2: c.f64_view()?,
        eta_prime_mean: c.f64_view()?,
        x_shard: c.f32_view()?,
    };
    c.finish()?;
    Ok(r)
}

/// Borrowed view of a row-mode `StepCmd` broadcast.
#[derive(Debug, Clone, Copy)]
pub struct StepCmdRef<'a> {
    /// Iteration index.
    pub t: u32,
    /// Per-signal Onsager coefficients.
    pub coefs: LeF32s<'a>,
    /// Current estimates, `B × N` column-major.
    pub x: LeF32s<'a>,
}

/// Parse a `StepCmd` frame without allocating — the worker-side
/// zero-copy path: `B × N` broadcast floats stay in the endpoint's
/// receive buffer and are copied straight into reused scratch.
pub fn decode_step_cmd(buf: &[u8]) -> Result<StepCmdRef<'_>> {
    let mut c = Cursor { buf, pos: 0 };
    let tag = c.u8()?;
    if tag != TAG_STEP {
        return Err(Error::Protocol(format!("expected StepCmd frame, got tag {tag}")));
    }
    let r = StepCmdRef { t: c.u32()?, coefs: c.f32_view()?, x: c.f32_view()? };
    c.finish()?;
    Ok(r)
}

/// Borrowed view of a column-mode `ColStep` broadcast.
#[derive(Debug, Clone, Copy)]
pub struct ColStepRef<'a> {
    /// Iteration index.
    pub t: u32,
    /// Per-signal denoiser noise levels.
    pub sigma_eff2: LeF64s<'a>,
    /// Combined residuals, `B × M` column-major.
    pub z: LeF32s<'a>,
}

/// Parse a `ColStep` frame without allocating (the column-mode analogue
/// of [`decode_step_cmd`]).
pub fn decode_col_step(buf: &[u8]) -> Result<ColStepRef<'_>> {
    let mut c = Cursor { buf, pos: 0 };
    let tag = c.u8()?;
    if tag != TAG_COLSTEP {
        return Err(Error::Protocol(format!("expected ColStep frame, got tag {tag}")));
    }
    let r = ColStepRef { t: c.u32()?, sigma_eff2: c.f64_view()?, z: c.f32_view()? };
    c.finish()?;
    Ok(r)
}

/// Borrowed view of one `FVector` payload.
#[derive(Debug, Clone, Copy)]
pub enum FPayloadRef<'a> {
    /// Raw floats (also carries dequantized analytic-codec values).
    Raw(LeF32s<'a>),
    /// Entropy-coded symbols.
    Coded {
        /// Number of symbols.
        n: u32,
        /// Codec output bytes.
        bytes: &'a [u8],
    },
    /// Zero-rate iteration.
    Skipped,
}

impl FPayloadRef<'_> {
    /// Wire payload bits of this payload (the paper's uplink metric;
    /// matches [`Message::f_payload_bits`] per payload).
    pub fn wire_bits(&self) -> f64 {
        match self {
            FPayloadRef::Raw(v) => 32.0 * v.len() as f64,
            FPayloadRef::Coded { bytes, .. } => 8.0 * bytes.len() as f64,
            FPayloadRef::Skipped => 0.0,
        }
    }
}

/// Parse an `FVector` frame without allocating: `f(sig, payload)` runs
/// once per payload in signal order. Returns `(t, worker, payload_count)`
/// after validating the batch cap and trailing bytes exactly like
/// [`Message::decode`].
pub fn decode_fvector<'a>(
    buf: &'a [u8],
    mut f: impl FnMut(usize, FPayloadRef<'a>) -> Result<()>,
) -> Result<(u32, u32, usize)> {
    let mut c = Cursor { buf, pos: 0 };
    let tag = c.u8()?;
    if tag != TAG_FVEC {
        return Err(Error::Protocol(format!("expected FVector frame, got tag {tag}")));
    }
    let t = c.u32()?;
    let worker = c.u32()?;
    let count = c.batch_count()?;
    for sig in 0..count {
        let payload = match c.u8()? {
            PAY_RAW => FPayloadRef::Raw(c.f32_view()?),
            PAY_CODED => {
                let n = c.u32()?;
                let len = c.u32()? as usize;
                FPayloadRef::Coded { n, bytes: c.bytes(len)? }
            }
            PAY_SKIPPED => FPayloadRef::Skipped,
            other => {
                return Err(Error::Protocol(format!("bad payload tag {other}")))
            }
        };
        f(sig, payload)?;
    }
    c.finish()?;
    Ok((t, worker, count))
}

pub(crate) fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn push_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Length-prefixed little-endian `f32` block. One resize + bulk fill —
/// broadcast frames carry `B × N` floats re-encoded once per endpoint per
/// round, so per-element `Vec` bookkeeping would sit on the hot wire path.
fn push_f32_block(out: &mut Vec<u8>, vs: &[f32]) {
    push_u32(out, vs.len() as u32);
    let base = out.len();
    out.resize(base + 4 * vs.len(), 0);
    for (chunk, v) in out[base..].chunks_exact_mut(4).zip(vs) {
        chunk.copy_from_slice(&v.to_le_bytes());
    }
}

/// Length-prefixed little-endian `f64` block (bulk-filled like
/// [`push_f32_block`]).
fn push_f64_block(out: &mut Vec<u8>, vs: &[f64]) {
    push_u32(out, vs.len() as u32);
    let base = out.len();
    out.resize(base + 8 * vs.len(), 0);
    for (chunk, v) in out[base..].chunks_exact_mut(8).zip(vs) {
        chunk.copy_from_slice(&v.to_le_bytes());
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::Protocol(format!(
                "message truncated: need {n} bytes at {}",
                self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// A batch count, validated against [`MAX_WIRE_BATCH`] before any
    /// allocation sized by it.
    fn batch_count(&mut self) -> Result<usize> {
        let count = self.u32()?;
        if count > MAX_WIRE_BATCH {
            return Err(Error::Protocol(format!(
                "batch count {count} exceeds the wire limit {MAX_WIRE_BATCH}"
            )));
        }
        Ok(count as usize)
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.bytes(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    fn f64(&mut self) -> Result<f64> {
        let b = self.bytes(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(f64::from_le_bytes(a))
    }

    fn f32_block(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.bytes(4 * n)?;
        let mut out = vec![0f32; n];
        for (i, o) in out.iter_mut().enumerate() {
            *o = f32::from_le_bytes([
                raw[4 * i],
                raw[4 * i + 1],
                raw[4 * i + 2],
                raw[4 * i + 3],
            ]);
        }
        Ok(out)
    }

    fn f64_block(&mut self) -> Result<Vec<f64>> {
        let n = self.u32()? as usize;
        let raw = self.bytes(8 * n)?;
        let mut out = vec![0f64; n];
        for (i, o) in out.iter_mut().enumerate() {
            let mut a = [0u8; 8];
            a.copy_from_slice(&raw[8 * i..8 * i + 8]);
            *o = f64::from_le_bytes(a);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{prop_assert, Prop};

    #[test]
    fn roundtrip_all_variants() {
        let msgs = vec![
            Message::StepCmd { t: 3, coefs: vec![0.25, -0.5], x: vec![1.0, -2.5, 3.25, 0.0, 1.5, -9.0] },
            Message::ZNorm { t: 1, worker: 7, z_norm2: vec![123.456, 0.25] },
            Message::QuantCmd { t: 2, specs: vec![QuantSpec::Raw, QuantSpec::Skip] },
            Message::QuantCmd {
                t: 9,
                specs: vec![
                    QuantSpec::Stack {
                        name: "ecsq.range".into(),
                        model_var: 0.7,
                        seed: 0xDEAD_BEEF_u64,
                        params: vec![0.031, 200.0],
                    },
                    QuantSpec::Raw,
                    QuantSpec::Stack {
                        name: "topk.raw".into(),
                        model_var: 0.2,
                        seed: 0,
                        params: vec![64.0],
                    },
                ],
            },
            Message::FVector {
                t: 4,
                worker: 0,
                payloads: vec![FPayload::Raw(vec![0.5; 17]), FPayload::Skipped],
            },
            Message::FVector {
                t: 4,
                worker: 2,
                payloads: vec![
                    FPayload::Coded { n: 100, bytes: vec![1, 2, 3, 255] },
                    FPayload::Coded { n: 7, bytes: vec![9] },
                ],
            },
            Message::ColStep {
                t: 6,
                sigma_eff2: vec![0.042, 0.011],
                z: vec![0.5, -1.25, 2.0, 0.25, 0.0, -3.0],
            },
            Message::ColScalars {
                t: 6,
                worker: 4,
                u_norm2: vec![9.75, 1.5],
                eta_prime_mean: vec![0.125, 0.25],
                x_shard: vec![1.0, 0.0, -0.5, 2.0],
            },
            Message::Done,
        ];
        for m in msgs {
            let enc = m.encode();
            let dec = Message::decode(&enc).unwrap();
            assert_eq!(m, dec);
        }
    }

    #[test]
    fn roundtrip_random_batched_stepcmds() {
        Prop::new("StepCmd roundtrip", 50).check(|g| {
            let b = g.usize_in(1, 5);
            let n = g.usize_in(0, 200);
            let x = g.gaussian_vec(b * n, 2.0);
            let coefs: Vec<f32> =
                (0..b).map(|_| g.f64_in(-1.0, 1.0) as f32).collect();
            let m = Message::StepCmd { t: g.u64() as u32, coefs, x };
            let dec = Message::decode(&m.encode()).map_err(|e| e.to_string())?;
            prop_assert(dec == m, "mismatch")
        });
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Message::decode(&[]).is_err());
        assert!(Message::decode(&[99]).is_err());
        assert!(Message::decode(&[TAG_ZNORM, 1, 2]).is_err()); // truncated
        // Trailing bytes rejected.
        let mut enc = Message::Done.encode();
        enc.push(0);
        assert!(Message::decode(&enc).is_err());
        // Truncated batch payloads rejected.
        let enc = Message::QuantCmd { t: 0, specs: vec![QuantSpec::Raw; 3] }.encode();
        assert!(Message::decode(&enc[..enc.len() - 1]).is_err());
    }

    #[test]
    fn decode_rejects_absurd_batch_counts() {
        // A hostile count must be rejected before any count-sized
        // allocation: QuantCmd claiming u32::MAX specs...
        let mut enc = vec![TAG_QUANT];
        enc.extend_from_slice(&7u32.to_le_bytes());
        enc.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = Message::decode(&enc).unwrap_err().to_string();
        assert!(err.contains("batch count"), "{err}");
        // ...and an FVector claiming one tag byte per fake payload.
        let mut enc = vec![TAG_FVEC];
        enc.extend_from_slice(&0u32.to_le_bytes());
        enc.extend_from_slice(&0u32.to_le_bytes());
        enc.extend_from_slice(&(MAX_WIRE_BATCH + 1).to_le_bytes());
        enc.extend_from_slice(&[PAY_SKIPPED; 64]);
        let err = Message::decode(&enc).unwrap_err().to_string();
        assert!(err.contains("batch count"), "{err}");
        // The limit itself is generous: a real batch passes untouched.
        let big = Message::QuantCmd { t: 1, specs: vec![QuantSpec::Skip; 512] };
        assert_eq!(Message::decode(&big.encode()).unwrap(), big);
    }

    #[test]
    fn decode_rejects_hostile_stack_specs() {
        // Oversized name length must be rejected before allocation.
        let mut enc = vec![TAG_QUANT];
        enc.extend_from_slice(&0u32.to_le_bytes()); // t
        enc.extend_from_slice(&1u32.to_le_bytes()); // one spec
        enc.push(SPEC_STACK);
        enc.extend_from_slice(&(MAX_WIRE_STACK_NAME + 1).to_le_bytes());
        let err = Message::decode(&enc).unwrap_err().to_string();
        assert!(err.contains("stack name length"), "{err}");
        // Oversized param counts likewise.
        let good_name = b"ecsq.range";
        let mut enc = vec![TAG_QUANT];
        enc.extend_from_slice(&0u32.to_le_bytes());
        enc.extend_from_slice(&1u32.to_le_bytes());
        enc.push(SPEC_STACK);
        enc.extend_from_slice(&(good_name.len() as u32).to_le_bytes());
        enc.extend_from_slice(good_name);
        enc.extend_from_slice(&0.5f64.to_le_bytes());
        enc.extend_from_slice(&7u64.to_le_bytes());
        enc.extend_from_slice(&(MAX_WIRE_SPEC_PARAMS + 1).to_le_bytes());
        let err = Message::decode(&enc).unwrap_err().to_string();
        assert!(err.contains("param count"), "{err}");
        // Non-UTF-8 names fail loudly.
        let mut enc = vec![TAG_QUANT];
        enc.extend_from_slice(&0u32.to_le_bytes());
        enc.extend_from_slice(&1u32.to_le_bytes());
        enc.push(SPEC_STACK);
        enc.extend_from_slice(&2u32.to_le_bytes());
        enc.extend_from_slice(&[0xFF, 0xFE]);
        enc.extend_from_slice(&0.5f64.to_le_bytes());
        enc.extend_from_slice(&7u64.to_le_bytes());
        enc.extend_from_slice(&0u32.to_le_bytes());
        let err = Message::decode(&enc).unwrap_err().to_string();
        assert!(err.contains("UTF-8"), "{err}");
    }

    #[test]
    fn encode_into_reuses_buffer_and_matches_encode() {
        // One buffer across many messages: every frame is byte-identical
        // to the allocating `encode`, regardless of what the buffer held.
        let msgs = vec![
            Message::StepCmd { t: 3, coefs: vec![0.25], x: vec![1.0; 9] },
            Message::Done,
            Message::ColStep { t: 1, sigma_eff2: vec![0.5, 0.25], z: vec![2.0; 4] },
            Message::ZNorm { t: 2, worker: 1, z_norm2: vec![7.0] },
            Message::QuantCmd {
                t: 4,
                specs: vec![
                    QuantSpec::Stack {
                        name: "ecsq.range".into(),
                        model_var: 0.3,
                        seed: 9,
                        params: vec![0.1, 64.0],
                    },
                    QuantSpec::Skip,
                ],
            },
            Message::FVector {
                t: 5,
                worker: 2,
                payloads: vec![
                    FPayload::Raw(vec![1.5; 3]),
                    FPayload::Coded { n: 4, bytes: vec![7, 8] },
                    FPayload::Skipped,
                ],
            },
        ];
        let mut buf = vec![0xAAu8; 129]; // dirty, oversized
        for m in msgs {
            m.encode_into(&mut buf);
            assert_eq!(buf, m.encode(), "{m:?}");
        }
    }

    #[test]
    fn frame_builders_match_message_encode() {
        // The field-level builders (the encode-once path that never
        // materializes a Message) produce identical frames.
        let coefs = vec![0.25f32, -0.5];
        let x = vec![1.0f32, -2.0, 3.0, 4.0];
        let mut buf = Vec::new();
        encode_step_cmd(&mut buf, 7, &coefs, &x);
        assert_eq!(buf, Message::StepCmd { t: 7, coefs: coefs.clone(), x: x.clone() }.encode());
        let s2 = vec![0.1f64, 0.2];
        encode_col_step(&mut buf, 3, &s2, &x);
        assert_eq!(
            buf,
            Message::ColStep { t: 3, sigma_eff2: s2.clone(), z: x.clone() }.encode()
        );
        let zn = vec![1.5f64, 2.5];
        encode_znorm(&mut buf, 2, 4, &zn);
        assert_eq!(buf, Message::ZNorm { t: 2, worker: 4, z_norm2: zn.clone() }.encode());
        let eta = vec![0.5f64];
        encode_col_scalars(&mut buf, 1, 0, &zn, &eta, &x);
        assert_eq!(
            buf,
            Message::ColScalars {
                t: 1,
                worker: 0,
                u_norm2: zn.clone(),
                eta_prime_mean: eta,
                x_shard: x.clone(),
            }
            .encode()
        );
        let specs = vec![
            QuantSpec::Raw,
            QuantSpec::Stack {
                name: "topk.raw".into(),
                model_var: 0.2,
                seed: 11,
                params: vec![64.0],
            },
        ];
        encode_quant_cmd(&mut buf, 9, &specs);
        assert_eq!(buf, Message::QuantCmd { t: 9, specs }.encode());
        begin_fvector(&mut buf, 6, 3, 3);
        push_raw_payload(&mut buf, &x);
        push_coded_payload(&mut buf, 10, &[1, 2, 3]);
        push_skipped_payload(&mut buf);
        assert_eq!(
            buf,
            Message::FVector {
                t: 6,
                worker: 3,
                payloads: vec![
                    FPayload::Raw(x),
                    FPayload::Coded { n: 10, bytes: vec![1, 2, 3] },
                    FPayload::Skipped,
                ],
            }
            .encode()
        );
    }

    #[test]
    fn borrowed_decoders_match_owned_decode() {
        let zn = Message::ZNorm { t: 8, worker: 2, z_norm2: vec![1.5, 0.25, 9.0] };
        let enc = zn.encode();
        let view = decode_znorm(&enc).unwrap();
        assert_eq!((view.t, view.worker), (8, 2));
        assert_eq!(view.z_norm2.iter().collect::<Vec<_>>(), vec![1.5, 0.25, 9.0]);
        // Wrong tag and trailing bytes rejected.
        assert!(decode_znorm(&Message::Done.encode()).is_err());
        let mut bad = enc.clone();
        bad.push(0);
        assert!(decode_znorm(&bad).is_err());

        let cs = Message::ColScalars {
            t: 4,
            worker: 1,
            u_norm2: vec![2.0, 3.0],
            eta_prime_mean: vec![0.5, 0.75],
            x_shard: vec![1.0, -1.0, 2.0, -2.0],
        };
        let enc = cs.encode();
        let view = decode_col_scalars(&enc).unwrap();
        assert_eq!((view.t, view.worker), (4, 1));
        assert_eq!(view.u_norm2.iter().collect::<Vec<_>>(), vec![2.0, 3.0]);
        assert_eq!(view.eta_prime_mean.iter().collect::<Vec<_>>(), vec![0.5, 0.75]);
        let mut got = vec![0f32; 4];
        view.x_shard.copy_to(&mut got);
        assert_eq!(got, vec![1.0, -1.0, 2.0, -2.0]);

        let fv = Message::FVector {
            t: 6,
            worker: 0,
            payloads: vec![
                FPayload::Raw(vec![1.0, 2.0]),
                FPayload::Coded { n: 5, bytes: vec![9, 8, 7] },
                FPayload::Skipped,
            ],
        };
        let enc = fv.encode();
        let mut seen = Vec::new();
        let mut bits = 0.0;
        let (t, worker, count) = decode_fvector(&enc, |sig, p| {
            bits += p.wire_bits();
            match p {
                FPayloadRef::Raw(v) => {
                    let mut sum = vec![10.0f32; v.len()];
                    v.add_to(&mut sum);
                    seen.push((sig, format!("raw{:?}", sum)));
                }
                FPayloadRef::Coded { n, bytes } => {
                    seen.push((sig, format!("coded{n}/{bytes:?}")));
                }
                FPayloadRef::Skipped => seen.push((sig, "skip".into())),
            }
            Ok(())
        })
        .unwrap();
        assert_eq!((t, worker, count), (6, 0, 3));
        assert_eq!(bits, fv.f_payload_bits());
        assert_eq!(
            seen,
            vec![
                (0, "raw[11.0, 12.0]".to_string()),
                (1, "coded5/[9, 8, 7]".to_string()),
                (2, "skip".to_string()),
            ]
        );
        // Truncated payloads rejected, same as the owned decoder.
        assert!(decode_fvector(&enc[..enc.len() - 1], |_, _| Ok(())).is_err());
    }

    #[test]
    fn borrowed_broadcast_decoders_match_owned_decode() {
        // Row broadcast: the worker-side zero-copy view must see the
        // exact floats the owned decoder produces.
        let sc = Message::StepCmd {
            t: 5,
            coefs: vec![0.25, -0.5],
            x: vec![1.0, -2.0, 3.5, 0.0, 9.0, -1.25],
        };
        let enc = sc.encode();
        let view = decode_step_cmd(&enc).unwrap();
        assert_eq!(view.t, 5);
        let mut coefs = vec![0f32; view.coefs.len()];
        view.coefs.copy_to(&mut coefs);
        assert_eq!(coefs, vec![0.25, -0.5]);
        let mut x = vec![0f32; view.x.len()];
        view.x.copy_to(&mut x);
        assert_eq!(x, vec![1.0, -2.0, 3.5, 0.0, 9.0, -1.25]);
        // Wrong tag and trailing bytes rejected, same as `decode`.
        assert!(decode_step_cmd(&Message::Done.encode()).is_err());
        let mut bad = enc.clone();
        bad.push(0);
        assert!(decode_step_cmd(&bad).is_err());
        assert!(decode_step_cmd(&enc[..enc.len() - 1]).is_err());

        // Column broadcast likewise, including the f64 block view.
        let cs = Message::ColStep {
            t: 7,
            sigma_eff2: vec![0.042, 0.011],
            z: vec![0.5, -1.25, 2.0, 0.25],
        };
        let enc = cs.encode();
        let view = decode_col_step(&enc).unwrap();
        assert_eq!(view.t, 7);
        let mut s2 = vec![0f64; view.sigma_eff2.len()];
        view.sigma_eff2.copy_to(&mut s2);
        assert_eq!(s2, vec![0.042, 0.011]);
        let mut z = vec![0f32; view.z.len()];
        view.z.copy_to(&mut z);
        assert_eq!(z, vec![0.5, -1.25, 2.0, 0.25]);
        assert!(decode_col_step(&Message::Done.encode()).is_err());
        let mut bad = enc.clone();
        bad.push(0);
        assert!(decode_col_step(&bad).is_err());
    }

    #[test]
    fn payload_bits_sum_over_batch() {
        let raw = Message::FVector {
            t: 0,
            worker: 0,
            payloads: vec![FPayload::Raw(vec![0.0; 10]), FPayload::Raw(vec![0.0; 10])],
        };
        assert_eq!(raw.f_payload_bits(), 640.0);
        let mixed = Message::FVector {
            t: 0,
            worker: 0,
            payloads: vec![
                FPayload::Coded { n: 10, bytes: vec![0; 3] },
                FPayload::Skipped,
            ],
        };
        assert_eq!(mixed.f_payload_bits(), 24.0);
        assert_eq!(Message::Done.f_payload_bits(), 0.0);
        // Column-mode eval shards ride outside the rate accounting.
        let scalars = Message::ColScalars {
            t: 0,
            worker: 0,
            u_norm2: vec![1.0],
            eta_prime_mean: vec![0.5],
            x_shard: vec![0.0; 100],
        };
        assert_eq!(scalars.f_payload_bits(), 0.0);
    }
}
