//! Wire format between workers and the fusion center.
//!
//! Binary little-endian framing (no serde in the offline crate set):
//! one type byte, fixed header fields, then the payload. Every message
//! round-trips exactly (property-tested) and reports its payload bit cost
//! for the paper's communication accounting.

use byteorder::{ByteOrder, LittleEndian as LE};

use crate::error::{Error, Result};

/// How workers should code `f_t^p` this iteration (broadcast by fusion).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum QuantSpec {
    /// Send raw 32-bit floats.
    Raw,
    /// Send nothing (zero-rate iteration).
    Skip,
    /// Entropy-coded scalar quantization. Workers and fusion rebuild the
    /// identical quantizer + model pmf from these parameters (plus the
    /// static prior/P from config) — no codebook on the wire.
    Ecsq {
        /// Bin width Δ_Q.
        delta: f64,
        /// Largest bin index (2·k_max+1 bins).
        k_max: u32,
        /// The σ̂²_{t,D} estimate the model pmf is built from.
        sigma_d2_hat: f64,
    },
}

/// The uplinked local estimate.
#[derive(Debug, Clone, PartialEq)]
pub enum FPayload {
    /// Raw floats (32 bits/element), or dequantized values under the
    /// analytic codec (entropy-accounted, not entropy-coded).
    Raw(Vec<f32>),
    /// Entropy-coded symbols.
    Coded {
        /// Number of symbols.
        n: u32,
        /// Codec output bytes.
        bytes: Vec<u8>,
    },
    /// Zero-rate iteration (fusion substitutes zeros).
    Skipped,
}

/// All protocol messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Fusion → workers: run LC for iteration `t`.
    StepCmd {
        /// Iteration index.
        t: u32,
        /// Onsager coefficient `(1/κ)·mean(η′_{t−1})`.
        coef: f32,
        /// Current estimate `x_t` (raw broadcast, length N).
        x: Vec<f32>,
    },
    /// Worker → fusion: `‖z_t^p‖²` for the σ̂² estimate.
    ZNorm {
        /// Iteration index.
        t: u32,
        /// Worker id.
        worker: u32,
        /// Squared norm of the local residual.
        z_norm2: f64,
    },
    /// Fusion → workers: coding directive for `f_t^p`.
    QuantCmd {
        /// Iteration index.
        t: u32,
        /// The directive.
        spec: QuantSpec,
    },
    /// Worker → fusion: the (coded) local estimate.
    FVector {
        /// Iteration index.
        t: u32,
        /// Worker id.
        worker: u32,
        /// Payload.
        payload: FPayload,
    },
    /// Fusion → workers (column mode, C-MP-AMP): the combined residual
    /// `z_t` plus the effective noise level for the local denoiser.
    ColStep {
        /// Iteration index.
        t: u32,
        /// Denoiser noise level `σ̂² = ‖z_t‖²/M`.
        sigma_eff2: f64,
        /// Combined residual (raw broadcast, length M).
        z: Vec<f32>,
    },
    /// Worker → fusion (column mode): the scalars the fusion center needs
    /// before designing the quantizer, plus the worker's updated estimate
    /// block. The block is carried for evaluation/reporting only and is
    /// excluded from the uplink rate accounting (`f_payload_bits`).
    ColScalars {
        /// Iteration index.
        t: u32,
        /// Worker id.
        worker: u32,
        /// `‖u^p‖²` of the pending residual contribution.
        u_norm2: f64,
        /// Mean of `η′` over this worker's block (Onsager aggregation).
        eta_prime_mean: f64,
        /// The worker's updated `x^p` block (length N/P, eval only).
        x_shard: Vec<f32>,
    },
    /// Fusion → workers: shut down.
    Done,
}

const TAG_STEP: u8 = 1;
const TAG_ZNORM: u8 = 2;
const TAG_QUANT: u8 = 3;
const TAG_FVEC: u8 = 4;
const TAG_DONE: u8 = 5;
const TAG_COLSTEP: u8 = 6;
const TAG_COLSCALARS: u8 = 7;

const SPEC_RAW: u8 = 0;
const SPEC_SKIP: u8 = 1;
const SPEC_ECSQ: u8 = 2;

const PAY_RAW: u8 = 0;
const PAY_CODED: u8 = 1;
const PAY_SKIPPED: u8 = 2;

impl Message {
    /// Serialize to bytes.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16);
        match self {
            Message::StepCmd { t, coef, x } => {
                out.push(TAG_STEP);
                push_u32(&mut out, *t);
                push_f32(&mut out, *coef);
                push_u32(&mut out, x.len() as u32);
                let base = out.len();
                out.resize(base + 4 * x.len(), 0);
                LE::write_f32_into(x, &mut out[base..]);
            }
            Message::ZNorm { t, worker, z_norm2 } => {
                out.push(TAG_ZNORM);
                push_u32(&mut out, *t);
                push_u32(&mut out, *worker);
                push_f64(&mut out, *z_norm2);
            }
            Message::QuantCmd { t, spec } => {
                out.push(TAG_QUANT);
                push_u32(&mut out, *t);
                match spec {
                    QuantSpec::Raw => out.push(SPEC_RAW),
                    QuantSpec::Skip => out.push(SPEC_SKIP),
                    QuantSpec::Ecsq { delta, k_max, sigma_d2_hat } => {
                        out.push(SPEC_ECSQ);
                        push_f64(&mut out, *delta);
                        push_u32(&mut out, *k_max);
                        push_f64(&mut out, *sigma_d2_hat);
                    }
                }
            }
            Message::FVector { t, worker, payload } => {
                out.push(TAG_FVEC);
                push_u32(&mut out, *t);
                push_u32(&mut out, *worker);
                match payload {
                    FPayload::Raw(v) => {
                        out.push(PAY_RAW);
                        push_u32(&mut out, v.len() as u32);
                        let base = out.len();
                        out.resize(base + 4 * v.len(), 0);
                        LE::write_f32_into(v, &mut out[base..]);
                    }
                    FPayload::Coded { n, bytes } => {
                        out.push(PAY_CODED);
                        push_u32(&mut out, *n);
                        push_u32(&mut out, bytes.len() as u32);
                        out.extend_from_slice(bytes);
                    }
                    FPayload::Skipped => out.push(PAY_SKIPPED),
                }
            }
            Message::ColStep { t, sigma_eff2, z } => {
                out.push(TAG_COLSTEP);
                push_u32(&mut out, *t);
                push_f64(&mut out, *sigma_eff2);
                push_u32(&mut out, z.len() as u32);
                let base = out.len();
                out.resize(base + 4 * z.len(), 0);
                LE::write_f32_into(z, &mut out[base..]);
            }
            Message::ColScalars { t, worker, u_norm2, eta_prime_mean, x_shard } => {
                out.push(TAG_COLSCALARS);
                push_u32(&mut out, *t);
                push_u32(&mut out, *worker);
                push_f64(&mut out, *u_norm2);
                push_f64(&mut out, *eta_prime_mean);
                push_u32(&mut out, x_shard.len() as u32);
                let base = out.len();
                out.resize(base + 4 * x_shard.len(), 0);
                LE::write_f32_into(x_shard, &mut out[base..]);
            }
            Message::Done => out.push(TAG_DONE),
        }
        out
    }

    /// Deserialize.
    pub fn decode(buf: &[u8]) -> Result<Message> {
        let mut c = Cursor { buf, pos: 0 };
        let tag = c.u8()?;
        let msg = match tag {
            TAG_STEP => {
                let t = c.u32()?;
                let coef = c.f32()?;
                let n = c.u32()? as usize;
                let raw = c.bytes(4 * n)?;
                let mut x = vec![0f32; n];
                LE::read_f32_into(raw, &mut x);
                Message::StepCmd { t, coef, x }
            }
            TAG_ZNORM => Message::ZNorm {
                t: c.u32()?,
                worker: c.u32()?,
                z_norm2: c.f64()?,
            },
            TAG_QUANT => {
                let t = c.u32()?;
                let spec = match c.u8()? {
                    SPEC_RAW => QuantSpec::Raw,
                    SPEC_SKIP => QuantSpec::Skip,
                    SPEC_ECSQ => QuantSpec::Ecsq {
                        delta: c.f64()?,
                        k_max: c.u32()?,
                        sigma_d2_hat: c.f64()?,
                    },
                    other => {
                        return Err(Error::Protocol(format!("bad quant spec tag {other}")))
                    }
                };
                Message::QuantCmd { t, spec }
            }
            TAG_FVEC => {
                let t = c.u32()?;
                let worker = c.u32()?;
                let payload = match c.u8()? {
                    PAY_RAW => {
                        let n = c.u32()? as usize;
                        let raw = c.bytes(4 * n)?;
                        let mut v = vec![0f32; n];
                        LE::read_f32_into(raw, &mut v);
                        FPayload::Raw(v)
                    }
                    PAY_CODED => {
                        let n = c.u32()?;
                        let len = c.u32()? as usize;
                        FPayload::Coded { n, bytes: c.bytes(len)?.to_vec() }
                    }
                    PAY_SKIPPED => FPayload::Skipped,
                    other => {
                        return Err(Error::Protocol(format!("bad payload tag {other}")))
                    }
                };
                Message::FVector { t, worker, payload }
            }
            TAG_COLSTEP => {
                let t = c.u32()?;
                let sigma_eff2 = c.f64()?;
                let n = c.u32()? as usize;
                let raw = c.bytes(4 * n)?;
                let mut z = vec![0f32; n];
                LE::read_f32_into(raw, &mut z);
                Message::ColStep { t, sigma_eff2, z }
            }
            TAG_COLSCALARS => {
                let t = c.u32()?;
                let worker = c.u32()?;
                let u_norm2 = c.f64()?;
                let eta_prime_mean = c.f64()?;
                let n = c.u32()? as usize;
                let raw = c.bytes(4 * n)?;
                let mut x_shard = vec![0f32; n];
                LE::read_f32_into(raw, &mut x_shard);
                Message::ColScalars { t, worker, u_norm2, eta_prime_mean, x_shard }
            }
            TAG_DONE => Message::Done,
            other => return Err(Error::Protocol(format!("unknown message tag {other}"))),
        };
        if c.pos != buf.len() {
            return Err(Error::Protocol(format!(
                "trailing bytes: consumed {} of {}",
                c.pos,
                buf.len()
            )));
        }
        Ok(msg)
    }

    /// Payload bits of the f-vector content (the paper's uplink metric);
    /// 0 for non-FVector messages.
    pub fn f_payload_bits(&self) -> f64 {
        match self {
            Message::FVector { payload, .. } => match payload {
                FPayload::Raw(v) => 32.0 * v.len() as f64,
                FPayload::Coded { bytes, .. } => 8.0 * bytes.len() as f64,
                FPayload::Skipped => 0.0,
            },
            _ => 0.0,
        }
    }
}

fn push_u32(out: &mut Vec<u8>, v: u32) {
    let mut b = [0u8; 4];
    LE::write_u32(&mut b, v);
    out.extend_from_slice(&b);
}

fn push_f32(out: &mut Vec<u8>, v: f32) {
    let mut b = [0u8; 4];
    LE::write_f32(&mut b, v);
    out.extend_from_slice(&b);
}

fn push_f64(out: &mut Vec<u8>, v: f64) {
    let mut b = [0u8; 8];
    LE::write_f64(&mut b, v);
    out.extend_from_slice(&b);
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::Protocol(format!(
                "message truncated: need {n} bytes at {}",
                self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(LE::read_u32(self.bytes(4)?))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(LE::read_f32(self.bytes(4)?))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(LE::read_f64(self.bytes(8)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{prop_assert, Prop};

    #[test]
    fn roundtrip_all_variants() {
        let msgs = vec![
            Message::StepCmd { t: 3, coef: 0.25, x: vec![1.0, -2.5, 3.25] },
            Message::ZNorm { t: 1, worker: 7, z_norm2: 123.456 },
            Message::QuantCmd { t: 2, spec: QuantSpec::Raw },
            Message::QuantCmd { t: 2, spec: QuantSpec::Skip },
            Message::QuantCmd {
                t: 9,
                spec: QuantSpec::Ecsq { delta: 0.031, k_max: 200, sigma_d2_hat: 0.7 },
            },
            Message::FVector { t: 4, worker: 0, payload: FPayload::Raw(vec![0.5; 17]) },
            Message::FVector {
                t: 4,
                worker: 2,
                payload: FPayload::Coded { n: 100, bytes: vec![1, 2, 3, 255] },
            },
            Message::FVector { t: 5, worker: 3, payload: FPayload::Skipped },
            Message::ColStep { t: 6, sigma_eff2: 0.042, z: vec![0.5, -1.25, 2.0] },
            Message::ColScalars {
                t: 6,
                worker: 4,
                u_norm2: 9.75,
                eta_prime_mean: 0.125,
                x_shard: vec![1.0, 0.0, -0.5],
            },
            Message::Done,
        ];
        for m in msgs {
            let enc = m.encode();
            let dec = Message::decode(&enc).unwrap();
            assert_eq!(m, dec);
        }
    }

    #[test]
    fn roundtrip_random_stepcmds() {
        Prop::new("StepCmd roundtrip", 50).check(|g| {
            let n = g.usize_in(0, 500);
            let x = g.gaussian_vec(n, 2.0);
            let m = Message::StepCmd { t: g.u64() as u32, coef: g.f64_in(-1.0, 1.0) as f32, x };
            let dec = Message::decode(&m.encode()).map_err(|e| e.to_string())?;
            prop_assert(dec == m, "mismatch")
        });
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Message::decode(&[]).is_err());
        assert!(Message::decode(&[99]).is_err());
        assert!(Message::decode(&[TAG_ZNORM, 1, 2]).is_err()); // truncated
        // Trailing bytes rejected.
        let mut enc = Message::Done.encode();
        enc.push(0);
        assert!(Message::decode(&enc).is_err());
    }

    #[test]
    fn payload_bits_accounting() {
        let raw = Message::FVector { t: 0, worker: 0, payload: FPayload::Raw(vec![0.0; 10]) };
        assert_eq!(raw.f_payload_bits(), 320.0);
        let coded = Message::FVector {
            t: 0,
            worker: 0,
            payload: FPayload::Coded { n: 10, bytes: vec![0; 3] },
        };
        assert_eq!(coded.f_payload_bits(), 24.0);
        assert_eq!(Message::Done.f_payload_bits(), 0.0);
        // Column-mode eval shards ride outside the rate accounting.
        let scalars = Message::ColScalars {
            t: 0,
            worker: 0,
            u_norm2: 1.0,
            eta_prime_mean: 0.5,
            x_shard: vec![0.0; 100],
        };
        assert_eq!(scalars.f_payload_bits(), 0.0);
    }
}
