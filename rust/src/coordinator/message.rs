//! Wire format between workers and the fusion center.
//!
//! Binary little-endian framing (no serde in the offline crate set): one
//! type byte, fixed header fields, then the payload. Every message
//! round-trips exactly (property-tested) and reports its payload bit cost
//! for the paper's communication accounting.
//!
//! Since protocol version 2 every data-bearing message is **natively
//! batched**: a session carries `B ≥ 1` signal instances, and each round
//! trip moves all `B` per-signal vectors in one frame (column-major, one
//! length-prefixed block per message). `B = 1` is simply a batch of one.
//! Peers exchange [`PROTOCOL_VERSION`] in the transport hello so a
//! mismatched peer fails fast instead of decoding garbage.

use crate::error::{Error, Result};

/// Version byte exchanged in the worker hello frame. Bump on every wire
/// format change; peers with a different version refuse to talk.
///
/// * v1 — single-signal messages (PR 1–2).
/// * v2 — batched messages (`B` signals per frame) + versioned hello.
pub const PROTOCOL_VERSION: u8 = 2;

/// How workers should code one signal's uplink vector this iteration
/// (broadcast by fusion; one spec per batch member rides in a single
/// [`Message::QuantCmd`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum QuantSpec {
    /// Send raw 32-bit floats.
    Raw,
    /// Send nothing (zero-rate iteration).
    Skip,
    /// Entropy-coded scalar quantization. Workers and fusion rebuild the
    /// identical quantizer + model pmf from these parameters (plus the
    /// static prior/P from config) — no codebook on the wire.
    Ecsq {
        /// Bin width Δ_Q.
        delta: f64,
        /// Largest bin index (2·k_max+1 bins).
        k_max: u32,
        /// The variance estimate the model pmf is built from (σ̂²_{t,D}
        /// in row mode, the message variance v̂ in column mode).
        sigma_d2_hat: f64,
    },
}

/// The uplinked vector of one signal.
#[derive(Debug, Clone, PartialEq)]
pub enum FPayload {
    /// Raw floats (32 bits/element), or dequantized values under the
    /// analytic codec (entropy-accounted, not entropy-coded).
    Raw(Vec<f32>),
    /// Entropy-coded symbols.
    Coded {
        /// Number of symbols.
        n: u32,
        /// Codec output bytes.
        bytes: Vec<u8>,
    },
    /// Zero-rate iteration (fusion substitutes zeros).
    Skipped,
}

/// All protocol messages. Vector fields hold `B` per-signal blocks
/// (column-major: signal `j`'s block is contiguous).
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Fusion → workers (row mode): run LC for iteration `t` on all `B`
    /// signals.
    StepCmd {
        /// Iteration index.
        t: u32,
        /// Per-signal Onsager coefficients `(1/κ)·mean(η′_{t−1})`.
        coefs: Vec<f32>,
        /// Current estimates, `B × N` column-major (raw broadcast).
        x: Vec<f32>,
    },
    /// Worker → fusion (row mode): per-signal `‖z_t^p‖²` for the σ̂²
    /// estimates.
    ZNorm {
        /// Iteration index.
        t: u32,
        /// Worker id.
        worker: u32,
        /// Per-signal squared norms of the local residuals.
        z_norm2: Vec<f64>,
    },
    /// Fusion → workers: per-signal coding directives for this round's
    /// uplink (one quantizer-design round trip covers the whole batch).
    QuantCmd {
        /// Iteration index.
        t: u32,
        /// One spec per batch member.
        specs: Vec<QuantSpec>,
    },
    /// Worker → fusion: the (coded) uplink vectors, one per signal.
    FVector {
        /// Iteration index.
        t: u32,
        /// Worker id.
        worker: u32,
        /// One payload per batch member.
        payloads: Vec<FPayload>,
    },
    /// Fusion → workers (column mode, C-MP-AMP): the combined residuals
    /// plus per-signal effective noise levels for the local denoisers.
    ColStep {
        /// Iteration index.
        t: u32,
        /// Per-signal denoiser noise levels `σ̂²_j = ‖z_{t,j}‖²/M`.
        sigma_eff2: Vec<f64>,
        /// Combined residuals, `B × M` column-major (raw broadcast).
        z: Vec<f32>,
    },
    /// Worker → fusion (column mode): the scalars the fusion center needs
    /// before designing the quantizers, plus the worker's updated estimate
    /// blocks. The blocks are carried for evaluation/reporting only and
    /// are excluded from the uplink rate accounting (`f_payload_bits`).
    ColScalars {
        /// Iteration index.
        t: u32,
        /// Worker id.
        worker: u32,
        /// Per-signal `‖u^p_j‖²` of the pending residual contributions.
        u_norm2: Vec<f64>,
        /// Per-signal means of `η′` over this worker's block.
        eta_prime_mean: Vec<f64>,
        /// Updated `x^p` blocks, `B × (N/P)` column-major (eval only).
        x_shard: Vec<f32>,
    },
    /// Fusion → workers: shut down.
    Done,
}

const TAG_STEP: u8 = 1;
const TAG_ZNORM: u8 = 2;
const TAG_QUANT: u8 = 3;
const TAG_FVEC: u8 = 4;
const TAG_DONE: u8 = 5;
const TAG_COLSTEP: u8 = 6;
const TAG_COLSCALARS: u8 = 7;

const SPEC_RAW: u8 = 0;
const SPEC_SKIP: u8 = 1;
const SPEC_ECSQ: u8 = 2;

const PAY_RAW: u8 = 0;
const PAY_CODED: u8 = 1;
const PAY_SKIPPED: u8 = 2;

/// Upper bound on the per-message batch count accepted by `decode`. The
/// float blocks are naturally bounded by the transport's frame cap (4–8
/// wire bytes per element), but `QuantCmd`/`FVector` entries can be a
/// single tag byte on the wire while costing tens of bytes in memory —
/// an unbounded count would let a malicious peer amplify a ~1 GiB frame
/// into a multi-ten-GiB allocation. No real session approaches this.
const MAX_WIRE_BATCH: u32 = 65_536;

impl Message {
    /// Serialize to bytes.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16);
        match self {
            Message::StepCmd { t, coefs, x } => {
                out.push(TAG_STEP);
                push_u32(&mut out, *t);
                push_f32_block(&mut out, coefs);
                push_f32_block(&mut out, x);
            }
            Message::ZNorm { t, worker, z_norm2 } => {
                out.push(TAG_ZNORM);
                push_u32(&mut out, *t);
                push_u32(&mut out, *worker);
                push_f64_block(&mut out, z_norm2);
            }
            Message::QuantCmd { t, specs } => {
                out.push(TAG_QUANT);
                push_u32(&mut out, *t);
                push_u32(&mut out, specs.len() as u32);
                for spec in specs {
                    match spec {
                        QuantSpec::Raw => out.push(SPEC_RAW),
                        QuantSpec::Skip => out.push(SPEC_SKIP),
                        QuantSpec::Ecsq { delta, k_max, sigma_d2_hat } => {
                            out.push(SPEC_ECSQ);
                            push_f64(&mut out, *delta);
                            push_u32(&mut out, *k_max);
                            push_f64(&mut out, *sigma_d2_hat);
                        }
                    }
                }
            }
            Message::FVector { t, worker, payloads } => {
                out.push(TAG_FVEC);
                push_u32(&mut out, *t);
                push_u32(&mut out, *worker);
                push_u32(&mut out, payloads.len() as u32);
                for payload in payloads {
                    match payload {
                        FPayload::Raw(v) => {
                            out.push(PAY_RAW);
                            push_f32_block(&mut out, v);
                        }
                        FPayload::Coded { n, bytes } => {
                            out.push(PAY_CODED);
                            push_u32(&mut out, *n);
                            push_u32(&mut out, bytes.len() as u32);
                            out.extend_from_slice(bytes);
                        }
                        FPayload::Skipped => out.push(PAY_SKIPPED),
                    }
                }
            }
            Message::ColStep { t, sigma_eff2, z } => {
                out.push(TAG_COLSTEP);
                push_u32(&mut out, *t);
                push_f64_block(&mut out, sigma_eff2);
                push_f32_block(&mut out, z);
            }
            Message::ColScalars { t, worker, u_norm2, eta_prime_mean, x_shard } => {
                out.push(TAG_COLSCALARS);
                push_u32(&mut out, *t);
                push_u32(&mut out, *worker);
                push_f64_block(&mut out, u_norm2);
                push_f64_block(&mut out, eta_prime_mean);
                push_f32_block(&mut out, x_shard);
            }
            Message::Done => out.push(TAG_DONE),
        }
        out
    }

    /// Deserialize.
    pub fn decode(buf: &[u8]) -> Result<Message> {
        let mut c = Cursor { buf, pos: 0 };
        let tag = c.u8()?;
        let msg = match tag {
            TAG_STEP => Message::StepCmd {
                t: c.u32()?,
                coefs: c.f32_block()?,
                x: c.f32_block()?,
            },
            TAG_ZNORM => Message::ZNorm {
                t: c.u32()?,
                worker: c.u32()?,
                z_norm2: c.f64_block()?,
            },
            TAG_QUANT => {
                let t = c.u32()?;
                let count = c.batch_count()?;
                let mut specs = Vec::with_capacity(count);
                for _ in 0..count {
                    specs.push(match c.u8()? {
                        SPEC_RAW => QuantSpec::Raw,
                        SPEC_SKIP => QuantSpec::Skip,
                        SPEC_ECSQ => QuantSpec::Ecsq {
                            delta: c.f64()?,
                            k_max: c.u32()?,
                            sigma_d2_hat: c.f64()?,
                        },
                        other => {
                            return Err(Error::Protocol(format!(
                                "bad quant spec tag {other}"
                            )))
                        }
                    });
                }
                Message::QuantCmd { t, specs }
            }
            TAG_FVEC => {
                let t = c.u32()?;
                let worker = c.u32()?;
                let count = c.batch_count()?;
                let mut payloads = Vec::with_capacity(count);
                for _ in 0..count {
                    payloads.push(match c.u8()? {
                        PAY_RAW => FPayload::Raw(c.f32_block()?),
                        PAY_CODED => {
                            let n = c.u32()?;
                            let len = c.u32()? as usize;
                            FPayload::Coded { n, bytes: c.bytes(len)?.to_vec() }
                        }
                        PAY_SKIPPED => FPayload::Skipped,
                        other => {
                            return Err(Error::Protocol(format!(
                                "bad payload tag {other}"
                            )))
                        }
                    });
                }
                Message::FVector { t, worker, payloads }
            }
            TAG_COLSTEP => Message::ColStep {
                t: c.u32()?,
                sigma_eff2: c.f64_block()?,
                z: c.f32_block()?,
            },
            TAG_COLSCALARS => Message::ColScalars {
                t: c.u32()?,
                worker: c.u32()?,
                u_norm2: c.f64_block()?,
                eta_prime_mean: c.f64_block()?,
                x_shard: c.f32_block()?,
            },
            TAG_DONE => Message::Done,
            other => return Err(Error::Protocol(format!("unknown message tag {other}"))),
        };
        if c.pos != buf.len() {
            return Err(Error::Protocol(format!(
                "trailing bytes: consumed {} of {}",
                c.pos,
                buf.len()
            )));
        }
        Ok(msg)
    }

    /// Payload bits of the uplinked vector content, summed over the batch
    /// (the paper's uplink metric); 0 for non-FVector messages.
    pub fn f_payload_bits(&self) -> f64 {
        match self {
            Message::FVector { payloads, .. } => payloads
                .iter()
                .map(|payload| match payload {
                    FPayload::Raw(v) => 32.0 * v.len() as f64,
                    FPayload::Coded { bytes, .. } => 8.0 * bytes.len() as f64,
                    FPayload::Skipped => 0.0,
                })
                .sum(),
            _ => 0.0,
        }
    }
}

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Length-prefixed little-endian `f32` block. One resize + bulk fill —
/// broadcast frames carry `B × N` floats re-encoded once per endpoint per
/// round, so per-element `Vec` bookkeeping would sit on the hot wire path.
fn push_f32_block(out: &mut Vec<u8>, vs: &[f32]) {
    push_u32(out, vs.len() as u32);
    let base = out.len();
    out.resize(base + 4 * vs.len(), 0);
    for (chunk, v) in out[base..].chunks_exact_mut(4).zip(vs) {
        chunk.copy_from_slice(&v.to_le_bytes());
    }
}

/// Length-prefixed little-endian `f64` block (bulk-filled like
/// [`push_f32_block`]).
fn push_f64_block(out: &mut Vec<u8>, vs: &[f64]) {
    push_u32(out, vs.len() as u32);
    let base = out.len();
    out.resize(base + 8 * vs.len(), 0);
    for (chunk, v) in out[base..].chunks_exact_mut(8).zip(vs) {
        chunk.copy_from_slice(&v.to_le_bytes());
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::Protocol(format!(
                "message truncated: need {n} bytes at {}",
                self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// A batch count, validated against [`MAX_WIRE_BATCH`] before any
    /// allocation sized by it.
    fn batch_count(&mut self) -> Result<usize> {
        let count = self.u32()?;
        if count > MAX_WIRE_BATCH {
            return Err(Error::Protocol(format!(
                "batch count {count} exceeds the wire limit {MAX_WIRE_BATCH}"
            )));
        }
        Ok(count as usize)
    }

    fn f64(&mut self) -> Result<f64> {
        let b = self.bytes(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(f64::from_le_bytes(a))
    }

    fn f32_block(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.bytes(4 * n)?;
        let mut out = vec![0f32; n];
        for (i, o) in out.iter_mut().enumerate() {
            *o = f32::from_le_bytes([
                raw[4 * i],
                raw[4 * i + 1],
                raw[4 * i + 2],
                raw[4 * i + 3],
            ]);
        }
        Ok(out)
    }

    fn f64_block(&mut self) -> Result<Vec<f64>> {
        let n = self.u32()? as usize;
        let raw = self.bytes(8 * n)?;
        let mut out = vec![0f64; n];
        for (i, o) in out.iter_mut().enumerate() {
            let mut a = [0u8; 8];
            a.copy_from_slice(&raw[8 * i..8 * i + 8]);
            *o = f64::from_le_bytes(a);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{prop_assert, Prop};

    #[test]
    fn roundtrip_all_variants() {
        let msgs = vec![
            Message::StepCmd { t: 3, coefs: vec![0.25, -0.5], x: vec![1.0, -2.5, 3.25, 0.0, 1.5, -9.0] },
            Message::ZNorm { t: 1, worker: 7, z_norm2: vec![123.456, 0.25] },
            Message::QuantCmd { t: 2, specs: vec![QuantSpec::Raw, QuantSpec::Skip] },
            Message::QuantCmd {
                t: 9,
                specs: vec![
                    QuantSpec::Ecsq { delta: 0.031, k_max: 200, sigma_d2_hat: 0.7 },
                    QuantSpec::Raw,
                    QuantSpec::Ecsq { delta: 0.011, k_max: 64, sigma_d2_hat: 0.2 },
                ],
            },
            Message::FVector {
                t: 4,
                worker: 0,
                payloads: vec![FPayload::Raw(vec![0.5; 17]), FPayload::Skipped],
            },
            Message::FVector {
                t: 4,
                worker: 2,
                payloads: vec![
                    FPayload::Coded { n: 100, bytes: vec![1, 2, 3, 255] },
                    FPayload::Coded { n: 7, bytes: vec![9] },
                ],
            },
            Message::ColStep {
                t: 6,
                sigma_eff2: vec![0.042, 0.011],
                z: vec![0.5, -1.25, 2.0, 0.25, 0.0, -3.0],
            },
            Message::ColScalars {
                t: 6,
                worker: 4,
                u_norm2: vec![9.75, 1.5],
                eta_prime_mean: vec![0.125, 0.25],
                x_shard: vec![1.0, 0.0, -0.5, 2.0],
            },
            Message::Done,
        ];
        for m in msgs {
            let enc = m.encode();
            let dec = Message::decode(&enc).unwrap();
            assert_eq!(m, dec);
        }
    }

    #[test]
    fn roundtrip_random_batched_stepcmds() {
        Prop::new("StepCmd roundtrip", 50).check(|g| {
            let b = g.usize_in(1, 5);
            let n = g.usize_in(0, 200);
            let x = g.gaussian_vec(b * n, 2.0);
            let coefs: Vec<f32> =
                (0..b).map(|_| g.f64_in(-1.0, 1.0) as f32).collect();
            let m = Message::StepCmd { t: g.u64() as u32, coefs, x };
            let dec = Message::decode(&m.encode()).map_err(|e| e.to_string())?;
            prop_assert(dec == m, "mismatch")
        });
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Message::decode(&[]).is_err());
        assert!(Message::decode(&[99]).is_err());
        assert!(Message::decode(&[TAG_ZNORM, 1, 2]).is_err()); // truncated
        // Trailing bytes rejected.
        let mut enc = Message::Done.encode();
        enc.push(0);
        assert!(Message::decode(&enc).is_err());
        // Truncated batch payloads rejected.
        let enc = Message::QuantCmd { t: 0, specs: vec![QuantSpec::Raw; 3] }.encode();
        assert!(Message::decode(&enc[..enc.len() - 1]).is_err());
    }

    #[test]
    fn decode_rejects_absurd_batch_counts() {
        // A hostile count must be rejected before any count-sized
        // allocation: QuantCmd claiming u32::MAX specs...
        let mut enc = vec![TAG_QUANT];
        enc.extend_from_slice(&7u32.to_le_bytes());
        enc.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = Message::decode(&enc).unwrap_err().to_string();
        assert!(err.contains("batch count"), "{err}");
        // ...and an FVector claiming one tag byte per fake payload.
        let mut enc = vec![TAG_FVEC];
        enc.extend_from_slice(&0u32.to_le_bytes());
        enc.extend_from_slice(&0u32.to_le_bytes());
        enc.extend_from_slice(&(MAX_WIRE_BATCH + 1).to_le_bytes());
        enc.extend_from_slice(&[PAY_SKIPPED; 64]);
        let err = Message::decode(&enc).unwrap_err().to_string();
        assert!(err.contains("batch count"), "{err}");
        // The limit itself is generous: a real batch passes untouched.
        let big = Message::QuantCmd { t: 1, specs: vec![QuantSpec::Skip; 512] };
        assert_eq!(Message::decode(&big.encode()).unwrap(), big);
    }

    #[test]
    fn payload_bits_sum_over_batch() {
        let raw = Message::FVector {
            t: 0,
            worker: 0,
            payloads: vec![FPayload::Raw(vec![0.0; 10]), FPayload::Raw(vec![0.0; 10])],
        };
        assert_eq!(raw.f_payload_bits(), 640.0);
        let mixed = Message::FVector {
            t: 0,
            worker: 0,
            payloads: vec![
                FPayload::Coded { n: 10, bytes: vec![0; 3] },
                FPayload::Skipped,
            ],
        };
        assert_eq!(mixed.f_payload_bits(), 24.0);
        assert_eq!(Message::Done.f_payload_bits(), 0.0);
        // Column-mode eval shards ride outside the rate accounting.
        let scalars = Message::ColScalars {
            t: 0,
            worker: 0,
            u_norm2: vec![1.0],
            eta_prime_mean: vec![0.5],
            x_shard: vec![0.0; 100],
        };
        assert_eq!(scalars.f_payload_bits(), 0.0);
    }
}
