//! The MP-AMP coordinator — the paper's system contribution.
//!
//! * [`message`] — the batched wire protocol (StepCmd/ZNorm/QuantCmd/
//!   FVector/ColStep/ColScalars/Done) + the protocol version byte,
//! * [`transport`] — byte-metered in-process + TCP duplex links with
//!   connect/accept/read timeouts and a versioned hello,
//! * [`fault`] — deterministic fault injection: seeded [`fault::FaultPlan`]s
//!   (drop/delay/kill/corrupt) installable on any transport, so every
//!   degradation path the elastic protocol tolerates is reproducible,
//! * [`scenario`] — the scenario-generic protocol core: the [`Scenario`]
//!   trait (implemented by [`scenario::Row`] and [`scenario::Column`])
//!   and the generic [`scenario::ProtocolCore`] round driver,
//! * [`worker`] — the one generic worker loop (local step + quantize +
//!   encode, whatever the scenario),
//! * [`fusion`] — quantizer-spec design + the thin [`fusion::ProtocolState`]
//!   enum dispatching to the monomorphized cores,
//! * [`session`] — end-to-end orchestration producing a [`session::RunReport`].
//!
//! Sessions are **batched**: `B ≥ 1` signal instances share one sensing
//! matrix and travel through every round together, so each pass over `A`
//! and each protocol round trip is amortized across the batch.
//!
//! Row-partitioned protocol per iteration `t` (paper §3.1–§3.3), batched:
//!
//! ```text
//! fusion ──StepCmd{t, X_t, coefs}──▶ workers          (broadcast, B signals)
//! fusion ◀──ZNorm{‖z_t^p‖² × B}──── workers          (σ̂² estimates)
//! fusion ──QuantCmd{t, specs × B}──▶ workers          (quantizer designs)
//! fusion ◀──FVector{coded f_t^p × B} workers          (the expensive uplink)
//! fusion: f̃_j = Σ_p dequant(f_j^p); x_{t+1,j} = η(f̃_j); loop
//! ```
//!
//! Column-partitioned protocol (C-MP-AMP, 1701.02578) — denoising moves
//! to the workers, the fusion center owns `y` and the combined residuals:
//!
//! ```text
//! fusion ──ColStep{t, Z_t, σ̂² × B}─▶ workers           (residual broadcast)
//! workers: f_j^p = x_j^p + (A^p)ᵀ z_{t,j}; x_j^p ← η(f_j^p); u_j^p = A^p x_j^p
//! fusion ◀──ColScalars{‖u^p‖², η̄′ × B}─ workers        (v̂ + Onsager terms)
//! fusion ──QuantCmd{t, specs × B}──▶ workers           (quantizer designs)
//! fusion ◀──FVector{coded u^p × B}── workers           (the expensive uplink)
//! fusion: z_{t+1,j} = y_j − Σ_p dequant(u_j^p) + coef_j·z_{t,j}; loop
//! ```
//!
//! [`Scenario`]: scenario::Scenario

pub mod builder;
pub mod fault;
pub mod fusion;
pub mod message;
pub mod scenario;
pub mod session;
pub mod transport;
pub mod worker;

pub use builder::SessionBuilder;
pub use fault::{Fault, FaultPlan};
pub use message::{FPayload, Message, QuantSpec, PROTOCOL_VERSION};
pub use scenario::{ProtocolCore, Scenario};
pub use session::{IterSnapshot, MpAmpSession, RunReport, Session};
