//! The MP-AMP coordinator — the paper's system contribution.
//!
//! * [`message`] — the wire protocol (StepCmd/ZNorm/QuantCmd/FVector/Done),
//! * [`transport`] — byte-metered in-process + TCP duplex links,
//! * [`worker`] — the worker processor loop (LC + quantize + encode),
//! * [`fusion`] — the fusion-center loop (aggregate, design quantizer,
//!   decode, denoise, broadcast),
//! * [`session`] — end-to-end orchestration producing a [`session::RunReport`].
//!
//! Row-partitioned protocol per iteration `t` (paper §3.1–§3.3):
//!
//! ```text
//! fusion ──StepCmd{t, x_t, coef}──▶ workers          (broadcast)
//! fusion ◀──ZNorm{‖z_t^p‖²}─────── workers          (σ̂² estimate)
//! fusion ──QuantCmd{t, Δ, K, σ̂²}──▶ workers         (quantizer design)
//! fusion ◀──FVector{coded f_t^p}── workers          (the expensive uplink)
//! fusion: f̃ = Σ dequant(f^p); x_{t+1} = η(f̃); loop
//! ```
//!
//! Column-partitioned protocol (C-MP-AMP, 1701.02578) — denoising moves
//! to the workers, the fusion center owns `y` and the combined residual:
//!
//! ```text
//! fusion ──ColStep{t, z_t, σ̂²}───▶ workers           (residual broadcast)
//! workers: f^p = x^p + (A^p)ᵀ z_t; x^p ← η(f^p); u^p = A^p x^p
//! fusion ◀──ColScalars{‖u^p‖², η̄′}─ workers          (v̂ + Onsager terms)
//! fusion ──QuantCmd{t, Δ, K, v̂}───▶ workers          (quantizer design)
//! fusion ◀──FVector{coded u^p}──── workers           (the expensive uplink)
//! fusion: z_{t+1} = y − Σ dequant(u^p) + coef·z_t; loop
//! ```

pub mod builder;
pub mod fusion;
pub mod message;
pub mod session;
pub mod transport;
pub mod worker;

pub use builder::SessionBuilder;
pub use message::{FPayload, Message, QuantSpec};
pub use session::{IterSnapshot, MpAmpSession, RunReport, Session};
