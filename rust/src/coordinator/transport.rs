//! Byte-metered transports between workers and the fusion center.
//!
//! Two implementations of the same [`Channel`] trait:
//! * [`inproc_pair`] — `std::sync::mpsc` channels (default; zero-copy-ish),
//! * [`tcp_pair_listener`]/[`tcp_pair_connect`] — length-prefixed frames
//!   over TCP loopback, demonstrating the protocol works across real
//!   sockets (`examples/tcp_cluster.rs`).
//!
//! Every [`Endpoint`] owns one side of a duplex link and a shared
//! [`ByteMeter`]: worker-side sends count as uplink, fusion-side sends as
//! downlink, so the run report's communication accounting is exact.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::coordinator::message::Message;
use crate::error::{Error, Result};
use crate::metrics::ByteMeter;

/// A reliable, ordered byte-frame channel.
pub trait Channel: Send {
    /// Send one frame.
    fn send_bytes(&mut self, buf: &[u8]) -> Result<()>;
    /// Receive one frame (blocking).
    fn recv_bytes(&mut self) -> Result<Vec<u8>>;
}

/// Which side of the link this endpoint is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Side {
    /// Fusion center (sends = downlink).
    Fusion,
    /// Worker (sends = uplink).
    Worker,
}

/// One side of a duplex link, with metering.
pub struct Endpoint {
    chan: Box<dyn Channel>,
    meter: Arc<ByteMeter>,
    side: Side,
}

impl Endpoint {
    /// Wrap a channel.
    pub fn new(chan: Box<dyn Channel>, meter: Arc<ByteMeter>, side: Side) -> Self {
        Endpoint { chan, meter, side }
    }

    /// Send a message (metered).
    pub fn send(&mut self, msg: &Message) -> Result<()> {
        let buf = msg.encode();
        match self.side {
            Side::Worker => self.meter.add_uplink_bits(8 * buf.len() as u64),
            Side::Fusion => self.meter.add_downlink_bits(8 * buf.len() as u64),
        }
        self.chan.send_bytes(&buf)
    }

    /// Receive a message (blocking).
    pub fn recv(&mut self) -> Result<Message> {
        Message::decode(&self.chan.recv_bytes()?)
    }

    /// The shared meter.
    pub fn meter(&self) -> &Arc<ByteMeter> {
        &self.meter
    }
}

// ---------- in-process transport ----------

struct InProcChannel {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

impl Channel for InProcChannel {
    fn send_bytes(&mut self, buf: &[u8]) -> Result<()> {
        self.tx
            .send(buf.to_vec())
            .map_err(|_| Error::Transport("peer hung up (send)".into()))
    }

    fn recv_bytes(&mut self) -> Result<Vec<u8>> {
        self.rx.recv().map_err(|_| Error::Transport("peer hung up (recv)".into()))
    }
}

/// Build a metered in-process duplex pair (fusion side, worker side).
pub fn inproc_pair(meter: Arc<ByteMeter>) -> (Endpoint, Endpoint) {
    let (tx_f2w, rx_f2w) = channel();
    let (tx_w2f, rx_w2f) = channel();
    let fusion = Endpoint::new(
        Box::new(InProcChannel { tx: tx_f2w, rx: rx_w2f }),
        meter.clone(),
        Side::Fusion,
    );
    let worker = Endpoint::new(
        Box::new(InProcChannel { tx: tx_w2f, rx: rx_f2w }),
        meter,
        Side::Worker,
    );
    (fusion, worker)
}

// ---------- TCP transport ----------

struct TcpChannel {
    stream: TcpStream,
}

impl TcpChannel {
    fn new(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true).map_err(Error::Io)?;
        Ok(TcpChannel { stream })
    }
}

impl Channel for TcpChannel {
    fn send_bytes(&mut self, buf: &[u8]) -> Result<()> {
        let mut hdr = [0u8; 4];
        byteorder::LittleEndian::write_u32(&mut hdr, buf.len() as u32);
        self.stream.write_all(&hdr)?;
        self.stream.write_all(buf)?;
        Ok(())
    }

    fn recv_bytes(&mut self) -> Result<Vec<u8>> {
        use byteorder::ByteOrder;
        let mut hdr = [0u8; 4];
        self.stream.read_exact(&mut hdr)?;
        let len = byteorder::LittleEndian::read_u32(&hdr) as usize;
        if len > 1 << 30 {
            return Err(Error::Transport(format!("oversized frame: {len} bytes")));
        }
        let mut buf = vec![0u8; len];
        self.stream.read_exact(&mut buf)?;
        Ok(buf)
    }
}

use byteorder::ByteOrder as _;

/// Fusion-side TCP listener: bind first (so the address is known), then
/// block in [`TcpFusionListener::accept_all`] while workers connect.
pub struct TcpFusionListener {
    listener: TcpListener,
    n_workers: usize,
}

impl TcpFusionListener {
    /// Bind on `addr` ("127.0.0.1:0" for an ephemeral port).
    pub fn bind(addr: &str, n_workers: usize) -> Result<Self> {
        Ok(TcpFusionListener { listener: TcpListener::bind(addr)?, n_workers })
    }

    /// The bound address workers should connect to.
    pub fn addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept all workers; returns endpoints **in worker-id order**
    /// (workers identify themselves with a 4-byte hello).
    pub fn accept_all(self, meter: Arc<ByteMeter>) -> Result<Vec<Endpoint>> {
        let mut slots: Vec<Option<Endpoint>> = (0..self.n_workers).map(|_| None).collect();
        for _ in 0..self.n_workers {
            let (mut stream, _) = self.listener.accept()?;
            let mut hello = [0u8; 4];
            stream.read_exact(&mut hello)?;
            let id = byteorder::LittleEndian::read_u32(&hello) as usize;
            if id >= self.n_workers || slots[id].is_some() {
                return Err(Error::Transport(format!("bad worker hello id {id}")));
            }
            slots[id] = Some(Endpoint::new(
                Box::new(TcpChannel::new(stream)?),
                meter.clone(),
                Side::Fusion,
            ));
        }
        Ok(slots.into_iter().map(|s| s.unwrap()).collect())
    }
}

/// Worker side: connect to the fusion listener and identify as `worker_id`.
pub fn tcp_connect(
    addr: std::net::SocketAddr,
    worker_id: u32,
    meter: Arc<ByteMeter>,
) -> Result<Endpoint> {
    let mut stream = TcpStream::connect(addr)?;
    let mut hello = [0u8; 4];
    byteorder::LittleEndian::write_u32(&mut hello, worker_id);
    stream.write_all(&hello)?;
    Ok(Endpoint::new(Box::new(TcpChannel::new(stream)?), meter, Side::Worker))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::message::Message;

    #[test]
    fn inproc_roundtrip_and_metering() {
        let meter = Arc::new(ByteMeter::new());
        let (mut fusion, mut worker) = inproc_pair(meter.clone());
        let m1 = Message::StepCmd { t: 0, coef: 0.0, x: vec![1.0; 8] };
        fusion.send(&m1).unwrap();
        assert_eq!(worker.recv().unwrap(), m1);
        let m2 = Message::ZNorm { t: 0, worker: 3, z_norm2: 2.5 };
        worker.send(&m2).unwrap();
        assert_eq!(fusion.recv().unwrap(), m2);
        assert_eq!(meter.downlink_bits(), 8 * m1.encode().len() as u64);
        assert_eq!(meter.uplink_bits(), 8 * m2.encode().len() as u64);
    }

    #[test]
    fn inproc_hangup_reported() {
        let meter = Arc::new(ByteMeter::new());
        let (fusion, mut worker) = inproc_pair(meter);
        drop(fusion);
        assert!(worker.recv().is_err());
        assert!(worker.send(&Message::Done).is_err());
    }

    #[test]
    fn tcp_roundtrip_multi_worker() {
        let meter = Arc::new(ByteMeter::new());
        let n = 3usize;
        let listener = TcpFusionListener::bind("127.0.0.1:0", n).unwrap();
        let addr = listener.addr().unwrap();
        // Workers connect from threads while the main thread accepts.
        let worker_handles: Vec<_> = (0..n as u32)
            .map(|id| {
                let meter = meter.clone();
                std::thread::spawn(move || {
                    let mut ep = tcp_connect(addr, id, meter).unwrap();
                    // Echo protocol: recv one StepCmd, reply with ZNorm(id).
                    let msg = ep.recv().unwrap();
                    match msg {
                        Message::StepCmd { t, .. } => {
                            ep.send(&Message::ZNorm {
                                t,
                                worker: id,
                                z_norm2: id as f64 + 0.5,
                            })
                            .unwrap();
                        }
                        other => panic!("unexpected {other:?}"),
                    }
                })
            })
            .collect();
        let mut fusion_eps = listener.accept_all(meter.clone()).unwrap();
        for (i, ep) in fusion_eps.iter_mut().enumerate() {
            ep.send(&Message::StepCmd { t: 9, coef: 0.5, x: vec![1.0; 4] }).unwrap();
            let reply = ep.recv().unwrap();
            assert_eq!(
                reply,
                Message::ZNorm { t: 9, worker: i as u32, z_norm2: i as f64 + 0.5 }
            );
        }
        for h in worker_handles {
            h.join().unwrap();
        }
        assert!(meter.uplink_bits() > 0 && meter.downlink_bits() > 0);
    }
}
