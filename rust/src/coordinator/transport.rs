//! Byte-metered transports between workers and the fusion center.
//!
//! Two implementations of the same [`Channel`] trait:
//! * [`inproc_pair`] — `std::sync::mpsc` channels (default; zero-copy-ish),
//! * [`TcpFusionListener`]/[`tcp_connect`] — length-prefixed frames over
//!   TCP loopback, demonstrating the protocol works across real sockets
//!   (`examples/tcp_cluster.rs`).
//!
//! Every [`Endpoint`] owns one side of a duplex link and a shared
//! [`ByteMeter`]: worker-side sends count as uplink, fusion-side sends as
//! downlink, so the run report's communication accounting is exact.
//!
//! ## TCP hardening
//!
//! The TCP paths never block forever on a dead peer. [`TcpTimeouts`]
//! bounds connection establishment, the fusion-side accept loop, and
//! (optionally) every blocking read; expiry surfaces as
//! [`Error::Transport`] instead of a hang. Workers identify themselves
//! with a 5-byte hello `[PROTOCOL_VERSION, worker_id: u32 LE]`; a peer
//! speaking a different protocol version is rejected at accept time with
//! a clear error rather than decoding garbage frames later.
//!
//! ## Multiplexed (serve-mode) links — protocol v4
//!
//! The `mpamp serve` daemon runs many sessions over one worker fleet.
//! [`TcpFusionListener::accept_all_mux`] / [`tcp_connect_mux`] build
//! links whose frames carry a session-ID prefix
//! (`[len][session: u32 LE][frame]`); [`MuxFusionLink::open_session`] and
//! [`MuxWorkerLink::session_endpoint`] expose ordinary per-session
//! [`Endpoint`]s above the prefix, so the protocol core — and the byte
//! metering — is oblivious to the multiplexing.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::message::{Message, PROTOCOL_VERSION};
use crate::error::{Error, Result};
use crate::metrics::ByteMeter;

/// Outcome of a deadline-bounded receive: either a frame arrived in
/// time, or the deadline expired with the channel still intact (the
/// frame may yet arrive — elastic rounds use this to proceed without a
/// straggler and drain its late frame next round).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecvStatus {
    /// A frame was received into the buffer.
    Frame,
    /// The deadline expired before a frame arrived; the channel is
    /// still usable.
    TimedOut,
}

/// A reliable, ordered byte-frame channel.
///
/// Both implementations are allocation-free in steady state: the TCP
/// side reads frames into the caller's reused buffer, and the in-process
/// side circulates frame buffers through a shared [`FramePool`] (a sent
/// buffer comes back to the sender's side after the receiver swaps it
/// out).
pub trait Channel: Send {
    /// Send one frame.
    fn send_bytes(&mut self, buf: &[u8]) -> Result<()>;
    /// Receive one frame (blocking) into `buf`, replacing its contents.
    fn recv_bytes_into(&mut self, buf: &mut Vec<u8>) -> Result<()>;
    /// Receive one frame, waiting at most `timeout`. The default
    /// implementation blocks indefinitely (correct for channels with no
    /// deadline machinery); transports used by elastic K-of-P rounds
    /// override it so a straggler cannot stall the fleet.
    fn recv_bytes_into_by(
        &mut self,
        buf: &mut Vec<u8>,
        timeout: Duration,
    ) -> Result<RecvStatus> {
        let _ = timeout;
        self.recv_bytes_into(buf).map(|_| RecvStatus::Frame)
    }
}

/// A small free-list of frame buffers shared by both directions of an
/// in-process link, so steady-state rounds recycle a fixed set of
/// allocations instead of `to_vec`-ing every frame.
struct FramePool {
    free: Mutex<Vec<Vec<u8>>>,
}

/// Bound on pooled buffers per link (2 directions × a frame in flight
/// plus the one being swapped out; beyond that we let extras drop).
const FRAME_POOL_CAP: usize = 8;

impl FramePool {
    fn new() -> Arc<FramePool> {
        Arc::new(FramePool { free: Mutex::new(Vec::new()) })
    }

    fn get(&self) -> Vec<u8> {
        self.free.lock().expect("frame pool poisoned").pop().unwrap_or_default()
    }

    fn put(&self, buf: Vec<u8>) {
        let mut free = self.free.lock().expect("frame pool poisoned");
        if free.len() < FRAME_POOL_CAP {
            free.push(buf);
        }
    }
}

/// Which side of the link this endpoint is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Side {
    /// Fusion center (sends = downlink).
    Fusion,
    /// Worker (sends = uplink).
    Worker,
}

/// One side of a duplex link, with metering. Owns a reused send and
/// receive frame buffer, so steady-state protocol rounds move frames with
/// zero per-message allocation on this layer.
pub struct Endpoint {
    chan: Box<dyn Channel>,
    meter: Arc<ByteMeter>,
    side: Side,
    send_buf: Vec<u8>,
    recv_buf: Vec<u8>,
}

impl Endpoint {
    /// Wrap a channel.
    pub fn new(chan: Box<dyn Channel>, meter: Arc<ByteMeter>, side: Side) -> Self {
        Endpoint { chan, meter, side, send_buf: Vec::new(), recv_buf: Vec::new() }
    }

    fn meter_send(&self, bytes: usize) {
        match self.side {
            Side::Worker => self.meter.add_uplink_bits(8 * bytes as u64),
            Side::Fusion => self.meter.add_downlink_bits(8 * bytes as u64),
        }
    }

    /// Send a message (metered); encodes into the endpoint's reused
    /// frame buffer.
    pub fn send(&mut self, msg: &Message) -> Result<()> {
        msg.encode_into(&mut self.send_buf);
        self.meter_send(self.send_buf.len());
        self.chan.send_bytes(&self.send_buf)
    }

    /// Send an already-encoded frame (metered). The encode-once broadcast
    /// path: the fusion center encodes a round command once and hands the
    /// same bytes to every endpoint.
    pub fn send_encoded(&mut self, frame: &[u8]) -> Result<()> {
        self.meter_send(frame.len());
        self.chan.send_bytes(frame)
    }

    /// Send a frame built in place by `fill` (metered): `fill` writes a
    /// complete frame into the endpoint's reused send buffer (see the
    /// `encode_*` builders in
    /// [`message`](crate::coordinator::message)) — no owned `Message`,
    /// no staging clone.
    pub fn send_frame(&mut self, fill: impl FnOnce(&mut Vec<u8>) -> Result<()>) -> Result<()> {
        self.send_buf.clear();
        fill(&mut self.send_buf)?;
        self.meter_send(self.send_buf.len());
        self.chan.send_bytes(&self.send_buf)
    }

    /// Receive a message (blocking); decodes out of the endpoint's reused
    /// receive buffer.
    pub fn recv(&mut self) -> Result<Message> {
        self.chan.recv_bytes_into(&mut self.recv_buf)?;
        Message::decode(&self.recv_buf)
    }

    /// Receive one raw frame (blocking) into the endpoint's reused
    /// receive buffer and borrow it — the zero-copy fusion path, parsed
    /// with the borrowed decoders in
    /// [`message`](crate::coordinator::message).
    pub fn recv_frame(&mut self) -> Result<&[u8]> {
        self.chan.recv_bytes_into(&mut self.recv_buf)?;
        Ok(&self.recv_buf)
    }

    /// Receive one raw frame (blocking) into a caller-owned buffer —
    /// the worker-side zero-copy path, where the frame must outlive
    /// further endpoint calls (the reply to a broadcast is sent while
    /// the borrowed broadcast view is still alive).
    pub fn recv_frame_into(&mut self, buf: &mut Vec<u8>) -> Result<()> {
        self.chan.recv_bytes_into(buf)
    }

    /// Deadline-bounded [`recv_frame`](Endpoint::recv_frame): `Ok(None)`
    /// means the deadline expired with the link intact (elastic rounds
    /// treat the worker as a straggler and move on); `Ok(Some(frame))`
    /// borrows the received frame from the endpoint's reuse buffer.
    pub fn recv_frame_by(&mut self, timeout: Duration) -> Result<Option<&[u8]>> {
        match self.chan.recv_bytes_into_by(&mut self.recv_buf, timeout)? {
            RecvStatus::Frame => Ok(Some(&self.recv_buf)),
            RecvStatus::TimedOut => Ok(None),
        }
    }

    /// Borrow the most recently received frame again. The elastic round
    /// driver classifies a frame inside a drain loop (tag/round peeked,
    /// no borrow escaping) and then re-borrows it here for the actual
    /// zero-copy decode once the loop has settled on it.
    pub fn last_frame(&self) -> &[u8] {
        &self.recv_buf
    }

    /// Replace the underlying channel with a wrapper built from it —
    /// the hook the fault-injection harness uses to interpose a
    /// [`fault::FaultChannel`](crate::coordinator::fault::FaultChannel)
    /// on any transport without the transport knowing.
    pub fn wrap_channel(
        &mut self,
        wrap: impl FnOnce(Box<dyn Channel>) -> Box<dyn Channel>,
    ) {
        // Temporarily park a stub so `wrap` can consume the real channel.
        struct Hole;
        impl Channel for Hole {
            fn send_bytes(&mut self, _buf: &[u8]) -> Result<()> {
                Err(Error::Transport("channel hole".into()))
            }
            fn recv_bytes_into(&mut self, _buf: &mut Vec<u8>) -> Result<()> {
                Err(Error::Transport("channel hole".into()))
            }
        }
        let chan = std::mem::replace(&mut self.chan, Box::new(Hole));
        self.chan = wrap(chan);
    }

    /// The shared meter.
    pub fn meter(&self) -> &Arc<ByteMeter> {
        &self.meter
    }
}

// ---------- in-process transport ----------

struct InProcChannel {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    pool: Arc<FramePool>,
}

impl Channel for InProcChannel {
    fn send_bytes(&mut self, buf: &[u8]) -> Result<()> {
        // Copy into a recycled buffer instead of `to_vec`: after a couple
        // of rounds the link circulates a fixed set of allocations.
        let mut frame = self.pool.get();
        frame.clear();
        frame.extend_from_slice(buf);
        self.tx
            .send(frame)
            .map_err(|_| Error::Transport("peer hung up (send)".into()))
    }

    fn recv_bytes_into(&mut self, buf: &mut Vec<u8>) -> Result<()> {
        let frame = self
            .rx
            .recv()
            .map_err(|_| Error::Transport("peer hung up (recv)".into()))?;
        // Swap the received frame in (zero-copy) and return the old
        // buffer's allocation to the pool for the next sender.
        self.pool.put(std::mem::replace(buf, frame));
        Ok(())
    }

    fn recv_bytes_into_by(
        &mut self,
        buf: &mut Vec<u8>,
        timeout: Duration,
    ) -> Result<RecvStatus> {
        use std::sync::mpsc::RecvTimeoutError;
        match self.rx.recv_timeout(timeout) {
            Ok(frame) => {
                self.pool.put(std::mem::replace(buf, frame));
                Ok(RecvStatus::Frame)
            }
            Err(RecvTimeoutError::Timeout) => Ok(RecvStatus::TimedOut),
            Err(RecvTimeoutError::Disconnected) => {
                Err(Error::Transport("peer hung up (recv)".into()))
            }
        }
    }
}

/// Build a metered in-process duplex pair (fusion side, worker side).
/// Both directions share one [`FramePool`], so frame buffers circulate
/// between the peers instead of being reallocated per message.
pub fn inproc_pair(meter: Arc<ByteMeter>) -> (Endpoint, Endpoint) {
    let (tx_f2w, rx_f2w) = channel();
    let (tx_w2f, rx_w2f) = channel();
    let pool = FramePool::new();
    let fusion = Endpoint::new(
        Box::new(InProcChannel { tx: tx_f2w, rx: rx_w2f, pool: pool.clone() }),
        meter.clone(),
        Side::Fusion,
    );
    let worker = Endpoint::new(
        Box::new(InProcChannel { tx: tx_w2f, rx: rx_f2w, pool }),
        meter,
        Side::Worker,
    );
    (fusion, worker)
}

// ---------- TCP transport ----------

/// Timeout policy for the TCP transport. Every limit surfaces as
/// [`Error::Transport`] when it expires — nothing blocks forever on a
/// dead peer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TcpTimeouts {
    /// Limit on establishing a worker→fusion connection.
    pub connect: Duration,
    /// Limit on the fusion side waiting for all workers to connect and
    /// say hello.
    pub accept: Duration,
    /// Limit on any single blocking frame read once the link is up;
    /// `None` waits forever (an idle worker legitimately blocks between
    /// rounds, so per-read timeouts are opt-in).
    pub read: Option<Duration>,
}

impl Default for TcpTimeouts {
    fn default() -> Self {
        TcpTimeouts {
            connect: Duration::from_secs(10),
            accept: Duration::from_secs(30),
            read: None,
        }
    }
}

struct TcpChannel {
    stream: TcpStream,
    read_timeout: Option<Duration>,
}

impl TcpChannel {
    fn new(stream: TcpStream, read_timeout: Option<Duration>) -> Result<Self> {
        stream.set_nodelay(true).map_err(Error::Io)?;
        stream.set_read_timeout(read_timeout).map_err(Error::Io)?;
        Ok(TcpChannel { stream, read_timeout })
    }

    fn read_exact_deadlined(&mut self, buf: &mut [u8]) -> Result<()> {
        self.stream.read_exact(buf).map_err(|e| {
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            ) {
                Error::Transport(format!(
                    "tcp read timed out after {:?} (peer silent)",
                    self.read_timeout.unwrap_or_default()
                ))
            } else {
                Error::Io(e)
            }
        })
    }
}

impl Channel for TcpChannel {
    fn send_bytes(&mut self, buf: &[u8]) -> Result<()> {
        let hdr = (buf.len() as u32).to_le_bytes();
        self.stream.write_all(&hdr)?;
        self.stream.write_all(buf)?;
        Ok(())
    }

    fn recv_bytes_into(&mut self, buf: &mut Vec<u8>) -> Result<()> {
        let mut hdr = [0u8; 4];
        self.read_exact_deadlined(&mut hdr)?;
        let len = u32::from_le_bytes(hdr) as usize;
        if len > 1 << 30 {
            return Err(Error::Transport(format!("oversized frame: {len} bytes")));
        }
        // Reuse the caller's buffer: its capacity is retained across
        // rounds, so steady-state frames read with no allocation (and no
        // redundant zeroing — `read_exact` overwrites every byte of
        // `[0, len)`, so the resize only zero-fills genuinely new tail
        // capacity).
        buf.resize(len, 0);
        self.read_exact_deadlined(buf)?;
        Ok(())
    }

    fn recv_bytes_into_by(
        &mut self,
        buf: &mut Vec<u8>,
        timeout: Duration,
    ) -> Result<RecvStatus> {
        // Peek one byte under the deadline: a timeout before the first
        // byte leaves the stream's framing intact (nothing consumed), so
        // the straggler's frame can still be drained next round. Once
        // the first byte is visible the frame is in flight and the
        // normal (blocking under the steady-state policy) read finishes
        // it.
        self.stream
            .set_read_timeout(Some(timeout.max(Duration::from_millis(1))))
            .map_err(Error::Io)?;
        let mut first = [0u8; 1];
        let peeked = self.stream.peek(&mut first);
        self.stream.set_read_timeout(self.read_timeout).map_err(Error::Io)?;
        match peeked {
            Ok(0) => Err(Error::Transport("peer hung up (recv)".into())),
            Ok(_) => self.recv_bytes_into(buf).map(|_| RecvStatus::Frame),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                Ok(RecvStatus::TimedOut)
            }
            Err(e) => Err(Error::Io(e)),
        }
    }
}

/// Fusion-side TCP listener: bind first (so the address is known), then
/// block in [`TcpFusionListener::accept_all`] — bounded by the accept
/// timeout — while workers connect.
pub struct TcpFusionListener {
    listener: TcpListener,
    n_workers: usize,
    timeouts: TcpTimeouts,
}

impl TcpFusionListener {
    /// Bind on `addr` ("127.0.0.1:0" for an ephemeral port) with default
    /// timeouts.
    pub fn bind(addr: &str, n_workers: usize) -> Result<Self> {
        Self::bind_with(addr, n_workers, TcpTimeouts::default())
    }

    /// Bind with an explicit timeout policy.
    pub fn bind_with(addr: &str, n_workers: usize, timeouts: TcpTimeouts) -> Result<Self> {
        Ok(TcpFusionListener {
            listener: TcpListener::bind(addr)?,
            n_workers,
            timeouts,
        })
    }

    /// The bound address workers should connect to.
    pub fn addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept all workers; returns endpoints **in worker-id order**.
    /// Workers identify themselves with the 5-byte versioned hello; a
    /// version mismatch, duplicate id, or expired accept timeout is an
    /// [`Error::Transport`].
    pub fn accept_all(self, meter: Arc<ByteMeter>) -> Result<Vec<Endpoint>> {
        let read = self.timeouts.read;
        let mut eps = Vec::with_capacity(self.n_workers);
        for stream in self.accept_streams()? {
            eps.push(Endpoint::new(
                Box::new(TcpChannel::new(stream, read)?),
                meter.clone(),
                Side::Fusion,
            ));
        }
        Ok(eps)
    }

    /// Accept all workers onto **multiplexed** (protocol-v4 serve mode)
    /// links, in worker-id order. Each returned [`MuxFusionLink`] carries
    /// interleaved session-tagged frames for any number of concurrent
    /// sessions over the one physical connection; open per-session
    /// [`Endpoint`]s with [`MuxFusionLink::open_session`].
    pub fn accept_all_mux(self) -> Result<Vec<MuxFusionLink>> {
        let mut links = Vec::with_capacity(self.n_workers);
        for stream in self.accept_streams()? {
            links.push(MuxFusionLink::new(stream)?);
        }
        Ok(links)
    }

    /// Accept **one** serve-mode worker connection without consuming the
    /// listener: block for at most `timeout`, returning `Ok(None)` if no
    /// peer arrived (the caller's poll loop checks its shutdown flag and
    /// calls again). This is the daemon's persistent fleet acceptor —
    /// unlike [`accept_all_mux`](TcpFusionListener::accept_all_mux) it
    /// keeps the listener alive so workers that die can reconnect with
    /// the same versioned hello and be re-admitted mid-flight.
    pub fn accept_one_mux(
        &self,
        timeout: Duration,
    ) -> Result<Option<(u32, MuxFusionLink)>> {
        let deadline = Instant::now() + timeout;
        self.listener.set_nonblocking(true).map_err(Error::Io)?;
        let mut stream = loop {
            match self.listener.accept() {
                Ok((stream, _)) => break stream,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Ok(None);
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => return Err(Error::Io(e)),
            }
        };
        stream.set_nonblocking(false).map_err(Error::Io)?;
        let id = read_hello(&mut stream, self.timeouts.accept)?;
        if id as usize >= self.n_workers {
            return Err(Error::Transport(format!("bad worker hello id {id}")));
        }
        Ok(Some((id, MuxFusionLink::new(stream)?)))
    }

    /// The shared accept/hello loop: raw streams in worker-id order.
    fn accept_streams(self) -> Result<Vec<TcpStream>> {
        let deadline = Instant::now() + self.timeouts.accept;
        self.listener.set_nonblocking(true).map_err(Error::Io)?;
        let mut slots: Vec<Option<TcpStream>> = (0..self.n_workers).map(|_| None).collect();
        let mut accepted = 0usize;
        while accepted < self.n_workers {
            let mut stream = match self.listener.accept() {
                Ok((stream, _)) => stream,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(Error::Transport(format!(
                            "tcp accept timed out after {:?} ({accepted}/{} workers \
                             connected)",
                            self.timeouts.accept, self.n_workers
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(2));
                    continue;
                }
                Err(e) => return Err(Error::Io(e)),
            };
            stream.set_nonblocking(false).map_err(Error::Io)?;
            // The hello read is bounded by whatever accept budget remains.
            let remaining = deadline
                .saturating_duration_since(Instant::now())
                .max(Duration::from_millis(1));
            stream.set_read_timeout(Some(remaining)).map_err(Error::Io)?;
            // Read the version byte *before* the id so a pre-versioning
            // peer (whose 4-byte hello starts with its worker-id byte) is
            // rejected from its first byte instead of stalling the accept
            // loop waiting for bytes it will never send.
            let mut version = [0u8; 1];
            stream.read_exact(&mut version).map_err(|e| {
                Error::Transport(format!("tcp hello read failed: {e}"))
            })?;
            if version[0] != PROTOCOL_VERSION {
                return Err(Error::Transport(format!(
                    "protocol version mismatch: peer speaks v{}, this build \
                     speaks v{PROTOCOL_VERSION} — upgrade the older side",
                    version[0]
                )));
            }
            let mut id_bytes = [0u8; 4];
            stream.read_exact(&mut id_bytes).map_err(|e| {
                Error::Transport(format!("tcp hello read failed: {e}"))
            })?;
            let id = u32::from_le_bytes(id_bytes) as usize;
            if id >= self.n_workers || slots[id].is_some() {
                return Err(Error::Transport(format!("bad worker hello id {id}")));
            }
            // Clear the hello-read deadline; steady-state read timeouts
            // are (re)applied by the channel built around the stream.
            stream.set_read_timeout(None).map_err(Error::Io)?;
            slots[id] = Some(stream);
            accepted += 1;
        }
        Ok(slots.into_iter().map(|s| s.unwrap()).collect())
    }
}

/// Read the 5-byte versioned hello `[PROTOCOL_VERSION, worker_id u32 LE]`
/// from a freshly-accepted stream, bounded by `budget`; clears the
/// stream's read deadline afterwards.
fn read_hello(stream: &mut TcpStream, budget: Duration) -> Result<u32> {
    stream
        .set_read_timeout(Some(budget.max(Duration::from_millis(1))))
        .map_err(Error::Io)?;
    let mut version = [0u8; 1];
    stream
        .read_exact(&mut version)
        .map_err(|e| Error::Transport(format!("tcp hello read failed: {e}")))?;
    if version[0] != PROTOCOL_VERSION {
        return Err(Error::Transport(format!(
            "protocol version mismatch: peer speaks v{}, this build speaks \
             v{PROTOCOL_VERSION} — upgrade the older side",
            version[0]
        )));
    }
    let mut id_bytes = [0u8; 4];
    stream
        .read_exact(&mut id_bytes)
        .map_err(|e| Error::Transport(format!("tcp hello read failed: {e}")))?;
    stream.set_read_timeout(None).map_err(Error::Io)?;
    Ok(u32::from_le_bytes(id_bytes))
}

/// Worker side: connect to the fusion listener (default timeouts) and
/// identify as `worker_id` with the versioned hello.
pub fn tcp_connect(
    addr: std::net::SocketAddr,
    worker_id: u32,
    meter: Arc<ByteMeter>,
) -> Result<Endpoint> {
    tcp_connect_with(addr, worker_id, meter, TcpTimeouts::default())
}

/// Worker side with an explicit timeout policy.
pub fn tcp_connect_with(
    addr: std::net::SocketAddr,
    worker_id: u32,
    meter: Arc<ByteMeter>,
    timeouts: TcpTimeouts,
) -> Result<Endpoint> {
    let mut stream = TcpStream::connect_timeout(&addr, timeouts.connect).map_err(|e| {
        if matches!(
            e.kind(),
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
        ) {
            Error::Transport(format!(
                "tcp connect to {addr} timed out after {:?}",
                timeouts.connect
            ))
        } else {
            Error::Transport(format!("tcp connect to {addr} failed: {e}"))
        }
    })?;
    let mut hello = [0u8; 5];
    hello[0] = PROTOCOL_VERSION;
    hello[1..5].copy_from_slice(&worker_id.to_le_bytes());
    stream.write_all(&hello)?;
    Ok(Endpoint::new(
        Box::new(TcpChannel::new(stream, timeouts.read)?),
        meter,
        Side::Worker,
    ))
}

// ---------- multiplexed (serve-mode) TCP transport ----------
//
// Protocol v4: on a multiplexed link every frame is wrapped as
// `[len: u32 LE][session: u32 LE][frame bytes]`, where `len` counts the
// session id plus the frame. The wrapper lives *below* the metered
// [`Endpoint`] layer — an endpoint opened for one session sees (and
// meters) exactly the same frame bytes a standalone link would carry, so
// a served job's communication accounting is bit-identical to a
// standalone run of the same config.

/// Session-id routing table of one multiplexed link: the demux reader
/// thread delivers each inbound frame to its session's queue. `closed`
/// is flipped (under the same lock) when the reader exits, so a session
/// opened against an already-dead link fails fast instead of parking on
/// a queue nobody will ever feed.
struct MuxRouteTable {
    routes: std::collections::HashMap<u32, Sender<Vec<u8>>>,
    closed: bool,
}

type MuxRoutes = Arc<Mutex<MuxRouteTable>>;

/// Largest accepted mux frame (session id + payload), mirroring the
/// standalone [`TcpChannel`] bound.
const MAX_MUX_FRAME: usize = (1 << 30) + 4;

/// Fusion side of one multiplexed worker connection (protocol v4). One
/// physical TCP stream carries interleaved frames for many sessions: a
/// background reader thread demultiplexes inbound frames by session id,
/// and every per-session [`Endpoint`] from
/// [`open_session`](MuxFusionLink::open_session) shares the write half
/// behind a mutex (each frame is written atomically).
///
/// Dropping the link shuts the stream down — the worker's demux loop sees
/// EOF and exits cleanly — and joins the reader thread.
pub struct MuxFusionLink {
    writer: Arc<Mutex<TcpStream>>,
    routes: MuxRoutes,
    reader: Option<std::thread::JoinHandle<()>>,
}

impl MuxFusionLink {
    fn new(stream: TcpStream) -> Result<MuxFusionLink> {
        stream.set_nodelay(true).map_err(Error::Io)?;
        stream.set_read_timeout(None).map_err(Error::Io)?;
        let mut read_half = stream.try_clone().map_err(Error::Io)?;
        let routes: MuxRoutes = Arc::new(Mutex::new(MuxRouteTable {
            routes: std::collections::HashMap::new(),
            closed: false,
        }));
        let reader_routes = routes.clone();
        let reader = std::thread::Builder::new()
            .name("mpamp-mux-demux".into())
            .spawn(move || {
                demux_loop(&mut read_half, &reader_routes);
                // Link gone (EOF, error, or shutdown): drop every route
                // sender so blocked session receivers observe the close
                // instead of waiting forever, and mark the table closed
                // so later `open_session` calls fail fast too.
                let mut tbl = reader_routes.lock().expect("mux routes poisoned");
                tbl.routes.clear();
                tbl.closed = true;
            })
            .map_err(|e| Error::Transport(format!("spawn mux reader: {e}")))?;
        Ok(MuxFusionLink {
            writer: Arc::new(Mutex::new(stream)),
            routes,
            reader: Some(reader),
        })
    }

    /// Open the fusion-side [`Endpoint`] of `session` on this link.
    /// Frames it sends are tagged with the session id on the wire; frames
    /// tagged for it are queued by the demux thread. `meter` should be the
    /// session's own [`ByteMeter`] — metering happens above the mux
    /// wrapper, so the counted bytes match a standalone link exactly.
    pub fn open_session(&self, session: u32, meter: Arc<ByteMeter>) -> Endpoint {
        Endpoint::new(self.open_session_channel(session), meter, Side::Fusion)
    }

    /// The raw per-session [`Channel`] behind
    /// [`open_session`](MuxFusionLink::open_session) — the daemon's
    /// reconnect-following slot channel re-opens one of these on the
    /// replacement link after a worker comes back, swapping it in under
    /// the same session [`Endpoint`] (and meter) the job already holds.
    pub(crate) fn open_session_channel(&self, session: u32) -> Box<dyn Channel> {
        let (tx, rx) = channel();
        {
            let mut tbl = self.routes.lock().expect("mux routes poisoned");
            if !tbl.closed {
                tbl.routes.insert(session, tx);
            }
            // Closed link: `tx` drops here and the session's first recv
            // reports the dead link instead of blocking forever.
        }
        Box::new(MuxChannel {
            session,
            writer: self.writer.clone(),
            rx,
            routes: self.routes.clone(),
            scratch: Vec::new(),
        })
    }

    /// Has the demux reader exited (worker hung up or the stream was
    /// shut down)? Once closed a link never recovers — the daemon swaps
    /// in a fresh link when the worker reconnects.
    pub fn is_closed(&self) -> bool {
        self.routes.lock().map(|t| t.closed).unwrap_or(true)
    }
}

impl Drop for MuxFusionLink {
    fn drop(&mut self) {
        if let Ok(w) = self.writer.lock() {
            let _ = w.shutdown(std::net::Shutdown::Both);
        }
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

/// Inbound half of a multiplexed link: route each `[len][session][frame]`
/// to the session's queue. Frames for unknown sessions (already finished
/// or cancelled) are dropped. Returns when the stream closes or any frame
/// is malformed.
fn demux_loop(stream: &mut TcpStream, routes: &MuxRoutes) {
    let mut hdr = [0u8; 4];
    loop {
        if stream.read_exact(&mut hdr).is_err() {
            return;
        }
        let len = u32::from_le_bytes(hdr) as usize;
        if !(4..=MAX_MUX_FRAME).contains(&len) {
            return;
        }
        let mut sid = [0u8; 4];
        if stream.read_exact(&mut sid).is_err() {
            return;
        }
        let session = u32::from_le_bytes(sid);
        let mut frame = vec![0u8; len - 4];
        if stream.read_exact(&mut frame).is_err() {
            return;
        }
        let tx =
            routes.lock().expect("mux routes poisoned").routes.get(&session).cloned();
        if let Some(tx) = tx {
            let _ = tx.send(frame);
        }
    }
}

/// One session's fusion-side view of a multiplexed link.
struct MuxChannel {
    session: u32,
    writer: Arc<Mutex<TcpStream>>,
    rx: Receiver<Vec<u8>>,
    routes: MuxRoutes,
    /// Reused assembly buffer so each send is one `write_all` (atomic
    /// under the writer lock, one packet with nodelay).
    scratch: Vec<u8>,
}

impl Channel for MuxChannel {
    fn send_bytes(&mut self, buf: &[u8]) -> Result<()> {
        self.scratch.clear();
        self.scratch.extend_from_slice(&((buf.len() + 4) as u32).to_le_bytes());
        self.scratch.extend_from_slice(&self.session.to_le_bytes());
        self.scratch.extend_from_slice(buf);
        let mut w = self
            .writer
            .lock()
            .map_err(|_| Error::Transport("mux writer poisoned".into()))?;
        w.write_all(&self.scratch)?;
        Ok(())
    }

    fn recv_bytes_into(&mut self, buf: &mut Vec<u8>) -> Result<()> {
        let frame = self.rx.recv().map_err(|_| {
            Error::Transport(format!(
                "mux link closed while session {} awaited a frame",
                self.session
            ))
        })?;
        *buf = frame;
        Ok(())
    }

    fn recv_bytes_into_by(
        &mut self,
        buf: &mut Vec<u8>,
        timeout: Duration,
    ) -> Result<RecvStatus> {
        use std::sync::mpsc::RecvTimeoutError;
        match self.rx.recv_timeout(timeout) {
            Ok(frame) => {
                *buf = frame;
                Ok(RecvStatus::Frame)
            }
            Err(RecvTimeoutError::Timeout) => Ok(RecvStatus::TimedOut),
            Err(RecvTimeoutError::Disconnected) => Err(Error::Transport(format!(
                "mux link closed while session {} awaited a frame",
                self.session
            ))),
        }
    }
}

impl Drop for MuxChannel {
    fn drop(&mut self) {
        if let Ok(mut tbl) = self.routes.lock() {
            tbl.routes.remove(&self.session);
        }
    }
}

/// Worker side of one multiplexed connection. The worker's serve loop is
/// the single reader: [`recv_session_frame`](MuxWorkerLink::recv_session_frame)
/// yields `(session, frame)` pairs in arrival order, and replies go out
/// through per-session send-only [`Endpoint`]s from
/// [`session_endpoint`](MuxWorkerLink::session_endpoint).
pub struct MuxWorkerLink {
    reader: TcpStream,
    writer: Arc<Mutex<TcpStream>>,
}

/// Worker side: connect to a serve-mode fusion listener and identify as
/// `worker_id` with the standard versioned hello.
pub fn tcp_connect_mux(
    addr: std::net::SocketAddr,
    worker_id: u32,
    timeouts: TcpTimeouts,
) -> Result<MuxWorkerLink> {
    let mut stream = TcpStream::connect_timeout(&addr, timeouts.connect).map_err(|e| {
        Error::Transport(format!("tcp connect to {addr} failed: {e}"))
    })?;
    stream.set_nodelay(true).map_err(Error::Io)?;
    let mut hello = [0u8; 5];
    hello[0] = PROTOCOL_VERSION;
    hello[1..5].copy_from_slice(&worker_id.to_le_bytes());
    stream.write_all(&hello)?;
    let writer = stream.try_clone().map_err(Error::Io)?;
    Ok(MuxWorkerLink { reader: stream, writer: Arc::new(Mutex::new(writer)) })
}

impl MuxWorkerLink {
    /// Block for the next session-tagged frame, writing its payload into
    /// `buf` and returning the session id. `Ok(None)` means the fusion
    /// side closed the link — the fleet-wide shutdown signal, not an
    /// error.
    pub fn recv_session_frame(&mut self, buf: &mut Vec<u8>) -> Result<Option<u32>> {
        let mut hdr = [0u8; 4];
        if let Err(e) = self.reader.read_exact(&mut hdr) {
            return if e.kind() == std::io::ErrorKind::UnexpectedEof {
                Ok(None)
            } else {
                Err(Error::Io(e))
            };
        }
        let len = u32::from_le_bytes(hdr) as usize;
        if !(4..=MAX_MUX_FRAME).contains(&len) {
            return Err(Error::Transport(format!("malformed mux frame length {len}")));
        }
        let mut sid = [0u8; 4];
        self.reader.read_exact(&mut sid).map_err(Error::Io)?;
        buf.resize(len - 4, 0);
        self.reader.read_exact(buf).map_err(Error::Io)?;
        Ok(Some(u32::from_le_bytes(sid)))
    }

    /// Tear the physical connection down in both directions — the
    /// deterministic "kill connection at round t" fault: the fusion-side
    /// demux sees EOF and marks the worker dead, and this side's next
    /// read fails, sending the worker into its reconnect loop.
    pub fn kill(&self) -> Result<()> {
        self.reader
            .shutdown(std::net::Shutdown::Both)
            .map_err(|e| Error::Transport(format!("connection killed: {e}")))
    }

    /// Per-session reply endpoint (send-only — inbound frames arrive via
    /// [`recv_session_frame`](MuxWorkerLink::recv_session_frame)). `meter`
    /// should be the session's own [`ByteMeter`], so uplink accounting
    /// lands on the job it belongs to.
    pub fn session_endpoint(&self, session: u32, meter: Arc<ByteMeter>) -> Endpoint {
        Endpoint::new(
            Box::new(MuxWorkerChannel {
                session,
                writer: self.writer.clone(),
                scratch: Vec::new(),
            }),
            meter,
            Side::Worker,
        )
    }
}

/// One session's worker-side reply channel (send-only).
struct MuxWorkerChannel {
    session: u32,
    writer: Arc<Mutex<TcpStream>>,
    scratch: Vec<u8>,
}

impl Channel for MuxWorkerChannel {
    fn send_bytes(&mut self, buf: &[u8]) -> Result<()> {
        self.scratch.clear();
        self.scratch.extend_from_slice(&((buf.len() + 4) as u32).to_le_bytes());
        self.scratch.extend_from_slice(&self.session.to_le_bytes());
        self.scratch.extend_from_slice(buf);
        let mut w = self
            .writer
            .lock()
            .map_err(|_| Error::Transport("mux writer poisoned".into()))?;
        w.write_all(&self.scratch)?;
        Ok(())
    }

    fn recv_bytes_into(&mut self, _buf: &mut Vec<u8>) -> Result<()> {
        Err(Error::Transport(format!(
            "mux worker channel for session {} is send-only (inbound frames \
             arrive via the link's demux loop)",
            self.session
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::message::Message;

    #[test]
    fn inproc_roundtrip_and_metering() {
        let meter = Arc::new(ByteMeter::new());
        let (mut fusion, mut worker) = inproc_pair(meter.clone());
        let m1 = Message::StepCmd { t: 0, coefs: vec![0.0], x: vec![1.0; 8] };
        fusion.send(&m1).unwrap();
        assert_eq!(worker.recv().unwrap(), m1);
        let m2 = Message::ZNorm { t: 0, worker: 3, z_norm2: vec![2.5] };
        worker.send(&m2).unwrap();
        assert_eq!(fusion.recv().unwrap(), m2);
        assert_eq!(meter.downlink_bits(), 8 * m1.encode().len() as u64);
        assert_eq!(meter.uplink_bits(), 8 * m2.encode().len() as u64);
    }

    #[test]
    fn send_encoded_and_frame_paths_roundtrip_with_metering() {
        use crate::coordinator::message::{decode_znorm, encode_znorm};
        let meter = Arc::new(ByteMeter::new());
        let (mut fusion, mut worker) = inproc_pair(meter.clone());
        // Encode-once: the same pre-encoded frame can be sent repeatedly.
        let m = Message::StepCmd { t: 1, coefs: vec![0.5], x: vec![2.0; 6] };
        let frame = m.encode();
        fusion.send_encoded(&frame).unwrap();
        fusion.send_encoded(&frame).unwrap();
        assert_eq!(worker.recv().unwrap(), m);
        assert_eq!(worker.recv().unwrap(), m);
        assert_eq!(meter.downlink_bits(), 2 * 8 * frame.len() as u64);
        // send_frame builds the reply in place; recv_frame borrows the
        // raw bytes for the borrowed decoders.
        worker
            .send_frame(|buf| {
                encode_znorm(buf, 1, 0, &[2.5]);
                Ok(())
            })
            .unwrap();
        let raw = fusion.recv_frame().unwrap();
        let view = decode_znorm(raw).unwrap();
        assert_eq!((view.t, view.worker), (1, 0));
        assert_eq!(view.z_norm2.iter().collect::<Vec<_>>(), vec![2.5]);
        assert!(meter.uplink_bits() > 0);
    }

    #[test]
    fn inproc_hangup_reported() {
        let meter = Arc::new(ByteMeter::new());
        let (fusion, mut worker) = inproc_pair(meter);
        drop(fusion);
        assert!(worker.recv().is_err());
        assert!(worker.send(&Message::Done).is_err());
    }

    #[test]
    fn tcp_roundtrip_multi_worker() {
        let meter = Arc::new(ByteMeter::new());
        let n = 3usize;
        let listener = TcpFusionListener::bind("127.0.0.1:0", n).unwrap();
        let addr = listener.addr().unwrap();
        // Workers connect from threads while the main thread accepts.
        let worker_handles: Vec<_> = (0..n as u32)
            .map(|id| {
                let meter = meter.clone();
                std::thread::spawn(move || {
                    let mut ep = tcp_connect(addr, id, meter).unwrap();
                    // Echo protocol: recv one StepCmd, reply with ZNorm(id).
                    let msg = ep.recv().unwrap();
                    match msg {
                        Message::StepCmd { t, .. } => {
                            ep.send(&Message::ZNorm {
                                t,
                                worker: id,
                                z_norm2: vec![id as f64 + 0.5],
                            })
                            .unwrap();
                        }
                        other => panic!("unexpected {other:?}"),
                    }
                })
            })
            .collect();
        let mut fusion_eps = listener.accept_all(meter.clone()).unwrap();
        for (i, ep) in fusion_eps.iter_mut().enumerate() {
            ep.send(&Message::StepCmd { t: 9, coefs: vec![0.5], x: vec![1.0; 4] })
                .unwrap();
            let reply = ep.recv().unwrap();
            assert_eq!(
                reply,
                Message::ZNorm { t: 9, worker: i as u32, z_norm2: vec![i as f64 + 0.5] }
            );
        }
        for h in worker_handles {
            h.join().unwrap();
        }
        assert!(meter.uplink_bits() > 0 && meter.downlink_bits() > 0);
    }

    #[test]
    fn accept_times_out_instead_of_hanging() {
        let timeouts = TcpTimeouts {
            accept: Duration::from_millis(60),
            ..TcpTimeouts::default()
        };
        let listener = TcpFusionListener::bind_with("127.0.0.1:0", 1, timeouts).unwrap();
        let meter = Arc::new(ByteMeter::new());
        let t0 = Instant::now();
        let err = listener.accept_all(meter).unwrap_err();
        assert!(
            matches!(err, Error::Transport(_)),
            "expected Transport error, got {err:?}"
        );
        assert!(err.to_string().contains("accept timed out"), "{err}");
        assert!(t0.elapsed() < Duration::from_secs(5), "accept hung");
    }

    #[test]
    fn read_timeout_surfaces_as_transport_error() {
        let timeouts = TcpTimeouts {
            read: Some(Duration::from_millis(60)),
            ..TcpTimeouts::default()
        };
        let listener = TcpFusionListener::bind_with("127.0.0.1:0", 1, timeouts).unwrap();
        let addr = listener.addr().unwrap();
        let meter = Arc::new(ByteMeter::new());
        let m2 = meter.clone();
        let worker = std::thread::spawn(move || {
            // Connect, say hello, then stay silent until dropped.
            let ep = tcp_connect_with(addr, 0, m2, timeouts).unwrap();
            std::thread::sleep(Duration::from_millis(400));
            drop(ep);
        });
        let mut fusion_eps = listener.accept_all(meter).unwrap();
        let err = fusion_eps[0].recv().unwrap_err();
        assert!(
            matches!(err, Error::Transport(_)),
            "expected Transport error, got {err:?}"
        );
        assert!(err.to_string().contains("timed out"), "{err}");
        worker.join().unwrap();
    }

    #[test]
    fn version_mismatch_rejected_at_hello() {
        let listener = TcpFusionListener::bind("127.0.0.1:0", 1).unwrap();
        let addr = listener.addr().unwrap();
        let rogue = std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            // A peer with the wrong version byte, then worker id 0.
            let mut hello = [0u8; 5];
            hello[0] = 99;
            stream.write_all(&hello).unwrap();
            // Hold the socket open until the listener has decided.
            std::thread::sleep(Duration::from_millis(200));
        });
        let meter = Arc::new(ByteMeter::new());
        let err = listener.accept_all(meter).unwrap_err();
        assert!(err.to_string().contains("version mismatch"), "{err}");
        rogue.join().unwrap();
    }

    #[test]
    fn pre_versioning_peer_fails_fast_not_on_timeout() {
        // A v1-era peer sends only a 4-byte hello [worker_id u32 LE] and
        // then waits. The version byte is read first, so a worker-id-0
        // hello (first byte 0 ≠ PROTOCOL_VERSION) is rejected from its
        // first byte — well before the accept budget would expire.
        let timeouts =
            TcpTimeouts { accept: Duration::from_secs(30), ..TcpTimeouts::default() };
        let listener = TcpFusionListener::bind_with("127.0.0.1:0", 1, timeouts).unwrap();
        let addr = listener.addr().unwrap();
        let rogue = std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.write_all(&0u32.to_le_bytes()).unwrap(); // v1 hello, id 0
            std::thread::sleep(Duration::from_millis(300));
        });
        let meter = Arc::new(ByteMeter::new());
        let t0 = Instant::now();
        let err = listener.accept_all(meter).unwrap_err();
        assert!(err.to_string().contains("version mismatch"), "{err}");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "v1 peer stalled the accept loop"
        );
        rogue.join().unwrap();
    }

    #[test]
    fn mux_link_interleaves_sessions_with_standalone_metering() {
        use crate::coordinator::message::{decode_step_cmd, decode_znorm, encode_znorm};
        let listener = TcpFusionListener::bind("127.0.0.1:0", 1).unwrap();
        let addr = listener.addr().unwrap();
        let worker_meter_a = Arc::new(ByteMeter::new());
        let worker_meter_b = Arc::new(ByteMeter::new());
        let wm_a = worker_meter_a.clone();
        let wm_b = worker_meter_b.clone();
        let worker = std::thread::spawn(move || {
            let mut link = tcp_connect_mux(addr, 0, TcpTimeouts::default()).unwrap();
            let mut ep_a = link.session_endpoint(7, wm_a);
            let mut ep_b = link.session_endpoint(9, wm_b);
            let mut frame = Vec::new();
            // Serve frames for both sessions in arrival order until EOF.
            while let Some(session) = link.recv_session_frame(&mut frame).unwrap() {
                let cmd = decode_step_cmd(&frame).unwrap();
                let ep = match session {
                    7 => &mut ep_a,
                    9 => &mut ep_b,
                    other => panic!("unexpected session {other}"),
                };
                let norm = vec![session as f64 + cmd.t as f64 / 10.0];
                ep.send_frame(|buf| {
                    encode_znorm(buf, cmd.t, 0, &norm);
                    Ok(())
                })
                .unwrap();
            }
        });
        let links = listener.accept_all_mux().unwrap();
        let meter_a = Arc::new(ByteMeter::new());
        let meter_b = Arc::new(ByteMeter::new());
        let mut sess_a = links[0].open_session(7, meter_a.clone());
        let mut sess_b = links[0].open_session(9, meter_b.clone());
        // Interleave rounds from both sessions over the one stream.
        for t in 0..3u32 {
            let cmd_a = Message::StepCmd { t, coefs: vec![0.5], x: vec![1.0; 4] };
            let cmd_b = Message::StepCmd { t, coefs: vec![0.25], x: vec![2.0; 6] };
            sess_a.send(&cmd_a).unwrap();
            sess_b.send(&cmd_b).unwrap();
            let view_b = decode_znorm(sess_b.recv_frame().unwrap()).unwrap();
            assert_eq!(view_b.t, t);
            assert_eq!(
                view_b.z_norm2.iter().collect::<Vec<_>>(),
                vec![9.0 + t as f64 / 10.0]
            );
            let view_a = decode_znorm(sess_a.recv_frame().unwrap()).unwrap();
            assert_eq!(view_a.t, t);
            assert_eq!(
                view_a.z_norm2.iter().collect::<Vec<_>>(),
                vec![7.0 + t as f64 / 10.0]
            );
        }
        // Metering sits above the mux prefix: each session's downlink
        // counts exactly the payload bytes a standalone link would carry.
        let want_a: u64 = (0..3)
            .map(|t| {
                8 * Message::StepCmd { t, coefs: vec![0.5], x: vec![1.0; 4] }
                    .encode()
                    .len() as u64
            })
            .sum();
        assert_eq!(meter_a.downlink_bits(), want_a);
        assert!(meter_b.downlink_bits() > meter_a.downlink_bits());
        assert!(worker_meter_a.uplink_bits() > 0);
        assert!(worker_meter_b.uplink_bits() > 0);
        // Dropping the fusion links is the fleet shutdown signal: the
        // worker loop sees EOF and joins cleanly.
        drop(sess_a);
        drop(sess_b);
        drop(links);
        worker.join().unwrap();
    }

    #[test]
    fn mux_recv_after_link_drop_reports_closed_session() {
        let listener = TcpFusionListener::bind("127.0.0.1:0", 1).unwrap();
        let addr = listener.addr().unwrap();
        let worker = std::thread::spawn(move || {
            let link = tcp_connect_mux(addr, 0, TcpTimeouts::default()).unwrap();
            // Hang up immediately without serving anything.
            drop(link);
        });
        let links = listener.accept_all_mux().unwrap();
        worker.join().unwrap();
        let meter = Arc::new(ByteMeter::new());
        let mut sess = links[0].open_session(3, meter);
        let err = sess.recv().unwrap_err();
        assert!(
            matches!(err, Error::Transport(_)),
            "expected Transport error, got {err:?}"
        );
        assert!(err.to_string().contains("session 3"), "{err}");
    }

    #[test]
    fn connect_to_dead_port_errors_fast() {
        // Bind a listener to learn a free port, then drop it so nothing is
        // listening there; connect must error (refused), not hang.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let meter = Arc::new(ByteMeter::new());
        let t0 = Instant::now();
        let err = tcp_connect(addr, 0, meter).unwrap_err();
        assert!(
            matches!(err, Error::Transport(_)),
            "expected Transport error, got {err:?}"
        );
        assert!(t0.elapsed() < Duration::from_secs(11), "connect hung");
    }
}
