//! Session orchestration: generate (or accept) a signal batch, shard it
//! across `P` worker threads, and drive the fusion protocol — either one
//! iteration at a time via [`Session::step`] (observable, stoppable) or to
//! completion via [`Session::run`] (a thin loop over `step`), producing a
//! [`RunReport`] with per-iteration quality and exact communication costs.
//!
//! Sessions carry `B ≥ 1` signal instances end-to-end (`cfg.batch`): all
//! `B` signals share one sensing matrix, every protocol round moves the
//! whole batch in one message per link, and the engine's blocked kernels
//! amortize each pass over `A` across the batch. `B = 1` reproduces the
//! single-signal protocol bit-for-bit.
//!
//! Construct sessions with [`SessionBuilder`](crate::SessionBuilder); the
//! `new`/`with_instance`/`with_batch` constructors remain for callers that
//! already hold a validated [`RunConfig`].

use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::alloc::schedule::{allocator_from_config, RateAllocator};
use crate::config::{EngineKind, Partitioning, RunConfig, ScheduleKind, TransportKind};
use crate::coordinator::fault::{FaultChannel, FaultPlan};
use crate::coordinator::fusion::ProtocolState;
use crate::coordinator::message::Message;
use crate::coordinator::scenario::{Column, Row, Scenario};
use crate::coordinator::transport::{inproc_pair, tcp_connect, Endpoint, TcpFusionListener};
use crate::coordinator::worker::{run_scenario_worker_traced, WorkerParams};
use crate::engine::{ComputeEngine, RustEngine};
use crate::error::{Error, Result};
use crate::metrics::{ByteMeter, Csv, IterRecord, Json};
use crate::observe::{NullObserver, RunObserver, StopSet};
use crate::rd::RdCache;
use crate::se::StateEvolution;
use crate::signal::{Batch, Instance, ProblemDims};
use crate::telemetry::{metrics as tel_metrics, Telemetry};
use crate::util::rng::Rng;

/// Result of one MP-AMP run.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Per-iteration records (per-signal quantities as batch means).
    pub iters: Vec<IterRecord>,
    /// Final estimates, one per signal in the batch.
    pub final_xs: Vec<Vec<f32>>,
    /// Final per-signal SDR in dB (same order as `final_xs`).
    pub sdr_db_per_signal: Vec<f64>,
    /// Number of signal instances processed end-to-end.
    pub batch: usize,
    /// Problem size (N, M, P).
    pub dims: (usize, usize, usize),
    /// Schedule name.
    pub schedule: String,
    /// Engine name.
    pub engine: String,
    /// Partitioning scenario ("row" or "column").
    pub partitioning: String,
    /// Total raw bits that crossed the transport, uplink (incl. headers).
    pub transport_uplink_bits: u64,
    /// Total raw bits that crossed the transport, downlink (incl. headers).
    pub transport_downlink_bits: u64,
    /// Wall-clock for the whole session.
    pub wall_s: f64,
    /// Why the run stopped before `cfg.iters`, if a [`StopRule`] fired.
    ///
    /// [`StopRule`]: crate::observe::StopRule
    pub stopped_early: Option<String>,
}

impl RunReport {
    /// Final estimate of the batch's first signal (the whole-report view
    /// for `B = 1` runs; batched callers index [`RunReport::final_xs`]).
    pub fn final_x(&self) -> &[f32] {
        &self.final_xs[0]
    }

    /// Final-iteration SDR in dB (batch mean).
    pub fn final_sdr_db(&self) -> f64 {
        self.iters.last().map(|r| r.sdr_db).unwrap_or(f64::NAN)
    }

    /// Aggregate throughput: signal instances recovered per wall-clock
    /// second. The headline number batching moves.
    pub fn signals_per_s(&self) -> f64 {
        self.batch as f64 / self.wall_s.max(1e-12)
    }

    /// The paper's headline metric: total uplink bits per element of
    /// the uplinked message (sum over iterations of the measured
    /// per-element wire rate; batched elements included in the base).
    pub fn total_uplink_bits_per_element(&self) -> f64 {
        self.iters.iter().map(|r| r.rate_wire).sum()
    }

    /// Analytic (allocated) total rate — the DP/BT budget actually used.
    pub fn total_alloc_bits_per_element(&self) -> f64 {
        self.iters.iter().map(|r| r.rate_alloc).sum()
    }

    /// Total uplink *payload* bytes across all workers, signals, and
    /// iterations — the coded message bits only (the paper's cost metric).
    /// This is the number to compare across partitionings:
    /// `transport_uplink_bits` additionally counts protocol headers and,
    /// in column mode, the eval-only estimate shards that ride the wire
    /// for reporting.
    pub fn uplink_payload_bytes(&self) -> u64 {
        let msg_len =
            if self.partitioning == "column" { self.dims.1 } else { self.dims.0 };
        let bits = self.total_uplink_bits_per_element()
            * (self.dims.2 * msg_len * self.batch.max(1)) as f64;
        (bits / 8.0).round() as u64
    }

    /// Communication saving vs 32-bit floats (%).
    pub fn savings_vs_float_pct(&self) -> f64 {
        let raw = 32.0 * self.iters.len() as f64;
        100.0 * (1.0 - self.total_uplink_bits_per_element() / raw)
    }

    /// Render the per-iteration table as CSV.
    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new(&[
            "t",
            "sdr_db",
            "sdr_pred_db",
            "rate_alloc",
            "rate_wire",
            "sigma_q2",
            "sigma_d2_hat",
            "wall_s",
        ]);
        for r in &self.iters {
            csv.push_f64(&[
                r.t as f64,
                r.sdr_db,
                r.sdr_pred_db,
                r.rate_alloc,
                r.rate_wire,
                r.sigma_q2,
                r.sigma_d2_hat,
                r.wall_s,
            ]);
        }
        csv
    }

    /// Render a summary JSON object.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("n", Json::Num(self.dims.0 as f64))
            .set("m", Json::Num(self.dims.1 as f64))
            .set("p", Json::Num(self.dims.2 as f64))
            .set("batch", Json::Num(self.batch as f64))
            .set("schedule", Json::Str(self.schedule.clone()))
            .set("engine", Json::Str(self.engine.clone()))
            .set("partitioning", Json::Str(self.partitioning.clone()))
            .set("iters", Json::Num(self.iters.len() as f64))
            .set("final_sdr_db", Json::Num(self.final_sdr_db()))
            .set(
                "sdr_db_per_signal",
                Json::Arr(self.sdr_db_per_signal.iter().map(|&v| Json::Num(v)).collect()),
            )
            .set(
                "total_bits_per_element",
                Json::Num(self.total_uplink_bits_per_element()),
            )
            .set("savings_vs_float_pct", Json::Num(self.savings_vs_float_pct()))
            .set("signals_per_s", Json::Num(self.signals_per_s()))
            .set(
                "stopped_early",
                match &self.stopped_early {
                    Some(s) => Json::Str(s.clone()),
                    None => Json::Null,
                },
            )
            .set("wall_s", Json::Num(self.wall_s))
    }
}

/// Owned view of one completed iteration, returned by [`Session::step`]
/// and streamed to [`RunObserver`]s.
#[derive(Debug, Clone)]
pub struct IterSnapshot {
    /// The iteration's record (quality, rates, σ estimates, timing).
    pub record: IterRecord,
    /// Measured uplink spend so far, bits per element of the uplink.
    pub cum_wire_bits_per_element: f64,
    /// Allocated (analytic) spend so far, bits per element.
    pub cum_alloc_bits_per_element: f64,
}

impl IterSnapshot {
    /// Iteration index (0-based).
    pub fn t(&self) -> usize {
        self.record.t
    }

    /// Empirical SDR after this iteration, dB (batch mean).
    pub fn sdr_db(&self) -> f64 {
        self.record.sdr_db
    }
}

/// Live protocol state: worker threads, their endpoints, and the fusion
/// iteration state. Created lazily on the first [`Session::step`].
struct Active {
    controller: Box<dyn RateAllocator>,
    meter: Arc<ByteMeter>,
    endpoints: Vec<Endpoint>,
    workers: Vec<JoinHandle<Result<usize>>>,
    state: ProtocolState,
    records: Vec<IterRecord>,
    t0: Instant,
    stop_reason: Option<String>,
}

/// A configured MP-AMP session — the stepwise driver at the heart of the
/// crate's public API.
///
/// ```no_run
/// use mpamp::SessionBuilder;
///
/// let mut session = SessionBuilder::test_small(0.05).build().unwrap();
/// while let Some(snap) = session.step().unwrap() {
///     println!("t={} SDR={:.2} dB", snap.t(), snap.sdr_db());
///     if snap.sdr_db() > 15.0 {
///         break; // caller-driven early stop
///     }
/// }
/// let report = session.finish().unwrap();
/// println!("{} iterations, {:.2} bits/element",
///          report.iters.len(), report.total_uplink_bits_per_element());
/// ```
pub struct Session {
    cfg: RunConfig,
    batch: Arc<Batch>,
    se: StateEvolution,
    cache: Option<RdCache>,
    engine: Arc<dyn ComputeEngine>,
    active: Option<Active>,
    /// Set once a step failed; the session is unusable afterwards (a
    /// later `finish` must not silently start a fresh run).
    failed: bool,
    /// Set once `finish` produced a report; further `step`/`finish`
    /// calls error instead of silently starting a second run.
    finished: bool,
    /// Span-recording handle threaded into the protocol core and the
    /// worker threads (off by default — a true no-op).
    tel: Telemetry,
    /// Deterministic fault plan installed on the worker-side channels at
    /// start; `None` (the default) leaves the transports untouched.
    fault_plan: Option<Arc<FaultPlan>>,
}

/// Former name of [`Session`], kept so existing call sites read naturally.
pub type MpAmpSession = Session;

impl Session {
    /// Build from a config (generates a `cfg.batch`-signal batch from the
    /// config's seed).
    pub fn new(cfg: RunConfig) -> Result<Self> {
        cfg.validate()?;
        let mut rng = Rng::new(cfg.seed);
        let batch = Batch::generate(
            cfg.prior,
            ProblemDims { n: cfg.n, m: cfg.m, sigma_e2: cfg.sigma_e2() },
            &mut rng,
            cfg.batch,
        )?;
        Self::with_batch(cfg, batch)
    }

    /// Build around an existing single instance (requires
    /// `cfg.batch == 1`). A uniquely-owned `Arc` is unwrapped without
    /// copying the sensing matrix; a **shared** `Arc<Instance>` must be
    /// deep-cloned into the session's batch — callers that reuse one
    /// problem across sessions should share an `Arc<Batch>` via
    /// [`with_batch`](Session::with_batch) (or
    /// `SessionBuilder::signal_batch`) instead, which shares `A` with no
    /// copy.
    pub fn with_instance(
        cfg: RunConfig,
        instance: impl Into<Arc<Instance>>,
    ) -> Result<Self> {
        if cfg.batch != 1 {
            return Err(Error::Config(format!(
                "with_instance carries one signal but cfg.batch = {}; use \
                 with_batch for batched sessions",
                cfg.batch
            )));
        }
        let instance: Arc<Instance> = instance.into();
        let inst = Arc::try_unwrap(instance).unwrap_or_else(|arc| (*arc).clone());
        Self::with_batch(cfg, Batch::from_instance(inst))
    }

    /// Build around an existing signal batch (`cfg.batch` must match).
    pub fn with_batch(cfg: RunConfig, batch: impl Into<Arc<Batch>>) -> Result<Self> {
        cfg.validate()?;
        let batch: Arc<Batch> = batch.into();
        batch.validate()?;
        if batch.a.rows() != cfg.m || batch.a.cols() != cfg.n {
            return Err(Error::Config(format!(
                "batch shape ({}, {}) does not match config (M={}, N={})",
                batch.a.rows(),
                batch.a.cols(),
                cfg.m,
                cfg.n
            )));
        }
        if batch.batch() != cfg.batch {
            return Err(Error::Config(format!(
                "batch holds {} signals but cfg.batch = {}",
                batch.batch(),
                cfg.batch
            )));
        }
        let se = StateEvolution::new(cfg.prior, cfg.kappa(), cfg.sigma_e2());
        let cache = match cfg.schedule {
            // Only the DP allocator consults the RD function at runtime.
            ScheduleKind::Dp { .. } => {
                let fp = se.fixed_point(1e-10, 300);
                Some(RdCache::build(
                    &cfg.prior,
                    cfg.p,
                    fp * 0.5,
                    se.sigma0_sq() * 2.0,
                    &cfg.rd,
                )?)
            }
            _ => None,
        };
        let engine: Arc<dyn ComputeEngine> = match cfg.engine {
            EngineKind::Rust => Arc::new(RustEngine::new(cfg.prior, cfg.threads)),
            EngineKind::Xla => Arc::new(crate::runtime::XlaEngine::load(
                &cfg.artifact_dir,
                cfg.prior,
                cfg.n,
                cfg.m / cfg.p,
                cfg.p,
            )?),
        };
        Ok(Session {
            cfg,
            batch,
            se,
            cache,
            engine,
            active: None,
            failed: false,
            finished: false,
            tel: Telemetry::off(),
            fault_plan: None,
        })
    }

    /// Build a session that drives **externally supplied** transport
    /// endpoints instead of spawning its own worker fleet — the serving
    /// daemon's job driver. `endpoints` are the fusion sides of `cfg.p`
    /// per-session links (in worker-id order) whose worker sides are
    /// served elsewhere (the daemon's multiplexed fleet); `meter` is the
    /// job's own byte meter, shared with those worker sides. The protocol
    /// state is pre-armed, so `step`/`finish` behave exactly as in a
    /// standalone session except that there are no worker threads to
    /// spawn or join — which is what makes a served job's report
    /// bit-identical to a standalone run by construction.
    pub(crate) fn with_external_transport(
        cfg: RunConfig,
        batch: Arc<Batch>,
        engine: Arc<dyn ComputeEngine>,
        meter: Arc<ByteMeter>,
        endpoints: Vec<Endpoint>,
    ) -> Result<Self> {
        if endpoints.len() != cfg.p {
            return Err(Error::Config(format!(
                "{} external endpoints for P={} workers",
                endpoints.len(),
                cfg.p
            )));
        }
        let mut session = Session::with_batch(cfg, batch)?;
        session.engine = engine;
        let controller =
            allocator_from_config(&session.cfg, &session.se, session.cache.as_ref())?;
        let state = ProtocolState::new(session.batch.as_ref(), &session.cfg);
        let iters = session.cfg.iters;
        tel_metrics().sessions_started.add(1);
        session.active = Some(Active {
            controller,
            meter,
            endpoints,
            workers: Vec::new(),
            state,
            records: Vec::with_capacity(iters),
            t0: Instant::now(),
            stop_reason: None,
        });
        Ok(session)
    }

    /// Attach a [`Telemetry`] handle: the protocol core records one span
    /// per round phase (plus the whole-round envelope with wire bits,
    /// σ_Q², and SE-predicted vs empirical MSE) and locally spawned
    /// workers record their encode/local-step spans into the same ring.
    /// Recording is measurement-only: a traced session is bit-identical
    /// to an untraced one. Call before the first [`step`](Session::step)
    /// to capture every round.
    pub fn set_telemetry(&mut self, tel: Telemetry) {
        self.tel = tel.clone();
        if let Some(act) = self.active.as_mut() {
            act.state.set_telemetry(tel);
        }
    }

    /// Install a deterministic [`FaultPlan`] on this session's transports.
    ///
    /// Each worker-side channel is wrapped in a
    /// [`FaultChannel`](crate::coordinator::fault::FaultChannel) when the
    /// fleet spawns (first [`step`](Session::step)), so drops, delays,
    /// kills, and corruptions fire at exactly the scripted `(worker,
    /// round)` coordinates regardless of thread timing. Pair with
    /// `min_workers`/`round_deadline_ms` so the elastic protocol can
    /// absorb the injected losses; an empty plan is a strict no-op.
    /// Call before the first `step`; plans installed later are ignored.
    pub fn set_fault_plan(&mut self, plan: Arc<FaultPlan>) {
        self.fault_plan = Some(plan);
    }

    /// Access the underlying signal batch (e.g. for external SDR checks).
    pub fn batch(&self) -> &Batch {
        self.batch.as_ref()
    }

    /// The state-evolution engine for this session's problem.
    pub fn se(&self) -> &StateEvolution {
        &self.se
    }

    /// The session's configuration.
    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    /// Records of all iterations completed so far.
    pub fn history(&self) -> &[IterRecord] {
        self.active.as_ref().map(|a| a.records.as_slice()).unwrap_or(&[])
    }

    /// The current estimate of the batch's first signal (zeros before the
    /// first step).
    pub fn current_x(&self) -> Option<&[f32]> {
        self.active.as_ref().map(|a| a.state.x(0))
    }

    /// Spawn the worker threads for one scenario over its shards.
    fn spawn_workers<S: Scenario>(
        &self,
        worker_eps: Vec<Endpoint>,
    ) -> Result<Vec<JoinHandle<Result<usize>>>> {
        let cfg = &self.cfg;
        let shards = S::split(self.batch.as_ref(), cfg.p)?;
        let mut workers = Vec::with_capacity(cfg.p);
        for (id, (shard, mut ep)) in
            shards.into_iter().zip(worker_eps.into_iter()).enumerate()
        {
            let params = WorkerParams {
                id: id as u32,
                p_workers: cfg.p,
                batch: cfg.batch,
                prior: cfg.prior,
            };
            let engine = self.engine.clone();
            let tel = self.tel.clone();
            workers.push(std::thread::spawn(move || {
                run_scenario_worker_traced::<S>(&params, &shard, engine.as_ref(), &mut ep, tel)
            }));
        }
        Ok(workers)
    }

    /// Spawn workers and transports; called lazily by the first `step`.
    fn start(&mut self) -> Result<()> {
        debug_assert!(self.active.is_none());
        let t0 = Instant::now();
        let cfg = &self.cfg;
        let controller = allocator_from_config(cfg, &self.se, self.cache.as_ref())?;
        let meter = Arc::new(ByteMeter::new());

        // Build transport pairs.
        let (fusion_eps, mut worker_eps): (Vec<Endpoint>, Vec<Endpoint>) =
            match cfg.transport {
                TransportKind::InProc => {
                    let pairs: Vec<_> =
                        (0..cfg.p).map(|_| inproc_pair(meter.clone())).collect();
                    pairs.into_iter().unzip()
                }
                TransportKind::Tcp => {
                    let listener = TcpFusionListener::bind("127.0.0.1:0", cfg.p)?;
                    let addr = listener.addr()?;
                    let meter2 = meter.clone();
                    let accept =
                        std::thread::spawn(move || listener.accept_all(meter2));
                    let mut workers = Vec::with_capacity(cfg.p);
                    for id in 0..cfg.p as u32 {
                        workers.push(tcp_connect(addr, id, meter.clone())?);
                    }
                    let fusion = accept
                        .join()
                        .map_err(|_| Error::Transport("tcp accept thread panicked".into()))??;
                    (fusion, workers)
                }
            };

        // Install the fault plan on the worker sides so injected drops /
        // delays / kills / corruptions hit the wire exactly where the
        // plan scripts them, on both inproc and TCP transports.
        if let Some(plan) = &self.fault_plan {
            for (id, ep) in worker_eps.iter_mut().enumerate() {
                let plan = plan.clone();
                ep.wrap_channel(move |inner| {
                    Box::new(FaultChannel::new(inner, plan, id as u32))
                });
            }
        }

        // Spawn the worker threads; they serve protocol rounds until the
        // fusion side broadcasts `Done` (or their endpoint drops). The
        // partitioning picks the scenario (and with it the shard type,
        // worker loop, and fusion core) — everything else is generic.
        let workers = match cfg.partitioning {
            Partitioning::Row => self.spawn_workers::<Row>(worker_eps)?,
            Partitioning::Column => self.spawn_workers::<Column>(worker_eps)?,
        };
        let mut state = ProtocolState::new(self.batch.as_ref(), cfg);
        state.set_telemetry(self.tel.clone());
        tel_metrics().sessions_started.add(1);
        self.active = Some(Active {
            controller,
            meter,
            endpoints: fusion_eps,
            workers,
            state,
            records: Vec::with_capacity(cfg.iters),
            t0,
            stop_reason: None,
        });
        Ok(())
    }

    /// Advance the protocol by exactly one iteration (all `B` signals).
    ///
    /// Returns `Ok(Some(snapshot))` for a completed iteration and
    /// `Ok(None)` once `cfg.iters` iterations have run (the session is
    /// then waiting for [`finish`](Session::finish)). The first call
    /// spawns the worker threads.
    pub fn step(&mut self) -> Result<Option<IterSnapshot>> {
        if self.failed {
            return Err(Error::Protocol(
                "session failed during an earlier step; build a new one".into(),
            ));
        }
        if self.finished {
            return Err(Error::Protocol(
                "session already finished; build a new one to run again".into(),
            ));
        }
        if self.active.is_none() {
            self.start()?;
        }
        let act = self.active.as_mut().expect("just started");
        if act.state.t() >= self.cfg.iters {
            return Ok(None);
        }
        let stepped = act.state.step(
            &self.cfg,
            &self.se,
            act.controller.as_ref(),
            self.cache.as_ref(),
            self.engine.as_ref(),
            &mut act.endpoints,
            Some(self.batch.as_ref()),
        );
        match stepped {
            Ok(record) => {
                tel_metrics().rounds_total.add(1);
                act.records.push(record.clone());
                let snap = IterSnapshot {
                    cum_wire_bits_per_element: act
                        .records
                        .iter()
                        .map(|r| r.rate_wire)
                        .sum(),
                    cum_alloc_bits_per_element: act
                        .records
                        .iter()
                        .map(|r| r.rate_alloc)
                        .sum(),
                    record,
                };
                Ok(Some(snap))
            }
            // A dead worker surfaces as a transport/protocol error on the
            // fusion side; join the workers to report the root cause.
            Err(e) => Err(self.collect_worker_error(e)),
        }
    }

    /// Record why the driver is stopping early (shows up in the report).
    pub fn note_stop(&mut self, reason: String) {
        if let Some(act) = self.active.as_mut() {
            act.stop_reason = Some(reason);
        }
    }

    /// Release the workers, join them, and assemble the [`RunReport`].
    ///
    /// Valid after any number of `step` calls (including zero). Erroring
    /// workers take precedence over count mismatches in the result.
    pub fn finish(&mut self) -> Result<RunReport> {
        if self.failed {
            return Err(Error::Protocol(
                "session failed during an earlier step; no report available".into(),
            ));
        }
        if self.finished {
            return Err(Error::Protocol(
                "session already finished; the report was already returned".into(),
            ));
        }
        if self.active.is_none() {
            // Zero-step finish: still spin up/down the protocol so the
            // report reflects a real (empty) run.
            self.start()?;
        }
        let mut act = self.active.take().expect("active session");
        let steps = act.records.len();
        // Elastic sessions expect casualties: a worker lost to a fault or
        // a missed deadline was already absorbed by the K-of-P rounds, so
        // its dead link / short serve count is not an error here.
        let elastic = self.cfg.min_workers > 0;
        let tolerated =
            |e: &Error| elastic && (e.is_peer_loss() || e.is_timeout());
        // A failed Done send means the worker already died; keep going so
        // the join below can report its root-cause error.
        let mut root_err: Option<Error> = None; // errors returned by workers
        let mut side_err: Option<Error> = None; // send failures, counts, panics
        for ep in act.endpoints.iter_mut() {
            if let Err(e) = ep.send(&Message::Done) {
                if !tolerated(&e) {
                    side_err.get_or_insert(e);
                }
            }
        }
        // Drop the endpoints so a worker stuck mid-protocol errors out
        // rather than deadlocking the join below. Join *every* handle —
        // even after an error — so no worker thread outlives the session.
        act.endpoints.clear();
        for (id, h) in act.workers.into_iter().enumerate() {
            match h.join() {
                Ok(Ok(served)) => {
                    if served != steps && !elastic && side_err.is_none() {
                        side_err = Some(Error::Protocol(format!(
                            "worker {id} served {served} != {steps} iterations"
                        )));
                    }
                }
                Ok(Err(e)) if tolerated(&e) => {}
                Ok(Err(e)) => {
                    root_err.get_or_insert(e);
                }
                Err(_) => {
                    side_err.get_or_insert(Error::Transport(format!(
                        "worker {id} panicked"
                    )));
                }
            }
        }
        // Worker root causes beat Done-send/count/panic secondaries.
        if let Some(e) = root_err.or(side_err) {
            self.failed = true;
            return Err(e);
        }
        self.finished = true;
        // Feed the process-wide registry once per session: byte totals
        // come from the meter, so standalone and served sessions account
        // identically.
        let reg = tel_metrics();
        reg.sessions_finished.add(1);
        reg.uplink_bytes_total.add(act.meter.uplink_bits() / 8);
        reg.downlink_bytes_total.add(act.meter.downlink_bits() / 8);
        let final_xs = act.state.into_xs();
        let sdr_db_per_signal: Vec<f64> = final_xs
            .iter()
            .enumerate()
            .map(|(j, x)| self.batch.sdr_db(j, x))
            .collect();
        Ok(RunReport {
            iters: act.records,
            final_xs,
            sdr_db_per_signal,
            batch: self.cfg.batch,
            dims: (self.cfg.n, self.cfg.m, self.cfg.p),
            schedule: act.controller.name().to_string(),
            engine: self.engine.name().to_string(),
            partitioning: self.cfg.partitioning.as_str().to_string(),
            transport_uplink_bits: act.meter.uplink_bits(),
            transport_downlink_bits: act.meter.downlink_bits(),
            wall_s: act.t0.elapsed().as_secs_f64(),
            stopped_early: act.stop_reason,
        })
    }

    /// Run the full protocol: a thin loop over [`step`](Session::step)
    /// followed by [`finish`](Session::finish).
    pub fn run(self) -> Result<RunReport> {
        self.run_observed(&mut NullObserver, &StopSet::none())
    }

    /// Run with per-iteration observation and early stopping: after each
    /// step the observer sees the snapshot, then the stop rules are
    /// evaluated on the history; the first rule to fire ends the run (its
    /// description lands in [`RunReport::stopped_early`]).
    pub fn run_observed(
        mut self,
        observer: &mut dyn RunObserver,
        stop: &StopSet,
    ) -> Result<RunReport> {
        observer.on_start(&self.cfg);
        while let Some(snap) = self.step()? {
            observer.on_iter(&snap);
            // Observer-driven stops (client cancel, job deadline) first,
            // then the history-based rules.
            if let Some(reason) = observer.should_stop() {
                self.note_stop(reason);
                break;
            }
            if let Some(reason) = stop.triggered(self.history()) {
                self.note_stop(reason);
                break;
            }
        }
        let report = self.finish()?;
        observer.on_finish(&report);
        Ok(report)
    }

    /// Join workers after a fusion-side error. A worker's own
    /// non-transport error is the root cause and wins; transport errors
    /// reported by workers are usually secondary (their endpoint was just
    /// dropped to unblock them), so the fusion error wins over those.
    fn collect_worker_error(&mut self, fusion_err: Error) -> Error {
        self.failed = true;
        let mut root: Option<Error> = None;
        if let Some(act) = self.active.take() {
            // Unblock workers waiting on a recv, then join every handle.
            drop(act.endpoints);
            for h in act.workers {
                match h.join() {
                    Ok(Err(Error::Transport(_))) => {}
                    Ok(Err(worker_err)) => {
                        root.get_or_insert(worker_err);
                    }
                    _ => {}
                }
            }
        }
        root.unwrap_or(fusion_err)
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        // Best-effort cleanup when the session is dropped mid-run (e.g. a
        // caller bails out of a step loop): release and join the workers
        // so no threads outlive the session.
        if let Some(mut act) = self.active.take() {
            for ep in act.endpoints.iter_mut() {
                let _ = ep.send(&Message::Done);
            }
            drop(act.endpoints);
            for h in act.workers {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::observe::{RecordLog, StopRule};

    fn run_with(schedule: ScheduleKind, compressor: &str) -> RunReport {
        let mut cfg = RunConfig::test_small(0.05);
        cfg.schedule = schedule;
        cfg.compressor = compressor.to_string();
        Session::new(cfg).unwrap().run().unwrap()
    }

    #[test]
    fn uncompressed_recovers_signal() {
        let r = run_with(ScheduleKind::Uncompressed, "ecsq.range");
        assert_eq!(r.iters.len(), 6);
        assert!(
            r.final_sdr_db() > 10.0,
            "MP-AMP should recover at small scale: SDR={}",
            r.final_sdr_db()
        );
        // Raw = 32 bits/element/iteration.
        assert!((r.total_uplink_bits_per_element() - 32.0 * 6.0).abs() < 1e-9);
    }

    #[test]
    fn fixed_rate_compresses_with_small_loss() {
        let raw = run_with(ScheduleKind::Uncompressed, "ecsq.range");
        let fixed = run_with(ScheduleKind::Fixed { bits: 4.0 }, "ecsq.range");
        // ~8x fewer bits...
        assert!(
            fixed.total_uplink_bits_per_element()
                < raw.total_uplink_bits_per_element() / 5.0
        );
        // ...with modest SDR loss.
        assert!(
            fixed.final_sdr_db() > raw.final_sdr_db() - 3.0,
            "fixed {} vs raw {}",
            fixed.final_sdr_db(),
            raw.final_sdr_db()
        );
    }

    #[test]
    fn bt_schedule_runs_and_stays_under_cap() {
        let r = run_with(
            ScheduleKind::BackTrack { ratio_max: 1.05, r_max: 6.0 },
            "ecsq.range",
        );
        for it in &r.iters {
            assert!(it.rate_wire <= 7.0, "t={}: wire rate {}", it.t, it.rate_wire);
        }
        assert!(r.final_sdr_db() > 8.0, "SDR={}", r.final_sdr_db());
        assert!(r.savings_vs_float_pct() > 75.0);
    }

    #[test]
    fn codecs_agree_numerically() {
        // Analytic / Range / Huffman all quantize identically; only the
        // wire bits differ. Same seed ⇒ identical SDR trajectories.
        let a = run_with(ScheduleKind::Fixed { bits: 3.0 }, "ecsq.analytic");
        let b = run_with(ScheduleKind::Fixed { bits: 3.0 }, "ecsq.range");
        let c = run_with(ScheduleKind::Fixed { bits: 3.0 }, "ecsq.huffman");
        for ((ra, rb), rc) in a.iters.iter().zip(&b.iters).zip(&c.iters) {
            assert!((ra.sdr_db - rb.sdr_db).abs() < 1e-9);
            assert!((ra.sdr_db - rc.sdr_db).abs() < 1e-9);
        }
        // Range ≤ Huffman (integer-length penalty), both ≈ analytic.
        assert!(
            b.total_uplink_bits_per_element()
                <= c.total_uplink_bits_per_element() + 1e-9
        );
    }

    #[test]
    fn column_partitioning_runs_end_to_end() {
        let mut cfg = RunConfig::test_small(0.05);
        cfg.partitioning = Partitioning::Column;
        cfg.schedule = ScheduleKind::Fixed { bits: 5.0 };
        let r = Session::new(cfg).unwrap().run().unwrap();
        assert_eq!(r.iters.len(), 6);
        assert_eq!(r.partitioning, "column");
        assert!(r.final_sdr_db() > 8.0, "C-MP-AMP SDR={}", r.final_sdr_db());
        // Entropy-coded uplinks stay well under the 32-bit baseline.
        assert!(
            r.total_uplink_bits_per_element() < 6.5 * 6.0,
            "column uplink spend {}",
            r.total_uplink_bits_per_element()
        );
        // Report plumbing: the scenario shows up in the JSON summary.
        assert!(r.to_json().render().contains("\"partitioning\":\"column\""));
    }

    #[test]
    fn column_tcp_transport_matches_inproc() {
        let mut cfg = RunConfig::test_small(0.05);
        cfg.partitioning = Partitioning::Column;
        cfg.schedule = ScheduleKind::Fixed { bits: 4.0 };
        let inproc = Session::new(cfg.clone()).unwrap().run().unwrap();
        cfg.transport = TransportKind::Tcp;
        let tcp = Session::new(cfg).unwrap().run().unwrap();
        for (a, b) in inproc.iters.iter().zip(&tcp.iters) {
            assert!((a.sdr_db - b.sdr_db).abs() < 1e-9, "transport changed numerics");
            assert!((a.rate_wire - b.rate_wire).abs() < 1e-12);
        }
    }

    #[test]
    fn tcp_transport_matches_inproc() {
        let mut cfg = RunConfig::test_small(0.05);
        cfg.schedule = ScheduleKind::Fixed { bits: 4.0 };
        let inproc = Session::new(cfg.clone()).unwrap().run().unwrap();
        cfg.transport = TransportKind::Tcp;
        let tcp = Session::new(cfg).unwrap().run().unwrap();
        for (a, b) in inproc.iters.iter().zip(&tcp.iters) {
            assert!((a.sdr_db - b.sdr_db).abs() < 1e-9, "transport changed numerics");
            assert!((a.rate_wire - b.rate_wire).abs() < 1e-12);
        }
    }

    #[test]
    fn transport_meter_counts_everything() {
        let r = run_with(ScheduleKind::Fixed { bits: 4.0 }, "ecsq.range");
        // Uplink raw bytes ≥ payload bits (headers included).
        let payload_bits: f64 = r.iters.iter().map(|it| it.rate_wire).sum::<f64>()
            * (r.dims.0 * r.dims.2) as f64;
        assert!(r.transport_uplink_bits as f64 >= payload_bits);
        // Downlink dominated by P broadcasts of x per iteration.
        let min_downlink = (r.iters.len() * r.dims.2 * r.dims.0 * 32) as u64;
        assert!(r.transport_downlink_bits >= min_downlink);
    }

    #[test]
    fn stepwise_drive_matches_run() {
        let mut cfg = RunConfig::test_small(0.05);
        cfg.schedule = ScheduleKind::Fixed { bits: 4.0 };
        let whole = Session::new(cfg.clone()).unwrap().run().unwrap();

        let mut session = Session::new(cfg).unwrap();
        let mut snaps = Vec::new();
        while let Some(s) = session.step().unwrap() {
            snaps.push(s);
        }
        let stepped = session.finish().unwrap();
        assert_eq!(whole.iters.len(), stepped.iters.len());
        for (a, b) in whole.iters.iter().zip(&stepped.iters) {
            assert_eq!(a.sdr_db.to_bits(), b.sdr_db.to_bits(), "t={}", a.t);
            assert_eq!(a.rate_wire.to_bits(), b.rate_wire.to_bits(), "t={}", a.t);
        }
        // Snapshots accumulate the wire spend.
        let total: f64 = stepped.iters.iter().map(|r| r.rate_wire).sum();
        assert!(
            (snaps.last().unwrap().cum_wire_bits_per_element - total).abs() < 1e-12
        );
    }

    #[test]
    fn batched_session_runs_and_reports_per_signal() {
        let mut cfg = RunConfig::test_small(0.05);
        cfg.batch = 3;
        cfg.schedule = ScheduleKind::Fixed { bits: 4.0 };
        let r = Session::new(cfg).unwrap().run().unwrap();
        assert_eq!(r.batch, 3);
        assert_eq!(r.final_xs.len(), 3);
        assert_eq!(r.sdr_db_per_signal.len(), 3);
        for (j, &sdr) in r.sdr_db_per_signal.iter().enumerate() {
            assert!(sdr > 5.0, "signal {j}: SDR {sdr}");
        }
        // The record's SDR is the batch mean of the per-signal finals.
        let mean: f64 = r.sdr_db_per_signal.iter().sum::<f64>() / 3.0;
        assert!((r.final_sdr_db() - mean).abs() < 1e-9);
        assert!(r.signals_per_s() > 0.0);
        let json = r.to_json().render();
        assert!(json.contains("\"batch\":3"), "{json}");
        assert!(json.contains("\"signals_per_s\""), "{json}");
    }

    #[test]
    fn with_instance_rejects_batched_config() {
        let mut cfg = RunConfig::test_small(0.05);
        cfg.batch = 2;
        let mut rng = Rng::new(1);
        let inst = Instance::generate(
            cfg.prior,
            ProblemDims { n: cfg.n, m: cfg.m, sigma_e2: cfg.sigma_e2() },
            &mut rng,
        )
        .unwrap();
        let err = Session::with_instance(cfg, inst).unwrap_err();
        assert!(err.to_string().contains("with_batch"), "{err}");
    }

    #[test]
    fn early_stop_joins_workers_cleanly() {
        let mut cfg = RunConfig::test_small(0.05);
        cfg.schedule = ScheduleKind::Fixed { bits: 4.0 };
        let stop = StopSet::none().with(StopRule::MaxIters(2));
        let mut log = RecordLog::new();
        let report = Session::new(cfg)
            .unwrap()
            .run_observed(&mut log, &stop)
            .unwrap();
        assert_eq!(report.iters.len(), 2);
        assert_eq!(log.records.len(), 2);
        let why = report.stopped_early.as_deref().unwrap();
        assert!(why.contains("max iterations"), "{why}");
    }

    #[test]
    fn dropping_mid_run_does_not_hang() {
        let mut cfg = RunConfig::test_small(0.05);
        cfg.schedule = ScheduleKind::Fixed { bits: 4.0 };
        let mut session = Session::new(cfg).unwrap();
        session.step().unwrap().unwrap();
        drop(session); // Drop impl must release + join the workers.
    }
}
