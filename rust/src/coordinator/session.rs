//! Session orchestration: generate (or accept) a problem instance, shard it
//! across `P` worker threads, run the fusion protocol, and produce a
//! [`RunReport`] with per-iteration quality and exact communication costs.

use std::sync::Arc;
use std::time::Instant;

use crate::alloc::schedule::RateController;
use crate::config::{EngineKind, RunConfig, ScheduleKind, TransportKind};
use crate::coordinator::fusion::{run_fusion, FusionOutput};
use crate::coordinator::transport::{inproc_pair, tcp_connect, Endpoint, TcpFusionListener};
use crate::coordinator::worker::{run_worker, WorkerParams};
use crate::engine::{ComputeEngine, RustEngine, WorkerData};
use crate::error::{Error, Result};
use crate::metrics::{ByteMeter, Csv, IterRecord, Json};
use crate::rd::RdCache;
use crate::se::StateEvolution;
use crate::signal::{Instance, ProblemDims};
use crate::util::rng::Rng;

/// Result of one MP-AMP run.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Per-iteration records.
    pub iters: Vec<IterRecord>,
    /// Final estimate.
    pub final_x: Vec<f32>,
    /// Problem size (N, M, P).
    pub dims: (usize, usize, usize),
    /// Schedule name.
    pub schedule: String,
    /// Engine name.
    pub engine: String,
    /// Total raw bits that crossed the transport, uplink (incl. headers).
    pub transport_uplink_bits: u64,
    /// Total raw bits that crossed the transport, downlink (incl. headers).
    pub transport_downlink_bits: u64,
    /// Wall-clock for the whole session.
    pub wall_s: f64,
}

impl RunReport {
    /// Final-iteration SDR in dB.
    pub fn final_sdr_db(&self) -> f64 {
        self.iters.last().map(|r| r.sdr_db).unwrap_or(f64::NAN)
    }

    /// The paper's headline metric: total uplink bits per element of
    /// `f_t^p` (sum over iterations of the measured per-element wire rate).
    pub fn total_uplink_bits_per_element(&self) -> f64 {
        self.iters.iter().map(|r| r.rate_wire).sum()
    }

    /// Analytic (allocated) total rate — the DP/BT budget actually used.
    pub fn total_alloc_bits_per_element(&self) -> f64 {
        self.iters.iter().map(|r| r.rate_alloc).sum()
    }

    /// Communication saving vs 32-bit floats (%).
    pub fn savings_vs_float_pct(&self) -> f64 {
        let raw = 32.0 * self.iters.len() as f64;
        100.0 * (1.0 - self.total_uplink_bits_per_element() / raw)
    }

    /// Render the per-iteration table as CSV.
    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new(&[
            "t",
            "sdr_db",
            "sdr_pred_db",
            "rate_alloc",
            "rate_wire",
            "sigma_q2",
            "sigma_d2_hat",
            "wall_s",
        ]);
        for r in &self.iters {
            csv.push_f64(&[
                r.t as f64,
                r.sdr_db,
                r.sdr_pred_db,
                r.rate_alloc,
                r.rate_wire,
                r.sigma_q2,
                r.sigma_d2_hat,
                r.wall_s,
            ]);
        }
        csv
    }

    /// Render a summary JSON object.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("n", Json::Num(self.dims.0 as f64))
            .set("m", Json::Num(self.dims.1 as f64))
            .set("p", Json::Num(self.dims.2 as f64))
            .set("schedule", Json::Str(self.schedule.clone()))
            .set("engine", Json::Str(self.engine.clone()))
            .set("iters", Json::Num(self.iters.len() as f64))
            .set("final_sdr_db", Json::Num(self.final_sdr_db()))
            .set(
                "total_bits_per_element",
                Json::Num(self.total_uplink_bits_per_element()),
            )
            .set("savings_vs_float_pct", Json::Num(self.savings_vs_float_pct()))
            .set("wall_s", Json::Num(self.wall_s))
    }
}

/// A configured MP-AMP session.
pub struct MpAmpSession {
    cfg: RunConfig,
    instance: Instance,
    se: StateEvolution,
    cache: Option<RdCache>,
    engine: Arc<dyn ComputeEngine>,
}

impl MpAmpSession {
    /// Build from a config (generates the instance from the config's seed).
    pub fn new(cfg: RunConfig) -> Result<Self> {
        cfg.validate()?;
        let mut rng = Rng::new(cfg.seed);
        let instance = Instance::generate(
            cfg.prior,
            ProblemDims { n: cfg.n, m: cfg.m, sigma_e2: cfg.sigma_e2() },
            &mut rng,
        )?;
        Self::with_instance(cfg, instance)
    }

    /// Build around an existing instance (benches reuse one instance
    /// across schedules).
    pub fn with_instance(cfg: RunConfig, instance: Instance) -> Result<Self> {
        cfg.validate()?;
        let se = StateEvolution::new(cfg.prior, cfg.kappa(), cfg.sigma_e2());
        let cache = match cfg.schedule {
            // Only the DP allocator consults the RD function at runtime.
            ScheduleKind::Dp { .. } => {
                let fp = se.fixed_point(1e-10, 300);
                Some(RdCache::build(
                    &cfg.prior,
                    cfg.p,
                    fp * 0.5,
                    se.sigma0_sq() * 2.0,
                    &cfg.rd,
                )?)
            }
            _ => None,
        };
        let engine: Arc<dyn ComputeEngine> = match cfg.engine {
            EngineKind::Rust => Arc::new(RustEngine::new(cfg.prior, cfg.threads)),
            EngineKind::Xla => Arc::new(crate::runtime::XlaEngine::load(
                &cfg.artifact_dir,
                cfg.prior,
                cfg.n,
                cfg.m / cfg.p,
                cfg.p,
            )?),
        };
        Ok(MpAmpSession { cfg, instance, se, cache, engine })
    }

    /// Access the underlying instance (e.g. for external SDR checks).
    pub fn instance(&self) -> &Instance {
        &self.instance
    }

    /// The state-evolution engine for this session's problem.
    pub fn se(&self) -> &StateEvolution {
        &self.se
    }

    /// Run the full protocol; returns the report.
    pub fn run(&self) -> Result<RunReport> {
        let t0 = Instant::now();
        let cfg = &self.cfg;
        let controller = RateController::from_config(cfg, &self.se, self.cache.as_ref())?;
        let meter = Arc::new(ByteMeter::new());
        let shards = WorkerData::split(&self.instance.a, &self.instance.y, cfg.p);

        // Build transport pairs.
        let (mut fusion_eps, worker_eps): (Vec<Endpoint>, Vec<Endpoint>) =
            match cfg.transport {
                TransportKind::InProc => {
                    let pairs: Vec<_> =
                        (0..cfg.p).map(|_| inproc_pair(meter.clone())).collect();
                    pairs.into_iter().unzip()
                }
                TransportKind::Tcp => {
                    let listener = TcpFusionListener::bind("127.0.0.1:0", cfg.p)?;
                    let addr = listener.addr()?;
                    let meter2 = meter.clone();
                    let accept =
                        std::thread::spawn(move || listener.accept_all(meter2));
                    let mut workers = Vec::with_capacity(cfg.p);
                    for id in 0..cfg.p as u32 {
                        workers.push(tcp_connect(addr, id, meter.clone())?);
                    }
                    let fusion = accept
                        .join()
                        .map_err(|_| Error::Transport("tcp accept thread panicked".into()))??;
                    (fusion, workers)
                }
            };

        // Spawn workers, run fusion, join.
        let output: Result<FusionOutput> = std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(cfg.p);
            for (id, (shard, mut ep)) in
                shards.iter().zip(worker_eps.into_iter()).enumerate()
            {
                let params = WorkerParams {
                    id: id as u32,
                    p_workers: cfg.p,
                    prior: cfg.prior,
                    codec: cfg.codec,
                };
                let engine = self.engine.clone();
                handles.push(s.spawn(move || {
                    run_worker(&params, shard, engine.as_ref(), &mut ep)
                }));
            }
            let out = run_fusion(
                cfg,
                &self.se,
                &controller,
                self.cache.as_ref(),
                self.engine.as_ref(),
                &mut fusion_eps,
                Some(&self.instance),
            );
            for (id, h) in handles.into_iter().enumerate() {
                match h.join() {
                    Ok(Ok(iters)) => {
                        if out.is_ok() && iters != cfg.iters {
                            return Err(Error::Protocol(format!(
                                "worker {id} served {iters} != {} iterations",
                                cfg.iters
                            )));
                        }
                    }
                    Ok(Err(e)) => return Err(e),
                    Err(_) => {
                        return Err(Error::Transport(format!("worker {id} panicked")))
                    }
                }
            }
            out
        });
        let output = output?;
        Ok(RunReport {
            iters: output.iters,
            final_x: output.final_x,
            dims: (cfg.n, cfg.m, cfg.p),
            schedule: controller.name().to_string(),
            engine: self.engine.name().to_string(),
            transport_uplink_bits: meter.uplink_bits(),
            transport_downlink_bits: meter.downlink_bits(),
            wall_s: t0.elapsed().as_secs_f64(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CodecKind;

    fn run_with(schedule: ScheduleKind, codec: CodecKind) -> RunReport {
        let mut cfg = RunConfig::test_small(0.05);
        cfg.schedule = schedule;
        cfg.codec = codec;
        MpAmpSession::new(cfg).unwrap().run().unwrap()
    }

    #[test]
    fn uncompressed_recovers_signal() {
        let r = run_with(ScheduleKind::Uncompressed, CodecKind::Range);
        assert_eq!(r.iters.len(), 6);
        assert!(
            r.final_sdr_db() > 10.0,
            "MP-AMP should recover at small scale: SDR={}",
            r.final_sdr_db()
        );
        // Raw = 32 bits/element/iteration.
        assert!((r.total_uplink_bits_per_element() - 32.0 * 6.0).abs() < 1e-9);
    }

    #[test]
    fn fixed_rate_compresses_with_small_loss() {
        let raw = run_with(ScheduleKind::Uncompressed, CodecKind::Range);
        let fixed = run_with(ScheduleKind::Fixed { bits: 4.0 }, CodecKind::Range);
        // ~8x fewer bits...
        assert!(
            fixed.total_uplink_bits_per_element()
                < raw.total_uplink_bits_per_element() / 5.0
        );
        // ...with modest SDR loss.
        assert!(
            fixed.final_sdr_db() > raw.final_sdr_db() - 3.0,
            "fixed {} vs raw {}",
            fixed.final_sdr_db(),
            raw.final_sdr_db()
        );
    }

    #[test]
    fn bt_schedule_runs_and_stays_under_cap() {
        let r = run_with(
            ScheduleKind::BackTrack { ratio_max: 1.05, r_max: 6.0 },
            CodecKind::Range,
        );
        for it in &r.iters {
            assert!(it.rate_wire <= 7.0, "t={}: wire rate {}", it.t, it.rate_wire);
        }
        assert!(r.final_sdr_db() > 8.0, "SDR={}", r.final_sdr_db());
        assert!(r.savings_vs_float_pct() > 75.0);
    }

    #[test]
    fn codecs_agree_numerically() {
        // Analytic / Range / Huffman all quantize identically; only the
        // wire bits differ. Same seed ⇒ identical SDR trajectories.
        let a = run_with(ScheduleKind::Fixed { bits: 3.0 }, CodecKind::Analytic);
        let b = run_with(ScheduleKind::Fixed { bits: 3.0 }, CodecKind::Range);
        let c = run_with(ScheduleKind::Fixed { bits: 3.0 }, CodecKind::Huffman);
        for ((ra, rb), rc) in a.iters.iter().zip(&b.iters).zip(&c.iters) {
            assert!((ra.sdr_db - rb.sdr_db).abs() < 1e-9);
            assert!((ra.sdr_db - rc.sdr_db).abs() < 1e-9);
        }
        // Range ≤ Huffman (integer-length penalty), both ≈ analytic.
        assert!(
            b.total_uplink_bits_per_element()
                <= c.total_uplink_bits_per_element() + 1e-9
        );
    }

    #[test]
    fn tcp_transport_matches_inproc() {
        let mut cfg = RunConfig::test_small(0.05);
        cfg.schedule = ScheduleKind::Fixed { bits: 4.0 };
        let inproc = MpAmpSession::new(cfg.clone()).unwrap().run().unwrap();
        cfg.transport = TransportKind::Tcp;
        let tcp = MpAmpSession::new(cfg).unwrap().run().unwrap();
        for (a, b) in inproc.iters.iter().zip(&tcp.iters) {
            assert!((a.sdr_db - b.sdr_db).abs() < 1e-9, "transport changed numerics");
            assert!((a.rate_wire - b.rate_wire).abs() < 1e-12);
        }
    }

    #[test]
    fn transport_meter_counts_everything() {
        let r = run_with(ScheduleKind::Fixed { bits: 4.0 }, CodecKind::Range);
        // Uplink raw bytes ≥ payload bits (headers included).
        let payload_bits: f64 = r.iters.iter().map(|it| it.rate_wire).sum::<f64>()
            * (r.dims.0 * r.dims.2) as f64;
        assert!(r.transport_uplink_bits as f64 >= payload_bits);
        // Downlink dominated by P broadcasts of x per iteration.
        let min_downlink = (r.iters.len() * r.dims.2 * r.dims.0 * 32) as u64;
        assert!(r.transport_downlink_bits >= min_downlink);
    }
}
