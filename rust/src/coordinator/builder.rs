//! Fluent construction of [`Session`]s (and validated [`RunConfig`]s):
//! start from a preset, chain typed setters, validate once at
//! [`build`](SessionBuilder::build).
//!
//! ```no_run
//! use mpamp::SessionBuilder;
//!
//! let report = SessionBuilder::paper_default(0.05)
//!     .dims(2_000, 600)
//!     .workers(10)
//!     .backtrack(1.02, 6.0)
//!     .build()
//!     .unwrap()
//!     .run()
//!     .unwrap();
//! ```

use std::sync::Arc;

use crate::config::{
    paper_iters, EngineKind, Partitioning, RdConfig, RunConfig, ScheduleKind, TransportKind,
};
use crate::coordinator::fault::FaultPlan;
use crate::coordinator::session::Session;
use crate::error::Result;
use crate::signal::{Batch, BernoulliGauss, Instance};

/// Builder for [`Session`]s. Setters never fail; all invariants are
/// checked together by [`build`](Self::build) / [`config`](Self::config).
#[derive(Debug, Clone)]
pub struct SessionBuilder {
    cfg: RunConfig,
    instance: Option<Arc<Instance>>,
    batch_data: Option<Arc<Batch>>,
    fault_plan: Option<Arc<FaultPlan>>,
}

impl SessionBuilder {
    /// Start from the paper's evaluation setup for sparsity ε
    /// (N=10 000, M=3 000, P=30, SNR=20 dB, BT schedule, paper's T).
    pub fn paper_default(eps: f64) -> Self {
        SessionBuilder {
            cfg: RunConfig::paper_default(eps),
            instance: None,
            batch_data: None,
            fault_plan: None,
        }
    }

    /// Start from the fast-test preset (N=600, M=180, P=6, T=6).
    pub fn test_small(eps: f64) -> Self {
        SessionBuilder {
            cfg: RunConfig::test_small(eps),
            instance: None,
            batch_data: None,
            fault_plan: None,
        }
    }

    /// Start from an existing config (e.g. loaded from a file / CLI).
    pub fn from_config(cfg: RunConfig) -> Self {
        SessionBuilder { cfg, instance: None, batch_data: None, fault_plan: None }
    }

    // ---- problem shape ----

    /// Signal length N and measurement count M together (they are almost
    /// always changed as a pair to preserve κ = M/N).
    pub fn dims(mut self, n: usize, m: usize) -> Self {
        self.cfg.n = n;
        self.cfg.m = m;
        self
    }

    /// Signal length N.
    pub fn n(mut self, n: usize) -> Self {
        self.cfg.n = n;
        self
    }

    /// Measurement count M.
    pub fn m(mut self, m: usize) -> Self {
        self.cfg.m = m;
        self
    }

    /// Worker processor count P (must divide M for row partitioning, N
    /// for column partitioning — checked at build).
    pub fn workers(mut self, p: usize) -> Self {
        self.cfg.p = p;
        self
    }

    /// Number of signal instances `B ≥ 1` the session carries end-to-end.
    /// All `B` signals share one sensing matrix; every protocol round and
    /// every pass over `A` is amortized across the batch.
    pub fn batch(mut self, batch: usize) -> Self {
        self.cfg.batch = batch;
        self
    }

    /// How the sensing matrix is sharded across the workers.
    pub fn partitioning(mut self, partitioning: Partitioning) -> Self {
        self.cfg.partitioning = partitioning;
        self
    }

    /// Row-wise sharding (the 2016 paper's MP-AMP; the default).
    pub fn row_partitioned(self) -> Self {
        self.partitioning(Partitioning::Row)
    }

    /// Column-wise sharding (C-MP-AMP, Ma–Lu–Baron 2017): workers own
    /// column blocks and uplink quantized partial residuals `A^p x^p`.
    pub fn column_partitioned(self) -> Self {
        self.partitioning(Partitioning::Column)
    }

    /// Sparsity ε of the Bernoulli-Gauss prior. Also re-derives the
    /// paper's iteration count for that sparsity — call
    /// [`iters`](Self::iters) *afterwards* to pin T explicitly.
    pub fn eps(mut self, eps: f64) -> Self {
        self.cfg.prior.eps = eps;
        self.cfg.iters = paper_iters(eps);
        self
    }

    /// Full source prior (leaves the iteration count untouched).
    pub fn prior(mut self, prior: BernoulliGauss) -> Self {
        self.cfg.prior = prior;
        self
    }

    /// Measurement SNR in dB.
    pub fn snr_db(mut self, snr_db: f64) -> Self {
        self.cfg.snr_db = snr_db;
        self
    }

    /// AMP iteration count T.
    pub fn iters(mut self, iters: usize) -> Self {
        self.cfg.iters = iters;
        self
    }

    /// RNG seed for instance generation.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Worker-side compute threads for the pure-Rust engine.
    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.threads = threads;
        self
    }

    // ---- rate allocation ----

    /// Any schedule, verbatim.
    pub fn schedule(mut self, schedule: ScheduleKind) -> Self {
        self.cfg.schedule = schedule;
        self
    }

    /// 32-bit floats on the wire (the paper's baseline).
    pub fn uncompressed(self) -> Self {
        self.schedule(ScheduleKind::Uncompressed)
    }

    /// Fixed ECSQ rate (bits/element) every iteration.
    pub fn fixed_rate(self, bits: f64) -> Self {
        self.schedule(ScheduleKind::Fixed { bits })
    }

    /// BT-MP-AMP online back-tracking (paper §3.3).
    pub fn backtrack(self, ratio_max: f64, r_max: f64) -> Self {
        self.schedule(ScheduleKind::BackTrack { ratio_max, r_max })
    }

    /// DP-MP-AMP offline allocation (paper §3.4); `None` → `R = 2T`.
    pub fn dp(self, total_rate: Option<f64>, delta_r: f64) -> Self {
        self.schedule(ScheduleKind::Dp { total_rate, delta_r })
    }

    // ---- execution substrate ----

    /// Compression stack for the uplink, by registry name (e.g.
    /// `"ecsq.huffman"`, `"ecsq-dithered.range"`, `"topk.raw"`; see
    /// [`compress::registry::names`](crate::compress::registry::names)).
    /// Validated against the registry at build.
    pub fn compressor(mut self, name: impl Into<String>) -> Self {
        self.cfg.compressor = name.into();
        self
    }

    /// Compute engine.
    pub fn engine(mut self, engine: EngineKind) -> Self {
        self.cfg.engine = engine;
        self
    }

    /// Artifact directory for the XLA engine.
    pub fn artifact_dir(mut self, dir: impl Into<String>) -> Self {
        self.cfg.artifact_dir = dir.into();
        self
    }

    /// Transport between workers and the fusion center.
    pub fn transport(mut self, transport: TransportKind) -> Self {
        self.cfg.transport = transport;
        self
    }

    /// Rate-distortion substrate tuning.
    pub fn rd(mut self, rd: RdConfig) -> Self {
        self.cfg.rd = rd;
        self
    }

    // ---- fault tolerance ----

    /// Elastic K-of-P floor: the minimum number of live worker uplinks a
    /// fusion round may proceed on (0 = disabled, the default). Requires
    /// [`round_deadline_ms`](Self::round_deadline_ms) — checked at build.
    pub fn min_workers(mut self, k: usize) -> Self {
        self.cfg.min_workers = k;
        self
    }

    /// Per-round reply deadline in milliseconds for elastic sessions:
    /// how long the fusion center waits on each worker before proceeding
    /// without it (rescaling the partial fusion by `P/K`).
    pub fn round_deadline_ms(mut self, ms: u64) -> Self {
        self.cfg.round_deadline_ms = ms;
        self
    }

    /// Install a deterministic [`FaultPlan`] on the session's worker
    /// links: uplink drops, delays, kills, and corruptions fire at the
    /// planned `(worker, round)` points on any transport. Measurement
    /// and test machinery — an empty plan leaves the session bit-identical.
    pub fn fault_plan(mut self, plan: Arc<FaultPlan>) -> Self {
        self.fault_plan = Some(plan);
        self
    }

    // ---- data ----

    /// Run on this problem instance instead of generating one from the
    /// seed. A uniquely-owned instance is moved in without copying; a
    /// *shared* `Arc<Instance>` is deep-cloned at build — callers that
    /// reuse one problem across sessions should share an `Arc<Batch>`
    /// via [`signal_batch`](Self::signal_batch), which shares the
    /// sensing matrix with no copy.
    pub fn instance(mut self, instance: impl Into<Arc<Instance>>) -> Self {
        self.instance = Some(instance.into());
        self
    }

    /// Run on this signal batch instead of generating one from the seed
    /// (its size must match the `batch` knob — checked at build). The
    /// batch is shared by `Arc`, so reusing one across trials never
    /// copies the sensing matrix.
    pub fn signal_batch(mut self, batch: impl Into<Arc<Batch>>) -> Self {
        self.batch_data = Some(batch.into());
        self
    }

    // ---- terminal operations ----

    /// Validate and return the accumulated config without building a
    /// session (for offline SE/RD machinery that needs no data).
    pub fn config(&self) -> Result<RunConfig> {
        self.cfg.validate()?;
        Ok(self.cfg.clone())
    }

    /// Validate everything and construct the [`Session`].
    pub fn build(self) -> Result<Session> {
        let mut session = match (self.batch_data, self.instance) {
            (Some(_), Some(_)) => {
                return Err(crate::error::Error::Config(
                    "both instance() and signal_batch() were set; supply exactly \
                     one data source"
                        .into(),
                ))
            }
            (Some(batch), None) => Session::with_batch(self.cfg, batch)?,
            (None, Some(inst)) => Session::with_instance(self.cfg, inst)?,
            (None, None) => Session::new(self.cfg)?,
        };
        if let Some(plan) = self.fault_plan {
            session.set_fault_plan(plan);
        }
        Ok(session)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_runconfig_presets() {
        let b = SessionBuilder::paper_default(0.05).config().unwrap();
        assert_eq!(b, RunConfig::paper_default(0.05));
        let s = SessionBuilder::test_small(0.1).config().unwrap();
        assert_eq!(s, RunConfig::test_small(0.1));
    }

    #[test]
    fn setters_compose() {
        let cfg = SessionBuilder::paper_default(0.05)
            .dims(2_000, 600)
            .workers(10)
            .iters(7)
            .seed(42)
            .fixed_rate(3.5)
            .compressor("ecsq.huffman")
            .transport(TransportKind::Tcp)
            .config()
            .unwrap();
        assert_eq!((cfg.n, cfg.m, cfg.p, cfg.iters, cfg.seed), (2_000, 600, 10, 7, 42));
        assert_eq!(cfg.schedule, ScheduleKind::Fixed { bits: 3.5 });
        assert_eq!(cfg.compressor, "ecsq.huffman");
        assert_eq!(cfg.transport, TransportKind::Tcp);
    }

    #[test]
    fn unknown_compressor_fails_at_config_time() {
        let err = SessionBuilder::test_small(0.05).compressor("ecsq.lzma").config();
        assert!(err.is_err());
        let msg = err.unwrap_err().to_string();
        assert!(msg.contains("ecsq.lzma"), "{msg}");
        // The error carries the menu of registered stacks.
        assert!(msg.contains("ecsq.range"), "{msg}");
    }

    #[test]
    fn eps_rederives_paper_iters_until_pinned() {
        let cfg = SessionBuilder::paper_default(0.05).eps(0.1).config().unwrap();
        assert_eq!(cfg.iters, paper_iters(0.1));
        let cfg =
            SessionBuilder::paper_default(0.05).eps(0.1).iters(3).config().unwrap();
        assert_eq!(cfg.iters, 3);
    }

    #[test]
    fn build_validates() {
        // P=7 does not divide M=3000 — must fail at build, not at run.
        let err = SessionBuilder::paper_default(0.05).workers(7).build();
        assert!(err.is_err());
        let err = SessionBuilder::paper_default(0.05).fixed_rate(-2.0).config();
        assert!(err.is_err());
    }

    #[test]
    fn partitioning_setters_compose_and_validate() {
        let cfg = SessionBuilder::test_small(0.05)
            .column_partitioned()
            .config()
            .unwrap();
        assert_eq!(cfg.partitioning, Partitioning::Column);
        let cfg = SessionBuilder::test_small(0.05)
            .column_partitioned()
            .row_partitioned()
            .config()
            .unwrap();
        assert_eq!(cfg.partitioning, Partitioning::Row);
        // P must divide N for columns: N=600, P=7 fails at config time.
        let err = SessionBuilder::test_small(0.05)
            .column_partitioned()
            .workers(7)
            .config();
        assert!(err.is_err());
    }

    #[test]
    fn batch_knob_composes_and_validates() {
        let cfg = SessionBuilder::test_small(0.05).batch(8).config().unwrap();
        assert_eq!(cfg.batch, 8);
        // batch = 0 fails at config time, not at run time.
        assert!(SessionBuilder::test_small(0.05).batch(0).config().is_err());
        // A supplied batch must match the knob.
        let mut rng = crate::util::rng::Rng::new(3);
        let cfg = SessionBuilder::test_small(0.05).batch(2).config().unwrap();
        let data = crate::signal::Batch::generate(
            cfg.prior,
            crate::signal::ProblemDims { n: cfg.n, m: cfg.m, sigma_e2: cfg.sigma_e2() },
            &mut rng,
            3,
        )
        .unwrap();
        let err = SessionBuilder::test_small(0.05)
            .batch(2)
            .signal_batch(data)
            .build();
        assert!(err.is_err());
    }

    #[test]
    fn conflicting_data_sources_rejected() {
        // Setting both instance() and signal_batch() must fail loudly
        // instead of silently running on one of them.
        let cfg = SessionBuilder::test_small(0.05).config().unwrap();
        let mut rng = crate::util::rng::Rng::new(5);
        let dims = crate::signal::ProblemDims {
            n: cfg.n,
            m: cfg.m,
            sigma_e2: cfg.sigma_e2(),
        };
        let inst =
            crate::signal::Instance::generate(cfg.prior, dims, &mut rng).unwrap();
        let data = crate::signal::Batch::generate(cfg.prior, dims, &mut rng, 1).unwrap();
        let err = SessionBuilder::test_small(0.05)
            .instance(inst)
            .signal_batch(data)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("exactly"), "{err}");
    }

    #[test]
    fn elastic_setters_compose_and_validate() {
        let cfg = SessionBuilder::test_small(0.05)
            .min_workers(4)
            .round_deadline_ms(100)
            .config()
            .unwrap();
        assert_eq!((cfg.min_workers, cfg.round_deadline_ms), (4, 100));
        // A floor without a deadline fails at config time.
        assert!(SessionBuilder::test_small(0.05).min_workers(4).config().is_err());
        // K > P fails at config time.
        assert!(SessionBuilder::test_small(0.05)
            .min_workers(7)
            .round_deadline_ms(100)
            .config()
            .is_err());
    }

    #[test]
    fn builder_runs_end_to_end() {
        let report = SessionBuilder::test_small(0.05)
            .fixed_rate(4.0)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(report.iters.len(), 6);
        assert!(report.final_sdr_db() > 8.0);
    }
}
