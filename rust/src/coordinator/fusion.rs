//! The fusion center: drives the iteration protocol, aggregates worker
//! uplinks, designs the per-iteration quantizer from the rate controller's
//! directive, denoises, and broadcasts the next estimate.
//!
//! The per-iteration logic lives in [`FusionState::step`] — resumable
//! state that the stepwise [`crate::coordinator::session::Session`] driver
//! advances one iteration at a time. [`run_fusion`] is the monolithic
//! wrapper (a plain loop over `step` + the `Done` barrier) kept for
//! callers that want the whole protocol in one call; both paths execute
//! the identical per-iteration code, so their numerics agree bit-for-bit.

use std::time::Instant;

use crate::alloc::schedule::{Directive, RateController};
use crate::config::{CodecKind, RunConfig};
use crate::coordinator::message::{FPayload, Message, QuantSpec};
use crate::coordinator::transport::Endpoint;
use crate::coordinator::worker::coder_for_spec;
use crate::engine::ComputeEngine;
use crate::error::{Error, Result};
use crate::metrics::IterRecord;
use crate::quant::{EncodedBlock, UniformQuantizer};
use crate::rd::RdCache;
use crate::se::prior::BgChannel;
use crate::se::StateEvolution;
use crate::signal::Instance;

/// Everything the fusion loop produces.
#[derive(Debug, Clone)]
pub struct FusionOutput {
    /// Per-iteration records.
    pub iters: Vec<IterRecord>,
    /// Final estimate `x_T`.
    pub final_x: Vec<f32>,
}

/// Design a [`QuantSpec`] from a directive, given the current σ̂².
pub fn spec_for_directive(
    directive: &Directive,
    se: &StateEvolution,
    p_workers: usize,
    sigma_d2_hat: f64,
    clip_sds: f64,
) -> Result<QuantSpec> {
    Ok(match directive {
        Directive::Raw => QuantSpec::Raw,
        Directive::Skip => QuantSpec::Skip,
        Directive::QuantizeMse(q2) => {
            let (wch, ws2) = se.channel.worker_channel(sigma_d2_hat, p_workers);
            let clip = wch.clip_range(ws2, clip_sds);
            let q = UniformQuantizer::for_mse(*q2, clip, 0.0)?;
            QuantSpec::Ecsq {
                delta: q.delta,
                k_max: q.k_max as u32,
                sigma_d2_hat,
            }
        }
        Directive::QuantizeRate(rate) => {
            let (wch, ws2) = se.channel.worker_channel(sigma_d2_hat, p_workers);
            let q = UniformQuantizer::for_rate(&wch, ws2, *rate, clip_sds, 0.0)?;
            QuantSpec::Ecsq {
                delta: q.delta,
                k_max: q.k_max as u32,
                sigma_d2_hat,
            }
        }
    })
}

/// Resumable fusion-center iteration state: the current estimate `x_t`,
/// the Onsager coefficient, and the iteration counter. One [`step`]
/// executes exactly one protocol round (broadcast → σ̂² → quantizer design
/// → fuse → denoise) against live worker endpoints.
///
/// [`step`]: FusionState::step
#[derive(Debug, Clone)]
pub struct FusionState {
    x: Vec<f32>,
    coef: f32,
    t: usize,
}

impl FusionState {
    /// Fresh state at `t = 0` with the all-zero estimate.
    pub fn new(n: usize) -> Self {
        FusionState { x: vec![0f32; n], coef: 0.0, t: 0 }
    }

    /// Iterations completed so far.
    pub fn t(&self) -> usize {
        self.t
    }

    /// The current estimate `x_t`.
    pub fn x(&self) -> &[f32] {
        &self.x
    }

    /// Consume the state, yielding the final estimate.
    pub fn into_x(self) -> Vec<f32> {
        self.x
    }

    /// Run one protocol iteration over the worker endpoints. `eval`
    /// (ground truth) fills the SDR fields of the record — it is
    /// measurement-only and never feeds back into the algorithm.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &mut self,
        cfg: &RunConfig,
        se: &StateEvolution,
        controller: &RateController,
        cache: Option<&RdCache>,
        engine: &dyn ComputeEngine,
        endpoints: &mut [Endpoint],
        eval: Option<&Instance>,
    ) -> Result<IterRecord> {
        let n = cfg.n;
        let p = cfg.p;
        let m = cfg.m as f64;
        let t = self.t;
        debug_assert_eq!(endpoints.len(), p);
        let t0 = Instant::now();
        // 1. Broadcast the step command.
        let step = Message::StepCmd { t: t as u32, coef: self.coef, x: self.x.clone() };
        for ep in endpoints.iter_mut() {
            ep.send(&step)?;
        }
        // 2. Collect ‖z‖² scalars → σ̂²_{t,D}.
        let mut znorm_sum = 0.0f64;
        for (widx, ep) in endpoints.iter_mut().enumerate() {
            match ep.recv()? {
                Message::ZNorm { t: rt, worker, z_norm2 } => {
                    if rt as usize != t || worker as usize != widx {
                        return Err(Error::Protocol(format!(
                            "fusion: bad ZNorm (t={rt}, worker={worker}) expected \
                             (t={t}, worker={widx})"
                        )));
                    }
                    znorm_sum += z_norm2;
                }
                other => {
                    return Err(Error::Protocol(format!(
                        "fusion: expected ZNorm, got {other:?}"
                    )))
                }
            }
        }
        let sigma_d2_hat = znorm_sum / m;
        // 3. Resolve the directive and broadcast the quantizer design.
        let directive =
            controller.directive(t, sigma_d2_hat, se, p, cfg.iters, cache);
        let spec = spec_for_directive(&directive, se, p, sigma_d2_hat, 8.0)?;
        let quant = Message::QuantCmd { t: t as u32, spec };
        for ep in endpoints.iter_mut() {
            ep.send(&quant)?;
        }
        // The decoder matching the workers' encoder.
        let coder = coder_for_spec(&spec, &cfg.prior, p, cfg.codec)?;
        let sigma_q2 = match &spec {
            QuantSpec::Ecsq { delta, .. } => delta * delta / 12.0,
            QuantSpec::Raw => 0.0,
            // Zero-rate: reconstruction is 0, per-worker error = Var(F^p).
            QuantSpec::Skip => {
                let (wch, ws2) = se.channel.worker_channel(sigma_d2_hat, p);
                wch.var_f(ws2)
            }
        };
        // 4. Collect and fuse the f vectors.
        let mut f_sum = vec![0f32; n];
        let mut wire_bits = 0.0f64;
        let mut rate_alloc = 0.0f64;
        for (widx, ep) in endpoints.iter_mut().enumerate() {
            let msg = ep.recv()?;
            wire_bits += msg.f_payload_bits();
            match msg {
                Message::FVector { t: rt, worker, payload } => {
                    if rt as usize != t || worker as usize != widx {
                        return Err(Error::Protocol(format!(
                            "fusion: bad FVector (t={rt}, worker={worker})"
                        )));
                    }
                    match payload {
                        FPayload::Raw(v) => {
                            if v.len() != n {
                                return Err(Error::Protocol(format!(
                                    "fusion: raw f length {} != N {n}",
                                    v.len()
                                )));
                            }
                            // Analytic codec: account model entropy instead
                            // of the raw float bits that moved in-process.
                            if let (CodecKind::Analytic, Some(c)) = (cfg.codec, &coder) {
                                wire_bits += c.entropy_bits * n as f64 - 32.0 * n as f64;
                            }
                            crate::linalg::axpy(1.0, &v, &mut f_sum);
                        }
                        FPayload::Coded { n: n_syms, bytes } => {
                            let c = coder.as_ref().ok_or_else(|| {
                                Error::Protocol("coded payload without ECSQ spec".into())
                            })?;
                            if n_syms as usize != n {
                                return Err(Error::Protocol(format!(
                                    "fusion: coded f length {n_syms} != N {n}"
                                )));
                            }
                            let block = EncodedBlock {
                                bytes,
                                wire_bits: 0.0,
                                n: n_syms as usize,
                            };
                            let mut v = vec![0f32; n];
                            c.decode(&block, None, &mut v)?;
                            crate::linalg::axpy(1.0, &v, &mut f_sum);
                        }
                        FPayload::Skipped => {}
                    }
                }
                other => {
                    return Err(Error::Protocol(format!(
                        "fusion: expected FVector, got {other:?}"
                    )))
                }
            }
        }
        // Allocation accounting (analytic rate for the record).
        rate_alloc += match &directive {
            Directive::Raw => 32.0,
            Directive::Skip => 0.0,
            Directive::QuantizeRate(r) => *r,
            Directive::QuantizeMse(_) => coder.as_ref().map(|c| c.entropy_bits).unwrap_or(0.0),
        };
        // 5. Global computation: denoise at the quantization-aware level.
        let sigma_eff2 = sigma_d2_hat + p as f64 * sigma_q2;
        let gc = engine.gc_step(&f_sum, sigma_eff2)?;
        self.x = gc.x_next;
        self.coef = (gc.eta_prime_mean / se.kappa) as f32;
        self.t = t + 1;
        // 6. Record.
        let predicted_next = se.step_quantized(sigma_d2_hat, p as f64 * sigma_q2);
        Ok(IterRecord {
            t,
            sdr_db: eval.map(|inst| inst.sdr_db(&self.x)).unwrap_or(f64::NAN),
            sdr_pred_db: se.sdr_db(predicted_next),
            rate_alloc,
            rate_wire: wire_bits / (p as f64 * n as f64),
            sigma_q2,
            sigma_d2_hat,
            wall_s: t0.elapsed().as_secs_f64(),
        })
    }

    /// Release the workers: broadcast `Done` on every endpoint.
    pub fn finish(endpoints: &mut [Endpoint]) -> Result<()> {
        for ep in endpoints.iter_mut() {
            ep.send(&Message::Done)?;
        }
        Ok(())
    }
}

/// Run the fusion protocol for `cfg.iters` iterations over the given
/// worker endpoints — a thin loop over [`FusionState::step`] followed by
/// the `Done` broadcast.
#[allow(clippy::too_many_arguments)]
pub fn run_fusion(
    cfg: &RunConfig,
    se: &StateEvolution,
    controller: &RateController,
    cache: Option<&RdCache>,
    engine: &dyn ComputeEngine,
    endpoints: &mut [Endpoint],
    eval: Option<&Instance>,
) -> Result<FusionOutput> {
    let mut state = FusionState::new(cfg.n);
    let mut iters = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        iters.push(state.step(cfg, se, controller, cache, engine, endpoints, eval)?);
    }
    FusionState::finish(endpoints)?;
    Ok(FusionOutput { iters, final_x: state.into_x() })
}

/// Model channel for the worker uplink at the given σ̂² (re-exported for
/// benches and examples that need the same construction).
pub fn worker_channel_for(
    se: &StateEvolution,
    sigma_d2_hat: f64,
    p_workers: usize,
) -> (BgChannel, f64) {
    se.channel.worker_channel(sigma_d2_hat, p_workers)
}
