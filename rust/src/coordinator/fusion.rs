//! Fusion-center entry points: the [`ProtocolState`] dispatcher the
//! stepwise [`Session`](crate::coordinator::session::Session) driver
//! advances.
//!
//! The per-iteration round logic lives **once**, in the scenario-generic
//! [`ProtocolCore`]; quantizer-spec design lives in the compression
//! registry ([`design_spec`](crate::coordinator::scenario::design_spec)
//! assembles the configured stack per directive). This module keeps the
//! thin enum that picks the monomorphized core for the configured
//! [`Partitioning`](crate::config::Partitioning), plus the model-channel
//! helper shared with benches and examples.

use crate::alloc::schedule::RateAllocator;
use crate::config::{Partitioning, RunConfig};
use crate::coordinator::scenario::{Column, ProtocolCore, Row};
use crate::coordinator::transport::Endpoint;
use crate::engine::ComputeEngine;
use crate::error::Result;
use crate::metrics::IterRecord;
use crate::rd::RdCache;
use crate::se::prior::BgChannel;
use crate::se::StateEvolution;
use crate::signal::Batch;

/// The partitioning-dispatched fusion state a [`Session`] drives — a thin
/// enum over the monomorphized [`ProtocolCore`]s, one protocol round per
/// [`step`](ProtocolState::step), whichever message type is on the wire.
///
/// [`Session`]: crate::coordinator::session::Session
pub enum ProtocolState {
    /// Row-wise MP-AMP (Han et al. 2016).
    Row(ProtocolCore<Row>),
    /// Column-wise C-MP-AMP (Ma, Lu & Baron 2017).
    Column(ProtocolCore<Column>),
}

impl ProtocolState {
    /// Fresh state at `t = 0` for the configured partitioning.
    pub fn new(batch: &Batch, cfg: &RunConfig) -> Self {
        match cfg.partitioning {
            Partitioning::Row => ProtocolState::Row(ProtocolCore::new(batch, cfg)),
            Partitioning::Column => {
                ProtocolState::Column(ProtocolCore::new(batch, cfg))
            }
        }
    }

    /// Attach a [`Telemetry`](crate::telemetry::Telemetry) handle to the
    /// underlying core (measurement-only; see
    /// [`ProtocolCore::set_telemetry`]).
    pub fn set_telemetry(&mut self, tel: crate::telemetry::Telemetry) {
        match self {
            ProtocolState::Row(s) => s.set_telemetry(tel),
            ProtocolState::Column(s) => s.set_telemetry(tel),
        }
    }

    /// Iterations completed so far.
    pub fn t(&self) -> usize {
        match self {
            ProtocolState::Row(s) => s.t(),
            ProtocolState::Column(s) => s.t(),
        }
    }

    /// The current estimate of signal `sig`.
    pub fn x(&self, sig: usize) -> &[f32] {
        match self {
            ProtocolState::Row(s) => s.x(sig),
            ProtocolState::Column(s) => s.x(sig),
        }
    }

    /// Consume the state, yielding the per-signal final estimates.
    pub fn into_xs(self) -> Vec<Vec<f32>> {
        match self {
            ProtocolState::Row(s) => s.into_xs(),
            ProtocolState::Column(s) => s.into_xs(),
        }
    }

    /// Run one protocol round over the worker endpoints.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &mut self,
        cfg: &RunConfig,
        se: &StateEvolution,
        controller: &dyn RateAllocator,
        cache: Option<&RdCache>,
        engine: &dyn ComputeEngine,
        endpoints: &mut [Endpoint],
        eval: Option<&Batch>,
    ) -> Result<IterRecord> {
        match self {
            ProtocolState::Row(s) => {
                s.step(cfg, se, controller, cache, engine, endpoints, eval)
            }
            ProtocolState::Column(s) => {
                s.step(cfg, se, controller, cache, engine, endpoints, eval)
            }
        }
    }
}

/// Model channel for the row-mode worker uplink at the given σ̂²
/// (re-exported for benches and examples that need the same construction).
pub fn worker_channel_for(
    se: &StateEvolution,
    sigma_d2_hat: f64,
    p_workers: usize,
) -> (BgChannel, f64) {
    se.channel.worker_channel(sigma_d2_hat, p_workers)
}
