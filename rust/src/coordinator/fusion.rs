//! The fusion center: drives the iteration protocol, aggregates worker
//! uplinks, designs the per-iteration quantizer from the rate controller's
//! directive, denoises (row mode) or updates the combined residual
//! (column mode), and broadcasts the next state.
//!
//! The per-iteration logic lives in [`FusionState::step`] (row-wise
//! MP-AMP) and [`ColumnFusionState::step`] (column-wise C-MP-AMP) —
//! resumable states that the stepwise
//! [`crate::coordinator::session::Session`] driver advances one iteration
//! at a time through the [`ProtocolState`] dispatcher. [`run_fusion`] is
//! the monolithic row-mode wrapper (a plain loop over `step` + the `Done`
//! barrier) kept for callers that want the whole protocol in one call;
//! both paths execute the identical per-iteration code, so their numerics
//! agree bit-for-bit.

use std::time::Instant;

use crate::alloc::schedule::{Directive, RateController};
use crate::config::{CodecKind, RunConfig};
use crate::coordinator::message::{FPayload, Message, QuantSpec};
use crate::coordinator::transport::Endpoint;
use crate::coordinator::worker::{coder_for_spec, column_coder_for_spec};
use crate::engine::ComputeEngine;
use crate::error::{Error, Result};
use crate::metrics::IterRecord;
use crate::quant::{EncodedBlock, UniformQuantizer};
use crate::rd::RdCache;
use crate::se::prior::BgChannel;
use crate::se::StateEvolution;
use crate::signal::Instance;

/// Everything the fusion loop produces.
#[derive(Debug, Clone)]
pub struct FusionOutput {
    /// Per-iteration records.
    pub iters: Vec<IterRecord>,
    /// Final estimate `x_T`.
    pub final_x: Vec<f32>,
}

/// Design a [`QuantSpec`] from a directive, given the current σ̂².
pub fn spec_for_directive(
    directive: &Directive,
    se: &StateEvolution,
    p_workers: usize,
    sigma_d2_hat: f64,
    clip_sds: f64,
) -> Result<QuantSpec> {
    Ok(match directive {
        Directive::Raw => QuantSpec::Raw,
        Directive::Skip => QuantSpec::Skip,
        Directive::QuantizeMse(q2) => {
            let (wch, ws2) = se.channel.worker_channel(sigma_d2_hat, p_workers);
            let clip = wch.clip_range(ws2, clip_sds);
            let q = UniformQuantizer::for_mse(*q2, clip, 0.0)?;
            QuantSpec::Ecsq {
                delta: q.delta,
                k_max: q.k_max as u32,
                sigma_d2_hat,
            }
        }
        Directive::QuantizeRate(rate) => {
            let (wch, ws2) = se.channel.worker_channel(sigma_d2_hat, p_workers);
            let q = UniformQuantizer::for_rate(&wch, ws2, *rate, clip_sds, 0.0)?;
            QuantSpec::Ecsq {
                delta: q.delta,
                k_max: q.k_max as u32,
                sigma_d2_hat,
            }
        }
    })
}

/// Column-mode [`QuantSpec`] design: the model channel is the Gaussian
/// uplink-message channel at variance `v_hat`, which the spec carries (in
/// its `sigma_d2_hat` field) so workers rebuild the identical coder.
pub fn column_spec_for_directive(
    directive: &Directive,
    v_hat: f64,
    clip_sds: f64,
) -> Result<QuantSpec> {
    Ok(match directive {
        Directive::Raw => QuantSpec::Raw,
        Directive::Skip => QuantSpec::Skip,
        Directive::QuantizeMse(q2) => {
            let (wch, ws2) = BgChannel::column_message_channel(v_hat);
            let clip = wch.clip_range(ws2, clip_sds);
            let q = UniformQuantizer::for_mse(*q2, clip, 0.0)?;
            QuantSpec::Ecsq {
                delta: q.delta,
                k_max: q.k_max as u32,
                sigma_d2_hat: v_hat,
            }
        }
        Directive::QuantizeRate(rate) => {
            let (wch, ws2) = BgChannel::column_message_channel(v_hat);
            let q = UniformQuantizer::for_rate(&wch, ws2, *rate, clip_sds, 0.0)?;
            QuantSpec::Ecsq {
                delta: q.delta,
                k_max: q.k_max as u32,
                sigma_d2_hat: v_hat,
            }
        }
    })
}

/// Resumable fusion-center iteration state: the current estimate `x_t`,
/// the Onsager coefficient, and the iteration counter. One [`step`]
/// executes exactly one protocol round (broadcast → σ̂² → quantizer design
/// → fuse → denoise) against live worker endpoints.
///
/// [`step`]: FusionState::step
#[derive(Debug, Clone)]
pub struct FusionState {
    x: Vec<f32>,
    coef: f32,
    t: usize,
}

impl FusionState {
    /// Fresh state at `t = 0` with the all-zero estimate.
    pub fn new(n: usize) -> Self {
        FusionState { x: vec![0f32; n], coef: 0.0, t: 0 }
    }

    /// Iterations completed so far.
    pub fn t(&self) -> usize {
        self.t
    }

    /// The current estimate `x_t`.
    pub fn x(&self) -> &[f32] {
        &self.x
    }

    /// Consume the state, yielding the final estimate.
    pub fn into_x(self) -> Vec<f32> {
        self.x
    }

    /// Run one protocol iteration over the worker endpoints. `eval`
    /// (ground truth) fills the SDR fields of the record — it is
    /// measurement-only and never feeds back into the algorithm.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &mut self,
        cfg: &RunConfig,
        se: &StateEvolution,
        controller: &RateController,
        cache: Option<&RdCache>,
        engine: &dyn ComputeEngine,
        endpoints: &mut [Endpoint],
        eval: Option<&Instance>,
    ) -> Result<IterRecord> {
        let n = cfg.n;
        let p = cfg.p;
        let m = cfg.m as f64;
        let t = self.t;
        debug_assert_eq!(endpoints.len(), p);
        let t0 = Instant::now();
        // 1. Broadcast the step command.
        let step = Message::StepCmd { t: t as u32, coef: self.coef, x: self.x.clone() };
        for ep in endpoints.iter_mut() {
            ep.send(&step)?;
        }
        // 2. Collect ‖z‖² scalars → σ̂²_{t,D}.
        let mut znorm_sum = 0.0f64;
        for (widx, ep) in endpoints.iter_mut().enumerate() {
            match ep.recv()? {
                Message::ZNorm { t: rt, worker, z_norm2 } => {
                    if rt as usize != t || worker as usize != widx {
                        return Err(Error::Protocol(format!(
                            "fusion: bad ZNorm (t={rt}, worker={worker}) expected \
                             (t={t}, worker={widx})"
                        )));
                    }
                    znorm_sum += z_norm2;
                }
                other => {
                    return Err(Error::Protocol(format!(
                        "fusion: expected ZNorm, got {other:?}"
                    )))
                }
            }
        }
        let sigma_d2_hat = znorm_sum / m;
        // 3. Resolve the directive and broadcast the quantizer design.
        let directive =
            controller.directive(t, sigma_d2_hat, se, p, cfg.iters, cache);
        let spec = spec_for_directive(&directive, se, p, sigma_d2_hat, 8.0)?;
        let quant = Message::QuantCmd { t: t as u32, spec };
        for ep in endpoints.iter_mut() {
            ep.send(&quant)?;
        }
        // The decoder matching the workers' encoder.
        let coder = coder_for_spec(&spec, &cfg.prior, p, cfg.codec)?;
        let sigma_q2 = match &spec {
            QuantSpec::Ecsq { delta, .. } => delta * delta / 12.0,
            QuantSpec::Raw => 0.0,
            // Zero-rate: reconstruction is 0, per-worker error = Var(F^p).
            QuantSpec::Skip => {
                let (wch, ws2) = se.channel.worker_channel(sigma_d2_hat, p);
                wch.var_f(ws2)
            }
        };
        // 4. Collect and fuse the f vectors.
        let mut f_sum = vec![0f32; n];
        let mut wire_bits = 0.0f64;
        let mut rate_alloc = 0.0f64;
        for (widx, ep) in endpoints.iter_mut().enumerate() {
            let msg = ep.recv()?;
            wire_bits += msg.f_payload_bits();
            match msg {
                Message::FVector { t: rt, worker, payload } => {
                    if rt as usize != t || worker as usize != widx {
                        return Err(Error::Protocol(format!(
                            "fusion: bad FVector (t={rt}, worker={worker})"
                        )));
                    }
                    match payload {
                        FPayload::Raw(v) => {
                            if v.len() != n {
                                return Err(Error::Protocol(format!(
                                    "fusion: raw f length {} != N {n}",
                                    v.len()
                                )));
                            }
                            // Analytic codec: account model entropy instead
                            // of the raw float bits that moved in-process.
                            if let (CodecKind::Analytic, Some(c)) = (cfg.codec, &coder) {
                                wire_bits += c.entropy_bits * n as f64 - 32.0 * n as f64;
                            }
                            crate::linalg::axpy(1.0, &v, &mut f_sum);
                        }
                        FPayload::Coded { n: n_syms, bytes } => {
                            let c = coder.as_ref().ok_or_else(|| {
                                Error::Protocol("coded payload without ECSQ spec".into())
                            })?;
                            if n_syms as usize != n {
                                return Err(Error::Protocol(format!(
                                    "fusion: coded f length {n_syms} != N {n}"
                                )));
                            }
                            let block = EncodedBlock {
                                bytes,
                                wire_bits: 0.0,
                                n: n_syms as usize,
                            };
                            let mut v = vec![0f32; n];
                            c.decode(&block, None, &mut v)?;
                            crate::linalg::axpy(1.0, &v, &mut f_sum);
                        }
                        FPayload::Skipped => {}
                    }
                }
                other => {
                    return Err(Error::Protocol(format!(
                        "fusion: expected FVector, got {other:?}"
                    )))
                }
            }
        }
        // Allocation accounting (analytic rate for the record).
        rate_alloc += match &directive {
            Directive::Raw => 32.0,
            Directive::Skip => 0.0,
            Directive::QuantizeRate(r) => *r,
            Directive::QuantizeMse(_) => coder.as_ref().map(|c| c.entropy_bits).unwrap_or(0.0),
        };
        // 5. Global computation: denoise at the quantization-aware level.
        let sigma_eff2 = sigma_d2_hat + p as f64 * sigma_q2;
        let gc = engine.gc_step(&f_sum, sigma_eff2)?;
        self.x = gc.x_next;
        self.coef = (gc.eta_prime_mean / se.kappa) as f32;
        self.t = t + 1;
        // 6. Record.
        let predicted_next = se.step_quantized(sigma_d2_hat, p as f64 * sigma_q2);
        Ok(IterRecord {
            t,
            sdr_db: eval.map(|inst| inst.sdr_db(&self.x)).unwrap_or(f64::NAN),
            sdr_pred_db: se.sdr_db(predicted_next),
            rate_alloc,
            rate_wire: wire_bits / (p as f64 * n as f64),
            sigma_q2,
            sigma_d2_hat,
            wall_s: t0.elapsed().as_secs_f64(),
        })
    }

    /// Release the workers: broadcast `Done` on every endpoint.
    pub fn finish(endpoints: &mut [Endpoint]) -> Result<()> {
        for ep in endpoints.iter_mut() {
            ep.send(&Message::Done)?;
        }
        Ok(())
    }
}

/// Resumable C-MP-AMP fusion state (column partitioning): the
/// measurements `y`, the combined residual `z_t`, the assembled estimate
/// (from the workers' eval shards), and the iteration counter. One
/// [`step`](ColumnFusionState::step) executes exactly one protocol round
/// (broadcast residual → scalars → quantizer design → aggregate partial
/// residuals → Onsager-corrected residual update).
///
/// The denoiser runs *at the workers* in this partitioning — the fusion
/// center only aggregates, so its per-iteration work is `O(M)`.
#[derive(Debug, Clone)]
pub struct ColumnFusionState {
    y: Vec<f32>,
    z: Vec<f32>,
    x: Vec<f32>,
    t: usize,
}

impl ColumnFusionState {
    /// Fresh state at `t = 0`: the residual starts at `y` (the estimate is
    /// all-zero), matching centralized AMP's first iteration exactly.
    pub fn new(y: Vec<f32>, n: usize) -> Self {
        ColumnFusionState { z: y.clone(), y, x: vec![0f32; n], t: 0 }
    }

    /// Iterations completed so far.
    pub fn t(&self) -> usize {
        self.t
    }

    /// The assembled estimate `x_t` (from the eval shards).
    pub fn x(&self) -> &[f32] {
        &self.x
    }

    /// Consume the state, yielding the final estimate.
    pub fn into_x(self) -> Vec<f32> {
        self.x
    }

    /// Run one C-MP-AMP protocol iteration over the worker endpoints.
    /// `eval` (ground truth) fills the SDR fields of the record — it is
    /// measurement-only and never feeds back into the algorithm.
    pub fn step(
        &mut self,
        cfg: &RunConfig,
        se: &StateEvolution,
        controller: &RateController,
        cache: Option<&RdCache>,
        endpoints: &mut [Endpoint],
        eval: Option<&Instance>,
    ) -> Result<IterRecord> {
        let p = cfg.p;
        let m_rows = cfg.m;
        let m = cfg.m as f64;
        let np = cfg.n / p;
        let t = self.t;
        debug_assert_eq!(endpoints.len(), p);
        let t0 = Instant::now();
        // 1. Broadcast the residual + the denoiser's effective noise level
        //    (the residual variance already carries the quantization noise
        //    of previous iterations — see `StateEvolution::column_residual_step`).
        let sigma_d2_hat = crate::linalg::norm2_sq(&self.z) / m;
        let step = Message::ColStep {
            t: t as u32,
            sigma_eff2: sigma_d2_hat,
            z: self.z.clone(),
        };
        for ep in endpoints.iter_mut() {
            ep.send(&step)?;
        }
        // 2. Collect the pre-uplink scalars + eval shards.
        let mut unorm_sum = 0.0f64;
        let mut deriv_mean_sum = 0.0f64;
        for (widx, ep) in endpoints.iter_mut().enumerate() {
            match ep.recv()? {
                Message::ColScalars { t: rt, worker, u_norm2, eta_prime_mean, x_shard } => {
                    if rt as usize != t || worker as usize != widx {
                        return Err(Error::Protocol(format!(
                            "fusion: bad ColScalars (t={rt}, worker={worker}) expected \
                             (t={t}, worker={widx})"
                        )));
                    }
                    if x_shard.len() != np {
                        return Err(Error::Protocol(format!(
                            "fusion: x shard length {} != N/P {np}",
                            x_shard.len()
                        )));
                    }
                    unorm_sum += u_norm2;
                    deriv_mean_sum += eta_prime_mean;
                    self.x[widx * np..(widx + 1) * np].copy_from_slice(&x_shard);
                }
                other => {
                    return Err(Error::Protocol(format!(
                        "fusion: expected ColScalars, got {other:?}"
                    )))
                }
            }
        }
        // Empirical message variance v̂ = Σ‖u^p‖²/(P·M) — the quantizer's
        // model channel (the same CLT-Gaussian for every worker).
        let v_hat = unorm_sum / (p as f64 * m);
        // 3. Resolve the directive on the residual variance (the SE state
        //    variable the allocators already understand) and design the
        //    quantizer on the message variance. BT/DP pick their σ_Q²
        //    targets under the row-mode SE — a deliberate approximation
        //    that carries over because the fused quantization noise is
        //    P·σ_Q² at the denoiser input in *both* scenarios (here via
        //    the next residual, see `StateEvolution::column_residual_step`);
        //    only the allocators' internal rate accounting keeps the row
        //    message model.
        let directive =
            controller.directive(t, sigma_d2_hat, se, p, cfg.iters, cache);
        let spec = column_spec_for_directive(&directive, v_hat, 8.0)?;
        let quant = Message::QuantCmd { t: t as u32, spec };
        for ep in endpoints.iter_mut() {
            ep.send(&quant)?;
        }
        let coder = column_coder_for_spec(&spec, cfg.codec)?;
        let sigma_q2 = match &spec {
            QuantSpec::Ecsq { delta, .. } => delta * delta / 12.0,
            QuantSpec::Raw => 0.0,
            // Zero-rate: reconstruction is 0, per-worker error = Var(U^p).
            QuantSpec::Skip => v_hat,
        };
        // 4. Aggregate the quantized partial residuals.
        let mut u_sum = vec![0f32; m_rows];
        let mut wire_bits = 0.0f64;
        let mut rate_alloc = 0.0f64;
        for (widx, ep) in endpoints.iter_mut().enumerate() {
            let msg = ep.recv()?;
            wire_bits += msg.f_payload_bits();
            match msg {
                Message::FVector { t: rt, worker, payload } => {
                    if rt as usize != t || worker as usize != widx {
                        return Err(Error::Protocol(format!(
                            "fusion: bad FVector (t={rt}, worker={worker})"
                        )));
                    }
                    match payload {
                        FPayload::Raw(v) => {
                            if v.len() != m_rows {
                                return Err(Error::Protocol(format!(
                                    "fusion: raw u length {} != M {m_rows}",
                                    v.len()
                                )));
                            }
                            // Analytic codec: account model entropy instead
                            // of the raw float bits that moved in-process.
                            if let (CodecKind::Analytic, Some(c)) = (cfg.codec, &coder) {
                                wire_bits += c.entropy_bits * m - 32.0 * m;
                            }
                            crate::linalg::axpy(1.0, &v, &mut u_sum);
                        }
                        FPayload::Coded { n: n_syms, bytes } => {
                            let c = coder.as_ref().ok_or_else(|| {
                                Error::Protocol("coded payload without ECSQ spec".into())
                            })?;
                            if n_syms as usize != m_rows {
                                return Err(Error::Protocol(format!(
                                    "fusion: coded u length {n_syms} != M {m_rows}"
                                )));
                            }
                            let block = EncodedBlock {
                                bytes,
                                wire_bits: 0.0,
                                n: n_syms as usize,
                            };
                            let mut v = vec![0f32; m_rows];
                            c.decode(&block, None, &mut v)?;
                            crate::linalg::axpy(1.0, &v, &mut u_sum);
                        }
                        FPayload::Skipped => {}
                    }
                }
                other => {
                    return Err(Error::Protocol(format!(
                        "fusion: expected FVector, got {other:?}"
                    )))
                }
            }
        }
        // Allocation accounting (analytic rate for the record).
        rate_alloc += match &directive {
            Directive::Raw => 32.0,
            Directive::Skip => 0.0,
            Directive::QuantizeRate(r) => *r,
            Directive::QuantizeMse(_) => {
                coder.as_ref().map(|c| c.entropy_bits).unwrap_or(0.0)
            }
        };
        // 5. Onsager-corrected residual update with the aggregated η′ mean
        //    (equal-size blocks ⇒ the mean of per-block means is the global
        //    mean): z_{t+1} = y − Σ û^p + coef·z_t.
        let coef = ((deriv_mean_sum / p as f64) / se.kappa) as f32;
        for i in 0..m_rows {
            self.z[i] = self.y[i] - u_sum[i] + coef * self.z[i];
        }
        self.t = t + 1;
        // 6. Record. The estimate x_{t+1} saw the residual at σ̂², so its
        //    predicted quality is one plain SE step from there; the new
        //    quantization noise shows up in the *next* residual.
        Ok(IterRecord {
            t,
            sdr_db: eval.map(|inst| inst.sdr_db(&self.x)).unwrap_or(f64::NAN),
            sdr_pred_db: se.sdr_db(se.step(sigma_d2_hat)),
            rate_alloc,
            rate_wire: wire_bits / (p as f64 * m),
            sigma_q2,
            sigma_d2_hat,
            wall_s: t0.elapsed().as_secs_f64(),
        })
    }
}

/// The partitioning-dispatched fusion state a [`Session`] drives — one
/// protocol round per [`step`](ProtocolState::step), whichever message
/// type is on the wire.
///
/// [`Session`]: crate::coordinator::session::Session
#[derive(Debug, Clone)]
pub enum ProtocolState {
    /// Row-wise MP-AMP (Han et al. 2016).
    Row(FusionState),
    /// Column-wise C-MP-AMP (Ma, Lu & Baron 2017).
    Column(ColumnFusionState),
}

impl ProtocolState {
    /// Iterations completed so far.
    pub fn t(&self) -> usize {
        match self {
            ProtocolState::Row(s) => s.t(),
            ProtocolState::Column(s) => s.t(),
        }
    }

    /// The current estimate `x_t`.
    pub fn x(&self) -> &[f32] {
        match self {
            ProtocolState::Row(s) => s.x(),
            ProtocolState::Column(s) => s.x(),
        }
    }

    /// Consume the state, yielding the final estimate.
    pub fn into_x(self) -> Vec<f32> {
        match self {
            ProtocolState::Row(s) => s.into_x(),
            ProtocolState::Column(s) => s.into_x(),
        }
    }

    /// Run one protocol iteration over the worker endpoints.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &mut self,
        cfg: &RunConfig,
        se: &StateEvolution,
        controller: &RateController,
        cache: Option<&RdCache>,
        engine: &dyn ComputeEngine,
        endpoints: &mut [Endpoint],
        eval: Option<&Instance>,
    ) -> Result<IterRecord> {
        match self {
            ProtocolState::Row(s) => {
                s.step(cfg, se, controller, cache, engine, endpoints, eval)
            }
            ProtocolState::Column(s) => {
                s.step(cfg, se, controller, cache, endpoints, eval)
            }
        }
    }
}

/// Run the fusion protocol for `cfg.iters` iterations over the given
/// worker endpoints — a thin loop over [`FusionState::step`] followed by
/// the `Done` broadcast.
#[allow(clippy::too_many_arguments)]
pub fn run_fusion(
    cfg: &RunConfig,
    se: &StateEvolution,
    controller: &RateController,
    cache: Option<&RdCache>,
    engine: &dyn ComputeEngine,
    endpoints: &mut [Endpoint],
    eval: Option<&Instance>,
) -> Result<FusionOutput> {
    let mut state = FusionState::new(cfg.n);
    let mut iters = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        iters.push(state.step(cfg, se, controller, cache, engine, endpoints, eval)?);
    }
    FusionState::finish(endpoints)?;
    Ok(FusionOutput { iters, final_x: state.into_x() })
}

/// Model channel for the worker uplink at the given σ̂² (re-exported for
/// benches and examples that need the same construction).
pub fn worker_channel_for(
    se: &StateEvolution,
    sigma_d2_hat: f64,
    p_workers: usize,
) -> (BgChannel, f64) {
    se.channel.worker_channel(sigma_d2_hat, p_workers)
}
