//! Fusion-center entry points: quantizer-spec design for both scenarios
//! and the [`ProtocolState`] dispatcher the stepwise
//! [`Session`](crate::coordinator::session::Session) driver advances.
//!
//! The per-iteration round logic lives **once**, in the scenario-generic
//! [`ProtocolCore`]; this module only keeps the spec-design helpers
//! (shared with workers, benches, and examples) and the thin enum that
//! picks the monomorphized core for the configured
//! [`Partitioning`](crate::config::Partitioning).

use crate::alloc::schedule::{Directive, RateController};
use crate::config::{Partitioning, RunConfig};
use crate::coordinator::message::QuantSpec;
use crate::coordinator::scenario::{Column, ProtocolCore, Row};
use crate::coordinator::transport::Endpoint;
use crate::engine::ComputeEngine;
use crate::error::Result;
use crate::metrics::IterRecord;
use crate::quant::UniformQuantizer;
use crate::rd::RdCache;
use crate::se::prior::BgChannel;
use crate::se::StateEvolution;
use crate::signal::Batch;

/// Design a row-mode [`QuantSpec`] from a directive, given the current σ̂².
pub fn spec_for_directive(
    directive: &Directive,
    se: &StateEvolution,
    p_workers: usize,
    sigma_d2_hat: f64,
    clip_sds: f64,
) -> Result<QuantSpec> {
    Ok(match directive {
        Directive::Raw => QuantSpec::Raw,
        Directive::Skip => QuantSpec::Skip,
        Directive::QuantizeMse(q2) => {
            let (wch, ws2) = se.channel.worker_channel(sigma_d2_hat, p_workers);
            let clip = wch.clip_range(ws2, clip_sds);
            let q = UniformQuantizer::for_mse(*q2, clip, 0.0)?;
            QuantSpec::Ecsq {
                delta: q.delta,
                k_max: q.k_max as u32,
                sigma_d2_hat,
            }
        }
        Directive::QuantizeRate(rate) => {
            let (wch, ws2) = se.channel.worker_channel(sigma_d2_hat, p_workers);
            let q = UniformQuantizer::for_rate(&wch, ws2, *rate, clip_sds, 0.0)?;
            QuantSpec::Ecsq {
                delta: q.delta,
                k_max: q.k_max as u32,
                sigma_d2_hat,
            }
        }
    })
}

/// Column-mode [`QuantSpec`] design: the model channel is the Gaussian
/// uplink-message channel at variance `v_hat`, which the spec carries (in
/// its `sigma_d2_hat` field) so workers rebuild the identical coder.
pub fn column_spec_for_directive(
    directive: &Directive,
    v_hat: f64,
    clip_sds: f64,
) -> Result<QuantSpec> {
    Ok(match directive {
        Directive::Raw => QuantSpec::Raw,
        Directive::Skip => QuantSpec::Skip,
        Directive::QuantizeMse(q2) => {
            let (wch, ws2) = BgChannel::column_message_channel(v_hat);
            let clip = wch.clip_range(ws2, clip_sds);
            let q = UniformQuantizer::for_mse(*q2, clip, 0.0)?;
            QuantSpec::Ecsq {
                delta: q.delta,
                k_max: q.k_max as u32,
                sigma_d2_hat: v_hat,
            }
        }
        Directive::QuantizeRate(rate) => {
            let (wch, ws2) = BgChannel::column_message_channel(v_hat);
            let q = UniformQuantizer::for_rate(&wch, ws2, *rate, clip_sds, 0.0)?;
            QuantSpec::Ecsq {
                delta: q.delta,
                k_max: q.k_max as u32,
                sigma_d2_hat: v_hat,
            }
        }
    })
}

/// The partitioning-dispatched fusion state a [`Session`] drives — a thin
/// enum over the monomorphized [`ProtocolCore`]s, one protocol round per
/// [`step`](ProtocolState::step), whichever message type is on the wire.
///
/// [`Session`]: crate::coordinator::session::Session
pub enum ProtocolState {
    /// Row-wise MP-AMP (Han et al. 2016).
    Row(ProtocolCore<Row>),
    /// Column-wise C-MP-AMP (Ma, Lu & Baron 2017).
    Column(ProtocolCore<Column>),
}

impl ProtocolState {
    /// Fresh state at `t = 0` for the configured partitioning.
    pub fn new(batch: &Batch, cfg: &RunConfig) -> Self {
        match cfg.partitioning {
            Partitioning::Row => ProtocolState::Row(ProtocolCore::new(batch, cfg)),
            Partitioning::Column => {
                ProtocolState::Column(ProtocolCore::new(batch, cfg))
            }
        }
    }

    /// Iterations completed so far.
    pub fn t(&self) -> usize {
        match self {
            ProtocolState::Row(s) => s.t(),
            ProtocolState::Column(s) => s.t(),
        }
    }

    /// The current estimate of signal `sig`.
    pub fn x(&self, sig: usize) -> &[f32] {
        match self {
            ProtocolState::Row(s) => s.x(sig),
            ProtocolState::Column(s) => s.x(sig),
        }
    }

    /// Consume the state, yielding the per-signal final estimates.
    pub fn into_xs(self) -> Vec<Vec<f32>> {
        match self {
            ProtocolState::Row(s) => s.into_xs(),
            ProtocolState::Column(s) => s.into_xs(),
        }
    }

    /// Run one protocol round over the worker endpoints.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &mut self,
        cfg: &RunConfig,
        se: &StateEvolution,
        controller: &RateController,
        cache: Option<&RdCache>,
        engine: &dyn ComputeEngine,
        endpoints: &mut [Endpoint],
        eval: Option<&Batch>,
    ) -> Result<IterRecord> {
        match self {
            ProtocolState::Row(s) => {
                s.step(cfg, se, controller, cache, engine, endpoints, eval)
            }
            ProtocolState::Column(s) => {
                s.step(cfg, se, controller, cache, engine, endpoints, eval)
            }
        }
    }
}

/// Model channel for the row-mode worker uplink at the given σ̂²
/// (re-exported for benches and examples that need the same construction).
pub fn worker_channel_for(
    se: &StateEvolution,
    sigma_d2_hat: f64,
    p_workers: usize,
) -> (BgChannel, f64) {
    se.channel.worker_channel(sigma_d2_hat, p_workers)
}
