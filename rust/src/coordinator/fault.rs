//! Deterministic fault injection for MP-AMP transports.
//!
//! Every degradation path the fault-tolerant protocol must survive —
//! dropped uplinks, slow workers, severed connections, corrupted frames
//! — is reproducible from a [`FaultPlan`]: a plain list of
//! `(kind, worker, round)` events, either written out explicitly
//! ([`FaultPlan::parse`]) or drawn from a seed
//! ([`FaultPlan::generate`]), so a chaos test that fails in CI replays
//! bit-for-bit on a laptop.
//!
//! A plan is installed on a worker-side transport by wrapping its
//! [`Channel`] in a [`FaultChannel`]
//! (via [`Endpoint::wrap_channel`](crate::coordinator::transport::Endpoint::wrap_channel)
//! — [`Session`](crate::coordinator::session::Session) does this
//! automatically when a plan is set on the builder), or consulted
//! directly by the daemon's fleet loop, which simulates kills by
//! severing the real mux socket so the reconnect path is exercised.
//!
//! # Worked example
//!
//! Kill worker 1 at round 2 and delay worker 0 by 40 ms at round 1,
//! then run an elastic session that must absorb both:
//!
//! ```no_run
//! use std::sync::Arc;
//! use mpamp::coordinator::fault::FaultPlan;
//! use mpamp::SessionBuilder;
//!
//! let plan = FaultPlan::parse("kill:w=1,t=2;delay:w=0,t=1,ms=40")?;
//! let report = SessionBuilder::test_small(0.05)
//!     .min_workers(4)              // K: proceed on any 4 of the 6 uplinks
//!     .round_deadline_ms(100)      // per-round reply deadline
//!     .fault_plan(Arc::new(plan))
//!     .build()?
//!     .run()?;
//! println!("survived with final SDR {:.2} dB", report.final_sdr_db());
//! # Ok::<(), mpamp::Error>(())
//! ```
//!
//! With `min_workers` at its default (0 = require all `P`) the same
//! plan fails the session with a typed
//! [`Error::Transport`](crate::Error::Transport) /
//! [`Error::Degraded`](crate::Error::Degraded) instead — never a hang:
//! the round deadline bounds every wait.

use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::message::{TAG_COLSTEP, TAG_FVEC, TAG_STEP};
use crate::coordinator::transport::{Channel, RecvStatus};
use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// One injected fault, targeting `(worker, round)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// The worker's coded uplink frame for round `round` is silently
    /// never sent (the pre-uplink scalar reply still goes out).
    DropUplink {
        /// Target worker id.
        worker: u32,
        /// Round whose `FVector` vanishes.
        round: u32,
    },
    /// The worker stalls `ms` milliseconds before serving round
    /// `round`'s broadcast — a straggler, not a death.
    Delay {
        /// Target worker id.
        worker: u32,
        /// Round that arrives late.
        round: u32,
        /// Stall length in milliseconds.
        ms: u64,
    },
    /// The worker's connection dies at the start of round `round` and
    /// every later operation on it fails. Standalone sessions lose the
    /// worker for good; the daemon's fleet loop severs the real socket
    /// so the reconnect-with-backoff path brings the worker back.
    KillConn {
        /// Target worker id.
        worker: u32,
        /// Round at which the connection is severed.
        round: u32,
    },
    /// The worker-id field of round `round`'s uplink frame is flipped
    /// before sending, so fusion-side validation deterministically
    /// rejects the frame (a detectable corruption, not a silent one).
    Corrupt {
        /// Target worker id.
        worker: u32,
        /// Round whose uplink frame is corrupted.
        round: u32,
    },
}

impl Fault {
    /// The worker this fault targets.
    pub fn worker(&self) -> u32 {
        match *self {
            Fault::DropUplink { worker, .. }
            | Fault::Delay { worker, .. }
            | Fault::KillConn { worker, .. }
            | Fault::Corrupt { worker, .. } => worker,
        }
    }

    /// The round this fault fires at.
    pub fn round(&self) -> u32 {
        match *self {
            Fault::DropUplink { round, .. }
            | Fault::Delay { round, .. }
            | Fault::KillConn { round, .. }
            | Fault::Corrupt { round, .. } => round,
        }
    }

    fn render(&self) -> String {
        match *self {
            Fault::DropUplink { worker, round } => format!("drop:w={worker},t={round}"),
            Fault::Delay { worker, round, ms } => {
                format!("delay:w={worker},t={round},ms={ms}")
            }
            Fault::KillConn { worker, round } => format!("kill:w={worker},t={round}"),
            Fault::Corrupt { worker, round } => format!("corrupt:w={worker},t={round}"),
        }
    }
}

/// A deterministic set of faults to inject into one session or served
/// workload. Plans are plain data: identical plans produce identical
/// degradations on identical configs.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// The injected faults, in no particular order.
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    /// A plan injecting nothing (the fault-free baseline).
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Draw `n_faults` faults from `seed`, targeting rounds `< rounds`
    /// and workers `< p`. Deterministic: the same arguments always
    /// yield the same plan (the proptest harness sweeps seeds).
    pub fn generate(seed: u64, rounds: u32, p: u32, n_faults: usize) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xFA017_F1A9);
        let mut faults = Vec::with_capacity(n_faults);
        for _ in 0..n_faults {
            let worker = rng.below(p.max(1) as u64) as u32;
            let round = rng.below(rounds.max(1) as u64) as u32;
            faults.push(match rng.below(4) {
                0 => Fault::DropUplink { worker, round },
                1 => Fault::Delay { worker, round, ms: 5 + rng.below(40) },
                2 => Fault::KillConn { worker, round },
                _ => Fault::Corrupt { worker, round },
            });
        }
        FaultPlan { faults }
    }

    /// Parse the `--fault-plan` syntax: `;`-separated events, each
    /// `kind:w=<worker>,t=<round>[,ms=<ms>]` with kind one of `drop`,
    /// `delay`, `kill`, `corrupt`. Example:
    /// `"kill:w=1,t=2;delay:w=0,t=1,ms=40"`.
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut faults = Vec::new();
        for ev in s.split(';').map(str::trim).filter(|e| !e.is_empty()) {
            let (kind, fields) = ev.split_once(':').ok_or_else(|| {
                Error::Config(format!("fault '{ev}': expected kind:w=..,t=.."))
            })?;
            let mut worker = None;
            let mut round = None;
            let mut ms = None;
            for field in fields.split(',').map(str::trim) {
                let (key, val) = field.split_once('=').ok_or_else(|| {
                    Error::Config(format!("fault '{ev}': bad field '{field}'"))
                })?;
                let val: u64 = val.trim().parse().map_err(|_| {
                    Error::Config(format!("fault '{ev}': non-numeric '{val}'"))
                })?;
                match key.trim() {
                    "w" => worker = Some(val as u32),
                    "t" => round = Some(val as u32),
                    "ms" => ms = Some(val),
                    other => {
                        return Err(Error::Config(format!(
                            "fault '{ev}': unknown field '{other}'"
                        )))
                    }
                }
            }
            let worker = worker
                .ok_or_else(|| Error::Config(format!("fault '{ev}': missing w=")))?;
            let round = round
                .ok_or_else(|| Error::Config(format!("fault '{ev}': missing t=")))?;
            faults.push(match kind.trim() {
                "drop" => Fault::DropUplink { worker, round },
                "delay" => Fault::Delay {
                    worker,
                    round,
                    ms: ms.ok_or_else(|| {
                        Error::Config(format!("fault '{ev}': delay needs ms="))
                    })?,
                },
                "kill" => Fault::KillConn { worker, round },
                "corrupt" => Fault::Corrupt { worker, round },
                other => {
                    return Err(Error::Config(format!("unknown fault kind '{other}'")))
                }
            });
        }
        Ok(FaultPlan { faults })
    }

    /// Render back to the [`parse`](FaultPlan::parse) syntax.
    pub fn render(&self) -> String {
        self.faults.iter().map(Fault::render).collect::<Vec<_>>().join(";")
    }

    /// Is the plan empty?
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Should `worker`'s round-`round` uplink frame vanish?
    pub fn should_drop(&self, worker: u32, round: u32) -> bool {
        self.faults
            .iter()
            .any(|f| matches!(f, Fault::DropUplink { worker: w, round: r } if *w == worker && *r == round))
    }

    /// Milliseconds `worker` stalls before serving round `round` (sum
    /// of all matching delay faults).
    pub fn delay_ms(&self, worker: u32, round: u32) -> u64 {
        self.faults
            .iter()
            .filter_map(|f| match f {
                Fault::Delay { worker: w, round: r, ms } if *w == worker && *r == round => {
                    Some(*ms)
                }
                _ => None,
            })
            .sum()
    }

    /// Does `worker`'s connection die at (or before) round `round`?
    pub fn should_kill(&self, worker: u32, round: u32) -> bool {
        self.faults
            .iter()
            .any(|f| matches!(f, Fault::KillConn { worker: w, round: r } if *w == worker && *r <= round))
    }

    /// Should `worker`'s round-`round` uplink frame be corrupted?
    pub fn should_corrupt(&self, worker: u32, round: u32) -> bool {
        self.faults
            .iter()
            .any(|f| matches!(f, Fault::Corrupt { worker: w, round: r } if *w == worker && *r == round))
    }
}

/// `(tag, t)` of a protocol frame, when it has one (`Done` frames are a
/// bare tag byte and carry no round).
pub(crate) fn frame_round(frame: &[u8]) -> Option<(u8, u32)> {
    if frame.len() < 5 {
        return None;
    }
    Some((frame[0], u32::from_le_bytes(frame[1..5].try_into().ok()?)))
}

/// A [`Channel`] wrapper executing a [`FaultPlan`] against one worker's
/// link: drops/corrupts matching uplink frames on the send path, stalls
/// round-opening broadcasts on the receive path, and — once a kill
/// round is reached — fails every subsequent operation the way a
/// severed connection would.
pub struct FaultChannel {
    inner: Box<dyn Channel>,
    plan: Arc<FaultPlan>,
    worker: u32,
    killed: bool,
}

impl FaultChannel {
    /// Wrap `inner` so `plan`'s faults targeting `worker` fire.
    pub fn new(inner: Box<dyn Channel>, plan: Arc<FaultPlan>, worker: u32) -> Self {
        FaultChannel { inner, plan, worker, killed: false }
    }

    fn killed_err(&self, round: u32) -> Error {
        Error::Transport(format!(
            "connection killed by fault plan at round {round} (worker {})",
            self.worker
        ))
    }

    /// Check a frame's round against the plan's kill schedule; latch
    /// the killed state the first time it fires.
    fn check_kill(&mut self, round: u32) -> Result<()> {
        if self.plan.should_kill(self.worker, round) {
            self.killed = true;
            return Err(self.killed_err(round));
        }
        Ok(())
    }
}

impl Channel for FaultChannel {
    fn send_bytes(&mut self, buf: &[u8]) -> Result<()> {
        if self.killed {
            return Err(Error::Transport(format!(
                "connection killed by fault plan (worker {})",
                self.worker
            )));
        }
        let Some((tag, t)) = frame_round(buf) else {
            return self.inner.send_bytes(buf);
        };
        self.check_kill(t)?;
        if tag == TAG_FVEC {
            if self.plan.should_drop(self.worker, t) {
                return Ok(()); // the uplink frame vanishes in transit
            }
            if self.plan.should_corrupt(self.worker, t) {
                // Flip a worker-id byte (offset 5 of the fvector header)
                // so fusion-side validation rejects the frame
                // deterministically instead of fusing garbage.
                let mut corrupted = buf.to_vec();
                corrupted[5] ^= 0x20;
                return self.inner.send_bytes(&corrupted);
            }
        }
        self.inner.send_bytes(buf)
    }

    fn recv_bytes_into(&mut self, buf: &mut Vec<u8>) -> Result<()> {
        if self.killed {
            return Err(Error::Transport(format!(
                "connection killed by fault plan (worker {})",
                self.worker
            )));
        }
        self.inner.recv_bytes_into(buf)?;
        if let Some((tag, t)) = frame_round(buf) {
            self.check_kill(t)?;
            if tag == TAG_STEP || tag == TAG_COLSTEP {
                let ms = self.plan.delay_ms(self.worker, t);
                if ms > 0 {
                    std::thread::sleep(Duration::from_millis(ms));
                }
            }
        }
        Ok(())
    }

    fn recv_bytes_into_by(
        &mut self,
        buf: &mut Vec<u8>,
        timeout: Duration,
    ) -> Result<RecvStatus> {
        if self.killed {
            return Err(Error::Transport(format!(
                "connection killed by fault plan (worker {})",
                self.worker
            )));
        }
        let status = self.inner.recv_bytes_into_by(buf, timeout)?;
        if status == RecvStatus::Frame {
            if let Some((tag, t)) = frame_round(buf) {
                self.check_kill(t)?;
                if tag == TAG_STEP || tag == TAG_COLSTEP {
                    let ms = self.plan.delay_ms(self.worker, t);
                    if ms > 0 {
                        std::thread::sleep(Duration::from_millis(ms));
                    }
                }
            }
        }
        Ok(status)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_render_roundtrip() {
        let plan =
            FaultPlan::parse("drop:w=1,t=2; delay:w=0,t=1,ms=50;kill:w=2,t=3;corrupt:w=1,t=4")
                .unwrap();
        assert_eq!(plan.faults.len(), 4);
        assert!(plan.should_drop(1, 2) && !plan.should_drop(1, 3));
        assert_eq!(plan.delay_ms(0, 1), 50);
        assert!(plan.should_kill(2, 3) && plan.should_kill(2, 7), "kill is sticky");
        assert!(!plan.should_kill(2, 2));
        assert!(plan.should_corrupt(1, 4));
        let reparsed = FaultPlan::parse(&plan.render()).unwrap();
        assert_eq!(plan, reparsed);
    }

    #[test]
    fn parse_rejects_malformed_plans() {
        for bad in [
            "explode:w=1,t=2",
            "drop:w=1",
            "drop:t=2",
            "delay:w=1,t=2",
            "drop:w=x,t=2",
            "drop:w=1,t=2,zz=3",
            "droppity",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted {bad:?}");
        }
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn generate_is_deterministic_and_in_bounds() {
        let a = FaultPlan::generate(7, 6, 4, 8);
        let b = FaultPlan::generate(7, 6, 4, 8);
        assert_eq!(a, b);
        assert_eq!(a.faults.len(), 8);
        for f in &a.faults {
            assert!(f.worker() < 4, "{f:?}");
            assert!(f.round() < 6, "{f:?}");
        }
        let c = FaultPlan::generate(8, 6, 4, 8);
        assert_ne!(a, c, "different seeds should differ");
    }

    #[test]
    fn fault_channel_drops_corrupts_and_kills() {
        use crate::coordinator::transport::inproc_pair;
        use crate::metrics::ByteMeter;
        let meter = Arc::new(ByteMeter::new());
        let (mut fusion, mut worker) = inproc_pair(meter);
        let plan = Arc::new(
            FaultPlan::parse("drop:w=0,t=1;corrupt:w=0,t=2;kill:w=0,t=3").unwrap(),
        );
        worker.wrap_channel(|inner| Box::new(FaultChannel::new(inner, plan, 0)));

        // Round 0: untouched fvector passes through.
        let mk_fvec = |t: u32| {
            let mut f = vec![TAG_FVEC];
            f.extend_from_slice(&t.to_le_bytes());
            f.extend_from_slice(&0u32.to_le_bytes()); // worker id
            f.extend_from_slice(&1u32.to_le_bytes()); // payload count
            f.push(9); // payload byte
            f
        };
        worker.send_encoded(&mk_fvec(0)).unwrap();
        assert_eq!(fusion.recv_frame().unwrap(), &mk_fvec(0)[..]);

        // Round 1: dropped — nothing arrives (bounded probe times out).
        worker.send_encoded(&mk_fvec(1)).unwrap();
        assert!(fusion.recv_frame_by(Duration::from_millis(30)).unwrap().is_none());

        // Round 2: corrupted worker-id field.
        worker.send_encoded(&mk_fvec(2)).unwrap();
        let got = fusion.recv_frame().unwrap();
        assert_eq!(u32::from_le_bytes(got[5..9].try_into().unwrap()), 0x20);

        // Round 3: the connection dies and stays dead.
        let err = worker.send_encoded(&mk_fvec(3)).unwrap_err();
        assert!(err.is_peer_loss(), "kill should read as peer loss: {err}");
        let err = worker.send_encoded(&mk_fvec(4)).unwrap_err();
        assert!(err.to_string().contains("killed"), "{err}");
    }
}
