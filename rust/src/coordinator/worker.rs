//! The worker processor loops, one per [`Partitioning`]:
//!
//! * [`run_worker`] (row mode) owns an `(M/P) × N` row block plus `y^p`,
//!   runs the LC step on command, and uplinks `‖z‖²` scalars and the
//!   (entropy-coded) local estimate `f_t^p`;
//! * [`run_column_worker`] (column mode, C-MP-AMP) owns an `M × (N/P)`
//!   column block plus its slice of the estimate, denoises locally
//!   against the broadcast residual, and uplinks the (entropy-coded)
//!   partial residual `u_t^p = A^p x_t^p`.
//!
//! [`Partitioning`]: crate::config::Partitioning

use crate::config::CodecKind;
use crate::coordinator::message::{FPayload, Message, QuantSpec};
use crate::coordinator::transport::Endpoint;
use crate::engine::{ColumnWorkerData, ComputeEngine, WorkerData};
use crate::error::{Error, Result};
use crate::quant::{EcsqCoder, UniformQuantizer};
use crate::se::prior::BgChannel;
use crate::signal::BernoulliGauss;

/// Static parameters a worker needs beyond its data shard.
#[derive(Debug, Clone)]
pub struct WorkerParams {
    /// This worker's id.
    pub id: u32,
    /// Total number of workers P.
    pub p_workers: usize,
    /// Source prior (for model-pmf reconstruction).
    pub prior: BernoulliGauss,
    /// Wire codec.
    pub codec: CodecKind,
}

/// Build the ECSQ coder implied by a [`QuantSpec`] (both sides call this —
/// determinism of the model pmf is what keeps the codec in sync).
pub fn coder_for_spec(
    spec: &QuantSpec,
    prior: &BernoulliGauss,
    p_workers: usize,
    codec: CodecKind,
) -> Result<Option<EcsqCoder>> {
    match spec {
        QuantSpec::Raw | QuantSpec::Skip => Ok(None),
        QuantSpec::Ecsq { delta, k_max, sigma_d2_hat } => {
            let base = BgChannel::new(*prior);
            let (wch, ws2) = base.worker_channel(*sigma_d2_hat, p_workers);
            let q = UniformQuantizer { delta: *delta, k_max: *k_max as i32, center: 0.0 };
            Ok(Some(EcsqCoder::new(q, &wch, ws2, codec)?))
        }
    }
}

/// Column-mode analogue of [`coder_for_spec`]: the message model is the
/// Gaussian column-uplink channel rebuilt from the variance estimate the
/// spec carries (its `sigma_d2_hat` field holds `v̂ = Σ‖u^p‖²/(P·M)` in
/// column mode). Deterministic on both sides, like the row path.
pub fn column_coder_for_spec(
    spec: &QuantSpec,
    codec: CodecKind,
) -> Result<Option<EcsqCoder>> {
    match spec {
        QuantSpec::Raw | QuantSpec::Skip => Ok(None),
        QuantSpec::Ecsq { delta, k_max, sigma_d2_hat } => {
            let (wch, ws2) = BgChannel::column_message_channel(*sigma_d2_hat);
            let q = UniformQuantizer { delta: *delta, k_max: *k_max as i32, center: 0.0 };
            Ok(Some(EcsqCoder::new(q, &wch, ws2, codec)?))
        }
    }
}

/// Code one uplink vector according to the spec, using the given coder
/// builder (row and column workers differ only in the model channel).
fn payload_for_spec(
    v: Vec<f32>,
    spec: &QuantSpec,
    codec: CodecKind,
    coder: Option<EcsqCoder>,
) -> Result<FPayload> {
    Ok(match spec {
        QuantSpec::Raw => FPayload::Raw(v),
        QuantSpec::Skip => FPayload::Skipped,
        QuantSpec::Ecsq { .. } => {
            let coder = coder.expect("ECSQ spec yields a coder");
            let syms = coder.quantizer.quantize_block(&v);
            match codec {
                CodecKind::Analytic => {
                    // Entropy-accounted, not entropy-coded: ship the
                    // dequantized values so numerics match the coded path
                    // exactly.
                    let mut deq = vec![0f32; v.len()];
                    coder.quantizer.dequantize_block(&syms, &mut deq);
                    FPayload::Raw(deq)
                }
                CodecKind::Range | CodecKind::Huffman => {
                    let block = coder.encode_symbols(&syms)?;
                    FPayload::Coded { n: block.n as u32, bytes: block.bytes }
                }
            }
        }
    })
}

/// Run the worker protocol until `Done`. Returns the number of iterations
/// served (for tests / sanity checks).
pub fn run_worker(
    params: &WorkerParams,
    data: &WorkerData,
    engine: &dyn ComputeEngine,
    endpoint: &mut Endpoint,
) -> Result<usize> {
    let mp = data.a.rows();
    let mut z_prev = vec![0f32; mp];
    let mut f_cur: Option<Vec<f32>> = None;
    let mut iters = 0usize;
    loop {
        match endpoint.recv()? {
            Message::StepCmd { t, coef, x } => {
                if x.len() != data.a.cols() {
                    return Err(Error::Protocol(format!(
                        "worker {}: x length {} != N {}",
                        params.id,
                        x.len(),
                        data.a.cols()
                    )));
                }
                let out = engine.lc_step(data, &x, &z_prev, coef, params.p_workers)?;
                z_prev = out.z;
                endpoint.send(&Message::ZNorm {
                    t,
                    worker: params.id,
                    z_norm2: out.z_norm2,
                })?;
                f_cur = Some(out.f_partial);
                iters += 1;
            }
            Message::QuantCmd { t, spec } => {
                let f = f_cur.take().ok_or_else(|| {
                    Error::Protocol(format!(
                        "worker {}: QuantCmd before StepCmd at t={t}",
                        params.id
                    ))
                })?;
                let coder =
                    coder_for_spec(&spec, &params.prior, params.p_workers, params.codec)?;
                let payload = payload_for_spec(f, &spec, params.codec, coder)?;
                endpoint.send(&Message::FVector { t, worker: params.id, payload })?;
            }
            Message::Done => return Ok(iters),
            other => {
                return Err(Error::Protocol(format!(
                    "worker {}: unexpected message {other:?}",
                    params.id
                )))
            }
        }
    }
}

/// Run the column-mode (C-MP-AMP) worker protocol until `Done`: hold the
/// local estimate block across iterations, denoise against each broadcast
/// residual, and uplink quantized partial residuals `u_t^p = A^p x_t^p`.
/// Returns the number of iterations served.
pub fn run_column_worker(
    params: &WorkerParams,
    data: &ColumnWorkerData,
    engine: &dyn ComputeEngine,
    endpoint: &mut Endpoint,
) -> Result<usize> {
    let np = data.a.cols();
    let mut x = vec![0f32; np];
    let mut u_cur: Option<Vec<f32>> = None;
    let mut iters = 0usize;
    loop {
        match endpoint.recv()? {
            Message::ColStep { t, sigma_eff2, z } => {
                if z.len() != data.a.rows() {
                    return Err(Error::Protocol(format!(
                        "worker {}: z length {} != M {}",
                        params.id,
                        z.len(),
                        data.a.rows()
                    )));
                }
                let out = engine.col_lc_step(data, &x, &z, sigma_eff2)?;
                x = out.x_next;
                endpoint.send(&Message::ColScalars {
                    t,
                    worker: params.id,
                    u_norm2: out.u_norm2,
                    eta_prime_mean: out.eta_prime_mean,
                    x_shard: x.clone(),
                })?;
                u_cur = Some(out.u);
                iters += 1;
            }
            Message::QuantCmd { t, spec } => {
                let u = u_cur.take().ok_or_else(|| {
                    Error::Protocol(format!(
                        "worker {}: QuantCmd before ColStep at t={t}",
                        params.id
                    ))
                })?;
                let coder = column_coder_for_spec(&spec, params.codec)?;
                let payload = payload_for_spec(u, &spec, params.codec, coder)?;
                endpoint.send(&Message::FVector { t, worker: params.id, payload })?;
            }
            Message::Done => return Ok(iters),
            other => {
                return Err(Error::Protocol(format!(
                    "worker {}: unexpected message {other:?}",
                    params.id
                )))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::RustEngine;
    use crate::signal::{Instance, ProblemDims};
    use crate::util::rng::Rng;

    #[test]
    fn coder_for_spec_deterministic_across_sides() {
        let prior = BernoulliGauss::standard(0.05);
        let spec = QuantSpec::Ecsq { delta: 0.01, k_max: 150, sigma_d2_hat: 0.08 };
        let a = coder_for_spec(&spec, &prior, 30, CodecKind::Range).unwrap().unwrap();
        let b = coder_for_spec(&spec, &prior, 30, CodecKind::Range).unwrap().unwrap();
        assert_eq!(a.pmf, b.pmf);
        assert_eq!(a.quantizer, b.quantizer);
    }

    #[test]
    fn column_coder_deterministic_and_gaussian_modeled() {
        let spec = QuantSpec::Ecsq { delta: 0.004, k_max: 120, sigma_d2_hat: 0.03 };
        let a = column_coder_for_spec(&spec, CodecKind::Range).unwrap().unwrap();
        let b = column_coder_for_spec(&spec, CodecKind::Range).unwrap().unwrap();
        assert_eq!(a.pmf, b.pmf);
        assert_eq!(a.quantizer, b.quantizer);
        // The model pmf is symmetric (zero-mean Gaussian message).
        let n = a.pmf.len();
        for i in 0..n / 2 {
            assert!((a.pmf[i] - a.pmf[n - 1 - i]).abs() < 1e-12, "bin {i}");
        }
        // Raw/Skip specs need no coder.
        assert!(column_coder_for_spec(&QuantSpec::Raw, CodecKind::Range)
            .unwrap()
            .is_none());
    }

    #[test]
    fn column_worker_rejects_quant_before_step() {
        let prior = BernoulliGauss::standard(0.1);
        let mut rng = Rng::new(2);
        let inst = Instance::generate(
            prior,
            ProblemDims { n: 50, m: 10, sigma_e2: 1e-3 },
            &mut rng,
        )
        .unwrap();
        let data = ColumnWorkerData::try_split(&inst.a, 2).unwrap().remove(0);
        let engine = RustEngine::new(prior, 1);
        let params =
            WorkerParams { id: 0, p_workers: 2, prior, codec: CodecKind::Range };
        let meter = std::sync::Arc::new(crate::metrics::ByteMeter::new());
        let (mut fusion_ep, mut worker_ep) =
            crate::coordinator::transport::inproc_pair(meter);
        let h = std::thread::spawn(move || {
            run_column_worker(&params, &data, &engine, &mut worker_ep)
        });
        fusion_ep
            .send(&Message::QuantCmd { t: 0, spec: QuantSpec::Raw })
            .unwrap();
        let err = h.join().unwrap();
        assert!(err.is_err(), "expected protocol error, got {err:?}");
    }

    #[test]
    fn worker_rejects_quant_before_step() {
        let prior = BernoulliGauss::standard(0.1);
        let mut rng = Rng::new(1);
        let inst = Instance::generate(
            prior,
            ProblemDims { n: 50, m: 10, sigma_e2: 1e-3 },
            &mut rng,
        )
        .unwrap();
        let data = WorkerData::try_split(&inst.a, &inst.y, 2).unwrap().remove(0);
        let engine = RustEngine::new(prior, 1);
        let params =
            WorkerParams { id: 0, p_workers: 2, prior, codec: CodecKind::Range };
        let meter = std::sync::Arc::new(crate::metrics::ByteMeter::new());
        let (mut fusion_ep, mut worker_ep) =
            crate::coordinator::transport::inproc_pair(meter);
        let h = std::thread::spawn(move || {
            run_worker(&params, &data, &engine, &mut worker_ep)
        });
        fusion_ep
            .send(&Message::QuantCmd { t: 0, spec: QuantSpec::Raw })
            .unwrap();
        let err = h.join().unwrap();
        assert!(err.is_err(), "expected protocol error, got {err:?}");
    }
}
