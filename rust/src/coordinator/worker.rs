//! The worker processor loop: owns one row block of the sensing matrix,
//! runs the LC step on command, and uplinks `‖z‖²` scalars and the
//! (entropy-coded) local estimate `f_t^p`.

use crate::config::CodecKind;
use crate::coordinator::message::{FPayload, Message, QuantSpec};
use crate::coordinator::transport::Endpoint;
use crate::engine::{ComputeEngine, WorkerData};
use crate::error::{Error, Result};
use crate::quant::{EcsqCoder, UniformQuantizer};
use crate::se::prior::BgChannel;
use crate::signal::BernoulliGauss;

/// Static parameters a worker needs beyond its data shard.
#[derive(Debug, Clone)]
pub struct WorkerParams {
    /// This worker's id.
    pub id: u32,
    /// Total number of workers P.
    pub p_workers: usize,
    /// Source prior (for model-pmf reconstruction).
    pub prior: BernoulliGauss,
    /// Wire codec.
    pub codec: CodecKind,
}

/// Build the ECSQ coder implied by a [`QuantSpec`] (both sides call this —
/// determinism of the model pmf is what keeps the codec in sync).
pub fn coder_for_spec(
    spec: &QuantSpec,
    prior: &BernoulliGauss,
    p_workers: usize,
    codec: CodecKind,
) -> Result<Option<EcsqCoder>> {
    match spec {
        QuantSpec::Raw | QuantSpec::Skip => Ok(None),
        QuantSpec::Ecsq { delta, k_max, sigma_d2_hat } => {
            let base = BgChannel::new(*prior);
            let (wch, ws2) = base.worker_channel(*sigma_d2_hat, p_workers);
            let q = UniformQuantizer { delta: *delta, k_max: *k_max as i32, center: 0.0 };
            Ok(Some(EcsqCoder::new(q, &wch, ws2, codec)?))
        }
    }
}

/// Run the worker protocol until `Done`. Returns the number of iterations
/// served (for tests / sanity checks).
pub fn run_worker(
    params: &WorkerParams,
    data: &WorkerData,
    engine: &dyn ComputeEngine,
    endpoint: &mut Endpoint,
) -> Result<usize> {
    let mp = data.a.rows();
    let mut z_prev = vec![0f32; mp];
    let mut f_cur: Option<Vec<f32>> = None;
    let mut iters = 0usize;
    loop {
        match endpoint.recv()? {
            Message::StepCmd { t, coef, x } => {
                if x.len() != data.a.cols() {
                    return Err(Error::Protocol(format!(
                        "worker {}: x length {} != N {}",
                        params.id,
                        x.len(),
                        data.a.cols()
                    )));
                }
                let out = engine.lc_step(data, &x, &z_prev, coef, params.p_workers)?;
                z_prev = out.z;
                endpoint.send(&Message::ZNorm {
                    t,
                    worker: params.id,
                    z_norm2: out.z_norm2,
                })?;
                f_cur = Some(out.f_partial);
                iters += 1;
            }
            Message::QuantCmd { t, spec } => {
                let f = f_cur.take().ok_or_else(|| {
                    Error::Protocol(format!(
                        "worker {}: QuantCmd before StepCmd at t={t}",
                        params.id
                    ))
                })?;
                let payload = match &spec {
                    QuantSpec::Raw => FPayload::Raw(f),
                    QuantSpec::Skip => FPayload::Skipped,
                    QuantSpec::Ecsq { .. } => {
                        let coder = coder_for_spec(
                            &spec,
                            &params.prior,
                            params.p_workers,
                            params.codec,
                        )?
                        .expect("ECSQ spec yields a coder");
                        let syms = coder.quantizer.quantize_block(&f);
                        match params.codec {
                            CodecKind::Analytic => {
                                // Entropy-accounted, not entropy-coded: ship
                                // the dequantized values so numerics match
                                // the coded path exactly.
                                let mut deq = vec![0f32; f.len()];
                                coder.quantizer.dequantize_block(&syms, &mut deq);
                                FPayload::Raw(deq)
                            }
                            CodecKind::Range | CodecKind::Huffman => {
                                let block = coder.encode_symbols(&syms)?;
                                FPayload::Coded {
                                    n: block.n as u32,
                                    bytes: block.bytes,
                                }
                            }
                        }
                    }
                };
                endpoint.send(&Message::FVector { t, worker: params.id, payload })?;
            }
            Message::Done => return Ok(iters),
            other => {
                return Err(Error::Protocol(format!(
                    "worker {}: unexpected message {other:?}",
                    params.id
                )))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::RustEngine;
    use crate::signal::{Instance, ProblemDims};
    use crate::util::rng::Rng;

    #[test]
    fn coder_for_spec_deterministic_across_sides() {
        let prior = BernoulliGauss::standard(0.05);
        let spec = QuantSpec::Ecsq { delta: 0.01, k_max: 150, sigma_d2_hat: 0.08 };
        let a = coder_for_spec(&spec, &prior, 30, CodecKind::Range).unwrap().unwrap();
        let b = coder_for_spec(&spec, &prior, 30, CodecKind::Range).unwrap().unwrap();
        assert_eq!(a.pmf, b.pmf);
        assert_eq!(a.quantizer, b.quantizer);
    }

    #[test]
    fn worker_rejects_quant_before_step() {
        let prior = BernoulliGauss::standard(0.1);
        let mut rng = Rng::new(1);
        let inst = Instance::generate(
            prior,
            ProblemDims { n: 50, m: 10, sigma_e2: 1e-3 },
            &mut rng,
        )
        .unwrap();
        let data = WorkerData::try_split(&inst.a, &inst.y, 2).unwrap().remove(0);
        let engine = RustEngine::new(prior, 1);
        let params =
            WorkerParams { id: 0, p_workers: 2, prior, codec: CodecKind::Range };
        let meter = std::sync::Arc::new(crate::metrics::ByteMeter::new());
        let (mut fusion_ep, mut worker_ep) =
            crate::coordinator::transport::inproc_pair(meter);
        let h = std::thread::spawn(move || {
            run_worker(&params, &data, &engine, &mut worker_ep)
        });
        fusion_ep
            .send(&Message::QuantCmd { t: 0, spec: QuantSpec::Raw })
            .unwrap();
        let err = h.join().unwrap();
        assert!(err.is_err(), "expected protocol error, got {err:?}");
    }
}
