//! The generic worker processor loop.
//!
//! [`run_scenario_worker`] serves protocol rounds for **any**
//! [`Scenario`]: each round it hands the broadcast frame to
//! [`Scenario::worker_serve`] (zero-copy borrowed decode, local step,
//! pre-uplink reply), then codes the pending per-signal uplink vectors
//! when the batched `QuantCmd` arrives. Row mode uplinks local estimates
//! `f_t^p`, column mode partial residuals `u_t^p = A^p x_t^p`; the
//! quantize/encode machinery is the spec-named
//! [`CompressionStack`](crate::compress::CompressionStack), assembled
//! identically on both protocol sides by [`compressor_for_spec`], and
//! differs across scenarios only in the model channel the scenario's
//! [`channel_for_var`](Scenario::channel_for_var) rebuilds.
//!
//! The per-frame core is factored into [`WorkerSession`] so the serving
//! daemon's multiplexing worker loop (many concurrent sessions over one
//! physical link) can drive the identical state machine one frame at a
//! time, while the standalone loop here stays a thin recv-dispatch shell.

use crate::compress::{BlockCtx, Compressor};
use crate::coordinator::message::{self, Message, QuantSpec};
use crate::coordinator::scenario::{design_ctx, Scenario};
use crate::coordinator::transport::Endpoint;
use crate::engine::ComputeEngine;
use crate::error::{Error, Result};
use crate::signal::BernoulliGauss;
use crate::telemetry::{Stage, Telemetry};

/// Static parameters a worker needs beyond its data shard.
#[derive(Debug, Clone)]
pub struct WorkerParams {
    /// This worker's id.
    pub id: u32,
    /// Total number of workers P.
    pub p_workers: usize,
    /// Number of signal instances B in the session's batch.
    pub batch: usize,
    /// Source prior (for model-channel reconstruction).
    pub prior: BernoulliGauss,
}

/// Assemble the compressor implied by a [`QuantSpec`] (both protocol
/// sides call this — determinism of the registry assembly is what keeps
/// the codecs in sync). `len` is the per-signal uplink vector length.
pub fn compressor_for_spec<S: Scenario>(
    spec: &QuantSpec,
    prior: &BernoulliGauss,
    p_workers: usize,
    len: usize,
) -> Result<Option<Compressor>> {
    match spec {
        QuantSpec::Raw | QuantSpec::Skip => Ok(None),
        QuantSpec::Stack { name, model_var, seed, params } => {
            let stack = crate::compress::registry::get(name)?;
            let ctx = design_ctx::<S>(prior, p_workers, *model_var, len, *seed);
            Ok(Some(stack.assemble(&ctx, params)?))
        }
    }
}

/// What one served frame means for the session's lifetime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Served {
    /// The session continues — more frames expected.
    Continue,
    /// The fusion side released this session (`Done`).
    Done,
}

/// Per-session worker-side protocol state, driven one frame at a time.
///
/// [`run_scenario_worker`] wraps this in a blocking recv loop for the
/// standalone one-session-per-link case; the serving daemon's worker
/// keeps one `WorkerSession` per live session id and routes each demuxed
/// frame to [`handle_frame`](WorkerSession::handle_frame). All round
/// buffers (uplink staging, dequantization scratch, broadcast decode
/// scratch inside the scenario's `WorkerState`) persist across rounds,
/// so steady-state rounds allocate nothing proportional to the signal.
pub(crate) struct WorkerSession<S: Scenario> {
    state: S::WorkerState,
    /// Flat `B × len` staging for the round's pending uplink vectors.
    pending: Vec<f32>,
    have_pending: bool,
    /// Dequantization scratch for payload-free codecs.
    deq: Vec<f32>,
    iters: usize,
    /// Span recording (off by default — a single flag check per frame).
    tel: Telemetry,
}

impl<S: Scenario> WorkerSession<S> {
    /// Fresh session state at `t = 0`.
    pub(crate) fn new(shard: &S::Shard, batch: usize) -> Self {
        WorkerSession {
            state: S::worker_init(shard, batch),
            pending: Vec::new(),
            have_pending: false,
            deq: Vec::new(),
            iters: 0,
            tel: Telemetry::off(),
        }
    }

    /// Attach a [`Telemetry`] handle: each served broadcast records a
    /// `denoise` span (the local AMP/LC step) and each `QuantCmd` an
    /// `encode` span (quantize + entropy-code + uplink), tagged with
    /// this worker's id. Measurement-only.
    pub(crate) fn set_telemetry(&mut self, tel: Telemetry) {
        self.tel = tel;
    }

    /// Iterations served so far.
    pub(crate) fn iters(&self) -> usize {
        self.iters
    }

    /// Serve one fusion frame: dispatch on the leading tag byte — the
    /// batched `QuantCmd` codes + uplinks the pending vectors, `Done`
    /// ends the session, everything else is the scenario's broadcast
    /// (parsed zero-copy by [`Scenario::worker_serve`]). Replies go out
    /// on `endpoint`.
    pub(crate) fn handle_frame(
        &mut self,
        params: &WorkerParams,
        shard: &S::Shard,
        engine: &dyn ComputeEngine,
        frame: &[u8],
        endpoint: &mut Endpoint,
    ) -> Result<Served> {
        match frame.first().copied() {
            Some(message::TAG_DONE) => {
                if frame.len() != 1 {
                    return Err(Error::Protocol(format!(
                        "worker {}: trailing bytes on Done frame",
                        params.id
                    )));
                }
                Ok(Served::Done)
            }
            Some(message::TAG_QUANT) => {
                // Specs are O(B)-small; the owned decode here is the only
                // per-round allocation left on the worker's control path.
                let (t, specs) = match Message::decode(frame)? {
                    Message::QuantCmd { t, specs } => (t, specs),
                    other => {
                        return Err(Error::Protocol(format!(
                            "worker {}: unexpected message {other:?}",
                            params.id
                        )))
                    }
                };
                if !self.have_pending {
                    return Err(Error::Protocol(format!(
                        "worker {}: QuantCmd before the round's step command at t={t}",
                        params.id
                    )));
                }
                self.have_pending = false;
                let b = params.batch;
                if specs.len() != b {
                    return Err(Error::Protocol(format!(
                        "worker {}: {} specs for {b} pending uplinks at t={t}",
                        params.id,
                        specs.len(),
                    )));
                }
                debug_assert_eq!(self.pending.len() % b.max(1), 0);
                let len = self.pending.len() / b.max(1);
                let ctx = BlockCtx { worker: params.id };
                let tel_on = self.tel.is_on();
                let mark_us = if tel_on { self.tel.clock_us() } else { 0 };
                // Assemble the compressors first (fallible), then build
                // the FVector frame payload by payload straight from the
                // flat staging buffer.
                let pending_ref = &self.pending;
                let deq_ref = &mut self.deq;
                endpoint.send_frame(|buf| {
                    message::begin_fvector(buf, t, params.id, b as u32);
                    for (sig, spec) in specs.iter().enumerate() {
                        let v = &pending_ref[sig * len..(sig + 1) * len];
                        let comp = compressor_for_spec::<S>(
                            spec,
                            &params.prior,
                            params.p_workers,
                            len,
                        )?;
                        push_payload(buf, spec, comp.as_ref(), &ctx, v, deq_ref)?;
                    }
                    Ok(())
                })?;
                if tel_on {
                    self.tel.phase(Stage::Encode, t as usize, params.id as i32, mark_us, 0.0);
                }
                Ok(Served::Continue)
            }
            _ => {
                let tel_on = self.tel.is_on();
                let mark_us = if tel_on { self.tel.clock_us() } else { 0 };
                S::worker_serve(
                    params,
                    shard,
                    &mut self.state,
                    engine,
                    frame,
                    &mut self.pending,
                    endpoint,
                )?;
                if tel_on {
                    self.tel.phase(Stage::Denoise, self.iters, params.id as i32, mark_us, 0.0);
                }
                self.have_pending = true;
                self.iters += 1;
                Ok(Served::Continue)
            }
        }
    }
}

/// Run the worker protocol for scenario `S` until `Done`: serve each
/// round's broadcast through [`Scenario::worker_serve`] (which parses
/// the frame zero-copy, stages the pending per-signal uplink vectors
/// flat in a reused buffer, and sends its reply directly), then quantize
/// + entropy-code the pending vectors straight into the endpoint's frame
/// buffer when the batched `QuantCmd` arrives. Steady-state rounds reuse
/// every buffer involved. Returns the number of iterations served (for
/// tests / sanity checks).
pub fn run_scenario_worker<S: Scenario>(
    params: &WorkerParams,
    shard: &S::Shard,
    engine: &dyn ComputeEngine,
    endpoint: &mut Endpoint,
) -> Result<usize> {
    run_scenario_worker_traced::<S>(params, shard, engine, endpoint, Telemetry::off())
}

/// [`run_scenario_worker`] with a [`Telemetry`] handle: the worker's
/// `encode` (quantize + code + uplink) and `denoise` (local step) spans
/// are recorded into the handle's ring, tagged with the worker id — the
/// session driver passes a clone of the fusion side's handle so both
/// ends of every round land in one stream. Measurement-only.
pub fn run_scenario_worker_traced<S: Scenario>(
    params: &WorkerParams,
    shard: &S::Shard,
    engine: &dyn ComputeEngine,
    endpoint: &mut Endpoint,
    tel: Telemetry,
) -> Result<usize> {
    let mut session = WorkerSession::<S>::new(shard, params.batch);
    session.set_telemetry(tel);
    // The frame lives outside the endpoint so the reply to a broadcast
    // can be sent while the borrowed broadcast view is still alive.
    let mut frame: Vec<u8> = Vec::new();
    loop {
        endpoint.recv_frame_into(&mut frame)?;
        match session.handle_frame(params, shard, engine, &frame, endpoint)? {
            Served::Continue => {}
            Served::Done => return Ok(session.iters()),
        }
    }
}

/// Code one uplink vector according to its spec, appending the payload
/// to the frame being built (`deq` is reused dequantization scratch for
/// payload-free codecs).
fn push_payload(
    buf: &mut Vec<u8>,
    spec: &QuantSpec,
    comp: Option<&Compressor>,
    ctx: &BlockCtx,
    v: &[f32],
    deq: &mut Vec<f32>,
) -> Result<()> {
    match spec {
        QuantSpec::Raw => message::push_raw_payload(buf, v),
        QuantSpec::Skip => message::push_skipped_payload(buf),
        QuantSpec::Stack { .. } => {
            let comp = comp.expect("stack spec yields a compressor");
            if comp.carries_payload() {
                let block = comp.encode(ctx, v)?;
                message::push_coded_payload(buf, v.len() as u32, &block.bytes);
            } else {
                // Entropy-accounted, not entropy-coded (analytic codec):
                // ship the dequantized values so numerics match the coded
                // path exactly.
                let syms = comp.quantize(ctx, v);
                deq.resize(v.len(), 0.0);
                comp.dequantize(ctx, &syms, deq)?;
                message::push_raw_payload(buf, deq);
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scenario::{Column, Row};
    use crate::engine::{ColumnWorkerData, RowBatchData, RustEngine};
    use crate::signal::{Batch, ProblemDims};
    use crate::util::rng::Rng;

    fn sample_block(prior: &BernoulliGauss, s2: f64, n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (prior.sample(&mut rng) + rng.gaussian() * s2.sqrt()) as f32).collect()
    }

    #[test]
    fn compressor_for_spec_deterministic_across_sides() {
        // Two independent assemblies from the same spec must produce
        // byte-identical encodings and reconstructions — the property
        // that keeps fusion and workers in codec lockstep.
        let prior = BernoulliGauss::standard(0.05);
        let spec = QuantSpec::Stack {
            name: "ecsq.range".into(),
            model_var: 0.08,
            seed: 42,
            params: vec![0.01, 150.0],
        };
        let a = compressor_for_spec::<Row>(&spec, &prior, 30, 500).unwrap().unwrap();
        let b = compressor_for_spec::<Row>(&spec, &prior, 30, 500).unwrap().unwrap();
        let xs = sample_block(&prior, 0.08, 500, 9);
        let ctx = BlockCtx { worker: 3 };
        let ea = a.encode(&ctx, &xs).unwrap();
        let eb = b.encode(&ctx, &xs).unwrap();
        assert_eq!(ea.bytes, eb.bytes);
        let (mut ra, mut rb) = (vec![0f32; 500], vec![0f32; 500]);
        a.decode(&ctx, &ea.bytes, &mut ra).unwrap();
        b.decode(&ctx, &eb.bytes, &mut rb).unwrap();
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn column_compressor_deterministic_and_gaussian_modeled() {
        let prior = BernoulliGauss::standard(0.05);
        let spec = QuantSpec::Stack {
            name: "ecsq.range".into(),
            model_var: 0.03,
            seed: 1,
            params: vec![0.004, 120.0],
        };
        let a = compressor_for_spec::<Column>(&spec, &prior, 4, 200).unwrap().unwrap();
        let b = compressor_for_spec::<Column>(&spec, &prior, 4, 200).unwrap().unwrap();
        let mut rng = Rng::new(5);
        let xs: Vec<f32> = (0..200).map(|_| (rng.gaussian() * 0.03f64.sqrt()) as f32).collect();
        let ctx = BlockCtx { worker: 0 };
        assert_eq!(a.encode(&ctx, &xs).unwrap().bytes, b.encode(&ctx, &xs).unwrap().bytes);
        // Raw/Skip specs need no compressor.
        assert!(compressor_for_spec::<Column>(&QuantSpec::Raw, &prior, 4, 200)
            .unwrap()
            .is_none());
        assert!(compressor_for_spec::<Row>(&QuantSpec::Skip, &prior, 4, 200)
            .unwrap()
            .is_none());
    }

    #[test]
    fn unknown_stack_in_spec_fails_loudly() {
        let prior = BernoulliGauss::standard(0.05);
        let spec = QuantSpec::Stack {
            name: "ecsq.lzma".into(),
            model_var: 0.05,
            seed: 0,
            params: vec![0.01, 100.0],
        };
        let err = compressor_for_spec::<Row>(&spec, &prior, 6, 100).unwrap_err();
        assert!(err.to_string().contains("ecsq.lzma"), "{err}");
    }

    #[test]
    fn dithered_streams_differ_per_worker_but_agree_per_side() {
        let prior = BernoulliGauss::standard(0.05);
        let spec = QuantSpec::Stack {
            name: "ecsq-dithered.range".into(),
            model_var: 0.05,
            seed: 77,
            params: vec![0.02, 500.0], // ±10 range: no saturation in test data
        };
        let comp = compressor_for_spec::<Row>(&spec, &prior, 6, 300).unwrap().unwrap();
        let xs = sample_block(&prior, 0.05, 300, 21);
        let w0 = comp.quantize(&BlockCtx { worker: 0 }, &xs);
        let w1 = comp.quantize(&BlockCtx { worker: 1 }, &xs);
        assert_ne!(w0, w1, "per-worker dither streams must differ");
        // Encoder/decoder agreement for the same worker id.
        let ctx = BlockCtx { worker: 1 };
        let block = comp.encode(&ctx, &xs).unwrap();
        let mut out = vec![0f32; xs.len()];
        comp.decode(&ctx, &block.bytes, &mut out).unwrap();
        let delta = 0.02f64;
        for (x, o) in xs.iter().zip(&out) {
            assert!(
                ((x - o).abs() as f64) <= delta + 1e-6,
                "dithered error |{x}-{o}| beyond Δ"
            );
        }
    }

    fn small_batch(seed: u64, b: usize) -> Batch {
        let prior = BernoulliGauss::standard(0.1);
        let mut rng = Rng::new(seed);
        Batch::generate(
            prior,
            ProblemDims { n: 50, m: 10, sigma_e2: 1e-3 },
            &mut rng,
            b,
        )
        .unwrap()
    }

    fn params_for(prior: BernoulliGauss, batch: usize) -> WorkerParams {
        WorkerParams { id: 0, p_workers: 2, batch, prior }
    }

    #[test]
    fn row_worker_rejects_quant_before_step() {
        let batch = small_batch(1, 1);
        let prior = batch.prior;
        let shard = RowBatchData::try_split(&batch, 2).unwrap().remove(0);
        let engine = RustEngine::new(prior, 1);
        let params = params_for(prior, 1);
        let meter = std::sync::Arc::new(crate::metrics::ByteMeter::new());
        let (mut fusion_ep, mut worker_ep) =
            crate::coordinator::transport::inproc_pair(meter);
        let h = std::thread::spawn(move || {
            run_scenario_worker::<Row>(&params, &shard, &engine, &mut worker_ep)
        });
        fusion_ep
            .send(&Message::QuantCmd { t: 0, specs: vec![QuantSpec::Raw] })
            .unwrap();
        let err = h.join().unwrap();
        assert!(err.is_err(), "expected protocol error, got {err:?}");
    }

    #[test]
    fn column_worker_rejects_quant_before_step() {
        let batch = small_batch(2, 1);
        let prior = batch.prior;
        let shard = ColumnWorkerData::try_split(&batch.a, 2).unwrap().remove(0);
        let engine = RustEngine::new(prior, 1);
        let params = params_for(prior, 1);
        let meter = std::sync::Arc::new(crate::metrics::ByteMeter::new());
        let (mut fusion_ep, mut worker_ep) =
            crate::coordinator::transport::inproc_pair(meter);
        let h = std::thread::spawn(move || {
            run_scenario_worker::<Column>(&params, &shard, &engine, &mut worker_ep)
        });
        fusion_ep
            .send(&Message::QuantCmd { t: 0, specs: vec![QuantSpec::Raw] })
            .unwrap();
        let err = h.join().unwrap();
        assert!(err.is_err(), "expected protocol error, got {err:?}");
    }

    #[test]
    fn row_worker_rejects_wrong_scenario_message() {
        // A column broadcast arriving at a row worker is a protocol error,
        // not a hang or a panic.
        let batch = small_batch(3, 1);
        let prior = batch.prior;
        let shard = RowBatchData::try_split(&batch, 2).unwrap().remove(0);
        let engine = RustEngine::new(prior, 1);
        let params = params_for(prior, 1);
        let meter = std::sync::Arc::new(crate::metrics::ByteMeter::new());
        let (mut fusion_ep, mut worker_ep) =
            crate::coordinator::transport::inproc_pair(meter);
        let h = std::thread::spawn(move || {
            run_scenario_worker::<Row>(&params, &shard, &engine, &mut worker_ep)
        });
        fusion_ep
            .send(&Message::ColStep { t: 0, sigma_eff2: vec![0.1], z: vec![0.0; 10] })
            .unwrap();
        let err = h.join().unwrap();
        assert!(err.is_err(), "expected protocol error, got {err:?}");
    }

    #[test]
    fn worker_rejects_batch_size_mismatch() {
        // A StepCmd carrying the wrong number of signals fails loudly.
        let batch = small_batch(4, 2);
        let prior = batch.prior;
        let shard = RowBatchData::try_split(&batch, 2).unwrap().remove(0);
        let engine = RustEngine::new(prior, 1);
        let params = params_for(prior, 2);
        let meter = std::sync::Arc::new(crate::metrics::ByteMeter::new());
        let (mut fusion_ep, mut worker_ep) =
            crate::coordinator::transport::inproc_pair(meter);
        let h = std::thread::spawn(move || {
            run_scenario_worker::<Row>(&params, &shard, &engine, &mut worker_ep)
        });
        fusion_ep
            .send(&Message::StepCmd { t: 0, coefs: vec![0.0], x: vec![0.0; 50] })
            .unwrap();
        let err = h.join().unwrap();
        assert!(err.is_err(), "expected protocol error, got {err:?}");
    }
}
