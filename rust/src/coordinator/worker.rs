//! The generic worker processor loop.
//!
//! [`run_scenario_worker`] serves protocol rounds for **any**
//! [`Scenario`]: each round it hands the broadcast to
//! [`Scenario::worker_serve`] (local step + pre-uplink reply), then codes
//! the pending per-signal uplink vectors when the batched `QuantCmd`
//! arrives. Row mode uplinks local estimates `f_t^p`, column mode partial
//! residuals `u_t^p = A^p x_t^p`; the quantize/encode machinery is shared
//! and differs only in the model channel the scenario's
//! [`coder`](Scenario::coder) builds.

use crate::config::CodecKind;
use crate::coordinator::message::{FPayload, Message, QuantSpec};
use crate::coordinator::scenario::Scenario;
use crate::coordinator::transport::Endpoint;
use crate::engine::ComputeEngine;
use crate::error::{Error, Result};
use crate::quant::{EcsqCoder, UniformQuantizer};
use crate::se::prior::BgChannel;
use crate::signal::BernoulliGauss;

/// Static parameters a worker needs beyond its data shard.
#[derive(Debug, Clone)]
pub struct WorkerParams {
    /// This worker's id.
    pub id: u32,
    /// Total number of workers P.
    pub p_workers: usize,
    /// Number of signal instances B in the session's batch.
    pub batch: usize,
    /// Source prior (for model-pmf reconstruction).
    pub prior: BernoulliGauss,
    /// Wire codec.
    pub codec: CodecKind,
}

/// Build the ECSQ coder implied by a row-mode [`QuantSpec`] (both sides
/// call this — determinism of the model pmf is what keeps the codec in
/// sync).
pub fn coder_for_spec(
    spec: &QuantSpec,
    prior: &BernoulliGauss,
    p_workers: usize,
    codec: CodecKind,
) -> Result<Option<EcsqCoder>> {
    match spec {
        QuantSpec::Raw | QuantSpec::Skip => Ok(None),
        QuantSpec::Ecsq { delta, k_max, sigma_d2_hat } => {
            let base = BgChannel::new(*prior);
            let (wch, ws2) = base.worker_channel(*sigma_d2_hat, p_workers);
            let q = UniformQuantizer { delta: *delta, k_max: *k_max as i32, center: 0.0 };
            Ok(Some(EcsqCoder::new(q, &wch, ws2, codec)?))
        }
    }
}

/// Column-mode analogue of [`coder_for_spec`]: the message model is the
/// Gaussian column-uplink channel rebuilt from the variance estimate the
/// spec carries (its `sigma_d2_hat` field holds `v̂ = Σ‖u^p‖²/(P·M)` in
/// column mode). Deterministic on both sides, like the row path.
pub fn column_coder_for_spec(
    spec: &QuantSpec,
    codec: CodecKind,
) -> Result<Option<EcsqCoder>> {
    match spec {
        QuantSpec::Raw | QuantSpec::Skip => Ok(None),
        QuantSpec::Ecsq { delta, k_max, sigma_d2_hat } => {
            let (wch, ws2) = BgChannel::column_message_channel(*sigma_d2_hat);
            let q = UniformQuantizer { delta: *delta, k_max: *k_max as i32, center: 0.0 };
            Ok(Some(EcsqCoder::new(q, &wch, ws2, codec)?))
        }
    }
}

/// Code one uplink vector according to the spec, using the given coder
/// (scenarios differ only in the model channel the coder was built from).
fn payload_for_spec(
    v: Vec<f32>,
    spec: &QuantSpec,
    codec: CodecKind,
    coder: Option<&EcsqCoder>,
) -> Result<FPayload> {
    Ok(match spec {
        QuantSpec::Raw => FPayload::Raw(v),
        QuantSpec::Skip => FPayload::Skipped,
        QuantSpec::Ecsq { .. } => {
            let coder = coder.expect("ECSQ spec yields a coder");
            let syms = coder.quantizer.quantize_block(&v);
            match codec {
                CodecKind::Analytic => {
                    // Entropy-accounted, not entropy-coded: ship the
                    // dequantized values so numerics match the coded path
                    // exactly.
                    let mut deq = vec![0f32; v.len()];
                    coder.quantizer.dequantize_block(&syms, &mut deq);
                    FPayload::Raw(deq)
                }
                CodecKind::Range | CodecKind::Huffman => {
                    let block = coder.encode_symbols(&syms)?;
                    FPayload::Coded { n: block.n as u32, bytes: block.bytes }
                }
            }
        }
    })
}

/// Run the worker protocol for scenario `S` until `Done`: serve each
/// round's broadcast through [`Scenario::worker_serve`], then quantize +
/// entropy-code the pending per-signal uplink vectors when the batched
/// `QuantCmd` arrives. Returns the number of iterations served (for tests
/// / sanity checks).
pub fn run_scenario_worker<S: Scenario>(
    params: &WorkerParams,
    shard: &S::Shard,
    engine: &dyn ComputeEngine,
    endpoint: &mut Endpoint,
) -> Result<usize> {
    let mut state = S::worker_init(shard, params.batch);
    let mut pending: Option<Vec<Vec<f32>>> = None;
    let mut iters = 0usize;
    loop {
        match endpoint.recv()? {
            Message::QuantCmd { t, specs } => {
                let vs = pending.take().ok_or_else(|| {
                    Error::Protocol(format!(
                        "worker {}: QuantCmd before the round's step command at t={t}",
                        params.id
                    ))
                })?;
                if specs.len() != vs.len() {
                    return Err(Error::Protocol(format!(
                        "worker {}: {} specs for {} pending uplinks at t={t}",
                        params.id,
                        specs.len(),
                        vs.len()
                    )));
                }
                let mut payloads = Vec::with_capacity(vs.len());
                for (v, spec) in vs.into_iter().zip(&specs) {
                    let coder = S::coder(spec, &params.prior, params.p_workers, params.codec)?;
                    payloads.push(payload_for_spec(v, spec, params.codec, coder.as_ref())?);
                }
                endpoint.send(&Message::FVector { t, worker: params.id, payloads })?;
            }
            Message::Done => return Ok(iters),
            msg => {
                let (reply, vs) = S::worker_serve(params, shard, &mut state, engine, msg)?;
                endpoint.send(&reply)?;
                pending = Some(vs);
                iters += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scenario::{Column, Row};
    use crate::engine::{ColumnWorkerData, RowBatchData, RustEngine};
    use crate::signal::{Batch, ProblemDims};
    use crate::util::rng::Rng;

    #[test]
    fn coder_for_spec_deterministic_across_sides() {
        let prior = BernoulliGauss::standard(0.05);
        let spec = QuantSpec::Ecsq { delta: 0.01, k_max: 150, sigma_d2_hat: 0.08 };
        let a = coder_for_spec(&spec, &prior, 30, CodecKind::Range).unwrap().unwrap();
        let b = coder_for_spec(&spec, &prior, 30, CodecKind::Range).unwrap().unwrap();
        assert_eq!(a.pmf, b.pmf);
        assert_eq!(a.quantizer, b.quantizer);
    }

    #[test]
    fn column_coder_deterministic_and_gaussian_modeled() {
        let spec = QuantSpec::Ecsq { delta: 0.004, k_max: 120, sigma_d2_hat: 0.03 };
        let a = column_coder_for_spec(&spec, CodecKind::Range).unwrap().unwrap();
        let b = column_coder_for_spec(&spec, CodecKind::Range).unwrap().unwrap();
        assert_eq!(a.pmf, b.pmf);
        assert_eq!(a.quantizer, b.quantizer);
        // The model pmf is symmetric (zero-mean Gaussian message).
        let n = a.pmf.len();
        for i in 0..n / 2 {
            assert!((a.pmf[i] - a.pmf[n - 1 - i]).abs() < 1e-12, "bin {i}");
        }
        // Raw/Skip specs need no coder.
        assert!(column_coder_for_spec(&QuantSpec::Raw, CodecKind::Range)
            .unwrap()
            .is_none());
    }

    fn small_batch(seed: u64, b: usize) -> Batch {
        let prior = BernoulliGauss::standard(0.1);
        let mut rng = Rng::new(seed);
        Batch::generate(
            prior,
            ProblemDims { n: 50, m: 10, sigma_e2: 1e-3 },
            &mut rng,
            b,
        )
        .unwrap()
    }

    #[test]
    fn row_worker_rejects_quant_before_step() {
        let batch = small_batch(1, 1);
        let prior = batch.prior;
        let shard = RowBatchData::try_split(&batch, 2).unwrap().remove(0);
        let engine = RustEngine::new(prior, 1);
        let params = WorkerParams {
            id: 0,
            p_workers: 2,
            batch: 1,
            prior,
            codec: CodecKind::Range,
        };
        let meter = std::sync::Arc::new(crate::metrics::ByteMeter::new());
        let (mut fusion_ep, mut worker_ep) =
            crate::coordinator::transport::inproc_pair(meter);
        let h = std::thread::spawn(move || {
            run_scenario_worker::<Row>(&params, &shard, &engine, &mut worker_ep)
        });
        fusion_ep
            .send(&Message::QuantCmd { t: 0, specs: vec![QuantSpec::Raw] })
            .unwrap();
        let err = h.join().unwrap();
        assert!(err.is_err(), "expected protocol error, got {err:?}");
    }

    #[test]
    fn column_worker_rejects_quant_before_step() {
        let batch = small_batch(2, 1);
        let prior = batch.prior;
        let shard = ColumnWorkerData::try_split(&batch.a, 2).unwrap().remove(0);
        let engine = RustEngine::new(prior, 1);
        let params = WorkerParams {
            id: 0,
            p_workers: 2,
            batch: 1,
            prior,
            codec: CodecKind::Range,
        };
        let meter = std::sync::Arc::new(crate::metrics::ByteMeter::new());
        let (mut fusion_ep, mut worker_ep) =
            crate::coordinator::transport::inproc_pair(meter);
        let h = std::thread::spawn(move || {
            run_scenario_worker::<Column>(&params, &shard, &engine, &mut worker_ep)
        });
        fusion_ep
            .send(&Message::QuantCmd { t: 0, specs: vec![QuantSpec::Raw] })
            .unwrap();
        let err = h.join().unwrap();
        assert!(err.is_err(), "expected protocol error, got {err:?}");
    }

    #[test]
    fn row_worker_rejects_wrong_scenario_message() {
        // A column broadcast arriving at a row worker is a protocol error,
        // not a hang or a panic.
        let batch = small_batch(3, 1);
        let prior = batch.prior;
        let shard = RowBatchData::try_split(&batch, 2).unwrap().remove(0);
        let engine = RustEngine::new(prior, 1);
        let params = WorkerParams {
            id: 0,
            p_workers: 2,
            batch: 1,
            prior,
            codec: CodecKind::Range,
        };
        let meter = std::sync::Arc::new(crate::metrics::ByteMeter::new());
        let (mut fusion_ep, mut worker_ep) =
            crate::coordinator::transport::inproc_pair(meter);
        let h = std::thread::spawn(move || {
            run_scenario_worker::<Row>(&params, &shard, &engine, &mut worker_ep)
        });
        fusion_ep
            .send(&Message::ColStep { t: 0, sigma_eff2: vec![0.1], z: vec![0.0; 10] })
            .unwrap();
        let err = h.join().unwrap();
        assert!(err.is_err(), "expected protocol error, got {err:?}");
    }

    #[test]
    fn worker_rejects_batch_size_mismatch() {
        // A StepCmd carrying the wrong number of signals fails loudly.
        let batch = small_batch(4, 2);
        let prior = batch.prior;
        let shard = RowBatchData::try_split(&batch, 2).unwrap().remove(0);
        let engine = RustEngine::new(prior, 1);
        let params = WorkerParams {
            id: 0,
            p_workers: 2,
            batch: 2,
            prior,
            codec: CodecKind::Range,
        };
        let meter = std::sync::Arc::new(crate::metrics::ByteMeter::new());
        let (mut fusion_ep, mut worker_ep) =
            crate::coordinator::transport::inproc_pair(meter);
        let h = std::thread::spawn(move || {
            run_scenario_worker::<Row>(&params, &shard, &engine, &mut worker_ep)
        });
        fusion_ep
            .send(&Message::StepCmd { t: 0, coefs: vec![0.0], x: vec![0.0; 50] })
            .unwrap();
        let err = h.join().unwrap();
        assert!(err.is_err(), "expected protocol error, got {err:?}");
    }
}
