//! Centralized AMP baseline (paper §2, eqs. 1–3) — the quality ceiling the
//! MP-AMP schemes are compared against. Runs on any [`ComputeEngine`] by
//! treating the whole problem as a single worker with `P = 1`.

use crate::engine::ComputeEngine;
use crate::error::{Error, Result};
use crate::metrics::IterRecord;
use crate::se::StateEvolution;
use crate::signal::Instance;

/// Result of a centralized AMP run.
#[derive(Debug, Clone)]
pub struct CentralizedReport {
    /// Per-iteration records (rate fields = 0: nothing is communicated).
    pub iters: Vec<IterRecord>,
    /// Final estimate.
    pub final_x: Vec<f32>,
}

impl CentralizedReport {
    /// Final SDR in dB.
    pub fn final_sdr_db(&self) -> f64 {
        self.iters.last().map(|r| r.sdr_db).unwrap_or(f64::NAN)
    }
}

/// Run `t_iters` of centralized AMP on an instance.
pub fn run_centralized(
    inst: &Instance,
    se: &StateEvolution,
    engine: &dyn ComputeEngine,
    t_iters: usize,
) -> Result<CentralizedReport> {
    // A zero-iteration run has no final SDR (`final_sdr_db` would be NaN);
    // reject it up front with a config error, matching the session
    // builder's validation style.
    if t_iters == 0 {
        return Err(Error::Config(
            "t_iters must be ≥ 1 (a zero-iteration run has no estimate)".into(),
        ));
    }
    let n = inst.dims.n;
    let m = inst.dims.m as f64;
    let mut x = vec![0f32; n];
    let mut z_prev = vec![0f32; inst.dims.m];
    let mut coef = 0.0f32;
    let mut iters = Vec::with_capacity(t_iters);
    for t in 0..t_iters {
        let t0 = std::time::Instant::now();
        let lc = engine.lc_step(&inst.a, &inst.y, &x, &z_prev, coef, 1)?;
        z_prev = lc.z;
        let sigma_d2_hat = lc.z_norm2 / m;
        let gc = engine.gc_step(&lc.f_partial, sigma_d2_hat)?;
        x = gc.x_next;
        coef = (gc.eta_prime_mean / se.kappa) as f32;
        iters.push(IterRecord {
            t,
            sdr_db: inst.sdr_db(&x),
            sdr_pred_db: se.sdr_db(se.step(sigma_d2_hat)),
            rate_alloc: 0.0,
            rate_wire: 0.0,
            sigma_q2: 0.0,
            sigma_d2_hat,
            wall_s: t0.elapsed().as_secs_f64(),
        });
    }
    Ok(CentralizedReport { iters, final_x: x })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::RustEngine;
    use crate::signal::{BernoulliGauss, ProblemDims};
    use crate::util::rng::Rng;

    fn setup(n: usize, m: usize, eps: f64, seed: u64) -> (Instance, StateEvolution) {
        let prior = BernoulliGauss::standard(eps);
        let kappa = m as f64 / n as f64;
        let sigma_e2 = crate::signal::sigma_e2_for_snr(&prior, kappa, 20.0);
        let mut rng = Rng::new(seed);
        let inst =
            Instance::generate(prior, ProblemDims { n, m, sigma_e2 }, &mut rng).unwrap();
        let se = StateEvolution::new(prior, kappa, sigma_e2);
        (inst, se)
    }

    #[test]
    fn zero_iterations_rejected_with_config_error() {
        let (inst, se) = setup(200, 60, 0.1, 3);
        let engine = RustEngine::new(inst.prior, 1);
        let err = run_centralized(&inst, &se, &engine, 0).unwrap_err();
        assert!(
            matches!(err, crate::error::Error::Config(_)),
            "expected Config error, got {err:?}"
        );
    }

    #[test]
    fn centralized_amp_converges() {
        let (inst, se) = setup(2000, 600, 0.05, 11);
        let engine = RustEngine::new(inst.prior, 4);
        let rep = run_centralized(&inst, &se, &engine, 10).unwrap();
        // SDR grows monotonically (modulo small fluctuations) and ends high.
        assert!(rep.final_sdr_db() > 15.0, "SDR={}", rep.final_sdr_db());
        assert!(rep.iters[9].sdr_db > rep.iters[0].sdr_db + 5.0);
    }

    #[test]
    fn empirical_sdr_tracks_se_prediction() {
        // The defining property of AMP: the SE trajectory predicts the
        // empirical MSE. At N=4000 they agree to within ~1.5 dB.
        let (inst, se) = setup(4000, 1200, 0.05, 5);
        let engine = RustEngine::new(inst.prior, 4);
        let rep = run_centralized(&inst, &se, &engine, 10).unwrap();
        let traj = se.trajectory(10);
        for it in rep.iters.iter() {
            let pred = se.sdr_db(traj[it.t + 1]);
            assert!(
                (it.sdr_db - pred).abs() < 1.5,
                "t={}: empirical {} vs SE {}",
                it.t,
                it.sdr_db,
                pred
            );
        }
    }

    #[test]
    fn residual_estimates_sigma() {
        // σ̂² = ‖z‖²/M ≈ SE σ_t² along the run.
        let (inst, se) = setup(4000, 1200, 0.1, 7);
        let engine = RustEngine::new(inst.prior, 4);
        let rep = run_centralized(&inst, &se, &engine, 8).unwrap();
        let traj = se.trajectory(9);
        for it in &rep.iters {
            // Finite-N runs drift within about one SE step of the
            // trajectory; require σ̂_t² to stay inside the envelope
            // [σ²_{t+1}, σ²_t] with multiplicative slack. This still
            // catches Onsager-term and denoiser bugs, which blow the
            // trajectory up by orders of magnitude.
            let hi = traj[it.t] * 1.35;
            let lo = traj[it.t + 1] * 0.70;
            assert!(
                it.sigma_d2_hat <= hi && it.sigma_d2_hat >= lo,
                "t={}: σ̂²={} outside SE envelope [{lo}, {hi}]",
                it.t,
                it.sigma_d2_hat
            );
        }
    }
}
