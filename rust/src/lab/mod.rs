//! The **experiment lab**: declarative studies over the config knob space
//! plus the perf-trajectory gate CI runs on every PR.
//!
//! Three pieces, surfaced as `mpamp lab {manifest,check,run,gate}`:
//!
//! * [`manifest`] — a machine-readable knob manifest generated from
//!   [`RunConfig`](crate::config::RunConfig): every knob with a stable id,
//!   type, bounds, default, and scientific role (treatment / control /
//!   confound / infra). CI snapshots it (`ci/knob_manifest.json`) so knob
//!   additions are reviewed deliberately.
//! * [`study`] — an overrides-file format validated against the manifest
//!   that drives [`Sweep`](crate::experiment::Sweep) without custom Rust:
//!   `[base]` fixed overrides, `[grid]` crossed axes, one labelled trial
//!   per grid point.
//! * [`bench_util::compare`](crate::bench_util::compare) — classifies each
//!   record of a current `BENCH_pr.json` against stored baselines with
//!   per-metric-family noise bands (`mpamp lab gate`), exiting nonzero on
//!   out-of-band regressions and re-baselining with `--bless`.
//!
//! Worked example — a two-axis study driven entirely from text:
//!
//! ```
//! use mpamp::config::toml;
//! use mpamp::lab::manifest::Manifest;
//! use mpamp::lab::study::{records_from_reports, Study};
//!
//! let manifest = Manifest::generate();
//! let text = r#"
//!     [lab]
//!     name = "part-vs-rate"
//!     threads = 2
//!
//!     [base]
//!     n = 400
//!     m = 120
//!     p = 4
//!     iters = 2
//!     schedule.kind = "fixed"
//!
//!     [grid]
//!     partitioning = "row,column"
//!     schedule.bits = "2,4"
//! "#;
//! let study =
//!     Study::from_table(&toml::parse(text).unwrap(), "part-vs-rate", &manifest)
//!         .unwrap();
//! assert_eq!(study.len(), 4); // full cross product
//!
//! let reports = study.run().unwrap();
//! for record in records_from_reports(&reports) {
//!     // "part-vs-rate/partitioning=row,schedule.bits=2", ...
//!     println!("{}: {:?} dB/bit", record.name, record.sdr_per_bit);
//! }
//! ```
//!
//! The same study as a file is `mpamp lab run study.toml --records out.json`,
//! and `mpamp lab gate --baseline ci/baselines.json --current out.json`
//! closes the loop.

pub mod manifest;
pub mod study;

pub use manifest::{Knob, KnobRole, KnobType, Manifest};
pub use study::{records_from_reports, Study, StudyTrial};
